//! Quickstart: train an activity-sparse EGRU with exact sparse RTRL on the
//! paper's spiral task, with 80% parameter sparsity, and print the learning
//! curve plus the measured compute savings.
//!
//! Run: `cargo run --release --example quickstart`

use sparse_rtrl::config::{AlgorithmKind, ExperimentConfig};
use sparse_rtrl::metrics::Phase;
use sparse_rtrl::report::ascii_plot;
use sparse_rtrl::train::{build_dataset, Trainer};

fn main() {
    // Paper §6 setup, shortened: EGRU n=16, Adam, batch 32; ω = 0.8.
    let mut cfg = ExperimentConfig::default();
    cfg.name = "quickstart".into();
    cfg.task.num_sequences = 2000;
    cfg.train.iterations = 300;
    cfg.train.log_every = 10;
    cfg.train.eval_every = 50;
    cfg.model.param_sparsity = 0.8;
    cfg.train.algorithm = AlgorithmKind::RtrlBoth;

    println!("config:\n{}", cfg.to_toml());
    let mut data_rng = Trainer::data_rng(cfg.seed);
    let (train, val) = build_dataset(&cfg, &mut data_rng);
    println!("dataset: {} train / {} val spirals of length {}", train.len(), val.len(), cfg.task.timesteps);

    let mut trainer = Trainer::new(cfg);
    let t0 = std::time::Instant::now();
    let out = trainer.train(&train, &val);
    let secs = t0.elapsed().as_secs_f64();

    // learning curve
    let loss_series: Vec<(f64, f64)> = out
        .curve
        .points
        .iter()
        .map(|p| (p.iteration as f64, p.loss as f64))
        .collect();
    let acc_series: Vec<(f64, f64)> = out
        .curve
        .points
        .iter()
        .filter_map(|p| p.val_accuracy.map(|v| (p.iteration as f64, v as f64)))
        .collect();
    println!("{}", ascii_plot::plot(&[("train loss", loss_series)], 72, 12, "loss vs iteration"));
    println!("{}", ascii_plot::plot(&[("val accuracy", acc_series)], 72, 10, "validation accuracy"));

    let last = out.curve.points.last().unwrap();
    println!("final val accuracy: {:.3}", out.final_val_accuracy);
    println!("activity sparsity α = {:.2}, derivative sparsity β = {:.2}", last.alpha, last.beta);
    println!("influence-matrix sparsity = {:.2}", last.influence_sparsity);
    println!(
        "influence-update MACs: {} (dense RTRL would need ~{} — {:.1}x saving)",
        out.ops.macs_in(Phase::InfluenceUpdate),
        {
            // dense cost: iterations × batch × T × n²p
            let n = 16u64;
            let p = 2 * 16 * (2 + 16 + 1) as u64;
            300u64 * 32 * 17 * n * n * p
        },
        (300u64 * 32 * 17 * 16 * 16 * (2 * 16 * 19) as u64) as f64
            / out.ops.macs_in(Phase::InfluenceUpdate) as f64
    );
    println!("wallclock: {secs:.1}s");
}
