//! Fig. 3 reproduction driver: the full {activity}×{ω}×{seeds} grid on the
//! spiral task, writing `results/fig3_runs.csv` + `results/fig3_summary.csv`
//! and rendering all six panels as ASCII plots.
//!
//! Full paper scale (≈40 runs × 1700 iterations) takes a while; the defaults
//! here are a faithful-but-faster protocol. Override via flags:
//!
//! `cargo run --release --example fig3_sweep -- --iterations 1700 --sequences 10000 --seeds 5`

use sparse_rtrl::config::ExperimentConfig;
use sparse_rtrl::coordinator::{run_sweep, SweepPlan, SweepResult};
use sparse_rtrl::report::ascii_plot;
use sparse_rtrl::report::csv::write_text;
use sparse_rtrl::util::cli::Args;
use std::path::PathBuf;

fn panel(
    result: &SweepResult,
    activity: bool,
    x_compute: bool,
    title: &str,
    val_axis: bool,
) -> String {
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for (act, omega, layers) in result.arms() {
        if act != activity {
            continue;
        }
        let pts = result.aggregate(act, omega, layers);
        let data: Vec<(f64, f64)> = pts
            .iter()
            .filter(|p| !val_axis || p.val_accuracy_mean > 0.0)
            .map(|p| {
                let x = if x_compute { p.compute_adjusted_mean } else { p.iteration as f64 };
                let y = if val_axis { p.val_accuracy_mean as f64 } else { p.loss_mean as f64 };
                (x, y)
            })
            .collect();
        series.push((format!("ω={omega}"), data));
    }
    let named: Vec<(&str, Vec<(f64, f64)>)> =
        series.iter().map(|(n, d)| (n.as_str(), d.clone())).collect();
    ascii_plot::plot(&named, 76, 14, title)
}

fn main() {
    let mut args = Args::from_env().expect("args");
    let mut base = ExperimentConfig::default();
    base.train.iterations = args.get_parse("iterations", 400u64).expect("iterations");
    base.task.num_sequences = args.get_parse("sequences", 4000usize).expect("sequences");
    base.train.log_every = args.get_parse("log-every", 10u64).expect("log-every");
    base.train.eval_every = args.get_parse("eval-every", 25u64).expect("eval-every");
    let seeds: usize = args.get_parse("seeds", 5).expect("seeds");
    let workers: usize = args.get_parse("workers", 0).expect("workers");
    let layers: usize = args.get_parse("layers", 1).expect("layers");
    assert!(layers >= 1, "--layers must be ≥ 1");
    let out_dir: PathBuf = args.get("out-dir").unwrap_or_else(|| "results".into()).into();
    args.finish().expect("flags");

    let mut plan = SweepPlan::fig3(base, seeds);
    plan.max_workers = workers;
    plan.layers = vec![layers];
    eprintln!(
        "Fig 3 sweep: {} runs ({} iterations each) on {} workers",
        plan.expand().len(),
        plan.base.train.iterations,
        if plan.max_workers == 0 { "all".to_string() } else { plan.max_workers.to_string() }
    );
    let t0 = std::time::Instant::now();
    let result = run_sweep(&plan, true);
    eprintln!("sweep finished in {:.1}s", t0.elapsed().as_secs_f64());

    write_text(&out_dir.join("fig3_runs.csv"), &result.to_long_csv()).expect("write runs csv");
    write_text(&out_dir.join("fig3_summary.csv"), &result.to_summary_csv())
        .expect("write summary csv");

    // Panels A–F
    println!("{}", panel(&result, true, false, "Fig 3A: EGRU (activity sparse) — val acc vs iteration", true));
    println!("{}", panel(&result, true, true, "Fig 3B: EGRU — val acc vs compute-adjusted iteration (cum ω̃²β̃²)", true));
    // C: activity sparsity over training
    {
        let mut series = Vec::new();
        for (act, omega, layers) in result.arms() {
            if !act {
                continue;
            }
            let pts = result.aggregate(act, omega, layers);
            series.push((
                format!("α ω={omega}"),
                pts.iter().map(|p| (p.iteration as f64, p.alpha_mean as f64)).collect::<Vec<_>>(),
            ));
            series.push((
                format!("β ω={omega}"),
                pts.iter().map(|p| (p.iteration as f64, p.beta_mean as f64)).collect::<Vec<_>>(),
            ));
        }
        let named: Vec<(&str, Vec<(f64, f64)>)> =
            series.iter().map(|(n, d)| (n.as_str(), d.clone())).collect();
        println!("{}", ascii_plot::plot(&named, 76, 14, "Fig 3C: activity (α) and derivative (β) sparsity"));
    }
    // D: influence matrix sparsity
    {
        let mut series = Vec::new();
        for (act, omega, layers) in result.arms() {
            if !act {
                continue;
            }
            let pts = result.aggregate(act, omega, layers);
            series.push((
                format!("ω={omega}"),
                pts.iter()
                    .map(|p| (p.iteration as f64, p.influence_sparsity_mean as f64))
                    .collect::<Vec<_>>(),
            ));
        }
        let named: Vec<(&str, Vec<(f64, f64)>)> =
            series.iter().map(|(n, d)| (n.as_str(), d.clone())).collect();
        println!("{}", ascii_plot::plot(&named, 76, 14, "Fig 3D: influence-matrix sparsity"));
    }
    println!("{}", panel(&result, false, false, "Fig 3E: gated-tanh (no activity sparsity) — val acc vs iteration", true));
    println!("{}", panel(&result, false, true, "Fig 3F: gated-tanh — val acc vs compute-adjusted iteration (cum ω̃²)", true));

    // Headline check: which arm converges with least total compute?
    println!("\ncompute-to-85%-val-accuracy (compute-adjusted iterations, lower is better):");
    for (act, omega, layers) in result.arms() {
        let runs: Vec<_> = result
            .runs
            .iter()
            .filter(|r| r.activity == act && (r.omega - omega).abs() < 1e-6 && r.layers == layers)
            .collect();
        let costs: Vec<f64> =
            runs.iter().filter_map(|r| r.curve.compute_to_accuracy(0.85)).collect();
        let label = format!("{} ω={omega} L={layers}", if act { "EGRU " } else { "tanh " });
        if costs.is_empty() {
            println!("  {label:<16} never reached");
        } else {
            let mean = costs.iter().sum::<f64>() / costs.len() as f64;
            println!("  {label:<16} {:>10.2}  ({}/{} runs reached)", mean, costs.len(), runs.len());
        }
    }
}
