//! Gradient oracle: demonstrate the paper's exactness claim live, across
//! all three layers of the stack.
//!
//! 1. Runs one supervised sequence through every gradient engine (dense
//!    RTRL, the three sparse RTRL modes, SnAp-1/2, BPTT) on identical
//!    weights and data; prints the max deviation of each from dense RTRL —
//!    the exact engines agree to float tolerance, the SnAp approximations
//!    visibly do not.
//! 2. If `artifacts/` is built, additionally replays the forward + influence
//!    update through the AOT-compiled JAX/Pallas graph via PJRT and checks
//!    the Rust influence matrix against XLA's.
//!
//! Run: `cargo run --release --example gradient_oracle`

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::metrics::{OpCounter, Phase};
use sparse_rtrl::nn::{CellScratch, LayerStack, Loss, LossKind, Readout, RnnCell};
use sparse_rtrl::rtrl::{GradientEngine, Target};
use sparse_rtrl::runtime::{artifacts::names, ArtifactSet, PjrtRuntime};
use sparse_rtrl::sparse::MaskPattern;
use sparse_rtrl::train::build_engine;
use sparse_rtrl::util::Pcg64;

/// Run every engine over one supervised sequence on a stack and print max
/// gradient deviation vs dense RTRL plus the influence-MAC ratios.
fn oracle_table(net: &LayerStack, title: &str) {
    println!("{title}");
    let mut xrng = Pcg64::new(7);
    let seq: Vec<[f32; 2]> = (0..17).map(|_| [xrng.normal(), xrng.normal()]).collect();

    let run = |kind: AlgorithmKind| -> (Vec<f32>, u64) {
        let mut rrng = Pcg64::new(99);
        let mut readout = Readout::new(2, net.top_n(), &mut rrng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops = OpCounter::new();
        let mut eng = build_engine(kind, net, 2);
        eng.begin_sequence();
        for (t, x) in seq.iter().enumerate() {
            let target = if t == 8 || t == 16 { Target::Class(t % 2) } else { Target::None };
            eng.step(net, &mut readout, &mut loss, x, target, &mut ops);
        }
        eng.end_sequence(net, &mut readout, &mut ops);
        (eng.grads().to_vec(), ops.macs_in(Phase::InfluenceUpdate))
    };

    let (g_ref, macs_ref) = run(AlgorithmKind::RtrlDense);
    println!(
        "{:<16}{:>18}{:>16}{:>12}",
        "engine", "max |Δgrad| vs dense", "influence MACs", "vs dense"
    );
    println!("{:<16}{:>18}{:>16}{:>12}", "rtrl-dense", "—", macs_ref, "1.000");
    for kind in [
        AlgorithmKind::RtrlActivity,
        AlgorithmKind::RtrlParam,
        AlgorithmKind::RtrlBoth,
        AlgorithmKind::Bptt,
        AlgorithmKind::Snap1,
        AlgorithmKind::Snap2,
    ] {
        let (g, macs) = run(kind);
        let max_d = g_ref
            .iter()
            .zip(&g)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!(
            "{:<16}{:>18.3e}{:>16}{:>12.3}",
            kind.name(),
            max_d,
            macs,
            macs as f64 / macs_ref as f64
        );
    }
}

fn main() {
    let n = 16;
    let n_in = 2;
    let mut rng = Pcg64::new(2024);
    let mask = MaskPattern::random(n, n, 0.3, &mut rng);
    let net = LayerStack::single(RnnCell::egru(n, n_in, 0.1, 0.3, 0.5, Some(mask), &mut rng));
    oracle_table(
        &net,
        &format!(
            "EGRU n={n}, P={}, ω̃={:.2} — one 17-step supervised sequence\n",
            net.p(),
            net.omega_tilde()
        ),
    );

    // Depth: same check on a 2-layer stack — exactness survives the block
    // lower-bidiagonal recursion (SnAp rows diverge more: their per-layer
    // truncation drops cross-layer temporal paths too).
    let mask0 = MaskPattern::random(n, n, 0.3, &mut rng);
    let mask1 = MaskPattern::random(n, n, 0.3, &mut rng);
    let l0 = RnnCell::egru(n, n_in, 0.1, 0.3, 0.5, Some(mask0), &mut rng);
    let l1 = RnnCell::egru(n, n, 0.1, 0.3, 0.5, Some(mask1), &mut rng);
    let net2 = LayerStack::new(vec![l0, l1]);
    oracle_table(
        &net2,
        &format!(
            "\n2-layer EGRU n={n}×2, P={}, ω̃={:.2} — same sequence, stacked\n",
            net2.p(),
            net2.omega_tilde()
        ),
    );
    println!("\nexact engines match to float tolerance; SnAp rows are the approximations.");

    // ---- Layer-crossing check via PJRT --------------------------------
    let set = ArtifactSet::default_location();
    if !set.has(names::RTRL_STEP) {
        println!("\n(artifacts not built — `make artifacts` to enable the XLA cross-check)");
        return;
    }
    if !PjrtRuntime::available() {
        println!(
            "\n(PJRT support not compiled in — add the `xla` dep to rust/Cargo.toml and \
             rebuild with `--features pjrt`)"
        );
        return;
    }
    println!("\nXLA cross-check (AOT JAX/Pallas graph via PJRT):");
    let rt = PjrtRuntime::cpu().expect("pjrt");
    let exe = rt.load(&set.path(names::RTRL_STEP)).expect("compile rtrl_step");
    // dense cell matching the artifact's baked constants
    let info = set.info(names::RTRL_STEP).expect("manifest");
    let an = info.meta["n"] as usize;
    let ain = info.meta["n_in"] as usize;
    let mut arng = Pcg64::new(5);
    let mut acell = RnnCell::egru(an, ain, info.meta["theta"] as f32, info.meta["gamma"] as f32, info.meta["eps"] as f32, None, &mut arng);
    let mut wrng = Pcg64::new(31);
    for w in acell.params_mut() {
        *w = wrng.uniform(-0.4, 0.4);
    }
    let p = acell.p();
    let a_prev: Vec<f32> = (0..an).map(|_| if wrng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
    let x: Vec<f32> = (0..ain).map(|_| wrng.normal()).collect();
    let m_prev: Vec<f32> = (0..an * p).map(|_| wrng.uniform(-0.05, 0.05)).collect();
    let layout = acell.layout();
    let mut inputs: Vec<(Vec<usize>, Vec<f32>)> = vec![
        (vec![an], a_prev.clone()),
        (vec![ain], x.clone()),
        (vec![an, p], m_prev.clone()),
    ];
    for b in 0..layout.blocks().len() {
        let blk = &layout.blocks()[b];
        let shape = if blk.cols == 1 { vec![blk.rows] } else { vec![blk.rows, blk.cols] };
        inputs.push((shape, layout.block(acell.params(), b).to_vec()));
    }
    let refs: Vec<(&[usize], &[f32])> = inputs.iter().map(|(s, d)| (s.as_slice(), d.as_slice())).collect();
    let outs = exe.run_f32(&refs).expect("execute");
    // rust dense update on the same M
    let mut scratch = CellScratch::new(an);
    let mut ops = OpCounter::new();
    acell.forward(&a_prev, &x, &mut scratch, &mut ops);
    let mut m_next = vec![0.0f32; an * p];
    for k in 0..an {
        for l in 0..an {
            let jv = acell.dv_da(&scratch, k, l);
            for pi in 0..p {
                m_next[k * p + pi] += jv * m_prev[l * p + pi];
            }
        }
        let row = &mut m_next[k * p..(k + 1) * p];
        acell.immediate_row(&scratch, &a_prev, &x, k, |pi, val| row[pi] += val, &mut ops);
        for v in row.iter_mut() {
            *v *= scratch.dphi[k];
        }
    }
    let worst = m_next
        .iter()
        .zip(&outs[1])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("  max |Δ| rust influence update vs XLA: {worst:.3e}");
    assert!(worst < 5e-4);
    println!("  three-layer stack agrees.");
}
