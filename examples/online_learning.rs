//! Online learning — the capability RTRL exists for (and BPTT lacks):
//! learn from an *infinite stream* with updates at every step, no sequence
//! boundaries, no stored history, memory independent of stream length.
//!
//! Task: temporal parity over a sliding window (data::stream). The EGRU is
//! updated online from per-step losses; accuracy is reported over trailing
//! windows, demonstrating continual improvement. An equivalent BPTT learner
//! would need the entire (unbounded) history.
//!
//! Run: `cargo run --release --example online_learning`

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::data::stream::ParityStream;
use sparse_rtrl::data::StepTarget;
use sparse_rtrl::metrics::OpCounter;
use sparse_rtrl::nn::{LayerStack, Loss, LossKind, Readout, RnnCell};
use sparse_rtrl::optim::{Adam, Optimizer};
use sparse_rtrl::rtrl::GradientEngine;
use sparse_rtrl::sparse::MaskPattern;
use sparse_rtrl::train::build_engine;
use sparse_rtrl::util::cli::Args;
use sparse_rtrl::util::Pcg64;

fn main() {
    let mut args = Args::from_env().expect("args");
    let steps: u64 = args.get_parse("steps", 60_000).expect("steps");
    let window: usize = args.get_parse("window", 3).expect("window");
    let omega: f32 = args.get_parse("omega", 0.5).expect("omega");
    let layers: usize = args.get_parse("layers", 1).expect("layers");
    let lr: f32 = args.get_parse("lr", 0.003).expect("lr");
    args.finish().expect("flags");
    assert!(layers >= 1, "--layers must be ≥ 1");

    let n = 24;
    let mut rng = Pcg64::new(42);
    let mut cells = Vec::with_capacity(layers);
    for l in 0..layers {
        let n_in = if l == 0 { 1 } else { n };
        let mask = if omega > 0.0 {
            Some(MaskPattern::random(n, n, 1.0 - omega, &mut rng))
        } else {
            None
        };
        cells.push(RnnCell::egru(n, n_in, 0.0, 0.3, 0.6, mask, &mut rng));
    }
    let mut net = LayerStack::new(cells);
    let n_total = net.total_units();
    let mut readout = Readout::new(2, net.top_n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut engine = build_engine(AlgorithmKind::RtrlBoth, &net, 2);
    let mut opt_cell = Adam::new(net.p(), lr);
    let mut opt_readout = Adam::new(readout.param_len(), lr);
    let mut cell_params = vec![0.0f32; net.p()];
    let mut ops = OpCounter::new();

    let mut stream = ParityStream::new(window, 7);
    println!(
        "online temporal-parity(window={window}): EGRU n={n}×L{layers}, ω={omega}, RTRL updates every step"
    );
    println!("{:<12}{:>10}{:>12}{:>10}{:>10}{:>16}", "steps", "acc@5k", "loss@5k", "α", "β", "influence MACs");

    // One endless sequence: begin once, never reset — that's the point.
    engine.begin_sequence();
    let mut correct = 0u64;
    let mut seen = 0u64;
    let mut loss_sum = 0.0f64;
    let mut alpha_sum = 0.0f64;
    let mut beta_sum = 0.0f64;
    let mut rp = vec![0.0f32; readout.param_len()];
    let mut rg = vec![0.0f32; readout.param_len()];
    for step in 1..=steps {
        let (x, target) = stream.next_step();
        let t = match &target {
            StepTarget::Class(c) => sparse_rtrl::rtrl::Target::Class(*c),
            _ => sparse_rtrl::rtrl::Target::None,
        };
        let r = engine.step(&net, &mut readout, &mut loss, &x, t, &mut ops);
        alpha_sum += 1.0 - r.active_units as f64 / n_total as f64;
        beta_sum += 1.0 - r.deriv_units as f64 / n_total as f64;
        if let (Some(l), Some(c)) = (r.loss, r.correct) {
            loss_sum += l as f64;
            seen += 1;
            if c {
                correct += 1;
            }
            // online update from the *running* gradient: apply and clear
            // every step (pure online regime, batch size 1, T_grad = 1)
            engine.end_sequence(&net, &mut readout, &mut ops);
            net.copy_params_into(&mut cell_params);
            opt_cell.update(&mut cell_params, engine.grads());
            net.load_params(&cell_params);
            net.enforce_masks();
            readout.copy_params_into(&mut rp);
            readout.copy_grads_into(&mut rg);
            opt_readout.update(&mut rp, &rg);
            readout.load_params(&rp);
            readout.zero_grads();
            engine.reset_grads();
        }
        if step % 5000 == 0 {
            println!(
                "{:<12}{:>10.3}{:>12.4}{:>10.2}{:>10.2}{:>16}",
                step,
                correct as f64 / seen.max(1) as f64,
                loss_sum / seen.max(1) as f64,
                alpha_sum / 5000.0,
                beta_sum / 5000.0,
                ops.macs_in(sparse_rtrl::metrics::Phase::InfluenceUpdate),
            );
            correct = 0;
            seen = 0;
            loss_sum = 0.0;
            alpha_sum = 0.0;
            beta_sum = 0.0;
        }
    }
    println!(
        "\nstate memory: {} words — constant in stream length (BPTT would need ~{} words of history by now)",
        engine.state_memory_words(),
        steps as usize * (1 + 9 * n_total)
    );
}
