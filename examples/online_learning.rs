//! Online learning — the capability RTRL exists for (and BPTT lacks):
//! learn from an *infinite stream* with updates at every step, no sequence
//! boundaries, no stored history, memory independent of stream length.
//!
//! Built on the streaming session API: an [`OnlineSession`] with
//! `UpdatePolicy::EveryKSteps(1)` consumes the stream one `step(x, target)`
//! at a time and applies a parameter update at every supervised step.
//! Midway through, the session is checkpointed through the snapshot codec
//! facade (binary container) and resumed — the stream continues
//! bit-exactly, demonstrating live-session migration.
//!
//! Task: temporal parity over a sliding window (data::stream).
//!
//! Run: `cargo run --release --example online_learning`

use sparse_rtrl::config::{AlgorithmKind, ExperimentConfig};
use sparse_rtrl::data::stream::ParityStream;
use sparse_rtrl::data::StepTarget;
use sparse_rtrl::metrics::Phase;
use sparse_rtrl::session::{codec, OnlineSession, SessionBuilder, SnapshotFormat, UpdatePolicy};
use sparse_rtrl::util::cli::Args;

fn main() {
    let mut args = Args::from_env().expect("args");
    let steps: u64 = args.get_parse("steps", 60_000).expect("steps");
    let window: usize = args.get_parse("window", 3).expect("window");
    let omega: f32 = args.get_parse("omega", 0.5).expect("omega");
    let layers: usize = args.get_parse("layers", 1).expect("layers");
    let lr: f32 = args.get_parse("lr", 0.003).expect("lr");
    args.finish().expect("flags");
    assert!(layers >= 1, "--layers must be ≥ 1");

    let n = 24;
    // The parity stream is 1-input; describe the network via the config so
    // the session is checkpointable.
    let mut cfg = ExperimentConfig::default();
    cfg.name = "online-parity".into();
    cfg.model.hidden = n;
    cfg.model.layers = layers;
    cfg.model.theta = 0.0;
    cfg.model.gamma = 0.3;
    cfg.model.eps = 0.6;
    cfg.model.param_sparsity = omega;
    cfg.train.lr = lr;
    cfg.seed = 42;
    // the bundled tasks are 2-input; parity is 1-input, so pad below
    let mut session = SessionBuilder::from_config(cfg)
        .algorithm(AlgorithmKind::RtrlBoth)
        .policy(UpdatePolicy::EveryKSteps(1))
        .build();
    let n_total = session.net().total_units();
    let n_in = session.net().n_in();

    let mut stream = ParityStream::new(window, 7);
    println!(
        "online temporal-parity(window={window}): EGRU n={n}×L{layers}, ω={omega}, \
         RTRL update every supervised step"
    );
    println!(
        "{:<12}{:>10}{:>12}{:>10}{:>10}{:>16}",
        "steps", "acc@5k", "loss@5k", "α", "β", "influence MACs"
    );

    // One endless stream: no begin/end_sequence anywhere — that's the point.
    let mut correct = 0u64;
    let mut seen = 0u64;
    let mut loss_sum = 0.0f64;
    let mut alpha_sum = 0.0f64;
    let mut beta_sum = 0.0f64;
    for step in 1..=steps {
        let (bits, target) = stream.next_step();
        // pad the 1-channel parity input up to the config's input width
        let mut x = vec![0.0f32; n_in];
        x[0] = bits[0];
        let t = match &target {
            StepTarget::Class(c) => sparse_rtrl::rtrl::Target::Class(*c),
            _ => sparse_rtrl::rtrl::Target::None,
        };
        let o = session.step(&x, t);
        alpha_sum += 1.0 - o.active_units as f64 / n_total as f64;
        beta_sum += 1.0 - o.deriv_units as f64 / n_total as f64;
        if let (Some(l), Some(c)) = (o.loss, o.correct) {
            loss_sum += l as f64;
            seen += 1;
            if c {
                correct += 1;
            }
        }
        if step == steps / 2 {
            // live migration: encode → decode → resume, mid-stream, through
            // the snapshot codec facade (`step` starts at 1, so this fires
            // exactly once)
            let bytes = codec::encode(&session.checkpoint(), SnapshotFormat::Binary);
            let ck = codec::decode(&bytes).expect("snapshot decodes");
            session = OnlineSession::resume(&ck).expect("session resumes");
            println!(
                "-- checkpointed + resumed at step {step} ({} bytes, binary snapshot) --",
                bytes.len()
            );
        }
        if step % 5000 == 0 {
            println!(
                "{:<12}{:>10.3}{:>12.4}{:>10.2}{:>10.2}{:>16}",
                step,
                correct as f64 / seen.max(1) as f64,
                loss_sum / seen.max(1) as f64,
                alpha_sum / 5000.0,
                beta_sum / 5000.0,
                session.ops.macs_in(Phase::InfluenceUpdate),
            );
            correct = 0;
            seen = 0;
            loss_sum = 0.0;
            alpha_sum = 0.0;
            beta_sum = 0.0;
        }
    }
    println!(
        "\nstate memory: {} words — constant in stream length (BPTT would need ~{} words of \
         history by now)",
        session.state_memory_words(),
        steps as usize * (1 + 9 * n_total)
    );
}
