"""The paper's exactness claim, verified independently in JAX: the RTRL
influence recursion reproduces the gradient jax.grad computes through the
unrolled graph (BPTT-by-autodiff), using a straight-through Heaviside with
the paper's triangular pseudo-derivative.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

THETA, GAMMA, EPS = 0.1, 0.3, 0.5


@jax.custom_jvp
def heaviside_st(v):
    return (v > 0.0).astype(v.dtype)


@heaviside_st.defjvp
def _heaviside_jvp(primals, tangents):
    (v,), (dv,) = primals, tangents
    return heaviside_st(v), ref.pseudo_derivative(v, GAMMA, EPS) * dv


def egru_cell_st(a_prev, x, Wu, Vu, bu, Wz, Vz, bz):
    """Differentiable (surrogate) EGRU cell for autodiff-BPTT."""
    u = jax.nn.sigmoid(x @ Wu.T + a_prev @ Vu.T + bu)
    z = jnp.tanh(x @ Wz.T + a_prev @ Vz.T + bz)
    v = u * z - THETA
    return heaviside_st(v)


def rand_setup(seed, n=6, n_in=2, t=5):
    rng = np.random.default_rng(seed)
    params = tuple(
        jnp.asarray(rng.uniform(-0.5, 0.5, s), jnp.float32)
        for s in [(n, n_in), (n, n), (n,), (n, n_in), (n, n), (n,)]
    )
    xs = jnp.asarray(rng.normal(0, 1, (t, n_in)), jnp.float32)
    wo = jnp.asarray(rng.uniform(-0.5, 0.5, (2, n)), jnp.float32)
    bo = jnp.asarray(rng.uniform(-0.1, 0.1, 2), jnp.float32)
    # supervise the middle and final step
    targets = np.zeros((t, 2), np.float32)
    targets[t // 2, seed % 2] = 1.0
    targets[t - 1, (seed + 1) % 2] = 1.0
    return params, xs, wo, bo, jnp.asarray(targets)


def bptt_grad(params, xs, wo, bo, targets, n):
    """jax.grad through the unrolled surrogate graph, flat layout."""

    def loss_fn(flat):
        sizes = [p.size for p in params]
        shapes = [p.shape for p in params]
        parts = []
        o = 0
        for s, sh in zip(sizes, shapes):
            parts.append(flat[o : o + s].reshape(sh))
            o += s
        a = jnp.zeros((n,), jnp.float32)
        total = 0.0
        for t in range(xs.shape[0]):
            a = egru_cell_st(a, xs[t], *parts)
            has_loss = targets[t].sum() > 0
            logits = wo @ a + bo
            logz = jax.nn.logsumexp(logits)
            loss_t = logz - jnp.sum(targets[t] * logits)
            total = total + jnp.where(has_loss, loss_t, 0.0)
        return total

    flat = jnp.concatenate([p.reshape(-1) for p in params])
    return jax.grad(loss_fn)(flat)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_rtrl_equals_autodiff_bptt(seed):
    n = 6
    params, xs, wo, bo, targets = rand_setup(seed, n=n)
    p = ref.param_count(n, 2)
    m0 = jnp.zeros((n, p), jnp.float32)
    a0 = jnp.zeros((n,), jnp.float32)
    _loss, g_rtrl = model.rtrl_sequence_grad(
        xs, targets, m0, a0, params, wo, bo, THETA, GAMMA, EPS
    )
    g_bptt = bptt_grad(params, xs, wo, bo, targets, n)
    np.testing.assert_allclose(np.asarray(g_rtrl), np.asarray(g_bptt), rtol=2e-3, atol=2e-5)


def test_rtrl_loss_positive_and_grad_nonzero():
    params, xs, wo, bo, targets = rand_setup(3)
    n = 6
    p = ref.param_count(n, 2)
    loss, g = model.rtrl_sequence_grad(
        xs, targets, jnp.zeros((n, p), jnp.float32), jnp.zeros((n,), jnp.float32),
        params, wo, bo, THETA, GAMMA, EPS,
    )
    assert float(loss) > 0.0
    assert np.abs(np.asarray(g)).max() > 0.0
