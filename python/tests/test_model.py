"""L2 correctness: the exported model graphs and the AOT pipeline."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand_params(rng, n, n_in, scale=0.4):
    return [
        jnp.asarray(rng.uniform(-scale, scale, (n, n_in)), jnp.float32),
        jnp.asarray(rng.uniform(-scale, scale, (n, n)), jnp.float32),
        jnp.asarray(rng.uniform(-scale, scale, (n,)), jnp.float32),
        jnp.asarray(rng.uniform(-scale, scale, (n, n_in)), jnp.float32),
        jnp.asarray(rng.uniform(-scale, scale, (n, n)), jnp.float32),
        jnp.asarray(rng.uniform(-scale, scale, (n,)), jnp.float32),
    ]


def test_egru_step_shapes_and_values():
    rng = np.random.default_rng(1)
    step = model.make_egru_step(0.1, 0.3, 0.5)
    params = rand_params(rng, aot.N, aot.N_IN)
    a_prev = jnp.asarray(rng.integers(0, 2, (aot.BATCH, aot.N)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (aot.BATCH, aot.N_IN)), jnp.float32)
    a, v, dphi = step(a_prev, x, *params)
    assert a.shape == (aot.BATCH, aot.N)
    ar, vr, dr, *_ = ref.egru_cell(a_prev, x, *params, 0.1, 0.3, 0.5)
    np.testing.assert_allclose(a, ar, atol=0)
    np.testing.assert_allclose(v, vr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dphi, dr, rtol=1e-5, atol=1e-6)


def test_rtrl_step_matches_pure_ref():
    rng = np.random.default_rng(2)
    n, n_in = 8, 2
    p = ref.param_count(n, n_in)
    step = model.make_rtrl_step(0.1, 0.3, 0.5)
    params = rand_params(rng, n, n_in)
    a_prev = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, n_in), jnp.float32)
    m_prev = jnp.asarray(rng.normal(0, 0.05, (n, p)), jnp.float32)
    a, m_next = step(a_prev, x, m_prev, *params)
    ar, mr = ref.rtrl_step(a_prev, x, m_prev, *params, 0.1, 0.3, 0.5)
    np.testing.assert_allclose(a, ar, atol=0)
    np.testing.assert_allclose(m_next, mr, rtol=1e-4, atol=1e-6)


def test_immediate_influence_structure():
    """Mbar only touches unit k's fan-in slots — the 'default sparsity'."""
    rng = np.random.default_rng(3)
    n, n_in = 6, 2
    a_prev = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, n_in), jnp.float32)
    gu = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    gz = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    mbar = np.asarray(ref.immediate_influence(a_prev, x, gu, gz))
    # block offsets in the flat layout
    off = [0, n * n_in, n * n_in + n * n, n * (n_in + n + 1)]
    for k in range(n):
        for pi in range(mbar.shape[1]):
            half = pi % (n * (n_in + n + 1))
            if half < n * n_in:
                owner = half // n_in
            elif half < n * n_in + n * n:
                owner = (half - n * n_in) // n
            else:
                owner = half - n * n_in - n * n
            if owner != k:
                assert mbar[k, pi] == 0.0, f"Mbar[{k},{pi}] leaked outside fan-in"


def test_rtrl_step_influence_matches_autodiff_jacobian():
    """Jhat from ref must equal jax.jacobian of the pre-activation v
    w.r.t. a_prev (the smooth part of Eq. 6)."""
    rng = np.random.default_rng(4)
    n, n_in = 5, 2
    params = rand_params(rng, n, n_in)
    x = jnp.asarray(rng.normal(0, 1, n_in), jnp.float32)
    a_prev = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)

    def v_of_a(a):
        _a, v, *_ = ref.egru_cell(a, x, *params, 0.1, 0.3, 0.5)
        return v

    jac = jax.jacobian(v_of_a)(a_prev)
    _a, _v, _d, _u, _z, gu, gz = ref.egru_cell(a_prev, x, *params, 0.1, 0.3, 0.5)
    jhat = ref.jacobian_hat(gu, gz, params[1], params[4])
    np.testing.assert_allclose(jac, jhat, rtol=1e-4, atol=1e-5)


def test_immediate_influence_matches_autodiff():
    """Mbar must equal jax.jacobian of v w.r.t. the flat parameter vector."""
    rng = np.random.default_rng(5)
    n, n_in = 4, 2
    params = rand_params(rng, n, n_in)
    x = jnp.asarray(rng.normal(0, 1, n_in), jnp.float32)
    a_prev = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)

    sizes = [n * n_in, n * n, n, n * n_in, n * n, n]
    shapes = [(n, n_in), (n, n), (n,), (n, n_in), (n, n), (n,)]

    def v_of_flat(w):
        parts = []
        o = 0
        for s, sh in zip(sizes, shapes):
            parts.append(w[o : o + s].reshape(sh))
            o += s
        _a, v, *_ = ref.egru_cell(a_prev, x, *parts, 0.1, 0.3, 0.5)
        return v

    flat = jnp.concatenate([p.reshape(-1) for p in params])
    jac = jax.jacobian(v_of_flat)(flat)
    _a, _v, _d, _u, _z, gu, gz = ref.egru_cell(a_prev, x, *params, 0.1, 0.3, 0.5)
    mbar = ref.immediate_influence(a_prev, x, gu, gz)
    np.testing.assert_allclose(jac, mbar, rtol=1e-4, atol=1e-5)


def test_aot_writes_artifacts(tmp_path):
    """The AOT pipeline produces parseable HLO text + a manifest."""
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    for name in ["egru_step", "rtrl_step", "influence_kernel"]:
        text = (out / f"{name}.hlo.txt").read_text()
        assert text.startswith("HloModule"), f"{name} not HLO text"
    manifest = (out / "manifest.txt").read_text()
    assert "egru_step" in manifest and "n=16" in manifest
