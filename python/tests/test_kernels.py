"""L1 correctness: Pallas kernels vs the pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and random contents; exact structural properties
(binary activations, zero rows under dead pseudo-derivatives, block-skip
equivalence) are asserted separately.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import egru as egru_kernel
from compile.kernels import ref
from compile.kernels import rtrl as rtrl_kernel

jax.config.update("jax_platform_name", "cpu")


def rand_params(rng, n, n_in, scale=0.5):
    return [
        jnp.asarray(rng.uniform(-scale, scale, (n, n_in)), jnp.float32),
        jnp.asarray(rng.uniform(-scale, scale, (n, n)), jnp.float32),
        jnp.asarray(rng.uniform(-scale, scale, (n,)), jnp.float32),
        jnp.asarray(rng.uniform(-scale, scale, (n, n_in)), jnp.float32),
        jnp.asarray(rng.uniform(-scale, scale, (n, n)), jnp.float32),
        jnp.asarray(rng.uniform(-scale, scale, (n,)), jnp.float32),
    ]


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([4, 8, 16]),
    n_in=st.sampled_from([1, 2, 3]),
    batch=st.sampled_from([1, 4, 8, 32]),
    seed=st.integers(0, 10_000),
)
def test_egru_kernel_matches_ref(n, n_in, batch, seed):
    rng = np.random.default_rng(seed)
    params = rand_params(rng, n, n_in)
    a_prev = jnp.asarray(rng.integers(0, 2, (batch, n)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (batch, n_in)), jnp.float32)
    a, v, dphi = egru_kernel.egru_cell_forward(
        a_prev, x, *params, theta=0.1, gamma=0.3, eps=0.5
    )
    ar, vr, dr = egru_kernel.egru_cell_reference(
        a_prev, x, *params, theta=0.1, gamma=0.3, eps=0.5
    )
    np.testing.assert_allclose(a, ar, rtol=0, atol=0)
    np.testing.assert_allclose(v, vr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dphi, dr, rtol=1e-5, atol=1e-6)


def test_egru_kernel_binary_activations():
    rng = np.random.default_rng(0)
    params = rand_params(rng, 16, 2)
    a_prev = jnp.zeros((8, 16), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (8, 2)), jnp.float32)
    a, v, dphi = egru_kernel.egru_cell_forward(
        a_prev, x, *params, theta=0.1, gamma=0.3, eps=0.5
    )
    assert set(np.unique(np.asarray(a))).issubset({0.0, 1.0})
    # dphi zero exactly where |v| > eps
    np.testing.assert_array_equal(np.asarray(dphi) == 0.0, np.abs(np.asarray(v)) > 0.5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([4, 8, 16]),
    n_in=st.sampled_from([1, 2]),
    seed=st.integers(0, 10_000),
)
def test_influence_kernel_matches_ref(n, n_in, seed):
    rng = np.random.default_rng(seed)
    p = ref.param_count(n, n_in)
    dphi = jnp.asarray(
        rng.uniform(0, 0.3, n) * rng.integers(0, 2, n), jnp.float32
    )  # some rows dead
    jhat = jnp.asarray(rng.normal(0, 0.3, (n, n)), jnp.float32)
    m_prev = jnp.asarray(rng.normal(0, 0.1, (n, p)), jnp.float32)
    mbar = jnp.asarray(rng.normal(0, 0.1, (n, p)), jnp.float32)
    out = rtrl_kernel.influence_update(dphi, jhat, m_prev, mbar)
    expect = ref.influence_update(dphi, jhat, m_prev, mbar)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-5)


def test_influence_kernel_zero_rows_where_dphi_zero():
    rng = np.random.default_rng(3)
    n, n_in = 8, 2
    p = ref.param_count(n, n_in)
    dphi = jnp.zeros((n,), jnp.float32).at[2].set(0.3).at[5].set(0.1)
    jhat = jnp.asarray(rng.normal(0, 0.3, (n, n)), jnp.float32)
    m_prev = jnp.asarray(rng.normal(0, 0.1, (n, p)), jnp.float32)
    mbar = jnp.asarray(rng.normal(0, 0.1, (n, p)), jnp.float32)
    out = np.asarray(rtrl_kernel.influence_update(dphi, jhat, m_prev, mbar))
    for k in range(n):
        if k not in (2, 5):
            assert np.all(out[k] == 0.0), f"row {k} should be zero (paper Eq. 10)"
    assert np.any(out[2] != 0.0)


def test_influence_kernel_all_dead_is_all_zero():
    n, n_in = 8, 2
    p = ref.param_count(n, n_in)
    rng = np.random.default_rng(4)
    out = rtrl_kernel.influence_update(
        jnp.zeros((n,), jnp.float32),
        jnp.asarray(rng.normal(0, 1, (n, n)), jnp.float32),
        jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32),
        jnp.asarray(rng.normal(0, 1, (n, p)), jnp.float32),
    )
    assert np.all(np.asarray(out) == 0.0)


@pytest.mark.parametrize("row_block,col_block", [(1, None), (None, 22), (2, 44), (8, None)])
def test_influence_kernel_blocking_invariant(row_block, col_block):
    """The result must not depend on the tiling."""
    rng = np.random.default_rng(5)
    n, n_in = 8, 2
    p = ref.param_count(n, n_in)
    dphi = jnp.asarray(rng.uniform(0, 0.3, n), jnp.float32)
    jhat = jnp.asarray(rng.normal(0, 0.3, (n, n)), jnp.float32)
    m_prev = jnp.asarray(rng.normal(0, 0.1, (n, p)), jnp.float32)
    mbar = jnp.asarray(rng.normal(0, 0.1, (n, p)), jnp.float32)
    base = rtrl_kernel.influence_update(dphi, jhat, m_prev, mbar)
    tiled = rtrl_kernel.influence_update(
        dphi, jhat, m_prev, mbar, row_block=row_block, col_block=col_block
    )
    np.testing.assert_allclose(base, tiled, rtol=1e-5, atol=1e-6)


def test_pick_block():
    assert rtrl_kernel.pick_block(608, 128) == 76  # 608 = 8*76
    assert rtrl_kernel.pick_block(16, 8) == 8
    assert rtrl_kernel.pick_block(7, 4) == 1
    assert rtrl_kernel.pick_block(128, 128) == 128
