"""Ensure `compile.*` imports resolve when pytest runs from the repo root,
and skip test layers cleanly when their dependencies are absent (the gated
CI job runs on runners that may not provide jax or hypothesis)."""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def _missing(module):
    return importlib.util.find_spec(module) is None


collect_ignore_glob = []
if _missing("jax"):
    # The whole layer is JAX-based.
    collect_ignore_glob.append("tests/*")
elif _missing("hypothesis"):
    # Property-based modules need hypothesis; test_model.py does not.
    collect_ignore_glob.extend(["tests/test_kernels.py", "tests/test_rtrl_math.py"])
