"""L1 Pallas kernel: the RTRL influence-matrix update — the paper's compute
hot-spot (`M ← φ' ⊙ (Ĵ·M + M̄)`, Eq. 10) as a blocked, activity-gated kernel.

TPU mapping of the paper's insight (DESIGN.md §Hardware-Adaptation):

* the `n×p` influence matrix is tiled into `(ROW_BLK × COL_BLK)` panels; the
  grid sweeps (row-block, col-panel). `Ĵ`'s `(ROW_BLK × n)` slab and one
  `(n × COL_BLK)` panel of `M_prev` feed the MXU per step;
* **activity sparsity becomes block-row skipping**: the paper zeroes whole
  rows of `J`/`M̄`/`M` where `φ'(v_k) = 0`; the kernel reduces `φ'` over its
  row block and skips the entire matmul through `@pl.when` when the block is
  inactive — the block-granular version of event-driven skipping that a
  systolic array can actually exploit (the GPU version would be a warp-level
  gather; on TPU the unit of skip is the tile);
* parameter sparsity lives *outside* the kernel: masked columns are compacted
  away before the panel sweep (the Rust engines do the same), so `p` here is
  already `ω̃p`.

interpret=True: CPU PJRT cannot run Mosaic custom-calls; the BlockSpec
schedule is still the TPU design of record.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _influence_kernel(dphi_ref, jhat_ref, mprev_ref, mbar_ref, out_ref):
    """One (row-block × col-panel) tile of M_next."""
    dphi = dphi_ref[...]
    # Block-level activity gate: all rows in this block dead ⇒ whole tile is
    # zero; skip both the MXU contraction and the M̄ add.
    active = jnp.any(dphi != 0.0)

    @pl.when(active)
    def _compute():  # pragma: no cover - traced
        jm = jhat_ref[...] @ mprev_ref[...]
        out_ref[...] = dphi[:, None] * (jm + mbar_ref[...])

    @pl.when(jnp.logical_not(active))
    def _skip():  # pragma: no cover - traced
        out_ref[...] = jnp.zeros_like(out_ref)


def pick_block(total, target):
    """Largest divisor of `total` that is ≤ target (≥ 1)."""
    best = 1
    for d in range(1, total + 1):
        if total % d == 0 and d <= target:
            best = d
    return best


def influence_update(dphi, jhat, m_prev, mbar, *, row_block=None, col_block=None):
    """Blocked Eq.-10 update. Shapes: dphi (n,), jhat (n,n), m_prev/mbar (n,p).

    Returns M_next (n, p).
    """
    n, p = m_prev.shape
    assert jhat.shape == (n, n)
    assert mbar.shape == (n, p)
    if row_block is None:
        row_block = pick_block(n, 8)
    if col_block is None:
        # MXU-friendly 128-lane panels when p allows it
        col_block = pick_block(p, 128)
    assert n % row_block == 0 and p % col_block == 0
    grid = (n // row_block, p // col_block)
    return pl.pallas_call(
        _influence_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block,), lambda i, j: (i,)),        # dphi row block
            pl.BlockSpec((row_block, n), lambda i, j: (i, 0)),    # Ĵ slab
            pl.BlockSpec((n, col_block), lambda i, j: (0, j)),    # M_prev panel
            pl.BlockSpec((row_block, col_block), lambda i, j: (i, j)),  # M̄ tile
        ],
        out_specs=pl.BlockSpec((row_block, col_block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, p), m_prev.dtype),
        interpret=True,
    )(dphi, jhat, m_prev, mbar)


def vmem_words(n, p, row_block, col_block):
    """VMEM residency per grid step (words), for the §Perf roofline estimate:
    Ĵ slab + M_prev panel + M̄ tile + out tile + dphi block."""
    return row_block * n + n * col_block + 2 * row_block * col_block + row_block
