"""Pure-jnp reference oracles for the Pallas kernels and the L2 model.

These implement exactly the mathematics of the Rust engines
(rust/src/nn/cell.rs, rust/src/rtrl/*.rs):

  EGRU cell (paper Eq. 5, gated drive):
    u    = sigmoid(x @ Wu.T + a_prev @ Vu.T + bu)
    z    = tanh   (x @ Wz.T + a_prev @ Vz.T + bz)
    v    = u * z - theta
    a    = H(v)                                  (Heaviside)
    phi' = gamma * max(0, 1 - |v| / eps)          (pseudo-derivative)
    g_u  = z * u * (1 - u)                        (u-path coefficient)
    g_z  = u * (1 - z^2)                          (z-path coefficient)

  RTRL ingredients (paper Eqns. 6-10):
    Jhat[k,l]  = g_u[k] Vu[k,l] + g_z[k] Vz[k,l]  (dv_k/da_l before phi')
    Mbar[k,p]  = dv_k/dw_p  (structured: only unit k's fan-in rows)
    M_next     = phi'[:,None] * (Jhat @ M_prev + Mbar)

Parameter flattening matches rust/src/nn/layout.rs: block-major
[Wu, Vu, bu, Wz, Vz, bz], row-major within each block, so
p = 2n(n_in + n + 1).
"""

import jax
import jax.numpy as jnp


def pseudo_derivative(v, gamma, eps):
    """Triangular surrogate gradient, zero for |v| > eps (paper Fig. 1)."""
    return gamma * jnp.maximum(0.0, 1.0 - jnp.abs(v) / eps)


def egru_cell(a_prev, x, Wu, Vu, bu, Wz, Vz, bz, theta, gamma, eps):
    """EGRU forward step. Works for batched (B,n)/(B,n_in) or single (n,)/(n_in,).

    Returns (a, v, dphi, u, z, gu, gz).
    """
    su = x @ Wu.T + a_prev @ Vu.T + bu
    sz = x @ Wz.T + a_prev @ Vz.T + bz
    u = jax.nn.sigmoid(su)
    z = jnp.tanh(sz)
    v = u * z - theta
    a = (v > 0.0).astype(v.dtype)
    dphi = pseudo_derivative(v, gamma, eps)
    gu = z * u * (1.0 - u)
    gz = u * (1.0 - z * z)
    return a, v, dphi, u, z, gu, gz


def jacobian_hat(gu, gz, Vu, Vz):
    """dv_k/da_l before the phi' row gate (single sample: gu, gz are (n,))."""
    return gu[:, None] * Vu + gz[:, None] * Vz


def immediate_influence(a_prev, x, gu, gz):
    """Dense Mbar in the flat layout [Wu, Vu, bu, Wz, Vz, bz].

    Single-sample: a_prev (n,), x (n_in,), gu/gz (n,). Returns (n, p).
    """
    n = a_prev.shape[0]
    n_in = x.shape[0]
    eye = jnp.eye(n, dtype=a_prev.dtype)

    def gate_blocks(g):
        # W block: Mbar[k, k*n_in + j] = g[k] * x[j]
        w = (eye[:, :, None] * (g[:, None, None] * x[None, None, :])).reshape(n, n * n_in)
        # V block: Mbar[k, k*n + l] = g[k] * a_prev[l]
        vblk = (eye[:, :, None] * (g[:, None, None] * a_prev[None, None, :])).reshape(n, n * n)
        # bias block: Mbar[k, k] = g[k]
        b = eye * g[:, None]
        return w, vblk, b

    wu, vu, bu_ = gate_blocks(gu)
    wz, vz, bz_ = gate_blocks(gz)
    return jnp.concatenate([wu, vu, bu_, wz, vz, bz_], axis=1)


def influence_update(dphi, jhat, m_prev, mbar):
    """Dense Eq.-10 update: M_next = phi' * (Jhat @ M_prev + Mbar)."""
    return dphi[:, None] * (jhat @ m_prev + mbar)


def rtrl_step(a_prev, x, m_prev, Wu, Vu, bu, Wz, Vz, bz, theta, gamma, eps):
    """One full single-sample RTRL step: forward + influence update.

    Returns (a, m_next).
    """
    a, _v, dphi, _u, _z, gu, gz = egru_cell(
        a_prev, x, Wu, Vu, bu, Wz, Vz, bz, theta, gamma, eps
    )
    jhat = jacobian_hat(gu, gz, Vu, Vz)
    mbar = immediate_influence(a_prev, x, gu, gz)
    m_next = influence_update(dphi, jhat, m_prev, mbar)
    return a, m_next


def param_count(n, n_in):
    """p = 2n(n_in + n + 1), the flat layout length."""
    return 2 * n * (n_in + n + 1)
