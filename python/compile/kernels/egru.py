"""L1 Pallas kernel: batched EGRU cell forward.

TPU mapping of the cell: the two gate matmuls target the MXU (one
(B_blk × n_in+n) × (n_in+n × n) contraction per gate after fusing input and
recurrent weights would be ideal; here we keep them separate to preserve the
Rust layout bit-for-bit), all elementwise gate math stays in VMEM. The grid
tiles the batch so a block's activations never leave VMEM between the
pre-activation and the threshold.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (see /opt/xla-example
README). The BlockSpec structure is still the real TPU schedule; §Perf in
DESIGN.md estimates MXU/VMEM figures from it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _cell_kernel(aprev_ref, x_ref, wu_ref, vu_ref, bu_ref, wz_ref, vz_ref, bz_ref,
                 a_ref, v_ref, dphi_ref, *, theta, gamma, eps):
    """One batch-block of the EGRU forward."""
    x = x_ref[...]
    a_prev = aprev_ref[...]
    su = x @ wu_ref[...].T + a_prev @ vu_ref[...].T + bu_ref[...][None, :]
    sz = x @ wz_ref[...].T + a_prev @ vz_ref[...].T + bz_ref[...][None, :]
    u = jax.nn.sigmoid(su)
    z = jnp.tanh(sz)
    v = u * z - theta
    a_ref[...] = (v > 0.0).astype(v.dtype)
    v_ref[...] = v
    dphi_ref[...] = gamma * jnp.maximum(0.0, 1.0 - jnp.abs(v) / eps)


def egru_cell_forward(a_prev, x, Wu, Vu, bu, Wz, Vz, bz, *, theta, gamma, eps,
                      block_batch=None):
    """Batched EGRU forward via Pallas. Returns (a, v, dphi).

    a_prev: (B, n), x: (B, n_in); weights in the Rust row-major layout.
    """
    batch, n = a_prev.shape
    n_in = x.shape[1]
    if block_batch is None:
        block_batch = batch if batch <= 32 else 32
    assert batch % block_batch == 0, "batch must divide into blocks"
    grid = (batch // block_batch,)
    out_shape = [jax.ShapeDtypeStruct((batch, n), a_prev.dtype) for _ in range(3)]
    batch_spec = pl.BlockSpec((block_batch, n), lambda i: (i, 0))
    in_specs = [
        batch_spec,                                      # a_prev
        pl.BlockSpec((block_batch, n_in), lambda i: (i, 0)),  # x
        pl.BlockSpec((n, n_in), lambda i: (0, 0)),       # Wu (resident)
        pl.BlockSpec((n, n), lambda i: (0, 0)),          # Vu
        pl.BlockSpec((n,), lambda i: (0,)),              # bu
        pl.BlockSpec((n, n_in), lambda i: (0, 0)),       # Wz
        pl.BlockSpec((n, n), lambda i: (0, 0)),          # Vz
        pl.BlockSpec((n,), lambda i: (0,)),              # bz
    ]
    out_specs = [batch_spec, batch_spec, batch_spec]
    kernel = functools.partial(_cell_kernel, theta=theta, gamma=gamma, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=True,
    )(a_prev, x, Wu, Vu, bu, Wz, Vz, bz)


def egru_cell_reference(a_prev, x, Wu, Vu, bu, Wz, Vz, bz, *, theta, gamma, eps):
    """jnp oracle with the same signature (first three outputs)."""
    a, v, dphi, *_ = ref.egru_cell(a_prev, x, Wu, Vu, bu, Wz, Vz, bz, theta, gamma, eps)
    return a, v, dphi
