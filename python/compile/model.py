"""L2: the JAX model graphs that get AOT-lowered to `artifacts/*.hlo.txt`.

Three exported computations (consumed by rust/src/runtime):

* ``egru_step``       — batched EGRU forward (calls the L1 Pallas cell kernel)
* ``rtrl_step``       — one full single-sample dense RTRL step: forward +
                        Jacobian + immediate influence + Eq.-10 update via the
                        L1 Pallas influence kernel
* ``influence_kernel``— the blocked influence update alone

These serve as (a) the dense-XLA baseline the Rust engines are benchmarked
against and (b) the independent numerical oracle for cross-validation
(rust/tests/pjrt_xval.rs). Input order and parameter layout match
rust/src/nn/layout.rs exactly.
"""

import jax.numpy as jnp

from .kernels import egru as egru_kernel
from .kernels import ref
from .kernels import rtrl as rtrl_kernel


def make_egru_step(theta, gamma, eps):
    """Batched forward step: (a_prev, x, Wu, Vu, bu, Wz, Vz, bz) → (a, v, dphi)."""

    def egru_step(a_prev, x, Wu, Vu, bu, Wz, Vz, bz):
        a, v, dphi = egru_kernel.egru_cell_forward(
            a_prev, x, Wu, Vu, bu, Wz, Vz, bz, theta=theta, gamma=gamma, eps=eps
        )
        return a, v, dphi

    return egru_step


def make_rtrl_step(theta, gamma, eps):
    """Single-sample RTRL step:
    (a_prev, x, M_prev, Wu, Vu, bu, Wz, Vz, bz) → (a, M_next).
    """

    def rtrl_step(a_prev, x, m_prev, Wu, Vu, bu, Wz, Vz, bz):
        a, _v, dphi, _u, _z, gu, gz = ref.egru_cell(
            a_prev, x, Wu, Vu, bu, Wz, Vz, bz, theta, gamma, eps
        )
        jhat = ref.jacobian_hat(gu, gz, Vu, Vz)
        mbar = ref.immediate_influence(a_prev, x, gu, gz)
        m_next = rtrl_kernel.influence_update(dphi, jhat, m_prev, mbar)
        return a, m_next

    return rtrl_step


def make_influence_kernel():
    """(dphi, jhat, m_prev, mbar) → (m_next,) via the Pallas kernel."""

    def influence(dphi, jhat, m_prev, mbar):
        return (rtrl_kernel.influence_update(dphi, jhat, m_prev, mbar),)

    return influence


def rtrl_sequence_grad(xs, targets_onehot, m0, a0, params, wo, bo, theta, gamma, eps):
    """Reference multi-step RTRL gradient over a short sequence (test-only):
    runs T steps of forward + influence update, accumulating
    grad_w = Σ_t M_tᵀ · c̄_t for softmax-CE losses at every supervised step.

    ``targets_onehot`` rows of all-zeros mean "no loss at this step".
    Returns (total_loss, grad_w flat (p,)).
    """
    Wu, Vu, bu, Wz, Vz, bz = params
    a, m = a0, m0
    p = m0.shape[1]
    grad = jnp.zeros((p,), dtype=m0.dtype)
    total = 0.0
    for t in range(xs.shape[0]):
        a_prev = a
        a, _v, dphi, _u, _z, gu, gz = ref.egru_cell(
            a_prev, xs[t], Wu, Vu, bu, Wz, Vz, bz, theta, gamma, eps
        )
        jhat = ref.jacobian_hat(gu, gz, Vu, Vz)
        mbar = ref.immediate_influence(a_prev, xs[t], gu, gz)
        m = ref.influence_update(dphi, jhat, m, mbar)
        has_loss = targets_onehot[t].sum() > 0
        logits = wo @ a + bo
        probs = jnp.exp(logits - jnp.max(logits))
        probs = probs / probs.sum()
        loss_t = -jnp.sum(targets_onehot[t] * jnp.log(jnp.maximum(probs, 1e-12)))
        dlogits = jnp.where(has_loss, probs - targets_onehot[t], jnp.zeros_like(probs))
        c_bar = wo.T @ dlogits
        grad = grad + m.T @ c_bar
        total = total + jnp.where(has_loss, loss_t, 0.0)
    return total, grad
