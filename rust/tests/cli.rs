//! CLI-level tests against the real binary (`CARGO_BIN_EXE_sparse-rtrl`):
//! unknown-option errors list the valid choices from the engine registry,
//! and the `stream` subcommand runs a session from an event file —
//! including a checkpoint/resume round-trip across *separate processes*,
//! which must reproduce the uninterrupted run bit-for-bit.

use std::path::PathBuf;
use std::process::{Command, Output};

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_sparse-rtrl"))
}

fn run(args: &[&str]) -> Output {
    bin().args(args).output().expect("binary runs")
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn stdout_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

/// Fresh per-test scratch dir (no tempdir crate in-tree).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "sparse-rtrl-cli-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

#[test]
fn unknown_subcommand_lists_valid_ones() {
    let out = run(&["frobnicate"]);
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(2));
    let err = stderr_of(&out);
    assert!(err.contains("unknown subcommand"), "{err}");
    for cmd in ["stream", "train", "sweep", "bench", "report"] {
        assert!(err.contains(cmd), "subcommand list missing {cmd}: {err}");
    }
}

#[test]
fn unknown_algorithm_lists_engine_registry() {
    let out = run(&["train", "--algorithm", "nope", "--iterations", "1"]);
    assert!(!out.status.success());
    let err = stderr_of(&out);
    assert!(err.contains("unknown algorithm"), "{err}");
    // the list comes from AlgorithmKind::all() — the same source build_engine
    // dispatches on, so every engine must appear
    for name in ["rtrl-dense", "rtrl-activity", "rtrl-param", "rtrl-both", "snap1", "snap2", "uoro", "bptt"]
    {
        assert!(err.contains(name), "algorithm list missing {name}: {err}");
    }
}

#[test]
fn stream_unknown_policy_is_rejected() {
    let out = run(&["stream", "--policy", "sometimes", "--input", "-"]);
    assert!(!out.status.success());
    let err = stderr_of(&out);
    assert!(err.contains("unknown policy"), "{err}");
    assert!(err.contains("every-k"), "{err}");
}

/// Event lines (2-input session): a mix of unsupervised and supervised
/// steps. Deterministic content so runs are reproducible.
fn event_lines(range: std::ops::Range<usize>) -> String {
    let mut s = String::new();
    for i in range {
        let a = ((i as f32) * 0.37).sin();
        let b = ((i as f32) * 0.23).cos();
        if i % 3 == 2 {
            s.push_str(&format!("{a} {b} -> {}\n", i % 2));
        } else {
            s.push_str(&format!("{a} {b}\n"));
        }
    }
    s
}

#[test]
fn stream_emits_predictions_from_an_event_file() {
    let dir = scratch("smoke");
    let events = dir.join("events.txt");
    std::fs::write(&events, format!("# smoke stream\n{}", event_lines(0..9))).unwrap();
    let out = run(&["stream", "--input", events.to_str().unwrap(), "--seed", "3"]);
    assert!(out.status.success(), "stream failed: {}", stderr_of(&out));
    let stdout = stdout_of(&out);
    let pred_lines = stdout.lines().filter(|l| l.contains("pred=")).count();
    assert_eq!(pred_lines, 9, "one prediction per step expected:\n{stdout}");
    assert!(stdout.contains("loss="), "{stdout}");
    assert!(stderr_of(&out).contains("stream done"), "{}", stderr_of(&out));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stream_reads_stdin_dash() {
    use std::io::Write as _;
    let mut child = bin()
        .args(["stream", "--input", "-", "--seed", "5"])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn");
    child
        .stdin
        .as_mut()
        .unwrap()
        .write_all(event_lines(0..4).as_bytes())
        .unwrap();
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success(), "{}", stderr_of(&out));
    assert!(stdout_of(&out).contains("pred="));
}

/// The process-boundary acceptance test: run a 24-event stream in one
/// process, then the same stream split across two processes with a
/// checkpoint in between. The resumed process must emit byte-identical
/// step/pred/loss lines for the second half.
#[test]
fn stream_checkpoint_resume_across_processes_is_exact() {
    let dir = scratch("resume");
    let all = dir.join("all.txt");
    let head = dir.join("head.txt");
    let tail = dir.join("tail.txt");
    let ck = dir.join("ck.json");
    std::fs::write(&all, event_lines(0..24)).unwrap();
    std::fs::write(&head, event_lines(0..12)).unwrap();
    std::fs::write(&tail, event_lines(12..24)).unwrap();

    let full = run(&["stream", "--input", all.to_str().unwrap(), "--seed", "9"]);
    assert!(full.status.success(), "{}", stderr_of(&full));
    let full_lines: Vec<String> = stdout_of(&full).lines().map(str::to_string).collect();
    assert_eq!(full_lines.len(), 24);

    let first = run(&[
        "stream",
        "--input",
        head.to_str().unwrap(),
        "--seed",
        "9",
        "--checkpoint",
        ck.to_str().unwrap(),
    ]);
    assert!(first.status.success(), "{}", stderr_of(&first));
    assert!(ck.exists(), "checkpoint file not written");

    let second = run(&[
        "stream",
        "--input",
        tail.to_str().unwrap(),
        "--resume",
        ck.to_str().unwrap(),
    ]);
    assert!(second.status.success(), "{}", stderr_of(&second));
    assert!(stderr_of(&second).contains("resumed session at step 12"), "{}", stderr_of(&second));
    let resumed_lines: Vec<String> = stdout_of(&second).lines().map(str::to_string).collect();
    assert_eq!(
        resumed_lines,
        &full_lines[12..],
        "resumed process diverged from the uninterrupted run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The binary snapshot at the process boundary: checkpoint to the default
/// binary format in one process, resume in another, and the second half of
/// the stream is byte-identical both to the uninterrupted run and to a
/// JSON-checkpointed twin — cross-format equivalence, end to end.
#[test]
fn stream_binary_checkpoint_matches_json_across_processes() {
    let dir = scratch("binresume");
    let all = dir.join("all.txt");
    let head = dir.join("head.txt");
    let tail = dir.join("tail.txt");
    let ck_bin = dir.join("ck.snap");
    let ck_json = dir.join("ck.json");
    std::fs::write(&all, event_lines(0..24)).unwrap();
    std::fs::write(&head, event_lines(0..12)).unwrap();
    std::fs::write(&tail, event_lines(12..24)).unwrap();

    let full = run(&["stream", "--input", all.to_str().unwrap(), "--seed", "9"]);
    assert!(full.status.success(), "{}", stderr_of(&full));
    let full_lines: Vec<String> = stdout_of(&full).lines().map(str::to_string).collect();

    for ck in [&ck_bin, &ck_json] {
        let first = run(&[
            "stream",
            "--input",
            head.to_str().unwrap(),
            "--seed",
            "9",
            "--checkpoint",
            ck.to_str().unwrap(),
        ]);
        assert!(first.status.success(), "{}", stderr_of(&first));
        assert!(stderr_of(&first).contains("checkpoint written to"), "{}", stderr_of(&first));
    }
    // `.snap` means the binary container, `.json` the debug interchange
    let bin_bytes = std::fs::read(&ck_bin).unwrap();
    let json_bytes = std::fs::read(&ck_json).unwrap();
    assert_eq!(&bin_bytes[..8], b"SRTLSNAP", "default checkpoint is not the binary container");
    assert_eq!(json_bytes[0], b'{', "ck.json is not a JSON document");
    assert!(
        bin_bytes.len() * 3 <= json_bytes.len(),
        "binary snapshot ({} B) not 3x smaller than JSON ({} B)",
        bin_bytes.len(),
        json_bytes.len()
    );

    let mut resumed = Vec::new();
    for ck in [&ck_bin, &ck_json] {
        let second = run(&[
            "stream",
            "--input",
            tail.to_str().unwrap(),
            "--resume",
            ck.to_str().unwrap(),
        ]);
        assert!(second.status.success(), "{}", stderr_of(&second));
        assert!(
            stderr_of(&second).contains("resumed session at step 12"),
            "{}",
            stderr_of(&second)
        );
        resumed.push(stdout_of(&second).lines().map(str::to_string).collect::<Vec<_>>());
    }
    assert_eq!(resumed[0], &full_lines[12..], "binary-resumed run diverged from uninterrupted");
    assert_eq!(resumed[0], resumed[1], "binary- and json-resumed runs disagree");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A malformed event is reported as `file:line: message`, counting real
/// file lines (comments and blanks included), and the process fails.
#[test]
fn stream_bad_event_reports_file_and_line() {
    let dir = scratch("badline");
    let events = dir.join("events.txt");
    std::fs::write(&events, "# header comment\n0.1 0.2\n\n0.3 bogus\n").unwrap();
    let out = run(&["stream", "--input", events.to_str().unwrap()]);
    assert!(!out.status.success(), "malformed input must fail the stream");
    let err = stderr_of(&out);
    assert!(err.contains("events.txt:4:"), "no file:line prefix: {err}");
    assert!(err.contains("bogus"), "offending token not echoed: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// JSON-lines events built from the text values, consumed via the same
/// `--input` flag.
fn jsonl_lines(range: std::ops::Range<usize>) -> String {
    let mut s = String::new();
    for i in range {
        let a = ((i as f32) * 0.37).sin();
        let b = ((i as f32) * 0.23).cos();
        if i % 3 == 2 {
            s.push_str(&format!("{{\"x\": [{a}, {b}], \"class\": {}}}\n", i % 2));
        } else {
            s.push_str(&format!("{{\"x\": [{a}, {b}]}}\n"));
        }
    }
    s
}

/// The same stream in all three event formats — text, JSON-lines, raw
/// binary frames — autodetected from the bytes, produces byte-identical
/// session output.
#[test]
fn stream_accepts_all_three_event_formats_identically() {
    use sparse_rtrl::data::StepTarget;
    use sparse_rtrl::session::{events, StreamEvent};

    let dir = scratch("formats");
    let text = dir.join("events.txt");
    let jsonl = dir.join("events.jsonl");
    let binary = dir.join("events.bin");
    std::fs::write(&text, event_lines(0..9)).unwrap();
    std::fs::write(&jsonl, jsonl_lines(0..9)).unwrap();
    let evs: Vec<StreamEvent> = (0..9)
        .map(|i| {
            let a = ((i as f32) * 0.37).sin();
            let b = ((i as f32) * 0.23).cos();
            let target =
                if i % 3 == 2 { StepTarget::Class(i % 2) } else { StepTarget::None };
            StreamEvent::Step { x: vec![a, b], target }
        })
        .collect();
    std::fs::write(&binary, events::encode_binary(&evs)).unwrap();

    let outputs: Vec<String> = [&text, &jsonl, &binary]
        .iter()
        .map(|path| {
            let out = run(&["stream", "--input", path.to_str().unwrap(), "--seed", "3"]);
            assert!(out.status.success(), "{}: {}", path.display(), stderr_of(&out));
            stdout_of(&out)
        })
        .collect();
    assert_eq!(outputs[0], outputs[1], "jsonl stream diverged from text");
    assert_eq!(outputs[0], outputs[2], "binary stream diverged from text");

    // forcing the format explicitly agrees with autodetection
    let forced = run(&[
        "stream",
        "--input",
        jsonl.to_str().unwrap(),
        "--seed",
        "3",
        "--event-format",
        "jsonl",
    ]);
    assert!(forced.status.success(), "{}", stderr_of(&forced));
    assert_eq!(stdout_of(&forced), outputs[1]);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Regression targets in text form (`-> 0.2 0.8`) drive a session too:
/// ambiguity rule is integer → class, anything else → vector. A vector of
/// the wrong width is a `file:line:` error, not a crash.
#[test]
fn stream_accepts_regression_targets() {
    let dir = scratch("regress");
    let events = dir.join("events.txt");
    // vector targets of width n_out (bundled tasks: 2 outputs)
    let mut s = String::new();
    for i in 0..6 {
        let a = (i as f32) * 0.1;
        if i % 2 == 1 {
            s.push_str(&format!("{a} 0.5 -> 0.2 0.8\n"));
        } else {
            s.push_str(&format!("{a} 0.5\n"));
        }
    }
    std::fs::write(&events, s).unwrap();
    let out = run(&["stream", "--input", events.to_str().unwrap(), "--seed", "4"]);
    assert!(out.status.success(), "{}", stderr_of(&out));
    let stdout = stdout_of(&out);
    // the 3 supervised steps report a loss but no class prediction
    let regression_lines = stdout
        .lines()
        .filter(|l| l.contains("pred=-") && l.contains("loss=") && !l.contains("loss=-"))
        .count();
    assert_eq!(regression_lines, 3, "regression loss lines missing:\n{stdout}");

    let wide = dir.join("wide.txt");
    std::fs::write(&wide, "0.1 0.5 -> 0.2 0.3 0.5\n").unwrap();
    let bad = run(&["stream", "--input", wide.to_str().unwrap(), "--seed", "4"]);
    assert!(!bad.status.success(), "wrong-width target must fail");
    let err = stderr_of(&bad);
    assert!(err.contains("wide.txt:1:"), "no file:line prefix: {err}");
    assert!(err.contains("regression target has 3"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `stream --trace` writes a schema-valid JSON-lines trace: it opens with
/// a meta record, carries one sampled α/β window per `--metrics-every`
/// cadence plus the checkpoint event, parses with the in-tree parser, and
/// `stats` both validates (`--check`) and renders it. Tracing must not
/// change the stream itself: stdout is byte-identical to an untraced run.
#[test]
fn stream_trace_round_trips_through_stats() {
    use sparse_rtrl::telemetry::{parse_trace, TraceEventKind, TraceRecord};

    let dir = scratch("trace");
    let events = dir.join("events.txt");
    let trace = dir.join("trace.jsonl");
    let ck = dir.join("ck.snap");
    std::fs::write(&events, event_lines(0..16)).unwrap();

    let plain = run(&["stream", "--input", events.to_str().unwrap(), "--seed", "3"]);
    assert!(plain.status.success(), "{}", stderr_of(&plain));

    let traced = run(&[
        "stream",
        "--input",
        events.to_str().unwrap(),
        "--seed",
        "3",
        "--trace",
        trace.to_str().unwrap(),
        "--metrics-every",
        "4",
        "--checkpoint",
        ck.to_str().unwrap(),
    ]);
    assert!(traced.status.success(), "{}", stderr_of(&traced));
    assert!(stderr_of(&traced).contains("trace written to"), "{}", stderr_of(&traced));
    assert_eq!(stdout_of(&traced), stdout_of(&plain), "tracing changed the stream output");

    let text = std::fs::read_to_string(&trace).unwrap();
    let records = parse_trace(&text).expect("trace must be schema-valid");
    assert!(matches!(records[0], TraceRecord::Meta { .. }), "first record must be meta");
    let metrics = records.iter().filter(|r| matches!(r, TraceRecord::Metrics { .. })).count();
    assert_eq!(metrics, 4, "16 steps at cadence 4 must close 4 windows:\n{text}");
    assert!(
        records.iter().any(|r| matches!(
            r,
            TraceRecord::Event { event: TraceEventKind::Checkpoint, bytes: Some(_), .. }
        )),
        "checkpoint event missing:\n{text}"
    );

    let check = run(&["stats", "--trace", trace.to_str().unwrap(), "--check"]);
    assert!(check.status.success(), "{}", stderr_of(&check));
    let line = stdout_of(&check);
    assert!(line.contains("trace OK:"), "{line}");
    assert!(line.contains(&format!("{} record(s)", records.len())), "{line}");

    let render = run(&["stats", "--trace", trace.to_str().unwrap()]);
    assert!(render.status.success(), "{}", stderr_of(&render));
    let shown = stdout_of(&render);
    assert!(shown.contains("sparsity per window"), "{shown}");
    assert!(shown.contains("windows: 4"), "{shown}");
    assert!(shown.contains("checkpoint ×1"), "{shown}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--metrics-every` without `--trace` reports the sampled windows as
/// stderr lines instead, leaving stdout untouched for the predictions.
#[test]
fn stream_metrics_every_prints_stderr_series_without_trace() {
    let dir = scratch("metrics");
    let events = dir.join("events.txt");
    std::fs::write(&events, event_lines(0..12)).unwrap();
    let out = run(&[
        "stream",
        "--input",
        events.to_str().unwrap(),
        "--seed",
        "3",
        "--metrics-every",
        "4",
    ]);
    assert!(out.status.success(), "{}", stderr_of(&out));
    let err = stderr_of(&out);
    let lines: Vec<&str> = err.lines().filter(|l| l.starts_with("metrics step=")).collect();
    assert_eq!(lines.len(), 3, "12 steps at cadence 4:\n{err}");
    assert!(lines[0].contains("alpha="), "{err}");
    assert!(lines[2].contains("step=12"), "{err}");
    assert_eq!(stdout_of(&out).lines().filter(|l| l.contains("pred=")).count(), 12);
    let _ = std::fs::remove_dir_all(&dir);
}

/// `stats` needs exactly one input artifact, and validates/renders a
/// serialized pool snapshot too.
#[test]
fn stats_validates_snapshots_and_rejects_missing_input() {
    let bad = run(&["stats"]);
    assert!(!bad.status.success());
    assert!(stderr_of(&bad).contains("exactly one of --trace"), "{}", stderr_of(&bad));

    let dir = scratch("stats");
    let snap = dir.join("stats.json");
    std::fs::write(&snap, sparse_rtrl::telemetry::TelemetrySnapshot::default().to_json())
        .unwrap();
    let check = run(&["stats", "--snapshot", snap.to_str().unwrap(), "--check"]);
    assert!(check.status.success(), "{}", stderr_of(&check));
    assert!(stdout_of(&check).contains("snapshot OK: 0 session(s)"), "{}", stdout_of(&check));
    let render = run(&["stats", "--snapshot", snap.to_str().unwrap()]);
    assert!(render.status.success(), "{}", stderr_of(&render));
    assert!(stdout_of(&render).contains("0 live session(s)"), "{}", stdout_of(&render));
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--resume` plus a config-shaping flag is contradictory and must fail.
#[test]
fn stream_resume_rejects_config_flags() {
    let dir = scratch("resume-flags");
    let ck = dir.join("ck.json");
    let head = dir.join("head.txt");
    std::fs::write(&head, event_lines(0..3)).unwrap();
    let first = run(&[
        "stream",
        "--input",
        head.to_str().unwrap(),
        "--checkpoint",
        ck.to_str().unwrap(),
    ]);
    assert!(first.status.success(), "{}", stderr_of(&first));
    let out = run(&[
        "stream",
        "--resume",
        ck.to_str().unwrap(),
        "--hidden",
        "32",
        "--input",
        head.to_str().unwrap(),
    ]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("--resume"), "{}", stderr_of(&out));
    let _ = std::fs::remove_dir_all(&dir);
}
