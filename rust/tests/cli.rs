//! CLI-level tests against the real binary (`CARGO_BIN_EXE_sparse-rtrl`):
//! unknown-option errors list the valid choices from the engine registry,
//! and the `stream` subcommand runs a session from an event file —
//! including a checkpoint/resume round-trip across *separate processes*,
//! which must reproduce the uninterrupted run bit-for-bit.

use std::path::PathBuf;
use std::process::{Command, Output};

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_sparse-rtrl"))
}

fn run(args: &[&str]) -> Output {
    bin().args(args).output().expect("binary runs")
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn stdout_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

/// Fresh per-test scratch dir (no tempdir crate in-tree).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "sparse-rtrl-cli-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

#[test]
fn unknown_subcommand_lists_valid_ones() {
    let out = run(&["frobnicate"]);
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(2));
    let err = stderr_of(&out);
    assert!(err.contains("unknown subcommand"), "{err}");
    for cmd in ["stream", "train", "sweep", "bench", "report"] {
        assert!(err.contains(cmd), "subcommand list missing {cmd}: {err}");
    }
}

#[test]
fn unknown_algorithm_lists_engine_registry() {
    let out = run(&["train", "--algorithm", "nope", "--iterations", "1"]);
    assert!(!out.status.success());
    let err = stderr_of(&out);
    assert!(err.contains("unknown algorithm"), "{err}");
    // the list comes from AlgorithmKind::all() — the same source build_engine
    // dispatches on, so every engine must appear
    for name in ["rtrl-dense", "rtrl-activity", "rtrl-param", "rtrl-both", "snap1", "snap2", "uoro", "bptt"]
    {
        assert!(err.contains(name), "algorithm list missing {name}: {err}");
    }
}

#[test]
fn stream_unknown_policy_is_rejected() {
    let out = run(&["stream", "--policy", "sometimes", "--input", "-"]);
    assert!(!out.status.success());
    let err = stderr_of(&out);
    assert!(err.contains("unknown policy"), "{err}");
    assert!(err.contains("every-k"), "{err}");
}

/// Event lines (2-input session): a mix of unsupervised and supervised
/// steps. Deterministic content so runs are reproducible.
fn event_lines(range: std::ops::Range<usize>) -> String {
    let mut s = String::new();
    for i in range {
        let a = ((i as f32) * 0.37).sin();
        let b = ((i as f32) * 0.23).cos();
        if i % 3 == 2 {
            s.push_str(&format!("{a} {b} -> {}\n", i % 2));
        } else {
            s.push_str(&format!("{a} {b}\n"));
        }
    }
    s
}

#[test]
fn stream_emits_predictions_from_an_event_file() {
    let dir = scratch("smoke");
    let events = dir.join("events.txt");
    std::fs::write(&events, format!("# smoke stream\n{}", event_lines(0..9))).unwrap();
    let out = run(&["stream", "--input", events.to_str().unwrap(), "--seed", "3"]);
    assert!(out.status.success(), "stream failed: {}", stderr_of(&out));
    let stdout = stdout_of(&out);
    let pred_lines = stdout.lines().filter(|l| l.contains("pred=")).count();
    assert_eq!(pred_lines, 9, "one prediction per step expected:\n{stdout}");
    assert!(stdout.contains("loss="), "{stdout}");
    assert!(stderr_of(&out).contains("stream done"), "{}", stderr_of(&out));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stream_reads_stdin_dash() {
    use std::io::Write as _;
    let mut child = bin()
        .args(["stream", "--input", "-", "--seed", "5"])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn");
    child
        .stdin
        .as_mut()
        .unwrap()
        .write_all(event_lines(0..4).as_bytes())
        .unwrap();
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success(), "{}", stderr_of(&out));
    assert!(stdout_of(&out).contains("pred="));
}

/// The process-boundary acceptance test: run a 24-event stream in one
/// process, then the same stream split across two processes with a
/// checkpoint in between. The resumed process must emit byte-identical
/// step/pred/loss lines for the second half.
#[test]
fn stream_checkpoint_resume_across_processes_is_exact() {
    let dir = scratch("resume");
    let all = dir.join("all.txt");
    let head = dir.join("head.txt");
    let tail = dir.join("tail.txt");
    let ck = dir.join("ck.json");
    std::fs::write(&all, event_lines(0..24)).unwrap();
    std::fs::write(&head, event_lines(0..12)).unwrap();
    std::fs::write(&tail, event_lines(12..24)).unwrap();

    let full = run(&["stream", "--input", all.to_str().unwrap(), "--seed", "9"]);
    assert!(full.status.success(), "{}", stderr_of(&full));
    let full_lines: Vec<String> = stdout_of(&full).lines().map(str::to_string).collect();
    assert_eq!(full_lines.len(), 24);

    let first = run(&[
        "stream",
        "--input",
        head.to_str().unwrap(),
        "--seed",
        "9",
        "--checkpoint",
        ck.to_str().unwrap(),
    ]);
    assert!(first.status.success(), "{}", stderr_of(&first));
    assert!(ck.exists(), "checkpoint file not written");

    let second = run(&[
        "stream",
        "--input",
        tail.to_str().unwrap(),
        "--resume",
        ck.to_str().unwrap(),
    ]);
    assert!(second.status.success(), "{}", stderr_of(&second));
    assert!(stderr_of(&second).contains("resumed session at step 12"), "{}", stderr_of(&second));
    let resumed_lines: Vec<String> = stdout_of(&second).lines().map(str::to_string).collect();
    assert_eq!(
        resumed_lines,
        &full_lines[12..],
        "resumed process diverged from the uninterrupted run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--resume` plus a config-shaping flag is contradictory and must fail.
#[test]
fn stream_resume_rejects_config_flags() {
    let dir = scratch("resume-flags");
    let ck = dir.join("ck.json");
    let head = dir.join("head.txt");
    std::fs::write(&head, event_lines(0..3)).unwrap();
    let first = run(&[
        "stream",
        "--input",
        head.to_str().unwrap(),
        "--checkpoint",
        ck.to_str().unwrap(),
    ]);
    assert!(first.status.success(), "{}", stderr_of(&first));
    let out = run(&[
        "stream",
        "--resume",
        ck.to_str().unwrap(),
        "--hidden",
        "32",
        "--input",
        head.to_str().unwrap(),
    ]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("--resume"), "{}", stderr_of(&out));
    let _ = std::fs::remove_dir_all(&dir);
}
