//! The paper's central claim, as a test: sparse RTRL is **exact** — every
//! RTRL variant and BPTT produce the same gradient on the same weights and
//! data, because the skipped work is structurally zero ("without using any
//! approximations", §1).

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::metrics::OpCounter;
use sparse_rtrl::nn::{LayerStack, Loss, LossKind, Readout, RnnCell};
use sparse_rtrl::rtrl::{GradientEngine, Target};
use sparse_rtrl::sparse::MaskPattern;
use sparse_rtrl::train::build_engine;
use sparse_rtrl::util::Pcg64;

/// Run one supervised sequence through an algorithm on a stack; return
/// (stack grads, readout grads).
fn grads_for_net(
    kind: AlgorithmKind,
    net: &LayerStack,
    seq: &[(Vec<f32>, Option<usize>)],
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::new(seed);
    let mut readout = Readout::new(2, net.top_n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut ops = OpCounter::new();
    let mut eng = build_engine(kind, net, 2);
    eng.begin_sequence();
    for (x, t) in seq {
        let target = t.map(Target::Class).unwrap_or(Target::None);
        eng.step(net, &mut readout, &mut loss, x, target, &mut ops);
    }
    eng.end_sequence(net, &mut readout, &mut ops);
    let mut rg = vec![0.0; readout.param_len()];
    readout.copy_grads_into(&mut rg);
    (eng.grads().to_vec(), rg)
}

/// Single-cell convenience wrapper over [`grads_for_net`].
fn grads_for(
    kind: AlgorithmKind,
    cell: &RnnCell,
    seq: &[(Vec<f32>, Option<usize>)],
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    grads_for_net(kind, &LayerStack::single(cell.clone()), seq, seed)
}

fn random_sequence(n_in: usize, len: usize, rng: &mut Pcg64) -> Vec<(Vec<f32>, Option<usize>)> {
    (0..len)
        .map(|t| {
            let x: Vec<f32> = (0..n_in).map(|_| rng.normal()).collect();
            // losses at a middle step and the final step — exercises both
            // online grad accumulation and multi-target credit
            let target = if t == len / 2 || t + 1 == len { Some(t % 2) } else { None };
            (x, target)
        })
        .collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    let mut worst = 0.0f32;
    let mut worst_i = 0;
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let d = (x - y).abs();
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if d / scale > worst {
            worst = d / scale;
            worst_i = i;
        }
    }
    assert!(
        worst <= tol,
        "{what}: worst rel diff {worst:.2e} at index {worst_i} ({} vs {})",
        a[worst_i],
        b[worst_i]
    );
}

/// All exact methods agree on a *dense* EGRU.
#[test]
fn exact_methods_agree_dense_egru() {
    let mut rng = Pcg64::new(100);
    let cell = RnnCell::egru(12, 3, 0.05, 0.3, 0.5, None, &mut rng);
    let seq = random_sequence(3, 9, &mut rng);
    let (g_dense, r_dense) = grads_for(AlgorithmKind::RtrlDense, &cell, &seq, 5);
    assert!(
        g_dense.iter().any(|&g| g != 0.0),
        "degenerate test: dense gradient is all-zero"
    );
    for kind in [
        AlgorithmKind::RtrlActivity,
        AlgorithmKind::RtrlParam,
        AlgorithmKind::RtrlBoth,
        AlgorithmKind::Bptt,
    ] {
        let (g, r) = grads_for(kind, &cell, &seq, 5);
        assert_close(&g, &g_dense, 2e-4, &format!("{} cell grads", kind.name()));
        assert_close(&r, &r_dense, 2e-4, &format!("{} readout grads", kind.name()));
    }
}

/// All exact methods agree on a *masked* (80% parameter-sparse) EGRU.
#[test]
fn exact_methods_agree_masked_egru() {
    let mut rng = Pcg64::new(200);
    let mask = MaskPattern::random(12, 12, 0.2, &mut rng);
    let cell = RnnCell::egru(12, 3, 0.05, 0.3, 0.5, Some(mask), &mut rng);
    let seq = random_sequence(3, 9, &mut rng);
    let (g_dense, _) = grads_for(AlgorithmKind::RtrlDense, &cell, &seq, 6);
    assert!(g_dense.iter().any(|&g| g != 0.0));
    for kind in [
        AlgorithmKind::RtrlActivity,
        AlgorithmKind::RtrlParam,
        AlgorithmKind::RtrlBoth,
        AlgorithmKind::Bptt,
    ] {
        let (g, _) = grads_for(kind, &cell, &seq, 6);
        assert_close(&g, &g_dense, 2e-4, kind.name());
    }
}

/// Same agreement for the EvRNN (the §4 derivation cell) and the tanh cells.
#[test]
fn exact_methods_agree_other_cells() {
    let mut rng = Pcg64::new(300);
    let mask = MaskPattern::random(10, 10, 0.5, &mut rng);
    let cells = [
        RnnCell::evrnn(10, 2, 0.0, 0.3, 0.5, Some(mask.clone()), &mut rng),
        RnnCell::gated_tanh(10, 2, Some(mask.clone()), &mut rng),
        RnnCell::vanilla(10, 2, None, &mut rng),
    ];
    for cell in &cells {
        let seq = random_sequence(2, 7, &mut rng);
        let (g_dense, _) = grads_for(AlgorithmKind::RtrlDense, cell, &seq, 7);
        for kind in [AlgorithmKind::RtrlBoth, AlgorithmKind::Bptt] {
            let (g, _) = grads_for(kind, cell, &seq, 7);
            assert_close(&g, &g_dense, 3e-4, &format!("{:?}/{}", cell.dynamics(), kind.name()));
        }
    }
}

/// RTRL gradients match finite differences of the loss (end-to-end check
/// through forward dynamics and readout). Uses the tanh gated cell where
/// the loss is differentiable (no surrogate mismatch).
#[test]
fn rtrl_matches_finite_difference_loss() {
    let mut rng = Pcg64::new(400);
    let mut cell = RnnCell::gated_tanh(6, 2, None, &mut rng);
    let seq = random_sequence(2, 5, &mut rng);
    let (g, _) = grads_for(AlgorithmKind::RtrlDense, &cell, &seq, 8);

    // loss evaluation with fixed readout (same seed 8 readout)
    let eval_loss = |cell: &RnnCell| -> f64 {
        let net = LayerStack::single(cell.clone());
        let mut rng = Pcg64::new(8);
        let mut readout = Readout::new(2, net.top_n(), &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops = OpCounter::new();
        let mut eng = build_engine(AlgorithmKind::RtrlDense, &net, 2);
        eng.begin_sequence();
        let mut total = 0.0f64;
        for (x, t) in &seq {
            let target = t.map(Target::Class).unwrap_or(Target::None);
            let r = eng.step(&net, &mut readout, &mut loss, x, target, &mut ops);
            if let Some(l) = r.loss {
                total += l as f64;
            }
        }
        total
    };

    let h = 1e-3f32;
    let mut checked = 0;
    // spot-check a spread of parameters
    for pi in (0..cell.p()).step_by(cell.p() / 23) {
        let orig = cell.params()[pi];
        cell.params_mut()[pi] = orig + h;
        let up = eval_loss(&cell);
        cell.params_mut()[pi] = orig - h;
        let down = eval_loss(&cell);
        cell.params_mut()[pi] = orig;
        let fd = ((up - down) / (2.0 * h as f64)) as f32;
        assert!(
            (fd - g[pi]).abs() < 5e-3 + 0.05 * fd.abs().max(g[pi].abs()),
            "param {pi}: fd={fd} rtrl={}",
            g[pi]
        );
        checked += 1;
    }
    assert!(checked >= 20);
}

/// Gradients are deterministic: same cell + sequence ⇒ identical bits.
#[test]
fn grads_are_deterministic() {
    let mut rng = Pcg64::new(500);
    let cell = RnnCell::egru(8, 2, 0.05, 0.3, 0.5, None, &mut rng);
    let seq = random_sequence(2, 6, &mut rng);
    let (a, _) = grads_for(AlgorithmKind::RtrlBoth, &cell, &seq, 9);
    let (b, _) = grads_for(AlgorithmKind::RtrlBoth, &cell, &seq, 9);
    assert_eq!(a, b);
}

// ---------------------------------------------------------------------
// Depth: the "no approximations" claim must survive the block structure.
// ---------------------------------------------------------------------

/// Build a 2-layer EGRU stack (independent masks when `omega > 0`).
fn egru_stack2(n0: usize, n1: usize, omega: f32, rng: &mut Pcg64) -> LayerStack {
    let mask = |n: usize, rng: &mut Pcg64| {
        if omega > 0.0 {
            Some(MaskPattern::random(n, n, 1.0 - omega, rng))
        } else {
            None
        }
    };
    let m0 = mask(n0, rng);
    let l0 = RnnCell::egru(n0, 2, 0.05, 0.3, 0.5, m0, rng);
    let m1 = mask(n1, rng);
    let l1 = RnnCell::egru(n1, n0, 0.05, 0.3, 0.5, m1, rng);
    LayerStack::new(vec![l0, l1])
}

/// Delayed-XOR input/target sequences (the task the depth acceptance
/// criterion names), lifted from the bundled dataset generator.
fn delayed_xor_sequences(count: usize, timesteps: usize) -> Vec<Vec<(Vec<f32>, Option<usize>)>> {
    let mut rng = Pcg64::new(4242);
    let data = sparse_rtrl::data::delayed_xor::generate(
        &sparse_rtrl::data::delayed_xor::DelayedXorConfig { num_sequences: count, timesteps },
        &mut rng,
    );
    data.seqs
        .iter()
        .map(|seq| {
            seq.inputs
                .iter()
                .zip(&seq.targets)
                .map(|(x, t)| {
                    let target = match t {
                        sparse_rtrl::data::StepTarget::Class(c) => Some(*c),
                        _ => None,
                    };
                    (x.clone(), target)
                })
                .collect()
        })
        .collect()
}

/// Sparse RTRL == dense RTRL == BPTT on a 2-layer EGRU over delayed-XOR:
/// the exact family agrees at depth, dense stack.
#[test]
fn exact_methods_agree_depth2_delayed_xor() {
    let mut rng = Pcg64::new(600);
    let net = egru_stack2(10, 8, 0.0, &mut rng);
    for (si, seq) in delayed_xor_sequences(3, 9).iter().enumerate() {
        let (g_dense, r_dense) = grads_for_net(AlgorithmKind::RtrlDense, &net, seq, 15);
        assert!(
            g_dense.iter().any(|&g| g != 0.0),
            "degenerate test: depth-2 dense gradient is all-zero (seq {si})"
        );
        for kind in [
            AlgorithmKind::RtrlActivity,
            AlgorithmKind::RtrlParam,
            AlgorithmKind::RtrlBoth,
            AlgorithmKind::Bptt,
        ] {
            let (g, r) = grads_for_net(kind, &net, seq, 15);
            assert_close(&g, &g_dense, 3e-4, &format!("depth2 seq {si} {} grads", kind.name()));
            assert_close(&r, &r_dense, 3e-4, &format!("depth2 seq {si} {} readout", kind.name()));
        }
    }
}

/// Same at 80% parameter sparsity per layer — column compaction and the
/// nested block panels stay exact.
#[test]
fn exact_methods_agree_depth2_masked() {
    let mut rng = Pcg64::new(601);
    let net = egru_stack2(10, 8, 0.8, &mut rng);
    let seq = &delayed_xor_sequences(1, 9)[0];
    let (g_dense, _) = grads_for_net(AlgorithmKind::RtrlDense, &net, seq, 16);
    assert!(g_dense.iter().any(|&g| g != 0.0));
    for kind in [
        AlgorithmKind::RtrlActivity,
        AlgorithmKind::RtrlParam,
        AlgorithmKind::RtrlBoth,
        AlgorithmKind::Bptt,
    ] {
        let (g, _) = grads_for_net(kind, &net, seq, 16);
        assert_close(&g, &g_dense, 3e-4, &format!("depth2-masked {}", kind.name()));
    }
}

/// Finite differences through the *stacked* dynamics: dense RTRL on a
/// 2-layer tanh stack matches d(loss)/dw for parameters of both layers —
/// the cross-layer propagation is a true total derivative.
#[test]
fn depth2_rtrl_matches_finite_difference_loss() {
    let mut rng = Pcg64::new(602);
    let l0 = RnnCell::gated_tanh(5, 2, None, &mut rng);
    let l1 = RnnCell::gated_tanh(4, 5, None, &mut rng);
    let mut net = LayerStack::new(vec![l0, l1]);
    let seq = random_sequence(2, 5, &mut rng);
    let (g, _) = grads_for_net(AlgorithmKind::RtrlDense, &net, &seq, 17);

    let eval_loss = |net: &LayerStack| -> f64 {
        let mut rng = Pcg64::new(17);
        let mut readout = Readout::new(2, net.top_n(), &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops = OpCounter::new();
        let mut eng = build_engine(AlgorithmKind::RtrlDense, net, 2);
        eng.begin_sequence();
        let mut total = 0.0f64;
        for (x, t) in &seq {
            let target = t.map(Target::Class).unwrap_or(Target::None);
            let r = eng.step(net, &mut readout, &mut loss, x, target, &mut ops);
            if let Some(l) = r.loss {
                total += l as f64;
            }
        }
        total
    };

    let h = 1e-3f32;
    let p_total = net.p();
    let mut buf = vec![0.0; p_total];
    let mut checked = 0;
    for pi in (0..p_total).step_by(p_total / 23) {
        net.copy_params_into(&mut buf);
        let orig = buf[pi];
        buf[pi] = orig + h;
        net.load_params(&buf);
        let up = eval_loss(&net);
        buf[pi] = orig - h;
        net.load_params(&buf);
        let down = eval_loss(&net);
        buf[pi] = orig;
        net.load_params(&buf);
        let fd = ((up - down) / (2.0 * h as f64)) as f32;
        assert!(
            (fd - g[pi]).abs() < 5e-3 + 0.05 * fd.abs().max(g[pi].abs()),
            "param {pi}: fd={fd} rtrl={}",
            g[pi]
        );
        checked += 1;
    }
    assert!(checked >= 20);
}
