//! The paper's central claim, as a test: sparse RTRL is **exact** — every
//! RTRL variant and BPTT produce the same gradient on the same weights and
//! data, because the skipped work is structurally zero ("without using any
//! approximations", §1).

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::metrics::OpCounter;
use sparse_rtrl::nn::{Loss, LossKind, Readout, RnnCell};
use sparse_rtrl::rtrl::{GradientEngine, Target};
use sparse_rtrl::sparse::MaskPattern;
use sparse_rtrl::train::build_engine;
use sparse_rtrl::util::Pcg64;

/// Run one supervised sequence through an algorithm; return (cell grads,
/// readout grads).
fn grads_for(
    kind: AlgorithmKind,
    cell: &RnnCell,
    seq: &[(Vec<f32>, Option<usize>)],
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::new(seed);
    let mut readout = Readout::new(2, cell.n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut ops = OpCounter::new();
    let mut eng = build_engine(kind, cell, 2);
    eng.begin_sequence();
    for (x, t) in seq {
        let target = t.map(Target::Class).unwrap_or(Target::None);
        eng.step(cell, &mut readout, &mut loss, x, target, &mut ops);
    }
    eng.end_sequence(cell, &mut readout, &mut ops);
    let mut rg = vec![0.0; readout.param_len()];
    readout.copy_grads_into(&mut rg);
    (eng.grads().to_vec(), rg)
}

fn random_sequence(n_in: usize, len: usize, rng: &mut Pcg64) -> Vec<(Vec<f32>, Option<usize>)> {
    (0..len)
        .map(|t| {
            let x: Vec<f32> = (0..n_in).map(|_| rng.normal()).collect();
            // losses at a middle step and the final step — exercises both
            // online grad accumulation and multi-target credit
            let target = if t == len / 2 || t + 1 == len { Some(t % 2) } else { None };
            (x, target)
        })
        .collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    let mut worst = 0.0f32;
    let mut worst_i = 0;
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let d = (x - y).abs();
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if d / scale > worst {
            worst = d / scale;
            worst_i = i;
        }
    }
    assert!(
        worst <= tol,
        "{what}: worst rel diff {worst:.2e} at index {worst_i} ({} vs {})",
        a[worst_i],
        b[worst_i]
    );
}

/// All exact methods agree on a *dense* EGRU.
#[test]
fn exact_methods_agree_dense_egru() {
    let mut rng = Pcg64::new(100);
    let cell = RnnCell::egru(12, 3, 0.05, 0.3, 0.5, None, &mut rng);
    let seq = random_sequence(3, 9, &mut rng);
    let (g_dense, r_dense) = grads_for(AlgorithmKind::RtrlDense, &cell, &seq, 5);
    assert!(
        g_dense.iter().any(|&g| g != 0.0),
        "degenerate test: dense gradient is all-zero"
    );
    for kind in [
        AlgorithmKind::RtrlActivity,
        AlgorithmKind::RtrlParam,
        AlgorithmKind::RtrlBoth,
        AlgorithmKind::Bptt,
    ] {
        let (g, r) = grads_for(kind, &cell, &seq, 5);
        assert_close(&g, &g_dense, 2e-4, &format!("{} cell grads", kind.name()));
        assert_close(&r, &r_dense, 2e-4, &format!("{} readout grads", kind.name()));
    }
}

/// All exact methods agree on a *masked* (80% parameter-sparse) EGRU.
#[test]
fn exact_methods_agree_masked_egru() {
    let mut rng = Pcg64::new(200);
    let mask = MaskPattern::random(12, 12, 0.2, &mut rng);
    let cell = RnnCell::egru(12, 3, 0.05, 0.3, 0.5, Some(mask), &mut rng);
    let seq = random_sequence(3, 9, &mut rng);
    let (g_dense, _) = grads_for(AlgorithmKind::RtrlDense, &cell, &seq, 6);
    assert!(g_dense.iter().any(|&g| g != 0.0));
    for kind in [
        AlgorithmKind::RtrlActivity,
        AlgorithmKind::RtrlParam,
        AlgorithmKind::RtrlBoth,
        AlgorithmKind::Bptt,
    ] {
        let (g, _) = grads_for(kind, &cell, &seq, 6);
        assert_close(&g, &g_dense, 2e-4, kind.name());
    }
}

/// Same agreement for the EvRNN (the §4 derivation cell) and the tanh cells.
#[test]
fn exact_methods_agree_other_cells() {
    let mut rng = Pcg64::new(300);
    let mask = MaskPattern::random(10, 10, 0.5, &mut rng);
    let cells = [
        RnnCell::evrnn(10, 2, 0.0, 0.3, 0.5, Some(mask.clone()), &mut rng),
        RnnCell::gated_tanh(10, 2, Some(mask.clone()), &mut rng),
        RnnCell::vanilla(10, 2, None, &mut rng),
    ];
    for cell in &cells {
        let seq = random_sequence(2, 7, &mut rng);
        let (g_dense, _) = grads_for(AlgorithmKind::RtrlDense, cell, &seq, 7);
        for kind in [AlgorithmKind::RtrlBoth, AlgorithmKind::Bptt] {
            let (g, _) = grads_for(kind, cell, &seq, 7);
            assert_close(&g, &g_dense, 3e-4, &format!("{:?}/{}", cell.dynamics(), kind.name()));
        }
    }
}

/// RTRL gradients match finite differences of the loss (end-to-end check
/// through forward dynamics and readout). Uses the tanh gated cell where
/// the loss is differentiable (no surrogate mismatch).
#[test]
fn rtrl_matches_finite_difference_loss() {
    let mut rng = Pcg64::new(400);
    let mut cell = RnnCell::gated_tanh(6, 2, None, &mut rng);
    let seq = random_sequence(2, 5, &mut rng);
    let (g, _) = grads_for(AlgorithmKind::RtrlDense, &cell, &seq, 8);

    // loss evaluation with fixed readout (same seed 8 readout)
    let eval_loss = |cell: &RnnCell| -> f64 {
        let mut rng = Pcg64::new(8);
        let mut readout = Readout::new(2, cell.n(), &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops = OpCounter::new();
        let mut eng = build_engine(AlgorithmKind::RtrlDense, cell, 2);
        eng.begin_sequence();
        let mut total = 0.0f64;
        for (x, t) in &seq {
            let target = t.map(Target::Class).unwrap_or(Target::None);
            let r = eng.step(cell, &mut readout, &mut loss, x, target, &mut ops);
            if let Some(l) = r.loss {
                total += l as f64;
            }
        }
        total
    };

    let h = 1e-3f32;
    let mut checked = 0;
    // spot-check a spread of parameters
    for pi in (0..cell.p()).step_by(cell.p() / 23) {
        let orig = cell.params()[pi];
        cell.params_mut()[pi] = orig + h;
        let up = eval_loss(&cell);
        cell.params_mut()[pi] = orig - h;
        let down = eval_loss(&cell);
        cell.params_mut()[pi] = orig;
        let fd = ((up - down) / (2.0 * h as f64)) as f32;
        assert!(
            (fd - g[pi]).abs() < 5e-3 + 0.05 * fd.abs().max(g[pi].abs()),
            "param {pi}: fd={fd} rtrl={}",
            g[pi]
        );
        checked += 1;
    }
    assert!(checked >= 20);
}

/// Gradients are deterministic: same cell + sequence ⇒ identical bits.
#[test]
fn grads_are_deterministic() {
    let mut rng = Pcg64::new(500);
    let cell = RnnCell::egru(8, 2, 0.05, 0.3, 0.5, None, &mut rng);
    let seq = random_sequence(2, 6, &mut rng);
    let (a, _) = grads_for(AlgorithmKind::RtrlBoth, &cell, &seq, 9);
    let (b, _) = grads_for(AlgorithmKind::RtrlBoth, &cell, &seq, 9);
    assert_eq!(a, b);
}
