//! Cross-validation of the Rust engines against the JAX/XLA dense oracle.
//!
//! Requires `make artifacts` (Python build step). When artifacts are absent
//! the tests *skip* — CI without Python still exercises everything else —
//! but when present, the Rust forward pass and RTRL influence update must
//! match XLA's numerics on identical weights, proving the two stacks
//! implement the same mathematics.

use sparse_rtrl::nn::{CellScratch, RnnCell};
use sparse_rtrl::metrics::OpCounter;
use sparse_rtrl::runtime::{artifacts::names, ArtifactSet, PjrtRuntime};
use sparse_rtrl::util::Pcg64;

fn artifacts() -> Option<ArtifactSet> {
    if !PjrtRuntime::available() {
        eprintln!("skipping PJRT cross-validation: built without the `pjrt` feature");
        return None;
    }
    let set = ArtifactSet::default_location();
    if set.has(names::EGRU_STEP) {
        Some(set)
    } else {
        eprintln!("skipping PJRT cross-validation: run `make artifacts` first");
        None
    }
}

/// Rebuild the exact cell the AOT step was lowered for, from its manifest.
fn cell_from_manifest(set: &ArtifactSet, name: &str) -> (RnnCell, usize) {
    let info = set.info(name).expect("manifest entry");
    let n = info.meta["n"] as usize;
    let n_in = info.meta["n_in"] as usize;
    let theta = info.meta["theta"] as f32;
    let gamma = info.meta["gamma"] as f32;
    let eps = info.meta["eps"] as f32;
    let batch = info.meta["batch"] as usize;
    let mut rng = Pcg64::new(0); // weights are loaded, not drawn
    let cell = RnnCell::egru(n, n_in, theta, gamma, eps, None, &mut rng);
    (cell, batch)
}

/// The artifact's parameter order (see python/compile/model.py):
/// W_u, V_u, b_u, W_z, V_z, b_z — identical to the Rust gated layout.
fn params_as_artifact_inputs(cell: &RnnCell) -> Vec<(Vec<usize>, Vec<f32>)> {
    let layout = cell.layout();
    (0..layout.blocks().len())
        .map(|b| {
            let blk = &layout.blocks()[b];
            let shape = if blk.cols == 1 { vec![blk.rows] } else { vec![blk.rows, blk.cols] };
            (shape, layout.block(cell.params(), b).to_vec())
        })
        .collect()
}

#[test]
fn egru_forward_matches_xla() {
    let Some(set) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
    let exe = rt.load(&set.path(names::EGRU_STEP)).expect("compile egru_step");
    let (mut cell, batch) = cell_from_manifest(&set, names::EGRU_STEP);
    // randomize weights deterministically, then ship the same weights to XLA
    let mut wrng = Pcg64::new(123);
    for w in cell.params_mut() {
        *w = wrng.uniform(-0.4, 0.4);
    }
    let (n, n_in) = (cell.n(), cell.n_in());
    let mut xrng = Pcg64::new(321);
    let xs: Vec<f32> = (0..batch * n_in).map(|_| xrng.normal()).collect();
    let a_prev: Vec<f32> = (0..batch * n).map(|_| if xrng.bernoulli(0.4) { 1.0 } else { 0.0 }).collect();

    // XLA side: inputs are (a_prev[batch,n], x[batch,n_in], params...)
    let mut inputs: Vec<(Vec<usize>, Vec<f32>)> =
        vec![(vec![batch, n], a_prev.clone()), (vec![batch, n_in], xs.clone())];
    inputs.extend(params_as_artifact_inputs(&cell));
    let input_refs: Vec<(&[usize], &[f32])> =
        inputs.iter().map(|(s, d)| (s.as_slice(), d.as_slice())).collect();
    let outs = exe.run_f32(&input_refs).expect("execute egru_step");
    let (xla_a, xla_v, xla_dphi) = (&outs[0], &outs[1], &outs[2]);

    // Rust side, sample by sample
    let mut scratch = CellScratch::new(n);
    let mut ops = OpCounter::new();
    for b in 0..batch {
        let ap = &a_prev[b * n..(b + 1) * n];
        let x = &xs[b * n_in..(b + 1) * n_in];
        cell.forward(ap, x, &mut scratch, &mut ops);
        for k in 0..n {
            let (ra, xa) = (scratch.a[k], xla_a[b * n + k]);
            assert!(
                (ra - xa).abs() < 1e-5,
                "a mismatch sample {b} unit {k}: rust {ra} xla {xa}"
            );
            let (rv, xv) = (scratch.v[k], xla_v[b * n + k]);
            assert!(
                (rv - xv).abs() < 1e-4,
                "v mismatch sample {b} unit {k}: rust {rv} xla {xv}"
            );
            let (rd, xd) = (scratch.dphi[k], xla_dphi[b * n + k]);
            assert!(
                (rd - xd).abs() < 1e-4,
                "dphi mismatch sample {b} unit {k}: rust {rd} xla {xd}"
            );
        }
    }
}

#[test]
fn rtrl_influence_update_matches_xla() {
    let Some(set) = artifacts() else { return };
    if !set.has(names::RTRL_STEP) {
        eprintln!("skipping: no rtrl_step artifact");
        return;
    }
    let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
    let exe = rt.load(&set.path(names::RTRL_STEP)).expect("compile rtrl_step");
    let (mut cell, _) = cell_from_manifest(&set, names::RTRL_STEP);
    let mut wrng = Pcg64::new(55);
    for w in cell.params_mut() {
        *w = wrng.uniform(-0.4, 0.4);
    }
    let (n, n_in, p) = (cell.n(), cell.n_in(), cell.p());

    let mut xrng = Pcg64::new(66);
    let x: Vec<f32> = (0..n_in).map(|_| xrng.normal()).collect();
    let a_prev: Vec<f32> = (0..n).map(|_| if xrng.bernoulli(0.4) { 1.0 } else { 0.0 }).collect();
    let m_prev: Vec<f32> = (0..n * p).map(|_| xrng.uniform(-0.05, 0.05)).collect();

    let mut inputs: Vec<(Vec<usize>, Vec<f32>)> = vec![
        (vec![n], a_prev.clone()),
        (vec![n_in], x.clone()),
        (vec![n, p], m_prev.clone()),
    ];
    inputs.extend(params_as_artifact_inputs(&cell));
    let input_refs: Vec<(&[usize], &[f32])> =
        inputs.iter().map(|(s, d)| (s.as_slice(), d.as_slice())).collect();
    let outs = exe.run_f32(&input_refs).expect("execute rtrl_step");
    let (xla_a, xla_m) = (&outs[0], &outs[1]);

    // Rust reference: dense Eq.-10 update on the same M_prev.
    let mut scratch = CellScratch::new(n);
    let mut ops = OpCounter::new();
    cell.forward(&a_prev, &x, &mut scratch, &mut ops);
    for k in 0..n {
        assert!((scratch.a[k] - xla_a[k]).abs() < 1e-5, "a mismatch unit {k}");
    }
    let mut m_next = vec![0.0f32; n * p];
    for k in 0..n {
        for l in 0..n {
            let jv = cell.dv_da(&scratch, k, l);
            if jv == 0.0 {
                continue;
            }
            for pi in 0..p {
                m_next[k * p + pi] += jv * m_prev[l * p + pi];
            }
        }
        let row = &mut m_next[k * p..(k + 1) * p];
        cell.immediate_row(&scratch, &a_prev, &x, k, |pi, val| row[pi] += val, &mut ops);
        let d = scratch.dphi[k];
        for v in row.iter_mut() {
            *v *= d;
        }
    }
    let mut worst = 0.0f32;
    for i in 0..n * p {
        worst = worst.max((m_next[i] - xla_m[i]).abs());
    }
    assert!(worst < 5e-4, "influence update mismatch: worst abs diff {worst}");
}
