//! Trait-level contract tests for [`GradientEngine`]: every engine is
//! driven exclusively through `Box<dyn GradientEngine>` and the provided
//! `run_sequence`, exactly the way the trainer, sweep and bench subsystem
//! consume engines.
//!
//! Exactness: the engines that claim exactness (dense RTRL, the three
//! sparse modes, BPTT — plus SnAp-2 on a dense cell and SnAp-1 at n=1,
//! where their patterns are complete) must reproduce the dense-RTRL
//! gradient on the same tiny network bit-for-bit up to FP reassociation.
//! UORO, the stochastic engine, must match in expectation.

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::metrics::OpCounter;
use sparse_rtrl::nn::{CellScratch, LayerStack, Loss, LossKind, Readout, RnnCell};
use sparse_rtrl::rtrl::{GradientEngine, Target, Uoro};
use sparse_rtrl::sparse::MaskPattern;
use sparse_rtrl::train::build_engine;
use sparse_rtrl::util::Pcg64;

/// A fixed supervised sequence (mid-sequence and final targets).
fn sequence(n_in: usize, len: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<Target<'static>>) {
    let mut rng = Pcg64::new(seed);
    let inputs: Vec<Vec<f32>> = (0..len)
        .map(|_| (0..n_in).map(|_| rng.normal()).collect())
        .collect();
    let targets: Vec<Target<'static>> = (0..len)
        .map(|t| {
            if t == len / 2 || t + 1 == len {
                Target::Class(t % 2)
            } else {
                Target::None
            }
        })
        .collect();
    (inputs, targets)
}

/// Run one engine over the shared sequence entirely through the trait.
fn grads_via_trait(mut engine: Box<dyn GradientEngine>, net: &LayerStack, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    let mut readout = Readout::new(2, net.top_n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut ops = OpCounter::new();
    let (inputs, targets) = sequence(net.n_in(), 9, 77);
    let summary = engine.run_sequence(net, &mut readout, &mut loss, &inputs, &targets, &mut ops);
    assert_eq!(summary.steps, 9, "{}: wrong step count", engine.name());
    assert_eq!(summary.supervised_steps, 2, "{}: wrong supervised count", engine.name());
    assert!(ops.total_macs() > 0, "{}: no ops charged", engine.name());
    engine.grads().to_vec()
}

/// Reference implementation: textbook dense RTRL written directly against
/// the bare [`RnnCell`] — no `LayerStack`, no engine machinery. This pins
/// the *pre-refactor* single-cell semantics so the stacked engines at
/// depth 1 are provably behavior-preserving.
fn manual_single_cell_rtrl(cell: &RnnCell, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    let mut readout = Readout::new(2, cell.n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut ops = OpCounter::new();
    let (inputs, targets) = sequence(cell.n_in(), 9, 77);
    let (n, p) = (cell.n(), cell.p());
    let mut m_cur = vec![0.0f32; n * p];
    let mut m_next = vec![0.0f32; n * p];
    let mut a_prev = vec![0.0f32; n];
    let mut grads = vec![0.0f32; p];
    let mut scratch = CellScratch::new(n);
    let mut logits = [0.0f32; 2];
    let mut dlogits = [0.0f32; 2];
    let mut c_bar = vec![0.0f32; n];
    for (x, target) in inputs.iter().zip(&targets) {
        cell.forward(&a_prev, x, &mut scratch, &mut ops);
        for k in 0..n {
            let row = &mut m_next[k * p..(k + 1) * p];
            row.iter_mut().for_each(|r| *r = 0.0);
            for l in 0..n {
                let jv = cell.dv_da(&scratch, k, l);
                for (r, sv) in row.iter_mut().zip(&m_cur[l * p..(l + 1) * p]) {
                    *r += jv * sv;
                }
            }
            cell.immediate_row(&scratch, &a_prev, x, k, |pi, val| row[pi] += val, &mut ops);
            let dphi = scratch.dphi[k];
            for r in row.iter_mut() {
                let v = *r * dphi;
                *r = if v.abs() < 1e-30 { 0.0 } else { v };
            }
        }
        if let Target::Class(t) = target {
            readout.forward(&scratch.a, &mut logits, &mut ops);
            loss.cross_entropy(&logits, *t, &mut dlogits);
            readout.backward(&scratch.a, &dlogits, &mut c_bar, &mut ops);
            for k in 0..n {
                let coef = c_bar[k];
                for (g, m) in grads.iter_mut().zip(&m_next[k * p..(k + 1) * p]) {
                    *g += coef * m;
                }
            }
        }
        std::mem::swap(&mut m_cur, &mut m_next);
        a_prev.copy_from_slice(&scratch.a);
    }
    grads
}

fn assert_grads_match(reference: &[f32], got: &[f32], what: &str) {
    assert_eq!(reference.len(), got.len(), "{what}: length");
    for (i, (a, b)) in reference.iter().zip(got).enumerate() {
        let tol = 3e-4 * (1.0 + a.abs().max(b.abs()));
        assert!(
            (a - b).abs() <= tol,
            "{what}: grad[{i}] diverges: dense {a} vs {b}"
        );
    }
}

/// Exact engines equal dense RTRL on a dense tiny EGRU.
#[test]
fn exact_engines_match_dense_rtrl() {
    let mut rng = Pcg64::new(31);
    let net = LayerStack::single(RnnCell::egru(6, 2, 0.05, 0.3, 0.5, None, &mut rng));
    let reference = grads_via_trait(build_engine(AlgorithmKind::RtrlDense, &net, 2), &net, 5);
    assert!(
        reference.iter().any(|&g| g != 0.0),
        "degenerate reference gradient — retune the test cell"
    );
    for kind in [
        AlgorithmKind::RtrlActivity,
        AlgorithmKind::RtrlParam,
        AlgorithmKind::RtrlBoth,
        AlgorithmKind::Bptt,
        // SnAp-2's two-hop pattern is complete on a dense cell.
        AlgorithmKind::Snap2,
    ] {
        let g = grads_via_trait(build_engine(kind, &net, 2), &net, 5);
        assert_grads_match(&reference, &g, kind.name());
    }
}

/// **Behavior preservation at depth 1** — the refactor's contract: every
/// exact engine, now running on a `LayerStack`, reproduces the gradients
/// of a from-scratch single-cell dense RTRL implementation (the old
/// engine semantics) up to float reassociation. Checked dense and masked.
#[test]
fn depth1_stack_reproduces_single_cell_rtrl() {
    let mut rng = Pcg64::new(36);
    let dense_cell = RnnCell::egru(6, 2, 0.05, 0.3, 0.5, None, &mut rng);
    let mask = MaskPattern::random(6, 6, 0.4, &mut rng);
    let masked_cell = RnnCell::egru(6, 2, 0.05, 0.3, 0.5, Some(mask), &mut rng);
    for (what, cell) in [("dense", dense_cell), ("masked", masked_cell)] {
        let reference = manual_single_cell_rtrl(&cell, 9);
        assert!(
            reference.iter().any(|&g| g != 0.0),
            "{what}: degenerate manual reference gradient"
        );
        let net = LayerStack::single(cell);
        for kind in [
            AlgorithmKind::RtrlDense,
            AlgorithmKind::RtrlActivity,
            AlgorithmKind::RtrlParam,
            AlgorithmKind::RtrlBoth,
            AlgorithmKind::Bptt,
        ] {
            let g = grads_via_trait(build_engine(kind, &net, 2), &net, 9);
            assert_grads_match(&reference, &g, &format!("{what}/{} vs manual", kind.name()));
        }
    }
}

/// Same, on a parameter-sparse cell (SnAp-2 excluded: its pattern is
/// genuinely approximate under a mask).
#[test]
fn exact_engines_match_dense_rtrl_under_mask() {
    let mut rng = Pcg64::new(32);
    let mask = MaskPattern::random(6, 6, 0.4, &mut rng);
    let net = LayerStack::single(RnnCell::egru(6, 2, 0.05, 0.3, 0.5, Some(mask), &mut rng));
    let reference = grads_via_trait(build_engine(AlgorithmKind::RtrlDense, &net, 2), &net, 6);
    for kind in [
        AlgorithmKind::RtrlActivity,
        AlgorithmKind::RtrlParam,
        AlgorithmKind::RtrlBoth,
        AlgorithmKind::Bptt,
    ] {
        let g = grads_via_trait(build_engine(kind, &net, 2), &net, 6);
        assert_grads_match(&reference, &g, kind.name());
    }
}

/// At n=1 SnAp-1's fan-in pattern covers every parameter and the diagonal
/// Jacobian is the whole Jacobian, so it too must be exact.
#[test]
fn snap1_exact_on_single_unit_network() {
    let mut rng = Pcg64::new(33);
    let net = LayerStack::single(RnnCell::egru(1, 2, 0.0, 0.3, 0.9, None, &mut rng));
    let reference = grads_via_trait(build_engine(AlgorithmKind::RtrlDense, &net, 2), &net, 7);
    let g = grads_via_trait(build_engine(AlgorithmKind::Snap1, &net, 2), &net, 7);
    assert_grads_match(&reference, &g, "snap1@n=1");
}

/// UORO is unbiased: its gradient averaged over noise draws aligns with
/// dense RTRL (cosine similarity), even though single draws differ.
#[test]
fn uoro_matches_dense_in_expectation() {
    let mut rng = Pcg64::new(34);
    let net = LayerStack::single(RnnCell::gated_tanh(4, 2, None, &mut rng));
    let reference = grads_via_trait(build_engine(AlgorithmKind::RtrlDense, &net, 2), &net, 8);
    let trials = 1500u64;
    let mut mean = vec![0.0f64; net.p()];
    for trial in 0..trials {
        let eng: Box<dyn GradientEngine> = Box::new(Uoro::new(&net, 2, 5000 + trial));
        let g = grads_via_trait(eng, &net, 8);
        for (m, v) in mean.iter_mut().zip(&g) {
            *m += *v as f64 / trials as f64;
        }
    }
    let dot: f64 = mean.iter().zip(&reference).map(|(m, r)| m * *r as f64).sum();
    let nm = mean.iter().map(|m| m * m).sum::<f64>().sqrt();
    let nr = reference.iter().map(|r| (*r as f64).powi(2)).sum::<f64>().sqrt();
    let cos = dot / (nm * nr + 1e-12);
    assert!(cos > 0.7, "E[UORO] should align with dense RTRL: cos={cos:.3}");
}

/// **Snapshot exactness** — the save/load half of the contract: for every
/// engine, saving mid-sequence and restoring into a *freshly built* engine
/// must produce gradients **bit-identical** to the uninterrupted run. The
/// check runs on a 2-layer masked stack (the hardest configuration) and
/// includes the stochastic engine (UORO snapshots its noise-RNG position)
/// and BPTT (snapshots its stored tape).
#[test]
fn snapshot_mid_sequence_is_bit_exact_for_every_engine() {
    let mut rng = Pcg64::new(37);
    let mask0 = MaskPattern::random(6, 6, 0.5, &mut rng);
    let l0 = RnnCell::egru(6, 2, 0.05, 0.3, 0.5, Some(mask0), &mut rng);
    let mask1 = MaskPattern::random(4, 4, 0.5, &mut rng);
    let l1 = RnnCell::egru(4, 6, 0.05, 0.3, 0.5, Some(mask1), &mut rng);
    let net = LayerStack::new(vec![l0, l1]);
    let (inputs, targets) = sequence(net.n_in(), 9, 123);
    let cut = 5usize;
    for kind in AlgorithmKind::all() {
        // uninterrupted reference run
        let mut r1 = Pcg64::new(3);
        let mut readout1 = Readout::new(2, net.top_n(), &mut r1);
        let mut loss1 = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops1 = OpCounter::new();
        let mut full = build_engine(kind, &net, 2);
        full.begin_sequence();
        let mut full_losses = Vec::new();
        for (t, x) in inputs.iter().enumerate() {
            let r = full.step(&net, &mut readout1, &mut loss1, x, targets[t], &mut ops1);
            full_losses.push(r.loss.map(f32::to_bits));
        }
        full.end_sequence(&net, &mut readout1, &mut ops1);

        // interrupted run: save at `cut`, restore into a fresh engine
        let mut r2 = Pcg64::new(3);
        let mut readout2 = Readout::new(2, net.top_n(), &mut r2);
        let mut loss2 = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops2 = OpCounter::new();
        let mut first = build_engine(kind, &net, 2);
        first.begin_sequence();
        for (t, x) in inputs.iter().take(cut).enumerate() {
            first.step(&net, &mut readout2, &mut loss2, x, targets[t], &mut ops2);
        }
        assert_eq!(first.activations().len(), net.total_units(), "{}", kind.name());
        let snapshot = first.save_state();
        drop(first);
        let mut second = build_engine(kind, &net, 2);
        second
            .load_state(&net, &snapshot)
            .unwrap_or_else(|e| panic!("{}: load_state failed: {e}", kind.name()));
        let mut resumed_losses: Vec<Option<u32>> = full_losses[..cut].to_vec();
        for (t, x) in inputs.iter().enumerate().skip(cut) {
            let r = second.step(&net, &mut readout2, &mut loss2, x, targets[t], &mut ops2);
            resumed_losses.push(r.loss.map(f32::to_bits));
        }
        second.end_sequence(&net, &mut readout2, &mut ops2);

        assert_eq!(
            full.grads(),
            second.grads(),
            "{}: resumed gradients are not bit-identical",
            kind.name()
        );
        assert_eq!(
            full_losses,
            resumed_losses,
            "{}: resumed losses are not bit-identical",
            kind.name()
        );
        assert_eq!(
            full.activations(),
            second.activations(),
            "{}: resumed activations diverged",
            kind.name()
        );
    }
}

/// Snapshot headers are enforced: a snapshot from one engine cannot restore
/// into another, and a tampered version is rejected.
#[test]
fn snapshot_header_mismatches_are_rejected() {
    let mut rng = Pcg64::new(38);
    let net = LayerStack::single(RnnCell::egru(5, 2, 0.05, 0.3, 0.5, None, &mut rng));
    let donor = build_engine(AlgorithmKind::RtrlDense, &net, 2);
    let snapshot = donor.save_state();
    let mut other = build_engine(AlgorithmKind::Snap1, &net, 2);
    assert!(other.load_state(&net, &snapshot).is_err(), "cross-engine restore must fail");
    let mut tampered = snapshot.clone();
    tampered.version += 1;
    let mut same = build_engine(AlgorithmKind::RtrlDense, &net, 2);
    assert!(same.load_state(&net, &tampered).is_err(), "version bump must fail");
    // a differently-sized engine rejects the buffers
    let small = LayerStack::single(RnnCell::egru(3, 2, 0.05, 0.3, 0.5, None, &mut rng));
    let mut wrong_size = build_engine(AlgorithmKind::RtrlDense, &small, 2);
    assert!(wrong_size.load_state(&small, &snapshot).is_err(), "size mismatch must fail");
}

/// Contract invariants every engine must satisfy, checked uniformly
/// through the trait: stable name, `R^p` gradient buffer, finite values,
/// `reset_grads` clearing, measured state memory.
#[test]
fn every_engine_satisfies_the_contract() {
    let mut rng = Pcg64::new(35);
    let mask0 = MaskPattern::random(6, 6, 0.5, &mut rng);
    let l0 = RnnCell::egru(6, 2, 0.05, 0.3, 0.5, Some(mask0), &mut rng);
    let mask1 = MaskPattern::random(4, 4, 0.5, &mut rng);
    let l1 = RnnCell::egru(4, 6, 0.05, 0.3, 0.5, Some(mask1), &mut rng);
    // the uniform contract is checked on a *2-layer* masked stack — the
    // hardest configuration every engine must now support
    let net = LayerStack::new(vec![l0, l1]);
    let (inputs, targets) = sequence(net.n_in(), 9, 99);
    for kind in AlgorithmKind::all() {
        let mut engine = build_engine(kind, &net, 2);
        assert_eq!(engine.name(), kind.name(), "factory/name mismatch");
        let mut rrng = Pcg64::new(1);
        let mut readout = Readout::new(2, net.top_n(), &mut rrng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops = OpCounter::new();
        engine.run_sequence(&net, &mut readout, &mut loss, &inputs, &targets, &mut ops);
        assert_eq!(engine.grads().len(), net.p(), "{}: grads not R^P", kind.name());
        assert!(
            engine.grads().iter().all(|g| g.is_finite()),
            "{}: non-finite gradient",
            kind.name()
        );
        assert!(
            engine.state_memory_words() > 0,
            "{}: zero state memory reported",
            kind.name()
        );
        engine.reset_grads();
        assert!(
            engine.grads().iter().all(|&g| g == 0.0),
            "{}: reset_grads left residue",
            kind.name()
        );
    }
}
