//! End-to-end integration: full training runs on the paper's task reach
//! usable accuracy with every sparsity configuration, and the compute
//! accounting behind Fig. 3B is consistent.

use sparse_rtrl::config::{AlgorithmKind, CellKind, ExperimentConfig, TaskKind};
use sparse_rtrl::train::{build_dataset, Trainer};

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.task.num_sequences = 600;
    cfg.train.iterations = 120;
    cfg.train.batch_size = 16;
    cfg.train.log_every = 10;
    cfg.train.eval_every = 40;
    cfg.train.eval_sequences = 60;
    cfg.model.hidden = 16;
    cfg.seed = 3;
    cfg
}

fn run(cfg: ExperimentConfig) -> (f32, sparse_rtrl::train::TrainOutcome) {
    let mut data_rng = Trainer::data_rng(cfg.seed);
    let (train, val) = build_dataset(&cfg, &mut data_rng);
    let mut tr = Trainer::new(cfg);
    let out = tr.train(&train, &val);
    (out.final_val_accuracy, out)
}

/// Dense EGRU + sparse-RTRL learns the spiral task well above chance.
#[test]
fn egru_learns_spiral() {
    let (acc, _) = run(base_cfg());
    assert!(acc > 0.75, "EGRU spiral accuracy {acc} too low");
}

/// 80%-parameter-sparse EGRU still learns (the paper's headline combined
/// configuration), with far fewer influence MACs than the dense arm.
#[test]
fn sparse_egru_learns_spiral_cheaper() {
    let mut dense_cfg = base_cfg();
    dense_cfg.train.algorithm = AlgorithmKind::RtrlDense;
    let (acc_dense, out_dense) = run(dense_cfg);

    let mut sparse_cfg = base_cfg();
    sparse_cfg.model.param_sparsity = 0.8;
    sparse_cfg.train.algorithm = AlgorithmKind::RtrlBoth;
    let (acc_sparse, out_sparse) = run(sparse_cfg);

    assert!(acc_dense > 0.75, "dense arm failed to learn: {acc_dense}");
    assert!(acc_sparse > 0.7, "sparse arm failed to learn: {acc_sparse}");
    let dense_macs = out_dense.ops.macs_in(sparse_rtrl::metrics::Phase::InfluenceUpdate);
    let sparse_macs = out_sparse.ops.macs_in(sparse_rtrl::metrics::Phase::InfluenceUpdate);
    assert!(
        (sparse_macs as f64) < (dense_macs as f64) * 0.35,
        "expected large savings: sparse {sparse_macs} vs dense {dense_macs}"
    );
}

/// The no-activity-sparsity control (gated tanh) also learns, and its
/// β-sparsity is ~0 so compute-adjusted iterations advance at full ω̃² rate.
#[test]
fn tanh_control_learns_spiral() {
    let mut cfg = base_cfg();
    cfg.model.cell = CellKind::GatedTanh;
    cfg.train.algorithm = AlgorithmKind::RtrlParam;
    let (acc, out) = run(cfg);
    assert!(acc > 0.75, "tanh control accuracy {acc}");
    let last = out.curve.points.last().unwrap();
    assert!(last.beta < 0.05);
    // ω=0 ⇒ compute-adjusted == iteration count
    assert!((last.compute_adjusted - (last.iteration as f64 + 1.0)).abs() < 1.5);
}

/// Delayed-XOR requires multiplicative temporal credit — a harder check
/// that RTRL assigns credit across the gap.
#[test]
fn delayed_xor_learnable() {
    let mut cfg = base_cfg();
    cfg.task.task = TaskKind::DelayedXor;
    cfg.task.timesteps = 8;
    cfg.task.num_sequences = 800;
    cfg.train.iterations = 600;
    cfg.model.hidden = 32;
    cfg.model.theta = 0.05;
    cfg.model.eps = 1.0;
    cfg.model.gamma = 0.5;
    cfg.train.lr = 0.005;
    cfg.seed = 4;
    let (acc, _) = run(cfg);
    assert!(acc > 0.8, "delayed-xor accuracy {acc} (chance = 0.5)");
}

/// SnAp-1 (approximate) still trains the spiral task — the sanity property
/// Menick et al. report — though with biased gradients.
#[test]
fn snap1_trains_spiral() {
    let mut cfg = base_cfg();
    cfg.train.algorithm = AlgorithmKind::Snap1;
    let (acc, _) = run(cfg);
    assert!(acc > 0.7, "snap1 accuracy {acc}");
}

/// Dynamic rewiring (Deep-Rewiring extension, paper Discussion): training
/// with periodic magnitude-rewiring at 80 % sparsity still learns, density
/// stays constant, and the engine remains exact after every mask swap.
#[test]
fn rewiring_learns_and_preserves_density() {
    let mut cfg = base_cfg();
    cfg.model.param_sparsity = 0.8;
    cfg.train.algorithm = AlgorithmKind::RtrlBoth;
    cfg.train.rewire_every = 25;
    cfg.train.rewire_fraction = 0.2;
    cfg.train.iterations = 150;
    let mut data_rng = Trainer::data_rng(cfg.seed);
    let (train, val) = sparse_rtrl::train::build_dataset(&cfg, &mut data_rng);
    let mut tr = Trainer::new(cfg);
    let out = tr.train(&train, &val);
    assert!(out.final_val_accuracy > 0.7, "rewired run accuracy {}", out.final_val_accuracy);
    // density preserved through all rewirings
    let cell = tr.net().layer(0);
    let mask = cell.mask().expect("still masked");
    assert!((mask.density() - 0.2).abs() < 0.01, "density drifted: {}", mask.density());
    // masked entries exactly zero
    let n = cell.n();
    let layout = cell.layout().clone();
    for &b in &cell.recurrent_blocks() {
        let buf = layout.block(cell.params(), b);
        for r in 0..n {
            for c in 0..n {
                if !mask.is_kept(r, c) {
                    assert_eq!(buf[r * n + c], 0.0);
                }
            }
        }
    }
}

/// Sparsity metrics behave: α/β in (0,1) for the event cell and influence
/// sparsity ≥ parameter sparsity with both sparsities on.
#[test]
fn sparsity_metrics_sane() {
    let mut cfg = base_cfg();
    cfg.model.param_sparsity = 0.8;
    cfg.train.iterations = 40;
    let (_, out) = run(cfg);
    for p in &out.curve.points {
        assert!((0.0..=1.0).contains(&p.alpha));
        assert!((0.0..=1.0).contains(&p.beta));
        assert!(p.alpha > 0.01, "EGRU should show some activity sparsity");
    }
    let last = out.curve.points.last().unwrap();
    assert!(
        last.influence_sparsity > 0.5,
        "influence sparsity {} should exceed the 0.8-mask floor region",
        last.influence_sparsity
    );
}

/// **Depth acceptance**: a 2-layer EGRU stack trains on delayed-XOR via the
/// exact sparse engine with decreasing loss, well above chance, and the op
/// counters expose per-layer cost with layer 0's panel (own columns only)
/// cheaper than layer 1's (both layers' columns) — the never-charged
/// cross-layer zero blocks, visible end to end.
#[test]
fn two_layer_egru_learns_delayed_xor_with_sparse_rtrl() {
    let mut cfg = base_cfg();
    cfg.task.task = TaskKind::DelayedXor;
    cfg.task.timesteps = 8;
    cfg.task.num_sequences = 800;
    cfg.train.iterations = 400;
    cfg.train.algorithm = AlgorithmKind::RtrlBoth;
    cfg.model.hidden = 16;
    cfg.model.layers = 2;
    cfg.model.theta = 0.05;
    cfg.model.eps = 1.0;
    cfg.model.gamma = 0.5;
    cfg.train.lr = 0.005;
    cfg.seed = 4;
    let mut data_rng = Trainer::data_rng(cfg.seed);
    let (train, val) = build_dataset(&cfg, &mut data_rng);
    let mut tr = Trainer::new(cfg);
    let out = tr.train(&train, &val);
    let first = out.curve.points.first().unwrap().loss;
    let last = out.curve.points.last().unwrap().loss;
    assert!(last < first, "2-layer delayed-XOR loss did not decrease: {first} -> {last}");
    assert!(
        out.final_val_accuracy > 0.7,
        "2-layer delayed-XOR accuracy {} (chance = 0.5)",
        out.final_val_accuracy
    );
    // per-layer op accounting: both layers charged, split complete, and
    // layer 0 cheaper (narrower influence panel)
    let l0 = out.ops.macs_in_layer(0, sparse_rtrl::metrics::Phase::InfluenceUpdate);
    let l1 = out.ops.macs_in_layer(1, sparse_rtrl::metrics::Phase::InfluenceUpdate);
    assert!(l0 > 0 && l1 > 0, "per-layer influence counters empty: {l0}/{l1}");
    assert_eq!(l0 + l1, out.ops.macs_in(sparse_rtrl::metrics::Phase::InfluenceUpdate));
    assert!(l0 < l1, "layer 0 ({l0}) should charge less than layer 1 ({l1})");
}
