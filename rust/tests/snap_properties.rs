//! Properties of the SnAp approximations (Menick et al. 2020) that Table 1
//! relies on: SnAp-2 ≡ exact RTRL for dense cells, pattern restriction under
//! sparsity, and the cost ordering SnAp-1 < both-sparse RTRL < SnAp-2(dense).

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::metrics::{OpCounter, Phase};
use sparse_rtrl::nn::{LayerStack, Loss, LossKind, Readout, RnnCell};
use sparse_rtrl::rtrl::{GradientEngine, Target};
use sparse_rtrl::sparse::MaskPattern;
use sparse_rtrl::train::build_engine;
use sparse_rtrl::util::Pcg64;

fn grads_for(kind: AlgorithmKind, cell: &RnnCell, seed: u64, steps: usize) -> (Vec<f32>, u64) {
    let net = LayerStack::single(cell.clone());
    let mut rng = Pcg64::new(seed);
    let mut readout = Readout::new(2, net.top_n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut ops = OpCounter::new();
    let mut eng = build_engine(kind, &net, 2);
    eng.begin_sequence();
    let mut xrng = Pcg64::new(seed + 1000);
    for t in 0..steps {
        let x: Vec<f32> = (0..net.n_in()).map(|_| xrng.normal()).collect();
        let target = if t + 1 == steps { Target::Class(1) } else { Target::None };
        eng.step(&net, &mut readout, &mut loss, &x, target, &mut ops);
    }
    eng.end_sequence(&net, &mut readout, &mut ops);
    (eng.grads().to_vec(), ops.macs_in(Phase::InfluenceUpdate))
}

/// On a dense cell, SnAp-2's pattern is the whole matrix ⇒ identical to
/// exact RTRL (Menick et al.: SnAp-2 is exact for fully-connected nets
/// at n=2 hops because J is one hop).
#[test]
fn snap2_exact_on_dense_cell() {
    let mut rng = Pcg64::new(1);
    let cell = RnnCell::egru(10, 2, 0.05, 0.3, 0.5, None, &mut rng);
    let (g_exact, _) = grads_for(AlgorithmKind::RtrlDense, &cell, 3, 8);
    let (g_snap2, _) = grads_for(AlgorithmKind::Snap2, &cell, 3, 8);
    for (i, (a, b)) in g_exact.iter().zip(&g_snap2).enumerate() {
        assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "param {i}: {a} vs {b}");
    }
}

/// SnAp-1 keeps only fan-in influence: gradients are generally *different*
/// from exact RTRL (it is an approximation), but share the fan-in support.
#[test]
fn snap1_is_biased_but_supported_on_fan_in() {
    let mut rng = Pcg64::new(2);
    let cell = RnnCell::egru(10, 2, 0.05, 0.3, 0.5, None, &mut rng);
    let (g_exact, _) = grads_for(AlgorithmKind::RtrlDense, &cell, 4, 10);
    let (g_snap1, _) = grads_for(AlgorithmKind::Snap1, &cell, 4, 10);
    assert!(g_snap1.iter().any(|&g| g != 0.0), "snap1 produced no gradient");
    let diff: f32 = g_exact
        .iter()
        .zip(&g_snap1)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(diff > 1e-6, "snap1 should differ from exact RTRL on recurrent tasks");
}

/// SnAp-1's gradient has nonzero cosine similarity with the exact gradient
/// (it is a *useful* approximation — this is why Menick et al. can train
/// with it).
#[test]
fn snap1_correlates_with_exact() {
    let mut rng = Pcg64::new(3);
    let cell = RnnCell::egru(12, 2, 0.05, 0.3, 0.5, None, &mut rng);
    let (g_exact, _) = grads_for(AlgorithmKind::RtrlDense, &cell, 5, 12);
    let (g_snap1, _) = grads_for(AlgorithmKind::Snap1, &cell, 5, 12);
    let dot: f64 = g_exact.iter().zip(&g_snap1).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
    let na: f64 = g_exact.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = g_snap1.iter().map(|b| (*b as f64).powi(2)).sum::<f64>().sqrt();
    assert!(na > 0.0 && nb > 0.0);
    let cos = dot / (na * nb);
    assert!(cos > 0.3, "snap1/exact cosine {cos:.3} too low");
}

/// Cost ordering on a masked cell: snap1 < rtrl-both; snap2 < rtrl-dense.
#[test]
fn snap_cost_ordering() {
    let mut rng = Pcg64::new(4);
    let n = 20;
    let mask = MaskPattern::random(n, n, 0.3, &mut rng);
    let cell = RnnCell::egru(n, 2, 0.1, 0.3, 0.5, Some(mask), &mut rng);
    let (_, c_dense) = grads_for(AlgorithmKind::RtrlDense, &cell, 6, 10);
    let (_, c_both) = grads_for(AlgorithmKind::RtrlBoth, &cell, 6, 10);
    let (_, c_snap1) = grads_for(AlgorithmKind::Snap1, &cell, 6, 10);
    let (_, c_snap2) = grads_for(AlgorithmKind::Snap2, &cell, 6, 10);
    assert!(c_snap1 < c_both, "snap1 {c_snap1} !< rtrl-both {c_both}");
    assert!(c_snap2 < c_dense, "snap2 {c_snap2} !< dense {c_dense}");
    assert!(c_snap1 < c_snap2);
}

/// SnAp gradients at masked positions are exactly zero (patterns respect
/// the parameter mask).
#[test]
fn snap_respects_mask() {
    let mut rng = Pcg64::new(5);
    let n = 12;
    let mask = MaskPattern::random(n, n, 0.25, &mut rng);
    let cell = RnnCell::evrnn(n, 2, 0.0, 0.3, 0.5, Some(mask.clone()), &mut rng);
    for kind in [AlgorithmKind::Snap1, AlgorithmKind::Snap2] {
        let (g, _) = grads_for(kind, &cell, 7, 8);
        let layout = cell.layout();
        let voff = layout.offset(1); // V block
        for r in 0..n {
            for c in 0..n {
                if !mask.is_kept(r, c) {
                    assert_eq!(g[voff + r * n + c], 0.0, "{:?} leaked into masked param", kind);
                }
            }
        }
    }
}
