//! Acceptance tests for the telemetry subsystem, at the crate boundary:
//!
//! * **Disabled means off, enabled means invisible** — a session with
//!   telemetry on produces bit-identical step outcomes *and* bit-identical
//!   checkpoint bytes to a twin that never had it. Telemetry is pure
//!   inspection: no ops charged, nothing serialized.
//! * Sampling cadence and ring bounds hold on a real session, not just on
//!   the unit-level sampler.
//! * The pool's evict/admit lifecycle lands in its aggregated counters and
//!   snapshot, the snapshot survives its JSON round trip, and evict/admit
//!   events round-trip through the JSON-lines trace into the `stats`
//!   renderer.

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::report::stats::{render_snapshot, render_trace};
use sparse_rtrl::rtrl::Target;
use sparse_rtrl::session::{
    codec, OnlineSession, SessionBuilder, SessionPool, SnapshotFormat, UpdatePolicy,
};
use sparse_rtrl::telemetry::{
    parse_trace, TelemetryConfig, TelemetrySnapshot, TraceEventKind, TraceRecord, TraceSink,
};
use sparse_rtrl::util::Pcg64;

/// The paper's combined-sparsity engine at test scale (2 inputs, like the
/// bundled tasks).
fn reference_session(seed: u64) -> OnlineSession {
    SessionBuilder::new()
        .algorithm(AlgorithmKind::RtrlBoth)
        .hidden(16)
        .param_sparsity(0.8)
        .policy(UpdatePolicy::EveryKSteps(2))
        .seed(seed)
        .build()
}

/// Drive a deterministic mixed stream (supervised every third step) and
/// return every observable outcome field, losses as exact bit patterns.
fn drive(session: &mut OnlineSession, steps: usize) -> Vec<(u64, Option<u32>, Option<usize>, usize, usize, bool)> {
    let mut rng = Pcg64::new(99);
    (0..steps)
        .map(|i| {
            let x = [rng.normal(), rng.normal()];
            let t = if i % 3 == 2 { Target::Class(i % 2) } else { Target::None };
            let o = session.step(&x, t);
            (o.step, o.loss.map(f32::to_bits), o.prediction, o.active_units, o.deriv_units, o.updated)
        })
        .collect()
}

/// The headline acceptance claim: enabling telemetry changes *nothing*
/// observable about the learner. Same outcomes step by step, and the
/// checkpoints — which serialize weights, optimizer moments, engine state
/// AND op counters — are byte-identical in both formats. A telemetry
/// implementation that charged ops or perturbed state would fail here.
#[test]
fn enabled_telemetry_is_bit_identical_to_disabled() {
    let mut plain = reference_session(7);
    let mut instrumented = reference_session(7);
    instrumented.enable_telemetry(TelemetryConfig {
        sample_every: 4,
        ..TelemetryConfig::default()
    });

    let baseline = drive(&mut plain, 32);
    let observed = drive(&mut instrumented, 32);
    assert_eq!(baseline, observed, "telemetry perturbed the stream");

    // it genuinely ran: windows were sampled, latencies were recorded
    let tel = instrumented.telemetry().expect("telemetry is on");
    assert_eq!(tel.steps_seen(), 32);
    assert!(tel.points().count() > 0, "no windows sampled");
    assert_eq!(tel.latency_histogram().count(), 32);

    // nothing of it reaches the checkpoint, in either wire format
    for format in [SnapshotFormat::Binary, SnapshotFormat::Json] {
        let a = codec::encode(&plain.checkpoint(), format);
        let b = codec::encode(&instrumented.checkpoint(), format);
        assert_eq!(a, b, "telemetry leaked into the {format} checkpoint");
    }

    // and turning it off mid-stream keeps the twins in lockstep
    instrumented.disable_telemetry();
    assert!(instrumented.telemetry().is_none());
    assert_eq!(drive(&mut plain, 8), drive(&mut instrumented, 8));
}

/// Cadence and ring bounds on a live session: 32 steps at cadence 4
/// produce 8 windows, the ring keeps only the configured last 4, and every
/// sampled quantity is in range. Memory stays O(ring capacity) no matter
/// how long the stream runs.
#[test]
fn sampling_cadence_and_ring_bounds_on_a_live_session() {
    let mut session = reference_session(11);
    session.enable_telemetry(TelemetryConfig {
        sample_every: 4,
        ring_capacity: 4,
        ..TelemetryConfig::default()
    });
    drive(&mut session, 32);

    let tel = session.telemetry_mut().expect("telemetry is on");
    assert_eq!(tel.drain_new_points().len(), 8, "32 steps / cadence 4");
    assert!(tel.drain_new_points().is_empty(), "drain must empty the buffer");

    let tel = session.telemetry().expect("telemetry is on");
    let points: Vec<_> = tel.points().collect();
    assert_eq!(points.len(), 4, "ring must cap retained points");
    assert_eq!(points.last().unwrap().step, 32);
    assert_eq!(points.first().unwrap().window_start, 17, "oldest retained window");
    for p in &points {
        assert_eq!(p.window_len(), 4);
        assert!((0.0..=1.0).contains(&p.alpha), "alpha {}", p.alpha);
        assert!((0.0..=1.0).contains(&p.beta), "beta {}", p.beta);
        assert!((p.beta_tilde - (1.0 - p.beta)).abs() < 1e-6);
        let occ = p.influence_occupancy.expect("rtrl-both measures influence");
        assert!((0.0..=1.0).contains(&occ), "occupancy {occ}");
    }
    // supervised steps occurred, so the loss series is live
    assert!(tel.loss_ewma().is_some());
    assert!(points.last().unwrap().loss_ewma.is_some());
}

/// The pool lifecycle reaches the aggregated telemetry: one eviction and
/// one admission tick the counters and latency histograms, the snapshot
/// serializes and parses back equal, and the `stats` renderer tabulates
/// it.
#[test]
fn pool_lifecycle_lands_in_snapshot_and_survives_json() {
    let dir = std::env::temp_dir()
        .join(format!("sparse-rtrl-telemetry-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    let spill = dir.join("spill.snap");

    let mut a = reference_session(1);
    a.enable_telemetry(TelemetryConfig { sample_every: 2, ..TelemetryConfig::default() });
    drive(&mut a, 8);
    let b = reference_session(2);

    let mut pool = SessionPool::new(vec![a, b], 2);
    pool.enable_telemetry();
    pool.evict(1, &spill, SnapshotFormat::Binary).expect("evict");
    assert_eq!(pool.len(), 1);
    let readmitted = pool.admit(&spill).expect("admit");
    assert_eq!(readmitted, 1);

    let snap = pool.telemetry_snapshot();
    assert_eq!(snap.live_sessions, 2);
    assert_eq!(snap.evictions, 1);
    assert_eq!(snap.admissions, 1);
    assert!(snap.spill_bytes > 0, "spill bytes uncounted");
    assert_eq!(snap.evict_encode_ns.count, 1);
    assert_eq!(snap.resume_decode_ns.count, 1);
    // per-session rows: the instrumented session carries sampled columns
    assert_eq!(snap.sessions.len(), 2);
    assert_eq!(snap.sessions[0].steps, 8);
    assert!(snap.sessions[0].points > 0);
    assert!(snap.sessions[0].alpha.is_some());
    assert!(snap.sessions[1].alpha.is_none(), "uninstrumented session has no series");

    let back = TelemetrySnapshot::from_json(&snap.to_json()).expect("snapshot round trip");
    assert_eq!(back, snap);

    let rendered = render_snapshot(&snap);
    assert!(rendered.contains("2 live session(s)"), "{rendered}");
    assert!(rendered.contains("admissions 1, evictions 1"), "{rendered}");
    assert!(rendered.contains("evict encode ns: count 1"), "{rendered}");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Pool evict/admit events round-trip through the JSON-lines trace: a
/// trace carrying real sampled metric points plus the pool transition
/// events parses back record-for-record, and the renderer shows both the
/// α/β series and the event tallies.
#[test]
fn evict_admit_events_round_trip_through_the_trace() {
    let mut session = reference_session(5);
    session.enable_telemetry(TelemetryConfig {
        sample_every: 4,
        ..TelemetryConfig::default()
    });
    drive(&mut session, 8);

    let mut records = vec![TraceRecord::Meta {
        session: "s0".into(),
        engine: "rtrl-both".into(),
        hidden: 16,
        layers: 1,
        sample_every: 4,
    }];
    let points = session.telemetry_mut().expect("telemetry on").drain_new_points();
    assert_eq!(points.len(), 2, "8 steps / cadence 4");
    for point in points {
        records.push(TraceRecord::Metrics { session: "s0".into(), point });
    }
    records.push(TraceRecord::Event {
        session: "s0".into(),
        step: 8,
        event: TraceEventKind::Evict,
        bytes: Some(4_096),
        duration_ns: Some(52_000),
    });
    records.push(TraceRecord::Event {
        session: "s0".into(),
        step: 8,
        event: TraceEventKind::Admit,
        bytes: None,
        duration_ns: Some(31_000),
    });

    let mut buf = Vec::new();
    {
        let mut sink = TraceSink::new(&mut buf);
        for rec in &records {
            sink.emit(rec).expect("emit");
        }
        assert_eq!(sink.records(), records.len() as u64);
        sink.flush().expect("flush");
    }
    let text = String::from_utf8(buf).expect("utf8 trace");

    let parsed = parse_trace(&text).expect("trace parses");
    assert_eq!(parsed, records, "trace did not round-trip");

    let rendered = render_trace(&parsed);
    assert!(rendered.contains("alpha"), "{rendered}");
    assert!(rendered.contains("windows: 2 (steps 1..=8)"), "{rendered}");
    assert!(rendered.contains("evict ×1"), "{rendered}");
    assert!(rendered.contains("admit ×1"), "{rendered}");
}
