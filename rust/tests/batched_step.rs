//! The shared-weight batched stepping contract, end to end: lanes stepped
//! through one [`BatchedSparse`] engine never mix arithmetically, so lane
//! gradients are **bitwise** identical to the same lane run at any other
//! batch width or thread count — and the whole batched family stays inside
//! the exact-RTRL envelope against the dense oracle.

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::metrics::OpCounter;
use sparse_rtrl::nn::{LayerStack, Loss, LossKind, Readout, RnnCell};
use sparse_rtrl::rtrl::{BatchedSparse, GradientEngine, Target};
use sparse_rtrl::sparse::MaskPattern;
use sparse_rtrl::train::build_engine;
use sparse_rtrl::util::Pcg64;

type Seq = Vec<(Vec<f32>, Option<usize>)>;

/// One random sequence with supervised steps in the middle and at the end.
fn random_sequence(n_in: usize, len: usize, rng: &mut Pcg64) -> Seq {
    (0..len)
        .map(|t| {
            let x: Vec<f32> = (0..n_in).map(|_| rng.normal()).collect();
            let target = if t == len / 2 || t + 1 == len { Some(t % 2) } else { None };
            (x, target)
        })
        .collect()
}

/// Per-lane sequences of one shared length, each from its own stream.
fn lane_sequences(batch: usize, n_in: usize, len: usize, seed: u64) -> Vec<Seq> {
    (0..batch)
        .map(|s| {
            let mut rng = Pcg64::new(seed ^ ((s as u64 + 1) << 32));
            random_sequence(n_in, len, &mut rng)
        })
        .collect()
}

/// A parameter-sparse EGRU stack (the batched engine's native mode).
fn masked_egru(n: usize, n_in: usize, keep: f32, seed: u64) -> LayerStack {
    let mut rng = Pcg64::new(seed);
    let mask = (keep < 1.0).then(|| MaskPattern::random(n, n, keep, &mut rng));
    LayerStack::single(RnnCell::egru(n, n_in, 0.05, 0.3, 0.5, mask, &mut rng))
}

/// Drive `seqs` (one per lane) through a fresh [`BatchedSparse`] and return
/// every lane's end-of-sequence gradient. The readout is seeded identically
/// for every lane so a solo run with the same seed is directly comparable.
fn run_batched(net: &LayerStack, seqs: &[Seq], threads: usize, readout_seed: u64) -> Vec<Vec<f32>> {
    let batch = seqs.len();
    let mut rng = Pcg64::new(readout_seed);
    let proto = Readout::new(2, net.top_n(), &mut rng);
    let mut readouts: Vec<Readout> = (0..batch).map(|_| proto.clone()).collect();
    let mut losses: Vec<Loss> = (0..batch).map(|_| Loss::new(LossKind::CrossEntropy, 2)).collect();
    let mut counters: Vec<OpCounter> = (0..batch).map(|_| OpCounter::new()).collect();

    let mut eng = BatchedSparse::new(net, 2, batch);
    eng.set_threads(threads);
    eng.begin_sequence();
    for t in 0..seqs[0].len() {
        let xs: Vec<&[f32]> = seqs.iter().map(|s| s[t].0.as_slice()).collect();
        let targets: Vec<Target<'_>> =
            seqs.iter().map(|s| s[t].1.map(Target::Class).unwrap_or(Target::None)).collect();
        let mut rrefs: Vec<&mut Readout> = readouts.iter_mut().collect();
        let mut lrefs: Vec<&mut Loss> = losses.iter_mut().collect();
        let mut orefs: Vec<&mut OpCounter> = counters.iter_mut().collect();
        eng.step(&xs, &targets, &mut rrefs, &mut lrefs, &mut orefs);
    }
    eng.end_sequence();
    (0..batch).map(|s| eng.grads(s).to_vec()).collect()
}

/// The same lane sequence through a solo engine of `kind`.
fn run_solo(net: &LayerStack, kind: AlgorithmKind, seq: &Seq, readout_seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(readout_seed);
    let mut readout = Readout::new(2, net.top_n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut ops = OpCounter::new();
    let mut eng = build_engine(kind, net, 2);
    eng.begin_sequence();
    for (x, t) in seq {
        let target = t.map(Target::Class).unwrap_or(Target::None);
        eng.step(net, &mut readout, &mut loss, x, target, &mut ops);
    }
    eng.end_sequence(net, &mut readout, &mut ops);
    eng.grads().to_vec()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        assert!((x - y).abs() / scale <= tol, "{what}: index {i}: {x} vs {y}");
    }
}

/// Lane 0 of a width-4 run is **bitwise** the width-1 run: widening the
/// batch adds lanes without perturbing a single existing bit.
#[test]
fn lane_gradients_are_bitwise_invariant_to_batch_width() {
    let net = masked_egru(12, 3, 0.5, 7001);
    let seqs = lane_sequences(4, 3, 9, 7002);
    let wide = run_batched(&net, &seqs, 1, 7003);
    let solo_width = run_batched(&net, &seqs[..1], 1, 7003);
    assert!(wide[0].iter().any(|&g| g != 0.0), "degenerate test: lane-0 gradient is all-zero");
    assert_eq!(wide[0], solo_width[0], "lane 0 must not feel lanes 1..4");
    // and every lane individually matches its own width-1 run
    for (s, seq) in seqs.iter().enumerate() {
        let alone = run_batched(&net, std::slice::from_ref(seq), 1, 7003);
        assert_eq!(wide[s], alone[0], "lane {s} differs from its solo-width run");
    }
}

/// Threads are a wall-clock knob only, including above the parallel gate:
/// hidden 32 at full density puts the panel far beyond
/// `PAR_MIN_PANEL_ELEMS`, so the threaded row update genuinely engages —
/// and every lane's gradient must still match serial bit for bit.
#[test]
fn lane_gradients_are_bitwise_invariant_to_threads_above_par_gate() {
    let net = masked_egru(32, 3, 1.0, 7101); // dense mask: maximal panel
    let seqs = lane_sequences(4, 3, 8, 7102);
    let serial = run_batched(&net, &seqs, 1, 7103);
    let threaded = run_batched(&net, &seqs, 3, 7103);
    assert!(serial[0].iter().any(|&g| g != 0.0));
    for s in 0..seqs.len() {
        assert_eq!(serial[s], threaded[s], "lane {s} differs between 1 and 3 threads");
    }
}

/// Every batched lane stays within exact-RTRL tolerance of the dense
/// oracle run on that lane's sequence — batching amortizes structure, it
/// never approximates.
#[test]
fn batched_lanes_match_dense_rtrl() {
    let net = masked_egru(12, 3, 0.5, 7201);
    let seqs = lane_sequences(3, 3, 9, 7202);
    let lanes = run_batched(&net, &seqs, 1, 7203);
    for (s, seq) in seqs.iter().enumerate() {
        let dense = run_solo(&net, AlgorithmKind::RtrlDense, seq, 7203);
        assert!(dense.iter().any(|&g| g != 0.0));
        assert_close(&lanes[s], &dense, 2e-4, &format!("lane {s} vs dense oracle"));
    }
}

/// Lane snapshots transplant across engines of different widths
/// mid-sequence: save two lanes out of a width-3 engine, load them into a
/// fresh width-2 engine, and both engines finish the sequence with bitwise
/// identical gradients for the transplanted lanes.
#[test]
fn lane_state_transplants_across_batch_widths_mid_sequence() {
    let net = masked_egru(10, 3, 0.6, 7301);
    let seqs = lane_sequences(3, 3, 10, 7302);
    let split = 4;

    let batch = seqs.len();
    let mut rng = Pcg64::new(7303);
    let proto = Readout::new(2, net.top_n(), &mut rng);
    let mut readouts: Vec<Readout> = (0..batch).map(|_| proto.clone()).collect();
    let mut losses: Vec<Loss> = (0..batch).map(|_| Loss::new(LossKind::CrossEntropy, 2)).collect();
    let mut counters: Vec<OpCounter> = (0..batch).map(|_| OpCounter::new()).collect();
    let mut eng = BatchedSparse::new(&net, 2, batch);
    eng.begin_sequence();

    let drive = |eng: &mut BatchedSparse,
                 lanes: &[usize],
                 range: std::ops::Range<usize>,
                 readouts: &mut [Readout],
                 losses: &mut [Loss],
                 counters: &mut [OpCounter],
                 seqs: &[Seq]| {
        for t in range.clone() {
            let xs: Vec<&[f32]> = lanes.iter().map(|&s| seqs[s][t].0.as_slice()).collect();
            let targets: Vec<Target<'_>> = lanes
                .iter()
                .map(|&s| seqs[s][t].1.map(Target::Class).unwrap_or(Target::None))
                .collect();
            let mut rrefs: Vec<&mut Readout> = readouts.iter_mut().collect();
            let mut lrefs: Vec<&mut Loss> = losses.iter_mut().collect();
            let mut orefs: Vec<&mut OpCounter> = counters.iter_mut().collect();
            eng.step(&xs, &targets, &mut rrefs, &mut lrefs, &mut orefs);
        }
    };

    drive(&mut eng, &[0, 1, 2], 0..split, &mut readouts, &mut losses, &mut counters, &seqs);

    // transplant lanes 2 and 0 (in that order) into a width-2 engine
    let mut small = BatchedSparse::new(&net, 2, 2);
    small.load_lane(0, &eng.save_lane(2)).expect("lane 2 snapshot must load");
    small.load_lane(1, &eng.save_lane(0)).expect("lane 0 snapshot must load");
    let mut s_readouts = vec![readouts[2].clone(), readouts[0].clone()];
    let mut s_losses = vec![losses[2].clone(), losses[0].clone()];
    let mut s_counters = vec![OpCounter::new(), OpCounter::new()];

    let t_len = seqs[0].len();
    drive(&mut eng, &[0, 1, 2], split..t_len, &mut readouts, &mut losses, &mut counters, &seqs);
    drive(&mut small, &[2, 0], split..t_len, &mut s_readouts, &mut s_losses, &mut s_counters, &seqs);

    eng.end_sequence();
    small.end_sequence();
    assert!(eng.grads(2).iter().any(|&g| g != 0.0));
    assert_eq!(eng.grads(2), small.grads(0), "transplanted lane 2 diverged");
    assert_eq!(eng.grads(0), small.grads(1), "transplanted lane 0 diverged");
}
