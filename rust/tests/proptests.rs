//! Randomized property tests over the sparse-RTRL invariants.
//!
//! In-tree property harness (no proptest crate offline): each property runs
//! across many PCG-seeded random configurations — cells, masks, sparsity
//! levels, sequence lengths — and reports the failing seed on violation.

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::metrics::OpCounter;
use sparse_rtrl::nn::{Activation, Dynamics, LayerStack, Loss, LossKind, Readout, RnnCell};
use sparse_rtrl::rtrl::{ColumnMap, GradientEngine, Target};
use sparse_rtrl::sparse::{MaskPattern, RowSet};
use sparse_rtrl::train::build_engine;
use sparse_rtrl::util::Pcg64;

/// Draw a random cell configuration.
fn random_cell(rng: &mut Pcg64) -> RnnCell {
    let n = 4 + rng.below(12) as usize;
    let n_in = 1 + rng.below(3) as usize;
    let dynamics = if rng.bernoulli(0.5) { Dynamics::Gated } else { Dynamics::Linear };
    let activation = if rng.bernoulli(0.6) {
        Activation::Heaviside { gamma: rng.uniform(0.1, 0.6), eps: rng.uniform(0.2, 0.8) }
    } else {
        Activation::Tanh
    };
    let theta = rng.uniform(-0.1, 0.3);
    let mask = if rng.bernoulli(0.6) {
        Some(MaskPattern::random(n, n, rng.uniform(0.05, 0.9), rng))
    } else {
        None
    };
    RnnCell::new(n, n_in, dynamics, activation, theta, mask, rng)
}

fn run_pair(
    cell: &RnnCell,
    a: AlgorithmKind,
    b: AlgorithmKind,
    steps: usize,
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    let net = LayerStack::single(cell.clone());
    let (ga, gb) = (run_one(&net, a, steps, seed), run_one(&net, b, steps, seed));
    (ga, gb)
}

/// Run one engine over a stack for `steps` random supervised steps.
fn run_one(net: &LayerStack, kind: AlgorithmKind, steps: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    let mut readout = Readout::new(2, net.top_n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut ops = OpCounter::new();
    let mut eng = build_engine(kind, net, 2);
    eng.begin_sequence();
    let mut xrng = Pcg64::new(seed ^ 0xdead_beef);
    for t in 0..steps {
        let x: Vec<f32> = (0..net.n_in()).map(|_| xrng.normal()).collect();
        let target = if xrng.bernoulli(0.3) || t + 1 == steps {
            Target::Class(xrng.below(2) as usize)
        } else {
            Target::None
        };
        eng.step(net, &mut readout, &mut loss, &x, target, &mut ops);
    }
    eng.end_sequence(net, &mut readout, &mut ops);
    eng.grads().to_vec()
}

/// Draw a random 2-layer stack (uniform cell family per layer, independent
/// masks) — the depth analogue of `random_cell`.
fn random_stack2(rng: &mut Pcg64) -> LayerStack {
    let n0 = 4 + rng.below(8) as usize;
    let n1 = 3 + rng.below(8) as usize;
    let n_in = 1 + rng.below(3) as usize;
    let dynamics = if rng.bernoulli(0.5) { Dynamics::Gated } else { Dynamics::Linear };
    let activation = if rng.bernoulli(0.6) {
        Activation::Heaviside { gamma: rng.uniform(0.1, 0.6), eps: rng.uniform(0.2, 0.8) }
    } else {
        Activation::Tanh
    };
    let theta = rng.uniform(-0.1, 0.3);
    let m0 = if rng.bernoulli(0.6) {
        Some(MaskPattern::random(n0, n0, rng.uniform(0.05, 0.9), rng))
    } else {
        None
    };
    let l0 = RnnCell::new(n0, n_in, dynamics, activation, theta, m0, rng);
    let m1 = if rng.bernoulli(0.6) {
        Some(MaskPattern::random(n1, n1, rng.uniform(0.05, 0.9), rng))
    } else {
        None
    };
    let l1 = RnnCell::new(n1, n0, dynamics, activation, theta, m1, rng);
    LayerStack::new(vec![l0, l1])
}

/// PROPERTY: every sparse engine equals dense RTRL on random configs.
#[test]
fn prop_sparse_engines_exact() {
    for case in 0..40u64 {
        let mut rng = Pcg64::new(900 + case);
        let cell = random_cell(&mut rng);
        let steps = 2 + rng.below(10) as usize;
        for kind in [
            AlgorithmKind::RtrlActivity,
            AlgorithmKind::RtrlParam,
            AlgorithmKind::RtrlBoth,
            AlgorithmKind::Bptt,
        ] {
            let (g_ref, g) = run_pair(&cell, AlgorithmKind::RtrlDense, kind, steps, case);
            for (i, (x, y)) in g_ref.iter().zip(&g).enumerate() {
                let tol = 3e-4 * (1.0 + x.abs().max(y.abs()));
                assert!(
                    (x - y).abs() <= tol,
                    "case {case} {} param {i}: dense {x} vs {y} (cell n={} {:?} {:?})",
                    kind.name(),
                    cell.n(),
                    cell.dynamics(),
                    cell.activation(),
                );
            }
        }
    }
}

/// PROPERTY: gradients at masked parameter positions are exactly zero for
/// every engine.
#[test]
fn prop_masked_positions_zero_grad() {
    for case in 0..30u64 {
        let mut rng = Pcg64::new(1700 + case);
        let n = 4 + rng.below(10) as usize;
        let mask = MaskPattern::random(n, n, rng.uniform(0.1, 0.7), &mut rng);
        let cell = RnnCell::egru(n, 2, 0.1, 0.3, 0.5, Some(mask.clone()), &mut rng);
        for kind in AlgorithmKind::all() {
            let (g, _) = run_pair(&cell, kind, kind, 5, case);
            let layout = cell.layout();
            for &b in &cell.recurrent_blocks() {
                for r in 0..n {
                    let range = layout.row_range(b, r);
                    for (c, pi) in range.enumerate() {
                        if !mask.is_kept(r, c) {
                            assert_eq!(
                                g[pi],
                                0.0,
                                "case {case} {}: masked param ({b},{r},{c}) has grad",
                                kind.name()
                            );
                        }
                    }
                }
            }
        }
    }
}

/// PROPERTY: ColumnMap is a bijection between tracked params and columns.
#[test]
fn prop_column_map_bijection() {
    for case in 0..50u64 {
        let mut rng = Pcg64::new(2500 + case);
        let cell = random_cell(&mut rng);
        let map = ColumnMap::from_cell(&cell);
        let mut seen = vec![false; cell.p()];
        for j in 0..map.len() {
            let pi = map.param_of(j);
            assert!(!seen[pi], "case {case}: param {pi} mapped twice");
            seen[pi] = true;
            assert_eq!(map.compact_of(pi), Some(j), "case {case}");
        }
        // untracked params must be masked recurrent entries
        let layout = cell.layout();
        for pi in 0..cell.p() {
            if map.compact_of(pi).is_none() {
                let (b, r, c) = layout.decode(pi);
                assert!(cell.recurrent_blocks().contains(&b), "case {case}");
                assert!(!cell.mask().unwrap().is_kept(r, c), "case {case}");
            }
        }
    }
}

/// PROPERTY: RowSet behaves like a set under random insert/clear traffic.
#[test]
fn prop_rowset_semantics() {
    for case in 0..50u64 {
        let mut rng = Pcg64::new(3600 + case);
        let n = 1 + rng.below(64) as usize;
        let mut s = RowSet::empty(n);
        let mut reference = std::collections::BTreeSet::new();
        for _ in 0..200 {
            match rng.below(10) {
                0 => {
                    s.clear();
                    reference.clear();
                }
                _ => {
                    let k = rng.below(n as u64) as usize;
                    s.insert(k);
                    reference.insert(k);
                }
            }
            assert_eq!(s.len(), reference.len(), "case {case}");
            for k in 0..n {
                assert_eq!(s.contains(k), reference.contains(&k), "case {case} k={k}");
            }
        }
        let mut from_iter: Vec<usize> = s.iter().collect();
        from_iter.sort_unstable();
        let expect: Vec<usize> = reference.into_iter().collect();
        assert_eq!(from_iter, expect, "case {case}");
    }
}

/// PROPERTY: forward activations of Heaviside cells are always binary and
/// the deriv-active count never exceeds n.
#[test]
fn prop_event_cell_binary_activations() {
    for case in 0..30u64 {
        let mut rng = Pcg64::new(4700 + case);
        let n = 4 + rng.below(12) as usize;
        let cell = RnnCell::egru(n, 2, rng.uniform(0.0, 0.3), 0.3, rng.uniform(0.2, 0.8), None, &mut rng);
        let net = LayerStack::single(cell);
        let mut readout = Readout::new(2, n, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops = OpCounter::new();
        let mut eng = build_engine(AlgorithmKind::RtrlBoth, &net, 2);
        eng.begin_sequence();
        for _ in 0..10 {
            let x = [rng.normal(), rng.normal()];
            let r = eng.step(&net, &mut readout, &mut loss, &x, Target::None, &mut ops);
            assert!(r.active_units <= n);
            assert!(r.deriv_units <= n);
        }
    }
}

/// PROPERTY (depth): every exact engine equals dense RTRL on random
/// 2-layer stacks — the block lower-bidiagonal recursion keeps the "no
/// approximations" claim at depth.
#[test]
fn prop_sparse_engines_exact_depth2() {
    for case in 0..20u64 {
        let mut rng = Pcg64::new(7700 + case);
        let net = random_stack2(&mut rng);
        let steps = 2 + rng.below(8) as usize;
        let g_ref = run_one(&net, AlgorithmKind::RtrlDense, steps, case);
        for kind in [
            AlgorithmKind::RtrlActivity,
            AlgorithmKind::RtrlParam,
            AlgorithmKind::RtrlBoth,
            AlgorithmKind::Bptt,
        ] {
            let g = run_one(&net, kind, steps, case);
            for (i, (x, y)) in g_ref.iter().zip(&g).enumerate() {
                let tol = 4e-4 * (1.0 + x.abs().max(y.abs()));
                assert!(
                    (x - y).abs() <= tol,
                    "case {case} {} param {i}: dense {x} vs {y} (stack {}+{})",
                    kind.name(),
                    net.layer(0).n(),
                    net.layer(1).n(),
                );
            }
        }
    }
}

/// PROPERTY: dynamic rewiring preserves exactness — after any
/// magnitude-rewire + set_mask, a freshly built sparse engine still matches
/// dense RTRL on the new topology, and density is invariant.
#[test]
fn prop_rewiring_preserves_exactness_and_density() {
    for case in 0..15u64 {
        let mut rng = Pcg64::new(6900 + case);
        let n = 6 + rng.below(8) as usize;
        let density = rng.uniform(0.2, 0.6);
        let mask = MaskPattern::random(n, n, density, &mut rng);
        let mut cell = RnnCell::egru(n, 2, 0.1, 0.3, 0.5, Some(mask), &mut rng);
        let kept_before = cell.mask().unwrap().kept();
        for round in 0..3 {
            let new_mask = sparse_rtrl::sparse::rewire::magnitude_rewire(
                &cell,
                rng.uniform(0.1, 0.5),
                &mut rng,
            );
            cell.set_mask(new_mask, 0.05, &mut rng);
            assert_eq!(cell.mask().unwrap().kept(), kept_before, "case {case} round {round}");
            let steps = 4 + rng.below(5) as usize;
            let (g_ref, g) =
                run_pair(&cell, AlgorithmKind::RtrlDense, AlgorithmKind::RtrlBoth, steps, case);
            for (i, (x, y)) in g_ref.iter().zip(&g).enumerate() {
                assert!(
                    (x - y).abs() <= 3e-4 * (1.0 + x.abs().max(y.abs())),
                    "case {case} round {round} param {i}: {x} vs {y}"
                );
            }
        }
    }
}

/// PROPERTY: influence sparsity reported by the engine is within [0,1] and
/// at least the parameter-mask floor for column-compacted modes.
#[test]
fn prop_influence_sparsity_bounds() {
    for case in 0..20u64 {
        let mut rng = Pcg64::new(5800 + case);
        let n = 6 + rng.below(8) as usize;
        let density = rng.uniform(0.1, 0.9);
        let mask = MaskPattern::random(n, n, density, &mut rng);
        let cell = RnnCell::egru(n, 2, 0.1, 0.3, 0.5, Some(mask), &mut rng);
        let mut readout = Readout::new(2, n, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops = OpCounter::new();
        let net = LayerStack::single(cell);
        let mut eng = build_engine(AlgorithmKind::RtrlDense, &net, 2);
        eng.set_measure_influence(true);
        eng.begin_sequence();
        for _ in 0..6 {
            let x = [rng.normal(), rng.normal()];
            let r = eng.step(&net, &mut readout, &mut loss, &x, Target::None, &mut ops);
            let s = r.influence_sparsity.unwrap();
            assert!((0.0..=1.0).contains(&s), "case {case}: sparsity {s}");
        }
    }
}
