//! The step-Jacobian slab contract (see `rtrl::kernels`):
//!
//! * slab-built entries are **bit-exact** against direct `dv_da`/`dv_dx`
//!   evaluation for all four cell dynamics, masked and dense, at depths
//!   1 and 2;
//! * the slab refactor left engine op counts unchanged — pinned against
//!   the pre-refactor per-scalar charging formulas;
//! * intra-step parallelism changes wall-clock only: a multi-threaded run
//!   is bit-identical to the serial one — gradients, losses, op counters,
//!   and a full training run's final weights.

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::metrics::{OpCounter, Phase};
use sparse_rtrl::nn::{LayerStack, Loss, LossKind, Readout, RnnCell};
use sparse_rtrl::rtrl::kernels::{CrossSelect, JacobianSlab, OwnSelect, RowSelect};
use sparse_rtrl::rtrl::{GradientEngine, SparseRtrl, SparsityMode, Target};
use sparse_rtrl::sparse::MaskPattern;
use sparse_rtrl::train::build_engine;
use sparse_rtrl::util::Pcg64;

/// The four dynamics × activation combinations of the experiment matrix.
fn all_cells(n: usize, n_in: usize, mask: Option<MaskPattern>, rng: &mut Pcg64) -> Vec<(&'static str, RnnCell)> {
    vec![
        ("egru", RnnCell::egru(n, n_in, 0.05, 0.3, 0.5, mask.clone(), rng)),
        ("evrnn", RnnCell::evrnn(n, n_in, 0.05, 0.3, 0.5, mask.clone(), rng)),
        ("gated_tanh", RnnCell::gated_tanh(n, n_in, mask.clone(), rng)),
        ("vanilla", RnnCell::vanilla(n, n_in, mask, rng)),
    ]
}

/// Property: for every dynamics, at depths 1 and 2, every slab entry equals
/// the direct per-scalar evaluation bit-for-bit — own block and cross block,
/// dense and masked.
#[test]
fn slab_entries_bit_exact_for_all_dynamics_and_depths() {
    for masked in [false, true] {
        let mut rng = Pcg64::new(101 + masked as u64);
        let mask = masked.then(|| MaskPattern::random(7, 7, 0.4, &mut rng));
        for (what, cell0) in all_cells(7, 2, mask.clone(), &mut rng) {
            // depth 2: layer 1 reads layer 0's 7 activations
            let mut rng2 = Pcg64::new(202);
            let cell1 = match what {
                "egru" => RnnCell::egru(5, 7, 0.05, 0.3, 0.5, None, &mut rng2),
                "evrnn" => RnnCell::evrnn(5, 7, 0.05, 0.3, 0.5, None, &mut rng2),
                "gated_tanh" => RnnCell::gated_tanh(5, 7, None, &mut rng2),
                _ => RnnCell::vanilla(5, 7, None, &mut rng2),
            };
            let net = LayerStack::new(vec![cell0, cell1]);
            let mut scratch = net.scratch();
            let mut ops = OpCounter::new();
            let mut xr = Pcg64::new(303);
            let mut a_prev = vec![0.0; net.total_units()];
            for _ in 0..3 {
                net.forward(&a_prev, &[xr.normal(), xr.normal()], &mut scratch, &mut ops);
                scratch.write_state(&mut a_prev);
            }
            let mut slab = JacobianSlab::new();
            for l in 0..2 {
                let cell = net.layer(l);
                let sl = &scratch.layers[l];
                let cross = if l > 0 { CrossSelect::All } else { CrossSelect::Skip };
                // kept pattern, all rows
                slab.build(cell, sl, RowSelect::All, OwnSelect::Kept, cross);
                for k in 0..cell.n() {
                    let (cols, vals) = slab.own_row(k);
                    assert_eq!(cols, cell.kept_cols(k), "{what}/L{l} row {k} pattern");
                    for (&c, &v) in cols.iter().zip(vals) {
                        assert_eq!(
                            v.to_bits(),
                            cell.dv_da(sl, k, c as usize).to_bits(),
                            "{what}/L{l} dv_da[{k},{c}]"
                        );
                    }
                    for (j, &v) in slab.cross_row(k).iter().enumerate() {
                        assert_eq!(
                            v.to_bits(),
                            cell.dv_dx(sl, k, j).to_bits(),
                            "{what}/L{l} dv_dx[{k},{j}]"
                        );
                    }
                }
                // deriv-active rows only
                slab.build(cell, sl, RowSelect::DerivActive, OwnSelect::Kept, CrossSelect::Skip);
                for k in 0..cell.n() {
                    assert_eq!(slab.has_row(k), sl.dphi[k] != 0.0, "{what}/L{l} row gate {k}");
                }
                // diagonal build matches direct diagonal evaluation
                slab.build(cell, sl, RowSelect::All, OwnSelect::Diag, CrossSelect::Skip);
                for k in 0..cell.n() {
                    assert_eq!(
                        slab.diag(k).to_bits(),
                        cell.dv_da(sl, k, k).to_bits(),
                        "{what}/L{l} diag {k}"
                    );
                }
            }
        }
    }
}

/// Counts-unchanged pin (the op-hoisting satellite): the slab-driven sparse
/// engine charges exactly the pre-refactor per-scalar formulas. On a dense
/// vanilla-tanh cell under `SparsityMode::Parameter` (no activity skipping,
/// full column space) the historical charging was, per step `t`:
///
/// * Jacobian: `0` at `t = 1` (previous panel empty), `n²` after;
/// * InfluenceUpdate: `n·p` at `t = 1` (gate-scale only), `jnz·p + n·p`
///   after, where `jnz` = nonzero recurrent weights (each nonzero Jacobian
///   coefficient gathers one `p`-wide panel row).
#[test]
fn sparse_engine_op_counts_match_per_scalar_formulas() {
    let n = 6usize;
    let mut rng = Pcg64::new(17);
    let cell = RnnCell::vanilla(n, 2, None, &mut rng);
    let net = LayerStack::single(cell);
    let p = net.p();
    // nonzero recurrent entries (the jlist lengths of the historical path)
    let vblock = sparse_rtrl::nn::cell::linear_blocks::V;
    let layout = net.layer(0).layout();
    let v = layout.block(net.layer(0).params(), vblock);
    let jnz = v.iter().filter(|&&w| w != 0.0).count();
    assert!(jnz > 0, "degenerate init");

    let mut readout = Readout::new(2, n, &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut eng = SparseRtrl::new(&net, 2, SparsityMode::Parameter);
    let mut ops = OpCounter::new();
    eng.begin_sequence();
    let steps = 5u64;
    let mut xr = Pcg64::new(23);
    for _ in 0..steps {
        // small inputs: tanh stays unsaturated, φ' ≠ 0 everywhere
        let x = [0.3 * xr.normal(), 0.3 * xr.normal()];
        let r = eng.step(&net, &mut readout, &mut loss, &x, Target::None, &mut ops);
        assert_eq!(r.deriv_units, n, "tanh φ' must be nonzero for the formula to apply");
    }
    let (n64, p64) = (n as u64, p as u64);
    assert_eq!(ops.macs_in(Phase::Jacobian), (steps - 1) * n64 * n64);
    assert_eq!(
        ops.macs_in(Phase::InfluenceUpdate),
        steps * n64 * p64 + (steps - 1) * jnz as u64 * p64
    );
}

/// Threads are a pure wall-clock knob: a 3-thread engine produces
/// bit-identical gradients, losses, activations and op counters to the
/// serial engine. The stack is sized so every step's panel work clears the
/// engine's parallel threshold (gated-tanh → all rows deriv-active, panels
/// tens of thousands of elements wide), so the pooled row update genuinely
/// runs — on a 2-layer stack with a masked (column-compacted) layer 0.
#[test]
fn threaded_sparse_engine_bit_identical_to_serial() {
    let mut rng = Pcg64::new(61);
    let mask0 = MaskPattern::random(32, 32, 0.5, &mut rng);
    let l0 = RnnCell::gated_tanh(32, 2, Some(mask0), &mut rng);
    let l1 = RnnCell::gated_tanh(16, 32, None, &mut rng);
    let net = LayerStack::new(vec![l0, l1]);
    let mut xr = Pcg64::new(62);
    let inputs: Vec<[f32; 2]> = (0..12).map(|_| [xr.normal(), xr.normal()]).collect();

    let run = |threads: usize, mode: SparsityMode| {
        let mut rrng = Pcg64::new(7);
        let mut readout = Readout::new(2, net.top_n(), &mut rrng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops = OpCounter::new();
        let mut eng = SparseRtrl::new(&net, 2, mode);
        eng.set_threads(threads);
        eng.begin_sequence();
        let mut losses = Vec::new();
        for (t, x) in inputs.iter().enumerate() {
            let tg = if t % 4 == 3 { Target::Class(t % 2) } else { Target::None };
            let r = eng.step(&net, &mut readout, &mut loss, x, tg, &mut ops);
            losses.push(r.loss.map(f32::to_bits));
        }
        eng.end_sequence(&net, &mut readout, &mut ops);
        let grads: Vec<u32> = eng.grads().iter().map(|g| g.to_bits()).collect();
        let acts: Vec<u32> = eng.activations().iter().map(|a| a.to_bits()).collect();
        (grads, acts, losses, ops)
    };
    for mode in [SparsityMode::Both, SparsityMode::Activity, SparsityMode::Parameter] {
        let (g1, a1, l1s, o1) = run(1, mode);
        let (g3, a3, l3s, o3) = run(3, mode);
        assert_eq!(g1, g3, "{mode:?}: gradients diverged across thread counts");
        assert_eq!(a1, a3, "{mode:?}: activations diverged");
        assert_eq!(l1s, l3s, "{mode:?}: losses diverged");
        for ph in Phase::all() {
            assert_eq!(o1.macs_in(ph), o3.macs_in(ph), "{mode:?}/{}: MACs differ", ph.name());
            assert_eq!(o1.words_in(ph), o3.words_in(ph), "{mode:?}/{}: words differ", ph.name());
        }
        for l in 0..2 {
            for ph in Phase::all() {
                assert_eq!(o1.macs_in_layer(l, ph), o3.macs_in_layer(l, ph), "{mode:?} layer {l}");
            }
        }
    }
}

/// End-to-end: a full training run (trainer → session → engine) with
/// `threads = 4` ends at bit-identical weights and total op counts to the
/// serial run — the whole-system form of the invariant CI checks on the
/// smoke bench.
#[test]
fn full_training_run_bit_identical_across_thread_counts() {
    use sparse_rtrl::config::ExperimentConfig;
    use sparse_rtrl::train::{build_dataset, Trainer};
    let mut cfg = ExperimentConfig::default();
    cfg.task.num_sequences = 60;
    cfg.train.iterations = 8;
    cfg.train.batch_size = 4;
    cfg.train.eval_every = 0;
    cfg.model.hidden = 10;
    cfg.model.layers = 2;
    cfg.model.param_sparsity = 0.5;
    cfg.train.algorithm = AlgorithmKind::RtrlBoth;

    let run = |threads: usize| {
        let mut data_rng = Trainer::data_rng(cfg.seed);
        let (train, val) = build_dataset(&cfg, &mut data_rng);
        let mut tr = Trainer::new(cfg.clone());
        tr.set_threads(threads);
        let out = tr.train(&train, &val);
        let mut w = vec![0.0; tr.net().p()];
        tr.net().copy_params_into(&mut w);
        let bits: Vec<u32> = w.iter().map(|x| x.to_bits()).collect();
        (bits, out.ops.total_macs(), out.ops.total_words())
    };
    let (w1, m1, d1) = run(1);
    let (w4, m4, d4) = run(4);
    assert_eq!(w1, w4, "trained weights diverged across thread counts");
    assert_eq!(m1, m4, "total MACs diverged");
    assert_eq!(d1, d4, "total words diverged");
}

/// The slab path preserves gradient exactness across every exact engine —
/// a threaded sparse engine still matches dense RTRL on a masked stack.
#[test]
fn threaded_engine_still_matches_dense_reference() {
    let mut rng = Pcg64::new(91);
    let mask = MaskPattern::random(8, 8, 0.5, &mut rng);
    let net = LayerStack::single(RnnCell::egru(8, 2, 0.05, 0.3, 0.5, Some(mask), &mut rng));
    let mut xr = Pcg64::new(92);
    let inputs: Vec<[f32; 2]> = (0..9).map(|_| [xr.normal(), xr.normal()]).collect();
    let run = |mut eng: Box<dyn GradientEngine>| {
        let mut rrng = Pcg64::new(5);
        let mut readout = Readout::new(2, 8, &mut rrng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops = OpCounter::new();
        eng.set_threads(2);
        eng.begin_sequence();
        for (t, x) in inputs.iter().enumerate() {
            let tg = if t + 1 == inputs.len() { Target::Class(1) } else { Target::None };
            eng.step(&net, &mut readout, &mut loss, x, tg, &mut ops);
        }
        eng.end_sequence(&net, &mut readout, &mut ops);
        eng.grads().to_vec()
    };
    let reference = run(build_engine(AlgorithmKind::RtrlDense, &net, 2));
    for kind in [AlgorithmKind::RtrlActivity, AlgorithmKind::RtrlParam, AlgorithmKind::RtrlBoth] {
        let g = run(build_engine(kind, &net, 2));
        for (i, (a, b)) in reference.iter().zip(&g).enumerate() {
            let tol = 3e-4 * (1.0 + a.abs().max(b.abs()));
            assert!((a - b).abs() <= tol, "{}: grad[{i}] {a} vs {b}", kind.name());
        }
    }
}
