//! The serve loop's end-to-end contracts: an evicted-and-readmitted lane
//! rejoins the fused batched group **bitwise**, drained serve checkpoints
//! are byte-identical to offline `stream`-style sessions fed the same
//! events (including across LRU churn), the resident budget is invisible
//! in the learner state, and the line protocol round-trips over an
//! in-memory transport.

use sparse_rtrl::config::{AlgorithmKind, ExperimentConfig};
use sparse_rtrl::data::StepTarget;
use sparse_rtrl::serve::{serve_io, Scheduler, ServeConfig};
use sparse_rtrl::session::{
    codec, SessionBuilder, SessionPool, SnapshotFormat, StepOutcome, StreamEvent, UpdatePolicy,
};
use std::path::PathBuf;

fn unique_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sparse-rtrl-serve-it-{tag}-{}", std::process::id()))
}

/// A small parameter-sparse config — the batched engine's native mode.
fn model_config(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model.hidden = 6;
    cfg.model.param_sparsity = 0.5;
    cfg.train.algorithm = AlgorithmKind::RtrlParam;
    cfg.seed = seed;
    cfg
}

fn serve_cfg(tag: &str, max_resident: usize) -> ServeConfig {
    ServeConfig {
        base: model_config(0),
        policy: UpdatePolicy::EveryKSteps(1),
        max_resident,
        burst: 4,
        spill_dir: unique_dir(tag),
        ..ServeConfig::default()
    }
}

/// Deterministic per-tenant event mix: steps (every other one supervised),
/// a mid-stream sequence boundary, a trailing explicit update.
fn tenant_events(salt: u64, n: usize) -> Vec<StreamEvent> {
    let mut out = Vec::new();
    for i in 0..n {
        let t = i as u64 + salt;
        let x = vec![((t * 13 + 1) as f32 * 0.37).sin(), ((t * 7 + 2) as f32 * 0.23).cos()];
        let target = if i % 2 == 0 { StepTarget::Class(i % 2) } else { StepTarget::None };
        out.push(StreamEvent::Step { x, target });
        if i == n / 2 {
            out.push(StreamEvent::EndSequence);
        }
    }
    out.push(StreamEvent::Update);
    out
}

/// What `sparse-rtrl stream` would do with the same events: one offline
/// session, stepped directly, checkpointed in the binary format — the
/// byte-for-byte reference for every drained serve snapshot.
fn offline_checkpoint(cfg: &ServeConfig, seed: u64, events: &[StreamEvent]) -> Vec<u8> {
    let mut base = cfg.base.clone();
    base.seed = seed;
    let mut s = SessionBuilder::from_config(base)
        .policy(cfg.policy)
        .predict_always(true)
        .build();
    s.set_threads(cfg.threads);
    for ev in events {
        match ev {
            StreamEvent::Step { x, target } => {
                s.step(x, target.as_target());
            }
            StreamEvent::Update => {
                s.update_now();
            }
            StreamEvent::EndSequence => {
                s.end_sequence();
                s.begin_sequence();
            }
        }
    }
    codec::encode(&s.checkpoint(), SnapshotFormat::Binary)
}

fn assert_outcome_bits(a: &StepOutcome, b: &StepOutcome, what: &str) {
    assert_eq!(a.step, b.step, "{what}: step counter");
    assert_eq!(a.loss.map(f32::to_bits), b.loss.map(f32::to_bits), "{what}: loss bits");
    assert_eq!(a.prediction, b.prediction, "{what}: prediction");
    assert_eq!(a.correct, b.correct, "{what}: correctness");
    assert_eq!(a.active_units, b.active_units, "{what}: active units");
    assert_eq!(a.deriv_units, b.deriv_units, "{what}: derivative units");
}

/// The mid-stream spill round trip, at the pool level (satellite of the
/// serve loop): three shared-weight sessions step fused via
/// `step_batched`; one is evicted to a snapshot mid-stream and readmitted.
/// Every subsequent outcome and the final checkpoint must be **bitwise**
/// identical to a twin pool that never evicted — spilling a lane is
/// invisible to the arithmetic.
#[test]
fn evicted_lane_rejoins_batched_group_bit_exactly() {
    // Manual policy: no per-lane updates, so weights stay shared and the
    // three lanes keep fusing for the whole stream.
    let build = || {
        SessionBuilder::from_config(model_config(11))
            .policy(UpdatePolicy::Manual)
            .predict_always(true)
            .build()
    };
    let event = |t: u64, lane: usize| -> (Vec<f32>, StepTarget) {
        let x = vec![
            ((t * 7 + lane as u64 * 3 + 1) as f32 * 0.13).sin(),
            ((t + lane as u64 + 2) as f32 * 0.29).cos(),
        ];
        let target =
            if t % 2 == 0 { StepTarget::Class((t as usize + lane) % 2) } else { StepTarget::None };
        (x, target)
    };

    let mut pool = SessionPool::new((0..3).map(|_| build()).collect(), 1);
    let mut twin = SessionPool::new((0..3).map(|_| build()).collect(), 1);
    for t in 0..4u64 {
        let events: Vec<_> = (0..3).map(|lane| event(t, lane)).collect();
        let a = pool.step_batched(&events);
        let b = twin.step_batched(&events);
        for lane in 0..3 {
            assert_outcome_bits(&a[lane], &b[lane], &format!("pre-evict t={t} lane {lane}"));
        }
    }

    let dir = unique_dir("lane");
    std::fs::create_dir_all(&dir).expect("spill dir");
    let path = dir.join("lane1.snap");
    let id1 = pool.id_at(1).expect("slot 1 occupied");
    pool.evict_id(id1, &path, SnapshotFormat::Binary).expect("evict");
    assert_eq!(pool.len(), 2);
    let readmitted = pool.admit_id(&path).expect("admit");
    // slots are now [lane0, lane2, lane1]: the readmitted lane landed last
    let order = [0usize, 2, 1];

    for t in 4..10u64 {
        let events: Vec<_> = order.iter().map(|&lane| event(t, lane)).collect();
        let a = pool.step_batched(&events);
        let twin_events: Vec<_> = (0..3).map(|lane| event(t, lane)).collect();
        let b = twin.step_batched(&twin_events);
        for (slot, &lane) in order.iter().enumerate() {
            assert_outcome_bits(&a[slot], &b[lane], &format!("post-admit t={t} lane {lane}"));
        }
    }

    let roundtripped = codec::encode(
        &pool.session_by_id(readmitted).expect("readmitted resident").checkpoint(),
        SnapshotFormat::Binary,
    );
    let straight = codec::encode(&twin.session(1).checkpoint(), SnapshotFormat::Binary);
    assert_eq!(roundtripped, straight, "evict/readmit must not cost a single bit");
    std::fs::remove_dir_all(&dir).ok();
}

/// Drained serve checkpoints equal offline single-session runs byte for
/// byte, even when a resident budget of one forces the scheduler to churn
/// both tenants through spill-and-readmit mid-stream.
#[test]
fn drained_checkpoints_match_offline_sessions_under_budget_churn() {
    let mut sched = Scheduler::new(serve_cfg("drain", 1)).expect("scheduler");
    let ev_a = tenant_events(5, 9);
    let ev_b = tenant_events(11, 7);
    sched.open("alice", Some(101)).expect("open alice");
    sched.open("bob", Some(202)).expect("open bob");
    sched.enqueue("alice", ev_a.clone()).expect("enqueue alice");
    sched.enqueue("bob", ev_b.clone()).expect("enqueue bob");
    let drained = sched.drain().expect("drain");
    assert_eq!(drained.len(), 2);
    let snap = sched.stats();
    assert!(snap.evictions >= 2, "budget 1 with 2 tenants must churn: {}", snap.evictions);
    assert!(snap.admissions >= 1, "…and readmit: {}", snap.admissions);
    for (name, path) in &drained {
        let got = std::fs::read(path).expect("drained snapshot readable");
        let (seed, events) = if name == "alice" { (101, &ev_a) } else { (202, &ev_b) };
        let want = offline_checkpoint(sched.config(), seed, events);
        assert_eq!(got, want, "tenant {name}: drained state differs from the offline stream");
    }
    std::fs::remove_dir_all(&sched.config().spill_dir).ok();
}

/// The resident budget is a wall-clock/memory knob only: draining the same
/// three tenants with unlimited residency and with a budget of one yields
/// byte-identical snapshots.
#[test]
fn drained_state_is_invariant_to_the_resident_budget() {
    let run = |budget: usize, tag: &str| -> Vec<(String, Vec<u8>)> {
        let cfg = serve_cfg(tag, budget);
        let spill = cfg.spill_dir.clone();
        let mut sched = Scheduler::new(cfg).expect("scheduler");
        for (i, seed) in [301u64, 302, 303].iter().enumerate() {
            let name = format!("t{i}");
            sched.open(&name, Some(*seed)).expect("open");
            sched.enqueue(&name, tenant_events(i as u64 * 17 + 3, 6 + i)).expect("enqueue");
        }
        let drained = sched.drain().expect("drain");
        let out = drained
            .iter()
            .map(|(n, p)| (n.clone(), std::fs::read(p).expect("snapshot readable")))
            .collect();
        std::fs::remove_dir_all(&spill).ok();
        out
    };
    let unlimited = run(0, "inv0");
    let tight = run(1, "inv1");
    assert_eq!(unlimited.len(), 3);
    for ((n0, b0), (n1, b1)) in unlimited.iter().zip(&tight) {
        assert_eq!(n0, n1);
        assert_eq!(b0, b1, "tenant {n0}: learner state depends on the resident budget");
    }
}

/// The line protocol over an in-memory transport: open, framed text
/// payload, run, stats, shutdown — and the shutdown leaves a spill file.
#[test]
fn serve_io_round_trips_over_in_memory_transport() {
    let cfg = serve_cfg("proto", 0);
    let spill = cfg.spill_dir.clone();
    let mut sched = Scheduler::new(cfg).expect("scheduler");
    let payload = b"0.5 -0.25 -> 1\n0.125 0.75\n";
    let mut req = Vec::new();
    req.extend_from_slice(b"open alice 7\n");
    req.extend_from_slice(format!("event alice {}\n", payload.len()).as_bytes());
    req.extend_from_slice(payload);
    req.extend_from_slice(b"\nrun\nstats\nshutdown\n");
    let mut reply = Vec::new();
    let shutdown = serve_io(&mut sched, &req[..], &mut reply).expect("serve_io");
    assert!(shutdown, "shutdown request must end the connection loop");
    let text = String::from_utf8(reply).expect("utf8 replies");
    assert!(text.contains("ok open alice"), "{text}");
    assert!(text.contains("ok event alice 2"), "{text}");
    assert!(text.contains("ok run "), "{text}");
    assert!(text.contains("\"live_sessions\": 1"), "{text}");
    assert!(text.trim_end().ends_with("ok shutdown 1"), "{text}");
    assert!(sched.spill_path("alice").exists(), "shutdown spills the tenant");
    assert_eq!(sched.pending(), 0);
    std::fs::remove_dir_all(&spill).ok();
}

/// The serve load generator produces the three-row grid the v7 `serve`
/// bench block serializes, with every workload event applied.
#[test]
fn serve_bench_toy_grid_applies_every_event() {
    let rows = sparse_rtrl::bench::serve::measure(&[3], 24, 1);
    assert_eq!(rows.len(), 3);
    for r in &rows {
        assert_eq!(r.events, 24, "{}: all events apply", r.schedule);
        assert!(r.events_per_sec > 0.0);
        assert_eq!(r.fused_lane_steps + r.solo_steps, 24, "{}", r.schedule);
    }
    assert!(rows[0].fused_lane_steps > 0, "shared-seed tenants fuse under the batched schedule");
    assert_eq!(rows[1].fused_lane_steps, 0, "round-robin never fuses");
}
