//! Analyzer tests: lexer edge cases, rule scoping, pragma semantics, the
//! baseline ratchet, and the self-test that the repo's own tree is clean.

use sparse_rtrl::analysis::lexer::{strip_source, test_lines};
use sparse_rtrl::analysis::{
    analyze_tree, build_report, fresh_baseline, run_check, scan_file, Baseline, Finding,
};
use std::path::Path;

fn rules_of(findings: &[Finding]) -> Vec<&str> {
    findings.iter().map(|f| f.rule.as_str()).collect()
}

// ------------------------------------------------------------------ lexer

#[test]
fn lexer_blanks_plain_strings_and_keeps_positions() {
    let src = "let a = \"Instant::now() // not a comment\";\nlet b = 1;\n";
    let s = strip_source(src);
    assert_eq!(s.text.len(), src.len());
    assert!(!s.text.contains("Instant"));
    assert!(!s.text.contains("not a comment"));
    assert!(s.comments.is_empty(), "// inside a string is not a comment");
    assert_eq!(s.text.matches('\n').count(), src.matches('\n').count());
    assert!(s.text.contains("let b = 1;"));
}

#[test]
fn lexer_collects_line_comments_with_lines() {
    let src = "let a = 1; // trailing\n// standalone\nlet b = 2;\n";
    let s = strip_source(src);
    assert_eq!(s.comments.len(), 2);
    assert_eq!(s.comments[0].line, 1);
    assert_eq!(s.comments[0].text, "// trailing");
    assert_eq!(s.comments[1].line, 2);
    assert_eq!(s.comments[1].text, "// standalone");
    assert!(!s.text.contains("trailing"));
}

#[test]
fn lexer_handles_raw_strings() {
    let src = "let re = r#\"panic!( \" quote inside \" )\"#;\nlet x = 3;\n";
    let s = strip_source(src);
    assert!(!s.text.contains("panic"));
    assert!(s.text.contains("r#\""), "raw-string opener stays visible");
    assert!(s.text.contains("\"#;"), "raw-string closer stays visible");
    assert!(s.text.contains("let x = 3;"));
    // multi-line raw string preserves the newline count
    let src2 = "let t = r\"line one\nline two\";\nlet y = 9;\n";
    let s2 = strip_source(src2);
    assert_eq!(s2.text.matches('\n').count(), src2.matches('\n').count());
    assert!(s2.text.contains("let y = 9;"));
}

#[test]
fn lexer_handles_nested_block_comments() {
    let src = "let a = 1;\n/* outer /* inner */ still comment\nunwrap() */\nlet b = 2;\n";
    let s = strip_source(src);
    assert!(!s.text.contains("unwrap"));
    assert!(!s.text.contains("still comment"));
    assert_eq!(s.text.matches('\n').count(), src.matches('\n').count());
    assert!(s.text.contains("let b = 2;"));
}

#[test]
fn lexer_distinguishes_char_literals_from_lifetimes() {
    let src = "fn f<'a>(x: &'a str) -> char { let c = '{'; let d = '\\n'; c }\n";
    let s = strip_source(src);
    // the brace inside the char literal is blanked, so brace matching works
    let opens = s.text.matches('{').count();
    let closes = s.text.matches('}').count();
    assert_eq!(opens, closes, "stripped braces balance: {:?}", s.text);
    assert!(s.text.contains("<'a>"), "lifetime survives");
    assert!(s.text.contains("&'a str"), "lifetime reference survives");
}

#[test]
fn lexer_counts_crlf_lines_like_lf() {
    let src = "let a = 1;\r\n// note\r\nlet t = std::time::Instant::now();\r\n";
    let s = strip_source(src);
    assert_eq!(s.comments.len(), 1);
    assert_eq!(s.comments[0].line, 2);
    // the \r rides along inside the comment capture; content is what counts
    assert!(s.comments[0].text.starts_with("// note"));
    let f = scan_file("rtrl/x.rs", src);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "ambient-time");
    assert_eq!(f[0].line, 3, "CRLF files report correct 1-based lines");
}

#[test]
fn lexer_marks_cfg_test_blocks() {
    let src = "fn lib() {}\n\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn tail() {}\n";
    let t = test_lines(&strip_source(src).text);
    assert!(t.contains(&3) && t.contains(&4) && t.contains(&5) && t.contains(&6));
    assert!(!t.contains(&1) && !t.contains(&7));
}

// ------------------------------------------------------------------ rules

#[test]
fn determinism_rules_fire_in_compute_modules_only() {
    let src = "use std::collections::HashMap;\nlet t = Instant::now();\nlet r = thread_rng();\n";
    let in_compute = scan_file("rtrl/fake.rs", src);
    assert_eq!(rules_of(&in_compute), ["unordered-map", "ambient-time", "ambient-rng"]);
    assert!(scan_file("coordinator/fake.rs", src).is_empty(), "allowlisted path");
    assert!(scan_file("telemetry/fake.rs", src).is_empty(), "non-compute path");
    assert!(scan_file("main.rs", src).is_empty(), "bin target is exempt");
}

#[test]
fn seeded_instant_in_rtrl_sparse_is_a_violation() {
    // the acceptance-criteria seeding: an ambient clock in rtrl/sparse.rs
    let src = "pub fn step() { let _t = std::time::Instant::now(); }\n";
    let f = scan_file("rtrl/sparse.rs", src);
    assert_eq!(f.len(), 1);
    assert_eq!(f[0].rule, "ambient-time");
    let report = build_report(
        &[("rtrl/sparse.rs".to_string(), f)].into_iter().collect(),
        &Baseline::default(),
    );
    assert!(!report.clean());
    let line = report.render_text();
    assert!(line.contains("rtrl/sparse.rs:1: ambient-time:"), "{line}");
}

#[test]
fn seeded_unwrap_in_session_online_trips_the_ratchet() {
    // the acceptance-criteria seeding: a new unwrap() beyond the baseline
    let src = "pub fn load(x: Option<u32>) -> u32 { x.unwrap() }\n";
    let findings = scan_file("session/online.rs", src);
    assert_eq!(rules_of(&findings), ["panic"]);
    let map = [("session/online.rs".to_string(), findings)].into_iter().collect();
    // allowance 0: the unwrap is a violation, rendered file:line: rule: msg
    let over = build_report(&map, &Baseline::default());
    assert!(!over.clean());
    assert!(over.render_text().contains("session/online.rs:1: panic:"), "{}", over.render_text());
    // allowance 1: same tree passes — the ratchet absorbs legacy sites
    let mut counts = std::collections::BTreeMap::new();
    counts.insert("session/online.rs".to_string(), 1u64);
    let under = build_report(&map, &Baseline::from_counts(&counts));
    assert!(under.clean());
}

#[test]
fn float_reduce_rule_scopes_to_pinned_modules() {
    let typed = "fn m(xs: &[f32]) -> f32 { xs.iter().sum::<f32>() }\n";
    assert_eq!(rules_of(&scan_file("nn/fake.rs", typed)), ["float-reduce"]);
    assert!(scan_file("util/math.rs", typed).is_empty(), "pinned module");
    assert!(scan_file("rtrl/kernels/rowops.rs", typed).is_empty(), "pinned module");

    let fold = "fn m(xs: &[f32]) -> f32 { xs.iter().fold(0.0, |a, b| a + b) }\n";
    assert_eq!(rules_of(&scan_file("rtrl/fake.rs", fold)), ["float-reduce"]);

    let untyped = "fn m(xs: &[f32]) -> f32 { let s: f32 = xs.iter().sum(); s }\n";
    assert_eq!(rules_of(&scan_file("rtrl/fake.rs", untyped)), ["float-reduce"]);

    let integer = "fn m(xs: &[u64]) -> u64 { let s: u64 = xs.iter().sum(); s }\n";
    assert!(scan_file("rtrl/fake.rs", integer).is_empty(), "integer sums are order-safe");
}

#[test]
fn panic_rule_sees_all_library_files_but_not_tests() {
    let src = "pub fn f(x: Option<u8>) -> u8 { x.expect(\"set\") }\n\
               #[cfg(test)]\nmod tests {\n    fn t() { None::<u8>.unwrap(); panic!(); }\n}\n";
    let f = scan_file("report/fake.rs", src);
    assert_eq!(rules_of(&f), ["panic"], "{f:?}");
    assert_eq!(f[0].line, 1);
    let macros = "fn g(x: u8) { if x > 3 { unreachable!() } else { todo!() } }\n";
    assert_eq!(rules_of(&scan_file("util/fake.rs", macros)), ["panic", "panic"]);
}

// ---------------------------------------------------------------- pragmas

#[test]
fn trailing_pragma_suppresses_its_own_line() {
    let src = "let t = Instant::now(); // analyze: allow(ambient-time) -- test clock\n";
    assert!(scan_file("rtrl/fake.rs", src).is_empty());
}

#[test]
fn standalone_pragma_suppresses_the_next_code_line() {
    let src = "// analyze: allow(ambient-time) -- latency metric\n\
               \n\
               let t = Instant::now();\n";
    assert!(scan_file("session/fake.rs", src).is_empty(), "skips blank lines to its target");
}

#[test]
fn unused_pragma_is_an_error() {
    let src = "// analyze: allow(ambient-time) -- stale\nlet x = 1;\n";
    let f = scan_file("rtrl/fake.rs", src);
    assert_eq!(rules_of(&f), ["unused-pragma"]);
    assert_eq!(f[0].line, 1);
}

#[test]
fn malformed_pragmas_are_errors() {
    let missing_reason = "// analyze: allow(panic)\nlet x: Option<u8> = None;\n";
    assert_eq!(rules_of(&scan_file("rtrl/fake.rs", missing_reason)), ["bad-pragma"]);
    let unknown_rule = "// analyze: allow(no-such-rule) -- why\nlet x = 1;\n";
    assert_eq!(rules_of(&scan_file("rtrl/fake.rs", unknown_rule)), ["bad-pragma"]);
}

#[test]
fn pragma_suppresses_only_named_rules() {
    let src = "// analyze: allow(ambient-time) -- clock ok\n\
               let t = (Instant::now(), HashMap::<u8, u8>::new());\n";
    let f = scan_file("nn/fake.rs", src);
    assert_eq!(rules_of(&f), ["unordered-map"], "{f:?}");
}

#[test]
fn doc_comments_may_quote_pragma_syntax() {
    let src = "//! Suppress via `// analyze: allow(panic) -- reason`.\n\
               /// analyze: allow(panic) -- docs, not a pragma\n\
               pub fn f() {}\n";
    assert!(scan_file("rtrl/fake.rs", src).is_empty());
}

// --------------------------------------------------------------- baseline

#[test]
fn fix_baseline_freezes_live_counts() {
    let src = "pub fn f(x: Option<u8>) -> u8 { x.unwrap() }\n";
    let findings = [("util/fake.rs".to_string(), scan_file("util/fake.rs", src))]
        .into_iter()
        .collect();
    let b = fresh_baseline(&findings);
    assert_eq!(b.total(), 1);
    assert_eq!(b.allowance("util/fake.rs"), 1);
    assert!(build_report(&findings, &b).clean());
}

// -------------------------------------------------------------- self-test

#[test]
fn analyze_check_is_clean_on_this_repo() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let baseline = Path::new(env!("CARGO_MANIFEST_DIR")).join("../ANALYSIS_baseline.json");
    let report = run_check(&root, &baseline).expect("repo tree scans");
    assert!(
        report.clean(),
        "the tree must pass its own analyzer; violations:\n{}",
        report.render_text()
    );
    assert!(report.files_scanned > 40, "walked the real tree");
    // the ratchet is honest: live counts match the committed allowance
    let findings = analyze_tree(&root).expect("repo tree scans");
    assert_eq!(
        fresh_baseline(&findings).total(),
        report.baseline_total,
        "baseline is stale — run `sparse-rtrl analyze --fix-baseline`"
    );
}
