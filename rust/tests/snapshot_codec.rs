//! Acceptance tests for the snapshot codec facade: the binary container
//! and the JSON interchange must carry the same checkpoint bit-for-bit for
//! every gradient engine, resume must continue the stream exactly from a
//! binary snapshot, and corrupted snapshots must fail with a typed,
//! section-naming error — never a panic, never a silently wrong resume.

use sparse_rtrl::config::{AlgorithmKind, ExperimentConfig};
use sparse_rtrl::rtrl::Target;
use sparse_rtrl::session::codec::{self, binary, CodecError, SnapshotFormat};
use sparse_rtrl::session::{
    OnlineSession, SessionBuilder, SessionCheckpoint, StepOutcome, UpdatePolicy,
};
use sparse_rtrl::util::Pcg64;

fn make_session(kind: AlgorithmKind) -> OnlineSession {
    let mut cfg = ExperimentConfig::default();
    cfg.model.hidden = 8;
    cfg.model.layers = 2;
    cfg.model.param_sparsity = 0.5;
    cfg.train.lr = 0.02;
    cfg.seed = 33;
    SessionBuilder::from_config(cfg)
        .algorithm(kind)
        .policy(UpdatePolicy::EveryKSteps(1))
        .predict_always(true)
        .build()
}

/// Deterministic stream: supervision every third step, so updates fire
/// mid-stream and optimizer + engine state are non-trivial at the cut.
fn drive(s: &mut OnlineSession, from: usize, to: usize) -> Vec<StepOutcome> {
    let mut rng = Pcg64::new(99);
    let mut outs = Vec::new();
    for i in 0..to {
        let x = [rng.normal(), rng.normal()];
        let t = if i % 3 == 2 { Target::Class(i % 2) } else { Target::None };
        if i >= from {
            outs.push(s.step(&x, t));
        }
    }
    outs
}

fn outcome_bits(o: &StepOutcome) -> (u64, Option<u32>, Option<usize>, bool) {
    (o.step, o.loss.map(f32::to_bits), o.prediction, o.updated)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Field-by-field bitwise equality of two checkpoints.
fn assert_checkpoints_identical(a: &SessionCheckpoint, b: &SessionCheckpoint, ctx: &str) {
    assert_eq!(a.config_toml, b.config_toml, "{ctx}: config");
    assert_eq!(a.policy, b.policy, "{ctx}: policy");
    assert_eq!(a.predict_always, b.predict_always, "{ctx}: predict_always");
    assert_eq!(
        (a.steps, a.supervised_steps, a.updates_applied, a.pending_supervised),
        (b.steps, b.supervised_steps, b.updates_applied, b.pending_supervised),
        "{ctx}: counters"
    );
    assert_eq!(bits(&a.net_params), bits(&b.net_params), "{ctx}: net_params");
    assert_eq!(bits(&a.readout_params), bits(&b.readout_params), "{ctx}: readout_params");
    assert_eq!(bits(&a.readout_grads), bits(&b.readout_grads), "{ctx}: readout_grads");
    assert_eq!(bits(&a.grad_accum), bits(&b.grad_accum), "{ctx}: grad_accum");
    assert_eq!(bits(&a.opt_cell.m), bits(&b.opt_cell.m), "{ctx}: opt_cell.m");
    assert_eq!(bits(&a.opt_cell.v), bits(&b.opt_cell.v), "{ctx}: opt_cell.v");
    assert_eq!(a.opt_cell.t, b.opt_cell.t, "{ctx}: opt_cell.t");
    assert_eq!(bits(&a.opt_readout.m), bits(&b.opt_readout.m), "{ctx}: opt_readout.m");
    assert_eq!(bits(&a.opt_readout.v), bits(&b.opt_readout.v), "{ctx}: opt_readout.v");
    assert_eq!(a.opt_readout.t, b.opt_readout.t, "{ctx}: opt_readout.t");
    assert_eq!(a.masks, b.masks, "{ctx}: masks");
    assert_eq!(a.ops, b.ops, "{ctx}: ops");
    assert_eq!(a.engine, b.engine, "{ctx}: engine state");
}

/// The tentpole contract: for every engine, the binary and JSON encodings
/// of the same checkpoint decode to bit-identical checkpoints (through the
/// autodetecting facade), and a session resumed from the **binary**
/// snapshot continues the stream bit-exactly.
#[test]
fn binary_and_json_snapshots_agree_and_resume_exactly_for_every_engine() {
    for kind in AlgorithmKind::all() {
        let name = kind.name();
        let mut uninterrupted = make_session(kind);
        let full: Vec<_> = drive(&mut uninterrupted, 0, 18).iter().map(outcome_bits).collect();

        let mut cut = make_session(kind);
        drive(&mut cut, 0, 10);
        let ck = cut.checkpoint();
        drop(cut);

        let bin_bytes = codec::encode(&ck, SnapshotFormat::Binary);
        let json_bytes = codec::encode(&ck, SnapshotFormat::Json);
        assert_eq!(codec::detect(&bin_bytes), Some(SnapshotFormat::Binary), "{name}");
        assert_eq!(codec::detect(&json_bytes), Some(SnapshotFormat::Json), "{name}");

        let from_bin = codec::decode(&bin_bytes)
            .unwrap_or_else(|e| panic!("{name}: binary decode failed: {e}"));
        let from_json = codec::decode(&json_bytes)
            .unwrap_or_else(|e| panic!("{name}: json decode failed: {e}"));
        assert_checkpoints_identical(&from_bin, &ck, &format!("{name} binary"));
        assert_checkpoints_identical(&from_json, &from_bin, &format!("{name} cross-format"));

        // resume from the binary snapshot and replay the stream suffix
        let mut resumed = OnlineSession::resume(&from_bin)
            .unwrap_or_else(|e| panic!("{name}: resume from binary failed: {e}"));
        let mut rng = Pcg64::new(99);
        let mut tail = Vec::new();
        for i in 0..18 {
            let x = [rng.normal(), rng.normal()];
            let t = if i % 3 == 2 { Target::Class(i % 2) } else { Target::None };
            if i >= 10 {
                tail.push(outcome_bits(&resumed.step(&x, t)));
            }
        }
        assert_eq!(tail, full[10..], "{name}: binary-resumed outcomes diverged");
    }
}

fn driven_binary(kind: AlgorithmKind) -> (SessionCheckpoint, Vec<u8>) {
    let mut s = make_session(kind);
    drive(&mut s, 0, 10);
    let ck = s.checkpoint();
    let bytes = codec::encode(&ck, SnapshotFormat::Binary);
    (ck, bytes)
}

/// Every corruption error renders as `snapshot section "…": …` — the
/// section-naming contract the eviction loop relies on for diagnosis.
fn assert_names_a_section(e: &CodecError, ctx: &str) {
    let msg = e.to_string();
    assert!(msg.starts_with("snapshot section"), "{ctx}: unhelpful error {msg:?}");
}

/// Truncated files fail with a typed, section-naming error at every cut
/// point — never a panic, never an `Ok` — for every engine's layout.
#[test]
fn truncated_binary_snapshots_fail_loudly() {
    for kind in AlgorithmKind::all() {
        let (_, bytes) = driven_binary(kind);
        // cut points spanning header, directory and payloads
        let cuts = [0, 7, 8, 12, 15, 16, 30, bytes.len() / 4, bytes.len() / 2, bytes.len() - 1];
        for cut in cuts {
            let e = codec::decode(&bytes[..cut]).expect_err(&format!(
                "{}: truncation to {cut} bytes must not decode",
                kind.name()
            ));
            match &e {
                // 0..16-byte prefixes no longer sniff as any format
                CodecError::UnknownFormat => assert!(cut < 8),
                other => assert_names_a_section(other, kind.name()),
            }
        }
    }
}

/// A flipped byte anywhere in the file either fails with a section-naming
/// error or (if it hit alignment padding, which carries no data) decodes
/// to the identical checkpoint. It must never produce a *different*
/// checkpoint — that would be a silently wrong resume.
#[test]
fn flipped_bytes_never_yield_a_silently_different_checkpoint() {
    let (ck, bytes) = driven_binary(AlgorithmKind::RtrlBoth);
    let mut flips_that_errored = 0usize;
    for pos in (0..bytes.len()).step_by(13) {
        let mut corrupt = bytes.clone();
        corrupt[pos] ^= 0x20;
        match codec::decode(&corrupt) {
            Err(CodecError::UnknownFormat) => assert!(pos < 8, "magic flip misclassified"),
            Err(e) => {
                assert_names_a_section(&e, &format!("flip at {pos}"));
                flips_that_errored += 1;
            }
            Ok(decoded) => {
                assert_checkpoints_identical(&decoded, &ck, &format!("pad flip at {pos}"));
            }
        }
    }
    assert!(flips_that_errored > 10, "corruption detection barely exercised");
}

/// A flip inside a bulk payload is caught by that section's CRC and the
/// error names it. The file midpoint sits in the bulk float payloads; a
/// 32-byte window is wider than any section boundary (≤ 7 pad bytes plus
/// ~19 framing bytes), so at least one flip in it must hit CRC-covered
/// payload.
#[test]
fn payload_flip_is_attributed_to_its_section() {
    let (_, bytes) = driven_binary(AlgorithmKind::Snap1);
    let mid = bytes.len() / 2;
    let mut checksum_hits = 0usize;
    for pos in mid..(mid + 32).min(bytes.len()) {
        let mut corrupt = bytes.clone();
        corrupt[pos] ^= 0x01;
        if let Err(CodecError::Checksum { section, .. }) = codec::decode(&corrupt) {
            assert!(!section.is_empty(), "checksum error lost its section name");
            checksum_hits += 1;
        }
    }
    assert!(checksum_hits > 0, "no flip near the file midpoint tripped a section CRC");
}

#[test]
fn wrong_magic_and_future_version_are_rejected_for_every_engine() {
    for kind in AlgorithmKind::all() {
        let (_, bytes) = driven_binary(kind);

        let mut wrong_magic = bytes.clone();
        wrong_magic[..8].copy_from_slice(b"NOTASNAP");
        match codec::decode(&wrong_magic) {
            // not the binary magic, not JSON → autodetection refuses
            Err(CodecError::UnknownFormat) => {}
            other => panic!("{}: expected UnknownFormat, got {other:?}", kind.name()),
        }
        // forcing the binary codec still yields a header error, not a panic
        match codec::codec_for(SnapshotFormat::Binary).decode(&wrong_magic) {
            Err(e @ CodecError::BadHeader { .. }) => assert_names_a_section(&e, kind.name()),
            other => panic!("{}: expected BadHeader, got {other:?}", kind.name()),
        }

        let mut future = bytes.clone();
        future[8..12].copy_from_slice(&(binary::SCHEMA_VERSION + 3).to_le_bytes());
        match codec::decode(&future) {
            Err(e @ CodecError::UnsupportedVersion { .. }) => {
                assert_names_a_section(&e, kind.name());
                let msg = e.to_string();
                assert!(
                    msg.contains(&(binary::SCHEMA_VERSION + 3).to_string()),
                    "version error should echo the found version: {msg}"
                );
            }
            other => panic!("{}: expected UnsupportedVersion, got {other:?}", kind.name()),
        }
    }
}

/// Autodetection accepts both formats through one entry point, and
/// unrecognizable bytes are refused without touching a session.
#[test]
fn facade_decode_autodetects_and_refuses_garbage() {
    let (ck, bin_bytes) = driven_binary(AlgorithmKind::Uoro);
    let json_bytes = codec::encode(&ck, SnapshotFormat::Json);
    assert_checkpoints_identical(&codec::decode(&bin_bytes).unwrap(), &ck, "binary via facade");
    assert_checkpoints_identical(&codec::decode(&json_bytes).unwrap(), &ck, "json via facade");
    assert!(matches!(codec::decode(b"0.5 -0.2 -> 1"), Err(CodecError::UnknownFormat)));
    assert!(matches!(codec::decode(b""), Err(CodecError::UnknownFormat)));
}
