//! Checkpoint/resume exactness for the streaming session surface — the
//! acceptance criterion of the session redesign: an [`OnlineSession`]
//! checkpointed mid-stream, serialized to JSON, parsed back and resumed
//! (as a fresh process would) produces **bit-identical** predictions,
//! losses, weights and optimizer state versus the uninterrupted session,
//! for every gradient engine.

use sparse_rtrl::config::{AlgorithmKind, ExperimentConfig};
use sparse_rtrl::rtrl::Target;
use sparse_rtrl::session::{
    OnlineSession, SessionBuilder, SessionCheckpoint, StepOutcome, UpdatePolicy,
};
use sparse_rtrl::util::Pcg64;

fn make_session(kind: AlgorithmKind, sparsity: f32) -> OnlineSession {
    let mut cfg = ExperimentConfig::default();
    cfg.model.hidden = 8;
    cfg.model.layers = 2;
    cfg.model.param_sparsity = sparsity;
    cfg.train.lr = 0.02;
    cfg.seed = 21;
    SessionBuilder::from_config(cfg)
        .algorithm(kind)
        .policy(UpdatePolicy::EveryKSteps(1))
        .predict_always(true)
        .build()
}

/// Deterministic event stream: inputs from a fixed RNG, supervision every
/// third step. Updates therefore fire mid-stream, exercising optimizer
/// state as well as engine state.
fn drive(s: &mut OnlineSession, from: usize, to: usize) -> Vec<StepOutcome> {
    let mut rng = Pcg64::new(55);
    let mut outs = Vec::new();
    for i in 0..to {
        let x = [rng.normal(), rng.normal()];
        let t = if i % 3 == 2 { Target::Class(i % 2) } else { Target::None };
        if i >= from {
            outs.push(s.step(&x, t));
        } else {
            // keep the data stream aligned without stepping
            continue;
        }
    }
    outs
}

fn outcome_bits(o: &StepOutcome) -> (u64, Option<u32>, Option<usize>, Option<bool>, bool) {
    (o.step, o.loss.map(f32::to_bits), o.prediction, o.correct, o.updated)
}

#[test]
fn checkpoint_resume_is_bit_exact_for_every_engine() {
    for kind in AlgorithmKind::all() {
        let sparsity = 0.5;
        // uninterrupted session over 20 steps
        let mut uninterrupted = make_session(kind, sparsity);
        let full: Vec<_> =
            drive(&mut uninterrupted, 0, 20).iter().map(outcome_bits).collect();

        // interrupted twin: 11 steps → checkpoint → JSON → parse → resume
        let mut first_half = make_session(kind, sparsity);
        let head: Vec<_> = drive(&mut first_half, 0, 11).iter().map(outcome_bits).collect();
        assert_eq!(head, full[..11], "{}: pre-checkpoint divergence", kind.name());
        let macs_at_cut = first_half.ops.total_macs();
        let json = first_half.checkpoint().to_json();
        drop(first_half);
        let ck = SessionCheckpoint::from_json(&json)
            .unwrap_or_else(|e| panic!("{}: checkpoint parse failed: {e}", kind.name()));
        let mut resumed = OnlineSession::resume(&ck)
            .unwrap_or_else(|e| panic!("{}: resume failed: {e}", kind.name()));
        assert_eq!(resumed.steps(), 11, "{}: counters not restored", kind.name());
        assert_eq!(
            resumed.ops.total_macs(),
            macs_at_cut,
            "{}: op accounting did not survive migration",
            kind.name()
        );

        // replay the same stream suffix; every outcome must match bitwise
        let mut rng = Pcg64::new(55);
        let mut tail = Vec::new();
        for i in 0..20 {
            let x = [rng.normal(), rng.normal()];
            let t = if i % 3 == 2 { Target::Class(i % 2) } else { Target::None };
            if i >= 11 {
                tail.push(outcome_bits(&resumed.step(&x, t)));
            }
        }
        assert_eq!(
            tail,
            full[11..],
            "{}: resumed outcomes are not bit-identical",
            kind.name()
        );

        // and the final learned weights match bit-for-bit
        let mut w_full = vec![0.0; uninterrupted.net().p()];
        let mut w_resumed = vec![0.0; resumed.net().p()];
        uninterrupted.net().copy_params_into(&mut w_full);
        resumed.net().copy_params_into(&mut w_resumed);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&w_full), bits(&w_resumed), "{}: weights diverged", kind.name());
        assert_eq!(
            bits(uninterrupted.engine().grads()),
            bits(resumed.engine().grads()),
            "{}: engine gradients diverged",
            kind.name()
        );
    }
}

/// A session whose masks were *rewired* away from the config-seeded pattern
/// still checkpoints and resumes exactly: the checkpoint carries the masks
/// verbatim.
#[test]
fn resume_restores_rewired_masks() {
    let mut s = make_session(AlgorithmKind::RtrlBoth, 0.6);
    drive(&mut s, 0, 9);
    // move the mask away from its seeded pattern
    let mut rng = Pcg64::new(77);
    let new_mask = sparse_rtrl::sparse::rewire::magnitude_rewire(
        s.net().layer(0),
        0.3,
        &mut rng,
    );
    s.net_mut().layer_mut(0).set_mask(new_mask.clone(), 0.05, &mut rng);
    s.rebuild_engine();
    drive(&mut s, 9, 14);
    let ck = SessionCheckpoint::from_json(&s.checkpoint().to_json()).unwrap();
    let resumed = OnlineSession::resume(&ck).expect("rewired session must resume");
    let m = resumed.net().layer(0).mask().expect("mask survived");
    let n = resumed.net().layer(0).n();
    for r in 0..n {
        for c in 0..n {
            assert_eq!(m.is_kept(r, c), new_mask.is_kept(r, c), "mask bit ({r},{c}) lost");
        }
    }
    // weights match bitwise too
    let mut w0 = vec![0.0; s.net().p()];
    let mut w1 = vec![0.0; resumed.net().p()];
    s.net().copy_params_into(&mut w0);
    resumed.net().copy_params_into(&mut w1);
    assert_eq!(
        w0.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        w1.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
    );
}

/// Corrupted checkpoints fail loudly, not silently.
#[test]
fn corrupted_checkpoints_are_rejected() {
    let mut s = make_session(AlgorithmKind::RtrlBoth, 0.0);
    drive(&mut s, 0, 5);
    let good = s.checkpoint().to_json();
    // truncated document
    assert!(SessionCheckpoint::from_json(&good[..good.len() / 2]).is_err());
    // config swapped to a different topology → buffer length mismatch
    let mut ck = s.checkpoint();
    ck.config_toml = ck.config_toml.replace("hidden = 8", "hidden = 12");
    assert!(OnlineSession::resume(&ck).is_err(), "topology mismatch must fail");
}
