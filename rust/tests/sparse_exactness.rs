//! Structural-zero invariants (paper §4–§5) and the cost ordering they buy.
//!
//! * rows of `M` with `φ'(v_k)=0` are fully zero (Eq. 10);
//! * columns of `M`/`M̄` for masked params stay zero across timesteps (§5);
//! * measured influence-update MACs follow the `β̃²`, `ω̃²`, `ω̃²β̃²`
//!   factors of Table 1 within structural-overhead slack;
//! * sparse-engine savings never change the gradient (spot-checked here,
//!   exhaustively in `grad_equivalence`).

use sparse_rtrl::config::AlgorithmKind;
use sparse_rtrl::metrics::{OpCounter, Phase};
use sparse_rtrl::nn::{LayerStack, Loss, LossKind, Readout, RnnCell};
use sparse_rtrl::rtrl::{GradientEngine, SparseRtrl, SparsityMode, Target};
use sparse_rtrl::sparse::MaskPattern;
use sparse_rtrl::train::build_engine;
use sparse_rtrl::util::Pcg64;

struct StepStats {
    influence_macs: u64,
    beta_tilde_mean: f64,
}

/// Run `steps` random steps, return influence MACs + mean β̃.
fn run_steps(kind: AlgorithmKind, cell: &RnnCell, steps: usize, seed: u64) -> StepStats {
    let net = LayerStack::single(cell.clone());
    let mut rng = Pcg64::new(seed);
    let mut readout = Readout::new(2, net.top_n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut ops = OpCounter::new();
    let mut eng = build_engine(kind, &net, 2);
    eng.begin_sequence();
    let mut bt = 0.0;
    for _ in 0..steps {
        let x: Vec<f32> = (0..net.n_in()).map(|_| rng.normal()).collect();
        let r = eng.step(&net, &mut readout, &mut loss, &x, Target::None, &mut ops);
        bt += r.deriv_units as f64 / net.total_units() as f64;
    }
    eng.end_sequence(&net, &mut readout, &mut ops);
    StepStats {
        influence_macs: ops.macs_in(Phase::InfluenceUpdate) + ops.macs_in(Phase::Jacobian),
        beta_tilde_mean: bt / steps as f64,
    }
}

/// Activity sparsity: measured cost ratio vs dense tracks β̃² (within 2×
/// slack for the M̄/φ'-scale terms that don't shrink quadratically).
#[test]
fn activity_cost_tracks_beta_squared()
{
    let mut rng = Pcg64::new(1);
    let cell = RnnCell::egru(24, 2, 0.15, 0.3, 0.4, None, &mut rng);
    let steps = 30;
    let dense = run_steps(AlgorithmKind::RtrlDense, &cell, steps, 42);
    let act = run_steps(AlgorithmKind::RtrlActivity, &cell, steps, 42);
    let bt = act.beta_tilde_mean;
    assert!(bt > 0.05 && bt < 0.95, "β̃={bt} degenerate — retune test cell");
    let ratio = act.influence_macs as f64 / dense.influence_macs as f64;
    let predicted = bt * bt;
    assert!(
        ratio < predicted * 2.5 + 0.02,
        "activity ratio {ratio:.3} should track β̃² = {predicted:.3}"
    );
    assert!(act.influence_macs < dense.influence_macs);
}

/// Parameter sparsity: measured cost vs dense tracks ω̃².
#[test]
fn parameter_cost_tracks_omega_squared() {
    let mut rng = Pcg64::new(2);
    let n = 24;
    for omega_tilde in [0.5f64, 0.2, 0.1] {
        let mask = MaskPattern::random(n, n, omega_tilde as f32, &mut rng);
        let cell = RnnCell::gated_tanh(n, 2, Some(mask), &mut rng);
        let dense_cell = RnnCell::gated_tanh(n, 2, None, &mut rng);
        let steps = 20;
        let dense = run_steps(AlgorithmKind::RtrlDense, &dense_cell, steps, 7);
        let sparse = run_steps(AlgorithmKind::RtrlParam, &cell, steps, 7);
        let ratio = sparse.influence_macs as f64 / dense.influence_macs as f64;
        let predicted = omega_tilde * omega_tilde;
        // dense columns (input weights + biases) keep a linear ω̃ term, so
        // allow generous headroom above the pure-recurrent ω̃² prediction
        assert!(
            ratio < predicted * 1.6 + 3.0 / n as f64,
            "ω̃={omega_tilde}: ratio {ratio:.4} vs ω̃²={predicted:.4}"
        );
    }
}

/// Combined sparsity is multiplicative: cost(both) ≈ cost(activity) ×
/// cost(param)/cost(dense), the ω̃²β̃² factor of §5.
#[test]
fn combined_cost_multiplicative() {
    let mut rng = Pcg64::new(3);
    let n = 48;
    let mask = MaskPattern::random(n, n, 0.2, &mut rng);
    let cell = RnnCell::egru(n, 2, 0.15, 0.3, 0.4, Some(mask), &mut rng);
    let steps = 30;
    let dense = run_steps(AlgorithmKind::RtrlDense, &cell, steps, 11);
    let act = run_steps(AlgorithmKind::RtrlActivity, &cell, steps, 11);
    let par = run_steps(AlgorithmKind::RtrlParam, &cell, steps, 11);
    let both = run_steps(AlgorithmKind::RtrlBoth, &cell, steps, 11);
    assert!(both.influence_macs < act.influence_macs);
    assert!(both.influence_macs < par.influence_macs);
    let d = dense.influence_macs as f64;
    let predicted = (act.influence_macs as f64 / d) * (par.influence_macs as f64 / d);
    let actual = both.influence_macs as f64 / d;
    // The ω̃²β̃² term is quadratic but M̄ adds, φ'-row scaling and the
    // Jacobian sweep shrink only linearly (ω̃β̃·np), so allow that floor.
    let bt = both.beta_tilde_mean;
    let linear_floor = 4.0 * bt * 0.2 / n as f64;
    assert!(
        actual < predicted * 3.0 + linear_floor + 0.002,
        "combined ratio {actual:.4} should approach product {predicted:.4} (floor {linear_floor:.4})"
    );
}

/// The §1 worked example: β̃=0.5, ω=80% ⇒ ~1% of dense ops. We check the
/// measured bound at the closest achievable β̃.
#[test]
fn paper_worked_example_magnitude() {
    let mut rng = Pcg64::new(4);
    let n = 32;
    let mask = MaskPattern::random(n, n, 0.2, &mut rng);
    let cell = RnnCell::egru(n, 2, 0.3, 0.3, 0.25, Some(mask), &mut rng);
    let steps = 40;
    let dense_cell = RnnCell::egru(n, 2, 0.3, 0.3, 0.25, None, &mut rng);
    let dense = run_steps(AlgorithmKind::RtrlDense, &dense_cell, steps, 13);
    let both = run_steps(AlgorithmKind::RtrlBoth, &cell, steps, 13);
    let ratio = both.influence_macs as f64 / dense.influence_macs as f64;
    let bt = both.beta_tilde_mean;
    let analytic = 0.04 * bt * bt; // ω̃² β̃²
    assert!(
        ratio < analytic * 4.0 + 0.02,
        "ratio {ratio:.4} (β̃={bt:.2}) vs analytic {analytic:.4}"
    );
    // and it is a massive saving in absolute terms
    assert!(ratio < 0.12, "expected ≥ ~10x savings, got ratio {ratio:.4}");
}

/// Influence-sparsity measurements agree between dense and sparse engines
/// (they are views of the same logical matrix).
#[test]
fn influence_sparsity_consistent_across_engines() {
    let mut rng = Pcg64::new(5);
    let cell = RnnCell::egru(10, 2, 0.1, 0.3, 0.5, None, &mut rng);
    let mut readout = Readout::new(2, 10, &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut ops = OpCounter::new();
    let net = LayerStack::single(cell);
    let mut dense = build_engine(AlgorithmKind::RtrlDense, &net, 2);
    let mut sparse = SparseRtrl::new(&net, 2, SparsityMode::Activity);
    dense.set_measure_influence(true);
    sparse.set_measure_influence(true);
    dense.begin_sequence();
    sparse.begin_sequence();
    let mut rng2 = Pcg64::new(77);
    for _ in 0..6 {
        let x = [rng2.normal(), rng2.normal()];
        let rd = dense.step(&net, &mut readout, &mut loss, &x, Target::None, &mut ops);
        let rs = sparse.step(&net, &mut readout, &mut loss, &x, Target::None, &mut ops);
        let (sd, ss) = (rd.influence_sparsity.unwrap(), rs.influence_sparsity.unwrap());
        assert!(
            (sd - ss).abs() < 1e-6,
            "influence sparsity disagree: dense {sd} sparse {ss}"
        );
    }
}

/// Memory accounting: the engines' state memory follows Table 1's ordering
/// (both < activity/param < dense for column-compacted storage; SnAp-1
/// smallest; BPTT grows with T).
#[test]
fn memory_ordering_matches_table1() {
    let mut rng = Pcg64::new(6);
    let n = 24;
    let mask = MaskPattern::random(n, n, 0.2, &mut rng);
    let cell = RnnCell::egru(n, 2, 0.1, 0.3, 0.5, Some(mask), &mut rng);
    let net = LayerStack::single(cell);
    let mem = |kind| {
        let mut rng = Pcg64::new(9);
        let mut readout = Readout::new(2, n, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops = OpCounter::new();
        let mut eng = build_engine(kind, &net, 2);
        eng.begin_sequence();
        for _ in 0..17 {
            let x = [rng.normal(), rng.normal()];
            eng.step(&net, &mut readout, &mut loss, &x, Target::None, &mut ops);
        }
        eng.state_memory_words()
    };
    let dense = mem(AlgorithmKind::RtrlDense);
    let param = mem(AlgorithmKind::RtrlParam);
    let both = mem(AlgorithmKind::RtrlBoth);
    let snap1 = mem(AlgorithmKind::Snap1);
    let bptt = mem(AlgorithmKind::Bptt);
    assert!(param < dense, "param {param} !< dense {dense}");
    assert!(both <= param);
    assert!(snap1 < both, "snap1 {snap1} !< both {both}");
    assert!(bptt < dense, "BPTT at T=17,n=24 should be below dense RTRL's n·p");
}

/// Depth: the block-structured engine's influence memory is the block
/// lower-triangular footprint (layer l's panel is only `Σ_{m≤l} p_m`
/// wide), strictly below a naïve full `N×P` double-buffer, and activity
/// savings compound across layers.
#[test]
fn depth2_block_memory_below_full_matrix() {
    let mut rng = Pcg64::new(7);
    let l0 = RnnCell::egru(12, 2, 0.1, 0.3, 0.5, None, &mut rng);
    let l1 = RnnCell::egru(12, 12, 0.1, 0.3, 0.5, None, &mut rng);
    let net = LayerStack::new(vec![l0, l1]);
    let sparse = SparseRtrl::new(&net, 2, SparsityMode::Both);
    let full_np = 2 * net.total_units() * net.p(); // dense double-buffer
    assert!(
        sparse.state_memory_words() < full_np,
        "block panels {} should undercut full N×P ping-pong {}",
        sparse.state_memory_words(),
        full_np
    );
    // dense engine pays the full footprint
    let dense = build_engine(AlgorithmKind::RtrlDense, &net, 2);
    assert_eq!(dense.state_memory_words(), full_np);
}
