//! Minimal TOML-subset parser for experiment configs.
//!
//! Supports the subset the config system uses: `[section]` headers,
//! `key = value` pairs with string (`"…"`), boolean, integer and float
//! values, `#` comments and blank lines. No arrays-of-tables, no nesting
//! beyond one section level, no multi-line strings — experiment configs
//! don't need them. (In-tree because the build environment vendors no
//! general TOML crate; see Cargo.toml.)

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`lr = 1` ≡ `1.0`).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
}

/// Parsed document: `sections[""]` holds top-level keys.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    /// Parse a document; returns a line-annotated error message on failure.
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section header", ln + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", ln + 1));
                }
                section = name.to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", ln + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", ln + 1));
            }
            let value = parse_value(val.trim())
                .ok_or_else(|| format!("line {}: cannot parse value {:?}", ln + 1, val.trim()))?;
            doc.sections.entry(section.clone()).or_default().insert(key.to_string(), value);
        }
        Ok(doc)
    }

    /// Value lookup: `get("model", "hidden")`; use `""` for top level.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn has_section(&self, section: &str) -> bool {
        self.sections.contains_key(section)
    }

    /// Keys present in a section (sorted). Lets callers that care about
    /// strictness detect unknown keys; the config layer deliberately does
    /// *not* — unknown keys are preserved here and ignored there, so old
    /// binaries keep reading new config files and vice versa.
    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|s| s.keys().map(String::as_str).collect())
            .unwrap_or_default()
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<Value> {
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"')?;
        return Some(Value::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match s {
        "true" => return Some(Value::Bool(true)),
        "false" => return Some(Value::Bool(false)),
        _ => {}
    }
    if s.contains('.') || s.contains('e') || s.contains('E') {
        if let Ok(f) = s.parse::<f64>() {
            return Some(Value::Float(f));
        }
    }
    if let Ok(i) = s.parse::<i64>() {
        return Some(Value::Int(i));
    }
    s.parse::<f64>().ok().map(Value::Float)
}

/// Escape a string for emission.
pub fn escape(s: &str) -> String {
    format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            r#"
            name = "run-1"   # comment
            seed = 42
            [model]
            hidden = 16
            theta = 0.1
            event = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("run-1"));
        assert_eq!(doc.get("", "seed").unwrap().as_i64(), Some(42));
        assert_eq!(doc.get("model", "hidden").unwrap().as_i64(), Some(16));
        assert_eq!(doc.get("model", "theta").unwrap().as_f64(), Some(0.1));
        assert_eq!(doc.get("model", "event").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = Doc::parse("lr = 1").unwrap();
        assert_eq!(doc.get("", "lr").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = Doc::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(doc.get("", "tag").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Doc::parse("ok = 1\nbroken").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let doc = Doc::parse("a = -3\nb = 1.5e-2").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_i64(), Some(-3));
        assert!((doc.get("", "b").unwrap().as_f64().unwrap() - 0.015).abs() < 1e-12);
    }

    /// Unknown keys are parsed and retained, never an error — forward and
    /// backward compatibility of experiment TOMLs rests on this (e.g. files
    /// written before `model.layers` existed, or after keys this build does
    /// not know yet).
    #[test]
    fn unknown_keys_are_preserved_not_fatal() {
        let doc = Doc::parse(
            r#"
            future_top_level = "kept"
            [model]
            hidden = 8
            some_future_knob = 3.5
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "future_top_level").unwrap().as_str(), Some("kept"));
        assert_eq!(doc.get("model", "some_future_knob").unwrap().as_f64(), Some(3.5));
        let keys = doc.keys("model");
        assert!(keys.contains(&"hidden") && keys.contains(&"some_future_knob"));
        assert!(doc.keys("absent_section").is_empty());
    }

    #[test]
    fn escape_roundtrip() {
        let original = "say \"hi\" \\ there";
        let doc = Doc::parse(&format!("s = {}", escape(original))).unwrap();
        assert_eq!(doc.get("", "s").unwrap().as_str(), Some(original));
    }
}
