//! CRC-32 (IEEE 802.3, the zlib/PNG polynomial) — in-tree because the build
//! vendors no checksum crate. Used by the binary snapshot codec
//! ([`crate::session::codec`]) to checksum each section payload so a
//! flipped bit in a spilled checkpoint fails loudly on load instead of
//! resuming a session from silently corrupted state.

/// Reflected polynomial of CRC-32/ISO-HDLC.
const POLY: u32 = 0xEDB8_8320;

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// CRC-32 of `bytes` (init `0xFFFF_FFFF`, final xor `0xFFFF_FFFF` — the
/// standard zlib convention, so values can be cross-checked with any
/// external `crc32` tool).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The standard check value of CRC-32/ISO-HDLC.
    #[test]
    fn known_vectors() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn single_bit_flip_changes_checksum() {
        let a = b"some section payload".to_vec();
        let mut b = a.clone();
        b[7] ^= 0x04;
        assert_ne!(crc32(&a), crc32(&b));
    }
}
