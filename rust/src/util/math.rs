//! Scalar math helpers shared by cells and losses.

/// Numerically stable logistic sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        let e = (-x).exp();
        1.0 / (1.0 + e)
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Derivative of sigmoid expressed through its output: `s(1-s)`.
#[inline]
pub fn dsigmoid_from_out(s: f32) -> f32 {
    s * (1.0 - s)
}

/// Derivative of tanh expressed through its output: `1 - t²`.
#[inline]
pub fn dtanh_from_out(t: f32) -> f32 {
    1.0 - t * t
}

/// Numerically stable softmax over a slice, written into `out`.
pub fn softmax_into(logits: &[f32], out: &mut [f32]) {
    debug_assert_eq!(logits.len(), out.len());
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for (o, &l) in out.iter_mut().zip(logits) {
        let e = (l - max).exp();
        *o = e;
        sum += e;
    }
    let inv = 1.0 / sum;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// log-sum-exp of a slice (stable).
pub fn logsumexp(xs: &[f32]) -> f32 {
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if max.is_infinite() {
        return max;
    }
    max + xs.iter().map(|&x| (x - max).exp()).sum::<f32>().ln()
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

/// Sample standard error of the mean.
pub fn stderr(xs: &[f32]) -> f32 {
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / (n as f32 - 1.0);
    (var / n as f32).sqrt()
}

/// Sequential left-fold sum over f32 values. Element order is pinned here
/// (identical to `Iterator::sum`), so every caller inherits the same
/// bit-exact accumulation regardless of where the values came from.
#[inline]
pub fn sum_f32(xs: impl IntoIterator<Item = f32>) -> f32 {
    let mut acc = 0.0f32;
    for x in xs {
        acc += x;
    }
    acc
}

/// Sequential left-fold sum over f64 values; the f64 twin of [`sum_f32`].
#[inline]
pub fn sum_f64(xs: impl IntoIterator<Item = f64>) -> f64 {
    let mut acc = 0.0f64;
    for x in xs {
        acc += x;
    }
    acc
}

/// Mean of an f64 stream with a known element count (0 when `n == 0`).
/// Summary/report code funnels through here so the float-discipline rule
/// can pin reduction order in exactly one place.
#[inline]
pub fn mean_f64(xs: impl IntoIterator<Item = f64>, n: usize) -> f64 {
    if n == 0 {
        0.0
    } else {
        sum_f64(xs) / n as f64
    }
}

/// Pinned-order dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    sum_f32(a.iter().zip(b).map(|(x, y)| x * y))
}

/// Euclidean norm with pinned accumulation order.
#[inline]
pub fn l2_norm(xs: &[f32]) -> f32 {
    sum_f32(xs.iter().map(|x| x * x)).sqrt()
}

/// Max absolute difference between two slices (∞ if lengths differ).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    if a.len() != b.len() {
        return f32::INFINITY;
    }
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Relative closeness check used by the exactness tests:
/// `|a-b| <= atol + rtol*|b|` elementwise.
pub fn allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| (x - y).abs() <= atol + rtol * y.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_basics() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(30.0) > 0.9999);
        assert!(sigmoid(-30.0) < 0.0001);
        // stability at extremes
        assert!(sigmoid(-1000.0) >= 0.0);
        assert!(sigmoid(1000.0) <= 1.0);
    }

    #[test]
    fn sigmoid_symmetry() {
        for i in -50..=50 {
            let x = i as f32 * 0.2;
            assert!((sigmoid(x) + sigmoid(-x) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let logits = [1000.0, 1001.0, 999.0];
        let mut out = [0.0; 3];
        softmax_into(&logits, &mut out);
        let s: f32 = out.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(out[1] > out[0] && out[0] > out[2]);
    }

    #[test]
    fn logsumexp_matches_naive_in_safe_range() {
        let xs = [0.1f32, -0.3, 2.0];
        let naive = xs.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!((logsumexp(&xs) - naive).abs() < 1e-6);
    }

    #[test]
    fn stderr_of_constant_is_zero() {
        assert_eq!(stderr(&[2.0, 2.0, 2.0]), 0.0);
    }

    #[test]
    fn pinned_sums_match_iterator_sum_bitwise() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 * 0.37).sin() * 1e3).collect();
        assert_eq!(sum_f32(xs.iter().copied()).to_bits(), xs.iter().sum::<f32>().to_bits());
        let ys: Vec<f64> = xs.iter().map(|&x| x as f64 / 7.0).collect();
        assert_eq!(sum_f64(ys.iter().copied()).to_bits(), ys.iter().sum::<f64>().to_bits());
    }

    #[test]
    fn mean_f64_handles_empty_and_matches_manual() {
        assert_eq!(mean_f64(std::iter::empty(), 0), 0.0);
        let ys = [1.5f64, 2.5, -0.5];
        let manual = ys.iter().sum::<f64>() / 3.0;
        assert_eq!(mean_f64(ys.iter().copied(), 3).to_bits(), manual.to_bits());
    }

    #[test]
    fn dot_and_l2_norm_match_manual_folds() {
        let a = [1.0f32, -2.0, 3.0];
        let b = [0.5f32, 4.0, -1.0];
        let manual: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot(&a, &b).to_bits(), manual.to_bits());
        let norm: f32 = a.iter().map(|x| x * x).sum::<f32>();
        assert_eq!(l2_norm(&a).to_bits(), norm.sqrt().to_bits());
    }

    #[test]
    fn allclose_detects_mismatch() {
        assert!(allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6));
        assert!(!allclose(&[1.0, 2.0], &[1.0, 2.1], 1e-5, 1e-6));
        assert!(!allclose(&[1.0], &[1.0, 2.0], 1e-5, 1e-6));
    }
}
