//! Scoped worker pool for the sweep coordinator, the bench grid and the
//! session pool.
//!
//! A fixed number of OS threads drain a shared job queue; results are
//! collected in submission order. In-tree because the build environment
//! vendors no async runtime — and the unit of work (a whole training run,
//! or one session step) is long enough that OS threads are the right
//! granularity anyway.
//!
//! **Failure containment:** a failing job never kills its siblings. Worker
//! threads catch per-job panics and park them; every queued job still runs,
//! and only then is the first failure surfaced — as the job's own error for
//! [`try_run_parallel`], or by re-raising the first panic payload for
//! [`run_parallel`]. This is what lets one poisoned session in a
//! [`crate::session::SessionPool`] fail alone while the other users' work
//! completes.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// Run `jobs` across at most `workers` threads; returns outputs in the same
/// order as the inputs. `f` must be `Sync` (it is shared), jobs are consumed
/// exactly once. A panicking job does not abort its siblings: every job
/// runs, then the first panic (by job index) is re-raised on the caller.
pub fn run_parallel<I, O, F>(jobs: Vec<I>, workers: usize, f: F) -> Vec<O>
where
    I: Send,
    O: Send,
    F: Fn(usize, I) -> O + Sync,
{
    let results = run_caught(jobs, workers, &f);
    let mut out = Vec::with_capacity(results.len());
    let mut first_panic: Option<PanicPayload> = None;
    for r in results {
        match r {
            Ok(o) => out.push(o),
            Err(payload) => {
                if first_panic.is_none() {
                    first_panic = Some(payload);
                }
            }
        }
    }
    if let Some(p) = first_panic {
        resume_unwind(p);
    }
    out
}

/// Fallible variant: jobs return `Result<O, E>`. Every job runs to
/// completion regardless of sibling failures; on any failure the error of
/// the lowest-indexed failed job is returned together with its index
/// (successful siblings' outputs are dropped — jobs must be idempotent or
/// externally checkpointed if partial results matter). Panicking jobs are
/// contained the same way and re-raised only after every sibling finished.
pub fn try_run_parallel<I, O, E, F>(
    jobs: Vec<I>,
    workers: usize,
    f: F,
) -> Result<Vec<O>, (usize, E)>
where
    I: Send,
    O: Send,
    E: Send,
    F: Fn(usize, I) -> Result<O, E> + Sync,
{
    let results = run_caught(jobs, workers, &f);
    let mut out = Vec::with_capacity(results.len());
    let mut first_err: Option<(usize, E)> = None;
    let mut first_panic: Option<PanicPayload> = None;
    for (i, r) in results.into_iter().enumerate() {
        match r {
            Ok(Ok(o)) => out.push(o),
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some((i, e));
                }
            }
            Err(payload) => {
                if first_panic.is_none() {
                    first_panic = Some(payload);
                }
            }
        }
    }
    if let Some(p) = first_panic {
        resume_unwind(p);
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(out),
    }
}

/// Shared core: drain the queue, catching each job's panic individually so
/// one failure cannot poison the pool.
fn run_caught<I, O, F>(jobs: Vec<I>, workers: usize, f: &F) -> Vec<Result<O, PanicPayload>>
where
    I: Send,
    O: Send,
    F: Fn(usize, I) -> O + Sync,
{
    let workers = workers.max(1).min(jobs.len().max(1));
    let n = jobs.len();
    let queue: Mutex<Vec<Option<I>>> = Mutex::new(jobs.into_iter().map(Some).collect());
    let cursor = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<Result<O, PanicPayload>>>> =
        Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let job = queue.lock().expect("queue lock")[i].take().expect("job taken once");
                let out = catch_unwind(AssertUnwindSafe(|| f(i, job)));
                results.lock().expect("results lock")[i] = Some(out);
            });
        }
    });
    results
        .into_inner()
        .expect("results lock")
        .into_iter()
        .map(|o| o.expect("all jobs completed"))
        .collect()
}

/// Width assumed when the platform cannot report its parallelism
/// (`available_parallelism` errors on some containers/sandboxes): a small
/// multi-core guess beats falling all the way back to serial on machines
/// that are overwhelmingly multi-core, while staying cheap if wrong.
const FALLBACK_WORKERS: usize = 4;

/// The `--threads 0` fallback chain as a pure function of what the
/// platform reports: reported count → [`FALLBACK_WORKERS`] when the
/// platform cannot say → floored at 1 (a reported 0 would deadlock the
/// pool sizing math downstream).
fn worker_fallback_chain(reported: Option<usize>) -> usize {
    reported.unwrap_or(FALLBACK_WORKERS).max(1)
}

/// Available hardware parallelism (≥ 1), via [`worker_fallback_chain`]:
/// the platform-reported count when available, else 4, never below 1.
pub fn available_workers() -> usize {
    worker_fallback_chain(std::thread::available_parallelism().ok().map(|p| p.get()))
}

/// The uniform `--threads` semantics shared by `train`/`stream`/`bench`,
/// [`crate::session::SessionPool`] and the intra-step panel kernels:
/// `0` = [`available_workers`] (hardware parallelism with its fallback
/// chain), any other value is taken as-is.
pub fn resolve_workers(requested: usize) -> usize {
    if requested == 0 {
        available_workers()
    } else {
        requested
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<u64> = (0..50).collect();
        let out = run_parallel(jobs, 8, |_, x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_works() {
        let out = run_parallel(vec![1, 2, 3], 1, |i, x| i as i32 + x);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn empty_jobs_ok() {
        let out: Vec<i32> = run_parallel(Vec::<i32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = run_parallel(vec![7], 16, |_, x| x);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let out = run_parallel(vec![1, 2, 3], 0, |i, x| x + i as i32);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn zero_jobs_zero_workers() {
        let out: Vec<u8> = run_parallel(Vec::new(), 0, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn order_preserved_under_uneven_durations() {
        let jobs: Vec<u64> = (0..24).collect();
        let out = run_parallel(jobs, 6, |i, x| {
            // early jobs sleep longest so completion order inverts
            std::thread::sleep(std::time::Duration::from_millis((24 - i as u64) % 7));
            x * 10
        });
        assert_eq!(out, (0..24).map(|x| x * 10).collect::<Vec<_>>());
    }

    /// A panicking job must still fail the whole `run_parallel` call (the
    /// caller sees the panic) — but only after every sibling ran.
    #[test]
    #[should_panic]
    fn propagates_worker_panics() {
        run_parallel(vec![1, 2, 3], 2, |_, x| {
            if x == 2 {
                panic!("job failure");
            }
            x
        });
    }

    /// The containment satellite: one failing job must not kill or skip its
    /// siblings — all jobs run, and the caller receives the failed job's
    /// error (index + payload), not a poisoned pool.
    #[test]
    fn failing_job_does_not_kill_siblings() {
        use std::sync::atomic::AtomicUsize;
        static COMPLETED: AtomicUsize = AtomicUsize::new(0);
        let jobs: Vec<usize> = (0..8).collect();
        let r: Result<Vec<usize>, (usize, String)> = try_run_parallel(jobs, 4, |_, x| {
            COMPLETED.fetch_add(1, Ordering::SeqCst);
            if x == 3 {
                Err(format!("job {x} exploded"))
            } else {
                Ok(x * 10)
            }
        });
        assert_eq!(COMPLETED.load(Ordering::SeqCst), 8, "a sibling was skipped");
        let (idx, msg) = r.unwrap_err();
        assert_eq!(idx, 3);
        assert!(msg.contains("exploded"));
    }

    /// Same containment under a *panicking* job: siblings all complete
    /// before the panic is re-raised on the caller.
    #[test]
    fn panicking_job_lets_siblings_finish() {
        use std::sync::atomic::AtomicUsize;
        static COMPLETED: AtomicUsize = AtomicUsize::new(0);
        let jobs: Vec<usize> = (0..6).collect();
        let caught = catch_unwind(AssertUnwindSafe(|| {
            run_parallel(jobs, 3, |_, x| {
                if x == 1 {
                    panic!("bad job");
                }
                COMPLETED.fetch_add(1, Ordering::SeqCst);
                x
            })
        }));
        assert!(caught.is_err(), "panic must still reach the caller");
        assert_eq!(COMPLETED.load(Ordering::SeqCst), 5, "siblings died with the bad job");
    }

    #[test]
    fn resolve_workers_zero_means_available() {
        assert_eq!(resolve_workers(3), 3);
        assert_eq!(resolve_workers(1), 1);
        let auto = resolve_workers(0);
        assert!(auto >= 1);
        assert_eq!(auto, available_workers());
    }

    /// The `--threads 0` fallback chain, each link pinned: platform count
    /// when reported, 4 when the platform cannot say, floor of 1 always.
    #[test]
    fn worker_fallback_chain_links() {
        assert_eq!(worker_fallback_chain(Some(16)), 16);
        assert_eq!(worker_fallback_chain(Some(1)), 1);
        assert_eq!(worker_fallback_chain(None), FALLBACK_WORKERS);
        assert_eq!(worker_fallback_chain(None), 4);
        assert_eq!(worker_fallback_chain(Some(0)), 1);
    }

    #[test]
    fn try_run_parallel_all_ok() {
        let out: Result<Vec<i32>, (usize, String)> =
            try_run_parallel(vec![1, 2, 3], 2, |i, x| Ok(x + i as i32));
        assert_eq!(out.unwrap(), vec![1, 3, 5]);
    }

    /// With several failures, the lowest job index wins (deterministic
    /// regardless of scheduling).
    #[test]
    fn first_error_by_index_is_reported() {
        let jobs: Vec<usize> = (0..10).collect();
        let r: Result<Vec<usize>, (usize, usize)> = try_run_parallel(jobs, 4, |_, x| {
            if x % 3 == 2 {
                Err(x)
            } else {
                Ok(x)
            }
        });
        assert_eq!(r.unwrap_err(), (2, 2));
    }

    #[test]
    fn actually_parallel() {
        use std::sync::atomic::AtomicUsize;
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static CUR: AtomicUsize = AtomicUsize::new(0);
        let jobs: Vec<usize> = (0..8).collect();
        run_parallel(jobs, 4, |_, _| {
            let c = CUR.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(c, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(30));
            CUR.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(PEAK.load(Ordering::SeqCst) >= 2, "no overlap observed");
    }
}
