//! Scoped worker pool for the sweep coordinator.
//!
//! A fixed number of OS threads drain a shared job queue; results are
//! collected in submission order. In-tree because the build environment
//! vendors no async runtime — and the sweep's unit of work (a whole training
//! run) is seconds long, so OS threads are the right granularity anyway.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `jobs` across at most `workers` threads; returns outputs in the same
/// order as the inputs. `f` must be `Sync` (it is shared), jobs are consumed
/// exactly once.
pub fn run_parallel<I, O, F>(jobs: Vec<I>, workers: usize, f: F) -> Vec<O>
where
    I: Send,
    O: Send,
    F: Fn(usize, I) -> O + Sync,
{
    let workers = workers.max(1).min(jobs.len().max(1));
    let n = jobs.len();
    let queue: Mutex<Vec<Option<I>>> = Mutex::new(jobs.into_iter().map(Some).collect());
    let cursor = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<O>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let job = queue.lock().expect("queue lock")[i].take().expect("job taken once");
                let out = f(i, job);
                results.lock().expect("results lock")[i] = Some(out);
            });
        }
    });
    results
        .into_inner()
        .expect("results lock")
        .into_iter()
        .map(|o| o.expect("all jobs completed"))
        .collect()
}

/// Available hardware parallelism (≥ 1).
pub fn available_workers() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<u64> = (0..50).collect();
        let out = run_parallel(jobs, 8, |_, x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_works() {
        let out = run_parallel(vec![1, 2, 3], 1, |i, x| i as i32 + x);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn empty_jobs_ok() {
        let out: Vec<i32> = run_parallel(Vec::<i32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = run_parallel(vec![7], 16, |_, x| x);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let out = run_parallel(vec![1, 2, 3], 0, |i, x| x + i as i32);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn zero_jobs_zero_workers() {
        let out: Vec<u8> = run_parallel(Vec::new(), 0, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn order_preserved_under_uneven_durations() {
        let jobs: Vec<u64> = (0..24).collect();
        let out = run_parallel(jobs, 6, |i, x| {
            // early jobs sleep longest so completion order inverts
            std::thread::sleep(std::time::Duration::from_millis((24 - i as u64) % 7));
            x * 10
        });
        assert_eq!(out, (0..24).map(|x| x * 10).collect::<Vec<_>>());
    }

    /// A panicking job must fail the whole call (scoped threads propagate),
    /// not silently drop its slot.
    #[test]
    #[should_panic]
    fn propagates_worker_panics() {
        run_parallel(vec![1, 2, 3], 2, |_, x| {
            if x == 2 {
                panic!("job failure");
            }
            x
        });
    }

    #[test]
    fn actually_parallel() {
        use std::sync::atomic::AtomicUsize;
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static CUR: AtomicUsize = AtomicUsize::new(0);
        let jobs: Vec<usize> = (0..8).collect();
        run_parallel(jobs, 4, |_, _| {
            let c = CUR.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(c, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(30));
            CUR.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(PEAK.load(Ordering::SeqCst) >= 2, "no overlap observed");
    }
}
