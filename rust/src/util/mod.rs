//! Small utilities: deterministic RNG, math helpers, progress reporting.

pub mod cli;
pub mod crc32;
pub mod math;
pub mod pool;
pub mod rng;
pub mod toml_mini;

pub use rng::Pcg64;
