//! Deterministic PCG64 (XSL-RR) random number generator.
//!
//! Every stochastic component in the library (weight init, sparsity masks,
//! dataset generation, shuffling) draws from this generator so that runs are
//! bit-reproducible across platforms from a single `u64` seed — a requirement
//! for the paper's 5-seed mean ± stderr protocol (Fig. 3) and for the
//! exactness tests that compare engines on identical weights and data.

/// PCG-XSL-RR 128/64 generator (O'Neill 2014).
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64-style scrambling to build the 128-bit state/increment.
        let a = splitmix64(seed);
        let b = splitmix64(a);
        let c = splitmix64(b);
        let d = splitmix64(c);
        let mut rng = Pcg64 {
            state: ((a as u128) << 64) | b as u128,
            inc: (((c as u128) << 64) | d as u128) | 1,
        };
        rng.state = rng.state.wrapping_add(rng.inc);
        rng.next_u64();
        rng
    }

    /// Derive an independent child stream (used to give each run in a sweep
    /// its own generator without coupling to iteration order).
    pub fn split(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64())
    }

    /// Raw generator state as four words `[state_hi, state_lo, inc_hi,
    /// inc_lo]` — the checkpoint format for stochastic engines (UORO), which
    /// must resume their noise stream at the exact position to stay
    /// bit-reproducible across a save/restore boundary.
    pub fn state_words(&self) -> [u64; 4] {
        [
            (self.state >> 64) as u64,
            self.state as u64,
            (self.inc >> 64) as u64,
            self.inc as u64,
        ]
    }

    /// Rebuild a generator from [`Pcg64::state_words`] output; the restored
    /// stream continues exactly where the saved one stopped.
    pub fn from_state_words(w: [u64; 4]) -> Self {
        Pcg64 {
            state: ((w[0] as u128) << 64) | w[1] as u128,
            inc: ((w[2] as u128) << 64) | w[3] as u128,
        }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 high bits -> [0,1) with full float precision.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (one value per call; simple and exact).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's method (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Bernoulli draw with probability `p` of `true`.
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample exactly `k` distinct indices from `[0, n)` (reservoir-free;
    /// used for fixed parameter-sparsity masks where an exact count matters).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_roughly_half() {
        let mut r = Pcg64::new(3);
        let m: f64 = (0..100_000).map(|_| r.f64()).sum::<f64>() / 100_000.0;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Pcg64::new(9);
        let xs: Vec<f64> = (0..100_000).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn choose_k_exact_distinct_sorted() {
        let mut r = Pcg64::new(13);
        let ks = r.choose_k(100, 37);
        assert_eq!(ks.len(), 37);
        for w in ks.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(ks.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_words_resume_exact_stream() {
        let mut a = Pcg64::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Pcg64::from_state_words(a.state_words());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::new(5);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
