//! Tiny CLI argument parser: `prog <subcommand> [positionals] [--flag value]`.
//!
//! In-tree because the build environment vendors no argument-parsing crate.
//! Supports `--key value`, `--key=value`, bare boolean flags (`--verbose`)
//! and positional arguments; unknown-flag detection is the caller's choice
//! via [`Args::finish`].

use std::collections::BTreeMap;

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    consumed: std::collections::BTreeSet<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if flag.is_empty() {
                    return Err("stray `--`".into());
                }
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().expect("peeked");
                    out.flags.insert(flag.to_string(), v);
                } else {
                    out.flags.insert(flag.to_string(), "true".to_string());
                }
            } else {
                out.positionals.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    /// Positional argument `i`.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    pub fn positional_count(&self) -> usize {
        self.positionals.len()
    }

    /// String flag.
    pub fn get(&mut self, key: &str) -> Option<String> {
        self.consumed.insert(key.to_string());
        self.flags.get(key).cloned()
    }

    /// Typed flag with default.
    pub fn get_parse<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| format!("--{key}: cannot parse {v:?}")),
        }
    }

    /// Boolean flag (present without value, or `=true/false`).
    pub fn get_bool(&mut self, key: &str) -> Result<bool, String> {
        match self.get(key) {
            None => Ok(false),
            Some(v) => v.parse::<bool>().map_err(|_| format!("--{key}: expected bool, got {v:?}")),
        }
    }

    /// Error on any flag never consumed (typo protection).
    pub fn finish(&self) -> Result<(), String> {
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !self.consumed.contains(*k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown flags: {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positionals_and_flags() {
        let mut a = parse("train --seed 7 --out results/x.csv extra");
        assert_eq!(a.pos(0), Some("train"));
        assert_eq!(a.pos(1), Some("extra"));
        assert_eq!(a.get_parse::<u64>("seed", 0).unwrap(), 7);
        assert_eq!(a.get("out").unwrap(), "results/x.csv");
    }

    #[test]
    fn equals_form_and_bools() {
        let mut a = parse("run --omega=0.8 --verbose");
        assert!((a.get_parse::<f32>("omega", 0.0).unwrap() - 0.8).abs() < 1e-6);
        assert!(a.get_bool("verbose").unwrap());
        assert!(!a.get_bool("absent").unwrap());
    }

    #[test]
    fn defaults_apply() {
        let mut a = parse("cmd");
        assert_eq!(a.get_parse::<usize>("n", 16).unwrap(), 16);
    }

    #[test]
    fn finish_catches_typos() {
        let mut a = parse("cmd --seeed 1");
        let _ = a.get("seed");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let mut a = parse("cmd --n notanumber");
        assert!(a.get_parse::<usize>("n", 1).is_err());
    }
}
