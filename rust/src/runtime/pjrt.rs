//! PJRT bridge: compile and execute AOT-lowered HLO text on the CPU client.
//!
//! Interchange is HLO *text* (not serialized `HloModuleProto`): jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids and round-trips cleanly. The JAX side lowers
//! with `return_tuple=True`, so outputs arrive as a tuple literal.

use anyhow::{anyhow as eyre, Context, Result};
use std::path::Path;

/// Shared PJRT CPU client (compile once, execute many).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create the CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| eyre!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load(&self, path: &Path) -> Result<PjrtExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| eyre!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| eyre!("compile {path:?}: {e:?}"))?;
        Ok(PjrtExecutable {
            exe,
            name: path.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        })
    }
}

/// A compiled executable with an f32 convenience interface.
pub struct PjrtExecutable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl PjrtExecutable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with f32 inputs of the given shapes; returns all tuple
    /// outputs as flat f32 buffers (row-major).
    pub fn run_f32(&self, inputs: &[(&[usize], &[f32])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (shape, data) in inputs {
            let numel: usize = shape.iter().product();
            if numel != data.len() {
                return Err(eyre!(
                    "shape {shape:?} wants {numel} elements, got {}",
                    data.len()
                ));
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| eyre!("reshape to {dims:?}: {e:?}"))?;
            literals.push(lit);
        }
        let bufs = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| eyre!("execute {}: {e:?}", self.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| eyre!("fetch result: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| eyre!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| eyre!("to_vec: {e:?}")))
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("decoding outputs of {}", self.name))
    }
}
