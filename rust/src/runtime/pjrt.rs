//! PJRT bridge: compile and execute AOT-lowered HLO text on the CPU client.
//!
//! Interchange is HLO *text* (not serialized `HloModuleProto`): jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids and round-trips cleanly. The JAX side lowers
//! with `return_tuple=True`, so outputs arrive as a tuple literal.
//!
//! The real implementation needs the `xla` bindings crate plus a local
//! xla_extension build and is therefore gated behind the `pjrt` cargo
//! feature. The default build gets a stub with the identical API whose
//! constructors fail at runtime; callers check [`PjrtRuntime::available`]
//! and degrade gracefully (tests skip, the CLI explains how to enable it).

#[cfg(feature = "pjrt")]
mod imp {
    use anyhow::{anyhow as eyre, Context, Result};
    use std::path::Path;

    /// Shared PJRT CPU client (compile once, execute many).
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
    }

    impl PjrtRuntime {
        /// Whether this build can execute artifacts (true: `pjrt` feature on).
        pub fn available() -> bool {
            true
        }

        /// Create the CPU client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| eyre!("PJRT cpu client: {e:?}"))?;
            Ok(PjrtRuntime { client })
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact and compile it for this client.
        pub fn load(&self, path: &Path) -> Result<PjrtExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| eyre!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| eyre!("compile {path:?}: {e:?}"))?;
            Ok(PjrtExecutable {
                exe,
                name: path
                    .file_name()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_default(),
            })
        }
    }

    /// A compiled executable with an f32 convenience interface.
    pub struct PjrtExecutable {
        exe: xla::PjRtLoadedExecutable,
        name: String,
    }

    impl PjrtExecutable {
        pub fn name(&self) -> &str {
            &self.name
        }

        /// Execute with f32 inputs of the given shapes; returns all tuple
        /// outputs as flat f32 buffers (row-major).
        pub fn run_f32(&self, inputs: &[(&[usize], &[f32])]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (shape, data) in inputs {
                let numel: usize = shape.iter().product();
                if numel != data.len() {
                    return Err(eyre!(
                        "shape {shape:?} wants {numel} elements, got {}",
                        data.len()
                    ));
                }
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| eyre!("reshape to {dims:?}: {e:?}"))?;
                literals.push(lit);
            }
            let bufs = self
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| eyre!("execute {}: {e:?}", self.name))?;
            let result = bufs[0][0]
                .to_literal_sync()
                .map_err(|e| eyre!("fetch result: {e:?}"))?;
            let parts = result.to_tuple().map_err(|e| eyre!("untuple: {e:?}"))?;
            parts
                .into_iter()
                .map(|l| l.to_vec::<f32>().map_err(|e| eyre!("to_vec: {e:?}")))
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("decoding outputs of {}", self.name))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use anyhow::{anyhow, Result};
    use std::path::Path;

    const UNAVAILABLE: &str =
        "PJRT support not compiled in — add the unvendored `xla` bindings crate to \
         rust/Cargo.toml (plus a local xla_extension build) and rebuild with `--features pjrt`";

    /// Stub PJRT client: same API as the real one, never constructs.
    pub struct PjrtRuntime {
        _private: (),
    }

    impl PjrtRuntime {
        /// Whether this build can execute artifacts (false: stub build).
        pub fn available() -> bool {
            false
        }

        /// Always fails in the stub build.
        pub fn cpu() -> Result<Self> {
            Err(anyhow!(UNAVAILABLE))
        }

        pub fn platform_name(&self) -> String {
            "unavailable".to_string()
        }

        /// Always fails in the stub build (the runtime cannot be constructed,
        /// so this is unreachable in practice).
        pub fn load(&self, _path: &Path) -> Result<PjrtExecutable> {
            Err(anyhow!(UNAVAILABLE))
        }
    }

    /// Stub executable; cannot be constructed.
    pub struct PjrtExecutable {
        _private: (),
    }

    impl PjrtExecutable {
        pub fn name(&self) -> &str {
            "unavailable"
        }

        pub fn run_f32(&self, _inputs: &[(&[usize], &[f32])]) -> Result<Vec<Vec<f32>>> {
            Err(anyhow!(UNAVAILABLE))
        }
    }
}

pub use imp::{PjrtExecutable, PjrtRuntime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn availability_is_consistent_with_cpu_constructor() {
        match PjrtRuntime::cpu() {
            Ok(_) => assert!(PjrtRuntime::available()),
            Err(_) => {
                // Either the stub build, or a real build without a usable
                // PJRT plugin; the stub must report unavailability.
                if !cfg!(feature = "pjrt") {
                    assert!(!PjrtRuntime::available());
                }
            }
        }
    }
}
