//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) from Rust. Python never runs at request time.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::ArtifactSet;
pub use pjrt::{PjrtExecutable, PjrtRuntime};
