//! Artifact discovery + manifest.
//!
//! `make artifacts` runs `python/compile/aot.py`, which writes
//! `artifacts/<name>.hlo.txt` files plus `artifacts/manifest.txt` describing
//! shapes and constants baked into each lowering. The Rust side never
//! invokes Python — if artifacts are missing, callers degrade gracefully
//! (tests skip, the CLI prints how to build them).
//!
//! Manifest format (one artifact per line, `#` comments):
//!
//! ```text
//! name | in 32x2, 16 | out 32x16 | n=16 n_in=2 theta=0.1
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Canonical artifact names emitted by `python/compile/aot.py`.
pub mod names {
    /// One EGRU forward step over a batch.
    pub const EGRU_STEP: &str = "egru_step";
    /// One full dense RTRL step: forward + J/M̄ + influence update + grads.
    pub const RTRL_STEP: &str = "rtrl_step";
    /// The Pallas blocked influence-update kernel alone.
    pub const INFLUENCE_KERNEL: &str = "influence_kernel";
}

/// Per-artifact manifest entry (shapes are row-major).
#[derive(Debug, Clone, Default)]
pub struct ArtifactInfo {
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
    /// Model constants baked at lowering time (n, n_in, theta, ...).
    pub meta: HashMap<String, f64>,
}

fn parse_shapes(field: &str) -> Option<Vec<Vec<usize>>> {
    let body = field.trim();
    if body.is_empty() {
        return Some(Vec::new());
    }
    body.split(',')
        .map(|s| {
            s.trim()
                .split('x')
                .map(|d| d.trim().parse::<usize>().ok())
                .collect::<Option<Vec<usize>>>()
        })
        .collect()
}

fn parse_line(line: &str) -> Option<(String, ArtifactInfo)> {
    let mut info = ArtifactInfo::default();
    let mut parts = line.split('|');
    let name = parts.next()?.trim().to_string();
    if name.is_empty() {
        return None;
    }
    for field in parts {
        let field = field.trim();
        if let Some(rest) = field.strip_prefix("in ") {
            info.inputs = parse_shapes(rest)?;
        } else if let Some(rest) = field.strip_prefix("out ") {
            info.outputs = parse_shapes(rest)?;
        } else {
            for kv in field.split_whitespace() {
                let (k, v) = kv.split_once('=')?;
                info.meta.insert(k.to_string(), v.parse().ok()?);
            }
        }
    }
    Some((name, info))
}

/// A directory of compiled artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    dir: PathBuf,
    manifest: HashMap<String, ArtifactInfo>,
}

impl ArtifactSet {
    /// Open an artifact directory (typically `artifacts/` at the repo root).
    /// Succeeds even if empty; use [`ArtifactSet::has`] before loading.
    pub fn open(dir: impl Into<PathBuf>) -> Self {
        let dir = dir.into();
        let mut manifest = HashMap::new();
        if let Ok(text) = std::fs::read_to_string(dir.join("manifest.txt")) {
            for line in text.lines() {
                let line = line.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                if let Some((name, info)) = parse_line(line) {
                    manifest.insert(name, info);
                }
            }
        }
        ArtifactSet { dir, manifest }
    }

    /// Default location relative to the current working directory, honouring
    /// `SPARSE_RTRL_ARTIFACTS` for out-of-tree runs.
    pub fn default_location() -> Self {
        let dir = std::env::var("SPARSE_RTRL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(dir)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path of a named artifact.
    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Whether the artifact file exists.
    pub fn has(&self, name: &str) -> bool {
        self.path(name).is_file()
    }

    /// Manifest info for a named artifact, if present.
    pub fn info(&self, name: &str) -> Option<&ArtifactInfo> {
        self.manifest.get(name)
    }

    /// All `.hlo.txt` artifact stems present on disk.
    pub fn list(&self) -> Vec<String> {
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.flatten() {
                let name = e.file_name().to_string_lossy().into_owned();
                if let Some(stem) = name.strip_suffix(".hlo.txt") {
                    out.push(stem.to_string());
                }
            }
        }
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_missing_dir_is_empty() {
        let a = ArtifactSet::open("/nonexistent/path");
        assert!(!a.has(names::EGRU_STEP));
        assert!(a.list().is_empty());
        assert!(a.info(names::RTRL_STEP).is_none());
    }

    #[test]
    fn parses_manifest_lines() {
        let (name, info) = parse_line("egru_step | in 32x2, 16 | out 32x16 | n=16 theta=0.1").unwrap();
        assert_eq!(name, "egru_step");
        assert_eq!(info.inputs, vec![vec![32, 2], vec![16]]);
        assert_eq!(info.outputs, vec![vec![32, 16]]);
        assert_eq!(info.meta["n"], 16.0);
        assert_eq!(info.meta["theta"], 0.1);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_line("").is_none());
        assert!(parse_line("x | in axb").is_none());
        assert!(parse_line("x | n=notanumber").is_none());
    }

    #[test]
    fn discovers_files_and_manifest() {
        let dir = std::env::temp_dir().join("sparse_rtrl_artifacts_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("foo.hlo.txt"), "HloModule test").unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "# comment\nfoo | in 2x3 | out 2 | n=16\n",
        )
        .unwrap();
        let a = ArtifactSet::open(&dir);
        assert!(a.has("foo"));
        assert_eq!(a.list(), vec!["foo".to_string()]);
        let info = a.info("foo").unwrap();
        assert_eq!(info.inputs, vec![vec![2, 3]]);
        assert_eq!(info.meta["n"], 16.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
