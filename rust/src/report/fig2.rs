//! Fig. 2 regeneration: sparsity patterns of `J`, `M̄` and `M` under the four
//! regimes (dense / parameter / activity / both), rendered as ASCII grids.
//!
//! The paper's figure is schematic; ours is *measured* — we build a small
//! cell for each regime, run a few RTRL steps, and print which entries of
//! the actual matrices are nonzero.

use crate::metrics::OpCounter;
use crate::nn::{CellScratch, LayerStack, Loss, LossKind, Readout, RnnCell};
use crate::rtrl::{DenseRtrl, GradientEngine, Target};
use crate::sparse::MaskPattern;
use crate::util::Pcg64;

/// Render one matrix as a block grid (`█` nonzero, `·` zero).
fn grid(rows: usize, cols: usize, get: impl Fn(usize, usize) -> f32, max_cols: usize) -> String {
    let show = cols.min(max_cols);
    let mut s = String::new();
    for r in 0..rows {
        for c in 0..show {
            s.push(if get(r, c) != 0.0 { '█' } else { '·' });
        }
        if show < cols {
            s.push_str(" …");
        }
        s.push('\n');
    }
    s
}

/// Build, step and render one regime.
fn regime(name: &str, activity: bool, param_sparse: bool, out: &mut String) {
    let n = 8;
    let mut rng = Pcg64::new(42);
    let mask = if param_sparse {
        Some(MaskPattern::random(n, n, 0.3, &mut rng))
    } else {
        None
    };
    let cell = if activity {
        RnnCell::egru(n, 2, 0.1, 0.3, 0.5, mask, &mut rng)
    } else {
        RnnCell::gated_tanh(n, 2, mask, &mut rng)
    };
    let mut readout = Readout::new(2, n, &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let net = LayerStack::single(cell);
    let cell = net.layer(0);
    let mut eng = DenseRtrl::new(&net, 2);
    let mut ops = OpCounter::new();
    eng.begin_sequence();
    // a few steps so M accumulates cross-unit influence
    let mut scratch = CellScratch::new(n);
    let mut a_prev = vec![0.0; n];
    for t in 0..4 {
        let x = [(t as f32 * 0.9).sin(), (t as f32 * 0.4).cos()];
        eng.step(&net, &mut readout, &mut loss, &x, Target::None, &mut ops);
        cell.forward(&a_prev.clone(), &x, &mut scratch, &mut OpCounter::new());
        a_prev.copy_from_slice(&scratch.a);
    }
    out.push_str(&format!("\n--- {name} ---\n"));
    out.push_str(&format!("J (n×n, φ'-gated Jacobian):\n"));
    out.push_str(&grid(
        n,
        n,
        |k, l| scratch.dphi[k] * cell.dv_da(&scratch, k, l),
        n,
    ));
    out.push_str("M (influence, first 48 param columns):\n");
    out.push_str(&grid(n, cell.p(), |k, p| eng.influence().get(k, p), 48));
    let zero_rows = (0..n)
        .filter(|&k| (0..cell.p()).all(|p| eng.influence().get(k, p) == 0.0))
        .count();
    out.push_str(&format!(
        "zero rows of M: {zero_rows}/{n}   M sparsity: {:.2}\n",
        eng.influence().sparsity()
    ));
}

/// Full Fig.-2 report.
pub fn render() -> String {
    let mut out = String::from("Fig 2: measured sparsity structure of RTRL matrices\n");
    regime("(A) dense", false, false, &mut out);
    regime("(B) parameter sparsity only", false, true, &mut out);
    regime("(C) activity sparsity only", true, false, &mut out);
    regime("(D) activity + parameter sparsity", true, true, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_has_all_four_panels() {
        let r = render();
        for p in ["(A)", "(B)", "(C)", "(D)"] {
            assert!(r.contains(p), "missing panel {p}");
        }
        assert!(r.contains('█'));
        assert!(r.contains('·'));
    }
}
