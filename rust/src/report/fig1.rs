//! Fig. 1 regeneration: the Heaviside pseudo-derivative.

use crate::nn::pseudo;
use crate::report::ascii_plot;

/// CSV of the pseudo-derivative curve for (γ, ε) settings.
pub fn csv(gamma: f32, eps: f32) -> String {
    let mut s = String::from("v,pseudo_derivative\n");
    for (v, d) in pseudo::curve(gamma, eps, -2.0 * eps, 2.0 * eps, 201) {
        s.push_str(&format!("{v:.4},{d:.6}\n"));
    }
    s
}

/// ASCII rendering (terminal report).
pub fn render(gamma: f32, eps: f32) -> String {
    let pts: Vec<(f64, f64)> = pseudo::curve(gamma, eps, -2.0 * eps, 2.0 * eps, 80)
        .into_iter()
        .map(|(v, d)| (v as f64, d as f64))
        .collect();
    let mut out = ascii_plot::plot(
        &[("H'(v)", pts)],
        72,
        12,
        &format!("Fig 1: pseudo-derivative γ={gamma} ε={eps} (zero for |v|>ε ⇒ β-sparsity)"),
    );
    out.push_str("x axis: unit state v relative to threshold\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_has_rows_and_peak() {
        let c = csv(0.3, 0.5);
        assert_eq!(c.lines().count(), 202);
        assert!(c.contains("0.300000")); // peak value at v=0
    }

    #[test]
    fn render_contains_legend() {
        let r = render(0.3, 0.5);
        assert!(r.contains("H'(v)"));
    }
}
