//! Small file-output helpers for result artifacts.

use std::io::Write;
use std::path::Path;

/// Write text to a path, creating parent directories.
pub fn write_text(path: &Path, text: &str) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(text.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_creates_dirs() {
        let dir = std::env::temp_dir().join("sparse_rtrl_csv_test");
        let _ = std::fs::remove_dir_all(&dir);
        let p = dir.join("a/b/test.csv");
        write_text(&p, "x,y\n1,2\n").unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "x,y\n1,2\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
