//! Table 1 regeneration: memory and time-per-step costs for every method,
//! both **analytic** (the paper's factors, instantiated with measured
//! α/β/ω̃ and generalized to the block lower-bidiagonal stacked recursion)
//! and **measured** (actual MACs and state words from running each engine
//! on the same stack and input), with a per-layer op/memory breakdown for
//! depth > 1.

use crate::config::AlgorithmKind;
use crate::metrics::{OpCounter, Phase};
use crate::nn::{LayerStack, Loss, LossKind, Readout, RnnCell};
use crate::rtrl::{GradientEngine, Target};
use crate::sparse::MaskPattern;
use crate::train::build_engine;
use crate::util::Pcg64;

/// One measured row of the table.
#[derive(Debug, Clone)]
pub struct Row {
    pub method: &'static str,
    pub analytic_time: String,
    pub analytic_memory: String,
    pub measured_influence_macs: u64,
    pub measured_total_macs: u64,
    pub measured_memory_words: usize,
    /// Per-layer influence MACs per step (Jacobian + InfluenceUpdate +
    /// GradCombine, where layer-attributable).
    pub per_layer_influence_macs: Vec<u64>,
    /// Per-layer words per step.
    pub per_layer_words: Vec<u64>,
}

/// Cost-model parameters extracted from a run.
#[derive(Debug, Clone)]
pub struct CostParams {
    /// Hidden width per layer (uniform stacks).
    pub n: usize,
    /// Total parameter count `P` across layers.
    pub p: usize,
    /// Per-layer parameter counts.
    pub layer_p: Vec<usize>,
    pub t: usize,
    pub layers: usize,
    pub omega_tilde: f64,
    pub alpha_tilde: f64,
    pub beta_tilde: f64,
}

impl CostParams {
    /// `Σ_l n_l (n_l + n_{l-1})·P` — the fully dense influence gather
    /// volume at the *full* column width, which is what [`crate::rtrl::DenseRtrl`]
    /// actually performs and charges at every layer.
    fn full_volume(&self) -> f64 {
        let n = self.n as f64;
        let mut rows = 0.0;
        for l in 0..self.layers {
            let nprev = if l == 0 { 0.0 } else { n };
            rows += n * (n + nprev);
        }
        rows * self.p as f64
    }

    /// `Σ_l n_l (n_l + n_{l-1})·cols(l)` — the block-structured gather
    /// volume, where `cols(l)` is layer `l`'s nested panel width
    /// `Σ_{m≤l} p_m`. This is what the sparse engine's storage exposes:
    /// strictly below [`Self::full_volume`] at depth ≥ 2 because the
    /// cross-layer zero blocks are never touched.
    fn block_volume(&self) -> f64 {
        let n = self.n as f64;
        let mut vol = 0.0;
        let mut cum_p = 0.0;
        for l in 0..self.layers {
            cum_p += self.layer_p[l] as f64;
            let nprev = if l == 0 { 0.0 } else { n };
            vol += n * (n + nprev) * cum_p;
        }
        vol
    }

    /// `Σ_l n_l · cols(l)` — one block-triangular panel's size.
    fn panel_words(&self) -> f64 {
        let n = self.n as f64;
        let mut words = 0.0;
        let mut cum_p = 0.0;
        for l in 0..self.layers {
            cum_p += self.layer_p[l] as f64;
            words += n * cum_p;
        }
        words
    }

    /// Analytic time-per-step (second term of Table 1, the influence update)
    /// for a method, in MACs. At depth 1 these are exactly the paper's
    /// factors; for deeper stacks the dense row keeps the full `Σ n(n+n')·P`
    /// volume its engine pays, while the exact sparse rows scale the
    /// *block* volume — at depth ≥ 2 they beat dense even at ω̃ = β̃ = 1,
    /// because exploiting the architectural block structure alone already
    /// skips the cross-layer zero blocks.
    pub fn analytic_influence(&self, kind: AlgorithmKind) -> f64 {
        let (n, p) = (self.n as f64, self.p as f64);
        let (w, b) = (self.omega_tilde, self.beta_tilde);
        let nn = self.layers as f64 * n * n; // Σ_l own-block J volume
        let block = self.block_volume();
        match kind {
            AlgorithmKind::Bptt => nn + p,
            AlgorithmKind::RtrlDense => self.full_volume(),
            AlgorithmKind::RtrlParam => w * w * block,
            AlgorithmKind::RtrlActivity => b * b * block,
            AlgorithmKind::RtrlBoth => w * w * b * b * block,
            AlgorithmKind::Snap1 => w * p,
            AlgorithmKind::Snap2 => w * w * w * nn * p / self.layers as f64,
            AlgorithmKind::Uoro => w * nn + p,
        }
    }

    /// Analytic memory (Table 1 memory column), in words. The dense row
    /// holds the full `N×P` matrix; exact sparse rows scale with the
    /// block-triangular panel size `Σ_l n·cols(l)`.
    pub fn analytic_memory(&self, kind: AlgorithmKind) -> f64 {
        let (p, t) = (self.p as f64, self.t as f64);
        let big_n = (self.layers * self.n) as f64;
        let (w, b, a) = (self.omega_tilde, self.beta_tilde, self.alpha_tilde);
        let panel = self.panel_words();
        match kind {
            AlgorithmKind::Bptt => t * big_n + p,
            AlgorithmKind::RtrlDense => big_n + big_n * p,
            AlgorithmKind::RtrlParam => big_n + w * panel,
            AlgorithmKind::RtrlActivity => a * big_n + b * panel,
            AlgorithmKind::RtrlBoth => a * big_n + w * b * panel,
            AlgorithmKind::Snap1 => big_n + w * p,
            AlgorithmKind::Snap2 => big_n + w * w * panel,
            AlgorithmKind::Uoro => big_n + 2.0 * p,
        }
    }
}

/// Measurement of one engine on one stack.
pub struct Measured {
    pub influence_macs_per_step: u64,
    pub total_macs_per_step: u64,
    pub memory_words: usize,
    pub alpha_tilde: f64,
    pub beta_tilde: f64,
    pub per_layer_influence_macs: Vec<u64>,
    pub per_layer_words: Vec<u64>,
}

/// Measure one engine for `steps` timesteps on a fixed random input stream.
pub fn measure(kind: AlgorithmKind, net: &LayerStack, steps: usize, seed: u64) -> Measured {
    let mut rng = Pcg64::new(seed);
    let mut readout = Readout::new(2, net.top_n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut eng = build_engine(kind, net, 2);
    let mut ops = OpCounter::new();
    eng.begin_sequence();
    let mut alpha_sum = 0.0f64;
    let mut beta_sum = 0.0f64;
    let big_n = net.total_units() as f64;
    for t in 0..steps {
        let x = [rng.normal(), rng.normal()];
        let target = if t + 1 == steps { Target::Class(0) } else { Target::None };
        let r = eng.step(net, &mut readout, &mut loss, &x, target, &mut ops);
        alpha_sum += r.active_units as f64 / big_n;
        beta_sum += r.deriv_units as f64 / big_n;
    }
    eng.end_sequence(net, &mut readout, &mut ops);
    // "time per step", second term of Table 1: everything that touches the
    // influence/credit machinery. For RTRL engines this is dominated by the
    // J·M recursion; for BPTT it is the reverse pass (GradCombine).
    let influence_phases = [Phase::InfluenceUpdate, Phase::Jacobian, Phase::GradCombine];
    let influence: u64 =
        influence_phases.iter().map(|&ph| ops.macs_in(ph)).sum::<u64>() / steps as u64;
    let per_layer_influence_macs: Vec<u64> = (0..net.layers())
        .map(|l| {
            influence_phases.iter().map(|&ph| ops.macs_in_layer(l, ph)).sum::<u64>()
                / steps as u64
        })
        .collect();
    let per_layer_words: Vec<u64> =
        (0..net.layers()).map(|l| ops.layer_total_words(l) / steps as u64).collect();
    Measured {
        influence_macs_per_step: influence,
        total_macs_per_step: ops.total_macs() / steps as u64,
        memory_words: eng.state_memory_words(),
        alpha_tilde: alpha_sum / steps as f64,
        beta_tilde: beta_sum / steps as f64,
        per_layer_influence_macs,
        per_layer_words,
    }
}

/// Build a uniform EGRU stack for the table.
fn table_stack(n: usize, layers: usize, omega: f32, rng: &mut Pcg64) -> LayerStack {
    let mut cells = Vec::with_capacity(layers);
    for l in 0..layers {
        let n_in = if l == 0 { 2 } else { n };
        let mask = if omega > 0.0 {
            Some(MaskPattern::random(n, n, 1.0 - omega, rng))
        } else {
            None
        };
        cells.push(RnnCell::egru(n, n_in, 0.1, 0.3, 0.5, mask, rng));
    }
    LayerStack::new(cells)
}

/// Build the full table for given `n`, depth, ω and number of steps.
pub fn build(n: usize, layers: usize, omega: f32, steps: usize) -> (CostParams, Vec<Row>) {
    let mut rng = Pcg64::new(7);
    let net = table_stack(n, layers, omega, &mut rng);
    // measure α̃/β̃ once from the dense run (identical across engines)
    let base = measure(AlgorithmKind::RtrlDense, &net, steps, 99);
    let params = CostParams {
        n,
        p: net.p(),
        layer_p: (0..layers).map(|l| net.layer(l).p()).collect(),
        t: steps,
        layers,
        omega_tilde: net.omega_tilde() as f64,
        alpha_tilde: base.alpha_tilde,
        beta_tilde: base.beta_tilde,
    };
    let mut rows = Vec::new();
    for kind in AlgorithmKind::all() {
        let m = measure(kind, &net, steps, 99);
        rows.push(Row {
            method: kind.name(),
            analytic_time: format!("{:.0}", params.analytic_influence(kind)),
            analytic_memory: format!("{:.0}", params.analytic_memory(kind)),
            measured_influence_macs: m.influence_macs_per_step,
            measured_total_macs: m.total_macs_per_step,
            measured_memory_words: m.memory_words,
            per_layer_influence_macs: m.per_layer_influence_macs,
            per_layer_words: m.per_layer_words,
        });
    }
    (params, rows)
}

/// Formatted text table.
pub fn render(n: usize, layers: usize, omega: f32, steps: usize) -> String {
    let (p, rows) = build(n, layers, omega, steps);
    let mut s = format!(
        "Table 1 (measured): n={}×L{} P={} T={} ω̃={:.2} α̃={:.2} β̃={:.2}\n",
        p.n, p.layers, p.p, p.t, p.omega_tilde, p.alpha_tilde, p.beta_tilde
    );
    s.push_str(&format!(
        "{:<15}{:>18}{:>18}{:>14}{:>18}{:>14}\n",
        "method", "analytic t/step", "measured MACs/st", "ratio", "analytic memory", "measured mem"
    ));
    for r in &rows {
        let analytic: f64 = r.analytic_time.parse().unwrap_or(1.0);
        let ratio = r.measured_influence_macs as f64 / analytic.max(1.0);
        s.push_str(&format!(
            "{:<15}{:>18}{:>18}{:>14.2}{:>18}{:>14}\n",
            r.method,
            r.analytic_time,
            r.measured_influence_macs,
            ratio,
            r.analytic_memory,
            r.measured_memory_words
        ));
    }
    if layers > 1 {
        s.push_str(&format!(
            "\nPer-layer influence MACs/step (block panels; layer l tracks cols of layers 0..=l):\n{:<15}",
            "method"
        ));
        for l in 0..layers {
            s.push_str(&format!("{:>14}", format!("layer {l}")));
        }
        s.push('\n');
        for r in &rows {
            s.push_str(&format!("{:<15}", r.method));
            for &m in &r.per_layer_influence_macs {
                s.push_str(&format!("{m:>14}"));
            }
            s.push('\n');
        }
        s.push_str(&format!("\nPer-layer words/step:\n{:<15}", "method"));
        for l in 0..layers {
            s.push_str(&format!("{:>14}", format!("layer {l}")));
        }
        s.push('\n');
        for r in &rows {
            s.push_str(&format!("{:<15}", r.method));
            for &w in &r.per_layer_words {
                s.push_str(&format!("{w:>14}"));
            }
            s.push('\n');
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_methods_measured_cheaper_than_dense() {
        let (_, rows) = build(16, 1, 0.8, 8);
        let get = |name: &str| {
            rows.iter().find(|r| r.method == name).unwrap().measured_influence_macs
        };
        let dense = get("rtrl-dense");
        assert!(get("rtrl-activity") < dense);
        assert!(get("rtrl-param") < dense);
        assert!(get("rtrl-both") < get("rtrl-activity"));
        assert!(get("rtrl-both") < get("rtrl-param"));
        assert!(get("snap1") < get("rtrl-both"));
    }

    #[test]
    fn analytic_formulas_match_paper_at_unity() {
        // with ω̃=β̃=α̃=1 the sparse rows collapse to dense RTRL
        let p = CostParams {
            n: 16,
            p: 608,
            layer_p: vec![608],
            t: 17,
            layers: 1,
            omega_tilde: 1.0,
            alpha_tilde: 1.0,
            beta_tilde: 1.0,
        };
        let dense = p.analytic_influence(AlgorithmKind::RtrlDense);
        // at depth 1 the block volume is the paper's n²p
        assert_eq!(dense, 16.0 * 16.0 * 608.0);
        for kind in [AlgorithmKind::RtrlParam, AlgorithmKind::RtrlActivity, AlgorithmKind::RtrlBoth] {
            assert_eq!(p.analytic_influence(kind), dense);
        }
        // at depth 2, even at unity sparsity, the block rows undercut dense:
        // the dense engine charges full P at every layer, the block engine
        // only each panel's nested width — matching the measured engines
        let p2 = CostParams {
            n: 16,
            p: 608 + 1056,
            layer_p: vec![608, 1056],
            t: 17,
            layers: 2,
            omega_tilde: 1.0,
            alpha_tilde: 1.0,
            beta_tilde: 1.0,
        };
        assert!(
            p2.analytic_influence(AlgorithmKind::RtrlBoth)
                < p2.analytic_influence(AlgorithmKind::RtrlDense)
        );
    }

    #[test]
    fn render_contains_all_methods() {
        let s = render(8, 1, 0.5, 4);
        for m in ["bptt", "rtrl-dense", "rtrl-both", "snap1", "snap2"] {
            assert!(s.contains(m), "missing {m}");
        }
    }

    /// Depth 2: the per-layer breakdown is emitted and shows layer 0's
    /// panel (own columns only) costing less than layer 1's (which tracks
    /// both layers' columns) for the exact sparse engine.
    #[test]
    fn depth2_reports_per_layer_rows() {
        let (_, rows) = build(8, 2, 0.5, 4);
        let both = rows.iter().find(|r| r.method == "rtrl-both").unwrap();
        assert_eq!(both.per_layer_influence_macs.len(), 2);
        assert!(both.per_layer_influence_macs[1] > 0);
        assert!(
            both.per_layer_influence_macs[0] < both.per_layer_influence_macs[1],
            "layer 0 ({}) should be cheaper than layer 1 ({}): narrower panel",
            both.per_layer_influence_macs[0],
            both.per_layer_influence_macs[1]
        );
        let s = render(8, 2, 0.5, 4);
        assert!(s.contains("Per-layer influence MACs/step"));
        assert!(s.contains("layer 1"));
    }
}
