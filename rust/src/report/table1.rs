//! Table 1 regeneration: memory and time-per-step costs for every method,
//! both **analytic** (the paper's factors, instantiated with measured
//! α/β/ω̃) and **measured** (actual MACs and state words from running each
//! engine one step on the same cell and input).

use crate::config::AlgorithmKind;
use crate::metrics::{OpCounter, Phase};
use crate::nn::{Loss, LossKind, Readout, RnnCell};
use crate::rtrl::{GradientEngine, Target};
use crate::sparse::MaskPattern;
use crate::train::build_engine;
use crate::util::Pcg64;

/// One measured row of the table.
#[derive(Debug, Clone)]
pub struct Row {
    pub method: &'static str,
    pub analytic_time: String,
    pub analytic_memory: String,
    pub measured_influence_macs: u64,
    pub measured_total_macs: u64,
    pub measured_memory_words: usize,
}

/// Cost-model parameters extracted from a run.
#[derive(Debug, Clone, Copy)]
pub struct CostParams {
    pub n: usize,
    pub p: usize,
    pub t: usize,
    pub omega_tilde: f64,
    pub alpha_tilde: f64,
    pub beta_tilde: f64,
}

impl CostParams {
    /// Analytic time-per-step (second term of Table 1, the influence update)
    /// for a method, in MACs.
    pub fn analytic_influence(&self, kind: AlgorithmKind) -> f64 {
        let (n, p) = (self.n as f64, self.p as f64);
        let (w, b) = (self.omega_tilde, self.beta_tilde);
        match kind {
            AlgorithmKind::Bptt => n * n + p,
            AlgorithmKind::RtrlDense => n * n * p,
            AlgorithmKind::RtrlParam => w * w * n * n * p,
            AlgorithmKind::RtrlActivity => b * b * n * n * p,
            AlgorithmKind::RtrlBoth => w * w * b * b * n * n * p,
            AlgorithmKind::Snap1 => w * p,
            AlgorithmKind::Snap2 => w * w * w * n * n * p,
            AlgorithmKind::Uoro => w * n * n + p,
        }
    }

    /// Analytic memory (Table 1 memory column), in words.
    pub fn analytic_memory(&self, kind: AlgorithmKind) -> f64 {
        let (n, p, t) = (self.n as f64, self.p as f64, self.t as f64);
        let (w, b, a) = (self.omega_tilde, self.beta_tilde, self.alpha_tilde);
        match kind {
            AlgorithmKind::Bptt => t * n + p,
            AlgorithmKind::RtrlDense => n + n * p,
            AlgorithmKind::RtrlParam => n + w * n * p,
            AlgorithmKind::RtrlActivity => a * n + b * n * p,
            AlgorithmKind::RtrlBoth => a * n + w * b * n * p,
            AlgorithmKind::Snap1 => n + w * p,
            AlgorithmKind::Snap2 => n + w * w * n * p,
            AlgorithmKind::Uoro => n + 2.0 * p,
        }
    }
}

/// Measure one engine for `steps` timesteps on a fixed random input stream.
pub fn measure(
    kind: AlgorithmKind,
    cell: &RnnCell,
    steps: usize,
    seed: u64,
) -> (u64, u64, usize, f64, f64) {
    let mut rng = Pcg64::new(seed);
    let mut readout = Readout::new(2, cell.n(), &mut rng);
    let mut loss = Loss::new(LossKind::CrossEntropy, 2);
    let mut eng = build_engine(kind, cell, 2);
    let mut ops = OpCounter::new();
    eng.begin_sequence();
    let mut alpha_sum = 0.0f64;
    let mut beta_sum = 0.0f64;
    for t in 0..steps {
        let x = [rng.normal(), rng.normal()];
        let target = if t + 1 == steps { Target::Class(0) } else { Target::None };
        let r = eng.step(cell, &mut readout, &mut loss, &x, target, &mut ops);
        alpha_sum += r.active_units as f64 / cell.n() as f64;
        beta_sum += r.deriv_units as f64 / cell.n() as f64;
    }
    eng.end_sequence(cell, &mut readout, &mut ops);
    // "time per step", second term of Table 1: everything that touches the
    // influence/credit machinery. For RTRL engines this is dominated by the
    // J·M recursion; for BPTT it is the reverse pass (GradCombine).
    let influence = (ops.macs_in(Phase::InfluenceUpdate)
        + ops.macs_in(Phase::Jacobian)
        + ops.macs_in(Phase::GradCombine))
        / steps as u64;
    let total = ops.total_macs() / steps as u64;
    (
        influence,
        total,
        eng.state_memory_words(),
        alpha_sum / steps as f64,
        beta_sum / steps as f64,
    )
}

/// Build the full table for given `n`, ω and number of steps.
pub fn build(n: usize, omega: f32, steps: usize) -> (CostParams, Vec<Row>) {
    let mut rng = Pcg64::new(7);
    let mask = if omega > 0.0 {
        Some(MaskPattern::random(n, n, 1.0 - omega, &mut rng))
    } else {
        None
    };
    let cell = RnnCell::egru(n, 2, 0.1, 0.3, 0.5, mask, &mut rng);
    // measure α̃/β̃ once from the dense run (identical across engines)
    let (_, _, _, at, bt) = measure(AlgorithmKind::RtrlDense, &cell, steps, 99);
    let params = CostParams {
        n,
        p: cell.p(),
        t: steps,
        omega_tilde: cell.omega_tilde() as f64,
        alpha_tilde: at,
        beta_tilde: bt,
    };
    let mut rows = Vec::new();
    for kind in AlgorithmKind::all() {
        let (inf, total, mem, _, _) = measure(kind, &cell, steps, 99);
        rows.push(Row {
            method: kind.name(),
            analytic_time: format!("{:.0}", params.analytic_influence(kind)),
            analytic_memory: format!("{:.0}", params.analytic_memory(kind)),
            measured_influence_macs: inf,
            measured_total_macs: total,
            measured_memory_words: mem,
        });
    }
    (params, rows)
}

/// Formatted text table.
pub fn render(n: usize, omega: f32, steps: usize) -> String {
    let (p, rows) = build(n, omega, steps);
    let mut s = format!(
        "Table 1 (measured): n={} p={} T={} ω̃={:.2} α̃={:.2} β̃={:.2}\n",
        p.n, p.p, p.t, p.omega_tilde, p.alpha_tilde, p.beta_tilde
    );
    s.push_str(&format!(
        "{:<15}{:>18}{:>18}{:>14}{:>18}{:>14}\n",
        "method", "analytic t/step", "measured MACs/st", "ratio", "analytic memory", "measured mem"
    ));
    for r in &rows {
        let analytic: f64 = r.analytic_time.parse().unwrap_or(1.0);
        let ratio = r.measured_influence_macs as f64 / analytic.max(1.0);
        s.push_str(&format!(
            "{:<15}{:>18}{:>18}{:>14.2}{:>18}{:>14}\n",
            r.method,
            r.analytic_time,
            r.measured_influence_macs,
            ratio,
            r.analytic_memory,
            r.measured_memory_words
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_methods_measured_cheaper_than_dense() {
        let (_, rows) = build(16, 0.8, 8);
        let get = |name: &str| {
            rows.iter().find(|r| r.method == name).unwrap().measured_influence_macs
        };
        let dense = get("rtrl-dense");
        assert!(get("rtrl-activity") < dense);
        assert!(get("rtrl-param") < dense);
        assert!(get("rtrl-both") < get("rtrl-activity"));
        assert!(get("rtrl-both") < get("rtrl-param"));
        assert!(get("snap1") < get("rtrl-both"));
    }

    #[test]
    fn analytic_formulas_match_paper_at_unity() {
        // with ω̃=β̃=α̃=1 the sparse rows collapse to dense RTRL
        let p = CostParams { n: 16, p: 608, t: 17, omega_tilde: 1.0, alpha_tilde: 1.0, beta_tilde: 1.0 };
        let dense = p.analytic_influence(AlgorithmKind::RtrlDense);
        for kind in [AlgorithmKind::RtrlParam, AlgorithmKind::RtrlActivity, AlgorithmKind::RtrlBoth] {
            assert_eq!(p.analytic_influence(kind), dense);
        }
    }

    #[test]
    fn render_contains_all_methods() {
        let s = render(8, 0.5, 4);
        for m in ["bptt", "rtrl-dense", "rtrl-both", "snap1", "snap2"] {
            assert!(s.contains(m), "missing {m}");
        }
    }
}
