//! Report emitters: regenerate every table and figure of the paper.

pub mod ascii_plot;
pub mod csv;
pub mod fig1;
pub mod fig2;
pub mod stats;
pub mod table1;
