//! Minimal ASCII line plots for terminal reports (Fig. 3 panels).

/// Render multiple named series into a `width × height` ASCII plot.
/// Each series is a list of `(x, y)` points; series are drawn with distinct
/// glyphs and a legend is appended.
pub fn plot(series: &[(&str, Vec<(f64, f64)>)], width: usize, height: usize, title: &str) -> String {
    const GLYPHS: [char; 8] = ['o', '+', 'x', '*', '#', '@', '%', '&'];
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let pts: Vec<(f64, f64)> = series.iter().flat_map(|(_, p)| p.iter().copied()).collect();
    if pts.is_empty() {
        out.push_str("(no data)\n");
        return out;
    }
    let (mut xmin, mut xmax, mut ymin, mut ymax) =
        (f64::INFINITY, f64::NEG_INFINITY, f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, p)) in series.iter().enumerate() {
        let g = GLYPHS[si % GLYPHS.len()];
        for &(x, y) in p {
            let cx = (((x - xmin) / (xmax - xmin)) * (width - 1) as f64).round() as usize;
            let cy = (((y - ymin) / (ymax - ymin)) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = g;
        }
    }
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:>9.3} |")
        } else if i == height - 1 {
            format!("{ymin:>9.3} |")
        } else {
            format!("{:>9} |", "")
        };
        out.push_str(&label);
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!(
        "{:>9}  {}\n{:>9}  {:<.3} .. {:<.3}\n",
        "", "-".repeat(width), "x:", xmin, xmax
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("   {} = {}\n", GLYPHS[si % GLYPHS.len()], name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points_within_bounds() {
        let s = vec![("a", vec![(0.0, 0.0), (1.0, 1.0)]), ("b", vec![(0.5, 0.5)])];
        let p = plot(&s, 20, 10, "test");
        assert!(p.contains("== test =="));
        assert!(p.contains('o'));
        assert!(p.contains('+'));
        assert!(p.contains("a"));
    }

    #[test]
    fn empty_series_no_panic() {
        let p = plot(&[], 10, 5, "empty");
        assert!(p.contains("no data"));
    }

    #[test]
    fn constant_series_no_panic() {
        let s = vec![("c", vec![(1.0, 2.0), (1.0, 2.0)])];
        let p = plot(&s, 10, 5, "const");
        assert!(p.contains('o'));
    }
}
