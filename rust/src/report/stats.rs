//! Render telemetry artifacts for the terminal: a
//! [`TelemetrySnapshot`] as a counter/session table, a parsed trace
//! (`stream --trace` output) as α/β time-series plots plus event and
//! op-rate summaries. Pure string producers — the `stats` subcommand owns
//! the I/O.

use crate::metrics::Phase;
use crate::report::ascii_plot::plot;
use crate::telemetry::names;
use crate::telemetry::{
    HistogramSummary, MemoryRecorder, MetricPoint, TelemetrySnapshot, TraceEventKind, TraceRecord,
};

const PLOT_W: usize = 64;
const PLOT_H: usize = 12;

/// Render a pool snapshot: counters, spill/latency summaries, one row per
/// session.
pub fn render_snapshot(snap: &TelemetrySnapshot) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "pool: {} live session(s), {} worker(s)\n",
        snap.live_sessions, snap.workers
    ));
    out.push_str(&format!(
        "admissions {}, evictions {}, spill {} bytes\n",
        snap.admissions, snap.evictions, snap.spill_bytes
    ));
    out.push_str(&format!(
        "evict encode ns: count {}, mean {}, p50 {}, p99 {}, max {}\n",
        snap.evict_encode_ns.count,
        snap.evict_encode_ns.mean(),
        snap.evict_encode_ns.p50,
        snap.evict_encode_ns.p99,
        snap.evict_encode_ns.max
    ));
    out.push_str(&format!(
        "resume decode ns: count {}, mean {}, p50 {}, p99 {}, max {}\n",
        snap.resume_decode_ns.count,
        snap.resume_decode_ns.mean(),
        snap.resume_decode_ns.p50,
        snap.resume_decode_ns.p99,
        snap.resume_decode_ns.max
    ));
    out.push_str(&format!(
        "{:>7} {:>9} {:>10} {:>8} {:>10} {:>7} {:>7} {:>7}\n",
        "session", "steps", "supervised", "updates", "loss_ewma", "alpha", "beta", "points"
    ));
    for s in &snap.sessions {
        let fmt_opt = |x: Option<f32>| match x {
            Some(v) => format!("{v:.4}"),
            None => "-".to_string(),
        };
        out.push_str(&format!(
            "{:>7} {:>9} {:>10} {:>8} {:>10} {:>7} {:>7} {:>7}\n",
            s.index,
            s.steps,
            s.supervised_steps,
            s.updates_applied,
            fmt_opt(s.loss_ewma),
            fmt_opt(s.alpha),
            fmt_opt(s.beta),
            s.points
        ));
    }
    out
}

/// Render the serve-loop shutdown summary: rounds/events, the fused-vs-solo
/// lane-step split, per-lane-step latency quantiles, then the pool snapshot
/// table (residency churn + per-session rows) via [`render_snapshot`].
pub fn render_serve_summary(
    snap: &TelemetrySnapshot,
    rec: &MemoryRecorder,
    rounds: u64,
) -> String {
    let mut out = String::new();
    let events = rec.counter_value(names::SERVE_EVENTS);
    let fused = rec.counter_value(names::SERVE_FUSED_STEPS);
    let solo = rec.counter_value(names::SERVE_SOLO_STEPS);
    let lane_steps = fused + solo;
    out.push_str(&format!("serve: {rounds} round(s), {events} event(s) applied\n"));
    out.push_str(&format!(
        "lane-steps: {lane_steps} ({fused} fused, {solo} solo, {:.1}% fused)\n",
        100.0 * fused as f64 / lane_steps.max(1) as f64
    ));
    if let Some(h) = rec.histogram(names::SERVE_STEP_NS) {
        let s = HistogramSummary::from_histogram(h);
        out.push_str(&format!(
            "lane-step latency ns: count {}, mean {}, p50 {}, p99 {}, max {}\n",
            s.count,
            s.mean(),
            s.p50,
            s.p99,
            s.max
        ));
    }
    out.push_str(&render_snapshot(snap));
    out
}

fn series(points: &[&MetricPoint], f: impl Fn(&MetricPoint) -> Option<f32>) -> Vec<(f64, f64)> {
    points
        .iter()
        .filter_map(|p| f(p).map(|y| (p.step as f64, y as f64)))
        .collect()
}

/// Render a parsed trace: header, α/β/β̃ plot over the stream, loss-EWMA
/// plot when supervised steps occurred, event tallies and the last
/// window's per-phase MAC rates.
pub fn render_trace(records: &[TraceRecord]) -> String {
    let mut out = String::new();
    let mut points: Vec<&MetricPoint> = Vec::new();
    let mut event_counts = [0u64; 5];
    let event_kinds = [
        TraceEventKind::Update,
        TraceEventKind::SequenceEnd,
        TraceEventKind::Checkpoint,
        TraceEventKind::Evict,
        TraceEventKind::Admit,
    ];
    let mut span_ns = 0u64;
    for rec in records {
        match rec {
            TraceRecord::Meta { session, engine, hidden, layers, sample_every } => {
                out.push_str(&format!(
                    "trace: {} record(s), session {session:?} \
                     (engine {engine}, n={hidden}×L{layers}, sample_every {sample_every})\n",
                    records.len()
                ));
            }
            TraceRecord::Metrics { point, .. } => points.push(point),
            TraceRecord::Span { duration_ns, .. } => span_ns += duration_ns,
            TraceRecord::Event { event, .. } => {
                event_counts[event_kinds.iter().position(|k| k == event).unwrap()] += 1;
            }
        }
    }
    let sparsity = [
        ("alpha", series(&points, |p| Some(p.alpha))),
        ("beta", series(&points, |p| Some(p.beta))),
        ("beta_tilde", series(&points, |p| Some(p.beta_tilde))),
    ];
    out.push_str(&plot(&sparsity, PLOT_W, PLOT_H, "sparsity per window (x = step)"));
    let loss = series(&points, |p| p.loss_ewma);
    if !loss.is_empty() {
        out.push_str(&plot(&[("loss_ewma", loss)], PLOT_W, PLOT_H, "loss EWMA (x = step)"));
    }
    if let (Some(first), Some(last)) = (points.first(), points.last()) {
        let steps: u64 = points.iter().map(|p| p.window_len()).sum();
        let latency: u64 = points.iter().map(|p| p.window_latency_ns).sum();
        out.push_str(&format!(
            "windows: {} (steps {}..={}), {} ns in step spans, \
             mean step latency {} ns\n",
            points.len(),
            first.window_start,
            last.step,
            span_ns,
            latency / steps.max(1)
        ));
        out.push_str("last window MACs/step:");
        for ph in Phase::all() {
            out.push_str(&format!(" {} {}", ph.name(), last.macs_per_step[ph.index()]));
        }
        out.push('\n');
    } else {
        out.push_str("windows: 0 (no metrics records — stream shorter than the cadence?)\n");
    }
    out.push_str("events:");
    for (kind, count) in event_kinds.iter().zip(event_counts.iter()) {
        out.push_str(&format!(" {} ×{}", kind.name(), count));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::NUM_PHASES;
    use crate::telemetry::{HistogramSummary, SessionStats};

    fn point(start: u64, end: u64, alpha: f32, loss: Option<f32>) -> MetricPoint {
        MetricPoint {
            window_start: start,
            step: end,
            alpha,
            beta: 0.75,
            beta_tilde: 0.25,
            influence_occupancy: Some(0.5),
            loss_ewma: loss,
            macs_per_step: [7; NUM_PHASES],
            words_per_step: [3; NUM_PHASES],
            window_latency_ns: 4_000,
        }
    }

    #[test]
    fn trace_rendering_mentions_series_and_events() {
        let records = vec![
            TraceRecord::Meta {
                session: "s0".into(),
                engine: "rtrl-both".into(),
                hidden: 32,
                layers: 1,
                sample_every: 4,
            },
            TraceRecord::Metrics { session: "s0".into(), point: point(1, 4, 0.5, None) },
            TraceRecord::Span {
                session: "s0".into(),
                phase: "steps".into(),
                step_start: 1,
                step_end: 4,
                duration_ns: 4_000,
            },
            TraceRecord::Metrics { session: "s0".into(), point: point(5, 8, 0.6, Some(1.25)) },
            TraceRecord::Event {
                session: "s0".into(),
                step: 8,
                event: TraceEventKind::Update,
                bytes: None,
                duration_ns: None,
            },
        ];
        let r = render_trace(&records);
        assert!(r.contains("session \"s0\""), "{r}");
        assert!(r.contains("alpha"), "{r}");
        assert!(r.contains("beta_tilde"), "{r}");
        assert!(r.contains("loss EWMA"), "{r}");
        assert!(r.contains("windows: 2 (steps 1..=8)"), "{r}");
        assert!(r.contains("update ×1"), "{r}");
        assert!(r.contains("evict ×0"), "{r}");
        assert!(r.contains("influence_update 7"), "{r}");
    }

    #[test]
    fn empty_trace_renders_without_panicking() {
        let records = vec![TraceRecord::Meta {
            session: "s0".into(),
            engine: "bptt".into(),
            hidden: 8,
            layers: 2,
            sample_every: 16,
        }];
        let r = render_trace(&records);
        assert!(r.contains("windows: 0"), "{r}");
    }

    #[test]
    fn serve_summary_reports_split_latency_and_pool() {
        use crate::telemetry::{HistogramKind, Recorder};
        let mut rec = MemoryRecorder::new();
        rec.counter(names::SERVE_EVENTS, 10);
        rec.counter(names::SERVE_FUSED_STEPS, 6);
        rec.counter(names::SERVE_SOLO_STEPS, 2);
        for ns in [100, 200, 300, 400] {
            rec.observe(names::SERVE_STEP_NS, HistogramKind::LatencyNs, ns);
        }
        let snap = TelemetrySnapshot { live_sessions: 3, ..TelemetrySnapshot::default() };
        let r = render_serve_summary(&snap, &rec, 4);
        assert!(r.contains("serve: 4 round(s), 10 event(s) applied"), "{r}");
        assert!(r.contains("lane-steps: 8 (6 fused, 2 solo, 75.0% fused)"), "{r}");
        assert!(r.contains("lane-step latency ns: count 4"), "{r}");
        assert!(r.contains("3 live session(s)"), "{r}");
    }

    #[test]
    fn snapshot_rendering_tabulates_sessions() {
        let snap = TelemetrySnapshot {
            live_sessions: 2,
            workers: 4,
            admissions: 1,
            evictions: 3,
            spill_bytes: 6_144,
            evict_encode_ns: HistogramSummary {
                count: 3,
                sum: 30,
                min: 5,
                max: 15,
                p50: 10,
                p99: 15,
            },
            resume_decode_ns: HistogramSummary::default(),
            sessions: vec![SessionStats {
                index: 0,
                steps: 100,
                supervised_steps: 30,
                updates_applied: 30,
                loss_ewma: Some(0.625),
                alpha: Some(0.5),
                beta: None,
                points: 6,
            }],
        };
        let r = render_snapshot(&snap);
        assert!(r.contains("2 live session(s)"), "{r}");
        assert!(r.contains("evictions 3"), "{r}");
        assert!(r.contains("spill 6144 bytes"), "{r}");
        assert!(r.contains("0.6250"), "{r}");
        assert!(r.contains(" - "), "{r}"); // absent beta renders as a dash
    }
}
