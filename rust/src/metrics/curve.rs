//! Learning-curve records: the per-iteration rows behind Fig. 3's panels.
//!
//! This module only *carries* per-iteration values; cross-seed aggregation
//! (mean curves over sweep members) lives in [`crate::coordinator::sweep`]
//! and runs through the pinned-order reducers in [`crate::util::math`]
//! (`mean` / `mean_f64`), so summary statistics are bit-reproducible like
//! everything else.

/// One logged training iteration.
#[derive(Debug, Clone)]
pub struct CurvePoint {
    /// Parameter-update iteration index (Fig. 3A/E x-axis).
    pub iteration: u64,
    /// Cumulative compute-adjusted iteration (Fig. 3B/F x-axis).
    pub compute_adjusted: f64,
    /// Mean training loss over the batch.
    pub loss: f32,
    /// Training accuracy over the batch.
    pub accuracy: f32,
    /// Validation accuracy (if evaluated this iteration).
    pub val_accuracy: Option<f32>,
    /// Mean activation sparsity α this iteration (Fig. 3C).
    pub alpha: f32,
    /// Mean pseudo-derivative sparsity β this iteration (Fig. 3C).
    pub beta: f32,
    /// Mean influence-matrix sparsity this iteration (Fig. 3D).
    pub influence_sparsity: f32,
    /// Measured MACs spent on the influence update this iteration.
    pub influence_macs: u64,
}

/// A full learning curve for one run.
#[derive(Debug, Clone, Default)]
pub struct Curve {
    pub points: Vec<CurvePoint>,
}

impl Curve {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, p: CurvePoint) {
        self.points.push(p);
    }

    /// Final validation accuracy (or final train accuracy if never evaluated).
    pub fn final_accuracy(&self) -> f32 {
        self.points
            .iter()
            .rev()
            .find_map(|p| p.val_accuracy)
            .or_else(|| self.points.last().map(|p| p.accuracy))
            .unwrap_or(0.0)
    }

    /// First iteration at which val accuracy reached `threshold` (Fig. 3B's
    /// "converges with the least total compute" comparison), in
    /// compute-adjusted units. `None` if never reached.
    pub fn compute_to_accuracy(&self, threshold: f32) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.val_accuracy.unwrap_or(0.0) >= threshold)
            .map(|p| p.compute_adjusted)
    }

    /// CSV serialization (one row per point), with header.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "iteration,compute_adjusted,loss,accuracy,val_accuracy,alpha,beta,influence_sparsity,influence_macs\n",
        );
        for p in &self.points {
            s.push_str(&format!(
                "{},{:.6},{:.6},{:.4},{},{:.4},{:.4},{:.4},{}\n",
                p.iteration,
                p.compute_adjusted,
                p.loss,
                p.accuracy,
                p.val_accuracy.map(|v| format!("{v:.4}")).unwrap_or_default(),
                p.alpha,
                p.beta,
                p.influence_sparsity,
                p.influence_macs,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(iter: u64, ca: f64, val: Option<f32>) -> CurvePoint {
        CurvePoint {
            iteration: iter,
            compute_adjusted: ca,
            loss: 1.0,
            accuracy: 0.5,
            val_accuracy: val,
            alpha: 0.0,
            beta: 0.0,
            influence_sparsity: 0.0,
            influence_macs: 0,
        }
    }

    #[test]
    fn compute_to_accuracy_finds_first() {
        let mut c = Curve::new();
        c.push(pt(0, 0.1, Some(0.5)));
        c.push(pt(1, 0.2, Some(0.91)));
        c.push(pt(2, 0.3, Some(0.95)));
        assert_eq!(c.compute_to_accuracy(0.9), Some(0.2));
        assert_eq!(c.compute_to_accuracy(0.99), None);
    }

    #[test]
    fn final_accuracy_prefers_val() {
        let mut c = Curve::new();
        c.push(pt(0, 0.0, Some(0.8)));
        c.push(pt(1, 0.0, None));
        assert_eq!(c.final_accuracy(), 0.8);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut c = Curve::new();
        c.push(pt(0, 0.0, None));
        let csv = c.to_csv();
        assert!(csv.starts_with("iteration,"));
        assert_eq!(csv.lines().count(), 2);
    }
}
