//! The paper's "compute-adjusted iteration" (Fig. 3B/F): a cumulative sum of
//! the per-iteration computational-savings factor `ω̃²β̃²` (or `ω̃²` when
//! activity sparsity is off), measured from the *actual* β̃ of each batch.

/// Running compute-adjusted iteration counter.
#[derive(Debug, Clone)]
pub struct ComputeAdjusted {
    /// Parameter density ω̃ (fixed at init).
    omega_tilde: f64,
    /// Whether the network is activity sparse (β̃ < 1 possible).
    activity_sparse: bool,
    /// Cumulative Σ ω̃²β̃² over iterations.
    cumulative: f64,
}

impl ComputeAdjusted {
    pub fn new(omega_tilde: f32, activity_sparse: bool) -> Self {
        assert!((0.0..=1.0).contains(&omega_tilde));
        ComputeAdjusted { omega_tilde: omega_tilde as f64, activity_sparse, cumulative: 0.0 }
    }

    /// Fold one iteration with measured backward density `beta_tilde`
    /// (ignored when activity sparsity is off, matching the paper's ω̃²-only
    /// factor for the dense-activity arm). Returns the new cumulative value.
    pub fn record_iteration(&mut self, beta_tilde: f32) -> f64 {
        let factor = if self.activity_sparse {
            let bt = beta_tilde as f64;
            self.omega_tilde * self.omega_tilde * bt * bt
        } else {
            self.omega_tilde * self.omega_tilde
        };
        self.cumulative += factor;
        self.cumulative
    }

    /// Current cumulative compute-adjusted iteration count.
    pub fn value(&self) -> f64 {
        self.cumulative
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_counts_plain_iterations() {
        let mut c = ComputeAdjusted::new(1.0, false);
        for _ in 0..5 {
            c.record_iteration(0.5);
        }
        assert!((c.value() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn paper_worked_example() {
        // §1: β̃=0.5, ω̃=0.2 → factor 0.2²·0.5² = 0.01 (1% of dense ops).
        let mut c = ComputeAdjusted::new(0.2, true);
        c.record_iteration(0.5);
        assert!((c.value() - 0.01).abs() < 1e-9);
    }

    #[test]
    fn activity_only() {
        // β̃=0.5, ω̃=1 → 0.25 per iteration (§1: "25% of the operations").
        let mut c = ComputeAdjusted::new(1.0, true);
        c.record_iteration(0.5);
        c.record_iteration(0.5);
        assert!((c.value() - 0.5).abs() < 1e-9);
    }
}
