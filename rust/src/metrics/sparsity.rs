//! Running sparsity statistics: α (activation), β (pseudo-derivative) and
//! influence-matrix sparsity — the quantities plotted in Fig. 3C/D.

/// Accumulates per-step sparsity observations over a window (e.g. one
/// training iteration across the whole batch and sequence).
#[derive(Debug, Clone, Default)]
pub struct SparsityStats {
    /// Σ fraction of units with zero activation (α).
    alpha_sum: f64,
    /// Σ fraction of units with zero pseudo-derivative (β).
    beta_sum: f64,
    /// Σ fraction of exactly-zero influence-matrix entries.
    influence_sum: f64,
    /// Number of observations folded into α/β.
    steps: u64,
    /// Number of observations folded into the influence sparsity.
    influence_obs: u64,
}

impl SparsityStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one timestep's activity observation.
    /// `active_units` = α̃n (nonzero activations), `deriv_units` = β̃n.
    pub fn record_step(&mut self, n: usize, active_units: usize, deriv_units: usize) {
        let n = n as f64;
        self.alpha_sum += 1.0 - active_units as f64 / n;
        self.beta_sum += 1.0 - deriv_units as f64 / n;
        self.steps += 1;
    }

    /// Record an influence-matrix sparsity observation (fraction of zeros).
    pub fn record_influence(&mut self, zero_fraction: f32) {
        self.influence_sum += zero_fraction as f64;
        self.influence_obs += 1;
    }

    /// Mean activation sparsity α over the window.
    pub fn alpha(&self) -> f32 {
        if self.steps == 0 {
            0.0
        } else {
            (self.alpha_sum / self.steps as f64) as f32
        }
    }

    /// Mean derivative sparsity β over the window.
    pub fn beta(&self) -> f32 {
        if self.steps == 0 {
            0.0
        } else {
            (self.beta_sum / self.steps as f64) as f32
        }
    }

    /// Mean density of the backward pass, β̃ = 1 − β.
    pub fn beta_tilde(&self) -> f32 {
        1.0 - self.beta()
    }

    /// Mean influence-matrix sparsity over the window.
    pub fn influence_sparsity(&self) -> f32 {
        if self.influence_obs == 0 {
            0.0
        } else {
            (self.influence_sum / self.influence_obs as f64) as f32
        }
    }

    /// Number of α/β observations folded in so far.
    pub fn observations(&self) -> u64 {
        self.steps
    }

    /// Number of influence-sparsity observations folded in so far (telemetry
    /// uses this to tell "never measured" apart from "measured as 0").
    pub fn influence_observations(&self) -> u64 {
        self.influence_obs
    }

    pub fn reset(&mut self) {
        *self = Self::default();
    }

    pub fn merge(&mut self, other: &SparsityStats) {
        self.alpha_sum += other.alpha_sum;
        self.beta_sum += other.beta_sum;
        self.influence_sum += other.influence_sum;
        self.steps += other.steps;
        self.influence_obs += other.influence_obs;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_beta_means() {
        let mut s = SparsityStats::new();
        s.record_step(10, 5, 2); // α=0.5 β=0.8
        s.record_step(10, 10, 10); // α=0.0 β=0.0
        assert!((s.alpha() - 0.25).abs() < 1e-6);
        assert!((s.beta() - 0.4).abs() < 1e-6);
        assert!((s.beta_tilde() - 0.6).abs() < 1e-6);
    }

    #[test]
    fn influence_mean() {
        let mut s = SparsityStats::new();
        s.record_influence(0.9);
        s.record_influence(0.7);
        assert!((s.influence_sparsity() - 0.8).abs() < 1e-6);
    }

    #[test]
    fn empty_is_zero() {
        let s = SparsityStats::new();
        assert_eq!(s.alpha(), 0.0);
        assert_eq!(s.influence_sparsity(), 0.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = SparsityStats::new();
        a.record_step(4, 2, 2);
        let mut b = SparsityStats::new();
        b.record_step(4, 4, 4);
        a.merge(&b);
        assert!((a.alpha() - 0.25).abs() < 1e-6);
    }

    /// A reset accumulator is indistinguishable from a fresh one as a merge
    /// target: merging `b` into it reproduces `b`'s estimates exactly.
    #[test]
    fn merge_after_reset_equals_other() {
        let mut a = SparsityStats::new();
        a.record_step(10, 1, 9);
        a.record_influence(0.5);
        a.reset();
        assert_eq!(a.observations(), 0);
        assert_eq!(a.influence_observations(), 0);

        let mut b = SparsityStats::new();
        b.record_step(8, 2, 6); // α=0.75 β=0.25
        b.record_influence(0.9);
        a.merge(&b);
        assert_eq!(a.observations(), 1);
        assert_eq!(a.influence_observations(), 1);
        assert_eq!(a.alpha().to_bits(), b.alpha().to_bits());
        assert_eq!(a.beta().to_bits(), b.beta().to_bits());
        assert_eq!(a.influence_sparsity().to_bits(), b.influence_sparsity().to_bits());
    }

    /// Merging an empty counterpart is the identity on every estimate.
    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = SparsityStats::new();
        a.record_step(10, 3, 7);
        a.record_step(10, 5, 5);
        a.record_influence(0.4);
        let before = (a.alpha().to_bits(), a.beta().to_bits(), a.influence_sparsity().to_bits());
        a.merge(&SparsityStats::new());
        let after = (a.alpha().to_bits(), a.beta().to_bits(), a.influence_sparsity().to_bits());
        assert_eq!(before, after);
        assert_eq!(a.observations(), 2);
        assert_eq!(a.influence_observations(), 1);
    }

    /// α/β/influence estimates are commutative in the merge order: the sums
    /// are plain f64 additions, so `a ∪ b` and `b ∪ a` agree bit-for-bit.
    #[test]
    fn merge_is_commutative_in_estimates() {
        let mut a = SparsityStats::new();
        a.record_step(16, 3, 11);
        a.record_step(16, 7, 2);
        a.record_influence(0.25);
        let mut b = SparsityStats::new();
        b.record_step(12, 5, 5);
        b.record_influence(0.75);
        b.record_influence(0.125);

        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.alpha().to_bits(), ba.alpha().to_bits());
        assert_eq!(ab.beta().to_bits(), ba.beta().to_bits());
        assert_eq!(ab.beta_tilde().to_bits(), ba.beta_tilde().to_bits());
        assert_eq!(ab.influence_sparsity().to_bits(), ba.influence_sparsity().to_bits());
        assert_eq!(ab.observations(), ba.observations());
        assert_eq!(ab.influence_observations(), ba.influence_observations());
    }
}
