//! Running sparsity statistics: α (activation), β (pseudo-derivative) and
//! influence-matrix sparsity — the quantities plotted in Fig. 3C/D.

/// Accumulates per-step sparsity observations over a window (e.g. one
/// training iteration across the whole batch and sequence).
#[derive(Debug, Clone, Default)]
pub struct SparsityStats {
    /// Σ fraction of units with zero activation (α).
    alpha_sum: f64,
    /// Σ fraction of units with zero pseudo-derivative (β).
    beta_sum: f64,
    /// Σ fraction of exactly-zero influence-matrix entries.
    influence_sum: f64,
    /// Number of observations folded into α/β.
    steps: u64,
    /// Number of observations folded into the influence sparsity.
    influence_obs: u64,
}

impl SparsityStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one timestep's activity observation.
    /// `active_units` = α̃n (nonzero activations), `deriv_units` = β̃n.
    pub fn record_step(&mut self, n: usize, active_units: usize, deriv_units: usize) {
        let n = n as f64;
        self.alpha_sum += 1.0 - active_units as f64 / n;
        self.beta_sum += 1.0 - deriv_units as f64 / n;
        self.steps += 1;
    }

    /// Record an influence-matrix sparsity observation (fraction of zeros).
    pub fn record_influence(&mut self, zero_fraction: f32) {
        self.influence_sum += zero_fraction as f64;
        self.influence_obs += 1;
    }

    /// Mean activation sparsity α over the window.
    pub fn alpha(&self) -> f32 {
        if self.steps == 0 {
            0.0
        } else {
            (self.alpha_sum / self.steps as f64) as f32
        }
    }

    /// Mean derivative sparsity β over the window.
    pub fn beta(&self) -> f32 {
        if self.steps == 0 {
            0.0
        } else {
            (self.beta_sum / self.steps as f64) as f32
        }
    }

    /// Mean density of the backward pass, β̃ = 1 − β.
    pub fn beta_tilde(&self) -> f32 {
        1.0 - self.beta()
    }

    /// Mean influence-matrix sparsity over the window.
    pub fn influence_sparsity(&self) -> f32 {
        if self.influence_obs == 0 {
            0.0
        } else {
            (self.influence_sum / self.influence_obs as f64) as f32
        }
    }

    pub fn reset(&mut self) {
        *self = Self::default();
    }

    pub fn merge(&mut self, other: &SparsityStats) {
        self.alpha_sum += other.alpha_sum;
        self.beta_sum += other.beta_sum;
        self.influence_sum += other.influence_sum;
        self.steps += other.steps;
        self.influence_obs += other.influence_obs;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_beta_means() {
        let mut s = SparsityStats::new();
        s.record_step(10, 5, 2); // α=0.5 β=0.8
        s.record_step(10, 10, 10); // α=0.0 β=0.0
        assert!((s.alpha() - 0.25).abs() < 1e-6);
        assert!((s.beta() - 0.4).abs() < 1e-6);
        assert!((s.beta_tilde() - 0.6).abs() < 1e-6);
    }

    #[test]
    fn influence_mean() {
        let mut s = SparsityStats::new();
        s.record_influence(0.9);
        s.record_influence(0.7);
        assert!((s.influence_sparsity() - 0.8).abs() < 1e-6);
    }

    #[test]
    fn empty_is_zero() {
        let s = SparsityStats::new();
        assert_eq!(s.alpha(), 0.0);
        assert_eq!(s.influence_sparsity(), 0.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = SparsityStats::new();
        a.record_step(4, 2, 2);
        let mut b = SparsityStats::new();
        b.record_step(4, 4, 4);
        a.merge(&b);
        assert!((a.alpha() - 0.25).abs() < 1e-6);
    }
}
