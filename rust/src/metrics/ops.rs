//! Multiply-accumulate and memory-word counters, broken down by phase and
//! (optionally) by network layer.
//!
//! Counters are incremented in bulk (per row / per gather, never per scalar)
//! so instrumentation overhead in the hot loop is a single `u64 +=` — two
//! when a layer scope is active.
//!
//! # Layer attribution
//!
//! Stacked networks ([`crate::nn::LayerStack`]) charge every op twice: once
//! to the global per-phase counter (as before) and once to the
//! `(layer, Phase)` cell of the currently scoped layer. Scoping is explicit:
//! [`OpCounter::set_layer`] opens a layer context, [`OpCounter::clear_layer`]
//! closes it; charges issued outside any layer context (readout, loss,
//! optimizer) stay global-only. This is how the bench report and Table 1
//! attribute per-layer cost — in particular how the "cross-layer zero blocks
//! are never charged" property of the block-sparse engine is observable.

/// Phases of one training step, matching the cost decomposition of Table 1:
/// the forward term (`ω̃α̃n²`-ish) and the influence-update term
/// (`ω̃²β̃²n²p`), plus bookkeeping phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Cell forward pass (pre-activations + activation).
    Forward,
    /// Jacobian row construction (`∂v_k/∂a_l`).
    Jacobian,
    /// Immediate influence `M̄` row construction (`∂v_k/∂w_p`).
    Immediate,
    /// The `J·M` influence-matrix recursion — the paper's dominant term.
    InfluenceUpdate,
    /// Gradient combination `Mᵀ·c̄` + readout backward.
    GradCombine,
    /// Optimizer update.
    Optimizer,
}

pub const NUM_PHASES: usize = 6;

impl Phase {
    #[inline]
    pub fn index(self) -> usize {
        match self {
            Phase::Forward => 0,
            Phase::Jacobian => 1,
            Phase::Immediate => 2,
            Phase::InfluenceUpdate => 3,
            Phase::GradCombine => 4,
            Phase::Optimizer => 5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Phase::Forward => "forward",
            Phase::Jacobian => "jacobian",
            Phase::Immediate => "immediate",
            Phase::InfluenceUpdate => "influence_update",
            Phase::GradCombine => "grad_combine",
            Phase::Optimizer => "optimizer",
        }
    }

    pub fn all() -> [Phase; NUM_PHASES] {
        [
            Phase::Forward,
            Phase::Jacobian,
            Phase::Immediate,
            Phase::InfluenceUpdate,
            Phase::GradCombine,
            Phase::Optimizer,
        ]
    }
}

/// Per-phase MAC and memory-word counters, with optional per-layer
/// attribution (see module docs).
#[derive(Debug, Clone, Default)]
pub struct OpCounter {
    macs: [u64; NUM_PHASES],
    words: [u64; NUM_PHASES],
    /// Per-layer per-phase MACs; grown lazily to the highest scoped layer.
    layer_macs: Vec<[u64; NUM_PHASES]>,
    /// Per-layer per-phase words.
    layer_words: Vec<[u64; NUM_PHASES]>,
    /// Currently scoped layer (None = global-only charging).
    layer: Option<usize>,
}

impl OpCounter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Open a layer scope: subsequent charges are also attributed to layer
    /// `l` until [`Self::clear_layer`] (or the next `set_layer`).
    #[inline]
    pub fn set_layer(&mut self, l: usize) {
        if l >= self.layer_macs.len() {
            self.layer_macs.resize(l + 1, [0; NUM_PHASES]);
            self.layer_words.resize(l + 1, [0; NUM_PHASES]);
        }
        self.layer = Some(l);
    }

    /// Close the layer scope: charges go global-only again.
    #[inline]
    pub fn clear_layer(&mut self) {
        self.layer = None;
    }

    /// Charge `n` multiply-accumulates to `phase`.
    #[inline]
    pub fn macs(&mut self, phase: Phase, n: u64) {
        self.macs[phase.index()] += n;
        if let Some(l) = self.layer {
            self.layer_macs[l][phase.index()] += n;
        }
    }

    /// Charge `n` memory words touched to `phase`.
    #[inline]
    pub fn words(&mut self, phase: Phase, n: u64) {
        self.words[phase.index()] += n;
        if let Some(l) = self.layer {
            self.layer_words[l][phase.index()] += n;
        }
    }

    /// Flatten every counter into one word list (session checkpoints).
    /// Layout: `[NUM_PHASES, layers, macs.., words.., per-layer (macs..,
    /// words..)..]` — the leading phase count makes a schema change fail
    /// loudly on restore instead of misattributing counters.
    pub fn to_words_vec(&self) -> Vec<u64> {
        let layers = self.layer_macs.len();
        let mut out = Vec::with_capacity(2 + 2 * NUM_PHASES * (1 + layers));
        out.push(NUM_PHASES as u64);
        out.push(layers as u64);
        out.extend_from_slice(&self.macs);
        out.extend_from_slice(&self.words);
        for l in 0..layers {
            out.extend_from_slice(&self.layer_macs[l]);
            out.extend_from_slice(&self.layer_words[l]);
        }
        out
    }

    /// Rebuild from a [`OpCounter::to_words_vec`] snapshot.
    pub fn from_words_vec(words: &[u64]) -> Result<OpCounter, String> {
        if words.len() < 2 || words[0] != NUM_PHASES as u64 {
            return Err(format!(
                "op-counter snapshot has {} phases, this build counts {NUM_PHASES}",
                words.first().copied().unwrap_or(0)
            ));
        }
        let layers = words[1] as usize;
        let expect = 2 + 2 * NUM_PHASES * (1 + layers);
        if words.len() != expect {
            return Err(format!(
                "op-counter snapshot holds {} words, layout needs {expect}",
                words.len()
            ));
        }
        fn take<'a>(words: &'a [u64], off: &mut usize, n: usize) -> &'a [u64] {
            let s = &words[*off..*off + n];
            *off += n;
            s
        }
        let mut c = OpCounter::new();
        let mut off = 2usize;
        c.macs.copy_from_slice(take(words, &mut off, NUM_PHASES));
        c.words.copy_from_slice(take(words, &mut off, NUM_PHASES));
        for _ in 0..layers {
            let mut lm = [0u64; NUM_PHASES];
            lm.copy_from_slice(take(words, &mut off, NUM_PHASES));
            c.layer_macs.push(lm);
            let mut lw = [0u64; NUM_PHASES];
            lw.copy_from_slice(take(words, &mut off, NUM_PHASES));
            c.layer_words.push(lw);
        }
        Ok(c)
    }

    /// MACs charged to one phase.
    pub fn macs_in(&self, phase: Phase) -> u64 {
        self.macs[phase.index()]
    }

    /// Words charged to one phase.
    pub fn words_in(&self, phase: Phase) -> u64 {
        self.words[phase.index()]
    }

    /// Total MACs across phases.
    pub fn total_macs(&self) -> u64 {
        self.macs.iter().sum()
    }

    /// Total memory words across phases.
    pub fn total_words(&self) -> u64 {
        self.words.iter().sum()
    }

    /// Number of layers that have received at least one scoped charge.
    pub fn layers_tracked(&self) -> usize {
        self.layer_macs.len()
    }

    /// MACs charged to `(layer, phase)` (0 for never-scoped layers).
    pub fn macs_in_layer(&self, layer: usize, phase: Phase) -> u64 {
        self.layer_macs.get(layer).map_or(0, |m| m[phase.index()])
    }

    /// Words charged to `(layer, phase)`.
    pub fn words_in_layer(&self, layer: usize, phase: Phase) -> u64 {
        self.layer_words.get(layer).map_or(0, |w| w[phase.index()])
    }

    /// Total MACs attributed to one layer across phases.
    pub fn layer_total_macs(&self, layer: usize) -> u64 {
        self.layer_macs.get(layer).map_or(0, |m| m.iter().sum())
    }

    /// Total words attributed to one layer across phases.
    pub fn layer_total_words(&self, layer: usize) -> u64 {
        self.layer_words.get(layer).map_or(0, |w| w.iter().sum())
    }

    /// Zero all counters (layer scope survives a reset).
    pub fn reset(&mut self) {
        self.macs = [0; NUM_PHASES];
        self.words = [0; NUM_PHASES];
        self.layer_macs.clear();
        self.layer_words.clear();
        if let Some(l) = self.layer {
            self.layer_macs.resize(l + 1, [0; NUM_PHASES]);
            self.layer_words.resize(l + 1, [0; NUM_PHASES]);
        }
    }

    /// Fold another counter into this one (aggregating across samples/runs).
    pub fn merge(&mut self, other: &OpCounter) {
        for i in 0..NUM_PHASES {
            self.macs[i] += other.macs[i];
            self.words[i] += other.words[i];
        }
        if self.layer_macs.len() < other.layer_macs.len() {
            self.layer_macs.resize(other.layer_macs.len(), [0; NUM_PHASES]);
            self.layer_words.resize(other.layer_words.len(), [0; NUM_PHASES]);
        }
        for (l, (m, w)) in other.layer_macs.iter().zip(&other.layer_words).enumerate() {
            for i in 0..NUM_PHASES {
                self.layer_macs[l][i] += m[i];
                self.layer_words[l][i] += w[i];
            }
        }
    }

    /// Difference `self − baseline` (both must be monotone snapshots).
    pub fn since(&self, baseline: &OpCounter) -> OpCounter {
        let mut d = OpCounter::new();
        for i in 0..NUM_PHASES {
            d.macs[i] = self.macs[i] - baseline.macs[i];
            d.words[i] = self.words[i] - baseline.words[i];
        }
        d.layer_macs = self.layer_macs.clone();
        d.layer_words = self.layer_words.clone();
        for (l, (m, w)) in baseline.layer_macs.iter().zip(&baseline.layer_words).enumerate() {
            for i in 0..NUM_PHASES {
                d.layer_macs[l][i] -= m[i];
                d.layer_words[l][i] -= w[i];
            }
        }
        d
    }

    /// Human-readable per-phase table.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("{:<18}{:>16}{:>16}\n", "phase", "MACs", "words"));
        for ph in Phase::all() {
            s.push_str(&format!(
                "{:<18}{:>16}{:>16}\n",
                ph.name(),
                self.macs_in(ph),
                self.words_in(ph)
            ));
        }
        s.push_str(&format!(
            "{:<18}{:>16}{:>16}\n",
            "TOTAL",
            self.total_macs(),
            self.total_words()
        ));
        if self.layers_tracked() > 1 {
            s.push_str("per layer:\n");
            for l in 0..self.layers_tracked() {
                s.push_str(&format!(
                    "{:<18}{:>16}{:>16}\n",
                    format!("  layer {l}"),
                    self.layer_total_macs(l),
                    self.layer_total_words(l)
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_and_total() {
        let mut c = OpCounter::new();
        c.macs(Phase::Forward, 10);
        c.macs(Phase::InfluenceUpdate, 100);
        c.words(Phase::Forward, 5);
        assert_eq!(c.macs_in(Phase::Forward), 10);
        assert_eq!(c.total_macs(), 110);
        assert_eq!(c.total_words(), 5);
    }

    #[test]
    fn words_vec_roundtrip_including_layers() {
        let mut c = OpCounter::new();
        c.macs(Phase::Forward, 7);
        c.set_layer(1);
        c.macs(Phase::InfluenceUpdate, 11);
        c.words(Phase::InfluenceUpdate, 3);
        c.clear_layer();
        let back = OpCounter::from_words_vec(&c.to_words_vec()).unwrap();
        assert_eq!(back.total_macs(), c.total_macs());
        assert_eq!(back.total_words(), c.total_words());
        assert_eq!(back.layers_tracked(), 2);
        assert_eq!(back.macs_in_layer(1, Phase::InfluenceUpdate), 11);
        // malformed snapshots are loud
        assert!(OpCounter::from_words_vec(&[]).is_err());
        assert!(OpCounter::from_words_vec(&[99, 0]).is_err());
        assert!(OpCounter::from_words_vec(&c.to_words_vec()[..5]).is_err());
    }

    #[test]
    fn merge_and_since() {
        let mut a = OpCounter::new();
        a.macs(Phase::Forward, 3);
        let snapshot = a.clone();
        a.macs(Phase::Forward, 4);
        let d = a.since(&snapshot);
        assert_eq!(d.macs_in(Phase::Forward), 4);
        let mut b = OpCounter::new();
        b.merge(&a);
        b.merge(&a);
        assert_eq!(b.macs_in(Phase::Forward), 14);
    }

    #[test]
    fn reset_zeroes() {
        let mut c = OpCounter::new();
        c.macs(Phase::Optimizer, 7);
        c.reset();
        assert_eq!(c.total_macs(), 0);
    }

    #[test]
    fn layer_scoped_charges_attribute_both_ways() {
        let mut c = OpCounter::new();
        c.macs(Phase::Forward, 5); // unscoped: global only
        c.set_layer(0);
        c.macs(Phase::Forward, 10);
        c.words(Phase::InfluenceUpdate, 3);
        c.set_layer(1);
        c.macs(Phase::InfluenceUpdate, 20);
        c.clear_layer();
        c.macs(Phase::Optimizer, 7); // unscoped again
        assert_eq!(c.layers_tracked(), 2);
        assert_eq!(c.macs_in_layer(0, Phase::Forward), 10);
        assert_eq!(c.words_in_layer(0, Phase::InfluenceUpdate), 3);
        assert_eq!(c.macs_in_layer(1, Phase::InfluenceUpdate), 20);
        assert_eq!(c.layer_total_macs(0) + c.layer_total_macs(1), 30);
        // global totals include scoped and unscoped charges
        assert_eq!(c.macs_in(Phase::Forward), 15);
        assert_eq!(c.total_macs(), 42);
        // never-scoped layer reads as zero
        assert_eq!(c.macs_in_layer(5, Phase::Forward), 0);
    }

    #[test]
    fn merge_and_since_preserve_layer_counters() {
        let mut a = OpCounter::new();
        a.set_layer(1);
        a.macs(Phase::Jacobian, 4);
        a.clear_layer();
        let snap = a.clone();
        a.set_layer(1);
        a.macs(Phase::Jacobian, 6);
        a.clear_layer();
        let d = a.since(&snap);
        assert_eq!(d.macs_in_layer(1, Phase::Jacobian), 6);
        let mut b = OpCounter::new();
        b.set_layer(0);
        b.macs(Phase::Forward, 1);
        b.merge(&a);
        assert_eq!(b.macs_in_layer(0, Phase::Forward), 1);
        assert_eq!(b.macs_in_layer(1, Phase::Jacobian), 10);
        assert_eq!(b.layers_tracked(), 2);
    }

    #[test]
    fn phase_indices_unique() {
        let mut seen = [false; NUM_PHASES];
        for ph in Phase::all() {
            assert!(!seen[ph.index()]);
            seen[ph.index()] = true;
        }
    }
}
