//! Multiply-accumulate and memory-word counters, broken down by phase.
//!
//! Counters are incremented in bulk (per row / per gather, never per scalar)
//! so instrumentation overhead in the hot loop is a single `u64 +=`.

/// Phases of one training step, matching the cost decomposition of Table 1:
/// the forward term (`ω̃α̃n²`-ish) and the influence-update term
/// (`ω̃²β̃²n²p`), plus bookkeeping phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Cell forward pass (pre-activations + activation).
    Forward,
    /// Jacobian row construction (`∂v_k/∂a_l`).
    Jacobian,
    /// Immediate influence `M̄` row construction (`∂v_k/∂w_p`).
    Immediate,
    /// The `J·M` influence-matrix recursion — the paper's dominant term.
    InfluenceUpdate,
    /// Gradient combination `Mᵀ·c̄` + readout backward.
    GradCombine,
    /// Optimizer update.
    Optimizer,
}

pub const NUM_PHASES: usize = 6;

impl Phase {
    #[inline]
    pub fn index(self) -> usize {
        match self {
            Phase::Forward => 0,
            Phase::Jacobian => 1,
            Phase::Immediate => 2,
            Phase::InfluenceUpdate => 3,
            Phase::GradCombine => 4,
            Phase::Optimizer => 5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Phase::Forward => "forward",
            Phase::Jacobian => "jacobian",
            Phase::Immediate => "immediate",
            Phase::InfluenceUpdate => "influence_update",
            Phase::GradCombine => "grad_combine",
            Phase::Optimizer => "optimizer",
        }
    }

    pub fn all() -> [Phase; NUM_PHASES] {
        [
            Phase::Forward,
            Phase::Jacobian,
            Phase::Immediate,
            Phase::InfluenceUpdate,
            Phase::GradCombine,
            Phase::Optimizer,
        ]
    }
}

/// Per-phase MAC and memory-word counters.
#[derive(Debug, Clone, Default)]
pub struct OpCounter {
    macs: [u64; NUM_PHASES],
    words: [u64; NUM_PHASES],
}

impl OpCounter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `n` multiply-accumulates to `phase`.
    #[inline]
    pub fn macs(&mut self, phase: Phase, n: u64) {
        self.macs[phase.index()] += n;
    }

    /// Charge `n` memory words touched to `phase`.
    #[inline]
    pub fn words(&mut self, phase: Phase, n: u64) {
        self.words[phase.index()] += n;
    }

    /// MACs charged to one phase.
    pub fn macs_in(&self, phase: Phase) -> u64 {
        self.macs[phase.index()]
    }

    /// Words charged to one phase.
    pub fn words_in(&self, phase: Phase) -> u64 {
        self.words[phase.index()]
    }

    /// Total MACs across phases.
    pub fn total_macs(&self) -> u64 {
        self.macs.iter().sum()
    }

    /// Total memory words across phases.
    pub fn total_words(&self) -> u64 {
        self.words.iter().sum()
    }

    /// Zero all counters.
    pub fn reset(&mut self) {
        self.macs = [0; NUM_PHASES];
        self.words = [0; NUM_PHASES];
    }

    /// Fold another counter into this one (aggregating across samples/runs).
    pub fn merge(&mut self, other: &OpCounter) {
        for i in 0..NUM_PHASES {
            self.macs[i] += other.macs[i];
            self.words[i] += other.words[i];
        }
    }

    /// Difference `self − baseline` (both must be monotone snapshots).
    pub fn since(&self, baseline: &OpCounter) -> OpCounter {
        let mut d = OpCounter::new();
        for i in 0..NUM_PHASES {
            d.macs[i] = self.macs[i] - baseline.macs[i];
            d.words[i] = self.words[i] - baseline.words[i];
        }
        d
    }

    /// Human-readable per-phase table.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("{:<18}{:>16}{:>16}\n", "phase", "MACs", "words"));
        for ph in Phase::all() {
            s.push_str(&format!(
                "{:<18}{:>16}{:>16}\n",
                ph.name(),
                self.macs_in(ph),
                self.words_in(ph)
            ));
        }
        s.push_str(&format!(
            "{:<18}{:>16}{:>16}\n",
            "TOTAL",
            self.total_macs(),
            self.total_words()
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_and_total() {
        let mut c = OpCounter::new();
        c.macs(Phase::Forward, 10);
        c.macs(Phase::InfluenceUpdate, 100);
        c.words(Phase::Forward, 5);
        assert_eq!(c.macs_in(Phase::Forward), 10);
        assert_eq!(c.total_macs(), 110);
        assert_eq!(c.total_words(), 5);
    }

    #[test]
    fn merge_and_since() {
        let mut a = OpCounter::new();
        a.macs(Phase::Forward, 3);
        let snapshot = a.clone();
        a.macs(Phase::Forward, 4);
        let d = a.since(&snapshot);
        assert_eq!(d.macs_in(Phase::Forward), 4);
        let mut b = OpCounter::new();
        b.merge(&a);
        b.merge(&a);
        assert_eq!(b.macs_in(Phase::Forward), 14);
    }

    #[test]
    fn reset_zeroes() {
        let mut c = OpCounter::new();
        c.macs(Phase::Optimizer, 7);
        c.reset();
        assert_eq!(c.total_macs(), 0);
    }

    #[test]
    fn phase_indices_unique() {
        let mut seen = [false; NUM_PHASES];
        for ph in Phase::all() {
            assert!(!seen[ph.index()]);
            seen[ph.index()] = true;
        }
    }
}
