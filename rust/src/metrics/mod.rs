//! Instrumentation: operation counting, sparsity statistics, learning curves.
//!
//! The paper's evaluation metric is *analytic* compute (the
//! "compute-adjusted iteration", a cumulative `ω̃²β̃²` factor). This module
//! provides both that analytic measure ([`compute_adjusted`]) and a stronger
//! *measured* one: [`ops::OpCounter`] counts every multiply-accumulate the
//! engines actually perform, phase by phase, so Table 1's cost model can be
//! validated against real op counts rather than asymptotics.

pub mod compute_adjusted;
pub mod curve;
pub mod ops;
pub mod sparsity;

pub use compute_adjusted::ComputeAdjusted;
pub use ops::{OpCounter, Phase, NUM_PHASES};
pub use sparsity::SparsityStats;
