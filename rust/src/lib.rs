//! # sparse-rtrl
//!
//! Production reproduction of *"Efficient Real Time Recurrent Learning
//! through combined activity and parameter sparsity"* (Subramoney, 2023).
//!
//! The library implements **exact** Real-Time Recurrent Learning (RTRL)
//! whose per-step cost drops from `O(n²p)` to `O(ω̃²β̃²n²p)` by skipping the
//! structural zeros that appear in the influence-matrix recursion
//! `M ← J·M + M̄` when the network is
//!
//! * **activity sparse** — a thresholded event-based RNN (`a = H(v)`) whose
//!   pseudo-derivative `H'(v_k) = 0` zeroes entire *rows* of `J`, `M̄` and
//!   therefore `M` (paper Eqns. 6–10), and
//! * **parameter sparse** — a fixed random weight mask zeroes *columns* of
//!   `M̄`/`M` and elements of `J` (Menick et al., 2020), with the zero
//!   columns persisting across timesteps.
//!
//! Because the savings come from structural zeros in the exact equations, the
//! sparse engines in [`rtrl`] produce gradients numerically equal to dense
//! RTRL and to BPTT — enforced by the `grad_equivalence` and
//! `sparse_exactness` integration tests.
//!
//! ## Depth
//!
//! Networks are stacks ([`nn::LayerStack`], `model.layers` in the config):
//! layer `l` reads layer `l−1`'s new activations, so the one-step Jacobian
//! of the concatenated state is **block lower-bidiagonal** and the
//! influence matrix block lower-triangular over
//! (layer-row × layer-param-column). Exact RTRL propagates influence
//! layer-by-layer within a step; each layer's panel tracks only the
//! columns of layers `0..=l`, so the structural cross-layer zero blocks
//! are never stored or charged (see [`rtrl`] module docs). Depth 1 is the
//! paper's single-cell configuration, bit-for-bit.
//!
//! ## The session layer — the primary API
//!
//! Online learning is the point of RTRL, so the public surface is built
//! around it: [`session::SessionBuilder`] produces a long-lived
//! [`session::OnlineSession`] whose core call is
//! `step(input, target) → `[`session::StepOutcome`] (prediction, loss,
//! sparsity stats). There are no mandatory sequence boundaries — a
//! [`session::UpdatePolicy`] (every-k-supervised-steps / end-of-sequence /
//! manual) decides when accumulated gradients become parameter updates.
//! [`serve`] turns the pool into a long-lived multi-tenant server
//! (`sparse-rtrl serve`): per-tenant event queues drained in rounds with
//! fused shared-weight stepping, LRU spill-to-snapshot under a residency
//! budget, and a line protocol over a Unix socket or stdin.
//! Sessions checkpoint **bit-exactly** ([`session::SessionCheckpoint`]):
//! weights, Adam moments, stream counters and the engine's versioned
//! [`rtrl::EngineState`] snapshot travel in one JSON document, so a live
//! session migrates across process restarts with bit-identical gradients
//! and predictions. [`session::SessionPool`] steps N independent sessions
//! (the many-users scenario) concurrently over [`util::pool`]. The batch
//! [`train::Trainer`] is a thin client of the session (manual policy +
//! minibatch averaging), and the `stream` CLI subcommand drives a session
//! from a file/stdin event stream ([`session::events`]).
//!
//! ## Layers
//!
//! * **L3 (this crate)** — streaming sessions, event-driven sparse engines,
//!   datasets, optimizers, training loop, sweep coordinator, op-count
//!   instrumentation, reports, and the [`bench`] performance-trajectory
//!   subsystem.
//! * **L2 (JAX, build time)** — dense EGRU+RTRL step AOT-lowered to HLO text
//!   (`python/compile/model.py` → `artifacts/*.hlo.txt`), executed from
//!   [`runtime`] via PJRT as the dense baseline and numerical oracle
//!   (requires the `pjrt` cargo feature; the default build ships a stub).
//! * **L1 (Pallas, build time)** — blocked influence-update kernel with
//!   row-block activity skipping (`python/compile/kernels/`).
//!
//! ## The `GradientEngine` contract
//!
//! Every gradient method — dense RTRL, the three exact sparse RTRL modes,
//! SnAp-1/2, UORO and BPTT — implements [`rtrl::GradientEngine`]:
//! `begin_sequence` → `step`×T → `end_sequence` → `grads`, plus
//! `reset_grads` for the online regime and mandatory op-count accounting
//! (every MAC charged to the step's [`metrics::OpCounter`] under its
//! [`metrics::Phase`], inside the owning layer's `set_layer` scope where
//! attributable; `state_memory_words` reports the live footprint).
//!
//! **Snapshot contract:** engines also implement `save_state`/`load_state`
//! over a versioned [`rtrl::EngineState`] — a named-buffer snapshot of all
//! sequence state (influence panels, UORO's rank-1 vectors *and* noise-RNG
//! position, SnAp pattern slabs, BPTT's stored tape). A snapshot taken
//! between steps and restored into a freshly-built engine of the same
//! configuration continues the sequence **bit-identically**; name/version/
//! shape mismatches fail loudly (`tests/engine_contract.rs` pins both
//! halves for every engine).
//!
//! Sessions, the trainer, the sweep coordinator, the micro-benches and
//! [`bench`] all consume engines exclusively through this trait, so a new
//! engine plugs into every task, sweep arm and perf report by implementing
//! it and registering in [`train::build::build_engine`].
//!
//! ## The kernels layer
//!
//! All engines realize their recursions through [`rtrl::kernels`]: a
//! per-step, per-layer [`rtrl::JacobianSlab`] (the one-step Jacobian,
//! materialized once over the engine's exact evaluation set) plus fused
//! row kernels with bulk op charging. The exact sparse engine's influence
//! update additionally fans out across panel rows on the worker pool
//! (`set_threads` / the CLI `--threads` flag) with **bit-identical**
//! results at any thread count — gradients and op counters alike.
//!
//! ## Observability
//!
//! The [`telemetry`] subsystem makes the paper's drifting quantities —
//! α/β/β̃ series, influence occupancy, loss EWMA, per-phase MAC rates,
//! step latency — first-class runtime signals: opt-in per-session sampling
//! into bounded rings, pool-level counters surfaced as a
//! [`telemetry::TelemetrySnapshot`], and a JSON-lines structured trace
//! (`stream --trace`, rendered by the `stats` subcommand). Disabled
//! telemetry costs one branch per step and changes no result bits.
//!
//! ## The `bench` subsystem
//!
//! `sparse-rtrl bench` sweeps engine × hidden size × parameter sparsity
//! over the in-tree worker pool, measures wall-time and throughput next to
//! the op counters, and emits machine-readable `BENCH_rtrl.json`
//! (schema v3: depth + threads axes) — the artifact CI records on every PR
//! as the repo's performance trajectory (`--quick` is the CI smoke grid;
//! a dedicated arm fails the build if op counts differ between
//! `--threads 1` and `--threads 2`).

//!
//! ## Static analysis
//!
//! The [`analysis`] subsystem (`sparse-rtrl analyze`) is the build-time
//! guard on the determinism story: a dependency-free scanner that forbids
//! unordered-map iteration, ambient clocks/RNG, and unpinned float
//! reductions in compute modules, and ratchets library panic sites down
//! through the committed `ANALYSIS_baseline.json`. CI runs
//! `analyze --check` as a blocking job.

pub mod analysis;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod nn;
pub mod optim;
pub mod report;
pub mod rtrl;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod sparse;
pub mod telemetry;
pub mod tensor;
pub mod train;
pub mod util;
