//! Stacked recurrent layers — depth as a first-class dimension.
//!
//! A [`LayerStack`] is an ordered `Vec<RnnCell>` where layer 0 reads the
//! external input and layer `l ≥ 1` reads layer `l−1`'s *new* activation:
//!
//! ```text
//! a_0^{(t)} = φ(G_0(a_0^{(t-1)}, x^{(t)}))
//! a_l^{(t)} = φ(G_l(a_l^{(t-1)}, a_{l-1}^{(t)}))        l = 1..L−1
//! ```
//!
//! Viewed as one recurrent map over the concatenated state
//! `a = [a_0 … a_{L-1}] ∈ R^N`, the one-step dependency structure is
//! **block lower-bidiagonal**: layer `l` depends on its own previous state
//! (the diagonal block, through the masked recurrent matrices) and on layer
//! `l−1`'s new state (the sub-diagonal block, through the dense input
//! weights). RTRL engines exploit this by propagating influence
//! layer-by-layer within a step; the influence matrix `M` is block
//! lower-*triangular* over (layer-row × layer-param-column), because layer
//! `l`'s state can never depend on a deeper layer's parameters. The
//! cross-layer upper blocks are structural zeros that the sparse engine
//! never materializes or charges (see `rtrl::sparse`).
//!
//! The concatenated parameter vector follows [`NetworkLayout`]: layer-major,
//! each layer flattened by its own [`ParamLayout`]. Every per-layer op is
//! charged to the [`OpCounter`]'s `(layer, Phase)` cell via
//! [`OpCounter::set_layer`] scoping.

use super::cell::{CellScratch, RnnCell};
use crate::metrics::OpCounter;

/// Concatenated layout over per-layer [`super::ParamLayout`]s and state
/// slices: which global flat-parameter / global-unit ranges belong to which
/// layer.
#[derive(Debug, Clone)]
pub struct NetworkLayout {
    /// `param_offsets[l]..param_offsets[l+1]` = layer `l`'s flat params.
    param_offsets: Vec<usize>,
    /// `state_offsets[l]..state_offsets[l+1]` = layer `l`'s units.
    state_offsets: Vec<usize>,
}

impl NetworkLayout {
    fn from_cells(cells: &[RnnCell]) -> Self {
        let mut param_offsets = Vec::with_capacity(cells.len() + 1);
        let mut state_offsets = Vec::with_capacity(cells.len() + 1);
        let (mut p, mut n) = (0usize, 0usize);
        for c in cells {
            param_offsets.push(p);
            state_offsets.push(n);
            p += c.p();
            n += c.n();
        }
        param_offsets.push(p);
        state_offsets.push(n);
        NetworkLayout { param_offsets, state_offsets }
    }

    /// Number of layers.
    #[inline]
    pub fn layers(&self) -> usize {
        self.param_offsets.len() - 1
    }

    /// Global flat-parameter offset of layer `l`.
    #[inline]
    pub fn param_offset(&self, l: usize) -> usize {
        self.param_offsets[l]
    }

    /// Global flat-parameter range of layer `l`.
    #[inline]
    pub fn param_range(&self, l: usize) -> std::ops::Range<usize> {
        self.param_offsets[l]..self.param_offsets[l + 1]
    }

    /// Global unit offset of layer `l`.
    #[inline]
    pub fn state_offset(&self, l: usize) -> usize {
        self.state_offsets[l]
    }

    /// Global unit range of layer `l`.
    #[inline]
    pub fn state_range(&self, l: usize) -> std::ops::Range<usize> {
        self.state_offsets[l]..self.state_offsets[l + 1]
    }

    /// Total parameter count `P = Σ_l p_l`.
    #[inline]
    pub fn total_params(&self) -> usize {
        *self.param_offsets.last().unwrap()
    }

    /// Total state size `N = Σ_l n_l`.
    #[inline]
    pub fn total_units(&self) -> usize {
        *self.state_offsets.last().unwrap()
    }

    /// Decode a global flat parameter index to `(layer, local index)`.
    pub fn layer_of_param(&self, pi: usize) -> (usize, usize) {
        debug_assert!(pi < self.total_params());
        let l = match self.param_offsets.binary_search(&pi) {
            Ok(i) if i < self.layers() => i,
            Ok(i) => i - 1,
            Err(i) => i - 1,
        };
        (l, pi - self.param_offsets[l])
    }

    /// Decode a global unit index to `(layer, local unit)`.
    pub fn layer_of_unit(&self, k: usize) -> (usize, usize) {
        debug_assert!(k < self.total_units());
        let l = match self.state_offsets.binary_search(&k) {
            Ok(i) if i < self.layers() => i,
            Ok(i) => i - 1,
            Err(i) => i - 1,
        };
        (l, k - self.state_offsets[l])
    }
}

/// Per-timestep forward state of a whole stack: one [`CellScratch`] per
/// layer, filled bottom-up by [`LayerStack::forward`].
#[derive(Debug, Clone)]
pub struct StackScratch {
    pub layers: Vec<CellScratch>,
}

impl StackScratch {
    pub fn new(stack: &LayerStack) -> Self {
        StackScratch {
            layers: stack.cells.iter().map(|c| CellScratch::new(c.n())).collect(),
        }
    }

    /// Scratch of the top layer (whose activations feed the readout).
    #[inline]
    pub fn top(&self) -> &CellScratch {
        self.layers.last().expect("empty stack")
    }

    /// Σ active units over all layers (α̃N).
    pub fn active_units(&self) -> usize {
        self.layers.iter().map(|s| s.active_units()).sum()
    }

    /// Σ deriv-active units over all layers (β̃N).
    pub fn deriv_units(&self) -> usize {
        self.layers.iter().map(|s| s.deriv_units()).sum()
    }

    /// Concatenate the new activations into a global state vector.
    pub fn write_state(&self, out: &mut [f32]) {
        let mut off = 0;
        for s in &self.layers {
            out[off..off + s.a.len()].copy_from_slice(&s.a);
            off += s.a.len();
        }
        debug_assert_eq!(off, out.len());
    }
}

/// An ordered stack of recurrent cells wired input → layer 0 → … → layer
/// L−1 → readout. Depth 1 is exactly the single-cell network every engine
/// historically consumed.
#[derive(Debug, Clone)]
pub struct LayerStack {
    cells: Vec<RnnCell>,
    layout: NetworkLayout,
}

impl LayerStack {
    /// Build from pre-constructed cells. Panics unless layer `l`'s input
    /// width equals layer `l−1`'s hidden width.
    pub fn new(cells: Vec<RnnCell>) -> Self {
        assert!(!cells.is_empty(), "LayerStack needs at least one layer");
        for l in 1..cells.len() {
            assert_eq!(
                cells[l].n_in(),
                cells[l - 1].n(),
                "layer {l} reads layer {}: n_in must equal that layer's n",
                l - 1
            );
        }
        let layout = NetworkLayout::from_cells(&cells);
        LayerStack { cells, layout }
    }

    /// Single-layer stack — the historical single-cell configuration.
    pub fn single(cell: RnnCell) -> Self {
        Self::new(vec![cell])
    }

    #[inline]
    pub fn layers(&self) -> usize {
        self.cells.len()
    }

    #[inline]
    pub fn layer(&self, l: usize) -> &RnnCell {
        &self.cells[l]
    }

    /// Mutable access to one layer (mask rewiring, parameter surgery).
    /// Callers must not change layer dimensions.
    #[inline]
    pub fn layer_mut(&mut self, l: usize) -> &mut RnnCell {
        &mut self.cells[l]
    }

    #[inline]
    pub fn cells(&self) -> &[RnnCell] {
        &self.cells
    }

    #[inline]
    pub fn layout(&self) -> &NetworkLayout {
        &self.layout
    }

    /// External input width (layer 0's input).
    #[inline]
    pub fn n_in(&self) -> usize {
        self.cells[0].n_in()
    }

    /// Total state size `N`.
    #[inline]
    pub fn total_units(&self) -> usize {
        self.layout.total_units()
    }

    /// Hidden width of the top layer (readout input width).
    #[inline]
    pub fn top_n(&self) -> usize {
        self.cells.last().unwrap().n()
    }

    /// Total parameter count `P` across layers.
    #[inline]
    pub fn p(&self) -> usize {
        self.layout.total_params()
    }

    /// Fresh per-layer scratch sized for this stack.
    pub fn scratch(&self) -> StackScratch {
        StackScratch::new(self)
    }

    /// Kept fraction ω̃ over all layers' recurrent entries (1.0 when dense).
    pub fn omega_tilde(&self) -> f32 {
        let mut kept = 0.0f64;
        let mut total = 0.0f64;
        for c in &self.cells {
            let nn = (c.n() * c.n()) as f64;
            kept += c.omega_tilde() as f64 * nn;
            total += nn;
        }
        (kept / total.max(1.0)) as f32
    }

    /// One forward step over the whole stack. `a_prev` is the concatenated
    /// previous state (`R^N`), `x` the external input; each layer's ops are
    /// charged under its `(layer, Phase)` scope.
    pub fn forward(
        &self,
        a_prev: &[f32],
        x: &[f32],
        scratch: &mut StackScratch,
        ops: &mut OpCounter,
    ) {
        assert_eq!(a_prev.len(), self.total_units());
        assert_eq!(scratch.layers.len(), self.cells.len());
        for l in 0..self.cells.len() {
            ops.set_layer(l);
            let (below, rest) = scratch.layers.split_at_mut(l);
            let input: &[f32] = if l == 0 { x } else { &below[l - 1].a };
            let prev = &a_prev[self.layout.state_range(l)];
            self.cells[l].forward(prev, input, &mut rest[0], ops);
        }
        ops.clear_layer();
    }

    /// Copy the concatenated parameter vector (`R^P`) out — layer-major,
    /// each layer in its own [`super::ParamLayout`] order. This is the
    /// indexing engines' `grads()` use.
    pub fn copy_params_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.p());
        for (l, c) in self.cells.iter().enumerate() {
            out[self.layout.param_range(l)].copy_from_slice(c.params());
        }
    }

    /// Load a concatenated parameter vector back into the layers.
    pub fn load_params(&mut self, inp: &[f32]) {
        assert_eq!(inp.len(), self.p());
        for l in 0..self.cells.len() {
            let range = self.layout.param_range(l);
            self.cells[l].params_mut().copy_from_slice(&inp[range]);
        }
    }

    /// Re-zero masked entries in every layer (post-optimizer hygiene).
    pub fn enforce_masks(&mut self) {
        for c in &mut self.cells {
            c.enforce_mask();
        }
    }
}

impl From<RnnCell> for LayerStack {
    fn from(cell: RnnCell) -> Self {
        LayerStack::single(cell)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Phase;
    use crate::util::Pcg64;

    fn two_layer() -> LayerStack {
        let mut rng = Pcg64::new(50);
        let l0 = RnnCell::egru(6, 2, 0.05, 0.3, 0.5, None, &mut rng);
        let l1 = RnnCell::egru(4, 6, 0.05, 0.3, 0.5, None, &mut rng);
        LayerStack::new(vec![l0, l1])
    }

    #[test]
    fn layout_offsets_and_decoding() {
        let net = two_layer();
        let lay = net.layout();
        assert_eq!(lay.layers(), 2);
        assert_eq!(net.total_units(), 10);
        assert_eq!(net.top_n(), 4);
        assert_eq!(net.p(), net.layer(0).p() + net.layer(1).p());
        assert_eq!(lay.param_range(1), net.layer(0).p()..net.p());
        assert_eq!(lay.state_range(1), 6..10);
        // decode round-trips
        assert_eq!(lay.layer_of_param(0), (0, 0));
        assert_eq!(lay.layer_of_param(net.layer(0).p()), (1, 0));
        assert_eq!(lay.layer_of_param(net.p() - 1), (1, net.layer(1).p() - 1));
        assert_eq!(lay.layer_of_unit(5), (0, 5));
        assert_eq!(lay.layer_of_unit(6), (1, 0));
    }

    #[test]
    #[should_panic]
    fn mismatched_wiring_panics() {
        let mut rng = Pcg64::new(51);
        let l0 = RnnCell::egru(6, 2, 0.05, 0.3, 0.5, None, &mut rng);
        let l1 = RnnCell::egru(4, 5, 0.05, 0.3, 0.5, None, &mut rng);
        LayerStack::new(vec![l0, l1]);
    }

    /// Stack forward equals chaining the cells by hand: layer 1's input is
    /// layer 0's *new* activation.
    #[test]
    fn forward_matches_manual_chain() {
        let net = two_layer();
        let mut s = net.scratch();
        let mut ops = OpCounter::new();
        let a_prev: Vec<f32> = (0..10).map(|i| (i % 2) as f32).collect();
        let x = [0.4, -0.9];
        net.forward(&a_prev, &x, &mut s, &mut ops);

        let mut s0 = CellScratch::new(6);
        let mut s1 = CellScratch::new(4);
        let mut discard = OpCounter::new();
        net.layer(0).forward(&a_prev[..6], &x, &mut s0, &mut discard);
        net.layer(1).forward(&a_prev[6..], &s0.a, &mut s1, &mut discard);
        assert_eq!(s.layers[0].a, s0.a);
        assert_eq!(s.layers[1].a, s1.a);
        assert_eq!(s.top().a, s1.a);
        // and the same total ops were charged
        assert_eq!(ops.total_macs(), discard.total_macs());
        // per-layer attribution is populated for both layers
        assert!(ops.macs_in_layer(0, Phase::Forward) > 0);
        assert!(ops.macs_in_layer(1, Phase::Forward) > 0);
        assert_eq!(
            ops.macs_in(Phase::Forward),
            ops.macs_in_layer(0, Phase::Forward) + ops.macs_in_layer(1, Phase::Forward)
        );
    }

    #[test]
    fn write_state_concatenates() {
        let net = two_layer();
        let mut s = net.scratch();
        let mut ops = OpCounter::new();
        net.forward(&vec![0.0; 10], &[1.0, 1.0], &mut s, &mut ops);
        let mut state = vec![0.0; 10];
        s.write_state(&mut state);
        assert_eq!(&state[..6], &s.layers[0].a[..]);
        assert_eq!(&state[6..], &s.layers[1].a[..]);
        assert_eq!(s.active_units(), state.iter().filter(|&&v| v != 0.0).count());
    }

    #[test]
    fn params_roundtrip_through_concat() {
        let mut net = two_layer();
        let mut buf = vec![0.0; net.p()];
        net.copy_params_into(&mut buf);
        let orig = buf.clone();
        for v in buf.iter_mut() {
            *v += 0.5;
        }
        net.load_params(&buf);
        let mut back = vec![0.0; net.p()];
        net.copy_params_into(&mut back);
        for (b, o) in back.iter().zip(&orig) {
            assert!((b - o - 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn single_layer_stack_matches_cell() {
        let mut rng = Pcg64::new(52);
        let cell = RnnCell::egru(5, 2, 0.05, 0.3, 0.5, None, &mut rng);
        let net = LayerStack::single(cell.clone());
        assert_eq!(net.p(), cell.p());
        assert_eq!(net.total_units(), cell.n());
        let mut s = net.scratch();
        let mut sc = CellScratch::new(5);
        let mut ops = OpCounter::new();
        let a0 = vec![0.0; 5];
        net.forward(&a0, &[0.3, 0.3], &mut s, &mut ops);
        cell.forward(&a0, &[0.3, 0.3], &mut sc, &mut OpCounter::new());
        assert_eq!(s.top().a, sc.a);
    }
}
