//! Instantaneous losses `L(y, y_target)` with gradients w.r.t. logits.
//!
//! The paper's formulation puts a loss at every timestep (`𝓛 = Σ_t L_t`);
//! sequence classification (the spiral task) is the special case where only
//! the final step carries loss. Both modes are supported by the trainer.

use crate::util::math::softmax_into;

/// Which loss to apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossKind {
    /// Softmax cross-entropy against an integer class target.
    CrossEntropy,
    /// Mean squared error against a dense target vector.
    Mse,
}

/// Loss evaluator with scratch buffers.
#[derive(Debug, Clone)]
pub struct Loss {
    kind: LossKind,
    probs: Vec<f32>,
}

impl Loss {
    pub fn new(kind: LossKind, n_out: usize) -> Self {
        Loss { kind, probs: vec![0.0; n_out] }
    }

    #[inline]
    pub fn kind(&self) -> LossKind {
        self.kind
    }

    /// Cross-entropy for class `target`: returns `(loss, dlogits)` with
    /// `dlogits = softmax(logits) − onehot(target)` written into `dlogits`.
    pub fn cross_entropy(&mut self, logits: &[f32], target: usize, dlogits: &mut [f32]) -> f32 {
        assert_eq!(logits.len(), self.probs.len());
        assert!(target < logits.len());
        softmax_into(logits, &mut self.probs);
        dlogits.copy_from_slice(&self.probs);
        dlogits[target] -= 1.0;
        -(self.probs[target].max(1e-12)).ln()
    }

    /// MSE `0.5·Σ(y−t)²`: returns loss, writes `dlogits = y − t`.
    pub fn mse(&mut self, logits: &[f32], target: &[f32], dlogits: &mut [f32]) -> f32 {
        assert_eq!(logits.len(), target.len());
        let mut loss = 0.0;
        for ((d, &y), &t) in dlogits.iter_mut().zip(logits).zip(target) {
            let e = y - t;
            *d = e;
            loss += 0.5 * e * e;
        }
        loss
    }

    /// Predicted class (argmax of logits).
    pub fn predict(logits: &[f32]) -> usize {
        crate::tensor::ops::argmax(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ce_loss_decreases_with_confidence() {
        let mut l = Loss::new(LossKind::CrossEntropy, 2);
        let mut d = [0.0; 2];
        let weak = l.cross_entropy(&[0.1, 0.0], 0, &mut d);
        let strong = l.cross_entropy(&[5.0, 0.0], 0, &mut d);
        assert!(strong < weak);
    }

    #[test]
    fn ce_gradient_finite_difference() {
        let mut l = Loss::new(LossKind::CrossEntropy, 3);
        let logits = [0.2f32, -0.5, 1.0];
        let mut d = [0.0; 3];
        let base = l.cross_entropy(&logits, 1, &mut d);
        let analytic = d;
        let h = 1e-3;
        for i in 0..3 {
            let mut lp = logits;
            lp[i] += h;
            let mut dd = [0.0; 3];
            let up = l.cross_entropy(&lp, 1, &mut dd);
            let fd = (up - base) / h;
            assert!((fd - analytic[i]).abs() < 1e-2, "i={i} fd={fd} an={}", analytic[i]);
        }
    }

    #[test]
    fn ce_gradient_sums_to_zero() {
        let mut l = Loss::new(LossKind::CrossEntropy, 4);
        let mut d = [0.0; 4];
        l.cross_entropy(&[1.0, 2.0, -1.0, 0.0], 2, &mut d);
        let s: f32 = d.iter().sum();
        assert!(s.abs() < 1e-6);
    }

    #[test]
    fn mse_known_values() {
        let mut l = Loss::new(LossKind::Mse, 2);
        let mut d = [0.0; 2];
        let loss = l.mse(&[1.0, 2.0], &[0.0, 0.0], &mut d);
        assert!((loss - 2.5).abs() < 1e-6);
        assert_eq!(d, [1.0, 2.0]);
    }

    #[test]
    fn predict_argmax() {
        assert_eq!(Loss::predict(&[0.1, 0.9, 0.5]), 1);
    }
}
