//! The recurrent cell family (paper Eqns. 1 & 5): `v = G(a_prev, x; w) − ϑ`,
//! `a = φ(v)`.
//!
//! Two dynamics (`G`) × two activations (`φ`) cover the experiment matrix:
//!
//! * [`Dynamics::Gated`] — GRU-form drive `G = u ⊙ z` with
//!   `u = σ(W_u x + V_u a + b_u)`, `z = tanh(W_z x + V_z a + b_z)`;
//!   with [`Activation::Heaviside`] this is the **EGRU** in the Eq.-(5)
//!   formulation the paper's §4 derivation targets.
//! * [`Dynamics::Linear`] — `G = W x + V a + b`; with Heaviside this is the
//!   thresholded vanilla RNN (EvNN) of §4, with Tanh the dense baseline.
//!
//! The cell exposes exactly the three quantities RTRL needs, in factored
//! form (paper Eq. 10):
//!
//! * `φ'(v_k)` — the row gate ([`CellScratch::dphi`]); zero ⇒ row `k` of
//!   `J`, `M̄`, `M` is zero,
//! * `∂v_k/∂a_l` — Jacobian rows before the `φ'` factor ([`RnnCell::dv_da`]),
//! * `∂v_k/∂w_p` — immediate influence rows ([`RnnCell::immediate_row`]),
//!   structurally restricted to unit `k`'s fan-in parameters.
//!
//! Parameter sparsity is a fixed shared `n×n` [`MaskPattern`] over the
//! recurrent matrices (`V`, or `V_u`+`V_z`), so a dropped `(k,l)` zeroes the
//! corresponding `J` element and `M`/`M̄` columns exactly as in §5.

use super::layout::{ParamBlock, ParamLayout};
use super::pseudo::{heaviside, pseudo_derivative};
use crate::metrics::{OpCounter, Phase};
use crate::sparse::MaskPattern;
use crate::util::math::{dsigmoid_from_out, dtanh_from_out, sigmoid};
use crate::util::Pcg64;

/// Recurrent drive `G`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dynamics {
    /// `G = W x + V a_prev + b` (vanilla / EvNN).
    Linear,
    /// `G = σ(W_u x + V_u a + b_u) ⊙ tanh(W_z x + V_z a + b_z)` (EGRU-form).
    Gated,
}

/// Output nonlinearity `φ`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Activation {
    /// Heaviside step with triangular pseudo-derivative (γ, ε) — the
    /// event-based, activity-sparse case.
    Heaviside { gamma: f32, eps: f32 },
    /// `tanh` — the dense-activity control (β̃ ≈ 1).
    Tanh,
}

impl Activation {
    #[inline]
    fn apply(self, v: f32) -> f32 {
        match self {
            Activation::Heaviside { .. } => heaviside(v),
            Activation::Tanh => v.tanh(),
        }
    }

    #[inline]
    fn derivative(self, v: f32, a: f32) -> f32 {
        match self {
            Activation::Heaviside { gamma, eps } => pseudo_derivative(v, gamma, eps),
            Activation::Tanh => dtanh_from_out(a),
        }
    }

    /// Whether φ can produce exact zeros in its derivative (activity-sparse).
    pub fn is_event_based(self) -> bool {
        matches!(self, Activation::Heaviside { .. })
    }
}

/// Per-timestep forward state the derivative computations read.
#[derive(Debug, Clone)]
pub struct CellScratch {
    /// Pre-activation `v = G − ϑ`.
    pub v: Vec<f32>,
    /// Activation `a = φ(v)`.
    pub a: Vec<f32>,
    /// `φ'(v)` — the RTRL row gate.
    pub dphi: Vec<f32>,
    /// Gated only: update-gate output `u`.
    pub u: Vec<f32>,
    /// Gated only: candidate output `z`.
    pub z: Vec<f32>,
    /// Gated only: u-path coefficient `g_u[k] = z_k·u_k(1−u_k)`.
    pub gu: Vec<f32>,
    /// Gated only: z-path coefficient `g_z[k] = u_k(1−z_k²)`.
    pub gz: Vec<f32>,
}

impl CellScratch {
    pub fn new(n: usize) -> Self {
        CellScratch {
            v: vec![0.0; n],
            a: vec![0.0; n],
            dphi: vec![0.0; n],
            u: vec![0.0; n],
            z: vec![0.0; n],
            gu: vec![0.0; n],
            gz: vec![0.0; n],
        }
    }

    /// Number of units with nonzero activation (α̃n).
    pub fn active_units(&self) -> usize {
        self.a.iter().filter(|&&x| x != 0.0).count()
    }

    /// Number of units with nonzero pseudo-derivative (β̃n).
    pub fn deriv_units(&self) -> usize {
        self.dphi.iter().filter(|&&x| x != 0.0).count()
    }
}

/// Block indices for [`Dynamics::Linear`] layouts.
pub mod linear_blocks {
    pub const W: usize = 0;
    pub const V: usize = 1;
    pub const B: usize = 2;
}

/// Block indices for [`Dynamics::Gated`] layouts.
pub mod gated_blocks {
    pub const WU: usize = 0;
    pub const VU: usize = 1;
    pub const BU: usize = 2;
    pub const WZ: usize = 3;
    pub const VZ: usize = 4;
    pub const BZ: usize = 5;
}

/// A recurrent cell with optional fixed parameter sparsity.
#[derive(Debug, Clone)]
pub struct RnnCell {
    n: usize,
    n_in: usize,
    dynamics: Dynamics,
    activation: Activation,
    /// Per-unit thresholds ϑ (zero vector for tanh cells).
    theta: Vec<f32>,
    layout: ParamLayout,
    /// Flat parameters; masked entries are exactly 0 and stay 0.
    w: Vec<f32>,
    /// Shared recurrent mask (None = dense).
    mask: Option<MaskPattern>,
    /// Kept column indices per recurrent row (J-row / M̄-row iteration).
    row_kept: Vec<Vec<u32>>,
    /// Kept row indices per recurrent column (forward column-gather).
    col_kept: Vec<Vec<u32>>,
}

impl RnnCell {
    /// EGRU in the paper's Eq.-(5) formulation: gated drive + Heaviside.
    pub fn egru(
        n: usize,
        n_in: usize,
        theta: f32,
        gamma: f32,
        eps: f32,
        mask: Option<MaskPattern>,
        rng: &mut Pcg64,
    ) -> Self {
        Self::new(n, n_in, Dynamics::Gated, Activation::Heaviside { gamma, eps }, theta, mask, rng)
    }

    /// Thresholded vanilla RNN (EvNN) — the cell of the §4 derivation.
    pub fn evrnn(
        n: usize,
        n_in: usize,
        theta: f32,
        gamma: f32,
        eps: f32,
        mask: Option<MaskPattern>,
        rng: &mut Pcg64,
    ) -> Self {
        Self::new(n, n_in, Dynamics::Linear, Activation::Heaviside { gamma, eps }, theta, mask, rng)
    }

    /// Gated cell without activity sparsity (Fig. 3E/F control).
    pub fn gated_tanh(n: usize, n_in: usize, mask: Option<MaskPattern>, rng: &mut Pcg64) -> Self {
        Self::new(n, n_in, Dynamics::Gated, Activation::Tanh, 0.0, mask, rng)
    }

    /// Dense tanh vanilla RNN baseline.
    pub fn vanilla(n: usize, n_in: usize, mask: Option<MaskPattern>, rng: &mut Pcg64) -> Self {
        Self::new(n, n_in, Dynamics::Linear, Activation::Tanh, 0.0, mask, rng)
    }

    /// General constructor. Weights are Glorot-uniform; kept recurrent
    /// entries are rescaled by `1/sqrt(ω̃)` so the drive variance matches the
    /// dense init (standard sparse-init practice; without it the 90 %-sparse
    /// nets start below threshold and learn slowly).
    pub fn new(
        n: usize,
        n_in: usize,
        dynamics: Dynamics,
        activation: Activation,
        theta: f32,
        mask: Option<MaskPattern>,
        rng: &mut Pcg64,
    ) -> Self {
        if let Some(m) = &mask {
            assert_eq!((m.rows(), m.cols()), (n, n), "recurrent mask must be n×n");
        }
        let layout = Self::make_layout(n, n_in, dynamics);
        let mut w = vec![0.0; layout.total()];
        let rescale = mask
            .as_ref()
            .map(|m| if m.density() > 0.0 { 1.0 / m.density().sqrt() } else { 1.0 })
            .unwrap_or(1.0);
        for (b, blk) in layout.blocks().iter().enumerate() {
            let is_bias = blk.cols == 1;
            let is_recurrent = blk.cols == n && !is_bias;
            let s = if is_bias { 0.0 } else { (6.0 / (blk.rows + blk.cols) as f32).sqrt() };
            let buf = layout.block_mut(&mut w, b);
            for x in buf.iter_mut() {
                *x = if is_bias { 0.0 } else { rng.uniform(-s, s) };
            }
            if is_recurrent {
                if let Some(m) = &mask {
                    m.apply(buf);
                    for x in buf.iter_mut() {
                        *x *= rescale;
                    }
                }
            }
        }
        let (row_kept, col_kept) = Self::pattern_indices(n, mask.as_ref());
        RnnCell {
            n,
            n_in,
            dynamics,
            activation,
            theta: vec![theta; n],
            layout,
            w,
            mask,
            row_kept,
            col_kept,
        }
    }

    fn make_layout(n: usize, n_in: usize, dynamics: Dynamics) -> ParamLayout {
        match dynamics {
            Dynamics::Linear => ParamLayout::new(vec![
                ParamBlock { name: "W", rows: n, cols: n_in },
                ParamBlock { name: "V", rows: n, cols: n },
                ParamBlock { name: "b", rows: n, cols: 1 },
            ]),
            Dynamics::Gated => ParamLayout::new(vec![
                ParamBlock { name: "W_u", rows: n, cols: n_in },
                ParamBlock { name: "V_u", rows: n, cols: n },
                ParamBlock { name: "b_u", rows: n, cols: 1 },
                ParamBlock { name: "W_z", rows: n, cols: n_in },
                ParamBlock { name: "V_z", rows: n, cols: n },
                ParamBlock { name: "b_z", rows: n, cols: 1 },
            ]),
        }
    }

    fn pattern_indices(
        n: usize,
        mask: Option<&MaskPattern>,
    ) -> (Vec<Vec<u32>>, Vec<Vec<u32>>) {
        let mut row_kept = vec![Vec::new(); n];
        let mut col_kept = vec![Vec::new(); n];
        for r in 0..n {
            for c in 0..n {
                if mask.map(|m| m.is_kept(r, c)).unwrap_or(true) {
                    row_kept[r].push(c as u32);
                    col_kept[c].push(r as u32);
                }
            }
        }
        (row_kept, col_kept)
    }

    // ------------------------------------------------------------------
    // Accessors
    // ------------------------------------------------------------------

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn n_in(&self) -> usize {
        self.n_in
    }

    #[inline]
    pub fn dynamics(&self) -> Dynamics {
        self.dynamics
    }

    #[inline]
    pub fn activation(&self) -> Activation {
        self.activation
    }

    #[inline]
    pub fn layout(&self) -> &ParamLayout {
        &self.layout
    }

    /// Total parameter count `p` (dense count; masked entries included, as in
    /// the paper's `p`).
    #[inline]
    pub fn p(&self) -> usize {
        self.layout.total()
    }

    #[inline]
    pub fn params(&self) -> &[f32] {
        &self.w
    }

    #[inline]
    pub fn params_mut(&mut self) -> &mut [f32] {
        &mut self.w
    }

    #[inline]
    pub fn mask(&self) -> Option<&MaskPattern> {
        self.mask.as_ref()
    }

    /// Parameter density ω̃ of the recurrent blocks (1.0 when dense).
    pub fn omega_tilde(&self) -> f32 {
        self.mask.as_ref().map(|m| m.density()).unwrap_or(1.0)
    }

    /// Kept recurrent columns of row `k` (structural `J` row pattern).
    #[inline]
    pub fn kept_cols(&self, k: usize) -> &[u32] {
        &self.row_kept[k]
    }

    /// Kept recurrent rows of column `l` (forward gather pattern).
    #[inline]
    pub fn kept_rows_of_col(&self, l: usize) -> &[u32] {
        &self.col_kept[l]
    }

    /// Thresholds ϑ.
    #[inline]
    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Replace the recurrent sparsity mask (Deep-Rewiring-style dynamic
    /// sparsity — the extension the paper's Discussion points to via
    /// Bellec et al. 2018). Surviving entries keep their weights, dropped
    /// entries are zeroed, newly-grown entries are initialized to
    /// `U(-grow_scale, grow_scale)`. Pattern indices are rebuilt; callers
    /// must also rebuild any engine whose [`ColumnMap`] was derived from
    /// the old mask (influence columns of swapped params restart at zero,
    /// which is exact: a just-grown parameter has had no past influence).
    pub fn set_mask(&mut self, mask: MaskPattern, grow_scale: f32, rng: &mut Pcg64) {
        assert_eq!((mask.rows(), mask.cols()), (self.n, self.n), "mask must be n×n");
        let n = self.n;
        let old = self.mask.clone();
        for b in self.recurrent_blocks() {
            let buf = self.layout.block_mut(&mut self.w, b);
            for r in 0..n {
                for c in 0..n {
                    let was = old.as_ref().map(|m| m.is_kept(r, c)).unwrap_or(true);
                    let now = mask.is_kept(r, c);
                    match (was, now) {
                        (true, false) => buf[r * n + c] = 0.0,
                        (false, true) => buf[r * n + c] = rng.uniform(-grow_scale, grow_scale),
                        _ => {}
                    }
                }
            }
        }
        let (row_kept, col_kept) = Self::pattern_indices(n, Some(&mask));
        self.row_kept = row_kept;
        self.col_kept = col_kept;
        self.mask = Some(mask);
    }

    /// Re-zero masked entries (defensive hygiene after optimizer updates;
    /// gradients at masked positions are structurally zero so this is a
    /// no-op in exact arithmetic).
    pub fn enforce_mask(&mut self) {
        if let Some(mask) = self.mask.clone() {
            for b in self.recurrent_blocks() {
                mask.apply(self.layout.block_mut(&mut self.w, b));
            }
        }
    }

    /// Indices of the recurrent (masked) blocks for this dynamics.
    pub fn recurrent_blocks(&self) -> Vec<usize> {
        match self.dynamics {
            Dynamics::Linear => vec![linear_blocks::V],
            Dynamics::Gated => vec![gated_blocks::VU, gated_blocks::VZ],
        }
    }

    // ------------------------------------------------------------------
    // Forward
    // ------------------------------------------------------------------

    /// One forward step. `a_prev` is the previous activation, `x` the input.
    /// Fills `scratch` (v, a, φ', gate coefficients). Charges the forward
    /// phase with its MACs: dense `n·n_in` input terms plus the
    /// activity-×-parameter-sparse recurrent gather (`ω̃·α̃·n²` of Table 1).
    pub fn forward(&self, a_prev: &[f32], x: &[f32], scratch: &mut CellScratch, ops: &mut OpCounter) {
        assert_eq!(a_prev.len(), self.n);
        assert_eq!(x.len(), self.n_in);
        match self.dynamics {
            Dynamics::Linear => self.forward_linear(a_prev, x, scratch, ops),
            Dynamics::Gated => self.forward_gated(a_prev, x, scratch, ops),
        }
        // Activation + derivative.
        for k in 0..self.n {
            let v = scratch.v[k];
            let a = self.activation.apply(v);
            scratch.a[k] = a;
            scratch.dphi[k] = self.activation.derivative(v, a);
        }
        ops.words(Phase::Forward, 2 * self.n as u64);
    }

    /// Recurrent contribution `out[k] += Σ_l V[k,l]·a_prev[l]` as an
    /// event-driven column gather: only nonzero `a_prev[l]` (α̃n events) and
    /// kept mask entries are touched.
    fn recurrent_gather(&self, block: usize, a_prev: &[f32], out: &mut [f32], ops: &mut OpCounter) {
        let vmat = self.layout.block(&self.w, block);
        let n = self.n;
        let mut macs = 0u64;
        for (l, &al) in a_prev.iter().enumerate() {
            if al == 0.0 {
                continue;
            }
            let rows = &self.col_kept[l];
            for &k in rows {
                out[k as usize] += vmat[k as usize * n + l] * al;
            }
            macs += rows.len() as u64;
        }
        ops.macs(Phase::Forward, macs);
        ops.words(Phase::Forward, macs);
    }

    fn input_matvec(&self, block: usize, x: &[f32], out: &mut [f32], ops: &mut OpCounter) {
        let wmat = self.layout.block(&self.w, block);
        for k in 0..self.n {
            let row = &wmat[k * self.n_in..(k + 1) * self.n_in];
            let mut acc = 0.0;
            for (w, xv) in row.iter().zip(x) {
                acc += w * xv;
            }
            out[k] = acc;
        }
        ops.macs(Phase::Forward, (self.n * self.n_in) as u64);
    }

    fn forward_linear(&self, a_prev: &[f32], x: &[f32], s: &mut CellScratch, ops: &mut OpCounter) {
        use linear_blocks::*;
        self.input_matvec(W, x, &mut s.v, ops);
        self.recurrent_gather(V, a_prev, &mut s.v, ops);
        let b = self.layout.block(&self.w, B);
        for k in 0..self.n {
            s.v[k] += b[k] - self.theta[k];
        }
    }

    fn forward_gated(&self, a_prev: &[f32], x: &[f32], s: &mut CellScratch, ops: &mut OpCounter) {
        use gated_blocks::*;
        // u-gate pre-activation in s.u, z pre-activation in s.z (in place).
        self.input_matvec(WU, x, &mut s.u, ops);
        self.recurrent_gather(VU, a_prev, &mut s.u, ops);
        self.input_matvec(WZ, x, &mut s.z, ops);
        self.recurrent_gather(VZ, a_prev, &mut s.z, ops);
        let bu = self.layout.block(&self.w, BU);
        let bz = self.layout.block(&self.w, BZ);
        for k in 0..self.n {
            let u = sigmoid(s.u[k] + bu[k]);
            let z = (s.z[k] + bz[k]).tanh();
            s.u[k] = u;
            s.z[k] = z;
            s.v[k] = u * z - self.theta[k];
            s.gu[k] = z * dsigmoid_from_out(u);
            s.gz[k] = u * dtanh_from_out(z);
        }
        ops.macs(Phase::Forward, 4 * self.n as u64);
    }

    // ------------------------------------------------------------------
    // RTRL ingredients
    // ------------------------------------------------------------------

    /// `∂v_k/∂a_l` (before the `φ'` row gate). Structurally zero when the
    /// recurrent mask drops `(k,l)` — callers iterate [`Self::kept_cols`].
    #[inline]
    pub fn dv_da(&self, s: &CellScratch, k: usize, l: usize) -> f32 {
        match self.dynamics {
            Dynamics::Linear => {
                let v = self.layout.block(&self.w, linear_blocks::V);
                v[k * self.n + l]
            }
            Dynamics::Gated => {
                let vu = self.layout.block(&self.w, gated_blocks::VU);
                let vz = self.layout.block(&self.w, gated_blocks::VZ);
                s.gu[k] * vu[k * self.n + l] + s.gz[k] * vz[k * self.n + l]
            }
        }
    }

    /// MACs consumed per `dv_da` evaluation (for op accounting).
    #[inline]
    pub fn dv_da_cost(&self) -> u64 {
        match self.dynamics {
            Dynamics::Linear => 1,
            Dynamics::Gated => 2,
        }
    }

    /// `∂v_k/∂x_j` — the input-path Jacobian entry. In a [`super::LayerStack`]
    /// the input of layer `l ≥ 1` is layer `l−1`'s *new* activation, so this
    /// is the cross-layer block of the stacked Jacobian (block
    /// lower-bidiagonal structure). Input weights carry no mask, so the
    /// block is structurally dense; activity sparsity still zeroes it
    /// row-wise (φ' gate) and column-wise (inactive lower-layer rows of `M`).
    #[inline]
    pub fn dv_dx(&self, s: &CellScratch, k: usize, j: usize) -> f32 {
        match self.dynamics {
            Dynamics::Linear => {
                let w = self.layout.block(&self.w, linear_blocks::W);
                w[k * self.n_in + j]
            }
            Dynamics::Gated => {
                let wu = self.layout.block(&self.w, gated_blocks::WU);
                let wz = self.layout.block(&self.w, gated_blocks::WZ);
                s.gu[k] * wu[k * self.n_in + j] + s.gz[k] * wz[k * self.n_in + j]
            }
        }
    }

    /// MACs consumed per `dv_dx` evaluation (for op accounting).
    #[inline]
    pub fn dv_dx_cost(&self) -> u64 {
        match self.dynamics {
            Dynamics::Linear => 1,
            Dynamics::Gated => 2,
        }
    }

    /// Fill `out[i] = ∂v_k/∂a_{cols[i]}` — one row of the step-Jacobian
    /// slab ([`crate::rtrl::kernels::JacobianSlab`]). Identical arithmetic
    /// to per-entry [`Self::dv_da`] calls (bit-exact), but the dynamics
    /// dispatch and the gated `g_u/g_z` loads happen once per row instead
    /// of once per entry — the fused form the slab build runs.
    pub fn fill_dv_da_cols(&self, s: &CellScratch, k: usize, cols: &[u32], out: &mut [f32]) {
        debug_assert_eq!(cols.len(), out.len());
        let n = self.n;
        match self.dynamics {
            Dynamics::Linear => {
                let v = self.layout.block(&self.w, linear_blocks::V);
                let row = &v[k * n..(k + 1) * n];
                for (o, &c) in out.iter_mut().zip(cols) {
                    *o = row[c as usize];
                }
            }
            Dynamics::Gated => {
                let vu = self.layout.block(&self.w, gated_blocks::VU);
                let vz = self.layout.block(&self.w, gated_blocks::VZ);
                let (ru, rz) = (&vu[k * n..(k + 1) * n], &vz[k * n..(k + 1) * n]);
                let (gu, gz) = (s.gu[k], s.gz[k]);
                for (o, &c) in out.iter_mut().zip(cols) {
                    *o = gu * ru[c as usize] + gz * rz[c as usize];
                }
            }
        }
    }

    /// Fill `out[i] = ∂v_k/∂x_{cols[i]}` — one cross-layer row of the step
    /// Jacobian slab. Bit-exact with per-entry [`Self::dv_dx`] calls.
    pub fn fill_dv_dx_cols(&self, s: &CellScratch, k: usize, cols: &[u32], out: &mut [f32]) {
        debug_assert_eq!(cols.len(), out.len());
        let n_in = self.n_in;
        match self.dynamics {
            Dynamics::Linear => {
                let w = self.layout.block(&self.w, linear_blocks::W);
                let row = &w[k * n_in..(k + 1) * n_in];
                for (o, &c) in out.iter_mut().zip(cols) {
                    *o = row[c as usize];
                }
            }
            Dynamics::Gated => {
                let wu = self.layout.block(&self.w, gated_blocks::WU);
                let wz = self.layout.block(&self.w, gated_blocks::WZ);
                let (ru, rz) = (&wu[k * n_in..(k + 1) * n_in], &wz[k * n_in..(k + 1) * n_in]);
                let (gu, gz) = (s.gu[k], s.gz[k]);
                for (o, &c) in out.iter_mut().zip(cols) {
                    *o = gu * ru[c as usize] + gz * rz[c as usize];
                }
            }
        }
    }

    /// Strided variant of [`Self::fill_dv_da_cols`] for lane-interleaved
    /// batch panels: writes `out[i*stride] = ∂v_k/∂a_{cols[i]}`, leaving
    /// the other lanes' slots untouched. Identical arithmetic to the
    /// unstrided filler (bit-exact per entry) — only the destination
    /// addressing differs. `out` must span at least
    /// `(cols.len()-1)*stride + 1` elements.
    pub fn fill_dv_da_cols_strided(
        &self,
        s: &CellScratch,
        k: usize,
        cols: &[u32],
        out: &mut [f32],
        stride: usize,
    ) {
        let n = self.n;
        match self.dynamics {
            Dynamics::Linear => {
                let v = self.layout.block(&self.w, linear_blocks::V);
                let row = &v[k * n..(k + 1) * n];
                for (o, &c) in out.iter_mut().step_by(stride).zip(cols) {
                    *o = row[c as usize];
                }
            }
            Dynamics::Gated => {
                let vu = self.layout.block(&self.w, gated_blocks::VU);
                let vz = self.layout.block(&self.w, gated_blocks::VZ);
                let (ru, rz) = (&vu[k * n..(k + 1) * n], &vz[k * n..(k + 1) * n]);
                let (gu, gz) = (s.gu[k], s.gz[k]);
                for (o, &c) in out.iter_mut().step_by(stride).zip(cols) {
                    *o = gu * ru[c as usize] + gz * rz[c as usize];
                }
            }
        }
    }

    /// Strided variant of [`Self::fill_dv_dx_cols`] for lane-interleaved
    /// batch panels: writes `out[i*stride] = ∂v_k/∂x_{cols[i]}`. Bit-exact
    /// with the unstrided filler per entry.
    pub fn fill_dv_dx_cols_strided(
        &self,
        s: &CellScratch,
        k: usize,
        cols: &[u32],
        out: &mut [f32],
        stride: usize,
    ) {
        let n_in = self.n_in;
        match self.dynamics {
            Dynamics::Linear => {
                let w = self.layout.block(&self.w, linear_blocks::W);
                let row = &w[k * n_in..(k + 1) * n_in];
                for (o, &c) in out.iter_mut().step_by(stride).zip(cols) {
                    *o = row[c as usize];
                }
            }
            Dynamics::Gated => {
                let wu = self.layout.block(&self.w, gated_blocks::WU);
                let wz = self.layout.block(&self.w, gated_blocks::WZ);
                let (ru, rz) = (&wu[k * n_in..(k + 1) * n_in], &wz[k * n_in..(k + 1) * n_in]);
                let (gu, gz) = (s.gu[k], s.gz[k]);
                for (o, &c) in out.iter_mut().step_by(stride).zip(cols) {
                    *o = gu * ru[c as usize] + gz * rz[c as usize];
                }
            }
        }
    }

    /// Structural fan-in parameter indices of unit `k`: every flat parameter
    /// that can ever appear in row `k` of `M̄` (input weights, kept recurrent
    /// weights, biases), sorted ascending. This is SnAp-1's influence pattern
    /// (Menick et al. 2020) and the structural row pattern of `M̄`.
    pub fn fan_in_params(&self, k: usize) -> Vec<u32> {
        let mut out = Vec::new();
        let blocks: &[usize] = match self.dynamics {
            Dynamics::Linear => &[linear_blocks::W, linear_blocks::V, linear_blocks::B],
            Dynamics::Gated => &[
                gated_blocks::WU,
                gated_blocks::VU,
                gated_blocks::BU,
                gated_blocks::WZ,
                gated_blocks::VZ,
                gated_blocks::BZ,
            ],
        };
        for &b in blocks {
            let blk = &self.layout.blocks()[b];
            let is_recurrent = blk.cols == self.n && blk.cols != 1;
            if is_recurrent {
                let start = self.layout.row_range(b, k).start;
                for &l in &self.row_kept[k] {
                    out.push((start + l as usize) as u32);
                }
            } else {
                for pi in self.layout.row_range(b, k) {
                    out.push(pi as u32);
                }
            }
        }
        out.sort_unstable();
        out
    }

    /// Immediate influence row `k`: invokes `f(flat_param_index, ∂v_k/∂w_p)`
    /// for every *structurally nonzero* entry — unit `k`'s fan-in parameters,
    /// minus masked recurrent entries, minus recurrent/input entries whose
    /// presynaptic activation or input is zero (those have value exactly 0,
    /// the forward-activity term of `M̄`'s sparsity). Skipping `x_j = 0` is
    /// what makes stacked event-based layers cheap: layer `l ≥ 1`'s input is
    /// layer `l−1`'s activity-sparse activation vector. Returns emitted
    /// count.
    pub fn immediate_row(
        &self,
        s: &CellScratch,
        a_prev: &[f32],
        x: &[f32],
        k: usize,
        f: impl FnMut(usize, f32),
        ops: &mut OpCounter,
    ) -> u64 {
        let emitted = self.immediate_row_visit(s, a_prev, x, k, f);
        ops.macs(Phase::Immediate, emitted);
        emitted
    }

    /// [`Self::immediate_row`] without op charging — the form the parallel
    /// panel kernel calls from worker threads, where the shared
    /// [`OpCounter`] is unreachable: each row job returns its emitted count
    /// and the engine charges `Phase::Immediate` in bulk after the join.
    pub fn immediate_row_visit(
        &self,
        s: &CellScratch,
        a_prev: &[f32],
        x: &[f32],
        k: usize,
        mut f: impl FnMut(usize, f32),
    ) -> u64 {
        let mut emitted = 0u64;
        match self.dynamics {
            Dynamics::Linear => {
                use linear_blocks::*;
                let woff = self.layout.row_range(W, k).start;
                for (j, &xv) in x.iter().enumerate() {
                    if xv != 0.0 {
                        f(woff + j, xv);
                        emitted += 1;
                    }
                }
                let voff = self.layout.row_range(V, k).start;
                for &l in &self.row_kept[k] {
                    let al = a_prev[l as usize];
                    if al != 0.0 {
                        f(voff + l as usize, al);
                        emitted += 1;
                    }
                }
                f(self.layout.row_range(B, k).start, 1.0);
                emitted += 1;
            }
            Dynamics::Gated => {
                use gated_blocks::*;
                let (gu, gz) = (s.gu[k], s.gz[k]);
                let wu = self.layout.row_range(WU, k).start;
                let wz = self.layout.row_range(WZ, k).start;
                for (j, &xv) in x.iter().enumerate() {
                    if xv != 0.0 {
                        f(wu + j, gu * xv);
                        f(wz + j, gz * xv);
                        emitted += 2;
                    }
                }
                let vu = self.layout.row_range(VU, k).start;
                let vz = self.layout.row_range(VZ, k).start;
                for &l in &self.row_kept[k] {
                    let al = a_prev[l as usize];
                    if al != 0.0 {
                        f(vu + l as usize, gu * al);
                        f(vz + l as usize, gz * al);
                        emitted += 2;
                    }
                }
                f(self.layout.row_range(BU, k).start, gu);
                f(self.layout.row_range(BZ, k).start, gz);
                emitted += 2;
            }
        }
        emitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ops() -> OpCounter {
        OpCounter::new()
    }

    #[test]
    fn layout_sizes() {
        let mut rng = Pcg64::new(1);
        let egru = RnnCell::egru(16, 3, 0.1, 0.3, 0.5, None, &mut rng);
        assert_eq!(egru.p(), 2 * 16 * (3 + 16 + 1));
        let ev = RnnCell::evrnn(16, 3, 0.1, 0.3, 0.5, None, &mut rng);
        assert_eq!(ev.p(), 16 * (3 + 16 + 1));
    }

    #[test]
    fn heaviside_activations_are_binary_and_theta_shifts() {
        let mut rng = Pcg64::new(2);
        let cell = RnnCell::egru(8, 2, 0.1, 0.3, 0.5, None, &mut rng);
        let mut s = CellScratch::new(8);
        let a_prev = vec![0.0; 8];
        cell.forward(&a_prev, &[0.5, -0.3], &mut s, &mut ops());
        for k in 0..8 {
            assert!(s.a[k] == 0.0 || s.a[k] == 1.0);
            // v = u*z - theta
            assert!((s.v[k] - (s.u[k] * s.z[k] - 0.1)).abs() < 1e-6);
        }
    }

    #[test]
    fn tanh_cell_has_dense_derivative() {
        let mut rng = Pcg64::new(3);
        let cell = RnnCell::gated_tanh(8, 2, None, &mut rng);
        let mut s = CellScratch::new(8);
        cell.forward(&vec![0.1; 8], &[0.5, -0.3], &mut s, &mut ops());
        assert_eq!(s.deriv_units(), 8, "tanh derivative never exactly zero here");
    }

    /// Finite-difference check of ∂v/∂a_prev on the smooth part of the cell:
    /// perturb one presynaptic activation and compare v changes against
    /// dv_da. (The φ' factor is checked separately — it is a definition, not
    /// a derivative of a smooth function.)
    #[test]
    fn dv_da_matches_finite_difference() {
        for dynamics in [Dynamics::Linear, Dynamics::Gated] {
            let mut rng = Pcg64::new(4);
            let cell = RnnCell::new(6, 2, dynamics, Activation::Tanh, 0.0, None, &mut rng);
            let x = [0.3, -0.7];
            let a0: Vec<f32> = (0..6).map(|i| 0.1 * i as f32 - 0.2).collect();
            let mut s0 = CellScratch::new(6);
            cell.forward(&a0, &x, &mut s0, &mut ops());
            let h = 1e-3f32;
            for l in 0..6 {
                let mut ap = a0.clone();
                ap[l] += h;
                let mut s1 = CellScratch::new(6);
                cell.forward(&ap, &x, &mut s1, &mut ops());
                for k in 0..6 {
                    let fd = (s1.v[k] - s0.v[k]) / h;
                    let an = cell.dv_da(&s0, k, l);
                    assert!(
                        (fd - an).abs() < 2e-2,
                        "{dynamics:?} dv[{k}]/da[{l}]: fd={fd} analytic={an}"
                    );
                }
            }
        }
    }

    /// Finite-difference check of ∂v/∂x (the cross-layer Jacobian block a
    /// LayerStack feeds from layer l−1's new activations into layer l).
    #[test]
    fn dv_dx_matches_finite_difference() {
        for dynamics in [Dynamics::Linear, Dynamics::Gated] {
            let mut rng = Pcg64::new(14);
            let cell = RnnCell::new(5, 3, dynamics, Activation::Tanh, 0.0, None, &mut rng);
            let x0 = [0.3f32, -0.7, 0.2];
            let a0: Vec<f32> = (0..5).map(|i| 0.1 * i as f32 - 0.2).collect();
            let mut s0 = CellScratch::new(5);
            cell.forward(&a0, &x0, &mut s0, &mut ops());
            let h = 1e-3f32;
            for j in 0..3 {
                let mut xp = x0;
                xp[j] += h;
                let mut s1 = CellScratch::new(5);
                cell.forward(&a0, &xp, &mut s1, &mut ops());
                for k in 0..5 {
                    let fd = (s1.v[k] - s0.v[k]) / h;
                    let an = cell.dv_dx(&s0, k, j);
                    assert!(
                        (fd - an).abs() < 2e-2,
                        "{dynamics:?} dv[{k}]/dx[{j}]: fd={fd} analytic={an}"
                    );
                }
            }
        }
    }

    /// Zero inputs are skipped by the immediate row (event-driven M̄): they
    /// produce exactly-zero entries, so skipping is structural, not lossy.
    #[test]
    fn immediate_row_skips_zero_inputs() {
        let mut rng = Pcg64::new(15);
        let cell = RnnCell::egru(4, 3, 0.0, 0.3, 0.9, None, &mut rng);
        let a_prev = vec![1.0; 4];
        let mut s = CellScratch::new(4);
        cell.forward(&a_prev, &[0.5, 0.0, -0.2], &mut s, &mut ops());
        let mut touched = Vec::new();
        let emitted_sparse =
            cell.immediate_row(&s, &a_prev, &[0.5, 0.0, -0.2], 0, |pi, _| touched.push(pi), &mut ops());
        let emitted_dense =
            cell.immediate_row(&s, &a_prev, &[0.5, 0.1, -0.2], 0, |_, _| {}, &mut ops());
        // one zero input drops exactly two entries (W_u and W_z columns)
        assert_eq!(emitted_dense - emitted_sparse, 2);
        // the skipped flat indices are the j=1 input columns
        let wu1 = cell.layout().flat(gated_blocks::WU, 0, 1);
        let wz1 = cell.layout().flat(gated_blocks::WZ, 0, 1);
        assert!(!touched.contains(&wu1) && !touched.contains(&wz1));
    }

    /// Finite-difference check of the immediate influence ∂v_k/∂w_p.
    #[test]
    fn immediate_row_matches_finite_difference() {
        for dynamics in [Dynamics::Linear, Dynamics::Gated] {
            let mut rng = Pcg64::new(5);
            let mut cell = RnnCell::new(5, 2, dynamics, Activation::Tanh, 0.0, None, &mut rng);
            let x = [0.4, 0.9];
            let a0: Vec<f32> = (0..5).map(|i| 0.15 * i as f32 - 0.1).collect();
            let mut s0 = CellScratch::new(5);
            cell.forward(&a0, &x, &mut s0, &mut ops());
            // collect analytic rows
            let p = cell.p();
            let mut analytic = vec![vec![0.0f32; p]; 5];
            for k in 0..5 {
                let row = &mut analytic[k];
                cell.immediate_row(&s0, &a0, &x, k, |pi, val| row[pi] = val, &mut ops());
            }
            let h = 1e-3f32;
            for pi in 0..p {
                let orig = cell.params()[pi];
                cell.params_mut()[pi] = orig + h;
                let mut s1 = CellScratch::new(5);
                cell.forward(&a0, &x, &mut s1, &mut ops());
                cell.params_mut()[pi] = orig;
                for k in 0..5 {
                    let fd = (s1.v[k] - s0.v[k]) / h;
                    assert!(
                        (fd - analytic[k][pi]).abs() < 2e-2,
                        "{dynamics:?} dv[{k}]/dw[{pi}]: fd={fd} analytic={}",
                        analytic[k][pi]
                    );
                }
            }
        }
    }

    #[test]
    fn mask_zeroes_weights_and_patterns_agree() {
        let mut rng = Pcg64::new(6);
        let mask = MaskPattern::random(10, 10, 0.3, &mut rng);
        let cell = RnnCell::egru(10, 2, 0.1, 0.3, 0.5, Some(mask.clone()), &mut rng);
        assert!((cell.omega_tilde() - 0.3).abs() < 1e-6);
        // dropped entries are exactly zero in both V_u and V_z
        let vu = cell.layout().block(cell.params(), gated_blocks::VU);
        let vz = cell.layout().block(cell.params(), gated_blocks::VZ);
        for r in 0..10 {
            for c in 0..10 {
                if !mask.is_kept(r, c) {
                    assert_eq!(vu[r * 10 + c], 0.0);
                    assert_eq!(vz[r * 10 + c], 0.0);
                }
            }
        }
        // kept-pattern indices match the mask
        let total: usize = (0..10).map(|k| cell.kept_cols(k).len()).sum();
        assert_eq!(total, mask.kept());
        let total_c: usize = (0..10).map(|l| cell.kept_rows_of_col(l).len()).sum();
        assert_eq!(total_c, mask.kept());
    }

    #[test]
    fn forward_gather_matches_dense_matvec() {
        // The event-driven column gather must equal a dense matvec when all
        // activations are nonzero.
        let mut rng = Pcg64::new(7);
        let cell = RnnCell::vanilla(8, 3, None, &mut rng);
        let a_prev: Vec<f32> = (0..8).map(|i| 0.1 + 0.05 * i as f32).collect();
        let x = [0.2, -0.4, 0.6];
        let mut s = CellScratch::new(8);
        cell.forward(&a_prev, &x, &mut s, &mut ops());
        // reference: dense computation
        let wm = cell.layout().block(cell.params(), linear_blocks::W);
        let vm = cell.layout().block(cell.params(), linear_blocks::V);
        let b = cell.layout().block(cell.params(), linear_blocks::B);
        for k in 0..8 {
            let mut acc = b[k];
            for j in 0..3 {
                acc += wm[k * 3 + j] * x[j];
            }
            for l in 0..8 {
                acc += vm[k * 8 + l] * a_prev[l];
            }
            assert!((s.v[k] - acc).abs() < 1e-5, "unit {k}");
        }
    }

    #[test]
    fn forward_ops_scale_with_activity() {
        let mut rng = Pcg64::new(8);
        let cell = RnnCell::evrnn(32, 2, 0.0, 0.3, 0.5, None, &mut rng);
        let mut s = CellScratch::new(32);
        let mut dense_ops = OpCounter::new();
        cell.forward(&vec![1.0; 32], &[0.1, 0.2], &mut s, &mut dense_ops);
        let mut sparse_ops = OpCounter::new();
        let mut a = vec![0.0; 32];
        a[3] = 1.0; // one event
        cell.forward(&a, &[0.1, 0.2], &mut s, &mut sparse_ops);
        let dense_macs = dense_ops.macs_in(Phase::Forward);
        let sparse_macs = sparse_ops.macs_in(Phase::Forward);
        // gather term shrinks from 32·32 to 1·32
        assert_eq!(dense_macs - sparse_macs, (31 * 32) as u64);
    }

    #[test]
    fn enforce_mask_keeps_dropped_zero() {
        let mut rng = Pcg64::new(9);
        let mask = MaskPattern::random(6, 6, 0.5, &mut rng);
        let mut cell = RnnCell::evrnn(6, 2, 0.0, 0.3, 0.5, Some(mask.clone()), &mut rng);
        // simulate an optimizer that dirtied everything
        for w in cell.params_mut().iter_mut() {
            *w += 1.0;
        }
        cell.enforce_mask();
        let v = cell.layout().block(cell.params(), linear_blocks::V).to_vec();
        for r in 0..6 {
            for c in 0..6 {
                if !mask.is_kept(r, c) {
                    assert_eq!(v[r * 6 + c], 0.0);
                }
            }
        }
    }
}
