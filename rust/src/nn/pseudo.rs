//! Heaviside step activation with surrogate gradient (pseudo-derivative).
//!
//! Paper §4: `a = H(v)` with
//! `H'(v) = γ · max(0, 1 − |v|/ε)`,
//! so the derivative is exactly zero whenever `|v| > ε` — the condition the
//! paper uses for row sparsity ("zero derivative … because v > ε or v < −ε").
//! (The paper's Fig. 1 caption writes the width as `2ε`; we follow the text's
//! support `±ε` and expose ε, so either convention is reachable by halving ε.)
//! The fraction of units with `H' = 0` is the backward sparsity β; the
//! fraction with `a = 0` is the forward sparsity α. Reproduces Fig. 1.

/// Heaviside step: `1` if `v > 0` else `0`.
#[inline]
pub fn heaviside(v: f32) -> f32 {
    if v > 0.0 {
        1.0
    } else {
        0.0
    }
}

/// Triangular pseudo-derivative `γ·max(0, 1 − |v|/ε)`.
#[inline]
pub fn pseudo_derivative(v: f32, gamma: f32, eps: f32) -> f32 {
    let t = 1.0 - v.abs() / eps;
    if t > 0.0 {
        gamma * t
    } else {
        0.0
    }
}

/// Sampled curve of the pseudo-derivative for Fig. 1 regeneration.
pub fn curve(gamma: f32, eps: f32, lo: f32, hi: f32, points: usize) -> Vec<(f32, f32)> {
    assert!(points >= 2);
    (0..points)
        .map(|i| {
            let v = lo + (hi - lo) * i as f32 / (points - 1) as f32;
            (v, pseudo_derivative(v, gamma, eps))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heaviside_values() {
        assert_eq!(heaviside(0.5), 1.0);
        assert_eq!(heaviside(0.0), 0.0);
        assert_eq!(heaviside(-0.5), 0.0);
    }

    #[test]
    fn pseudo_peak_at_zero() {
        assert!((pseudo_derivative(0.0, 0.3, 0.5) - 0.3).abs() < 1e-7);
    }

    #[test]
    fn pseudo_zero_outside_support() {
        // Exactly zero strictly outside ±ε — the paper's sparsity condition.
        assert_eq!(pseudo_derivative(0.51, 0.3, 0.5), 0.0);
        assert_eq!(pseudo_derivative(-0.51, 0.3, 0.5), 0.0);
        assert_eq!(pseudo_derivative(10.0, 0.3, 0.5), 0.0);
    }

    #[test]
    fn pseudo_linear_inside_support() {
        let g = 0.3;
        let e = 0.5;
        assert!((pseudo_derivative(0.25, g, e) - g * 0.5).abs() < 1e-6);
        assert!((pseudo_derivative(-0.25, g, e) - g * 0.5).abs() < 1e-6);
    }

    #[test]
    fn curve_shape() {
        let c = curve(0.3, 0.5, -1.0, 1.0, 101);
        assert_eq!(c.len(), 101);
        // symmetric triangle peaking at v=0
        let peak = c.iter().cloned().fold((0.0f32, 0.0f32), |acc, p| if p.1 > acc.1 { p } else { acc });
        assert!(peak.0.abs() < 0.011);
        assert_eq!(c[0].1, 0.0);
        assert_eq!(c[100].1, 0.0);
    }
}
