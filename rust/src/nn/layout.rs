//! Flattened parameter-vector layout.
//!
//! RTRL's influence matrix `M ∈ R^{n×p}` indexes parameters by their position
//! in the flattened vector `w ∈ R^p`. [`ParamLayout`] fixes that flattening:
//! blocks in declaration order, row-major within a block. Because every
//! recurrent parameter feeds exactly one unit (its row), the layout also
//! answers the structural question behind `M̄`'s "default sparsity": which
//! slice of `w` belongs to unit `k`'s fan-in in each block.

/// One named parameter block (a weight matrix; biases are `rows × 1`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamBlock {
    pub name: &'static str,
    pub rows: usize,
    pub cols: usize,
}

/// Block-major, row-major-within-block flattening of the parameter vector.
#[derive(Debug, Clone)]
pub struct ParamLayout {
    blocks: Vec<ParamBlock>,
    offsets: Vec<usize>,
    total: usize,
}

impl ParamLayout {
    pub fn new(blocks: Vec<ParamBlock>) -> Self {
        let mut offsets = Vec::with_capacity(blocks.len());
        let mut total = 0;
        for b in &blocks {
            offsets.push(total);
            total += b.rows * b.cols;
        }
        ParamLayout { blocks, offsets, total }
    }

    /// Total parameter count `p`.
    #[inline]
    pub fn total(&self) -> usize {
        self.total
    }

    #[inline]
    pub fn blocks(&self) -> &[ParamBlock] {
        &self.blocks
    }

    /// Offset of block `b` in the flattened vector.
    #[inline]
    pub fn offset(&self, b: usize) -> usize {
        self.offsets[b]
    }

    /// Block index by name (panics if absent — layouts are static).
    pub fn block_index(&self, name: &str) -> usize {
        self.blocks
            .iter()
            .position(|b| b.name == name)
            .unwrap_or_else(|| panic!("no parameter block named {name:?}"))
    }

    /// Flat index of element `(r, c)` of block `b`.
    #[inline]
    pub fn flat(&self, b: usize, r: usize, c: usize) -> usize {
        let blk = &self.blocks[b];
        debug_assert!(r < blk.rows && c < blk.cols);
        self.offsets[b] + r * blk.cols + c
    }

    /// Flat range `[start, end)` of row `r` of block `b` — the fan-in
    /// parameters of unit `r` within that block.
    #[inline]
    pub fn row_range(&self, b: usize, r: usize) -> std::ops::Range<usize> {
        let blk = &self.blocks[b];
        debug_assert!(r < blk.rows);
        let start = self.offsets[b] + r * blk.cols;
        start..start + blk.cols
    }

    /// View of block `b` inside a flat parameter buffer.
    pub fn block<'a>(&self, w: &'a [f32], b: usize) -> &'a [f32] {
        let blk = &self.blocks[b];
        &w[self.offsets[b]..self.offsets[b] + blk.rows * blk.cols]
    }

    /// Mutable view of block `b` inside a flat parameter buffer.
    pub fn block_mut<'a>(&self, w: &'a mut [f32], b: usize) -> &'a mut [f32] {
        let blk = &self.blocks[b];
        &mut w[self.offsets[b]..self.offsets[b] + blk.rows * blk.cols]
    }

    /// Which `(block, row, col)` a flat index decodes to (reports/tests).
    pub fn decode(&self, flat: usize) -> (usize, usize, usize) {
        assert!(flat < self.total);
        let b = match self.offsets.binary_search(&flat) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        let rel = flat - self.offsets[b];
        (b, rel / self.blocks[b].cols, rel % self.blocks[b].cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> ParamLayout {
        ParamLayout::new(vec![
            ParamBlock { name: "W", rows: 3, cols: 2 },
            ParamBlock { name: "V", rows: 3, cols: 3 },
            ParamBlock { name: "b", rows: 3, cols: 1 },
        ])
    }

    #[test]
    fn totals_and_offsets() {
        let l = layout();
        assert_eq!(l.total(), 6 + 9 + 3);
        assert_eq!(l.offset(0), 0);
        assert_eq!(l.offset(1), 6);
        assert_eq!(l.offset(2), 15);
    }

    #[test]
    fn flat_and_decode_roundtrip() {
        let l = layout();
        for b in 0..3 {
            let blk = &l.blocks()[b];
            for r in 0..blk.rows {
                for c in 0..blk.cols {
                    let f = l.flat(b, r, c);
                    assert_eq!(l.decode(f), (b, r, c));
                }
            }
        }
    }

    #[test]
    fn row_range_is_fan_in() {
        let l = layout();
        assert_eq!(l.row_range(1, 2), 12..15); // V row 2
        assert_eq!(l.row_range(2, 0), 15..16); // b row 0
    }

    #[test]
    fn block_views() {
        let l = layout();
        let mut w: Vec<f32> = (0..18).map(|i| i as f32).collect();
        assert_eq!(l.block(&w, 1).len(), 9);
        assert_eq!(l.block(&w, 1)[0], 6.0);
        l.block_mut(&mut w, 2)[0] = 99.0;
        assert_eq!(w[15], 99.0);
    }

    #[test]
    fn block_index_by_name() {
        let l = layout();
        assert_eq!(l.block_index("V"), 1);
    }

    #[test]
    #[should_panic]
    fn unknown_block_panics() {
        layout().block_index("nope");
    }
}
