//! Linear readout `y = W_o a + b_o` (paper's `F_out`).
//!
//! The readout has no recurrence, so its parameters are trained with plain
//! instantaneous gradients — no influence matrix needed. Its backward pass
//! also produces the credit-assignment vector `c̄ = ∂L/∂a = W_oᵀ·∂L/∂y`
//! that RTRL combines with `M` (paper Eq. 3).

use crate::metrics::{OpCounter, Phase};
use crate::tensor::Matrix;
use crate::util::Pcg64;

/// Linear readout layer with gradient buffers.
#[derive(Debug, Clone)]
pub struct Readout {
    w: Matrix,
    b: Vec<f32>,
    grad_w: Matrix,
    grad_b: Vec<f32>,
}

impl Readout {
    pub fn new(n_out: usize, n: usize, rng: &mut Pcg64) -> Self {
        Readout {
            w: Matrix::glorot(n_out, n, rng),
            b: vec![0.0; n_out],
            grad_w: Matrix::zeros(n_out, n),
            grad_b: vec![0.0; n_out],
        }
    }

    #[inline]
    pub fn n_out(&self) -> usize {
        self.w.rows()
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.w.cols()
    }

    /// `logits = W_o a + b_o`. Event-driven: skips zero activations, so the
    /// forward cost is `α̃·n·n_out`.
    pub fn forward(&self, a: &[f32], logits: &mut [f32], ops: &mut OpCounter) {
        assert_eq!(a.len(), self.n());
        assert_eq!(logits.len(), self.n_out());
        logits.copy_from_slice(&self.b);
        let mut macs = 0u64;
        for (l, &al) in a.iter().enumerate() {
            if al == 0.0 {
                continue;
            }
            for (o, logit) in logits.iter_mut().enumerate() {
                *logit += self.w.get(o, l) * al;
            }
            macs += self.n_out() as u64;
        }
        ops.macs(Phase::Forward, macs);
    }

    /// Backward: given `dlogits = ∂L/∂y`, accumulates readout grads and
    /// writes the credit-assignment vector `c̄ = W_oᵀ dlogits` into `c_bar`.
    pub fn backward(
        &mut self,
        a: &[f32],
        dlogits: &[f32],
        c_bar: &mut [f32],
        ops: &mut OpCounter,
    ) {
        assert_eq!(dlogits.len(), self.n_out());
        assert_eq!(c_bar.len(), self.n());
        c_bar.iter_mut().for_each(|v| *v = 0.0);
        let mut macs = 0u64;
        for (o, &d) in dlogits.iter().enumerate() {
            self.grad_b[o] += d;
            if d == 0.0 {
                continue;
            }
            let wrow = self.w.row(o);
            let grow = self.grad_w.row_mut(o);
            for l in 0..c_bar.len() {
                c_bar[l] += wrow[l] * d;
                // grad only where activation nonzero (a_l = 0 ⇒ zero grad)
                if a[l] != 0.0 {
                    grow[l] += d * a[l];
                    macs += 1;
                }
                macs += 1;
            }
        }
        ops.macs(Phase::GradCombine, macs);
    }

    /// (params, grads) flattened views for the optimizer: `[W_o rows..., b_o]`.
    pub fn param_len(&self) -> usize {
        self.w.len() + self.b.len()
    }

    pub fn copy_params_into(&self, out: &mut [f32]) {
        let (wpart, bpart) = out.split_at_mut(self.w.len());
        wpart.copy_from_slice(self.w.as_slice());
        bpart.copy_from_slice(&self.b);
    }

    pub fn copy_grads_into(&self, out: &mut [f32]) {
        let (wpart, bpart) = out.split_at_mut(self.grad_w.len());
        wpart.copy_from_slice(self.grad_w.as_slice());
        bpart.copy_from_slice(&self.grad_b);
    }

    pub fn load_params(&mut self, inp: &[f32]) {
        let (wpart, bpart) = inp.split_at(self.w.len());
        self.w.as_mut_slice().copy_from_slice(wpart);
        self.b.copy_from_slice(bpart);
    }

    /// Restore accumulated gradients from a [`Readout::copy_grads_into`]
    /// buffer (session checkpoints taken mid-accumulation).
    pub fn load_grads(&mut self, inp: &[f32]) {
        let (wpart, bpart) = inp.split_at(self.grad_w.len());
        self.grad_w.as_mut_slice().copy_from_slice(wpart);
        self.grad_b.copy_from_slice(bpart);
    }

    pub fn zero_grads(&mut self) {
        self.grad_w.fill_zero();
        self.grad_b.iter_mut().for_each(|g| *g = 0.0);
    }

    /// Scale accumulated gradients (e.g. 1/batch_size).
    pub fn scale_grads(&mut self, s: f32) {
        for g in self.grad_w.as_mut_slice() {
            *g *= s;
        }
        for g in &mut self.grad_b {
            *g *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_known_values() {
        let mut rng = Pcg64::new(1);
        let mut r = Readout::new(2, 3, &mut rng);
        r.load_params(&[1.0, 0.0, 2.0, 0.0, 1.0, 0.0, 0.5, -0.5]);
        let mut logits = [0.0; 2];
        r.forward(&[1.0, 0.0, 3.0], &mut logits, &mut OpCounter::new());
        assert!((logits[0] - (1.0 + 6.0 + 0.5)).abs() < 1e-6);
        assert!((logits[1] - (0.0 + 0.0 - 0.5)).abs() < 1e-6);
    }

    #[test]
    fn forward_skips_zeros_in_op_count() {
        let mut rng = Pcg64::new(2);
        let r = Readout::new(4, 8, &mut rng);
        let mut logits = [0.0; 4];
        let mut dense = OpCounter::new();
        r.forward(&[1.0; 8], &mut logits, &mut dense);
        let mut sparse = OpCounter::new();
        let mut a = [0.0; 8];
        a[0] = 1.0;
        r.forward(&a, &mut logits, &mut sparse);
        assert_eq!(dense.macs_in(Phase::Forward), 32);
        assert_eq!(sparse.macs_in(Phase::Forward), 4);
    }

    #[test]
    fn backward_cbar_matches_transpose() {
        let mut rng = Pcg64::new(3);
        let mut r = Readout::new(2, 3, &mut rng);
        let a = [0.5, 0.0, 1.0];
        let d = [0.3, -0.7];
        let mut c_bar = [0.0; 3];
        r.backward(&a, &d, &mut c_bar, &mut OpCounter::new());
        for l in 0..3 {
            let expect = r.w.get(0, l) * d[0] + r.w.get(1, l) * d[1];
            assert!((c_bar[l] - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn backward_grads_finite_difference() {
        // Check grad_w against finite differences of L = sum(dlogits · logits)
        // for fixed dlogits (linear functional — exact).
        let mut rng = Pcg64::new(4);
        let mut r = Readout::new(2, 3, &mut rng);
        let a = [0.5, -0.2, 1.0];
        let d = [0.3, -0.7];
        r.zero_grads();
        let mut c_bar = [0.0; 3];
        r.backward(&a, &d, &mut c_bar, &mut OpCounter::new());
        let mut grads = vec![0.0; r.param_len()];
        r.copy_grads_into(&mut grads);
        // analytic: grad_w[o,l] = d[o]*a[l]; grad_b[o] = d[o]
        for o in 0..2 {
            for l in 0..3 {
                assert!((grads[o * 3 + l] - d[o] * a[l]).abs() < 1e-6);
            }
            assert!((grads[6 + o] - d[o]).abs() < 1e-6);
        }
    }

    #[test]
    fn params_roundtrip() {
        let mut rng = Pcg64::new(5);
        let mut r = Readout::new(3, 4, &mut rng);
        let mut buf = vec![0.0; r.param_len()];
        r.copy_params_into(&mut buf);
        let orig = buf.clone();
        buf.iter_mut().for_each(|x| *x += 1.0);
        r.load_params(&buf);
        r.copy_params_into(&mut buf);
        for (a, b) in buf.iter().zip(&orig) {
            assert!((a - b - 1.0).abs() < 1e-6);
        }
    }
}
