//! Neural network cells, readout and losses.
//!
//! One concrete recurrent cell type, [`RnnCell`], covers the four models in
//! the paper's experiment matrix through two orthogonal axes:
//!
//! | Model | [`Dynamics`] | [`Activation`] | Role |
//! |---|---|---|---|
//! | EGRU (paper Eq. 5 form) | `Gated` | `Heaviside` | activity-sparse experimental model |
//! | EvRNN (paper §4 derivation) | `Linear` | `Heaviside` | thresholded vanilla RNN |
//! | GatedRNN | `Gated` | `Tanh` | "without activity sparsity" arm (Fig. 3E/F) |
//! | VanillaRNN | `Linear` | `Tanh` | dense baseline (Table 1 rows) |
//!
//! All cells have the Markov form `v = G(a_prev, x; w) − ϑ`, `a = φ(v)` of
//! the paper's Eq. (1)/(5), so RTRL row-sparsity (`φ'(v_k)=0` ⇒ row `k` of
//! `J`, `M̄`, `M` is zero) holds *exactly* wherever `φ' = 0`.
//!
//! Depth is provided by [`LayerStack`] (`stack` module): an ordered stack of
//! cells where layer `l` reads layer `l−1`'s new activations, giving the
//! combined state-update Jacobian a block lower-bidiagonal structure that
//! every gradient engine in [`crate::rtrl`] operates on directly.

pub mod cell;
pub mod layout;
pub mod loss;
pub mod pseudo;
pub mod readout;
pub mod stack;

pub use cell::{Activation, CellScratch, Dynamics, RnnCell};
pub use layout::{ParamBlock, ParamLayout};
pub use loss::{Loss, LossKind};
pub use readout::Readout;
pub use stack::{LayerStack, NetworkLayout, StackScratch};
