//! `sparse-rtrl` CLI: stream, train, sweep, report, inspect artifacts.

use anyhow::{anyhow, bail, Result};
use sparse_rtrl::bench::{self, BenchConfig};
use sparse_rtrl::config::{AlgorithmKind, ExperimentConfig};
use sparse_rtrl::coordinator::{run_sweep, SweepPlan};
use sparse_rtrl::data::StepTarget;
use sparse_rtrl::report::{csv::write_text, fig1, fig2, table1};
use sparse_rtrl::runtime::{ArtifactSet, PjrtRuntime};
use sparse_rtrl::report::stats::{render_serve_summary, render_snapshot, render_trace};
use sparse_rtrl::serve::{serve_stdin, serve_unix, SchedulePolicy, Scheduler, ServeConfig};
use sparse_rtrl::session::{
    codec, EventFormat, EventReader, OnlineSession, SessionBuilder, SnapshotFormat, StreamEvent,
    UpdatePolicy,
};
use sparse_rtrl::telemetry::{
    parse_trace, TelemetryConfig, TelemetrySnapshot, TraceEventKind, TraceRecord, TraceSink,
};
use sparse_rtrl::train::{build_dataset, Trainer};
use sparse_rtrl::util::cli::Args;
use std::io::{BufRead, Write};
use std::path::{Path, PathBuf};

const USAGE: &str = "\
sparse-rtrl — Efficient RTRL through combined activity and parameter sparsity

USAGE:
  sparse-rtrl stream [--config cfg.toml] [--algorithm NAME] [--layers L]
                     [--hidden N] [--param-sparsity W] [--seed S] [--lr R]
                     [--policy every-k|sequence|manual] [--update-every K]
                     [--input events.txt|-] [--event-format auto|text|jsonl|binary]
                     [--checkpoint out.snap] [--snapshot-format auto|binary|json]
                     [--resume ck.snap] [--threads 1] [--quiet]
                     [--trace trace.jsonl] [--metrics-every K]
  sparse-rtrl serve  [--socket path.sock] [--config cfg.toml] [--algorithm NAME]
                     [--layers L] [--hidden N] [--param-sparsity W] [--seed S]
                     [--lr R] [--policy every-k|sequence|manual]
                     [--update-every K] [--threads 1] [--schedule batched|round-robin]
                     [--burst 16] [--max-resident 0] [--spill-dir serve-spill]
                     [--quiet]
  sparse-rtrl train  [--config cfg.toml] [--param-sparsity W] [--iterations N]
                     [--seed S] [--algorithm NAME] [--cell NAME] [--layers L]
                     [--threads 1] [--out results/train_curve.csv]
  sparse-rtrl sweep  [--config cfg.toml] [--seeds 5] [--iterations N]
                     [--sequences N] [--workers 0] [--algorithm NAME]
                     [--layers 1,2,..] [--out-dir results]
  sparse-rtrl bench  [--quick] [--engines a,b,..] [--hidden 16,32,..]
                     [--layers 1,2,..] [--sparsity 0.0,0.8,..]
                     [--timesteps 17] [--sequences 30] [--warmup 3]
                     [--workers 1] [--threads 1] [--batch 1,8,..]
                     [--serve-tenants 16,64,..] [--serve-events N]
                     [--serve-threads 2] [--out BENCH_rtrl.json]
  sparse-rtrl report <table1|fig1|fig2> [--n 16] [--layers 1] [--omega 0.8]
  sparse-rtrl stats  (--trace trace.jsonl | --snapshot stats.json) [--check]
  sparse-rtrl artifacts [--dir artifacts]
  sparse-rtrl analyze [--root src] [--baseline ANALYSIS_baseline.json]
                      [--check] [--json ANALYSIS_report.json] [--fix-baseline]
  sparse-rtrl config-dump            # print the default config TOML

--threads N sets the worker count for the intra-step RTRL kernels
(0 = available parallelism); results are bit-identical at any value.

bench --batch B1,B2,.. adds shared-weight batch widths to the grid:
rtrl-param cases step B lanes through one fused engine (width 1 included,
so widths compare bit-identically); other engines step the extra lanes
serially. Lane-0 gradients and op counts are batch-invariant.

serve runs a long-lived multi-tenant session server over a line protocol
(Unix socket with --socket, stdin/stdout otherwise): open/event/tick/run/
stats/drain/shutdown requests, per-tenant queues drained in rounds. Tenants
sharing one weight seed step through the fused batched path (--schedule
batched; round-robin is the per-session baseline); --max-resident N spills
idle sessions to binary snapshots in --spill-dir and re-admits them
transparently. Drained checkpoints are bit-identical to offline `stream`
runs. bench --serve-tenants/--serve-events/--serve-threads size the serve
load-generator grid of the report's v7 `serve` block.

stream formats: --resume autodetects the snapshot format from the file
bytes (binary or json). --snapshot-format auto writes binary unless the
--checkpoint path ends in .json. --event-format auto sniffs the input
(text lines, JSON lines, or binary f32 frames) from its leading bytes.

observability: stream --trace writes a JSON-lines structured trace
(schema sparse-rtrl/trace/v1); --metrics-every K samples α/β/loss/op-rate
windows every K steps (to the trace, or to stderr without --trace).
`stats` renders either artifact; --check validates without rendering.

analyze scans the library sources for determinism and panic-discipline
violations (see src/analysis/). --check exits non-zero on any violation;
--fix-baseline re-freezes the panic ratchet after paying sites down;
--json writes the machine report CI uploads.
";

/// Subcommand list for unknown-command errors (kept in sync with `main`).
const SUBCOMMANDS: &str =
    "stream, serve, train, sweep, bench, report, stats, artifacts, analyze, config-dump";

/// Engine names from the single source of truth ([`AlgorithmKind::all`],
/// the same registry `build_engine` dispatches on).
fn algorithm_names() -> String {
    AlgorithmKind::all().map(|k| k.name()).join(", ")
}

/// Resolve an engine name ("rtrl-both", "snap1", …) to its kind.
fn parse_algorithm(name: &str) -> Result<AlgorithmKind> {
    AlgorithmKind::from_name(name)
        .ok_or_else(|| anyhow!("unknown algorithm {name:?} (valid: {})", algorithm_names()))
}

fn load_config(args: &mut Args) -> Result<ExperimentConfig> {
    Ok(match args.get("config") {
        Some(p) => ExperimentConfig::from_toml(&std::fs::read_to_string(&p)?)
            .map_err(|e| anyhow!("config {p}: {e}"))?,
        None => ExperimentConfig::default(),
    })
}

/// Drive an [`OnlineSession`] from an event stream (file or stdin; text,
/// JSON-lines or binary frames). Emits one `step=… pred=… loss=… updated=…`
/// line per event and optionally writes a checkpoint at end of stream.
fn cmd_stream(mut args: Args) -> Result<()> {
    let session = match args.get("resume") {
        Some(path) => {
            for flag in ["config", "algorithm", "layers", "hidden", "param-sparsity", "seed", "lr", "policy", "update-every"] {
                if args.get(flag).is_some() {
                    bail!("--resume restores the full session (config, policy, weights); drop --{flag}");
                }
            }
            let bytes = std::fs::read(&path)
                .map_err(|e| anyhow!("cannot read checkpoint {path}: {e}"))?;
            // One ingestion entry point: the codec facade autodetects the
            // snapshot format (binary container or JSON interchange).
            let ck = codec::decode(&bytes).map_err(|e| anyhow!("{path}: {e}"))?;
            let s = OnlineSession::resume(&ck).map_err(err)?;
            eprintln!(
                "resumed session at step {} ({} updates applied, engine {})",
                s.steps(),
                s.updates_applied(),
                s.engine().name()
            );
            s
        }
        None => {
            let mut cfg = load_config(&mut args)?;
            if let Some(alg) = args.get("algorithm") {
                cfg.train.algorithm = parse_algorithm(&alg)?;
            }
            cfg.model.layers = args.get_parse("layers", cfg.model.layers).map_err(err)?;
            if cfg.model.layers == 0 {
                bail!("--layers must be ≥ 1");
            }
            cfg.model.hidden = args.get_parse("hidden", cfg.model.hidden).map_err(err)?;
            if let Some(w) = args.get("param-sparsity") {
                cfg.model.param_sparsity =
                    w.parse().map_err(|_| anyhow!("bad --param-sparsity"))?;
                if !(0.0..1.0).contains(&cfg.model.param_sparsity) {
                    bail!("--param-sparsity must be in [0,1)");
                }
            }
            cfg.seed = args.get_parse("seed", cfg.seed).map_err(err)?;
            cfg.train.lr = args.get_parse("lr", cfg.train.lr).map_err(err)?;
            let update_every: u64 = args.get_parse("update-every", 1).map_err(err)?;
            if update_every == 0 {
                bail!("--update-every must be ≥ 1");
            }
            let policy = match args.get("policy").as_deref().unwrap_or("every-k") {
                "every-k" => UpdatePolicy::EveryKSteps(update_every),
                "sequence" => UpdatePolicy::EndOfSequence,
                "manual" => UpdatePolicy::Manual,
                other => bail!("unknown policy {other:?} (valid: every-k, sequence, manual)"),
            };
            eprintln!(
                "new session: engine {}, n={}×L{}, ω={}, policy {:?}",
                cfg.train.algorithm.name(),
                cfg.model.hidden,
                cfg.model.layers,
                cfg.model.param_sparsity,
                policy
            );
            SessionBuilder::from_config(cfg).policy(policy).predict_always(true).build()
        }
    };
    let input = args.get("input").unwrap_or_else(|| "-".into());
    let checkpoint_out = args.get("checkpoint");
    let snapshot_format = match args.get("snapshot-format").as_deref().unwrap_or("auto") {
        "auto" => None,
        name => Some(SnapshotFormat::from_name(name).ok_or_else(|| {
            anyhow!("unknown --snapshot-format {name:?} (valid: auto, binary, json)")
        })?),
    };
    let event_format = match args.get("event-format").as_deref().unwrap_or("auto") {
        "auto" => None,
        name => Some(EventFormat::from_name(name).ok_or_else(|| {
            anyhow!("unknown --event-format {name:?} (valid: auto, text, jsonl, binary)")
        })?),
    };
    let quiet = args.get_bool("quiet").map_err(err)?;
    // Runtime knobs, deliberately allowed alongside --resume: thread count
    // and telemetry are not session state (results are bit-identical with
    // them at any setting).
    let threads: usize = args.get_parse("threads", 1).map_err(err)?;
    let trace_path = args.get("trace");
    let metrics_every: u64 = args.get_parse("metrics-every", 0).map_err(err)?;
    args.finish().map_err(err)?;

    let src: Box<dyn BufRead> = if input == "-" {
        Box::new(std::io::BufReader::new(std::io::stdin()))
    } else {
        Box::new(std::io::BufReader::new(
            std::fs::File::open(&input).map_err(|e| anyhow!("cannot open {input}: {e}"))?,
        ))
    };
    // `file:line:` error prefixes name stdin the conventional way.
    let input_name = if input == "-" { "<stdin>" } else { input.as_str() };
    let mut events = match event_format {
        Some(f) => EventReader::new(src, f),
        None => EventReader::autodetect(src)
            .map_err(|e| anyhow!("cannot sniff event format of {input_name}: {e}"))?,
    };
    let mut session = session;
    session.set_threads(threads);
    // Either flag turns telemetry on; --metrics-every also sets the window
    // cadence, otherwise the default cadence applies.
    let session_id = "s0";
    if trace_path.is_some() || metrics_every > 0 {
        let mut tc = TelemetryConfig::default();
        if metrics_every > 0 {
            tc.sample_every = metrics_every;
        }
        session.enable_telemetry(tc);
    }
    let mut sink = match &trace_path {
        Some(p) => {
            let f = std::fs::File::create(p)
                .map_err(|e| anyhow!("cannot create trace file {p}: {e}"))?;
            Some(TraceSink::new(std::io::BufWriter::new(f)))
        }
        None => None,
    };
    if let Some(sink) = &mut sink {
        let cfg = session.config();
        sink.emit(&TraceRecord::Meta {
            session: session_id.to_string(),
            engine: cfg.train.algorithm.name().to_string(),
            hidden: cfg.model.hidden as u64,
            layers: cfg.model.layers as u64,
            sample_every: session.telemetry().expect("telemetry on").config().sample_every,
        })?;
    }
    let stdout = std::io::stdout();
    let mut out = std::io::BufWriter::new(stdout.lock());
    while let Some(event) = events.next() {
        let event = event.map_err(|e| anyhow!("{}", e.in_file(input_name)))?;
        match event {
            StreamEvent::Update => {
                session.update_now();
                if let Some(sink) = &mut sink {
                    sink.emit(&TraceRecord::Event {
                        session: session_id.to_string(),
                        step: session.steps(),
                        event: TraceEventKind::Update,
                        bytes: None,
                        duration_ns: None,
                    })?;
                }
                if !quiet {
                    writeln!(out, "step={} update applied", session.steps())?;
                }
            }
            StreamEvent::EndSequence => {
                session.end_sequence();
                session.begin_sequence();
                if let Some(sink) = &mut sink {
                    sink.emit(&TraceRecord::Event {
                        session: session_id.to_string(),
                        step: session.steps(),
                        event: TraceEventKind::SequenceEnd,
                        bytes: None,
                        duration_ns: None,
                    })?;
                }
                if !quiet {
                    writeln!(out, "step={} sequence boundary", session.steps())?;
                }
            }
            StreamEvent::Step { x, target } => {
                if x.len() != session.net().n_in() {
                    bail!(
                        "{}: event has {} input values, session expects {}",
                        events.pos().in_file(input_name),
                        x.len(),
                        session.net().n_in()
                    );
                }
                if let StepTarget::Vector(t) = &target {
                    if t.len() != session.n_out() {
                        bail!(
                            "{}: regression target has {} values, session expects {}",
                            events.pos().in_file(input_name),
                            t.len(),
                            session.n_out()
                        );
                    }
                }
                let o = session.step(&x, target.as_target());
                if !quiet {
                    let pred = o.prediction.map_or("-".to_string(), |p| p.to_string());
                    let loss = o.loss.map_or("-".to_string(), |l| l.to_string());
                    writeln!(
                        out,
                        "step={} pred={pred} loss={loss} updated={}",
                        o.step, o.updated
                    )?;
                }
                // Emit closed metrics windows: to the trace when one is
                // open, to stderr for --metrics-every without --trace.
                if let Some(tel) = session.telemetry_mut() {
                    for point in tel.drain_new_points() {
                        match &mut sink {
                            Some(sink) => {
                                sink.emit(&TraceRecord::Span {
                                    session: session_id.to_string(),
                                    phase: "steps".to_string(),
                                    step_start: point.window_start,
                                    step_end: point.step,
                                    duration_ns: point.window_latency_ns,
                                })?;
                                sink.emit(&TraceRecord::Metrics {
                                    session: session_id.to_string(),
                                    point,
                                })?;
                            }
                            None => eprintln!(
                                "metrics step={} alpha={:.4} beta={:.4} beta_tilde={:.4} \
                                 loss_ewma={} mean_step_ns={}",
                                point.step,
                                point.alpha,
                                point.beta,
                                point.beta_tilde,
                                point
                                    .loss_ewma
                                    .map_or("-".to_string(), |l| format!("{l:.6}")),
                                point.mean_step_latency_ns()
                            ),
                        }
                    }
                }
            }
        }
    }
    out.flush()?;
    eprintln!(
        "stream done: {} steps ({} supervised), {} updates, engine state {} words",
        session.steps(),
        session.supervised_steps(),
        session.updates_applied(),
        session.state_memory_words()
    );
    if let Some(path) = checkpoint_out {
        let format = snapshot_format.unwrap_or_else(|| SnapshotFormat::for_path(&path));
        let t0 = std::time::Instant::now();
        let bytes = codec::encode(&session.checkpoint(), format);
        std::fs::write(&path, &bytes)
            .map_err(|e| anyhow!("cannot write checkpoint {path}: {e}"))?;
        if let Some(sink) = &mut sink {
            sink.emit(&TraceRecord::Event {
                session: session_id.to_string(),
                step: session.steps(),
                event: TraceEventKind::Checkpoint,
                bytes: Some(bytes.len() as u64),
                duration_ns: Some(t0.elapsed().as_nanos() as u64),
            })?;
        }
        eprintln!("checkpoint written to {path} ({format}, {} bytes)", bytes.len());
    }
    if let Some(sink) = &mut sink {
        sink.flush()?;
        let path = trace_path.as_deref().unwrap_or("?");
        eprintln!("trace written to {path} ({} records)", sink.records());
    }
    Ok(())
}

/// Run the multi-tenant session server: per-tenant event queues drained in
/// rounds (shared-weight tenants step through the fused batched path), LRU
/// spill to binary snapshots under `--max-resident`, line protocol over a
/// Unix socket (`--socket`) or stdin/stdout.
fn cmd_serve(mut args: Args) -> Result<()> {
    let mut cfg = load_config(&mut args)?;
    if let Some(alg) = args.get("algorithm") {
        cfg.train.algorithm = parse_algorithm(&alg)?;
    }
    cfg.model.layers = args.get_parse("layers", cfg.model.layers).map_err(err)?;
    if cfg.model.layers == 0 {
        bail!("--layers must be ≥ 1");
    }
    cfg.model.hidden = args.get_parse("hidden", cfg.model.hidden).map_err(err)?;
    if let Some(w) = args.get("param-sparsity") {
        cfg.model.param_sparsity = w.parse().map_err(|_| anyhow!("bad --param-sparsity"))?;
        if !(0.0..1.0).contains(&cfg.model.param_sparsity) {
            bail!("--param-sparsity must be in [0,1)");
        }
    }
    cfg.seed = args.get_parse("seed", cfg.seed).map_err(err)?;
    cfg.train.lr = args.get_parse("lr", cfg.train.lr).map_err(err)?;
    let update_every: u64 = args.get_parse("update-every", 1).map_err(err)?;
    if update_every == 0 {
        bail!("--update-every must be ≥ 1");
    }
    let policy = match args.get("policy").as_deref().unwrap_or("every-k") {
        "every-k" => UpdatePolicy::EveryKSteps(update_every),
        "sequence" => UpdatePolicy::EndOfSequence,
        "manual" => UpdatePolicy::Manual,
        other => bail!("unknown policy {other:?} (valid: every-k, sequence, manual)"),
    };
    let threads: usize = args.get_parse("threads", 1).map_err(err)?;
    let max_resident: usize = args.get_parse("max-resident", 0).map_err(err)?;
    let burst: usize = args.get_parse("burst", 16).map_err(err)?;
    if burst == 0 {
        bail!("--burst must be ≥ 1");
    }
    let schedule = {
        let name = args.get("schedule").unwrap_or_else(|| "batched".into());
        SchedulePolicy::from_name(&name).ok_or_else(|| {
            anyhow!("unknown --schedule {name:?} (valid: batched, round-robin)")
        })?
    };
    let spill_dir: PathBuf = args.get("spill-dir").unwrap_or_else(|| "serve-spill".into()).into();
    let socket = args.get("socket");
    let quiet = args.get_bool("quiet").map_err(err)?;
    args.finish().map_err(err)?;

    if !quiet {
        eprintln!(
            "serve: engine {}, n={}×L{}, ω={}, policy {policy:?}, schedule {}, burst {burst}, \
             max-resident {max_resident}, threads {threads}",
            cfg.train.algorithm.name(),
            cfg.model.hidden,
            cfg.model.layers,
            cfg.model.param_sparsity,
            schedule.name(),
        );
    }
    let serve_cfg =
        ServeConfig { base: cfg, policy, threads, max_resident, burst, spill_dir, schedule };
    let mut sched = Scheduler::new(serve_cfg).map_err(|e| anyhow!("{e}"))?;
    match socket {
        Some(path) => {
            serve_unix(&mut sched, Path::new(&path), quiet).map_err(|e| anyhow!("{e}"))?
        }
        None => serve_stdin(&mut sched).map_err(|e| anyhow!("{e}"))?,
    }
    if !quiet {
        let snap = sched.stats();
        eprint!("{}", render_serve_summary(&snap, sched.recorder(), sched.rounds()));
    }
    Ok(())
}

/// Render telemetry artifacts: a JSON-lines trace (`stream --trace`) or a
/// serialized [`TelemetrySnapshot`]. `--check` validates a trace against
/// the schema and prints a one-line summary instead of rendering.
fn cmd_stats(mut args: Args) -> Result<()> {
    let trace = args.get("trace");
    let snapshot = args.get("snapshot");
    let check = args.get_bool("check").map_err(err)?;
    args.finish().map_err(err)?;
    match (trace, snapshot) {
        (Some(path), None) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow!("cannot read trace {path}: {e}"))?;
            let records = parse_trace(&text).map_err(|e| anyhow!("{path}: {e}"))?;
            if check {
                println!("trace OK: {} record(s) in {path}", records.len());
            } else {
                print!("{}", render_trace(&records));
            }
        }
        (None, Some(path)) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow!("cannot read snapshot {path}: {e}"))?;
            let snap = TelemetrySnapshot::from_json(&text).map_err(|e| anyhow!("{path}: {e}"))?;
            if check {
                println!("snapshot OK: {} session(s) in {path}", snap.sessions.len());
            } else {
                print!("{}", render_snapshot(&snap));
            }
        }
        _ => bail!("stats needs exactly one of --trace <file> or --snapshot <file>"),
    }
    Ok(())
}

fn cmd_train(mut args: Args) -> Result<()> {
    let mut cfg = load_config(&mut args)?;
    if let Some(w) = args.get("param-sparsity") {
        cfg.model.param_sparsity = w.parse().map_err(|_| anyhow!("bad --param-sparsity"))?;
    }
    cfg.train.iterations = args.get_parse("iterations", cfg.train.iterations).map_err(err)?;
    cfg.seed = args.get_parse("seed", cfg.seed).map_err(err)?;
    if let Some(alg) = args.get("algorithm") {
        cfg.train.algorithm = parse_algorithm(&alg)?;
    }
    if let Some(cell) = args.get("cell") {
        cfg.model.cell = sparse_rtrl::config::CellKind::from_name(&cell)
            .ok_or_else(|| anyhow!("unknown cell {cell:?} (egru|ev_rnn|gated_tanh|vanilla)"))?;
    }
    cfg.model.layers = args.get_parse("layers", cfg.model.layers).map_err(err)?;
    if cfg.model.layers == 0 {
        bail!("--layers must be ≥ 1");
    }
    let threads: usize = args.get_parse("threads", 1).map_err(err)?;
    let out: PathBuf = args.get("out").unwrap_or_else(|| "results/train_curve.csv".into()).into();
    args.finish().map_err(err)?;

    eprintln!(
        "training {} (alg={}, ω={}, L={}, {} iters)",
        cfg.name,
        cfg.train.algorithm.name(),
        cfg.model.param_sparsity,
        cfg.model.layers,
        cfg.train.iterations
    );
    let mut data_rng = Trainer::data_rng(cfg.seed);
    let (train, val) = build_dataset(&cfg, &mut data_rng);
    let mut trainer = Trainer::new(cfg);
    trainer.set_threads(threads);
    let outcome = trainer.train(&train, &val);
    println!(
        "final val accuracy: {:.4}\ntotal MACs: {}\nstate memory (words): {}",
        outcome.final_val_accuracy,
        outcome.ops.total_macs(),
        outcome.state_memory_words
    );
    println!("{}", outcome.ops.report());
    write_text(&out, &outcome.curve.to_csv())?;
    eprintln!("curve written to {}", out.display());
    Ok(())
}

fn cmd_sweep(mut args: Args) -> Result<()> {
    let mut base = load_config(&mut args)?;
    base.train.iterations = args.get_parse("iterations", base.train.iterations).map_err(err)?;
    base.task.num_sequences = args.get_parse("sequences", base.task.num_sequences).map_err(err)?;
    let seeds: usize = args.get_parse("seeds", 5).map_err(err)?;
    let workers: usize = args.get_parse("workers", 0).map_err(err)?;
    let engine_override = match args.get("algorithm") {
        Some(alg) => Some(parse_algorithm(&alg)?),
        None => None,
    };
    let layers = match args.get("layers") {
        Some(s) => {
            let l: Vec<usize> = parse_csv(&s, "layers")?;
            if l.iter().any(|&d| d == 0) {
                bail!("--layers depths must be ≥ 1");
            }
            Some(l)
        }
        None => None,
    };
    let out_dir: PathBuf = args.get("out-dir").unwrap_or_else(|| "results".into()).into();
    args.finish().map_err(err)?;

    let mut plan = SweepPlan::fig3(base, seeds);
    plan.max_workers = workers;
    plan.engine_override = engine_override;
    if let Some(l) = layers {
        plan.layers = l;
    }
    let result = run_sweep(&plan, true);
    write_text(&out_dir.join("fig3_runs.csv"), &result.to_long_csv())?;
    write_text(&out_dir.join("fig3_summary.csv"), &result.to_summary_csv())?;
    eprintln!("wrote {0}/fig3_runs.csv and {0}/fig3_summary.csv", out_dir.display());
    Ok(())
}

/// Parse a comma-separated flag value into a typed list.
fn parse_csv<T: std::str::FromStr>(raw: &str, flag: &str) -> Result<Vec<T>> {
    raw.split(',')
        .map(|s| {
            s.trim()
                .parse::<T>()
                .map_err(|_| anyhow!("--{flag}: cannot parse {:?}", s.trim()))
        })
        .collect()
}

fn cmd_bench(mut args: Args) -> Result<()> {
    let quick = args.get_bool("quick").map_err(err)?;
    let mut cfg = if quick { BenchConfig::quick() } else { BenchConfig::full() };
    if let Some(s) = args.get("engines") {
        cfg.engines =
            s.split(',').map(|name| parse_algorithm(name.trim())).collect::<Result<Vec<_>>>()?;
    }
    if let Some(s) = args.get("hidden") {
        cfg.hidden_sizes = parse_csv(&s, "hidden")?;
    }
    if let Some(s) = args.get("layers") {
        cfg.layers = parse_csv(&s, "layers")?;
        if cfg.layers.iter().any(|&l| l == 0) {
            bail!("--layers depths must be ≥ 1");
        }
    }
    if let Some(s) = args.get("sparsity") {
        cfg.param_sparsities = parse_csv(&s, "sparsity")?;
        if cfg.param_sparsities.iter().any(|w| !(0.0..1.0).contains(w)) {
            bail!("--sparsity values must be in [0,1)");
        }
    }
    cfg.timesteps = args.get_parse("timesteps", cfg.timesteps).map_err(err)?;
    cfg.sequences = args.get_parse("sequences", cfg.sequences).map_err(err)?;
    cfg.warmup_sequences = args.get_parse("warmup", cfg.warmup_sequences).map_err(err)?;
    cfg.workers = args.get_parse("workers", cfg.workers).map_err(err)?;
    cfg.threads = args.get_parse("threads", cfg.threads).map_err(err)?;
    if let Some(s) = args.get("batch") {
        cfg.batches = parse_csv(&s, "batch")?;
        if cfg.batches.iter().any(|&b| b == 0) {
            bail!("--batch widths must be ≥ 1");
        }
    }
    if let Some(s) = args.get("serve-tenants") {
        cfg.serve_tenants = parse_csv(&s, "serve-tenants")?;
        if cfg.serve_tenants.iter().any(|&t| t == 0) {
            bail!("--serve-tenants counts must be ≥ 1");
        }
    }
    cfg.serve_events = args.get_parse("serve-events", cfg.serve_events).map_err(err)?;
    cfg.serve_threads = args.get_parse("serve-threads", cfg.serve_threads).map_err(err)?;
    let out: PathBuf = args.get("out").unwrap_or_else(|| "BENCH_rtrl.json".into()).into();
    args.finish().map_err(err)?;
    if cfg.engines.is_empty()
        || cfg.hidden_sizes.is_empty()
        || cfg.layers.is_empty()
        || cfg.param_sparsities.is_empty()
    {
        bail!("bench grid is empty");
    }
    if cfg.hidden_sizes.iter().any(|&n| n == 0) {
        bail!("--hidden sizes must be positive");
    }
    if cfg.timesteps == 0 || cfg.sequences == 0 {
        bail!("--timesteps and --sequences must be positive");
    }
    if cfg.batches.is_empty() {
        bail!("--batch needs at least one width");
    }

    eprintln!(
        "bench: {} engines × {} sizes × {} depths × {} sparsities × {} batch widths, \
         T={}, {} sequences/case{}",
        cfg.engines.len(),
        cfg.hidden_sizes.len(),
        cfg.layers.len(),
        cfg.param_sparsities.len(),
        cfg.batches.len(),
        cfg.timesteps,
        cfg.sequences,
        if cfg.quick { " (quick)" } else { "" },
    );
    let report = bench::run(&cfg, true);
    print!("{}", report.summary_table());
    write_text(&out, &report.to_json())?;
    eprintln!("bench report written to {}", out.display());
    Ok(())
}

fn cmd_report(mut args: Args) -> Result<()> {
    let what = args.pos(1).map(str::to_string).ok_or_else(|| anyhow!("report needs a target"))?;
    let n: usize = args.get_parse("n", 16).map_err(err)?;
    let layers: usize = args.get_parse("layers", 1).map_err(err)?;
    if layers == 0 {
        bail!("--layers must be ≥ 1");
    }
    let omega: f32 = args.get_parse("omega", 0.8).map_err(err)?;
    args.finish().map_err(err)?;
    match what.as_str() {
        "table1" => println!("{}", table1::render(n, layers, omega, 17)),
        "fig1" => println!("{}", fig1::render(0.3, 0.5)),
        "fig2" => println!("{}", fig2::render()),
        other => bail!("unknown report {other:?} (try table1|fig1|fig2)"),
    }
    Ok(())
}

fn cmd_artifacts(mut args: Args) -> Result<()> {
    let dir: PathBuf = args.get("dir").unwrap_or_else(|| "artifacts".into()).into();
    args.finish().map_err(err)?;
    let set = ArtifactSet::open(&dir);
    let list = set.list();
    if list.is_empty() {
        println!("no artifacts in {} — run `make artifacts`", dir.display());
        return Ok(());
    }
    if !PjrtRuntime::available() {
        println!("found {} artifact(s) in {}:", list.len(), dir.display());
        for name in &list {
            println!("  {name}");
        }
        println!(
            "(PJRT support not compiled in — add the `xla` dep to rust/Cargo.toml and \
             rebuild with `--features pjrt` to load them)"
        );
        return Ok(());
    }
    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform_name());
    for name in list {
        match rt.load(&set.path(&name)) {
            Ok(_) => println!("  {name}: loads + compiles OK"),
            Err(e) => println!("  {name}: ERROR {e:#}"),
        }
    }
    Ok(())
}

fn cmd_analyze(mut args: Args) -> Result<()> {
    // Default roots: `rust/src` from the repo root, `src` from `rust/`
    // (the CI working directory). The baseline lives next to the `rust`
    // directory either way.
    let root: PathBuf = match args.get("root") {
        Some(r) => r.into(),
        None if PathBuf::from("rust/src").is_dir() => "rust/src".into(),
        None => "src".into(),
    };
    let baseline_path: PathBuf = match args.get("baseline") {
        Some(b) => b.into(),
        None => root
            .parent()
            .map(|p| p.join("../ANALYSIS_baseline.json"))
            .unwrap_or_else(|| "ANALYSIS_baseline.json".into()),
    };
    let check = args.get_bool("check").map_err(err)?;
    let fix = args.get_bool("fix-baseline").map_err(err)?;
    let json_out: Option<PathBuf> = args.get("json").map(PathBuf::from);
    args.finish().map_err(err)?;

    let findings = sparse_rtrl::analysis::analyze_tree(&root).map_err(err)?;
    if fix {
        let old_total = sparse_rtrl::analysis::Baseline::load(&baseline_path)
            .map(|b| b.total())
            .unwrap_or(0);
        let fresh = sparse_rtrl::analysis::fresh_baseline(&findings);
        fresh.save(&baseline_path).map_err(err)?;
        println!(
            "baseline {}: panic allowance {old_total} -> {} across {} file(s)",
            baseline_path.display(),
            fresh.total(),
            fresh.files.len()
        );
    }
    let baseline = sparse_rtrl::analysis::Baseline::load(&baseline_path).map_err(err)?;
    let report = sparse_rtrl::analysis::build_report(&findings, &baseline);
    print!("{}", report.render_text());
    if let Some(path) = json_out {
        std::fs::write(&path, report.render_json(&baseline))
            .map_err(|e| anyhow!("cannot write {}: {e}", path.display()))?;
        println!("wrote {}", path.display());
    }
    if check && !report.clean() {
        bail!("analyze --check: {} violation(s)", report.violations.len());
    }
    Ok(())
}

fn err(e: String) -> anyhow::Error {
    anyhow!(e)
}

fn main() -> Result<()> {
    let args = Args::from_env().map_err(err)?;
    match args.pos(0) {
        Some("stream") => cmd_stream(args),
        Some("serve") => cmd_serve(args),
        Some("train") => cmd_train(args),
        Some("sweep") => cmd_sweep(args),
        Some("bench") => cmd_bench(args),
        Some("report") => cmd_report(args),
        Some("stats") => cmd_stats(args),
        Some("artifacts") => cmd_artifacts(args),
        Some("analyze") => cmd_analyze(args),
        Some("config-dump") => {
            print!("{}", ExperimentConfig::default().to_toml());
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?} (valid: {SUBCOMMANDS})");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
        None => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}
