//! Magnitude-based dynamic rewiring (SET / Deep-Rewiring style).
//!
//! The paper trains with a *fixed* random mask but its Discussion points to
//! Bellec et al. (2018) for "optimising the parameter sparsity pattern
//! during training". This module implements the standard
//! magnitude-drop / random-grow step at **constant density**, so all the
//! sparse-RTRL cost guarantees (`ω̃` stays fixed) continue to hold:
//!
//! 1. drop the `swap_fraction` of kept recurrent entries with the smallest
//!    combined magnitude across the recurrent blocks;
//! 2. grow the same number of connections at uniformly random vacant slots.
//!
//! Column-structural exactness is preserved: a dropped parameter's influence
//! column becomes structurally zero, a grown parameter starts with zero past
//! influence — both exactly what resetting the engine's `ColumnMap` yields.

use super::mask::MaskPattern;
use crate::nn::RnnCell;
use crate::util::math::sum_f32;
use crate::util::Pcg64;

/// One rewiring step. Returns the new mask (same density as the cell's
/// current mask) without applying it; pass it to [`RnnCell::set_mask`].
///
/// `swap_fraction` ∈ [0,1]: fraction of kept entries to relocate.
pub fn magnitude_rewire(cell: &RnnCell, swap_fraction: f32, rng: &mut Pcg64) -> MaskPattern {
    let mask = cell.mask().expect("rewiring requires a masked cell").clone();
    let n = cell.n();
    assert!((0.0..=1.0).contains(&swap_fraction));
    let kept = mask.kept();
    let swaps = ((kept as f32) * swap_fraction).round() as usize;
    if swaps == 0 {
        return mask;
    }
    // score kept entries by the summed |w| across recurrent blocks (V for
    // linear cells, V_u + V_z for gated ones — a connection exists in both)
    let layout = cell.layout();
    let blocks = cell.recurrent_blocks();
    let mut scored: Vec<(f32, usize)> = Vec::with_capacity(kept);
    for r in 0..n {
        for c in 0..n {
            if mask.is_kept(r, c) {
                let score = sum_f32(
                    blocks.iter().map(|&b| layout.block(cell.params(), b)[r * n + c].abs()),
                );
                scored.push((score, r * n + c));
            }
        }
    }
    scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut keep: Vec<bool> = mask.as_bools().to_vec();
    for &(_, idx) in scored.iter().take(swaps) {
        keep[idx] = false;
    }
    // grow at random vacant slots
    let vacant: Vec<usize> = (0..n * n).filter(|&i| !keep[i]).collect();
    for &slot in rng.choose_k(vacant.len(), swaps).iter() {
        keep[vacant[slot]] = true;
    }
    let new_mask = MaskPattern::from_bools(n, n, keep);
    debug_assert_eq!(new_mask.kept(), kept, "rewiring must preserve density");
    new_mask
}

#[cfg(test)]
mod tests {
    use super::*;

    fn masked_cell(seed: u64, density: f32) -> RnnCell {
        let mut rng = Pcg64::new(seed);
        let mask = MaskPattern::random(12, 12, density, &mut rng);
        RnnCell::egru(12, 2, 0.1, 0.3, 0.5, Some(mask), &mut rng)
    }

    #[test]
    fn preserves_density() {
        let cell = masked_cell(1, 0.3);
        let mut rng = Pcg64::new(9);
        let new = magnitude_rewire(&cell, 0.25, &mut rng);
        assert_eq!(new.kept(), cell.mask().unwrap().kept());
    }

    #[test]
    fn swaps_the_requested_fraction() {
        let cell = masked_cell(2, 0.3);
        let old = cell.mask().unwrap().clone();
        let mut rng = Pcg64::new(10);
        let new = magnitude_rewire(&cell, 0.25, &mut rng);
        let moved = old
            .as_bools()
            .iter()
            .zip(new.as_bools())
            .filter(|(a, b)| **a && !**b)
            .count();
        let expected = ((old.kept() as f32) * 0.25).round() as usize;
        // random growth can land on just-dropped slots, so moved ≤ expected
        assert!(moved <= expected && moved >= expected / 2, "moved {moved} vs {expected}");
    }

    #[test]
    fn drops_smallest_magnitudes() {
        let mut cell = masked_cell(3, 0.3);
        // force one kept entry to be enormous: it must survive
        let (r, c) = {
            let m = cell.mask().unwrap();
            let mut found = (0, 0);
            'outer: for r in 0..12 {
                for c in 0..12 {
                    if m.is_kept(r, c) {
                        found = (r, c);
                        break 'outer;
                    }
                }
            }
            found
        };
        let blocks = cell.recurrent_blocks();
        let layout = cell.layout().clone();
        for &b in &blocks {
            layout.block_mut(cell.params_mut(), b)[r * 12 + c] = 100.0;
        }
        let mut rng = Pcg64::new(11);
        let new = magnitude_rewire(&cell, 0.5, &mut rng);
        assert!(new.is_kept(r, c), "large weight must not be dropped");
    }

    #[test]
    fn set_mask_roundtrip_zeroes_and_grows() {
        let mut cell = masked_cell(4, 0.3);
        let old = cell.mask().unwrap().clone();
        let mut rng = Pcg64::new(12);
        let new = magnitude_rewire(&cell, 0.3, &mut rng);
        cell.set_mask(new.clone(), 0.1, &mut rng);
        let n = 12;
        let layout = cell.layout();
        for &b in &cell.recurrent_blocks() {
            let buf = layout.block(cell.params(), b);
            for r in 0..n {
                for c in 0..n {
                    if !new.is_kept(r, c) {
                        assert_eq!(buf[r * n + c], 0.0);
                    } else if !old.is_kept(r, c) {
                        let v = buf[r * n + c];
                        assert!(v.abs() <= 0.1, "grown weight out of init range: {v}");
                    }
                }
            }
        }
        // pattern indices rebuilt consistently
        let total: usize = (0..n).map(|k| cell.kept_cols(k).len()).sum();
        assert_eq!(total, new.kept());
    }

    #[test]
    fn zero_fraction_is_identity() {
        let cell = masked_cell(5, 0.4);
        let mut rng = Pcg64::new(13);
        let new = magnitude_rewire(&cell, 0.0, &mut rng);
        assert_eq!(&new, cell.mask().unwrap());
    }
}
