//! Fixed boolean sparsity masks over weight matrices.
//!
//! The paper fixes "a random sparsity mask at initialisation and train[s] the
//! network with this sparsity mask throughout" (§6). The mask has an *exact*
//! number of kept entries so the measured ω̃ matches the configured one.

use crate::util::Pcg64;

/// Boolean keep/drop pattern over a `rows × cols` matrix (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct MaskPattern {
    rows: usize,
    cols: usize,
    keep: Vec<bool>,
    kept: usize,
}

impl MaskPattern {
    /// Fully dense mask (all entries kept).
    pub fn dense(rows: usize, cols: usize) -> Self {
        MaskPattern { rows, cols, keep: vec![true; rows * cols], kept: rows * cols }
    }

    /// Random mask keeping exactly `round(density·rows·cols)` entries.
    /// `density = ω̃ = 1 − ω` where ω is the paper's parameter sparsity.
    pub fn random(rows: usize, cols: usize, density: f32, rng: &mut Pcg64) -> Self {
        assert!((0.0..=1.0).contains(&density), "density must be in [0,1]");
        let total = rows * cols;
        let kept = ((density as f64) * total as f64).round() as usize;
        let mut keep = vec![false; total];
        for i in rng.choose_k(total, kept) {
            keep[i] = true;
        }
        MaskPattern { rows, cols, keep, kept }
    }

    /// Mask from an explicit pattern.
    pub fn from_bools(rows: usize, cols: usize, keep: Vec<bool>) -> Self {
        assert_eq!(keep.len(), rows * cols);
        let kept = keep.iter().filter(|&&k| k).count();
        MaskPattern { rows, cols, keep, kept }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Whether entry `(r, c)` is kept (trainable / nonzero).
    #[inline]
    pub fn is_kept(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols);
        self.keep[r * self.cols + c]
    }

    /// Flat row-major view of the pattern.
    #[inline]
    pub fn as_bools(&self) -> &[bool] {
        &self.keep
    }

    /// Number of kept entries.
    #[inline]
    pub fn kept(&self) -> usize {
        self.kept
    }

    /// Achieved density ω̃ (kept / total).
    pub fn density(&self) -> f32 {
        if self.keep.is_empty() {
            1.0
        } else {
            self.kept as f32 / self.keep.len() as f32
        }
    }

    /// Zero out dropped entries of a row-major weight buffer in place.
    pub fn apply(&self, weights: &mut [f32]) {
        assert_eq!(weights.len(), self.keep.len());
        for (w, &k) in weights.iter_mut().zip(&self.keep) {
            if !k {
                *w = 0.0;
            }
        }
    }

    /// Kept column indices of row `r` (allocates; used at build time only).
    pub fn row_kept_cols(&self, r: usize) -> Vec<usize> {
        (0..self.cols).filter(|&c| self.is_kept(r, c)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_mask_keeps_everything() {
        let m = MaskPattern::dense(3, 4);
        assert_eq!(m.kept(), 12);
        assert_eq!(m.density(), 1.0);
    }

    #[test]
    fn random_mask_exact_count() {
        let mut rng = Pcg64::new(1);
        let m = MaskPattern::random(10, 10, 0.2, &mut rng);
        assert_eq!(m.kept(), 20);
        assert!((m.density() - 0.2).abs() < 1e-6);
    }

    #[test]
    fn random_mask_deterministic() {
        let a = MaskPattern::random(8, 8, 0.5, &mut Pcg64::new(7));
        let b = MaskPattern::random(8, 8, 0.5, &mut Pcg64::new(7));
        assert_eq!(a, b);
    }

    #[test]
    fn apply_zeroes_dropped() {
        let mut rng = Pcg64::new(2);
        let m = MaskPattern::random(4, 4, 0.25, &mut rng);
        let mut w = vec![1.0f32; 16];
        m.apply(&mut w);
        let nonzero = w.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nonzero, m.kept());
    }

    #[test]
    fn extreme_densities() {
        let mut rng = Pcg64::new(3);
        assert_eq!(MaskPattern::random(5, 5, 0.0, &mut rng).kept(), 0);
        assert_eq!(MaskPattern::random(5, 5, 1.0, &mut rng).kept(), 25);
    }

    #[test]
    fn row_kept_cols_consistent() {
        let mut rng = Pcg64::new(4);
        let m = MaskPattern::random(6, 6, 0.5, &mut rng);
        let total: usize = (0..6).map(|r| m.row_kept_cols(r).len()).sum();
        assert_eq!(total, m.kept());
        for r in 0..6 {
            for c in m.row_kept_cols(r) {
                assert!(m.is_kept(r, c));
            }
        }
    }
}
