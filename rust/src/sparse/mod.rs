//! Sparse structure substrate: fixed masks, CSR weight storage, active-row
//! sets.
//!
//! The paper's two sparsity axes map onto two structures:
//!
//! * **parameter sparsity** (fixed at init) — [`MaskPattern`] boolean masks
//!   over weight matrices, with a [`Csr`] compaction for the recurrent
//!   matrices so the forward pass and Jacobian sweep cost `ω̃n²` rather
//!   than `n²`;
//! * **activity sparsity** (changes every step) — [`RowSet`] active-row sets
//!   tracking which units have nonzero pseudo-derivative (`β̃n` rows of
//!   `J`/`M̄`/`M`) or nonzero activation (`α̃n` forward events).

pub mod csr;
pub mod mask;
pub mod rewire;
pub mod rowset;

pub use csr::Csr;
pub use mask::MaskPattern;
pub use rowset::RowSet;
