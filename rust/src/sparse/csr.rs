//! CSR (compressed sparse row) storage with a *fixed pattern* and mutable
//! values.
//!
//! Used for masked recurrent weight matrices: the pattern is frozen at
//! initialisation (paper §6) while the kept values keep training. The sparse
//! mat-vec is the `ω̃n²` forward-pass term of Table 1, and the row iterator
//! drives the `ω̃`-sparse Jacobian sweep in the RTRL engines.

use super::mask::MaskPattern;

/// Fixed-pattern CSR matrix.
#[derive(Debug, Clone)]
pub struct Csr {
    rows: usize,
    cols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<usize>,
    vals: Vec<f32>,
}

impl Csr {
    /// Build from a mask pattern and a dense row-major value buffer; dropped
    /// entries are discarded.
    pub fn from_mask(mask: &MaskPattern, dense: &[f32]) -> Self {
        assert_eq!(dense.len(), mask.rows() * mask.cols());
        let (rows, cols) = (mask.rows(), mask.cols());
        let mut row_ptr = Vec::with_capacity(rows + 1);
        let mut col_idx = Vec::with_capacity(mask.kept());
        let mut vals = Vec::with_capacity(mask.kept());
        row_ptr.push(0);
        for r in 0..rows {
            for c in 0..cols {
                if mask.is_kept(r, c) {
                    col_idx.push(c);
                    vals.push(dense[r * cols + c]);
                }
            }
            row_ptr.push(col_idx.len());
        }
        Csr { rows, cols, row_ptr, col_idx, vals }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored (kept) entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// `(column indices, values)` of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> (&[usize], &[f32]) {
        let (s, e) = (self.row_ptr[r], self.row_ptr[r + 1]);
        (&self.col_idx[s..e], &self.vals[s..e])
    }

    /// Mutable values of row `r` (pattern itself is immutable).
    #[inline]
    pub fn row_vals_mut(&mut self, r: usize) -> &mut [f32] {
        let (s, e) = (self.row_ptr[r], self.row_ptr[r + 1]);
        &mut self.vals[s..e]
    }

    /// Refresh values from a dense buffer (after an optimizer step on the
    /// dense master copy). Pattern must match the one used at construction.
    pub fn refresh_from_dense(&mut self, dense: &[f32]) {
        assert_eq!(dense.len(), self.rows * self.cols);
        let mut i = 0;
        for r in 0..self.rows {
            let (s, e) = (self.row_ptr[r], self.row_ptr[r + 1]);
            for k in s..e {
                self.vals[k] = dense[r * self.cols + self.col_idx[k]];
                i += 1;
            }
        }
        debug_assert_eq!(i, self.vals.len());
    }

    /// `y = A·x` touching only stored entries; returns the MAC count
    /// (`= nnz`), which the caller charges to its op counter.
    pub fn matvec_into(&self, x: &[f32], y: &mut [f32]) -> u64 {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            let mut acc = 0.0f32;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v * x[c];
            }
            y[r] = acc;
        }
        self.nnz() as u64
    }

    /// `y += A·x`; returns MAC count.
    pub fn matvec_add_into(&self, x: &[f32], y: &mut [f32]) -> u64 {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            let mut acc = 0.0f32;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v * x[c];
            }
            y[r] += acc;
        }
        self.nnz() as u64
    }

    /// Densify (tests / reports).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut d = vec![0.0; self.rows * self.cols];
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                d[r * self.cols + c] = v;
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn toy() -> (MaskPattern, Vec<f32>) {
        // 3x3 with a fixed pattern:
        // [1 . 2]
        // [. 3 .]
        // [. . .]
        let keep = vec![true, false, true, false, true, false, false, false, false];
        let mask = MaskPattern::from_bools(3, 3, keep);
        let dense = vec![1.0, 9.0, 2.0, 9.0, 3.0, 9.0, 9.0, 9.0, 9.0];
        (mask, dense)
    }

    #[test]
    fn from_mask_drops_entries() {
        let (mask, dense) = toy();
        let csr = Csr::from_mask(&mask, &dense);
        assert_eq!(csr.nnz(), 3);
        assert_eq!(csr.to_dense(), vec![1.0, 0.0, 2.0, 0.0, 3.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg64::new(5);
        let mask = MaskPattern::random(8, 8, 0.4, &mut rng);
        let dense: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let mut masked = dense.clone();
        mask.apply(&mut masked);
        let csr = Csr::from_mask(&mask, &dense);
        let x: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
        let mut y_sparse = vec![0.0; 8];
        let macs = csr.matvec_into(&x, &mut y_sparse);
        assert_eq!(macs, csr.nnz() as u64);
        let m = crate::tensor::Matrix::from_vec(8, 8, masked);
        let mut y_dense = vec![0.0; 8];
        m.matvec_into(&x, &mut y_dense);
        for (a, b) in y_sparse.iter().zip(&y_dense) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn refresh_updates_values_only() {
        let (mask, dense) = toy();
        let mut csr = Csr::from_mask(&mask, &dense);
        let new_dense: Vec<f32> = (0..9).map(|i| i as f32).collect();
        csr.refresh_from_dense(&new_dense);
        assert_eq!(csr.to_dense(), vec![0.0, 0.0, 2.0, 0.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn row_access() {
        let (mask, dense) = toy();
        let csr = Csr::from_mask(&mask, &dense);
        let (cols, vals) = csr.row(0);
        assert_eq!(cols, &[0, 2]);
        assert_eq!(vals, &[1.0, 2.0]);
        let (cols, _) = csr.row(2);
        assert!(cols.is_empty());
    }
}
