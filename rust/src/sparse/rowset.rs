//! Active-row sets for activity sparsity.
//!
//! At each timestep the engines track which units have nonzero
//! pseudo-derivative (`β̃n` of them — these index the nonzero rows of `J`,
//! `M̄` and `M`) and which have nonzero activation (`α̃n` — the forward
//! events). A [`RowSet`] is a membership bitmap plus a dense index list so
//! both O(1) membership tests and tight iteration are available.

/// Set of active row indices in `[0, n)`.
#[derive(Debug, Clone)]
pub struct RowSet {
    member: Vec<bool>,
    idx: Vec<usize>,
}

impl RowSet {
    /// Empty set over `n` rows.
    pub fn empty(n: usize) -> Self {
        RowSet { member: vec![false; n], idx: Vec::with_capacity(n) }
    }

    /// Full set over `n` rows (the dense / no-activity-sparsity case).
    pub fn full(n: usize) -> Self {
        RowSet { member: vec![true; n], idx: (0..n).collect() }
    }

    /// Build from a predicate over row indices.
    pub fn from_pred(n: usize, mut pred: impl FnMut(usize) -> bool) -> Self {
        let mut s = RowSet::empty(n);
        for k in 0..n {
            if pred(k) {
                s.insert(k);
            }
        }
        s
    }

    /// Capacity (total number of rows `n`).
    #[inline]
    pub fn capacity(&self) -> usize {
        self.member.len()
    }

    /// Number of active rows.
    #[inline]
    pub fn len(&self) -> usize {
        self.idx.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    /// O(1) membership test.
    #[inline]
    pub fn contains(&self, k: usize) -> bool {
        self.member[k]
    }

    /// Insert row `k` (no-op if present). Keeps `iter()` in insertion order —
    /// engines insert in ascending k, so iteration is ascending.
    #[inline]
    pub fn insert(&mut self, k: usize) {
        if !self.member[k] {
            self.member[k] = true;
            self.idx.push(k);
        }
    }

    /// Clear to empty (retains allocation; called once per timestep).
    pub fn clear(&mut self) {
        for &k in &self.idx {
            self.member[k] = false;
        }
        self.idx.clear();
    }

    /// Active indices, ascending when inserted ascending.
    #[inline]
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.idx.iter().copied()
    }

    /// Active indices as a slice.
    #[inline]
    pub fn as_slice(&self) -> &[usize] {
        &self.idx
    }

    /// Active fraction (`β̃` or `α̃` depending on what the set tracks).
    pub fn active_fraction(&self) -> f32 {
        if self.member.is_empty() {
            0.0
        } else {
            self.idx.len() as f32 / self.member.len() as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_full() {
        let e = RowSet::empty(5);
        assert_eq!(e.len(), 0);
        assert!(e.is_empty());
        let f = RowSet::full(5);
        assert_eq!(f.len(), 5);
        assert_eq!(f.as_slice(), &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn insert_idempotent() {
        let mut s = RowSet::empty(4);
        s.insert(2);
        s.insert(2);
        assert_eq!(s.len(), 1);
        assert!(s.contains(2));
        assert!(!s.contains(1));
    }

    #[test]
    fn clear_resets_membership() {
        let mut s = RowSet::empty(4);
        s.insert(0);
        s.insert(3);
        s.clear();
        assert!(s.is_empty());
        assert!(!s.contains(0));
        assert!(!s.contains(3));
        s.insert(1);
        assert_eq!(s.as_slice(), &[1]);
    }

    #[test]
    fn from_pred_ascending() {
        let s = RowSet::from_pred(6, |k| k % 2 == 0);
        assert_eq!(s.as_slice(), &[0, 2, 4]);
        assert!((s.active_fraction() - 0.5).abs() < 1e-6);
    }
}
