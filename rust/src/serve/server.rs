//! The serve transport: a line-oriented request protocol over a
//! Unix-domain socket ([`serve_unix`]) or stdin/stdout ([`serve_stdin`]),
//! both thin wrappers around the transport-agnostic [`serve_io`].
//!
//! # Protocol
//!
//! Requests are single lines of whitespace-separated words; the only
//! binary framing is the event payload, which follows its header line
//! verbatim:
//!
//! ```text
//! open <tenant> [seed]        -> ok open <tenant>
//! event <tenant> <nbytes>     -> ok event <tenant> <n-queued>
//!   (followed by exactly <nbytes> payload bytes and one '\n';
//!    the payload is a complete event stream in any EventFormat —
//!    text, JSONL or binary — autodetected per payload)
//! tick                        -> ok tick <tenants-scheduled>
//! run                         -> ok run <rounds>
//! stats                       -> ok stats <nbytes>   (then <nbytes> of JSON + '\n')
//! drain                       -> ok drain <n-tenants>
//! shutdown                    -> ok drain-first, then ok shutdown <n-tenants>
//! ```
//!
//! Request failures (unknown tenant, malformed payload, bad framing
//! numbers) answer with one `err <detail>` line and keep the connection
//! alive; transport failures and payload-framing corruption end the
//! connection. The `stats` reply is byte-counted because the
//! [`crate::telemetry::TelemetrySnapshot`] JSON is multi-line.

use super::{Scheduler, ServeError};
use crate::session::parse_payload;
use std::io::{BufRead, BufReader, Read, Write};
use std::os::unix::net::UnixListener;
use std::path::Path;

/// Largest accepted event payload (16 MiB). An `event` header declaring
/// more is rejected — after the payload is consumed, so the stream stays
/// framed.
pub const MAX_PAYLOAD: usize = 16 << 20;

fn io_err(e: std::io::Error) -> ServeError {
    ServeError::Io { detail: e.to_string() }
}

fn proto(detail: impl Into<String>) -> ServeError {
    ServeError::Protocol { detail: detail.into() }
}

/// Handle one request line (plus its payload, for `event`). `Ok(Some(s))`
/// is the success reply; `Ok(None)` means shutdown was requested (the
/// reply is already written by the caller from the returned drain count —
/// see [`serve_io`]). Any `Err` becomes an `err …` line unless it is
/// transport-level.
fn handle(
    sched: &mut Scheduler,
    words: &[&str],
    reader: &mut impl BufRead,
) -> Result<String, ServeError> {
    match words {
        ["open", tenant] => {
            sched.open(tenant, None)?;
            Ok(format!("ok open {tenant}"))
        }
        ["open", tenant, seed] => {
            let seed: u64 =
                seed.parse().map_err(|_| proto(format!("seed {seed:?} is not a u64")))?;
            sched.open(tenant, Some(seed))?;
            Ok(format!("ok open {tenant}"))
        }
        ["event", tenant, nbytes] => {
            let n: usize =
                nbytes.parse().map_err(|_| proto(format!("size {nbytes:?} is not a byte count")))?;
            if n > MAX_PAYLOAD {
                // consume payload + terminator so the stream stays framed
                let mut sink = std::io::sink();
                std::io::copy(&mut reader.take(n as u64 + 1), &mut sink).map_err(io_err)?;
                return Err(proto(format!("payload of {n} bytes exceeds {MAX_PAYLOAD}")));
            }
            let mut payload = vec![0u8; n];
            reader.read_exact(&mut payload).map_err(io_err)?;
            let mut nl = [0u8; 1];
            reader.read_exact(&mut nl).map_err(io_err)?;
            if nl[0] != b'\n' {
                // framing corruption — unrecoverable on this connection
                return Err(ServeError::Io {
                    detail: "event payload is not terminated by a newline".into(),
                });
            }
            let events = parse_payload(&payload)
                .map_err(|source| ServeError::Event { tenant: tenant.to_string(), source })?;
            let queued = sched.enqueue(tenant, events)?;
            Ok(format!("ok event {tenant} {queued}"))
        }
        ["tick"] => {
            let r = sched.run_round()?;
            Ok(format!("ok tick {}", r.scheduled))
        }
        ["run"] => {
            let rounds = sched.run_until_idle()?;
            Ok(format!("ok run {rounds}"))
        }
        ["stats"] => {
            // trim the JSON's own trailing newline: the reply terminator
            // supplies it, so the framing is exactly <nbytes> + '\n', same
            // as event payloads
            let json = sched.stats().to_json();
            let body = json.trim_end_matches('\n');
            Ok(format!("ok stats {}\n{body}", body.len()))
        }
        ["drain"] => {
            let drained = sched.drain()?;
            Ok(format!("ok drain {}", drained.len()))
        }
        _ => Err(proto(format!("unknown request {:?}", words.join(" ")))),
    }
}

/// Serve one connection worth of requests from `reader`, writing replies
/// to `writer`. Returns `Ok(true)` iff a `shutdown` request was handled
/// (the caller should stop accepting); `Ok(false)` on a clean EOF.
pub fn serve_io(
    sched: &mut Scheduler,
    mut reader: impl BufRead,
    mut writer: impl Write,
) -> Result<bool, ServeError> {
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).map_err(io_err)?;
        if n == 0 {
            return Ok(false);
        }
        let words: Vec<&str> = line.split_whitespace().collect();
        if words.is_empty() {
            continue;
        }
        let (reply, stop) = if words[0] == "shutdown" {
            match sched.drain() {
                Ok(drained) => (format!("ok shutdown {}", drained.len()), true),
                Err(e) => (format!("err {e}"), false),
            }
        } else {
            match handle(sched, &words, &mut reader) {
                Ok(reply) => (reply, false),
                // transport-level errors are unrecoverable on this stream
                Err(e @ ServeError::Io { .. }) => return Err(e),
                Err(e) => (format!("err {e}"), false),
            }
        };
        writer.write_all(reply.as_bytes()).map_err(io_err)?;
        writer.write_all(b"\n").map_err(io_err)?;
        writer.flush().map_err(io_err)?;
        if stop {
            return Ok(true);
        }
    }
}

/// Serve over a Unix-domain socket, one connection at a time, until a
/// client requests `shutdown`. A stale socket file from a dead server is
/// replaced; the live socket file is removed on exit.
pub fn serve_unix(sched: &mut Scheduler, path: &Path, quiet: bool) -> Result<(), ServeError> {
    let listener = match UnixListener::bind(path) {
        Ok(l) => l,
        Err(e) if e.kind() == std::io::ErrorKind::AddrInUse => {
            std::fs::remove_file(path).map_err(io_err)?;
            UnixListener::bind(path).map_err(io_err)?
        }
        Err(e) => return Err(io_err(e)),
    };
    if !quiet {
        eprintln!("serving on {}", path.display());
    }
    loop {
        let (stream, _) = match listener.accept() {
            Ok(s) => s,
            Err(e) => {
                std::fs::remove_file(path).ok();
                return Err(io_err(e));
            }
        };
        match serve_io(sched, BufReader::new(&stream), &stream) {
            Ok(true) => break,
            Ok(false) => {}
            Err(e) => {
                // one broken client must not take the server down
                if !quiet {
                    eprintln!("connection error: {e}");
                }
            }
        }
    }
    std::fs::remove_file(path).ok();
    Ok(())
}

/// Serve the protocol over stdin/stdout — the no-socket mode for piping
/// and tests. EOF without `shutdown` still drains to checkpoints, so a
/// closed pipe never loses learner state.
pub fn serve_stdin(sched: &mut Scheduler) -> Result<(), ServeError> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let shutdown = serve_io(sched, stdin.lock(), stdout.lock())?;
    if !shutdown {
        sched.drain()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgorithmKind, ExperimentConfig};
    use crate::serve::ServeConfig;
    use crate::session::UpdatePolicy;
    use std::io::Cursor;

    fn test_sched(tag: &str) -> Scheduler {
        let mut base = ExperimentConfig::default();
        base.model.hidden = 6;
        base.model.param_sparsity = 0.5;
        base.train.algorithm = AlgorithmKind::RtrlParam;
        let cfg = ServeConfig {
            base,
            policy: UpdatePolicy::Manual,
            spill_dir: std::env::temp_dir()
                .join(format!("sparse-rtrl-server-{tag}-{}", std::process::id())),
            ..ServeConfig::default()
        };
        Scheduler::new(cfg).unwrap()
    }

    fn request(req: &str, payloads: &[&[u8]]) -> Vec<u8> {
        // substitute each `{}` in req's lines with a framed payload
        let mut out = Vec::new();
        let mut p = payloads.iter();
        for line in req.lines() {
            if let Some(head) = line.strip_suffix("{}") {
                let body = p.next().expect("payload for each {}");
                out.extend_from_slice(head.as_bytes());
                out.extend_from_slice(body.len().to_string().as_bytes());
                out.push(b'\n');
                out.extend_from_slice(body);
                out.push(b'\n');
            } else {
                out.extend_from_slice(line.as_bytes());
                out.push(b'\n');
            }
        }
        out
    }

    #[test]
    fn protocol_round_trip_all_formats() {
        let mut sched = test_sched("proto");
        let dir = sched.config().spill_dir.clone();
        let text = b"0.5 -0.2 -> 0\n0.1 0.3\n!update\n";
        let jsonl = br#"{"x": [0.25, -0.5], "class": 1}"#;
        let binary = crate::session::events::encode_binary(&[
            crate::session::StreamEvent::Step {
                x: vec![0.75, 0.125],
                target: crate::data::StepTarget::None,
            },
        ]);
        let input = request(
            "open alice 7\nopen bob 8\nevent alice {}\nevent bob {}\nevent alice {}\nrun\nstats\ndrain\nshutdown\n",
            &[&text[..], &jsonl[..], &binary[..]],
        );
        let mut out = Vec::new();
        let stop = serve_io(&mut sched, Cursor::new(input), &mut out).unwrap();
        assert!(stop, "shutdown must stop the loop");
        let reply = String::from_utf8(out).unwrap();
        let mut lines = reply.lines();
        assert_eq!(lines.next(), Some("ok open alice"));
        assert_eq!(lines.next(), Some("ok open bob"));
        assert_eq!(lines.next(), Some("ok event alice 3"));
        assert_eq!(lines.next(), Some("ok event bob 1"));
        assert_eq!(lines.next(), Some("ok event alice 1"));
        let run = lines.next().unwrap();
        assert!(run.starts_with("ok run "), "got {run:?}");
        let stats = lines.next().unwrap();
        let nbytes: usize = stats.strip_prefix("ok stats ").unwrap().parse().unwrap();
        let at = reply.find("ok stats ").unwrap();
        let body_at = at + stats.len() + 1;
        let body = &reply.as_bytes()[body_at..body_at + nbytes];
        let body = std::str::from_utf8(body).unwrap();
        assert!(body.contains("\"schema\""), "stats body is the snapshot JSON");
        assert!(body.contains("\"live_sessions\": 2"), "both tenants resident:\n{body}");
        let tail = &reply[body_at + nbytes..];
        let mut lines = tail.lines().filter(|l| !l.is_empty());
        assert_eq!(lines.next(), Some("ok drain 2"));
        assert_eq!(lines.next(), Some("ok shutdown 2"));
        assert_eq!(lines.next(), None);
        // all five events actually stepped/updated sessions
        assert_eq!(sched.pending(), 0);
        for name in ["alice", "bob"] {
            assert!(sched.spill_path(name).exists(), "{name} drained to disk");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn errors_reply_and_keep_serving() {
        let mut sched = test_sched("errs");
        let dir = sched.config().spill_dir.clone();
        let bad_payload = b"not an event line\n";
        let input = request(
            "frobnicate\nopen 9\u{fc}ser\nevent ghost {}\nopen ok-1\nevent ok-1 {}\nopen ok-1\nshutdown\n",
            &[&b"0.1 0.2\n"[..], &bad_payload[..]],
        );
        let mut out = Vec::new();
        let stop = serve_io(&mut sched, Cursor::new(input), &mut out).unwrap();
        assert!(stop);
        let reply = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = reply.lines().collect();
        assert!(lines[0].starts_with("err bad request"), "got {:?}", lines[0]);
        assert!(lines[1].starts_with("err bad tenant name"), "got {:?}", lines[1]);
        assert!(lines[2].starts_with("err unknown tenant"), "got {:?}", lines[2]);
        assert_eq!(lines[3], "ok open ok-1");
        assert!(lines[4].starts_with("err tenant ok-1: bad payload"), "got {:?}", lines[4]);
        assert_eq!(lines[5], "ok open ok-1", "reopen is idempotent, not an error");
        assert_eq!(lines[6], "ok shutdown 1");
        assert_eq!(sched.pending(), 0, "the bad payload queued nothing");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn eof_without_shutdown_reports_clean_exit() {
        let mut sched = test_sched("eof");
        let dir = sched.config().spill_dir.clone();
        let input = request("open a\nevent a {}\n", &[&b"0.5 0.5\n"[..]]);
        let mut out = Vec::new();
        let stop = serve_io(&mut sched, Cursor::new(input), &mut out).unwrap();
        assert!(!stop, "EOF is not shutdown — the caller decides to drain");
        assert_eq!(sched.pending(), 1, "nothing ran without tick/run");
        std::fs::remove_dir_all(&dir).ok();
    }
}
