//! The serve transport: a line-oriented request protocol over a
//! Unix-domain socket ([`serve_unix`]) or stdin/stdout ([`serve_stdin`]),
//! both thin wrappers around the transport-agnostic [`serve_io`].
//!
//! # Protocol
//!
//! Requests are single lines of whitespace-separated words; the only
//! binary framing is the event payload, which follows its header line
//! verbatim:
//!
//! ```text
//! open <tenant> [seed]        -> ok open <tenant>
//! event <tenant> <nbytes>     -> ok event <tenant> <n-queued>
//!   (followed by exactly <nbytes> payload bytes and one '\n';
//!    the payload is a complete event stream in any EventFormat —
//!    text, JSONL or binary — autodetected per payload)
//! tick                        -> ok tick <tenants-scheduled>
//! run                         -> ok run <rounds>
//! stats                       -> ok stats <nbytes>   (then <nbytes> of JSON + '\n')
//! drain                       -> ok drain <n-tenants>
//! shutdown                    -> ok drain-first, then ok shutdown <n-tenants>
//! ```
//!
//! Request failures (unknown tenant, malformed payload, shape-invalid
//! events) answer with one `err <detail>` line and keep the connection
//! alive; transport failures and payload-framing corruption — including an
//! `event` header whose byte count doesn't parse, which leaves the
//! payload's length unknowable — end the connection. The `stats` reply is
//! byte-counted because the
//! [`crate::telemetry::TelemetrySnapshot`] JSON is multi-line.

use super::{Scheduler, ServeError};
use crate::session::parse_payload;
use std::io::{BufRead, BufReader, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;

/// Largest accepted event payload (16 MiB). An `event` header declaring
/// more is rejected — after the payload is consumed, so the stream stays
/// framed.
pub const MAX_PAYLOAD: usize = 16 << 20;

fn io_err(e: std::io::Error) -> ServeError {
    ServeError::Io { detail: e.to_string() }
}

fn proto(detail: impl Into<String>) -> ServeError {
    ServeError::Protocol { detail: detail.into() }
}

/// Handle one request line (plus its payload, for `event`). `Ok(Some(s))`
/// is the success reply; `Ok(None)` means shutdown was requested (the
/// reply is already written by the caller from the returned drain count —
/// see [`serve_io`]). Any `Err` becomes an `err …` line unless it is
/// transport-level.
fn handle(
    sched: &mut Scheduler,
    words: &[&str],
    reader: &mut impl BufRead,
) -> Result<String, ServeError> {
    match words {
        ["open", tenant] => {
            sched.open(tenant, None)?;
            Ok(format!("ok open {tenant}"))
        }
        ["open", tenant, seed] => {
            let seed: u64 =
                seed.parse().map_err(|_| proto(format!("seed {seed:?} is not a u64")))?;
            sched.open(tenant, Some(seed))?;
            Ok(format!("ok open {tenant}"))
        }
        ["event", tenant, nbytes] => {
            // An unparseable byte count is framing corruption, not a
            // protocol error: the payload that follows has unknowable
            // length, so replying `err` and reading on would reinterpret
            // payload bytes as requests. End the connection instead.
            let n: usize = nbytes.parse().map_err(|_| ServeError::Io {
                detail: format!("event size {nbytes:?} is not a byte count"),
            })?;
            if n > MAX_PAYLOAD {
                // consume payload + terminator so the stream stays framed
                let mut sink = std::io::sink();
                std::io::copy(&mut reader.take(n as u64 + 1), &mut sink).map_err(io_err)?;
                return Err(proto(format!("payload of {n} bytes exceeds {MAX_PAYLOAD}")));
            }
            let mut payload = vec![0u8; n];
            reader.read_exact(&mut payload).map_err(io_err)?;
            let mut nl = [0u8; 1];
            reader.read_exact(&mut nl).map_err(io_err)?;
            if nl[0] != b'\n' {
                // framing corruption — unrecoverable on this connection
                return Err(ServeError::Io {
                    detail: "event payload is not terminated by a newline".into(),
                });
            }
            let events = parse_payload(&payload)
                .map_err(|source| ServeError::Event { tenant: tenant.to_string(), source })?;
            let queued = sched.enqueue(tenant, events)?;
            Ok(format!("ok event {tenant} {queued}"))
        }
        ["tick"] => {
            let r = sched.run_round()?;
            Ok(format!("ok tick {}", r.scheduled))
        }
        ["run"] => {
            let rounds = sched.run_until_idle()?;
            Ok(format!("ok run {rounds}"))
        }
        ["stats"] => {
            // trim the JSON's own trailing newline: the reply terminator
            // supplies it, so the framing is exactly <nbytes> + '\n', same
            // as event payloads
            let json = sched.stats().to_json();
            let body = json.trim_end_matches('\n');
            Ok(format!("ok stats {}\n{body}", body.len()))
        }
        ["drain"] => {
            let drained = sched.drain()?;
            Ok(format!("ok drain {}", drained.len()))
        }
        // an event header with the wrong word count is equally unframeable —
        // any payload the client sent next would read back as request lines
        ["event", ..] => Err(ServeError::Io {
            detail: format!(
                "malformed event header {:?} (want: event <tenant> <nbytes>)",
                words.join(" ")
            ),
        }),
        _ => Err(proto(format!("unknown request {:?}", words.join(" ")))),
    }
}

/// Serve one connection worth of requests from `reader`, writing replies
/// to `writer`. Returns `Ok(true)` iff a `shutdown` request was handled
/// (the caller should stop accepting); `Ok(false)` on a clean EOF.
pub fn serve_io(
    sched: &mut Scheduler,
    mut reader: impl BufRead,
    mut writer: impl Write,
) -> Result<bool, ServeError> {
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line).map_err(io_err)?;
        if n == 0 {
            return Ok(false);
        }
        let words: Vec<&str> = line.split_whitespace().collect();
        if words.is_empty() {
            continue;
        }
        let (reply, stop) = if words[0] == "shutdown" {
            match sched.drain() {
                Ok(drained) => (format!("ok shutdown {}", drained.len()), true),
                Err(e) => (format!("err {e}"), false),
            }
        } else {
            match handle(sched, &words, &mut reader) {
                Ok(reply) => (reply, false),
                // transport-level errors are unrecoverable on this stream
                Err(e @ ServeError::Io { .. }) => return Err(e),
                Err(e) => (format!("err {e}"), false),
            }
        };
        writer.write_all(reply.as_bytes()).map_err(io_err)?;
        writer.write_all(b"\n").map_err(io_err)?;
        writer.flush().map_err(io_err)?;
        if stop {
            return Ok(true);
        }
    }
}

/// Serve over a Unix-domain socket, one connection at a time, until a
/// client requests `shutdown`. A stale socket file from a dead server is
/// replaced — but only after a connect probe confirms nobody is listening
/// (Unix sockets report `AddrInUse` either way, and silently unlinking
/// would steal a live server's socket). The live socket file is removed on
/// exit.
pub fn serve_unix(sched: &mut Scheduler, path: &Path, quiet: bool) -> Result<(), ServeError> {
    let listener = match UnixListener::bind(path) {
        Ok(l) => l,
        Err(e) if e.kind() == std::io::ErrorKind::AddrInUse => {
            if UnixStream::connect(path).is_ok() {
                return Err(ServeError::Io {
                    detail: format!(
                        "{} already has a live server listening (connect succeeded); \
                         refusing to replace its socket",
                        path.display()
                    ),
                });
            }
            std::fs::remove_file(path).map_err(io_err)?;
            UnixListener::bind(path).map_err(io_err)?
        }
        Err(e) => return Err(io_err(e)),
    };
    if !quiet {
        eprintln!("serving on {}", path.display());
    }
    loop {
        let (stream, _) = match listener.accept() {
            Ok(s) => s,
            Err(e) => {
                std::fs::remove_file(path).ok();
                return Err(io_err(e));
            }
        };
        match serve_io(sched, BufReader::new(&stream), &stream) {
            Ok(true) => break,
            Ok(false) => {}
            Err(e) => {
                // one broken client must not take the server down
                if !quiet {
                    eprintln!("connection error: {e}");
                }
            }
        }
    }
    std::fs::remove_file(path).ok();
    Ok(())
}

/// Serve the protocol over stdin/stdout — the no-socket mode for piping
/// and tests. EOF without `shutdown` still drains to checkpoints, so a
/// closed pipe never loses learner state.
pub fn serve_stdin(sched: &mut Scheduler) -> Result<(), ServeError> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let shutdown = serve_io(sched, stdin.lock(), stdout.lock())?;
    if !shutdown {
        sched.drain()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgorithmKind, ExperimentConfig};
    use crate::serve::ServeConfig;
    use crate::session::UpdatePolicy;
    use std::io::Cursor;

    fn test_sched(tag: &str) -> Scheduler {
        let mut base = ExperimentConfig::default();
        base.model.hidden = 6;
        base.model.param_sparsity = 0.5;
        base.train.algorithm = AlgorithmKind::RtrlParam;
        let cfg = ServeConfig {
            base,
            policy: UpdatePolicy::Manual,
            spill_dir: std::env::temp_dir()
                .join(format!("sparse-rtrl-server-{tag}-{}", std::process::id())),
            ..ServeConfig::default()
        };
        Scheduler::new(cfg).unwrap()
    }

    fn request(req: &str, payloads: &[&[u8]]) -> Vec<u8> {
        // substitute each `{}` in req's lines with a framed payload
        let mut out = Vec::new();
        let mut p = payloads.iter();
        for line in req.lines() {
            if let Some(head) = line.strip_suffix("{}") {
                let body = p.next().expect("payload for each {}");
                out.extend_from_slice(head.as_bytes());
                out.extend_from_slice(body.len().to_string().as_bytes());
                out.push(b'\n');
                out.extend_from_slice(body);
                out.push(b'\n');
            } else {
                out.extend_from_slice(line.as_bytes());
                out.push(b'\n');
            }
        }
        out
    }

    #[test]
    fn protocol_round_trip_all_formats() {
        let mut sched = test_sched("proto");
        let dir = sched.config().spill_dir.clone();
        let text = b"0.5 -0.2 -> 0\n0.1 0.3\n!update\n";
        let jsonl = br#"{"x": [0.25, -0.5], "class": 1}"#;
        let binary = crate::session::events::encode_binary(&[
            crate::session::StreamEvent::Step {
                x: vec![0.75, 0.125],
                target: crate::data::StepTarget::None,
            },
        ]);
        let input = request(
            "open alice 7\nopen bob 8\nevent alice {}\nevent bob {}\nevent alice {}\nrun\nstats\ndrain\nshutdown\n",
            &[&text[..], &jsonl[..], &binary[..]],
        );
        let mut out = Vec::new();
        let stop = serve_io(&mut sched, Cursor::new(input), &mut out).unwrap();
        assert!(stop, "shutdown must stop the loop");
        let reply = String::from_utf8(out).unwrap();
        let mut lines = reply.lines();
        assert_eq!(lines.next(), Some("ok open alice"));
        assert_eq!(lines.next(), Some("ok open bob"));
        assert_eq!(lines.next(), Some("ok event alice 3"));
        assert_eq!(lines.next(), Some("ok event bob 1"));
        assert_eq!(lines.next(), Some("ok event alice 1"));
        let run = lines.next().unwrap();
        assert!(run.starts_with("ok run "), "got {run:?}");
        let stats = lines.next().unwrap();
        let nbytes: usize = stats.strip_prefix("ok stats ").unwrap().parse().unwrap();
        let at = reply.find("ok stats ").unwrap();
        let body_at = at + stats.len() + 1;
        let body = &reply.as_bytes()[body_at..body_at + nbytes];
        let body = std::str::from_utf8(body).unwrap();
        assert!(body.contains("\"schema\""), "stats body is the snapshot JSON");
        assert!(body.contains("\"live_sessions\": 2"), "both tenants resident:\n{body}");
        let tail = &reply[body_at + nbytes..];
        let mut lines = tail.lines().filter(|l| !l.is_empty());
        assert_eq!(lines.next(), Some("ok drain 2"));
        assert_eq!(lines.next(), Some("ok shutdown 2"));
        assert_eq!(lines.next(), None);
        // all five events actually stepped/updated sessions
        assert_eq!(sched.pending(), 0);
        for name in ["alice", "bob"] {
            assert!(sched.spill_path(name).exists(), "{name} drained to disk");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn errors_reply_and_keep_serving() {
        let mut sched = test_sched("errs");
        let dir = sched.config().spill_dir.clone();
        let bad_payload = b"not an event line\n";
        let input = request(
            "frobnicate\nopen 9\u{fc}ser\nevent ghost {}\nopen ok-1\nevent ok-1 {}\nopen ok-1\nshutdown\n",
            &[&b"0.1 0.2\n"[..], &bad_payload[..]],
        );
        let mut out = Vec::new();
        let stop = serve_io(&mut sched, Cursor::new(input), &mut out).unwrap();
        assert!(stop);
        let reply = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = reply.lines().collect();
        assert!(lines[0].starts_with("err bad request"), "got {:?}", lines[0]);
        assert!(lines[1].starts_with("err bad tenant name"), "got {:?}", lines[1]);
        assert!(lines[2].starts_with("err unknown tenant"), "got {:?}", lines[2]);
        assert_eq!(lines[3], "ok open ok-1");
        assert!(lines[4].starts_with("err tenant ok-1: bad payload"), "got {:?}", lines[4]);
        assert_eq!(lines[5], "ok open ok-1", "reopen is idempotent, not an error");
        assert_eq!(lines[6], "ok shutdown 1");
        assert_eq!(sched.pending(), 0, "the bad payload queued nothing");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// An out-of-range class index in an otherwise well-formed payload is
    /// rejected at ingestion with an `err` reply — it must never reach a
    /// round and panic the server (the loss asserts on class bounds).
    #[test]
    fn out_of_range_class_rejects_transactionally_and_keeps_serving() {
        let mut sched = test_sched("class");
        let dir = sched.config().spill_dir.clone();
        // 2-class model; "-> 9" parses fine but can never be stepped
        let input = request(
            "open a\nevent a {}\nrun\nevent a {}\nrun\nshutdown\n",
            &[&b"0.1 0.2 -> 9\n"[..], &b"0.1 0.2 -> 1\n"[..]],
        );
        let mut out = Vec::new();
        let stop = serve_io(&mut sched, Cursor::new(input), &mut out).unwrap();
        assert!(stop, "the server survives to handle shutdown");
        let reply = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = reply.lines().collect();
        assert_eq!(lines[0], "ok open a");
        assert!(
            lines[1].starts_with("err tenant a:") && lines[1].contains("out of range"),
            "got {:?}",
            lines[1]
        );
        assert_eq!(lines[2], "ok run 0", "nothing from the rejected payload queued");
        assert_eq!(lines[3], "ok event a 1", "an in-range class still queues");
        assert_eq!(lines[4], "ok run 1");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A byte count that doesn't parse leaves the payload length
    /// unknowable: the connection must end rather than desync and read
    /// payload bytes (here, a `shutdown` line) as requests.
    #[test]
    fn unparseable_byte_count_ends_the_connection() {
        let mut sched = test_sched("badcount");
        let dir = sched.config().spill_dir.clone();
        let input = b"open a\nevent a twelve\nshutdown\n0.1 0.2\nshutdown\n".to_vec();
        let mut out = Vec::new();
        let err = serve_io(&mut sched, Cursor::new(input), &mut out).unwrap_err();
        assert!(matches!(err, ServeError::Io { .. }), "got {err:?}");
        let reply = String::from_utf8(out).unwrap();
        assert_eq!(reply.lines().count(), 1, "no reply after the corrupt header");
        assert!(!reply.contains("shutdown"), "payload lines were never read as requests");

        // wrong word count in an event header is equally unframeable
        let input = b"open b\nevent b\nshutdown\n".to_vec();
        let mut out = Vec::new();
        let err = serve_io(&mut sched, Cursor::new(input), &mut out).unwrap_err();
        assert!(matches!(err, ServeError::Io { .. }), "got {err:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `serve_unix` replaces a dead server's stale socket file but refuses
    /// to steal one a live server is still listening on.
    #[test]
    fn serve_unix_replaces_stale_but_not_live_sockets() {
        use std::os::unix::net::{UnixListener, UnixStream};
        let dir =
            std::env::temp_dir().join(format!("sparse-rtrl-sock-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("serve.sock");

        // live listener on the path: serve_unix must refuse to bind
        let live = UnixListener::bind(&path).unwrap();
        let mut sched = test_sched("sock-live");
        let live_spill = sched.config().spill_dir.clone();
        let err = serve_unix(&mut sched, &path, true).unwrap_err();
        assert!(matches!(err, ServeError::Io { .. }), "got {err:?}");
        assert!(path.exists(), "the live server's socket file is untouched");
        drop(live);

        // dead listener's stale file: serve_unix replaces it and serves
        assert!(path.exists(), "dropping the listener leaves the file");
        let path2 = path.clone();
        let handle = std::thread::spawn(move || {
            let mut sched = test_sched("sock-stale");
            let d = sched.config().spill_dir.clone();
            let r = serve_unix(&mut sched, &path2, true);
            std::fs::remove_dir_all(&d).ok();
            r
        });
        // the probe+rebind races the thread start; retry the connect
        let mut stream = None;
        for _ in 0..200 {
            match UnixStream::connect(&path) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(5)),
            }
        }
        let mut stream = stream.expect("stale socket was replaced and served");
        stream.write_all(b"shutdown\n").unwrap();
        let mut reply = String::new();
        BufReader::new(&stream).read_line(&mut reply).unwrap();
        assert_eq!(reply, "ok shutdown 0\n");
        handle.join().unwrap().unwrap();
        assert!(!path.exists(), "the socket file is removed on exit");
        std::fs::remove_dir_all(&live_spill).ok();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn eof_without_shutdown_reports_clean_exit() {
        let mut sched = test_sched("eof");
        let dir = sched.config().spill_dir.clone();
        let input = request("open a\nevent a {}\n", &[&b"0.5 0.5\n"[..]]);
        let mut out = Vec::new();
        let stop = serve_io(&mut sched, Cursor::new(input), &mut out).unwrap();
        assert!(!stop, "EOF is not shutdown — the caller decides to drain");
        assert_eq!(sched.pending(), 1, "nothing ran without tick/run");
        std::fs::remove_dir_all(&dir).ok();
    }
}
