//! `sparse-rtrl serve`: a long-lived multi-tenant serving loop on top of
//! [`crate::session::SessionPool`] — the production shape of the paper's
//! per-user online-learning story.
//!
//! Tenants (named users) each own a private [`crate::session::OnlineSession`]
//! and an event queue. The pieces:
//!
//! * [`Scheduler`] — drains the per-tenant queues in rounds. Ready tenants
//!   whose sessions share one weight-and-mask set step through the pool's
//!   fused shared-weight path ([`crate::session::SessionPool::step_batched_runs`],
//!   one influence-structure build and one lane state transfer amortized
//!   across the whole group and burst), everyone else steps per-session;
//!   [`RoundReport`] carries the per-round batching stats. A naive
//!   per-session mode ([`SchedulePolicy::RoundRobin`]) exists purely as the
//!   serve-bench baseline.
//! * LRU residency — a `--max-resident` budget caps live sessions; the
//!   least-recently-scheduled tenant spills to a binary snapshot
//!   ([`crate::session::SessionPool::evict_id`]) and is transparently
//!   re-admitted on its next event, with cold-start latency landing in the
//!   pool's existing telemetry histograms.
//! * [`server`] — the line protocol over a Unix-domain socket or stdin:
//!   tenant-framed event payloads in any [`crate::session::EventFormat`]
//!   (autodetected per payload), a `stats` request answering with a
//!   [`crate::telemetry::TelemetrySnapshot`], and graceful
//!   drain-to-checkpoint on shutdown. Drained checkpoints are bit-identical
//!   to an offline `stream` run of the same events (pinned by
//!   `tests/serve.rs` and the CI serve arm).
//! * [`crate::bench::serve`] — the deterministic load generator behind
//!   `bench`'s `serve` block (events/sec, p50/p99 step latency vs tenant
//!   count and resident budget).
//!
//! Failures are typed ([`ServeError`]) end to end — a corrupt spill file,
//! an unknown tenant, a malformed payload and a transport error are all
//! distinct, and none of them panic the server.

pub mod scheduler;
pub mod server;

pub use scheduler::{RoundReport, SchedulePolicy, Scheduler, ServeConfig};
pub use server::{serve_io, serve_stdin, serve_unix};

use crate::session::{EventError, PoolError};

/// Typed failure of the serve subsystem. Protocol-level errors render as
/// one `err …` reply line and keep the server alive; transport errors
/// ([`ServeError::Io`]) end the connection.
#[derive(Debug)]
pub enum ServeError {
    /// A pool spill/restore operation failed underneath the scheduler.
    Pool(PoolError),
    /// A tenant's event payload failed to parse. Transactional: nothing
    /// from the payload was queued.
    Event { tenant: String, source: EventError },
    /// A request names a tenant that was never opened.
    UnknownTenant { name: String },
    /// A tenant name the protocol refuses (empty, too long, or containing
    /// characters outside `[A-Za-z0-9._-]`).
    BadTenant { name: String, detail: String },
    /// A malformed protocol request.
    Protocol { detail: String },
    /// The transport (socket, stdin/stdout) failed.
    Io { detail: String },
    /// An event parsed but is impossible for the tenant's session — wrong
    /// input width, a regression target of the wrong length, or a class
    /// index outside the readout's range.
    Session { tenant: String, detail: String },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Pool(e) => write!(f, "pool: {e}"),
            ServeError::Event { tenant, source } => {
                write!(f, "tenant {tenant}: bad payload: {source}")
            }
            ServeError::UnknownTenant { name } => {
                write!(f, "unknown tenant {name:?} (open it first)")
            }
            ServeError::BadTenant { name, detail } => {
                write!(f, "bad tenant name {name:?}: {detail}")
            }
            ServeError::Protocol { detail } => write!(f, "bad request: {detail}"),
            ServeError::Io { detail } => write!(f, "transport: {detail}"),
            ServeError::Session { tenant, detail } => write!(f, "tenant {tenant}: {detail}"),
        }
    }
}

impl std::error::Error for ServeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServeError::Pool(e) => Some(e),
            ServeError::Event { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<PoolError> for ServeError {
    fn from(e: PoolError) -> Self {
        ServeError::Pool(e)
    }
}
