//! The serve scheduler: per-tenant event queues drained in rounds, fused
//! shared-weight stepping, and LRU residency under a `--max-resident`
//! budget.
//!
//! # Scheduling model
//!
//! Each tenant owns a FIFO queue of [`StreamEvent`]s. A round picks the
//! *ready* tenants (non-empty queue), least recently scheduled first,
//! truncated to the resident budget. A control event at the head of a
//! tenant's queue (update / end-of-sequence) applies immediately; tenants
//! with a step at the head contribute a **run** of consecutive steps to
//! this round. Runs fuse through
//! [`SessionPool::step_batched_runs`], which groups lanes by exact weight
//! identity and amortizes both the per-step influence-structure build and
//! the per-lane state transfer across the whole group and run. Tenants
//! with at least [`ServeConfig::burst`] steps queued run the full burst;
//! the stragglers share the longest uniform run they can all supply, so
//! every ready tenant progresses every round.
//!
//! # Residency
//!
//! With `max_resident = R > 0`, at most `R` sessions stay live. The
//! least-recently-scheduled resident spills to a binary snapshot in
//! [`ServeConfig::spill_dir`] ([`SessionPool::evict_id`]); a spilled
//! tenant's next event transparently re-admits it
//! ([`SessionPool::admit_id`]) — bit-exactly, with the cold-start latency
//! recorded in the pool's resume histogram. One `last_active` stamp drives
//! both the scheduling order and the eviction choice.
//!
//! # Determinism
//!
//! Learner outcomes never depend on the wall clock or ambient RNG: time is
//! read only for latency telemetry, and the round structure is a pure
//! function of queue contents and the budget. The serve-bench equivalence
//! tests (`tests/serve.rs`) pin that drained checkpoints are bit-identical
//! across resident budgets and against an offline `stream` run.

use super::ServeError;
use crate::config::ExperimentConfig;
use crate::data::StepTarget;
use crate::session::{
    BatchStats, SessionBuilder, SessionId, SessionPool, SnapshotFormat, StreamEvent, UpdatePolicy,
};
use crate::telemetry::names;
use crate::telemetry::{HistogramKind, MemoryRecorder, Recorder, TelemetrySnapshot};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::path::PathBuf;
use std::time::Instant;

/// How a round steps its ready tenants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Fuse shared-weight tenants through
    /// [`SessionPool::step_batched_runs`] (the default).
    Batched,
    /// Step every tenant per-session — the naive baseline the serve bench
    /// measures batching against.
    RoundRobin,
}

impl SchedulePolicy {
    pub fn name(self) -> &'static str {
        match self {
            SchedulePolicy::Batched => "batched",
            SchedulePolicy::RoundRobin => "round-robin",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "batched" => Some(SchedulePolicy::Batched),
            "round-robin" => Some(SchedulePolicy::RoundRobin),
            _ => None,
        }
    }
}

/// Everything a [`Scheduler`] needs to know up front. Every tenant session
/// is built from `base` (only the seed may vary per tenant), so one serve
/// process hosts one model family — the shape that makes fused stepping
/// possible at all.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Model/task/training description shared by all tenants.
    pub base: ExperimentConfig,
    /// Update policy for every tenant session.
    pub policy: UpdatePolicy,
    /// Intra-step kernel threads per session / fused group.
    pub threads: usize,
    /// Maximum live sessions; `0` = unlimited (nothing ever spills).
    pub max_resident: usize,
    /// Longest step run fused per tenant per round (≥ 1).
    pub burst: usize,
    /// Where evicted sessions spill their binary snapshots.
    pub spill_dir: PathBuf,
    /// Batched fusion or the per-session baseline.
    pub schedule: SchedulePolicy,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            base: ExperimentConfig::default(),
            policy: UpdatePolicy::EveryKSteps(1),
            threads: 1,
            max_resident: 0,
            burst: 16,
            spill_dir: PathBuf::from("serve-spill"),
            schedule: SchedulePolicy::Batched,
        }
    }
}

/// Where a tenant's session currently lives.
enum Residency {
    Resident(SessionId),
    Spilled(PathBuf),
}

struct Tenant {
    queue: VecDeque<StreamEvent>,
    residency: Residency,
    /// Round stamp of the tenant's last scheduled event — the shared LRU
    /// key for scheduling order and eviction choice.
    last_active: u64,
}

/// What one [`Scheduler::run_round`] did.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundReport {
    /// The round's number (0-based).
    pub round: u64,
    /// Tenants that consumed at least one event.
    pub scheduled: usize,
    /// Step events applied.
    pub steps: u64,
    /// Control events (update / end-of-sequence) applied.
    pub control: u64,
    /// How the step events ran (lanes counted once per fused call).
    pub batch: BatchStats,
}

/// The multi-tenant serving loop. See the module docs for the scheduling
/// and residency model; [`crate::serve::server`] drives this over a socket
/// or stdin, [`crate::bench::serve`] drives it as a load generator.
pub struct Scheduler {
    cfg: ServeConfig,
    pool: SessionPool,
    tenants: BTreeMap<String, Tenant>,
    rounds: u64,
    recorder: MemoryRecorder,
    /// `(n_in, n_out)` of the shared model family, set by the first open —
    /// enqueue validates event shapes against it so malformed events are
    /// rejected at ingestion, never mid-round.
    io_shape: Option<(usize, usize)>,
}

fn internal(name: &str, what: &str) -> ServeError {
    ServeError::Protocol { detail: format!("internal: tenant {name}: {what}") }
}

fn validate_name(name: &str) -> Result<(), ServeError> {
    let bad =
        |detail: &str| ServeError::BadTenant { name: name.to_string(), detail: detail.into() };
    if name.is_empty() {
        return Err(bad("empty"));
    }
    if name.len() > 64 {
        return Err(bad("longer than 64 bytes"));
    }
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphanumeric() => {}
        _ => return Err(bad("must start with an ASCII letter or digit")),
    }
    if !name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')) {
        return Err(bad("only [A-Za-z0-9._-] allowed"));
    }
    Ok(())
}

impl Scheduler {
    /// Create an empty scheduler, ensuring the spill directory exists.
    /// Pool telemetry is always on — the `stats` request needs it.
    pub fn new(cfg: ServeConfig) -> Result<Self, ServeError> {
        std::fs::create_dir_all(&cfg.spill_dir).map_err(|e| ServeError::Io {
            detail: format!("cannot create spill dir {}: {e}", cfg.spill_dir.display()),
        })?;
        let mut pool = SessionPool::new(Vec::new(), cfg.threads);
        pool.enable_telemetry();
        Ok(Scheduler {
            cfg,
            pool,
            tenants: BTreeMap::new(),
            rounds: 0,
            recorder: MemoryRecorder::new(),
            io_shape: None,
        })
    }

    /// Open a tenant: build its session from the base config (seed
    /// overridable per tenant) and make it resident, evicting the LRU
    /// resident first if the budget is full. Returns `false` (and does
    /// nothing) if the tenant already exists — opens are idempotent.
    pub fn open(&mut self, name: &str, seed: Option<u64>) -> Result<bool, ServeError> {
        validate_name(name)?;
        if self.tenants.contains_key(name) {
            return Ok(false);
        }
        let mut cfg = self.cfg.base.clone();
        if let Some(s) = seed {
            cfg.seed = s;
        }
        let mut session = SessionBuilder::from_config(cfg)
            .policy(self.cfg.policy)
            .predict_always(true)
            .build();
        session.set_threads(self.cfg.threads);
        let shape = (session.net().n_in(), session.n_out());
        self.io_shape.get_or_insert(shape);
        if self.cfg.max_resident > 0 {
            let nobody = BTreeSet::new();
            while self.pool.len() >= self.cfg.max_resident {
                if self.evict_lru(&nobody)?.is_none() {
                    break;
                }
            }
        }
        let id = self.pool.insert(session);
        self.tenants.insert(
            name.to_string(),
            Tenant {
                queue: VecDeque::new(),
                residency: Residency::Resident(id),
                last_active: self.rounds,
            },
        );
        Ok(true)
    }

    /// Queue events for a tenant — transactional: either every event is
    /// accepted or (on an unknown tenant or a shape-invalid event) none
    /// are. Returns the number queued.
    pub fn enqueue(&mut self, name: &str, events: Vec<StreamEvent>) -> Result<usize, ServeError> {
        if !self.tenants.contains_key(name) {
            return Err(ServeError::UnknownTenant { name: name.to_string() });
        }
        let Some((n_in, n_out)) = self.io_shape else {
            return Err(internal(name, "tenant exists but the io shape was never set"));
        };
        for ev in &events {
            if let StreamEvent::Step { x, target } = ev {
                if x.len() != n_in {
                    return Err(ServeError::Session {
                        tenant: name.to_string(),
                        detail: format!("event has {} inputs, the model takes {n_in}", x.len()),
                    });
                }
                match target {
                    StepTarget::Vector(t) if t.len() != n_out => {
                        return Err(ServeError::Session {
                            tenant: name.to_string(),
                            detail: format!(
                                "regression target has {} values, the readout emits {n_out}",
                                t.len()
                            ),
                        });
                    }
                    StepTarget::Class(c) if *c >= n_out => {
                        return Err(ServeError::Session {
                            tenant: name.to_string(),
                            detail: format!(
                                "class target {c} is out of range, the readout emits {n_out} classes"
                            ),
                        });
                    }
                    _ => {}
                }
            }
        }
        let n = events.len();
        if let Some(t) = self.tenants.get_mut(name) {
            t.queue.extend(events);
        }
        Ok(n)
    }

    /// Run one scheduling round. See the module docs for the exact model;
    /// a round with nothing queued returns `scheduled = 0` and advances
    /// nothing.
    pub fn run_round(&mut self) -> Result<RoundReport, ServeError> {
        let round = self.rounds;
        let mut report = RoundReport { round, ..RoundReport::default() };

        // Ready tenants, least recently scheduled first — the same LRU
        // order eviction uses, so the budget rotates fairly.
        let mut ready: Vec<(u64, String)> = self
            .tenants
            .iter()
            .filter(|(_, t)| !t.queue.is_empty())
            .map(|(n, t)| (t.last_active, n.clone()))
            .collect();
        ready.sort();
        if self.cfg.max_resident > 0 {
            ready.truncate(self.cfg.max_resident);
        }
        if ready.is_empty() {
            return Ok(report);
        }
        let ready_names: BTreeSet<String> = ready.iter().map(|(_, n)| n.clone()).collect();

        // Residency: re-admit every spilled ready tenant, spilling idle
        // LRU residents as needed to respect the budget.
        for (_, name) in &ready {
            if self.is_spilled(name) {
                if self.cfg.max_resident > 0 {
                    while self.pool.len() >= self.cfg.max_resident {
                        if self.evict_lru(&ready_names)?.is_none() {
                            break;
                        }
                    }
                }
                self.admit_tenant(name)?;
            }
        }

        // One event class per tenant per round: a control event at the
        // head applies immediately; step tenants join the fused runs.
        let mut step_names: Vec<String> = Vec::new();
        for (_, name) in &ready {
            let Some(t) = self.tenants.get_mut(name) else { continue };
            match t.queue.front() {
                Some(StreamEvent::Step { .. }) => step_names.push(name.clone()),
                Some(_) => {
                    let ev = t.queue.pop_front();
                    t.last_active = round;
                    let Residency::Resident(id) = &t.residency else {
                        return Err(internal(name, "control event on a non-resident tenant"));
                    };
                    let Some(s) = self.pool.session_by_id_mut(*id) else {
                        return Err(internal(name, "resident id missing from the pool"));
                    };
                    match ev {
                        Some(StreamEvent::Update) => s.update_now(),
                        Some(StreamEvent::EndSequence) => {
                            // mirror `stream`'s `!end`: close the sequence,
                            // immediately begin the next
                            s.end_sequence();
                            s.begin_sequence();
                        }
                        _ => {}
                    }
                    report.control += 1;
                    report.scheduled += 1;
                }
                None => {}
            }
        }

        // Burst policy: tenants with a full burst of consecutive steps
        // queued fuse at `burst`; the stragglers share the longest uniform
        // run they can all supply. Heavy queues amortize the lane state
        // transfer over the full burst, light ones still progress.
        let burst = self.cfg.burst.max(1);
        let mut full: Vec<(usize, String)> = Vec::new();
        let mut short: Vec<(usize, String)> = Vec::new();
        let mut k_short = burst;
        for name in &step_names {
            let Some(t) = self.tenants.get(name) else { continue };
            let Residency::Resident(id) = &t.residency else {
                return Err(internal(name, "step event on a non-resident tenant"));
            };
            let Some(slot) = self.pool.slot_of(*id) else {
                return Err(internal(name, "resident id missing from the pool"));
            };
            let lead = t
                .queue
                .iter()
                .take(burst)
                .take_while(|e| matches!(e, StreamEvent::Step { .. }))
                .count();
            if lead >= burst {
                full.push((slot, name.clone()));
            } else {
                k_short = k_short.min(lead);
                short.push((slot, name.clone()));
            }
        }
        if short.is_empty() {
            k_short = 0;
        }

        for (mut lanes, k) in [(full, burst), (short, k_short)] {
            if lanes.is_empty() || k == 0 {
                continue;
            }
            lanes.sort();
            let mut slots: Vec<usize> = Vec::with_capacity(lanes.len());
            let mut runs: Vec<Vec<(Vec<f32>, StepTarget)>> = Vec::with_capacity(lanes.len());
            for (slot, name) in &lanes {
                let Some(t) = self.tenants.get_mut(name) else { continue };
                let mut run = Vec::with_capacity(k);
                while run.len() < k {
                    match t.queue.pop_front() {
                        Some(StreamEvent::Step { x, target }) => run.push((x, target)),
                        Some(other) => {
                            t.queue.push_front(other);
                            break;
                        }
                        None => break,
                    }
                }
                t.last_active = round;
                if run.len() == k {
                    slots.push(*slot);
                    runs.push(run);
                } else {
                    // defensive: a queue that changed shape under us still
                    // steps, just per-session
                    report.steps += run.len() as u64;
                    report.scheduled += 1;
                    report.batch.solo += 1;
                    self.recorder.counter(names::SERVE_SOLO_STEPS, run.len() as u64);
                    let s = self.pool.session_mut(*slot);
                    for (x, tgt) in &run {
                        let _ = s.step(x, tgt.as_target());
                    }
                }
            }
            if slots.is_empty() {
                continue;
            }
            let lane_steps = (slots.len() * k) as u64;
            // wall clock feeds latency telemetry only; learner state stays clock-free
            let t0 = Instant::now();
            let stats = match self.cfg.schedule {
                SchedulePolicy::Batched => self.pool.step_batched_runs(&slots, &runs).1,
                SchedulePolicy::RoundRobin => {
                    let mut st = BatchStats::default();
                    for (j, &slot) in slots.iter().enumerate() {
                        let s = self.pool.session_mut(slot);
                        for (x, tgt) in &runs[j] {
                            let _ = s.step(x, tgt.as_target());
                        }
                        st.solo += 1;
                    }
                    st
                }
            };
            let per_step_ns = (t0.elapsed().as_nanos() as u64) / lane_steps.max(1);
            for _ in 0..lane_steps {
                self.recorder.observe(names::SERVE_STEP_NS, HistogramKind::LatencyNs, per_step_ns);
            }
            self.recorder.counter(names::SERVE_FUSED_STEPS, (stats.fused_lanes * k) as u64);
            self.recorder.counter(names::SERVE_SOLO_STEPS, (stats.solo * k) as u64);
            report.batch.fused_groups += stats.fused_groups;
            report.batch.fused_lanes += stats.fused_lanes;
            report.batch.solo += stats.solo;
            report.steps += lane_steps;
            report.scheduled += slots.len();
        }

        self.recorder.counter(names::SERVE_ROUNDS, 1);
        self.recorder.counter(names::SERVE_EVENTS, report.steps + report.control);
        self.rounds += 1;
        Ok(report)
    }

    /// Run rounds until every queue is empty. Returns the number of rounds
    /// that did work.
    pub fn run_until_idle(&mut self) -> Result<u64, ServeError> {
        let mut rounds = 0u64;
        loop {
            let r = self.run_round()?;
            if r.scheduled == 0 {
                return Ok(rounds);
            }
            rounds += 1;
        }
    }

    /// Graceful shutdown: apply every queued event, then checkpoint every
    /// tenant to its spill path (binary snapshots, same codec as `stream
    /// --checkpoint`). Returns `(tenant, snapshot path)` pairs, every
    /// tenant included — already-spilled ones report their existing file.
    pub fn drain(&mut self) -> Result<Vec<(String, PathBuf)>, ServeError> {
        self.run_until_idle()?;
        let names: Vec<String> = self.tenants.keys().cloned().collect();
        let mut out = Vec::with_capacity(names.len());
        for name in names {
            let path = self.spill_path(&name);
            let Some(t) = self.tenants.get_mut(&name) else { continue };
            if let Residency::Resident(id) = &t.residency {
                let id = *id;
                self.pool.evict_id(id, &path, SnapshotFormat::Binary)?;
                t.residency = Residency::Spilled(path.clone());
            }
            out.push((name, path));
        }
        Ok(out)
    }

    /// Pool-level telemetry (live sessions, admissions/evictions, spill
    /// bytes, cold-start latency histograms, one row per live session) —
    /// the `stats` request's reply.
    pub fn stats(&self) -> TelemetrySnapshot {
        self.pool.telemetry_snapshot()
    }

    /// The scheduler's own metrics: rounds, events, fused vs solo step
    /// counts, per-step latency histogram (`serve.*` names).
    pub fn recorder(&self) -> &MemoryRecorder {
        &self.recorder
    }

    /// Total events still queued across all tenants.
    pub fn pending(&self) -> usize {
        self.tenants.values().map(|t| t.queue.len()).sum()
    }

    /// Rounds run so far.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// The configuration the scheduler was built with.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// The underlying pool (telemetry inspection in tests and benches).
    pub fn pool(&self) -> &SessionPool {
        &self.pool
    }

    /// All tenant names, sorted.
    pub fn tenant_names(&self) -> Vec<String> {
        self.tenants.keys().cloned().collect()
    }

    /// Whether a tenant's session is currently live (`None`: no such
    /// tenant).
    pub fn is_resident(&self, name: &str) -> Option<bool> {
        self.tenants.get(name).map(|t| matches!(t.residency, Residency::Resident(_)))
    }

    /// The snapshot path tenant `name` spills to.
    pub fn spill_path(&self, name: &str) -> PathBuf {
        self.cfg.spill_dir.join(format!("{name}.snap"))
    }

    fn is_spilled(&self, name: &str) -> bool {
        matches!(self.tenants.get(name), Some(Tenant { residency: Residency::Spilled(_), .. }))
    }

    /// Spill the least-recently-scheduled resident tenant not in
    /// `exclude`. `Ok(None)`: nobody evictable.
    fn evict_lru(&mut self, exclude: &BTreeSet<String>) -> Result<Option<String>, ServeError> {
        let victim: Option<(u64, String)> = self
            .tenants
            .iter()
            .filter(|(n, t)| {
                !exclude.contains(*n) && matches!(t.residency, Residency::Resident(_))
            })
            .map(|(n, t)| (t.last_active, n.clone()))
            .min();
        let Some((_, name)) = victim else { return Ok(None) };
        let path = self.spill_path(&name);
        let Some(t) = self.tenants.get_mut(&name) else { return Ok(None) };
        let Residency::Resident(id) = &t.residency else { return Ok(None) };
        let id = *id;
        self.pool.evict_id(id, &path, SnapshotFormat::Binary)?;
        t.residency = Residency::Spilled(path.clone());
        Ok(Some(name))
    }

    /// Restore a spilled tenant's session (bit-exact) and delete its spill
    /// file. Runtime knobs never travel in snapshots, so the thread count
    /// is re-applied here.
    fn admit_tenant(&mut self, name: &str) -> Result<(), ServeError> {
        let Some(t) = self.tenants.get(name) else {
            return Err(ServeError::UnknownTenant { name: name.to_string() });
        };
        let Residency::Spilled(path) = &t.residency else { return Ok(()) };
        let path = path.clone();
        let id = self.pool.admit_id(&path)?;
        if let Some(s) = self.pool.session_by_id_mut(id) {
            s.set_threads(self.cfg.threads);
        }
        std::fs::remove_file(&path).ok();
        if let Some(t) = self.tenants.get_mut(name) {
            t.residency = Residency::Resident(id);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgorithmKind;

    fn test_cfg(tag: &str) -> ServeConfig {
        let mut base = ExperimentConfig::default();
        base.model.hidden = 6;
        base.model.param_sparsity = 0.5;
        base.train.algorithm = AlgorithmKind::RtrlParam;
        ServeConfig {
            base,
            policy: UpdatePolicy::Manual,
            spill_dir: std::env::temp_dir()
                .join(format!("sparse-rtrl-serve-{tag}-{}", std::process::id())),
            ..ServeConfig::default()
        }
    }

    fn steps(n: usize, salt: u64) -> Vec<StreamEvent> {
        (0..n)
            .map(|i| StreamEvent::Step {
                x: vec![((i as u64 + salt) as f32 * 0.37).sin(), 0.25],
                target: if i % 2 == 0 { StepTarget::Class(i % 2) } else { StepTarget::None },
            })
            .collect()
    }

    #[test]
    fn tenant_names_are_validated() {
        let mut sched = Scheduler::new(test_cfg("names")).unwrap();
        assert!(sched.open("alice", None).unwrap());
        assert!(!sched.open("alice", None).unwrap(), "reopen is idempotent");
        assert!(sched.open("user-2.prod_x", Some(7)).unwrap());
        for bad in ["", "-dash", "has space", ".dot", "a/b", &"x".repeat(65)] {
            assert!(
                matches!(sched.open(bad, None), Err(ServeError::BadTenant { .. })),
                "{bad:?} must be rejected"
            );
        }
        std::fs::remove_dir_all(&sched.cfg.spill_dir).ok();
    }

    #[test]
    fn enqueue_is_transactional_and_shape_checked() {
        let mut sched = Scheduler::new(test_cfg("shapes")).unwrap();
        sched.open("a", None).unwrap();
        assert!(matches!(
            sched.enqueue("ghost", steps(1, 0)),
            Err(ServeError::UnknownTenant { .. })
        ));
        // wrong input width rejects the whole payload
        let mut evs = steps(2, 0);
        evs.push(StreamEvent::Step { x: vec![1.0], target: StepTarget::None });
        assert!(matches!(sched.enqueue("a", evs), Err(ServeError::Session { .. })));
        assert_eq!(sched.pending(), 0, "nothing from a rejected payload is queued");
        // wrong regression-target length too
        let evs = vec![StreamEvent::Step {
            x: vec![0.1, 0.2],
            target: StepTarget::Vector(vec![0.5]),
        }];
        assert!(matches!(sched.enqueue("a", evs), Err(ServeError::Session { .. })));
        // out-of-range class index is rejected at ingestion, never mid-round
        let mut evs = steps(2, 0);
        evs.push(StreamEvent::Step { x: vec![0.1, 0.2], target: StepTarget::Class(9) });
        assert!(matches!(sched.enqueue("a", evs), Err(ServeError::Session { .. })));
        assert_eq!(sched.pending(), 0, "the bad-class payload queued nothing");
        assert_eq!(sched.enqueue("a", steps(3, 1)).unwrap(), 3);
        assert_eq!(sched.pending(), 3);
        std::fs::remove_dir_all(&sched.cfg.spill_dir).ok();
    }

    /// Three tenants under a budget of one: every round evicts somebody,
    /// yet all queues drain, all events apply, and drained snapshots exist
    /// for everyone.
    #[test]
    fn lru_budget_churns_and_still_drains_everyone() {
        let mut cfg = test_cfg("lru");
        cfg.max_resident = 1;
        let dir = cfg.spill_dir.clone();
        let mut sched = Scheduler::new(cfg).unwrap();
        for (i, name) in ["a", "b", "c"].iter().enumerate() {
            sched.open(name, Some(50 + i as u64)).unwrap();
            sched.enqueue(name, steps(4 + i, i as u64)).unwrap();
        }
        assert_eq!(sched.pool().len(), 1, "budget holds after opens");
        let rounds = sched.run_until_idle().unwrap();
        assert!(rounds >= 3, "a budget of one forces one tenant per round");
        assert_eq!(sched.pending(), 0);
        let snap = sched.stats();
        assert!(snap.evictions >= 2, "churn must evict");
        assert!(snap.admissions >= 2, "churn must re-admit");
        let paths = sched.drain().unwrap();
        assert_eq!(paths.len(), 3);
        for (name, p) in &paths {
            assert!(p.exists(), "tenant {name} must have a drained snapshot");
        }
        // per-tenant step counts survived the churn: 4 + 5 + 6 events
        let evs = sched.recorder().counter_value(names::SERVE_EVENTS);
        assert_eq!(evs, 15);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Shared-weight tenants fuse; the round-robin baseline never does.
    #[test]
    fn batched_rounds_fuse_shared_weights() {
        let run = |schedule: SchedulePolicy, tag: &str| {
            let mut cfg = test_cfg(tag);
            cfg.schedule = schedule;
            cfg.burst = 4;
            let dir = cfg.spill_dir.clone();
            let mut sched = Scheduler::new(cfg).unwrap();
            for name in ["a", "b", "c"] {
                sched.open(name, Some(9)).unwrap(); // same seed → shared weights
                sched.enqueue(name, steps(8, 3)).unwrap();
            }
            sched.run_until_idle().unwrap();
            let fused = sched.recorder().counter_value(names::SERVE_FUSED_STEPS);
            let solo = sched.recorder().counter_value(names::SERVE_SOLO_STEPS);
            std::fs::remove_dir_all(&dir).ok();
            (fused, solo)
        };
        let (fused_b, solo_b) = run(SchedulePolicy::Batched, "fuse-b");
        assert_eq!((fused_b, solo_b), (24, 0), "3 tenants × 8 steps all fuse");
        let (fused_r, solo_r) = run(SchedulePolicy::RoundRobin, "fuse-r");
        assert_eq!((fused_r, solo_r), (0, 24), "round-robin never fuses");
    }

    /// Control events interleave with bursts in queue order: `!update`
    /// and `!end` apply exactly once, exactly in place.
    #[test]
    fn control_events_apply_in_stream_order() {
        let cfg = test_cfg("control");
        let dir = cfg.spill_dir.clone();
        let mut sched = Scheduler::new(cfg).unwrap();
        sched.open("a", None).unwrap();
        let mut evs = steps(3, 0);
        evs.push(StreamEvent::Update);
        evs.extend(steps(2, 9));
        evs.push(StreamEvent::EndSequence);
        sched.enqueue("a", evs).unwrap();
        sched.run_until_idle().unwrap();
        assert_eq!(sched.pending(), 0);
        let snap = sched.stats();
        assert_eq!(snap.sessions.len(), 1);
        assert_eq!(snap.sessions[0].steps, 5, "5 step events");
        assert_eq!(snap.sessions[0].updates_applied, 1, "one !update");
        assert_eq!(sched.recorder().counter_value(names::SERVE_EVENTS), 7);
        std::fs::remove_dir_all(&dir).ok();
    }
}
