//! In-tree static analysis: the determinism and panic-discipline gate
//! behind `sparse-rtrl analyze`.
//!
//! The repo's core claim — gradients and op counts bit-identical across
//! thread counts, batch widths, and checkpoint round trips — is a property
//! of *code patterns*, not just of tests that happen to exercise the right
//! paths. This module makes the forbidden patterns a build-time error: a
//! dependency-free, comment/string-aware scanner ([`lexer`]) feeds a small
//! rule engine ([`rules`]), and CI runs `analyze --check` as a blocking
//! job. No `syn`, no regex crate — the same house style as
//! [`crate::util::toml_mini`] and [`crate::bench::json`].
//!
//! # Rules
//!
//! * **`unordered-map`** — `HashMap`/`HashSet` in compute modules.
//!   Hash-map iteration order varies per process (SipHash keys are
//!   randomized), so any reduction or traversal over one silently breaks
//!   run-to-run determinism. Compute code uses `BTreeMap`/`Vec` instead.
//! * **`ambient-time`** — `Instant`/`SystemTime` in compute modules.
//!   Clock reads in learner paths either leak into results (fatal) or
//!   tempt time-based branching (worse). Telemetry latency clocks are the
//!   legitimate exception and carry a pragma at each site.
//! * **`ambient-rng`** — `thread_rng`/`from_entropy`/`RandomState`/
//!   `getrandom` in compute modules. All randomness must flow from a
//!   seeded [`crate::util::Pcg64`] whose stream position is checkpointed;
//!   ambient entropy makes replay impossible.
//! * **`float-reduce`** — `.sum::<f32>()`-style reductions, untyped
//!   `.sum()` in float context, and float-seeded `fold`s outside the
//!   pinned-order modules (`util/math.rs`, `rtrl/kernels/rowops.rs`).
//!   Float addition does not reassociate; scattering ad-hoc reductions
//!   across the tree is how "exact RTRL" drifts into
//!   approximately-reproducible RTRL. Integer reductions are exempt.
//! * **`panic`** — `.unwrap()` / `.expect(` / `panic!`-family macros in
//!   library code. A long-running session host must surface malformed
//!   input as `Result`s, not process aborts. Existing sites are frozen in
//!   the committed `ANALYSIS_baseline.json` ratchet ([`baseline`]): counts
//!   may only shrink, so new sites fail `--check` while legacy ones are
//!   paid down over time. This is the only baselinable rule.
//!
//! Scope: rules apply to library sources only — `main.rs` and
//! `#[cfg(test)]` blocks are exempt. Determinism rules are further scoped
//! to the compute-module prefixes minus an explicit allowlist (see
//! [`rules::COMPUTE_PREFIXES`] and [`rules::ALLOWLIST`]).
//!
//! # Suppression pragmas
//!
//! A finding is suppressed only by a same-line or preceding-line comment
//! of the form
//!
//! ```text
//! // analyze: allow(<rule>[, <rule>…]) -- <reason>
//! ```
//!
//! The reason is mandatory, unknown rule names are `bad-pragma` errors,
//! and a pragma that suppresses nothing is an `unused-pragma` error — so
//! stale exemptions cannot accumulate. Neither pragma error is itself
//! suppressible or baselinable.
//!
//! # Workflow
//!
//! * `sparse-rtrl analyze` — scan and print findings (never fails).
//! * `sparse-rtrl analyze --check` — exit non-zero on any violation:
//!   a non-`panic` finding, a pragma error, or a file over its baseline
//!   `panic` allowance.
//! * `sparse-rtrl analyze --fix-baseline` — re-freeze the baseline to the
//!   current counts (use after paying down panic sites).
//! * `sparse-rtrl analyze --json out.json` — also write the machine
//!   report ([`report`]); CI uploads it as `ANALYSIS_report.json`.

pub mod baseline;
pub mod lexer;
pub mod report;
pub mod rules;

pub use baseline::Baseline;
pub use report::Report;
pub use rules::{scan_file, Finding};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// All findings from scanning every `.rs` file under `root`, in
/// deterministic (path-sorted) order, keyed by root-relative path.
pub fn analyze_tree(root: &Path) -> Result<BTreeMap<String, Vec<Finding>>, String> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut out = BTreeMap::new();
    for path in files {
        let rel = rel_name(root, &path)?;
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        out.insert(rel.clone(), scan_file(&rel, &text));
    }
    Ok(out)
}

/// Fold per-file findings + a baseline into the check outcome.
pub fn build_report(
    findings: &BTreeMap<String, Vec<Finding>>,
    baseline: &Baseline,
) -> Report {
    let mut panic_counts: BTreeMap<String, u64> = BTreeMap::new();
    for (rel, fs) in findings {
        let n = fs.iter().filter(|f| f.rule == "panic").count() as u64;
        panic_counts.insert(rel.clone(), n);
    }
    let mut violations = Vec::new();
    for (rel, fs) in findings {
        let over = panic_counts.get(rel).copied().unwrap_or(0) > baseline.allowance(rel);
        for f in fs {
            if f.rule != "panic" {
                violations.push(f.clone());
            } else if over {
                let mut f = f.clone();
                f.message = format!(
                    "{} — {} site(s) in this file, baseline allows {}",
                    f.message,
                    panic_counts.get(rel).copied().unwrap_or(0),
                    baseline.allowance(rel)
                );
                violations.push(f);
            }
        }
    }
    Report {
        files_scanned: findings.len(),
        violations,
        panic_counts,
        baseline_total: baseline.total(),
    }
}

/// Scan `root` and check against the baseline at `baseline_path`.
pub fn run_check(root: &Path, baseline_path: &Path) -> Result<Report, String> {
    let baseline = Baseline::load(baseline_path)?;
    let findings = analyze_tree(root)?;
    Ok(build_report(&findings, &baseline))
}

/// The live panic counts as a fresh baseline (for `--fix-baseline`).
pub fn fresh_baseline(findings: &BTreeMap<String, Vec<Finding>>) -> Baseline {
    let mut counts = BTreeMap::new();
    for (rel, fs) in findings {
        counts.insert(rel.clone(), fs.iter().filter(|f| f.rule == "panic").count() as u64);
    }
    Baseline::from_counts(&counts)
}

fn rel_name(root: &Path, path: &Path) -> Result<String, String> {
    let rel = path
        .strip_prefix(root)
        .map_err(|_| format!("{} is outside {}", path.display(), root.display()))?;
    let parts: Vec<String> = rel
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect();
    Ok(parts.join("/"))
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("cannot list {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("cannot list {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}
