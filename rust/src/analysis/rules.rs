//! The analyzer's rule engine: pattern checks over stripped source plus
//! pragma-based suppression. See `super` (module docs) for what each rule
//! protects and why.
//!
//! Scoping model, applied per file from its root-relative path:
//! * **determinism rules** (`unordered-map`, `ambient-time`, `ambient-rng`)
//!   fire only inside the compute-module prefixes ([`COMPUTE_PREFIXES`]),
//!   minus the explicit [`ALLOWLIST`] — observability and harness code may
//!   read clocks; learner state may not.
//! * **`float-reduce`** fires in every library file except the pinned-order
//!   modules ([`FLOAT_PINNED`]), where reduction order is the module's
//!   documented contract.
//! * **`panic`** fires in every library file and is the only rule that can
//!   be absorbed by the committed baseline ratchet (`super::baseline`).
//! * `main.rs` (the bin target) and `#[cfg(test)]` blocks are exempt from
//!   all rules.

use super::lexer::{strip_source, test_lines, LineComment};
use std::collections::BTreeSet;

/// Rule identifiers a pragma may name.
pub const RULES: [&str; 5] =
    ["unordered-map", "ambient-time", "ambient-rng", "float-reduce", "panic"];

/// Module prefixes whose code computes or carries learner state — the
/// determinism rules apply here.
pub const COMPUTE_PREFIXES: [&str; 8] =
    ["rtrl/", "nn/", "sparse/", "optim/", "session/", "tensor/", "data/", "metrics/"];

/// Compute-adjacent paths where the determinism rules do *not* apply, each
/// with the reason. Wall-clock reads and unordered containers are fine in
/// observability and harness code because nothing there feeds back into
/// gradients, parameters, or engine state.
pub const ALLOWLIST: [(&str, &str); 5] = [
    ("telemetry/", "observability: wall-clock latency is the measurement"),
    ("bench/", "harness: benchmarks time wall-clock by definition"),
    ("report/", "rendering only; consumes finished results"),
    ("coordinator/", "sweep harness: timestamps runs, never gradients"),
    ("runtime/", "artifact plumbing; no learner state"),
];

/// Files whose whole contract is a pinned reduction order; `.sum()` /
/// `fold` over floats is allowed only here.
pub const FLOAT_PINNED: [&str; 2] = ["util/math.rs", "rtrl/kernels/rowops.rs"];

const UNORDERED_IDENTS: [&str; 2] = ["HashMap", "HashSet"];
const TIME_IDENTS: [&str; 2] = ["Instant", "SystemTime"];
const RNG_IDENTS: [&str; 4] = ["thread_rng", "from_entropy", "RandomState", "getrandom"];
const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

/// One finding: a rule violation (or a pragma problem) at a source line.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    /// Root-relative path with `/` separators, e.g. `rtrl/sparse.rs`.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule id — one of [`RULES`], or `bad-pragma` / `unused-pragma`.
    pub rule: String,
    pub message: String,
}

impl Finding {
    /// The canonical `file:line: rule: message` form.
    pub fn render(&self) -> String {
        format!("{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

fn is_word(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn skip_ws(cs: &[char], mut i: usize) -> usize {
    while i < cs.len() && cs[i].is_whitespace() {
        i += 1;
    }
    i
}

/// Read the maximal identifier starting at `i` (empty if none).
fn word_at(cs: &[char], i: usize) -> (usize, String) {
    let mut j = i;
    while j < cs.len() && is_word(cs[j]) {
        j += 1;
    }
    (j, cs[i..j].iter().collect())
}

/// All identifiers in `cs` with their start positions (word-boundary
/// starts only; runs beginning with a digit are number literals, skipped).
fn idents(cs: &[char]) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < cs.len() {
        if is_word(cs[i]) && (i == 0 || !is_word(cs[i - 1])) {
            let (j, w) = word_at(cs, i);
            if !cs[i].is_ascii_digit() {
                out.push((i, w));
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

fn starts(cs: &[char], i: usize, lit: &str) -> bool {
    let mut j = i;
    for c in lit.chars() {
        if cs.get(j) != Some(&c) {
            return false;
        }
        j += 1;
    }
    true
}

/// `.sum::<f32>` / `.product::<f64>` — a typed float reduction.
fn typed_float_reduce(cs: &[char], dot: usize) -> bool {
    let i = skip_ws(cs, dot + 1);
    let (i, w) = word_at(cs, i);
    if w != "sum" && w != "product" {
        return false;
    }
    let i = skip_ws(cs, i);
    if !starts(cs, i, "::") {
        return false;
    }
    let i = skip_ws(cs, i + 2);
    if cs.get(i) != Some(&'<') {
        return false;
    }
    let i = skip_ws(cs, i + 1);
    let (i, ty) = word_at(cs, i);
    if ty != "f32" && ty != "f64" {
        return false;
    }
    let i = skip_ws(cs, i);
    cs.get(i) == Some(&'>')
}

/// `.fold(` whose next few characters mention a float literal or an
/// `f32::` / `f64::` constant — a float fold.
fn float_fold(cs: &[char], dot: usize) -> bool {
    let i = skip_ws(cs, dot + 1);
    let (i, w) = word_at(cs, i);
    if w != "fold" {
        return false;
    }
    let i = skip_ws(cs, i);
    if cs.get(i) != Some(&'(') {
        return false;
    }
    let window = &cs[i + 1..(i + 1 + 48).min(cs.len())];
    has_float_literal(window)
}

/// A float literal (`0.5`, `1f32`) or float-typed path (`f32::MAX`).
fn has_float_literal(w: &[char]) -> bool {
    let mut i = 0;
    while i < w.len() {
        let boundary = i == 0 || !is_word(w[i - 1]);
        if boundary && w[i].is_ascii_digit() {
            let mut j = i;
            while j < w.len() && w[j].is_ascii_digit() {
                j += 1;
            }
            if w.get(j) == Some(&'.') && w.get(j + 1).is_some_and(|c| c.is_ascii_digit()) {
                return true;
            }
            if (starts(w, j, "f32") || starts(w, j, "f64"))
                && !w.get(j + 3).is_some_and(|&c| is_word(c))
            {
                return true;
            }
            i = j;
            continue;
        }
        if boundary && w[i] == 'f' {
            let (j, ty) = word_at(w, i);
            if (ty == "f32" || ty == "f64") && starts(w, skip_ws(w, j), "::") {
                return true;
            }
            i = j;
            continue;
        }
        i += 1;
    }
    false
}

/// First untyped `.sum()` / `.product()` in a statement segment; flagged
/// when the segment also mentions `f32` / `f64` (integer sums reassociate
/// losslessly and are not findings). Returns the char index of the dot.
fn untyped_reduce_in(seg: &[char]) -> Option<usize> {
    let mut found = None;
    for dot in 0..seg.len() {
        if seg[dot] != '.' {
            continue;
        }
        let i = skip_ws(seg, dot + 1);
        let (i, w) = word_at(seg, i);
        if w != "sum" && w != "product" {
            continue;
        }
        let i = skip_ws(seg, i);
        if seg.get(i) != Some(&'(') {
            continue;
        }
        if seg.get(skip_ws(seg, i + 1)) != Some(&')') {
            continue;
        }
        found = Some(dot);
        break;
    }
    let dot = found?;
    let floaty = idents(seg).iter().any(|(_, w)| w == "f32" || w == "f64");
    if floaty {
        Some(dot)
    } else {
        None
    }
}

/// `.unwrap()`, `.expect(`, or a `panic!`-family macro at `i`.
fn panic_at(cs: &[char], i: usize) -> Option<String> {
    if cs[i] == '.' {
        let j = skip_ws(cs, i + 1);
        let (j, w) = word_at(cs, j);
        if w == "unwrap" {
            let j = skip_ws(cs, j);
            if cs.get(j) == Some(&'(') && cs.get(skip_ws(cs, j + 1)) == Some(&')') {
                return Some("unwrap()".into());
            }
        }
        if w == "expect" && cs.get(skip_ws(cs, j)) == Some(&'(') {
            return Some("expect(..)".into());
        }
        return None;
    }
    if is_word(cs[i]) && (i == 0 || !is_word(cs[i - 1])) && !cs[i].is_ascii_digit() {
        let (j, w) = word_at(cs, i);
        if PANIC_MACROS.contains(&w.as_str()) && cs.get(skip_ws(cs, j)) == Some(&'!') {
            return Some(format!("{w}!"));
        }
    }
    None
}

/// A parsed (or failed) suppression pragma.
struct Pragma {
    line: usize,
    rules: Vec<String>,
    /// Line the pragma suppresses: its own if it trails code, else the
    /// next non-blank code line.
    target: usize,
    used: bool,
}

/// A comment is a pragma *candidate* iff it is a plain `//` comment whose
/// first token is `analyze:`. Doc comments (`///`, `//!`) are never
/// candidates, so documentation may quote the pragma syntax freely.
fn pragma_candidate(text: &str) -> Option<&str> {
    let rest = text.strip_prefix("//")?;
    if rest.starts_with('/') || rest.starts_with('!') {
        return None;
    }
    let t = rest.trim_start();
    if t.starts_with("analyze:") {
        Some(t)
    } else {
        None
    }
}

/// Parse `analyze: allow(rule, …) -- reason`; `Err` carries the defect.
fn parse_pragma(t: &str) -> Result<Vec<String>, String> {
    let t = match t.strip_prefix("analyze:") {
        Some(t) => t.trim_start(),
        None => return Err("pragma must start with `analyze:`".into()),
    };
    let t = match t.strip_prefix("allow(") {
        Some(t) => t,
        None => return Err("expected `allow(<rule, …>)`".into()),
    };
    let (inner, rest) = match t.split_once(')') {
        Some(p) => p,
        None => return Err("unclosed `allow(`".into()),
    };
    let rules: Vec<String> = inner.split(',').map(|r| r.trim().to_string()).collect();
    if rules.iter().any(|r| r.is_empty()) {
        return Err("empty rule name in allow(..)".into());
    }
    for r in &rules {
        if !RULES.contains(&r.as_str()) {
            return Err(format!("unknown rule {r:?} (valid: {})", RULES.join(", ")));
        }
    }
    let rest = rest.trim_start();
    let reason = match rest.strip_prefix("--") {
        Some(r) => r.trim(),
        None => return Err("missing `-- <reason>`".into()),
    };
    if reason.is_empty() {
        return Err("missing `-- <reason>`".into());
    }
    Ok(rules)
}

fn in_compute_scope(rel: &str) -> bool {
    if ALLOWLIST.iter().any(|(p, _)| rel.starts_with(p)) {
        return false;
    }
    COMPUTE_PREFIXES.iter().any(|p| rel.starts_with(p))
}

/// Scan one file. `rel` is the root-relative path with `/` separators.
/// Returns all unsuppressed findings, sorted by line.
pub fn scan_file(rel: &str, text: &str) -> Vec<Finding> {
    let stripped = strip_source(text);
    let tlines = test_lines(&stripped.text);
    let slines: Vec<&str> = stripped.text.split('\n').collect();

    let compute = in_compute_scope(rel);
    let is_bin = rel == "main.rs";
    let pinned = FLOAT_PINNED.contains(&rel);

    let mut findings: Vec<Finding> = Vec::new();
    let mut push = |line: usize, rule: &str, message: String| {
        findings.push(Finding { file: rel.to_string(), line, rule: rule.to_string(), message });
    };

    for (idx, sl) in slines.iter().enumerate() {
        let line = idx + 1;
        if tlines.contains(&line) || is_bin {
            continue;
        }
        let lc: Vec<char> = sl.chars().collect();
        if compute {
            for (_, w) in idents(&lc) {
                if UNORDERED_IDENTS.contains(&w.as_str()) {
                    push(line, "unordered-map", format!("{w} iterates in hash order"));
                } else if TIME_IDENTS.contains(&w.as_str()) {
                    push(line, "ambient-time", format!("{w} reads the ambient clock"));
                } else if RNG_IDENTS.contains(&w.as_str()) {
                    push(line, "ambient-rng", format!("{w} draws ambient randomness"));
                }
            }
        }
        if !pinned {
            for dot in 0..lc.len() {
                if lc[dot] != '.' {
                    continue;
                }
                if typed_float_reduce(&lc, dot) {
                    push(line, "float-reduce", "typed float reduction".into());
                } else if float_fold(&lc, dot) {
                    push(line, "float-reduce", "float fold".into());
                }
            }
        }
        let mut col = 0;
        while col < lc.len() {
            if let Some(what) = panic_at(&lc, col) {
                push(line, "panic", format!("{what} in library code"));
            }
            col += 1;
        }
    }

    // untyped reduces need statement context, so they scan whole segments
    if !is_bin && !pinned {
        let cs: Vec<char> = stripped.text.chars().collect();
        let mut newlines_before = 0usize;
        let mut seg_start = 0usize;
        for i in 0..=cs.len() {
            let boundary = i == cs.len() || matches!(cs[i], ';' | '{' | '}');
            if !boundary {
                continue;
            }
            let seg = &cs[seg_start..i];
            if let Some(dot) = untyped_reduce_in(seg) {
                let line =
                    1 + newlines_before + seg[..dot].iter().filter(|&&c| c == '\n').count();
                if !tlines.contains(&line) {
                    push(line, "float-reduce", "untyped float reduction".into());
                }
            }
            newlines_before += seg.iter().filter(|&&c| c == '\n').count();
            seg_start = i + 1;
        }
    }

    // pragmas: parse, resolve targets, then apply suppression
    let code_lines: BTreeSet<usize> = slines
        .iter()
        .enumerate()
        .filter(|(_, l)| !l.trim().is_empty())
        .map(|(i, _)| i + 1)
        .collect();
    let mut pragmas: Vec<Pragma> = Vec::new();
    for LineComment { line, text } in &stripped.comments {
        let Some(t) = pragma_candidate(text) else { continue };
        match parse_pragma(t) {
            Err(e) => push(*line, "bad-pragma", e),
            Ok(rules) => {
                let target = if code_lines.contains(line) {
                    *line
                } else {
                    (*line + 1..=slines.len())
                        .find(|l| code_lines.contains(l))
                        .unwrap_or(usize::MAX)
                };
                pragmas.push(Pragma { line: *line, rules, target, used: false });
            }
        }
    }

    let mut kept: Vec<Finding> = Vec::new();
    for f in findings {
        let mut suppressed = false;
        if RULES.contains(&f.rule.as_str()) {
            for p in pragmas.iter_mut() {
                if p.target == f.line && p.rules.iter().any(|r| r == &f.rule) {
                    p.used = true;
                    suppressed = true;
                }
            }
        }
        if !suppressed {
            kept.push(f);
        }
    }
    for p in &pragmas {
        if !p.used {
            kept.push(Finding {
                file: rel.to_string(),
                line: p.line,
                rule: "unused-pragma".to_string(),
                message: format!("allow({}) suppresses nothing", p.rules.join(", ")),
            });
        }
    }
    kept.sort();
    kept
}
