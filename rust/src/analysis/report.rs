//! Rendering for analyzer results: the human `file:line: rule: message`
//! listing and the machine-readable JSON report CI uploads as an artifact.

use super::baseline::Baseline;
use super::rules::Finding;
use crate::bench::json::escape;
use std::collections::BTreeMap;

/// Schema identifier for the JSON report (`--json`).
pub const SCHEMA: &str = "sparse-rtrl/analysis-report/v1";

/// Everything one `analyze` run produced.
#[derive(Debug, Clone, Default)]
pub struct Report {
    /// Files scanned (even clean ones).
    pub files_scanned: usize,
    /// Findings that are *violations*: every non-`panic` finding, plus all
    /// `panic` findings in files over their baseline allowance.
    pub violations: Vec<Finding>,
    /// Live per-file `panic` finding counts (all of them, baselined or not).
    pub panic_counts: BTreeMap<String, u64>,
    /// Total allowance the baseline grants.
    pub baseline_total: u64,
}

impl Report {
    /// True when `--check` should exit 0.
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Live `panic` finding total.
    pub fn panic_total(&self) -> u64 {
        let mut t = 0;
        for v in self.panic_counts.values() {
            t += v;
        }
        t
    }

    /// The `file:line: rule: message` listing plus a one-line summary.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.violations {
            out.push_str(&f.render());
            out.push('\n');
        }
        out.push_str(&format!(
            "analyze: {} file(s), {} violation(s), panic findings {} (baseline {})\n",
            self.files_scanned,
            self.violations.len(),
            self.panic_total(),
            self.baseline_total,
        ));
        out
    }

    /// The JSON artifact CI uploads.
    pub fn render_json(&self, baseline: &Baseline) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"schema\": \"{}\",\n", escape(SCHEMA)));
        out.push_str(&format!("  \"files_scanned\": {},\n", self.files_scanned));
        out.push_str(&format!("  \"clean\": {},\n", self.clean()));
        out.push_str("  \"violations\": [");
        for (i, f) in self.violations.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \
                 \"message\": \"{}\"}}",
                escape(&f.file),
                f.line,
                escape(&f.rule),
                escape(&f.message)
            ));
        }
        out.push_str(if self.violations.is_empty() { "],\n" } else { "\n  ],\n" });
        out.push_str("  \"panic\": {\n");
        out.push_str(&format!("    \"total\": {},\n", self.panic_total()));
        out.push_str(&format!("    \"baseline_total\": {},\n", self.baseline_total));
        out.push_str("    \"files\": {");
        let entries: Vec<String> = self
            .panic_counts
            .iter()
            .filter(|(_, &c)| c > 0)
            .map(|(k, c)| {
                format!(
                    "\"{}\": {{\"count\": {c}, \"allowed\": {}}}",
                    escape(k),
                    baseline.allowance(k)
                )
            })
            .collect();
        out.push_str(&entries.join(", "));
        out.push_str("}\n  }\n}\n");
        out
    }
}
