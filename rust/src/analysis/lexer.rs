//! Comment/string-aware Rust source scanner for the analyzer.
//!
//! [`strip_source`] "blanks out" the contents of comments, string literals
//! and char literals — replacing them with spaces while preserving every
//! newline and the column of every remaining code character — so the rule
//! layer (`super::rules`) can pattern-match on *code only* without a full
//! Rust parser. Line comments are additionally collected verbatim, because
//! suppression pragmas live in them.
//!
//! Handled forms: `//` line comments (incl. `///` and `//!` doc comments),
//! nested `/* /* */ */` block comments, plain strings with escapes
//! (including escaped newlines), byte strings `b"…"`, raw strings
//! `r"…"` / `r#"…"#` / `br##"…"##`, char and byte-char literals, and the
//! char-literal-vs-lifetime ambiguity (`'a'` vs `&'a str`). The scanner
//! never fails: malformed input degrades to blanking through end-of-file,
//! which is safe for a linter (unterminated literals are rustc's job).

/// One `//` comment, verbatim, with the 1-based line it starts on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LineComment {
    pub line: usize,
    pub text: String,
}

/// Result of [`strip_source`]: blanked text plus the collected comments.
#[derive(Debug, Clone)]
pub struct Stripped {
    /// Source with comment/string/char contents replaced by spaces.
    /// Newline count and code-character positions match the input exactly.
    pub text: String,
    /// Every `//`-style comment (doc comments included), in file order.
    pub comments: Vec<LineComment>,
}

fn is_word(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn prev_is_word(cs: &[char], i: usize) -> bool {
    i > 0 && is_word(cs[i - 1])
}

/// If a raw-string opener (`r"`, `r#"`, `br##"`, …) starts at `i`, return
/// `(opener_length, hash_count)`.
fn raw_open(cs: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if cs.get(j) == Some(&'b') {
        j += 1;
    }
    if cs.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while cs.get(j) == Some(&'#') {
        j += 1;
        hashes += 1;
    }
    if cs.get(j) != Some(&'"') {
        return None;
    }
    Some((j + 1 - i, hashes))
}

/// Blank out comment and literal contents; collect `//` comments.
pub fn strip_source(text: &str) -> Stripped {
    let cs: Vec<char> = text.chars().collect();
    let n = cs.len();
    let mut out: Vec<char> = Vec::with_capacity(n);
    let mut comments = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            out.push('\n');
            line += 1;
            i += 1;
            continue;
        }
        // line comment: collect verbatim, blank in the output
        if c == '/' && cs.get(i + 1) == Some(&'/') {
            let mut j = i;
            while j < n && cs[j] != '\n' {
                j += 1;
            }
            comments.push(LineComment { line, text: cs[i..j].iter().collect() });
            for _ in i..j {
                out.push(' ');
            }
            i = j;
            continue;
        }
        // block comment (nested) — delimiters blanked too
        if c == '/' && cs.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if cs[j] == '/' && j + 1 < n && cs[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if cs[j] == '*' && j + 1 < n && cs[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            let j = j.min(n);
            for &ch in &cs[i..j] {
                if ch == '\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
            }
            i = j;
            continue;
        }
        // raw string: keep the delimiters (code structure), blank the body
        if (c == 'r' || (c == 'b' && cs.get(i + 1) == Some(&'r'))) && !prev_is_word(&cs, i) {
            if let Some((open_len, hashes)) = raw_open(&cs, i) {
                out.extend_from_slice(&cs[i..i + open_len]);
                let mut j = i + open_len;
                let closes = |cs: &[char], j: usize| {
                    cs.get(j) == Some(&'"')
                        && (1..=hashes).all(|h| cs.get(j + h) == Some(&'#'))
                };
                while j < n && !closes(&cs, j) {
                    if cs[j] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    j += 1;
                }
                let close_end = (j + 1 + hashes).min(n);
                out.extend_from_slice(&cs[j.min(n)..close_end]);
                i = close_end;
                continue;
            }
        }
        // byte string b"…"
        if c == 'b' && cs.get(i + 1) == Some(&'"') && !prev_is_word(&cs, i) {
            out.push('b');
            out.push('"');
            let mut j = i + 2;
            while j < n && cs[j] != '"' {
                if cs[j] == '\\' && j + 1 < n {
                    out.push(' ');
                    if cs[j + 1] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    j += 2;
                    continue;
                }
                if cs[j] == '\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
                j += 1;
            }
            if j < n {
                out.push('"');
            }
            i = j + 1;
            continue;
        }
        // plain string
        if c == '"' {
            out.push('"');
            let mut j = i + 1;
            while j < n && cs[j] != '"' {
                if cs[j] == '\\' && j + 1 < n {
                    out.push(' ');
                    if cs[j + 1] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    j += 2;
                    continue;
                }
                if cs[j] == '\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
                j += 1;
            }
            if j < n {
                out.push('"');
            }
            i = j + 1;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            // escaped char literal: '\n', '\'', '\u{1F600}', …
            if cs.get(i + 1) == Some(&'\\') {
                let mut j = i + 2;
                while j < n && cs[j] != '\'' && cs[j] != '\n' {
                    j += 1;
                }
                out.push('\'');
                for _ in i + 1..j {
                    out.push(' ');
                }
                if cs.get(j) == Some(&'\'') {
                    out.push('\'');
                    i = j + 1;
                } else {
                    i = j;
                }
                continue;
            }
            // plain char literal: 'x'
            if i + 2 < n && cs[i + 2] == '\'' && cs[i + 1] != '\n' {
                out.push('\'');
                out.push(' ');
                out.push('\'');
                i += 3;
                continue;
            }
            // lifetime (or stray quote): emit as-is
            out.push('\'');
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    Stripped { text: out.into_iter().collect(), comments }
}

/// 1-based line numbers covered by `#[cfg(test)]`-gated items in *stripped*
/// text (strings already blanked, so braces inside literals cannot
/// unbalance the match). From each attribute, the scanner brace-matches
/// the first `{ … }` that follows — in this codebase every occurrence is a
/// `#[cfg(test)] mod tests { … }` block.
pub fn test_lines(stripped: &str) -> std::collections::BTreeSet<usize> {
    let cs: Vec<char> = stripped.chars().collect();
    let mut lines = std::collections::BTreeSet::new();
    let mut pos = 0usize;
    while let Some(attr_end) = find_cfg_test(&cs, pos) {
        let attr_start = pos_of_attr_start(&cs, attr_end);
        pos = attr_end;
        let mut i = attr_end;
        while i < cs.len() && cs[i] != '{' {
            i += 1;
        }
        if i == cs.len() {
            break;
        }
        let mut depth = 0usize;
        let mut j = i;
        while j < cs.len() {
            if cs[j] == '{' {
                depth += 1;
            } else if cs[j] == '}' {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            j += 1;
        }
        let start_line = 1 + cs[..attr_start].iter().filter(|&&c| c == '\n').count();
        let end_line = 1 + cs[..j.min(cs.len())].iter().filter(|&&c| c == '\n').count();
        for l in start_line..=end_line {
            lines.insert(l);
        }
    }
    lines
}

/// Find the next `#[cfg(test)]` attribute at or after `from`; returns the
/// index one past its closing `]`.
fn find_cfg_test(cs: &[char], from: usize) -> Option<usize> {
    let mut i = from;
    while i < cs.len() {
        if cs[i] == '#' {
            if let Some(end) = match_cfg_test_at(cs, i) {
                return Some(end);
            }
        }
        i += 1;
    }
    None
}

fn skip_ws(cs: &[char], mut i: usize) -> usize {
    while i < cs.len() && cs[i].is_whitespace() {
        i += 1;
    }
    i
}

fn eat(cs: &[char], i: usize, lit: &str) -> Option<usize> {
    let mut j = i;
    for c in lit.chars() {
        if cs.get(j) != Some(&c) {
            return None;
        }
        j += 1;
    }
    Some(j)
}

fn match_cfg_test_at(cs: &[char], i: usize) -> Option<usize> {
    let j = eat(cs, i, "#")?;
    let j = skip_ws(cs, j);
    let j = eat(cs, j, "[")?;
    let j = skip_ws(cs, j);
    let j = eat(cs, j, "cfg")?;
    let j = skip_ws(cs, j);
    let j = eat(cs, j, "(")?;
    let j = skip_ws(cs, j);
    let j = eat(cs, j, "test")?;
    let j = skip_ws(cs, j);
    let j = eat(cs, j, ")")?;
    let j = skip_ws(cs, j);
    eat(cs, j, "]")
}

/// The attribute end index is where matching started from `#`; recover the
/// `#` position by scanning back (the attribute contains no newline in
/// practice, but scanning is bounded either way).
fn pos_of_attr_start(cs: &[char], attr_end: usize) -> usize {
    let mut i = attr_end;
    while i > 0 && cs[i - 1] != '#' {
        i -= 1;
    }
    i.saturating_sub(1)
}
