//! The committed panic-discipline ratchet: `ANALYSIS_baseline.json`.
//!
//! The baseline freezes today's per-file `panic`-rule finding counts.
//! `analyze --check` fails when any file's live count *exceeds* its frozen
//! allowance — so new `unwrap()`/`expect()`/`panic!` sites cannot land —
//! while counts below the allowance pass, and `analyze --fix-baseline`
//! re-freezes them so the ratchet only ever moves down. Only the `panic`
//! rule is baselinable; every other rule must be fixed or pragma'd at the
//! offending line.

use crate::bench::json::{self, Json};
use std::collections::BTreeMap;
use std::path::Path;

/// Schema identifier written into (and required of) the baseline file.
pub const SCHEMA: &str = "sparse-rtrl/analysis-baseline/v1";

/// Frozen per-file allowances for the `panic` rule.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Baseline {
    /// Root-relative path → allowed `panic` finding count.
    pub files: BTreeMap<String, u64>,
}

impl Baseline {
    /// Sum of all per-file allowances.
    pub fn total(&self) -> u64 {
        let mut t = 0;
        for v in self.files.values() {
            t += v;
        }
        t
    }

    /// Allowance for one file (0 when absent).
    pub fn allowance(&self, rel: &str) -> u64 {
        self.files.get(rel).copied().unwrap_or(0)
    }

    /// Build a baseline from live per-file counts (zero counts dropped).
    pub fn from_counts(counts: &BTreeMap<String, u64>) -> Baseline {
        let files =
            counts.iter().filter(|(_, &c)| c > 0).map(|(k, &c)| (k.clone(), c)).collect();
        Baseline { files }
    }

    /// Parse a baseline file.
    pub fn load(path: &Path) -> Result<Baseline, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read baseline {}: {e}", path.display()))?;
        let v = json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        let schema = v.get("schema").and_then(Json::as_str).unwrap_or("");
        if schema != SCHEMA {
            return Err(format!(
                "{}: schema {schema:?}, this build reads {SCHEMA:?}",
                path.display()
            ));
        }
        let mut files = BTreeMap::new();
        match v.get("files") {
            Some(Json::Obj(m)) => {
                for (k, count) in m {
                    let c = count.as_u64().ok_or_else(|| {
                        format!("{}: files.{k} is not a non-negative integer", path.display())
                    })?;
                    files.insert(k.clone(), c);
                }
            }
            _ => return Err(format!("{}: missing `files` object", path.display())),
        }
        Ok(Baseline { files })
    }

    /// Render to the committed JSON form (stable key order, one file per
    /// line, so ratchet diffs review cleanly).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"schema\": \"{}\",\n", json::escape(SCHEMA)));
        out.push_str("  \"rule\": \"panic\",\n");
        out.push_str(&format!("  \"total\": {},\n", self.total()));
        out.push_str("  \"files\": {");
        for (i, (k, c)) in self.files.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\n    \"{}\": {c}", json::escape(k)));
        }
        if self.files.is_empty() {
            out.push_str("}\n");
        } else {
            out.push_str("\n  }\n");
        }
        out.push_str("}\n");
        out
    }

    /// Write the committed form to `path`.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.render())
            .map_err(|e| format!("cannot write baseline {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_load_roundtrip() {
        let mut counts = BTreeMap::new();
        counts.insert("a/b.rs".to_string(), 3u64);
        counts.insert("c.rs".to_string(), 1u64);
        counts.insert("dropped.rs".to_string(), 0u64);
        let b = Baseline::from_counts(&counts);
        assert_eq!(b.total(), 4);
        assert_eq!(b.allowance("a/b.rs"), 3);
        assert_eq!(b.allowance("dropped.rs"), 0);
        let dir = std::env::temp_dir().join("sparse_rtrl_baseline_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.json");
        b.save(&path).unwrap();
        assert_eq!(Baseline::load(&path).unwrap(), b);
    }

    #[test]
    fn wrong_schema_is_rejected() {
        let dir = std::env::temp_dir().join("sparse_rtrl_baseline_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, "{\"schema\": \"other/v9\", \"files\": {}}").unwrap();
        let e = Baseline::load(&path).unwrap_err();
        assert!(e.contains("schema"), "{e}");
    }
}
