//! Backpropagation through time — the standard offline baseline, over the
//! stacked network.
//!
//! Stores the full forward history (the `T·N`-memory growth the paper
//! motivates against) and runs an exact reverse pass at `end_sequence`,
//! mirroring the block lower-bidiagonal forward structure in reverse: at
//! each stored step the adjoint flows top-down through the layers
//! (`δa_{l-1} += C_lᵀ δv_l`, the within-step cross-layer path) and then
//! backwards in time through each layer's own recurrence
//! (`δa_l^{(t-1)} += J_lᵀ δv_l`). Because both BPTT and RTRL differentiate
//! the same surrogate-gradient computational graph, their gradients agree
//! to FP tolerance at any depth — the cross-check used by
//! `rust/tests/grad_equivalence.rs`.
//!
//! The reverse pass does exploit activity sparsity (`δv_k = φ'_k·…` vanishes
//! where `φ' = 0`), matching Subramoney et al. (2022)'s sparse-BPTT
//! observation; the *memory* still grows with `T`, which is the axis the
//! paper contrasts. Its adjoint accumulations run on the same lane-chunked
//! [`super::kernels`] row kernels as the online engines.

use super::kernels::{self, CrossSelect, JacobianSlab, OwnSelect, RowSelect};
use super::{supervised_step, EngineState, GradientEngine, StateError, StepResult, Target};
use crate::metrics::{OpCounter, Phase};
use crate::nn::{LayerStack, Loss, Readout, StackScratch};

/// Snapshot-format version of [`Bptt`] (see [`EngineState`]).
const STATE_VERSION: u32 = 1;

/// One stored timestep of forward history.
struct Frame {
    x: Vec<f32>,
    /// Concatenated previous state (`R^N`).
    a_prev: Vec<f32>,
    scratch: StackScratch,
    /// Credit assignment c̄_t = ∂L_t/∂a_top,t (zero vector when unsupervised).
    c_bar: Vec<f32>,
}

/// BPTT engine (per-sequence state; reusable).
pub struct Bptt {
    frames: Vec<Frame>,
    a_prev: Vec<f32>,
    grads: Vec<f32>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    c_bar: Vec<f32>,
    /// Per-(frame, layer) step-Jacobian slab, rebuilt from stored scratch
    /// during the reverse pass (scratch, not part of the tape).
    slab: JacobianSlab,
    /// Rows with nonzero adjoint `δv` at the current frame/layer.
    rows_buf: Vec<u32>,
    /// Peak stored frames (memory reporting).
    peak_frames: usize,
    n_total: usize,
    n_in: usize,
    top_n: usize,
}

impl Bptt {
    pub fn new(net: &LayerStack, readout_n_out: usize) -> Self {
        let n_total = net.total_units();
        Bptt {
            frames: Vec::new(),
            a_prev: vec![0.0; n_total],
            grads: vec![0.0; net.p()],
            logits: vec![0.0; readout_n_out],
            dlogits: vec![0.0; readout_n_out],
            c_bar: vec![0.0; net.top_n()],
            slab: JacobianSlab::new(),
            rows_buf: Vec::new(),
            peak_frames: 0,
            n_total,
            n_in: net.n_in(),
            top_n: net.top_n(),
        }
    }
}

impl GradientEngine for Bptt {
    fn name(&self) -> &'static str {
        "bptt"
    }

    fn begin_sequence(&mut self) {
        self.frames.clear();
        self.a_prev.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn step(
        &mut self,
        net: &LayerStack,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult {
        let mut scratch = net.scratch();
        net.forward(&self.a_prev, x, &mut scratch, ops);
        let active_units = scratch.active_units();
        let deriv_units = scratch.deriv_units();

        let (loss_val, correct, prediction) = supervised_step(
            readout,
            loss,
            &scratch.top().a,
            target,
            &mut self.logits,
            &mut self.dlogits,
            &mut self.c_bar,
            ops,
        );
        let c_bar = if loss_val.is_some() {
            self.c_bar.clone()
        } else {
            vec![0.0; self.top_n]
        };

        let mut a_new = vec![0.0; self.n_total];
        scratch.write_state(&mut a_new);
        self.frames.push(Frame {
            x: x.to_vec(),
            a_prev: std::mem::replace(&mut self.a_prev, a_new),
            scratch,
            c_bar,
        });
        self.peak_frames = self.peak_frames.max(self.frames.len());

        StepResult {
            loss: loss_val,
            correct,
            prediction,
            active_units,
            deriv_units,
            influence_sparsity: None,
        }
    }

    fn end_sequence(&mut self, net: &LayerStack, _readout: &mut Readout, ops: &mut OpCounter) {
        let n = self.n_total;
        let layers = net.layers();
        let top_off = net.layout().state_offset(layers - 1);
        // da = ∂𝓛/∂a accumulated for the current step (all layers);
        // carry = own-recurrence adjoint flowing to step t−1.
        let mut da = vec![0.0f32; n];
        let mut carry = vec![0.0f32; n];
        let mut dv = vec![0.0f32; n];
        for t in (0..self.frames.len()).rev() {
            let frame = &self.frames[t];
            // credit enters at the top layer
            for (d, &c) in da[top_off..].iter_mut().zip(&frame.c_bar) {
                *d += c;
            }
            carry.iter_mut().for_each(|v| *v = 0.0);
            // top-down: within-step cross-layer adjoint reaches lower
            // layers before they are processed
            for l in (0..layers).rev() {
                ops.set_layer(l);
                let cell = net.layer(l);
                let sl = &frame.scratch.layers[l];
                let nl = cell.n();
                let soff = net.layout().state_offset(l);
                let mut bptt_macs = 0u64;
                for k in 0..nl {
                    dv[soff + k] = sl.dphi[k] * da[soff + k];
                }
                bptt_macs += nl as u64;
                // Step-Jacobian slab for this (frame, layer): only the rows
                // whose adjoint is nonzero — the exact evaluation set of the
                // per-scalar path. Eval + scatter are charged together at
                // the historical (1 + cost) per-entry rate below.
                self.rows_buf.clear();
                for k in 0..nl {
                    if dv[soff + k] != 0.0 {
                        self.rows_buf.push(k as u32);
                    }
                }
                let cross_sel = if l > 0 { CrossSelect::All } else { CrossSelect::Skip };
                self.slab.build(
                    cell,
                    sl,
                    RowSelect::Rows(&self.rows_buf),
                    OwnSelect::Kept,
                    cross_sel,
                );
                // grads += M̄_lᵀ dv_l (structural nonzeros only)
                let input_l: &[f32] =
                    if l == 0 { &frame.x } else { &frame.scratch.layers[l - 1].a };
                let a_prev_l = &frame.a_prev[soff..soff + nl];
                let poff = net.layout().param_offset(l);
                for &k in &self.rows_buf {
                    let dvk = dv[soff + k as usize];
                    let grads = &mut self.grads;
                    cell.immediate_row(
                        sl,
                        a_prev_l,
                        input_l,
                        k as usize,
                        |pi, val| grads[poff + pi] += dvk * val,
                        ops,
                    );
                }
                // own recurrence: carry_l = J_lᵀ dv_l (reaches step t−1),
                // a sparse adjoint scatter over the slab row; then the
                // cross-layer push δa_{l-1} += C_lᵀ dv_l (same step, dense)
                for &k in &self.rows_buf {
                    let dvk = dv[soff + k as usize];
                    let (jcols, jvals) = self.slab.own_row(k as usize);
                    kernels::scatter_axpy(&mut carry[soff..soff + nl], dvk, jcols, jvals);
                    bptt_macs += jcols.len() as u64 * (1 + cell.dv_da_cost());
                    if l > 0 {
                        let soff_prev = net.layout().state_offset(l - 1);
                        let nprev = net.layer(l - 1).n();
                        kernels::axpy(
                            &mut da[soff_prev..soff_prev + nprev],
                            dvk,
                            self.slab.cross_row(k as usize),
                        );
                        bptt_macs += nprev as u64 * (1 + cell.dv_dx_cost());
                    }
                }
                ops.macs(Phase::GradCombine, bptt_macs);
            }
            ops.clear_layer();
            std::mem::swap(&mut da, &mut carry);
        }
        self.frames.clear();
    }

    fn grads(&self) -> &[f32] {
        &self.grads
    }

    fn reset_grads(&mut self) {
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn state_memory_words(&self) -> usize {
        // x + a_prev(N) + scratch(7N) + c̄ per frame — the T·N growth term.
        self.peak_frames * (self.n_in + 8 * self.n_total + self.top_n)
    }

    fn activations(&self) -> &[f32] {
        &self.a_prev
    }

    fn save_state(&self) -> EngineState {
        // The whole stored tape travels: per frame `x | a_prev | per-layer
        // (v a dphi u z gu gz) | c̄`, concatenated in time order. This is the
        // honest cost of checkpointing BPTT mid-sequence — the T·N history
        // the paper's online methods exist to avoid.
        let frame_len = self.n_in + 8 * self.n_total + self.top_n;
        let mut data = Vec::with_capacity(self.frames.len() * frame_len);
        for f in &self.frames {
            data.extend_from_slice(&f.x);
            data.extend_from_slice(&f.a_prev);
            for sl in &f.scratch.layers {
                for buf in [&sl.v, &sl.a, &sl.dphi, &sl.u, &sl.z, &sl.gu, &sl.gz] {
                    data.extend_from_slice(buf);
                }
            }
            data.extend_from_slice(&f.c_bar);
        }
        let mut st = EngineState::new(self.name(), STATE_VERSION);
        st.put_scalar("frames", self.frames.len() as u64);
        st.put_scalar("peak_frames", self.peak_frames as u64);
        st.put_floats("frame_data", data);
        st.put_floats("a_prev", self.a_prev.clone());
        st.put_floats("grads", self.grads.clone());
        st
    }

    fn load_state(&mut self, net: &LayerStack, state: &EngineState) -> Result<(), StateError> {
        fn take<'a>(data: &'a [f32], off: &mut usize, len: usize) -> &'a [f32] {
            let s = &data[*off..*off + len];
            *off += len;
            s
        }
        state.require(self.name(), STATE_VERSION)?;
        if net.total_units() != self.n_total || net.n_in() != self.n_in {
            return Err(StateError("stack does not match the engine's dimensions".into()));
        }
        let count = state.scalar("frames")? as usize;
        let frame_len = self.n_in + 8 * self.n_total + self.top_n;
        let data = state.floats_exact("frame_data", count * frame_len)?;
        let a_prev = state.floats_exact("a_prev", self.n_total)?;
        let grads = state.floats_exact("grads", self.grads.len())?;
        self.frames.clear();
        for i in 0..count {
            let mut off = i * frame_len;
            let x = take(data, &mut off, self.n_in).to_vec();
            let fa_prev = take(data, &mut off, self.n_total).to_vec();
            let mut scratch = net.scratch();
            for sl in scratch.layers.iter_mut() {
                let n = sl.v.len();
                sl.v.copy_from_slice(take(data, &mut off, n));
                sl.a.copy_from_slice(take(data, &mut off, n));
                sl.dphi.copy_from_slice(take(data, &mut off, n));
                sl.u.copy_from_slice(take(data, &mut off, n));
                sl.z.copy_from_slice(take(data, &mut off, n));
                sl.gu.copy_from_slice(take(data, &mut off, n));
                sl.gz.copy_from_slice(take(data, &mut off, n));
            }
            let c_bar = take(data, &mut off, self.top_n).to_vec();
            self.frames.push(Frame { x, a_prev: fa_prev, scratch, c_bar });
        }
        self.peak_frames = state.scalar("peak_frames")? as usize;
        self.a_prev.copy_from_slice(a_prev);
        self.grads.copy_from_slice(grads);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{LossKind, RnnCell};
    use crate::util::Pcg64;

    #[test]
    fn memory_grows_with_sequence_length() {
        let mut rng = Pcg64::new(30);
        let net = LayerStack::single(RnnCell::egru(6, 2, 0.1, 0.3, 0.5, None, &mut rng));
        let mut readout = Readout::new(2, 6, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = Bptt::new(&net, 2);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        for _ in 0..10 {
            eng.step(&net, &mut readout, &mut loss, &[0.5, 0.1], Target::None, &mut ops);
        }
        assert_eq!(eng.frames.len(), 10);
        eng.end_sequence(&net, &mut readout, &mut ops);
        assert!(eng.frames.is_empty());
        assert_eq!(eng.peak_frames, 10);
    }

    #[test]
    fn grad_nonzero_for_learnable_sequence() {
        let mut rng = Pcg64::new(31);
        let net = LayerStack::single(RnnCell::egru(8, 2, 0.05, 0.3, 0.5, None, &mut rng));
        let mut readout = Readout::new(2, 8, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = Bptt::new(&net, 2);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        for t in 0..6 {
            let x = [(t as f32 * 0.7).sin(), (t as f32 * 0.3).cos()];
            let target = if t == 5 { Target::Class(0) } else { Target::None };
            eng.step(&net, &mut readout, &mut loss, &x, target, &mut ops);
        }
        eng.end_sequence(&net, &mut readout, &mut ops);
        let nonzero = eng.grads().iter().filter(|&&g| g != 0.0).count();
        assert!(nonzero > 0, "expected some nonzero grads");
    }

    /// Depth 2: the within-step cross-layer adjoint must reach layer 0 —
    /// with supervision only at the top, layer 0's parameters still get a
    /// gradient.
    #[test]
    fn depth2_credit_reaches_bottom_layer() {
        let mut rng = Pcg64::new(32);
        let l0 = RnnCell::egru(6, 2, 0.05, 0.3, 0.9, None, &mut rng);
        let l1 = RnnCell::egru(4, 6, 0.05, 0.3, 0.9, None, &mut rng);
        let net = LayerStack::new(vec![l0, l1]);
        let mut readout = Readout::new(2, 4, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = Bptt::new(&net, 2);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        let mut xr = Pcg64::new(4);
        for t in 0..8 {
            let target = if t >= 6 { Target::Class(t % 2) } else { Target::None };
            eng.step(&net, &mut readout, &mut loss, &[xr.normal(), xr.normal()], target, &mut ops);
        }
        eng.end_sequence(&net, &mut readout, &mut ops);
        let p0 = net.layer(0).p();
        assert!(eng.grads()[..p0].iter().any(|&g| g != 0.0), "layer 0 got no credit");
        assert!(eng.grads()[p0..].iter().any(|&g| g != 0.0), "layer 1 got no credit");
    }
}
