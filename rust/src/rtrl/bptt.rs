//! Backpropagation through time — the standard offline baseline.
//!
//! Stores the full forward history (the `T·n`-memory growth the paper
//! motivates against) and runs an exact reverse pass at `end_sequence`.
//! Because both BPTT and RTRL differentiate the same surrogate-gradient
//! computational graph, their gradients agree to FP tolerance — the
//! cross-check used by `rust/tests/grad_equivalence.rs`.
//!
//! The reverse pass does exploit activity sparsity (`δv_k = φ'_k·…` vanishes
//! where `φ' = 0`), matching Subramoney et al. (2022)'s sparse-BPTT
//! observation; the *memory* still grows with `T`, which is the axis the
//! paper contrasts.

use super::{supervised_step, GradientEngine, StepResult, Target};
use crate::metrics::{OpCounter, Phase};
use crate::nn::{CellScratch, Loss, Readout, RnnCell};

/// One stored timestep of forward history.
struct Frame {
    x: Vec<f32>,
    a_prev: Vec<f32>,
    scratch: CellScratch,
    /// Credit assignment c̄_t = ∂L_t/∂a_t (zero vector when unsupervised).
    c_bar: Vec<f32>,
}

/// BPTT engine (per-sequence state; reusable).
pub struct Bptt {
    frames: Vec<Frame>,
    a_prev: Vec<f32>,
    grads: Vec<f32>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    c_bar: Vec<f32>,
    /// Peak stored frames (memory reporting).
    peak_frames: usize,
    n: usize,
    n_in: usize,
}

impl Bptt {
    pub fn new(cell: &RnnCell, readout_n_out: usize) -> Self {
        let n = cell.n();
        Bptt {
            frames: Vec::new(),
            a_prev: vec![0.0; n],
            grads: vec![0.0; cell.p()],
            logits: vec![0.0; readout_n_out],
            dlogits: vec![0.0; readout_n_out],
            c_bar: vec![0.0; n],
            peak_frames: 0,
            n,
            n_in: cell.n_in(),
        }
    }
}

impl GradientEngine for Bptt {
    fn name(&self) -> &'static str {
        "bptt"
    }

    fn begin_sequence(&mut self) {
        self.frames.clear();
        self.a_prev.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn step(
        &mut self,
        cell: &RnnCell,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult {
        let n = cell.n();
        let mut scratch = CellScratch::new(n);
        cell.forward(&self.a_prev, x, &mut scratch, ops);
        let active_units = scratch.active_units();
        let deriv_units = scratch.deriv_units();

        let (loss_val, correct) = supervised_step(
            readout,
            loss,
            &scratch.a,
            target,
            &mut self.logits,
            &mut self.dlogits,
            &mut self.c_bar,
            ops,
        );
        let c_bar = if loss_val.is_some() {
            self.c_bar.clone()
        } else {
            vec![0.0; n]
        };

        self.frames.push(Frame {
            x: x.to_vec(),
            a_prev: self.a_prev.clone(),
            scratch: scratch.clone(),
            c_bar,
        });
        self.peak_frames = self.peak_frames.max(self.frames.len());
        self.a_prev.copy_from_slice(&scratch.a);

        StepResult {
            loss: loss_val,
            correct,
            active_units,
            deriv_units,
            influence_sparsity: None,
        }
    }

    fn end_sequence(&mut self, cell: &RnnCell, _readout: &mut Readout, ops: &mut OpCounter) {
        let n = cell.n();
        // da = ∂𝓛/∂a_t accumulated backwards; dv = φ'_t ⊙ da.
        let mut da = vec![0.0f32; n];
        let mut dv = vec![0.0f32; n];
        for t in (0..self.frames.len()).rev() {
            let frame = &self.frames[t];
            // da_t = c̄_t + (carried term already in `da` from t+1)
            for (d, &c) in da.iter_mut().zip(&frame.c_bar) {
                *d += c;
            }
            let mut bptt_macs = 0u64;
            for k in 0..n {
                dv[k] = frame.scratch.dphi[k] * da[k];
            }
            bptt_macs += n as u64;
            // grads += M̄_tᵀ dv (structural nonzeros only)
            for k in 0..n {
                if dv[k] == 0.0 {
                    continue;
                }
                let dvk = dv[k];
                let grads = &mut self.grads;
                cell.immediate_row(
                    &frame.scratch,
                    &frame.a_prev,
                    &frame.x,
                    k,
                    |pi, val| grads[pi] += dvk * val,
                    ops,
                );
            }
            // da_{t-1} = J_tᵀ dv ( = Σ_k dv_k · ∂v_k/∂a_l )
            da.iter_mut().for_each(|d| *d = 0.0);
            for k in 0..n {
                if dv[k] == 0.0 {
                    continue;
                }
                let dvk = dv[k];
                for &l in cell.kept_cols(k) {
                    da[l as usize] += dvk * cell.dv_da(&frame.scratch, k, l as usize);
                    bptt_macs += 1 + cell.dv_da_cost();
                }
            }
            ops.macs(Phase::GradCombine, bptt_macs);
        }
        self.frames.clear();
    }

    fn grads(&self) -> &[f32] {
        &self.grads
    }

    fn reset_grads(&mut self) {
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn state_memory_words(&self) -> usize {
        // x + a_prev + scratch(7n) + c̄ per frame — the T·n growth term.
        self.peak_frames * (self.n_in + 9 * self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LossKind;
    use crate::util::Pcg64;

    #[test]
    fn memory_grows_with_sequence_length() {
        let mut rng = Pcg64::new(30);
        let cell = RnnCell::egru(6, 2, 0.1, 0.3, 0.5, None, &mut rng);
        let mut readout = Readout::new(2, 6, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = Bptt::new(&cell, 2);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        for _ in 0..10 {
            eng.step(&cell, &mut readout, &mut loss, &[0.5, 0.1], Target::None, &mut ops);
        }
        assert_eq!(eng.frames.len(), 10);
        eng.end_sequence(&cell, &mut readout, &mut ops);
        assert!(eng.frames.is_empty());
        assert_eq!(eng.peak_frames, 10);
    }

    #[test]
    fn grad_nonzero_for_learnable_sequence() {
        let mut rng = Pcg64::new(31);
        let cell = RnnCell::egru(8, 2, 0.05, 0.3, 0.5, None, &mut rng);
        let mut readout = Readout::new(2, 8, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = Bptt::new(&cell, 2);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        for t in 0..6 {
            let x = [(t as f32 * 0.7).sin(), (t as f32 * 0.3).cos()];
            let target = if t == 5 { Target::Class(0) } else { Target::None };
            eng.step(&cell, &mut readout, &mut loss, &x, target, &mut ops);
        }
        eng.end_sequence(&cell, &mut readout, &mut ops);
        let nonzero = eng.grads().iter().filter(|&&g| g != 0.0).count();
        assert!(nonzero > 0, "expected some nonzero grads");
    }
}
