//! Column compaction for parameter sparsity.
//!
//! With a fixed mask, entire columns of `M`/`M̄` are structurally zero for
//! the dropped recurrent parameters and stay zero across timesteps (§5).
//! A [`ColumnMap`] stores only the `ω̃p`-ish live columns: the mapping
//! between flat parameter indices (`R^p`) and compact column indices.

use crate::nn::RnnCell;

/// Sentinel for "parameter not tracked" in the reverse map.
const UNTRACKED: u32 = u32::MAX;

/// Bijection between tracked flat parameter indices and compact columns.
#[derive(Debug, Clone)]
pub struct ColumnMap {
    /// Compact column → flat parameter index (sorted ascending).
    cols: Vec<u32>,
    /// Flat parameter index → compact column (or `UNTRACKED`).
    rank: Vec<u32>,
}

impl ColumnMap {
    /// Identity map over all `p` parameters (the dense-columns case).
    pub fn full(p: usize) -> Self {
        ColumnMap {
            cols: (0..p as u32).collect(),
            rank: (0..p as u32).collect(),
        }
    }

    /// Map tracking every parameter except masked-out recurrent entries.
    /// Equals [`ColumnMap::full`] when the cell is dense.
    pub fn from_cell(cell: &RnnCell) -> Self {
        let p = cell.p();
        let Some(mask) = cell.mask() else {
            return Self::full(p);
        };
        let n = cell.n();
        let mut dropped = vec![false; p];
        let layout = cell.layout();
        for b in cell.recurrent_blocks() {
            for r in 0..n {
                let range = layout.row_range(b, r);
                for (c, pi) in range.enumerate() {
                    if !mask.is_kept(r, c) {
                        dropped[pi] = true;
                    }
                }
            }
        }
        let mut cols = Vec::with_capacity(p);
        let mut rank = vec![UNTRACKED; p];
        for (pi, &d) in dropped.iter().enumerate() {
            if !d {
                rank[pi] = cols.len() as u32;
                cols.push(pi as u32);
            }
        }
        ColumnMap { cols, rank }
    }

    /// Number of tracked (compact) columns.
    #[inline]
    pub fn len(&self) -> usize {
        self.cols.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }

    /// Total flat parameter count `p`.
    #[inline]
    pub fn p(&self) -> usize {
        self.rank.len()
    }

    /// Flat parameter index of compact column `j`.
    #[inline]
    pub fn param_of(&self, j: usize) -> usize {
        self.cols[j] as usize
    }

    /// Compact column of flat parameter `pi`, if tracked.
    #[inline]
    pub fn compact_of(&self, pi: usize) -> Option<usize> {
        let r = self.rank[pi];
        if r == UNTRACKED {
            None
        } else {
            Some(r as usize)
        }
    }

    /// Compact column of flat parameter `pi`, assuming it is tracked.
    /// Panics (debug) if not — used where structure guarantees tracking.
    #[inline]
    pub fn compact_of_unchecked(&self, pi: usize) -> usize {
        debug_assert_ne!(self.rank[pi], UNTRACKED, "param {pi} untracked");
        self.rank[pi] as usize
    }

    /// Fraction of parameters tracked (≥ ω̃ since input/bias cols are dense).
    pub fn tracked_fraction(&self) -> f32 {
        if self.rank.is_empty() {
            1.0
        } else {
            self.cols.len() as f32 / self.rank.len() as f32
        }
    }

    /// Scatter a compact row into a dense `R^p` buffer: `dense[param_of(j)] += compact[j] · scale`.
    pub fn scatter_add(&self, compact: &[f32], scale: f32, dense: &mut [f32]) {
        debug_assert_eq!(compact.len(), self.cols.len());
        debug_assert_eq!(dense.len(), self.rank.len());
        for (j, &v) in compact.iter().enumerate() {
            if v != 0.0 {
                dense[self.cols[j] as usize] += v * scale;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::MaskPattern;
    use crate::util::Pcg64;

    #[test]
    fn full_is_identity() {
        let m = ColumnMap::full(10);
        assert_eq!(m.len(), 10);
        for i in 0..10 {
            assert_eq!(m.param_of(i), i);
            assert_eq!(m.compact_of(i), Some(i));
        }
    }

    #[test]
    fn from_dense_cell_tracks_everything() {
        let mut rng = Pcg64::new(1);
        let cell = RnnCell::egru(8, 2, 0.1, 0.3, 0.5, None, &mut rng);
        let m = ColumnMap::from_cell(&cell);
        assert_eq!(m.len(), cell.p());
    }

    #[test]
    fn from_masked_cell_drops_masked_recurrent_params() {
        let mut rng = Pcg64::new(2);
        let n = 8;
        let mask = MaskPattern::random(n, n, 0.25, &mut rng);
        let cell = RnnCell::egru(n, 2, 0.1, 0.3, 0.5, Some(mask.clone()), &mut rng);
        let m = ColumnMap::from_cell(&cell);
        // p − 2 recurrent blocks × dropped entries
        let dropped_per_block = n * n - mask.kept();
        assert_eq!(m.len(), cell.p() - 2 * dropped_per_block);
        // every tracked recurrent param must be kept in the mask
        let layout = cell.layout();
        for j in 0..m.len() {
            let pi = m.param_of(j);
            let (b, r, c) = layout.decode(pi);
            if cell.recurrent_blocks().contains(&b) {
                assert!(mask.is_kept(r, c), "tracked dropped param ({b},{r},{c})");
            }
        }
        // roundtrip
        for j in 0..m.len() {
            assert_eq!(m.compact_of(m.param_of(j)), Some(j));
        }
    }

    #[test]
    fn scatter_add_places_values() {
        let mut rng = Pcg64::new(3);
        let mask = MaskPattern::random(4, 4, 0.5, &mut rng);
        let cell = RnnCell::evrnn(4, 2, 0.0, 0.3, 0.5, Some(mask), &mut rng);
        let m = ColumnMap::from_cell(&cell);
        let compact: Vec<f32> = (0..m.len()).map(|j| j as f32 + 1.0).collect();
        let mut dense = vec![0.0; cell.p()];
        m.scatter_add(&compact, 2.0, &mut dense);
        for j in 0..m.len() {
            assert_eq!(dense[m.param_of(j)], 2.0 * (j as f32 + 1.0));
        }
        let nonzero = dense.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nonzero, m.len());
    }
}
