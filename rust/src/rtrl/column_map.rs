//! Column compaction for parameter sparsity, per layer and stacked.
//!
//! With a fixed mask, entire columns of `M`/`M̄` are structurally zero for
//! the dropped recurrent parameters and stay zero across timesteps (§5).
//! A [`ColumnMap`] stores only the `ω̃p`-ish live columns of one layer: the
//! mapping between flat parameter indices (`R^p`) and compact column
//! indices. A [`StackColumnMap`] concatenates per-layer maps for a
//! [`LayerStack`]: layer `l`'s influence panel tracks the compact columns of
//! layers `0..=l` (the block lower-triangular column structure), so the
//! compact column space of layer `l` is a *prefix* of layer `l+1`'s — which
//! is what lets the cross-layer gather accumulate a lower panel row into the
//! leading slice of an upper panel row with no index translation.

use crate::nn::{LayerStack, RnnCell};

/// Sentinel for "parameter not tracked" in the reverse map.
const UNTRACKED: u32 = u32::MAX;

/// Bijection between tracked flat parameter indices and compact columns.
#[derive(Debug, Clone)]
pub struct ColumnMap {
    /// Compact column → flat parameter index (sorted ascending).
    cols: Vec<u32>,
    /// Flat parameter index → compact column (or `UNTRACKED`).
    rank: Vec<u32>,
}

impl ColumnMap {
    /// Identity map over all `p` parameters (the dense-columns case).
    pub fn full(p: usize) -> Self {
        ColumnMap {
            cols: (0..p as u32).collect(),
            rank: (0..p as u32).collect(),
        }
    }

    /// Map tracking every parameter except masked-out recurrent entries.
    /// Equals [`ColumnMap::full`] when the cell is dense.
    pub fn from_cell(cell: &RnnCell) -> Self {
        let p = cell.p();
        let Some(mask) = cell.mask() else {
            return Self::full(p);
        };
        let n = cell.n();
        let mut dropped = vec![false; p];
        let layout = cell.layout();
        for b in cell.recurrent_blocks() {
            for r in 0..n {
                let range = layout.row_range(b, r);
                for (c, pi) in range.enumerate() {
                    if !mask.is_kept(r, c) {
                        dropped[pi] = true;
                    }
                }
            }
        }
        let mut cols = Vec::with_capacity(p);
        let mut rank = vec![UNTRACKED; p];
        for (pi, &d) in dropped.iter().enumerate() {
            if !d {
                rank[pi] = cols.len() as u32;
                cols.push(pi as u32);
            }
        }
        ColumnMap { cols, rank }
    }

    /// Number of tracked (compact) columns.
    #[inline]
    pub fn len(&self) -> usize {
        self.cols.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }

    /// Total flat parameter count `p`.
    #[inline]
    pub fn p(&self) -> usize {
        self.rank.len()
    }

    /// Flat parameter index of compact column `j`.
    #[inline]
    pub fn param_of(&self, j: usize) -> usize {
        self.cols[j] as usize
    }

    /// Compact column of flat parameter `pi`, if tracked.
    #[inline]
    pub fn compact_of(&self, pi: usize) -> Option<usize> {
        let r = self.rank[pi];
        if r == UNTRACKED {
            None
        } else {
            Some(r as usize)
        }
    }

    /// Compact column of flat parameter `pi`, assuming it is tracked.
    /// Panics (debug) if not — used where structure guarantees tracking.
    #[inline]
    pub fn compact_of_unchecked(&self, pi: usize) -> usize {
        debug_assert_ne!(self.rank[pi], UNTRACKED, "param {pi} untracked");
        self.rank[pi] as usize
    }

    /// Fraction of parameters tracked (≥ ω̃ since input/bias cols are dense).
    pub fn tracked_fraction(&self) -> f32 {
        if self.rank.is_empty() {
            1.0
        } else {
            self.cols.len() as f32 / self.rank.len() as f32
        }
    }

    /// Scatter a compact row into a dense `R^p` buffer: `dense[param_of(j)] += compact[j] · scale`.
    pub fn scatter_add(&self, compact: &[f32], scale: f32, dense: &mut [f32]) {
        debug_assert_eq!(compact.len(), self.cols.len());
        debug_assert_eq!(dense.len(), self.rank.len());
        for (j, &v) in compact.iter().enumerate() {
            if v != 0.0 {
                dense[self.cols[j] as usize] += v * scale;
            }
        }
    }
}

/// Per-layer [`ColumnMap`]s plus cumulative offsets over a [`LayerStack`].
///
/// Global compact column of layer `m`'s local parameter `pi` is
/// `compact_offset(m) + maps[m].compact_of(pi)`; layer `l`'s influence
/// panel is `cum_cols(l)` wide (columns of layers `0..=l` only — the
/// structurally-zero columns for deeper layers are never allocated).
#[derive(Debug, Clone)]
pub struct StackColumnMap {
    maps: Vec<ColumnMap>,
    /// `compact_offsets[l]` = Σ_{m<l} maps[m].len(); last entry = total.
    compact_offsets: Vec<usize>,
    /// Global flat parameter count `P`.
    p_total: usize,
}

impl StackColumnMap {
    /// Build from a stack. `compact` selects whether masked recurrent
    /// parameters are compacted out (`Parameter`/`Both` modes) or every
    /// parameter keeps a column.
    pub fn from_stack(net: &LayerStack, compact: bool) -> Self {
        let maps: Vec<ColumnMap> = net
            .cells()
            .iter()
            .map(|c| if compact { ColumnMap::from_cell(c) } else { ColumnMap::full(c.p()) })
            .collect();
        let mut compact_offsets = Vec::with_capacity(maps.len() + 1);
        let mut acc = 0;
        for m in &maps {
            compact_offsets.push(acc);
            acc += m.len();
        }
        compact_offsets.push(acc);
        StackColumnMap { maps, compact_offsets, p_total: net.p() }
    }

    /// Number of layers.
    #[inline]
    pub fn layers(&self) -> usize {
        self.maps.len()
    }

    /// Per-layer map.
    #[inline]
    pub fn layer(&self, l: usize) -> &ColumnMap {
        &self.maps[l]
    }

    /// Global compact-column offset of layer `l`'s own columns.
    #[inline]
    pub fn compact_offset(&self, l: usize) -> usize {
        self.compact_offsets[l]
    }

    /// Width of layer `l`'s influence panel: compact columns of layers
    /// `0..=l`.
    #[inline]
    pub fn cum_cols(&self, l: usize) -> usize {
        self.compact_offsets[l + 1]
    }

    /// Total compact columns across all layers (= top panel width).
    #[inline]
    pub fn total_cols(&self) -> usize {
        *self.compact_offsets.last().unwrap()
    }

    /// Total flat parameter count `P`.
    #[inline]
    pub fn p(&self) -> usize {
        self.p_total
    }

    /// Global compact column of layer `l`'s *local* flat parameter `pi`
    /// (must be tracked — structure guarantees it on the immediate path).
    #[inline]
    pub fn global_compact_of(&self, l: usize, pi: usize) -> usize {
        self.compact_offsets[l] + self.maps[l].compact_of_unchecked(pi)
    }

    /// Scatter a full-width compact vector into a dense `R^P` buffer
    /// (global flat layout of [`crate::nn::NetworkLayout`]).
    pub fn scatter_add(&self, net: &LayerStack, compact: &[f32], scale: f32, dense: &mut [f32]) {
        debug_assert_eq!(compact.len(), self.total_cols());
        debug_assert_eq!(dense.len(), self.p_total);
        for (l, map) in self.maps.iter().enumerate() {
            let cslice = &compact[self.compact_offsets[l]..self.compact_offsets[l + 1]];
            let dslice = &mut dense[net.layout().param_range(l)];
            map.scatter_add(cslice, scale, dslice);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::MaskPattern;
    use crate::util::Pcg64;

    #[test]
    fn full_is_identity() {
        let m = ColumnMap::full(10);
        assert_eq!(m.len(), 10);
        for i in 0..10 {
            assert_eq!(m.param_of(i), i);
            assert_eq!(m.compact_of(i), Some(i));
        }
    }

    #[test]
    fn from_dense_cell_tracks_everything() {
        let mut rng = Pcg64::new(1);
        let cell = RnnCell::egru(8, 2, 0.1, 0.3, 0.5, None, &mut rng);
        let m = ColumnMap::from_cell(&cell);
        assert_eq!(m.len(), cell.p());
    }

    #[test]
    fn from_masked_cell_drops_masked_recurrent_params() {
        let mut rng = Pcg64::new(2);
        let n = 8;
        let mask = MaskPattern::random(n, n, 0.25, &mut rng);
        let cell = RnnCell::egru(n, 2, 0.1, 0.3, 0.5, Some(mask.clone()), &mut rng);
        let m = ColumnMap::from_cell(&cell);
        // p − 2 recurrent blocks × dropped entries
        let dropped_per_block = n * n - mask.kept();
        assert_eq!(m.len(), cell.p() - 2 * dropped_per_block);
        // every tracked recurrent param must be kept in the mask
        let layout = cell.layout();
        for j in 0..m.len() {
            let pi = m.param_of(j);
            let (b, r, c) = layout.decode(pi);
            if cell.recurrent_blocks().contains(&b) {
                assert!(mask.is_kept(r, c), "tracked dropped param ({b},{r},{c})");
            }
        }
        // roundtrip
        for j in 0..m.len() {
            assert_eq!(m.compact_of(m.param_of(j)), Some(j));
        }
    }

    #[test]
    fn stack_map_prefix_structure() {
        let mut rng = Pcg64::new(4);
        let n = 6;
        let mask0 = MaskPattern::random(n, n, 0.5, &mut rng);
        let mask1 = MaskPattern::random(n, n, 0.5, &mut rng);
        let l0 = RnnCell::egru(n, 2, 0.1, 0.3, 0.5, Some(mask0), &mut rng);
        let l1 = RnnCell::egru(n, n, 0.1, 0.3, 0.5, Some(mask1), &mut rng);
        let net = LayerStack::new(vec![l0, l1]);
        let sm = StackColumnMap::from_stack(&net, true);
        assert_eq!(sm.layers(), 2);
        // layer 0's panel width is a strict prefix of layer 1's
        assert_eq!(sm.cum_cols(0), sm.layer(0).len());
        assert_eq!(sm.cum_cols(1), sm.layer(0).len() + sm.layer(1).len());
        assert_eq!(sm.total_cols(), sm.cum_cols(1));
        assert!(sm.total_cols() < net.p(), "compaction dropped masked columns");
        // global compact index of layer 1's first tracked param lands after
        // all of layer 0's columns
        let pi = sm.layer(1).param_of(0);
        assert_eq!(sm.global_compact_of(1, pi), sm.compact_offset(1));
        // dense (non-compacting) map covers everything
        let full = StackColumnMap::from_stack(&net, false);
        assert_eq!(full.total_cols(), net.p());
    }

    #[test]
    fn stack_scatter_add_respects_layer_offsets() {
        let mut rng = Pcg64::new(5);
        let n = 4;
        let l0 = RnnCell::evrnn(n, 2, 0.0, 0.3, 0.5, None, &mut rng);
        let l1 = RnnCell::evrnn(n, n, 0.0, 0.3, 0.5, None, &mut rng);
        let net = LayerStack::new(vec![l0, l1]);
        let sm = StackColumnMap::from_stack(&net, true);
        let compact: Vec<f32> = (0..sm.total_cols()).map(|j| j as f32 + 1.0).collect();
        let mut dense = vec![0.0; net.p()];
        sm.scatter_add(&net, &compact, 1.0, &mut dense);
        // dense cells: identity maps, so layer 1's first value lands at the
        // global param offset of layer 1
        let off1 = net.layout().param_offset(1);
        assert_eq!(dense[off1], compact[sm.compact_offset(1)]);
        assert_eq!(dense[0], compact[0]);
        assert_eq!(dense.iter().filter(|&&v| v != 0.0).count(), sm.total_cols());
    }

    #[test]
    fn scatter_add_places_values() {
        let mut rng = Pcg64::new(3);
        let mask = MaskPattern::random(4, 4, 0.5, &mut rng);
        let cell = RnnCell::evrnn(4, 2, 0.0, 0.3, 0.5, Some(mask), &mut rng);
        let m = ColumnMap::from_cell(&cell);
        let compact: Vec<f32> = (0..m.len()).map(|j| j as f32 + 1.0).collect();
        let mut dense = vec![0.0; cell.p()];
        m.scatter_add(&compact, 2.0, &mut dense);
        for j in 0..m.len() {
            assert_eq!(dense[m.param_of(j)], 2.0 * (j as f32 + 1.0));
        }
        let nonzero = dense.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nonzero, m.len());
    }
}
