//! SnAp-n — the *approximate* RTRL baselines of Menick et al. (2020),
//! included as Table 1's comparison rows.
//!
//! SnAp-n keeps only influence-matrix entries `(k, p)` reachable from
//! parameter `p` within `n` steps of the unrolled graph:
//!
//! * **SnAp-1** — the pattern of `M̄` itself (parameter `p` only influences
//!   its own row's unit), collapsing the recursion to a diagonal update
//!   `M_kp ← J_kk·M_kp + M̄_kp`. Cheap (`O(ω̃p)` per step) but biased.
//! * **SnAp-2** — two-step reachability: `(k,p)` is kept when `J_kl` is
//!   structurally nonzero for some `l` with `p` in `l`'s fan-in (plus the
//!   SnAp-1 pattern). With a dense cell this is the full matrix (SnAp-2 ≡
//!   exact RTRL); under parameter sparsity it is an `ω̃²np`-sized pattern.
//!
//! Contrast with this repo's sparse engines: SnAp *discards* true nonzero
//! influence terms outside the pattern (approximate), while activity/
//! parameter sparsity skips only *structural zeros* (exact).

use super::{supervised_step, GradientEngine, StepResult, Target};
use crate::metrics::{OpCounter, Phase};
use crate::nn::{CellScratch, Loss, Readout, RnnCell};

/// Shared machinery: a per-unit sparse influence slab `M[k] over pattern[k]`.
struct PatternInfluence {
    /// Sorted flat param indices kept per unit.
    pattern: Vec<Vec<u32>>,
    /// Values aligned with `pattern` (current step).
    cur: Vec<Vec<f32>>,
    /// Values aligned with `pattern` (staging).
    next: Vec<Vec<f32>>,
}

impl PatternInfluence {
    fn new(pattern: Vec<Vec<u32>>) -> Self {
        let cur = pattern.iter().map(|p| vec![0.0; p.len()]).collect::<Vec<_>>();
        let next = cur.clone();
        PatternInfluence { pattern, cur, next }
    }

    fn reset(&mut self) {
        for row in self.cur.iter_mut().chain(self.next.iter_mut()) {
            row.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    fn advance(&mut self) {
        std::mem::swap(&mut self.cur, &mut self.next);
    }

    fn memory_words(&self) -> usize {
        2 * self.pattern.iter().map(|p| p.len()).sum::<usize>()
    }
}

/// SnAp-1: diagonal-Jacobian approximation on the `M̄` pattern.
pub struct Snap1 {
    inf: PatternInfluence,
    scratch: CellScratch,
    a_prev: Vec<f32>,
    grads: Vec<f32>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    c_bar: Vec<f32>,
}

impl Snap1 {
    pub fn new(cell: &RnnCell, readout_n_out: usize) -> Self {
        let n = cell.n();
        let pattern = (0..n).map(|k| cell.fan_in_params(k)).collect();
        Snap1 {
            inf: PatternInfluence::new(pattern),
            scratch: CellScratch::new(n),
            a_prev: vec![0.0; n],
            grads: vec![0.0; cell.p()],
            logits: vec![0.0; readout_n_out],
            dlogits: vec![0.0; readout_n_out],
            c_bar: vec![0.0; n],
        }
    }

    /// Entries kept (the `ω̃p`-ish SnAp-1 memory of Table 1).
    pub fn pattern_size(&self) -> usize {
        self.inf.pattern.iter().map(|p| p.len()).sum()
    }
}

impl GradientEngine for Snap1 {
    fn name(&self) -> &'static str {
        "snap1"
    }

    fn begin_sequence(&mut self) {
        self.inf.reset();
        self.a_prev.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn step(
        &mut self,
        cell: &RnnCell,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult {
        let n = cell.n();
        cell.forward(&self.a_prev, x, &mut self.scratch, ops);
        let active_units = self.scratch.active_units();
        let deriv_units = self.scratch.deriv_units();

        let mut macs = 0u64;
        for k in 0..n {
            let dphi_k = self.scratch.dphi[k];
            // Diagonal Jacobian element J_kk = φ'_k · ∂v_k/∂a_k.
            let jkk = dphi_k * cell.dv_da(&self.scratch, k, k);
            let (cur, next) = (&self.inf.cur[k], &mut self.inf.next[k]);
            for (nx, &cu) in next.iter_mut().zip(cur) {
                *nx = jkk * cu;
            }
            macs += cur.len() as u64;
            // + φ'_k · M̄ entries (scatter into the pattern row)
            let inf_pattern = &self.inf.pattern[k];
            cell.immediate_row(
                &self.scratch,
                &self.a_prev,
                x,
                k,
                |pi, val| {
                    if let Ok(pos) = inf_pattern.binary_search(&(pi as u32)) {
                        next[pos] += dphi_k * val;
                    }
                },
                ops,
            );
        }
        ops.macs(Phase::InfluenceUpdate, macs);

        let (loss_val, correct) = supervised_step(
            readout,
            loss,
            &self.scratch.a,
            target,
            &mut self.logits,
            &mut self.dlogits,
            &mut self.c_bar,
            ops,
        );
        if loss_val.is_some() {
            let mut gmacs = 0u64;
            for k in 0..n {
                let coef = self.c_bar[k];
                if coef == 0.0 {
                    continue;
                }
                for (j, &pi) in self.inf.pattern[k].iter().enumerate() {
                    self.grads[pi as usize] += coef * self.inf.next[k][j];
                }
                gmacs += self.inf.pattern[k].len() as u64;
            }
            ops.macs(Phase::GradCombine, gmacs);
        }

        self.inf.advance();
        self.a_prev.copy_from_slice(&self.scratch.a);
        StepResult { loss: loss_val, correct, active_units, deriv_units, influence_sparsity: None }
    }

    fn end_sequence(&mut self, _cell: &RnnCell, _readout: &mut Readout, _ops: &mut OpCounter) {}

    fn grads(&self) -> &[f32] {
        &self.grads
    }

    fn reset_grads(&mut self) {
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn state_memory_words(&self) -> usize {
        self.inf.memory_words()
    }
}

/// SnAp-2: two-hop influence pattern.
pub struct Snap2 {
    inf: PatternInfluence,
    scratch: CellScratch,
    a_prev: Vec<f32>,
    grads: Vec<f32>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    c_bar: Vec<f32>,
}

impl Snap2 {
    pub fn new(cell: &RnnCell, readout_n_out: usize) -> Self {
        let n = cell.n();
        let fan_in: Vec<Vec<u32>> = (0..n).map(|k| cell.fan_in_params(k)).collect();
        // pattern(k) = fan_in(k) ∪ ⋃_{l ∈ struct J row k} fan_in(l)
        let pattern: Vec<Vec<u32>> = (0..n)
            .map(|k| {
                let mut set: Vec<u32> = fan_in[k].clone();
                for &l in cell.kept_cols(k) {
                    set.extend_from_slice(&fan_in[l as usize]);
                }
                set.sort_unstable();
                set.dedup();
                set
            })
            .collect();
        Snap2 {
            inf: PatternInfluence::new(pattern),
            scratch: CellScratch::new(n),
            a_prev: vec![0.0; n],
            grads: vec![0.0; cell.p()],
            logits: vec![0.0; readout_n_out],
            dlogits: vec![0.0; readout_n_out],
            c_bar: vec![0.0; n],
        }
    }

    /// Entries kept (the `ω̃²np`-ish SnAp-2 memory of Table 1).
    pub fn pattern_size(&self) -> usize {
        self.inf.pattern.iter().map(|p| p.len()).sum()
    }
}

impl GradientEngine for Snap2 {
    fn name(&self) -> &'static str {
        "snap2"
    }

    fn begin_sequence(&mut self) {
        self.inf.reset();
        self.a_prev.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn step(
        &mut self,
        cell: &RnnCell,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult {
        let n = cell.n();
        cell.forward(&self.a_prev, x, &mut self.scratch, ops);
        let active_units = self.scratch.active_units();
        let deriv_units = self.scratch.deriv_units();

        let mut macs = 0u64;
        for k in 0..n {
            let dphi_k = self.scratch.dphi[k];
            // Pattern-restricted J·M: for each kept (k,p), sum over l with
            // J_kl structurally nonzero and (l,p) in pattern.
            // First: stage = Σ_l Ĵ_kl · M_old[l, p∈pattern(k)]
            {
                let next = &mut self.inf.next[k];
                next.iter_mut().for_each(|x| *x = 0.0);
            }
            for &l in cell.kept_cols(k) {
                let jv = cell.dv_da(&self.scratch, k, l as usize);
                macs += cell.dv_da_cost();
                if jv == 0.0 {
                    continue;
                }
                // two-pointer merge of pattern(k) and pattern(l)
                let pk = &self.inf.pattern[k];
                let pl = &self.inf.pattern[l as usize];
                let ml = &self.inf.cur[l as usize];
                let next = &mut self.inf.next[k];
                let (mut i, mut j) = (0usize, 0usize);
                while i < pk.len() && j < pl.len() {
                    match pk[i].cmp(&pl[j]) {
                        std::cmp::Ordering::Less => i += 1,
                        std::cmp::Ordering::Greater => j += 1,
                        std::cmp::Ordering::Equal => {
                            next[i] += jv * ml[j];
                            macs += 1;
                            i += 1;
                            j += 1;
                        }
                    }
                }
            }
            // + M̄, then scale by φ'_k
            {
                let inf_pattern = &self.inf.pattern[k];
                let next = &mut self.inf.next[k];
                cell.immediate_row(
                    &self.scratch,
                    &self.a_prev,
                    x,
                    k,
                    |pi, val| {
                        if let Ok(pos) = inf_pattern.binary_search(&(pi as u32)) {
                            next[pos] += val;
                        }
                    },
                    ops,
                );
                for v in next.iter_mut() {
                    *v *= dphi_k;
                }
                macs += next.len() as u64;
            }
        }
        ops.macs(Phase::InfluenceUpdate, macs);

        let (loss_val, correct) = supervised_step(
            readout,
            loss,
            &self.scratch.a,
            target,
            &mut self.logits,
            &mut self.dlogits,
            &mut self.c_bar,
            ops,
        );
        if loss_val.is_some() {
            let mut gmacs = 0u64;
            for k in 0..n {
                let coef = self.c_bar[k];
                if coef == 0.0 {
                    continue;
                }
                for (j, &pi) in self.inf.pattern[k].iter().enumerate() {
                    self.grads[pi as usize] += coef * self.inf.next[k][j];
                }
                gmacs += self.inf.pattern[k].len() as u64;
            }
            ops.macs(Phase::GradCombine, gmacs);
        }

        self.inf.advance();
        self.a_prev.copy_from_slice(&self.scratch.a);
        StepResult { loss: loss_val, correct, active_units, deriv_units, influence_sparsity: None }
    }

    fn end_sequence(&mut self, _cell: &RnnCell, _readout: &mut Readout, _ops: &mut OpCounter) {}

    fn grads(&self) -> &[f32] {
        &self.grads
    }

    fn reset_grads(&mut self) {
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn state_memory_words(&self) -> usize {
        self.inf.memory_words()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LossKind;
    use crate::sparse::MaskPattern;
    use crate::util::Pcg64;

    #[test]
    fn snap1_pattern_is_fan_in() {
        let mut rng = Pcg64::new(40);
        let cell = RnnCell::egru(8, 2, 0.1, 0.3, 0.5, None, &mut rng);
        let s1 = Snap1::new(&cell, 2);
        // dense: every unit keeps 2(n_in + n + 1) params
        assert_eq!(s1.pattern_size(), 8 * 2 * (2 + 8 + 1));
    }

    #[test]
    fn snap2_dense_pattern_is_full() {
        let mut rng = Pcg64::new(41);
        let cell = RnnCell::evrnn(6, 2, 0.0, 0.3, 0.5, None, &mut rng);
        let s2 = Snap2::new(&cell, 2);
        // dense J reaches every unit, so every row keeps all p params
        assert_eq!(s2.pattern_size(), 6 * cell.p());
    }

    #[test]
    fn snap2_pattern_shrinks_with_mask() {
        let mut rng = Pcg64::new(42);
        let mask = MaskPattern::random(10, 10, 0.2, &mut rng);
        let cell = RnnCell::evrnn(10, 2, 0.0, 0.3, 0.5, Some(mask), &mut rng);
        let s2 = Snap2::new(&cell, 2);
        assert!(s2.pattern_size() < 10 * cell.p());
        let s1 = Snap1::new(&cell, 2);
        assert!(s1.pattern_size() < s2.pattern_size());
    }

    #[test]
    fn both_run_sequences() {
        let mut rng = Pcg64::new(43);
        let cell = RnnCell::egru(6, 2, 0.1, 0.3, 0.5, None, &mut rng);
        let mut readout = Readout::new(2, 6, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops = OpCounter::new();
        for alg in [&mut Snap1::new(&cell, 2) as &mut dyn GradientEngine, &mut Snap2::new(&cell, 2)] {
            alg.begin_sequence();
            for t in 0..5 {
                let x = [(t as f32).sin(), 0.3];
                let target = if t == 4 { Target::Class(1) } else { Target::None };
                alg.step(&cell, &mut readout, &mut loss, &x, target, &mut ops);
            }
            alg.end_sequence(&cell, &mut readout, &mut ops);
            assert_eq!(alg.grads().len(), cell.p());
        }
    }

    #[test]
    fn snap1_cheaper_than_snap2() {
        let mut rng = Pcg64::new(44);
        let cell = RnnCell::egru(8, 2, 0.0, 0.3, 0.9, None, &mut rng);
        let mut readout = Readout::new(2, 8, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops1 = OpCounter::new();
        let mut s1 = Snap1::new(&cell, 2);
        s1.begin_sequence();
        s1.step(&cell, &mut readout, &mut loss, &[0.5, 0.5], Target::None, &mut ops1);
        let mut ops2 = OpCounter::new();
        let mut s2 = Snap2::new(&cell, 2);
        s2.begin_sequence();
        s2.step(&cell, &mut readout, &mut loss, &[0.5, 0.5], Target::None, &mut ops2);
        assert!(
            ops1.macs_in(Phase::InfluenceUpdate) < ops2.macs_in(Phase::InfluenceUpdate),
            "snap1 {} !< snap2 {}",
            ops1.macs_in(Phase::InfluenceUpdate),
            ops2.macs_in(Phase::InfluenceUpdate)
        );
    }
}
