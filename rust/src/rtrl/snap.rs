//! SnAp-n — the *approximate* RTRL baselines of Menick et al. (2020),
//! included as Table 1's comparison rows, on the stacked network.
//!
//! SnAp-n keeps only influence-matrix entries `(k, p)` reachable from
//! parameter `p` within `n` steps of the unrolled graph:
//!
//! * **SnAp-1** — the pattern of the layer-local `M̄` (parameter `p` only
//!   influences its own row's unit), collapsing the recursion to a diagonal
//!   update `M_kp ← J_kk·M_kp + M̄_kp`. Cheap (`O(ω̃p)` per step) but biased.
//! * **SnAp-2** — two-step reachability within the layer: `(k,p)` is kept
//!   when `J_kl` is structurally nonzero for some `l` with `p` in `l`'s
//!   fan-in (plus the SnAp-1 pattern). With a dense single layer this is
//!   the full matrix (SnAp-2 ≡ exact RTRL); under parameter sparsity it is
//!   an `ω̃²np`-sized pattern.
//!
//! # Depth: per-layer panels + within-step credit backprop
//!
//! On a [`LayerStack`] the SnAp engines keep each layer's influence slab
//! *layer-local* (rows over the layer's own parameters only) and route
//! credit to lower layers by backpropagating `c̄` down the stack within the
//! step (`c̄_{l-1} += C_lᵀ(φ'_l ⊙ c̄_l)`) — the standard "RTRL through time,
//! backprop through depth" decomposition for stacked RNNs. This keeps every
//! layer trainable while dropping the cross-layer *temporal* influence
//! paths (a past parameter's effect on an upper layer's recurrent state),
//! which is exactly the kind of truncation SnAp already makes within a
//! layer. Contrast with this repo's sparse engines: SnAp *discards* true
//! nonzero influence terms outside the pattern (approximate), while
//! activity/parameter sparsity skips only *structural zeros* (exact; see
//! `rtrl::sparse` for the exact block treatment of depth). At depth 1 the
//! decomposition degenerates to the original single-cell SnAp exactly.
//! Both engines' slab updates run on the shared lane-chunked row kernels
//! of [`super::kernels`], so they inherit the SoA-layout speedups too.

use super::kernels::{CrossSelect, JacobianSlab, OwnSelect, RowSelect};
use super::{supervised_step, EngineState, GradientEngine, StateError, StepResult, Target};
use crate::metrics::{OpCounter, Phase};
use crate::nn::{LayerStack, Loss, Readout, StackScratch};

/// Snapshot-format version shared by [`Snap1`] and [`Snap2`].
const STATE_VERSION: u32 = 1;

/// Shared machinery: a per-unit sparse influence slab `M[k] over pattern[k]`,
/// with global (concatenated) rows and *global* flat parameter indices in
/// the patterns.
struct PatternInfluence {
    /// Sorted global flat param indices kept per global unit.
    pattern: Vec<Vec<u32>>,
    /// Values aligned with `pattern` (current step).
    cur: Vec<Vec<f32>>,
    /// Values aligned with `pattern` (staging).
    next: Vec<Vec<f32>>,
}

impl PatternInfluence {
    fn new(pattern: Vec<Vec<u32>>) -> Self {
        let cur = pattern.iter().map(|p| vec![0.0; p.len()]).collect::<Vec<_>>();
        let next = cur.clone();
        PatternInfluence { pattern, cur, next }
    }

    fn reset(&mut self) {
        for row in self.cur.iter_mut().chain(self.next.iter_mut()) {
            row.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    fn advance(&mut self) {
        std::mem::swap(&mut self.cur, &mut self.next);
    }

    fn memory_words(&self) -> usize {
        2 * self.pattern.iter().map(|p| p.len()).sum::<usize>()
    }

    /// Current-slab values, concatenated row-major over the pattern (the
    /// pattern itself is rebuilt deterministically from the stack).
    fn snapshot_cur(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.pattern.iter().map(|p| p.len()).sum());
        for row in &self.cur {
            out.extend_from_slice(row);
        }
        out
    }

    /// Restore [`PatternInfluence::snapshot_cur`] values; the staging slab is
    /// zeroed (it is fully rewritten each step before being read).
    fn restore_cur(&mut self, vals: &[f32]) -> Result<(), String> {
        let total: usize = self.pattern.iter().map(|p| p.len()).sum();
        if vals.len() != total {
            return Err(format!(
                "pattern snapshot holds {} values, engine pattern has {total}",
                vals.len()
            ));
        }
        let mut off = 0;
        for (cur, next) in self.cur.iter_mut().zip(self.next.iter_mut()) {
            cur.copy_from_slice(&vals[off..off + cur.len()]);
            next.iter_mut().for_each(|x| *x = 0.0);
            off += cur.len();
        }
        Ok(())
    }
}

/// Shared save/load bodies for the two SnAp engines (identical state shape).
fn snap_save(name: &'static str, inf: &PatternInfluence, a_prev: &[f32], grads: &[f32]) -> EngineState {
    let mut st = EngineState::new(name, STATE_VERSION);
    st.put_floats("inf_cur", inf.snapshot_cur());
    st.put_floats("a_prev", a_prev.to_vec());
    st.put_floats("grads", grads.to_vec());
    st
}

fn snap_load(
    name: &'static str,
    state: &EngineState,
    inf: &mut PatternInfluence,
    a_prev: &mut [f32],
    grads: &mut [f32],
) -> Result<(), StateError> {
    state.require(name, STATE_VERSION)?;
    let a = state.floats_exact("a_prev", a_prev.len())?;
    let g = state.floats_exact("grads", grads.len())?;
    inf.restore_cur(state.floats("inf_cur")?).map_err(StateError)?;
    a_prev.copy_from_slice(a);
    grads.copy_from_slice(g);
    Ok(())
}

/// Shared across Snap-1/2: after the supervised step, extend the top-layer
/// credit vector to every layer by backprop through the within-step stack
/// cascade, then fold `c̄_full ⊙ rows` into `grads`.
fn stacked_credit(
    net: &LayerStack,
    scratch: &StackScratch,
    c_bar_top: &[f32],
    c_bar_full: &mut [f32],
    ops: &mut OpCounter,
) {
    let layers = net.layers();
    let top_off = net.layout().state_offset(layers - 1);
    c_bar_full.iter_mut().for_each(|v| *v = 0.0);
    c_bar_full[top_off..].copy_from_slice(c_bar_top);
    let mut macs = 0u64;
    for l in (1..layers).rev() {
        let cell = net.layer(l);
        let sl = &scratch.layers[l];
        let soff = net.layout().state_offset(l);
        let soff_prev = net.layout().state_offset(l - 1);
        let nprev = net.layer(l - 1).n();
        for k in 0..cell.n() {
            let coef = sl.dphi[k] * c_bar_full[soff + k];
            if coef == 0.0 {
                continue;
            }
            for j in 0..nprev {
                c_bar_full[soff_prev + j] += coef * cell.dv_dx(sl, k, j);
            }
            macs += nprev as u64 * (1 + cell.dv_dx_cost());
        }
    }
    ops.macs(Phase::GradCombine, macs);
}

/// Build per-unit fan-in patterns with global flat indices, layer by layer.
fn layer_local_fan_in(net: &LayerStack) -> Vec<Vec<u32>> {
    let mut pattern = Vec::with_capacity(net.total_units());
    for l in 0..net.layers() {
        let poff = net.layout().param_offset(l) as u32;
        for k in 0..net.layer(l).n() {
            let mut row = net.layer(l).fan_in_params(k);
            for pi in row.iter_mut() {
                *pi += poff;
            }
            pattern.push(row);
        }
    }
    pattern
}

/// SnAp-1: diagonal-Jacobian approximation on the layer-local `M̄` pattern.
pub struct Snap1 {
    inf: PatternInfluence,
    scratch: StackScratch,
    a_prev: Vec<f32>,
    /// Per-step diagonal Jacobian slab (scratch; SnAp-1's structural need
    /// is exactly the `(k, k)` entries).
    slab: JacobianSlab,
    grads: Vec<f32>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    c_bar: Vec<f32>,
    c_bar_full: Vec<f32>,
}

impl Snap1 {
    pub fn new(net: &LayerStack, readout_n_out: usize) -> Self {
        Snap1 {
            inf: PatternInfluence::new(layer_local_fan_in(net)),
            scratch: net.scratch(),
            a_prev: vec![0.0; net.total_units()],
            slab: JacobianSlab::new(),
            grads: vec![0.0; net.p()],
            logits: vec![0.0; readout_n_out],
            dlogits: vec![0.0; readout_n_out],
            c_bar: vec![0.0; net.top_n()],
            c_bar_full: vec![0.0; net.total_units()],
        }
    }

    /// Entries kept (the `ω̃p`-ish SnAp-1 memory of Table 1).
    pub fn pattern_size(&self) -> usize {
        self.inf.pattern.iter().map(|p| p.len()).sum()
    }
}

impl GradientEngine for Snap1 {
    fn name(&self) -> &'static str {
        "snap1"
    }

    fn begin_sequence(&mut self) {
        self.inf.reset();
        self.a_prev.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn step(
        &mut self,
        net: &LayerStack,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult {
        net.forward(&self.a_prev, x, &mut self.scratch, ops);
        let active_units = self.scratch.active_units();
        let deriv_units = self.scratch.deriv_units();

        for l in 0..net.layers() {
            ops.set_layer(l);
            let cell = net.layer(l);
            let sl = &self.scratch.layers[l];
            let soff = net.layout().state_offset(l);
            let poff = net.layout().param_offset(l);
            let a_prev_l = &self.a_prev[soff..soff + cell.n()];
            let input_l: &[f32] = if l == 0 { x } else { &self.scratch.layers[l - 1].a };
            // Diagonal step-Jacobian slab — SnAp-1's whole structural need.
            // Diagonal evaluations stay uncharged, matching the engine's
            // historical cost model (the O(p) update is the charged term).
            self.slab.build(cell, sl, RowSelect::All, OwnSelect::Diag, CrossSelect::Skip);
            let mut macs = 0u64;
            for kl in 0..cell.n() {
                let k = soff + kl;
                let dphi_k = sl.dphi[kl];
                // Diagonal Jacobian element J_kk = φ'_k · ∂v_k/∂a_k.
                let jkk = dphi_k * self.slab.diag(kl);
                let (cur, next) = (&self.inf.cur[k], &mut self.inf.next[k]);
                for (nx, &cu) in next.iter_mut().zip(cur) {
                    *nx = jkk * cu;
                }
                macs += cur.len() as u64;
                // + φ'_k · M̄ entries (scatter into the pattern row)
                let inf_pattern = &self.inf.pattern[k];
                cell.immediate_row(
                    sl,
                    a_prev_l,
                    input_l,
                    kl,
                    |pi, val| {
                        if let Ok(pos) = inf_pattern.binary_search(&((poff + pi) as u32)) {
                            next[pos] += dphi_k * val;
                        }
                    },
                    ops,
                );
            }
            ops.macs(Phase::InfluenceUpdate, macs);
        }
        ops.clear_layer();

        let (loss_val, correct, prediction) = supervised_step(
            readout,
            loss,
            &self.scratch.top().a,
            target,
            &mut self.logits,
            &mut self.dlogits,
            &mut self.c_bar,
            ops,
        );
        if loss_val.is_some() {
            stacked_credit(net, &self.scratch, &self.c_bar, &mut self.c_bar_full, ops);
            let mut gmacs = 0u64;
            for k in 0..net.total_units() {
                let coef = self.c_bar_full[k];
                if coef == 0.0 {
                    continue;
                }
                for (j, &pi) in self.inf.pattern[k].iter().enumerate() {
                    self.grads[pi as usize] += coef * self.inf.next[k][j];
                }
                gmacs += self.inf.pattern[k].len() as u64;
            }
            ops.macs(Phase::GradCombine, gmacs);
        }

        self.inf.advance();
        self.scratch.write_state(&mut self.a_prev);
        StepResult { loss: loss_val, correct, prediction, active_units, deriv_units, influence_sparsity: None }
    }

    fn end_sequence(&mut self, _net: &LayerStack, _readout: &mut Readout, _ops: &mut OpCounter) {}

    fn grads(&self) -> &[f32] {
        &self.grads
    }

    fn reset_grads(&mut self) {
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn state_memory_words(&self) -> usize {
        self.inf.memory_words()
    }

    fn activations(&self) -> &[f32] {
        &self.a_prev
    }

    fn save_state(&self) -> EngineState {
        snap_save(self.name(), &self.inf, &self.a_prev, &self.grads)
    }

    fn load_state(&mut self, _net: &LayerStack, state: &EngineState) -> Result<(), StateError> {
        snap_load(self.name(), state, &mut self.inf, &mut self.a_prev, &mut self.grads)
    }
}

/// SnAp-2: two-hop influence pattern within each layer.
pub struct Snap2 {
    inf: PatternInfluence,
    scratch: StackScratch,
    a_prev: Vec<f32>,
    /// Per-step kept-pattern Jacobian slab (scratch).
    slab: JacobianSlab,
    grads: Vec<f32>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    c_bar: Vec<f32>,
    c_bar_full: Vec<f32>,
}

impl Snap2 {
    pub fn new(net: &LayerStack, readout_n_out: usize) -> Self {
        // pattern(k) = fan_in(k) ∪ ⋃_{l ∈ struct J row k} fan_in(l), per layer
        let mut pattern: Vec<Vec<u32>> = Vec::with_capacity(net.total_units());
        for l in 0..net.layers() {
            let cell = net.layer(l);
            let poff = net.layout().param_offset(l) as u32;
            let fan_in: Vec<Vec<u32>> = (0..cell.n()).map(|k| cell.fan_in_params(k)).collect();
            for k in 0..cell.n() {
                let mut set: Vec<u32> = fan_in[k].clone();
                for &c in cell.kept_cols(k) {
                    set.extend_from_slice(&fan_in[c as usize]);
                }
                set.sort_unstable();
                set.dedup();
                for pi in set.iter_mut() {
                    *pi += poff;
                }
                pattern.push(set);
            }
        }
        Snap2 {
            inf: PatternInfluence::new(pattern),
            scratch: net.scratch(),
            a_prev: vec![0.0; net.total_units()],
            slab: JacobianSlab::new(),
            grads: vec![0.0; net.p()],
            logits: vec![0.0; readout_n_out],
            dlogits: vec![0.0; readout_n_out],
            c_bar: vec![0.0; net.top_n()],
            c_bar_full: vec![0.0; net.total_units()],
        }
    }

    /// Entries kept (the `ω̃²np`-ish SnAp-2 memory of Table 1).
    pub fn pattern_size(&self) -> usize {
        self.inf.pattern.iter().map(|p| p.len()).sum()
    }
}

impl GradientEngine for Snap2 {
    fn name(&self) -> &'static str {
        "snap2"
    }

    fn begin_sequence(&mut self) {
        self.inf.reset();
        self.a_prev.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn step(
        &mut self,
        net: &LayerStack,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult {
        net.forward(&self.a_prev, x, &mut self.scratch, ops);
        let active_units = self.scratch.active_units();
        let deriv_units = self.scratch.deriv_units();

        for l in 0..net.layers() {
            ops.set_layer(l);
            let cell = net.layer(l);
            let sl = &self.scratch.layers[l];
            let soff = net.layout().state_offset(l);
            let poff = net.layout().param_offset(l);
            let a_prev_l = &self.a_prev[soff..soff + cell.n()];
            let input_l: &[f32] = if l == 0 { x } else { &self.scratch.layers[l - 1].a };
            // Step-Jacobian slab over the kept pattern, built once for the
            // layer; evaluations are charged in bulk per row below, to the
            // engine's historical phase (InfluenceUpdate).
            self.slab.build(cell, sl, RowSelect::All, OwnSelect::Kept, CrossSelect::Skip);
            let mut macs = 0u64;
            for kl in 0..cell.n() {
                let k = soff + kl;
                let dphi_k = sl.dphi[kl];
                // Pattern-restricted J·M within the layer: for each kept
                // (k,p), sum over c with J_kc structurally nonzero and (c,p)
                // in pattern.
                {
                    let next = &mut self.inf.next[k];
                    next.iter_mut().for_each(|x| *x = 0.0);
                }
                let (jcols, jvals) = self.slab.own_row(kl);
                macs += jcols.len() as u64 * cell.dv_da_cost();
                for (&c, &jv) in jcols.iter().zip(jvals) {
                    if jv == 0.0 {
                        continue;
                    }
                    // two-pointer merge of pattern(k) and pattern(c)
                    let gc = soff + c as usize;
                    let pk = &self.inf.pattern[k];
                    let pl = &self.inf.pattern[gc];
                    let ml = &self.inf.cur[gc];
                    let next = &mut self.inf.next[k];
                    let mut matched = 0u64;
                    let (mut i, mut j) = (0usize, 0usize);
                    while i < pk.len() && j < pl.len() {
                        match pk[i].cmp(&pl[j]) {
                            std::cmp::Ordering::Less => i += 1,
                            std::cmp::Ordering::Greater => j += 1,
                            std::cmp::Ordering::Equal => {
                                next[i] += jv * ml[j];
                                matched += 1;
                                i += 1;
                                j += 1;
                            }
                        }
                    }
                    macs += matched;
                }
                // + M̄, then scale by φ'_k
                {
                    let inf_pattern = &self.inf.pattern[k];
                    let next = &mut self.inf.next[k];
                    cell.immediate_row(
                        sl,
                        a_prev_l,
                        input_l,
                        kl,
                        |pi, val| {
                            if let Ok(pos) = inf_pattern.binary_search(&((poff + pi) as u32)) {
                                next[pos] += val;
                            }
                        },
                        ops,
                    );
                    for v in next.iter_mut() {
                        *v *= dphi_k;
                    }
                    macs += next.len() as u64;
                }
            }
            ops.macs(Phase::InfluenceUpdate, macs);
        }
        ops.clear_layer();

        let (loss_val, correct, prediction) = supervised_step(
            readout,
            loss,
            &self.scratch.top().a,
            target,
            &mut self.logits,
            &mut self.dlogits,
            &mut self.c_bar,
            ops,
        );
        if loss_val.is_some() {
            stacked_credit(net, &self.scratch, &self.c_bar, &mut self.c_bar_full, ops);
            let mut gmacs = 0u64;
            for k in 0..net.total_units() {
                let coef = self.c_bar_full[k];
                if coef == 0.0 {
                    continue;
                }
                for (j, &pi) in self.inf.pattern[k].iter().enumerate() {
                    self.grads[pi as usize] += coef * self.inf.next[k][j];
                }
                gmacs += self.inf.pattern[k].len() as u64;
            }
            ops.macs(Phase::GradCombine, gmacs);
        }

        self.inf.advance();
        self.scratch.write_state(&mut self.a_prev);
        StepResult { loss: loss_val, correct, prediction, active_units, deriv_units, influence_sparsity: None }
    }

    fn end_sequence(&mut self, _net: &LayerStack, _readout: &mut Readout, _ops: &mut OpCounter) {}

    fn grads(&self) -> &[f32] {
        &self.grads
    }

    fn reset_grads(&mut self) {
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn state_memory_words(&self) -> usize {
        self.inf.memory_words()
    }

    fn activations(&self) -> &[f32] {
        &self.a_prev
    }

    fn save_state(&self) -> EngineState {
        snap_save(self.name(), &self.inf, &self.a_prev, &self.grads)
    }

    fn load_state(&mut self, _net: &LayerStack, state: &EngineState) -> Result<(), StateError> {
        snap_load(self.name(), state, &mut self.inf, &mut self.a_prev, &mut self.grads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{LossKind, RnnCell};
    use crate::sparse::MaskPattern;
    use crate::util::Pcg64;

    #[test]
    fn snap1_pattern_is_fan_in() {
        let mut rng = Pcg64::new(40);
        let net = LayerStack::single(RnnCell::egru(8, 2, 0.1, 0.3, 0.5, None, &mut rng));
        let s1 = Snap1::new(&net, 2);
        // dense: every unit keeps 2(n_in + n + 1) params
        assert_eq!(s1.pattern_size(), 8 * 2 * (2 + 8 + 1));
    }

    #[test]
    fn snap2_dense_pattern_is_full() {
        let mut rng = Pcg64::new(41);
        let net = LayerStack::single(RnnCell::evrnn(6, 2, 0.0, 0.3, 0.5, None, &mut rng));
        let s2 = Snap2::new(&net, 2);
        // dense J reaches every unit, so every row keeps all p params
        assert_eq!(s2.pattern_size(), 6 * net.p());
    }

    #[test]
    fn snap2_pattern_shrinks_with_mask() {
        let mut rng = Pcg64::new(42);
        let mask = MaskPattern::random(10, 10, 0.2, &mut rng);
        let net = LayerStack::single(RnnCell::evrnn(10, 2, 0.0, 0.3, 0.5, Some(mask), &mut rng));
        let s2 = Snap2::new(&net, 2);
        assert!(s2.pattern_size() < 10 * net.p());
        let s1 = Snap1::new(&net, 2);
        assert!(s1.pattern_size() < s2.pattern_size());
    }

    #[test]
    fn both_run_sequences() {
        let mut rng = Pcg64::new(43);
        let net = LayerStack::single(RnnCell::egru(6, 2, 0.1, 0.3, 0.5, None, &mut rng));
        let mut readout = Readout::new(2, 6, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops = OpCounter::new();
        for alg in [&mut Snap1::new(&net, 2) as &mut dyn GradientEngine, &mut Snap2::new(&net, 2)] {
            alg.begin_sequence();
            for t in 0..5 {
                let x = [(t as f32).sin(), 0.3];
                let target = if t == 4 { Target::Class(1) } else { Target::None };
                alg.step(&net, &mut readout, &mut loss, &x, target, &mut ops);
            }
            alg.end_sequence(&net, &mut readout, &mut ops);
            assert_eq!(alg.grads().len(), net.p());
        }
    }

    #[test]
    fn snap1_cheaper_than_snap2() {
        let mut rng = Pcg64::new(44);
        let net = LayerStack::single(RnnCell::egru(8, 2, 0.0, 0.3, 0.9, None, &mut rng));
        let mut readout = Readout::new(2, 8, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops1 = OpCounter::new();
        let mut s1 = Snap1::new(&net, 2);
        s1.begin_sequence();
        s1.step(&net, &mut readout, &mut loss, &[0.5, 0.5], Target::None, &mut ops1);
        let mut ops2 = OpCounter::new();
        let mut s2 = Snap2::new(&net, 2);
        s2.begin_sequence();
        s2.step(&net, &mut readout, &mut loss, &[0.5, 0.5], Target::None, &mut ops2);
        assert!(
            ops1.macs_in(Phase::InfluenceUpdate) < ops2.macs_in(Phase::InfluenceUpdate),
            "snap1 {} !< snap2 {}",
            ops1.macs_in(Phase::InfluenceUpdate),
            ops2.macs_in(Phase::InfluenceUpdate)
        );
    }

    /// Depth 2: the within-step credit cascade must reach layer 0's
    /// parameters even though supervision only touches the top readout.
    #[test]
    fn depth2_snap_trains_bottom_layer() {
        let mut rng = Pcg64::new(45);
        let l0 = RnnCell::egru(6, 2, 0.0, 0.3, 0.9, None, &mut rng);
        let l1 = RnnCell::egru(4, 6, 0.0, 0.3, 0.9, None, &mut rng);
        let net = LayerStack::new(vec![l0, l1]);
        let mut readout = Readout::new(2, 4, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let p0 = net.layer(0).p();
        for alg in [&mut Snap1::new(&net, 2) as &mut dyn GradientEngine, &mut Snap2::new(&net, 2)] {
            let mut ops = OpCounter::new();
            alg.begin_sequence();
            let mut xr = Pcg64::new(6);
            for t in 0..8 {
                let target = if t >= 4 { Target::Class(t % 2) } else { Target::None };
                alg.step(&net, &mut readout, &mut loss, &[xr.normal(), xr.normal()], target, &mut ops);
            }
            alg.end_sequence(&net, &mut readout, &mut ops);
            assert!(
                alg.grads()[..p0].iter().any(|&g| g != 0.0),
                "{}: bottom layer got no credit",
                alg.name()
            );
        }
    }
}
