//! Gradient engines: exact RTRL (dense and sparse), the SnAp
//! approximations, UORO and BPTT — all operating on stacked recurrent
//! networks ([`crate::nn::LayerStack`]).
//!
//! All engines implement [`GradientEngine`] and are interchangeable in the
//! trainer, the sweep coordinator and the `bench` subsystem — nothing
//! outside the [`crate::train::build::build_engine`] factory matches on a
//! concrete engine type. The exactness contract (tested in `rust/tests/`):
//!
//! * [`DenseRtrl`], [`SparseRtrl`] (in all three sparsity modes) and
//!   [`Bptt`] compute the **same gradient** up to floating-point
//!   reassociation — the paper's central claim is that sparsity is exploited
//!   *"without using any approximations"*, and it survives depth;
//! * [`Snap1`]/[`Snap2`] are the Menick et al. (2020) comparison points and
//!   deliberately approximate; [`Uoro`] is the stochastic rank-1 baseline.
//!
//! # The stacked Jacobian: block lower-bidiagonal
//!
//! Over the concatenated state `a = [a_0 … a_{L-1}] ∈ R^N`, one step of the
//! stack gives layer `l` two dependency blocks (see `nn::stack`):
//!
//! ```text
//! ∂a_l^{(t)}/∂a_l^{(t-1)}     diagonal block    (masked recurrent weights)
//! ∂a_l^{(t)}/∂a_{l-1}^{(t)}   sub-diagonal block (dense input weights)
//! ```
//!
//! so the exact influence recursion propagates layer-by-layer *within* a
//! step: layer `l`'s new rows gather from its own previous rows (`M_l^{(t-1)}`)
//! and from layer `l−1`'s **already-updated** rows (`M_{l-1}^{(t)}`), then add
//! the immediate term and apply the `φ'` row gate:
//!
//! ```text
//! M_l^{(t)} = φ'_l ⊙ [ J_l·M_l^{(t-1)} + C_l·M_{l-1}^{(t)} + M̄_l ]
//! ```
//!
//! Columns follow the same order as parameters (layer-major), and because a
//! parameter of layer `m` can never influence a shallower layer's state,
//! `M` is block lower-*triangular* over (layer-row × layer-column): layer
//! `l`'s rows span only the parameters of layers `0..=l`. Activity sparsity
//! still zeroes entire rows per layer (`φ'(v_k) = 0`), and parameter
//! sparsity still drops columns — both exactly as in the single-layer
//! derivation (paper §4–§5), block by block.
//!
//! # Step-Jacobian slabs and panel kernels
//!
//! Every engine realizes its recursion through the shared [`kernels`]
//! layer. Once per step per layer, the cell materializes a
//! [`kernels::JacobianSlab`]: the own-layer block `∂v/∂a` as CSR over the
//! engine-selected rows × columns (deriv-active rows, `kept_cols` pattern,
//! active-set intersections — whatever evaluation set the engine's cost
//! model prescribes), plus the cross-layer block `∂v/∂x` as dense rows
//! over the lower layer's active rows. The engines then compose their
//! updates from fused row kernels — the Eq.-10 panel gather, cross-layer
//! axpy, the `φ'` gate with flush-to-zero, adjoint scatters, slab·vector
//! dots. This buys three things:
//!
//! * **No recomputation.** A gated cell's `∂v_k/∂a_l` costs two MACs and a
//!   `g_u/g_z` load per evaluation; slab rows are filled with one dynamics
//!   dispatch per *row* and the values are reused by every consumer within
//!   the step (UORO's backward substitution reads the forward slab instead
//!   of re-deriving every cross-layer entry).
//! * **Bulk op accounting.** Charges are computed from slab entry counts
//!   and kernel slice lengths — `count × per-entry cost` at the call site —
//!   so the innermost loops carry no accounting at all. Each engine keeps
//!   charging the *same counts in the same phases* as the historical
//!   per-scalar path (its cost model is the paper's, not the
//!   implementation's); `rust/tests/jacobian_slab.rs` pins this.
//! * **Intra-step parallelism.** Panel rows write disjoint memory, so the
//!   exact sparse engine fans the row update out over
//!   [`crate::util::pool`] ([`GradientEngine::set_threads`]). The kernels
//!   fix their float association order and every row's inputs are frozen
//!   during the update, so multi-threaded and single-threaded steps are
//!   **bit-identical** — same gradients, same op counts, pinned over a
//!   full training run.
//!
//! # The cost model, per step and layer (Table 1, generalized)
//!
//! With panel width `pc_l = Σ_{m≤l} ω̃-compact columns`, the exact sparse
//! engine charges per layer `l`:
//!
//! ```text
//! Jacobian    β̃ωn²·c        slab build: deriv rows × (kept ∩ prev-active cols)
//! Immediate   β̃ω̃n·fan-in    M̄ rows, event-driven (zero inputs skipped)
//! Influence   β̃²n·(ω̃n+1)·pc  panel gathers + cross rows + φ' gate
//! ```
//!
//! so the dominant term is `O(ω̃²β̃²n²p)` — the paper's §5 product — and
//! the structurally-zero blocks (masked columns, inactive rows, deeper
//! layers' columns in shallower panels) are never materialized *or*
//! charged. The dense baseline charges the full `n(n+1)P` per layer pair;
//! the bench subsystem records both, together with wall-clock, so the
//! op-count model and the hardware reality stay comparable in
//! `BENCH_rtrl.json` across history.
//!
//! # The `GradientEngine` contract
//!
//! Protocol per sequence: [`GradientEngine::begin_sequence`] →
//! [`GradientEngine::step`] × T → [`GradientEngine::end_sequence`] →
//! [`GradientEngine::grads`]. Or drive a whole sequence through the provided
//! [`GradientEngine::run_sequence`]. For the streaming session surface,
//! engines additionally implement the versioned snapshot contract
//! ([`GradientEngine::save_state`] / [`GradientEngine::load_state`] over
//! [`EngineState`], see [`state`]): a between-steps snapshot restored into
//! a freshly-built engine continues the sequence bit-identically.
//!
//! **Op-count accounting** is part of the contract, not an optional extra:
//! every multiply-accumulate an engine performs must be charged to the
//! [`OpCounter`] passed into `step`/`end_sequence`, attributed to the
//! matching [`crate::metrics::Phase`] **and**, for work attributable to one
//! layer, performed inside that layer's [`OpCounter::set_layer`] scope so
//! the `(layer, Phase)` breakdown stays truthful. In particular the
//! structural zero blocks of the stacked `M` (layer `l`'s rows over deeper
//! layers' parameter columns) must never be charged — the bench report
//! exposes per-layer counters precisely so this is checkable.
//! [`GradientEngine::state_memory_words`] must report the measured live
//! state footprint (Table 1's memory column; Jacobian slabs are per-step
//! scratch and are excluded). The `bench` subsystem and the Table-1 report
//! derive every per-engine cost figure from these counters, so an engine
//! that under- or over-charges corrupts the paper comparison. Charged
//! counts must also be **independent of the worker-thread count** — CI
//! diffs the per-phase counters between `--threads 1` and `--threads 2`
//! smoke benches on every PR.

pub mod batch;
pub mod bptt;
pub mod column_map;
pub mod dense;
pub mod influence;
pub mod kernels;
pub mod snap;
pub mod sparse;
pub mod state;
pub mod uoro;

pub use batch::BatchedSparse;
pub use bptt::Bptt;
pub use column_map::{ColumnMap, StackColumnMap};
pub use dense::DenseRtrl;
pub use influence::{InfluenceBuffers, StackedInfluence};
pub use kernels::JacobianSlab;
pub use snap::{Snap1, Snap2};
pub use sparse::{SparseRtrl, SparsityMode};
pub use state::{EngineState, StateError};
pub use uoro::Uoro;

use crate::metrics::OpCounter;
use crate::nn::{LayerStack, Loss, Readout};

/// Supervision for one timestep.
#[derive(Debug, Clone, Copy)]
pub enum Target<'a> {
    /// No loss at this step (influence still propagates).
    None,
    /// Integer class target (softmax cross-entropy).
    Class(usize),
    /// Dense regression target (MSE).
    Vector(&'a [f32]),
}

impl Target<'_> {
    pub fn is_some(&self) -> bool {
        !matches!(self, Target::None)
    }
}

/// Per-step observation returned by [`GradientEngine::step`].
#[derive(Debug, Clone, Copy, Default)]
pub struct StepResult {
    /// Instantaneous loss, if a target was given.
    pub loss: Option<f32>,
    /// Whether the prediction matched a class target.
    pub correct: Option<bool>,
    /// Predicted class on supervised classification steps (argmax logits).
    pub prediction: Option<usize>,
    /// α̃n — units with nonzero activation.
    pub active_units: usize,
    /// β̃n — units with nonzero pseudo-derivative.
    pub deriv_units: usize,
    /// Influence-matrix zero fraction, when measurement is enabled.
    pub influence_sparsity: Option<f32>,
}

/// Aggregated observations over one sequence, produced by
/// [`GradientEngine::run_sequence`].
#[derive(Debug, Clone, Copy, Default)]
pub struct SequenceSummary {
    /// Timesteps run.
    pub steps: usize,
    /// Steps that carried a target.
    pub supervised_steps: usize,
    /// Sum of per-step losses over supervised steps.
    pub loss_sum: f32,
    /// Correct class predictions over supervised classification steps.
    pub correct: usize,
    /// Σ per-step active units (divide by `steps·n` for α̃).
    pub active_unit_steps: usize,
    /// Σ per-step deriv-active units (divide by `steps·n` for β̃).
    pub deriv_unit_steps: usize,
}

impl SequenceSummary {
    /// Fold one step's observation in.
    pub fn absorb(&mut self, r: &StepResult) {
        self.steps += 1;
        self.active_unit_steps += r.active_units;
        self.deriv_unit_steps += r.deriv_units;
        if let Some(l) = r.loss {
            self.supervised_steps += 1;
            self.loss_sum += l;
        }
        if r.correct == Some(true) {
            self.correct += 1;
        }
    }

    /// Mean loss over supervised steps (0 when unsupervised).
    pub fn mean_loss(&self) -> f32 {
        self.loss_sum / self.supervised_steps.max(1) as f32
    }
}

/// A gradient engine over one sequence at a time.
///
/// Protocol: `begin_sequence` → `step` × T → `end_sequence` → `grads`.
/// RTRL variants accumulate gradients online during `step`; BPTT materializes
/// them in `end_sequence`. Readout gradients accumulate into the `Readout`
/// (scaled by the trainer), recurrent-parameter gradients into `grads()`
/// (concatenated layer-major layout `R^P` per
/// [`crate::nn::NetworkLayout`], structurally zero at masked positions).
///
/// Every MAC performed must be charged to the step's [`OpCounter`] under the
/// matching [`crate::metrics::Phase`], inside the owning layer's
/// [`OpCounter::set_layer`] scope where attributable — see the module docs
/// for why this is load-bearing.
///
/// Engines are `Send` so long-lived sessions holding them can migrate
/// across the worker threads of a [`crate::session::SessionPool`].
pub trait GradientEngine: Send {
    /// Short name for reports ("rtrl-dense", "snap1", …).
    fn name(&self) -> &'static str;

    /// Reset per-sequence state (influence matrix, histories, gradients).
    fn begin_sequence(&mut self);

    /// Advance one timestep of the whole stack.
    fn step(
        &mut self,
        net: &LayerStack,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult;

    /// Finish the sequence (no-op for online methods; backward pass for BPTT).
    fn end_sequence(&mut self, net: &LayerStack, readout: &mut Readout, ops: &mut OpCounter);

    /// Accumulated `∂𝓛/∂w` for the last completed sequence (dense `R^P`,
    /// concatenated layer-major).
    fn grads(&self) -> &[f32];

    /// Clear gradient accumulators while *keeping* sequence state (influence
    /// matrix, activations). This is the online-learning regime the paper
    /// motivates: apply an update every supervised step of an endless
    /// stream, M carries on. (BPTT cannot support this — its gradient needs
    /// the stored history, which is exactly what online learning forbids.)
    fn reset_grads(&mut self);

    /// Enable/disable influence-sparsity measurement (costs a scan; trainers
    /// turn it on only for logging iterations). Default: ignored.
    fn set_measure_influence(&mut self, _on: bool) {}

    /// Set the worker-thread count for intra-step kernels (`0` = available
    /// hardware parallelism, the uniform `--threads` semantics). Engines
    /// that parallelize ([`SparseRtrl`]'s panel-row update) must stay
    /// **bit-identical** across thread counts — same gradients, same op
    /// counts — because rows write disjoint memory and the row kernels fix
    /// their float association order. Default: ignored (serial engines).
    fn set_threads(&mut self, _threads: usize) {}

    /// Peak memory words this engine holds for sequence state (the
    /// Table-1 "memory" column): influence matrices for RTRL, stored history
    /// for BPTT. Measured, not analytic.
    fn state_memory_words(&self) -> usize;

    /// Concatenated current activations `a ∈ R^N` (the state produced by the
    /// last `step`, all zeros before the first). Sessions use this to run
    /// readout-only predictions on unsupervised steps without re-running the
    /// recurrent forward.
    fn activations(&self) -> &[f32];

    /// Versioned snapshot of **all** sequence state: influence panels for
    /// RTRL, SnAp pattern slabs, UORO's rank-1 vectors *and* noise-RNG
    /// position, BPTT's stored tape — plus the previous activations and the
    /// gradient accumulators. Taken between steps.
    ///
    /// Contract: restoring the snapshot via [`GradientEngine::load_state`]
    /// into a freshly-built engine of the same configuration continues the
    /// sequence with gradients and predictions **bit-identical** to the
    /// uninterrupted run (`rust/tests/engine_contract.rs` pins this for
    /// every engine).
    fn save_state(&self) -> EngineState;

    /// Restore a [`GradientEngine::save_state`] snapshot. `net` must be the
    /// stack the snapshotted engine was built for (same depth, widths and
    /// masks); mismatches in engine name, state version or buffer lengths
    /// fail loudly without partially mutating the engine where practical.
    fn load_state(&mut self, net: &LayerStack, state: &EngineState) -> Result<(), StateError>;

    /// Downcast to the exact sparse engine, when this engine is one. The
    /// session pool uses this to find sessions eligible for shared-weight
    /// batched stepping ([`BatchedSparse`]) — only `SparseRtrl` in
    /// parameter mode qualifies. Default: not a sparse engine.
    fn as_sparse(&mut self) -> Option<&mut SparseRtrl> {
        None
    }

    /// Drive one whole supervised sequence through the engine
    /// (`begin_sequence` → `step` × T → `end_sequence`), charging every op
    /// to `ops`. `targets` may be shorter than `inputs`; missing entries are
    /// [`Target::None`]. This is how the bench subsystem and the trait-level
    /// tests run engines, so it must stay equivalent to the manual protocol.
    fn run_sequence(
        &mut self,
        net: &LayerStack,
        readout: &mut Readout,
        loss: &mut Loss,
        inputs: &[Vec<f32>],
        targets: &[Target<'_>],
        ops: &mut OpCounter,
    ) -> SequenceSummary {
        self.begin_sequence();
        let mut summary = SequenceSummary::default();
        for (t, x) in inputs.iter().enumerate() {
            let target = targets.get(t).copied().unwrap_or(Target::None);
            let r = self.step(net, readout, loss, x, target, ops);
            summary.absorb(&r);
        }
        self.end_sequence(net, readout, ops);
        summary
    }
}

/// Shared helper: run readout + loss + credit assignment for a supervised
/// step, filling `c_bar`. Returns `(loss, correct, predicted class)`.
pub(crate) fn supervised_step(
    readout: &mut Readout,
    loss: &mut Loss,
    a: &[f32],
    target: Target,
    logits: &mut [f32],
    dlogits: &mut [f32],
    c_bar: &mut [f32],
    ops: &mut OpCounter,
) -> (Option<f32>, Option<bool>, Option<usize>) {
    match target {
        Target::None => (None, None, None),
        Target::Class(t) => {
            readout.forward(a, logits, ops);
            let l = loss.cross_entropy(logits, t, dlogits);
            let pred = Loss::predict(logits);
            readout.backward(a, dlogits, c_bar, ops);
            (Some(l), Some(pred == t), Some(pred))
        }
        Target::Vector(tv) => {
            readout.forward(a, logits, ops);
            let l = loss.mse(logits, tv, dlogits);
            readout.backward(a, dlogits, c_bar, ops);
            (Some(l), None, None)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LossKind;
    use crate::util::Pcg64;

    /// `run_sequence` must be behaviourally identical to the manual
    /// begin/step/end protocol.
    #[test]
    fn run_sequence_matches_manual_protocol() {
        let mut rng = Pcg64::new(81);
        let net = LayerStack::single(crate::nn::RnnCell::egru(6, 2, 0.1, 0.3, 0.5, None, &mut rng));
        let inputs: Vec<Vec<f32>> = (0..5)
            .map(|t| vec![(t as f32 * 0.7).sin(), (t as f32 * 0.4).cos()])
            .collect();
        let targets = [Target::None, Target::None, Target::Class(1), Target::None, Target::Class(0)];

        let mut r1 = Pcg64::new(9);
        let mut readout = Readout::new(2, 6, &mut r1);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops = OpCounter::new();
        let mut eng = DenseRtrl::new(&net, 2);
        let summary = eng.run_sequence(&net, &mut readout, &mut loss, &inputs, &targets, &mut ops);
        let g_auto = eng.grads().to_vec();

        let mut r2 = Pcg64::new(9);
        let mut readout2 = Readout::new(2, 6, &mut r2);
        let mut loss2 = Loss::new(LossKind::CrossEntropy, 2);
        let mut ops2 = OpCounter::new();
        let mut eng2 = DenseRtrl::new(&net, 2);
        eng2.begin_sequence();
        let mut loss_sum = 0.0;
        for (t, x) in inputs.iter().enumerate() {
            let r = eng2.step(&net, &mut readout2, &mut loss2, x, targets[t], &mut ops2);
            if let Some(l) = r.loss {
                loss_sum += l;
            }
        }
        eng2.end_sequence(&net, &mut readout2, &mut ops2);

        assert_eq!(summary.steps, 5);
        assert_eq!(summary.supervised_steps, 2);
        assert!((summary.loss_sum - loss_sum).abs() < 1e-6);
        assert_eq!(g_auto, eng2.grads());
        assert_eq!(ops.total_macs(), ops2.total_macs());
    }

    #[test]
    fn summary_absorbs_steps() {
        let mut s = SequenceSummary::default();
        s.absorb(&StepResult {
            loss: Some(0.5),
            correct: Some(true),
            prediction: Some(1),
            active_units: 3,
            deriv_units: 2,
            influence_sparsity: None,
        });
        s.absorb(&StepResult::default());
        assert_eq!(s.steps, 2);
        assert_eq!(s.supervised_steps, 1);
        assert_eq!(s.correct, 1);
        assert_eq!(s.active_unit_steps, 3);
        assert!((s.mean_loss() - 0.5).abs() < 1e-7);
    }
}
