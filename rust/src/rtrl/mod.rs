//! Gradient algorithms: exact RTRL (dense and sparse), the SnAp
//! approximations, and BPTT.
//!
//! All algorithms implement [`Algorithm`] and are interchangeable in the
//! trainer. The exactness contract (tested in `rust/tests/`):
//!
//! * [`DenseRtrl`], [`SparseRtrl`] (in all three sparsity modes) and
//!   [`Bptt`] compute the **same gradient** up to floating-point
//!   reassociation — the paper's central claim is that sparsity is exploited
//!   *"without using any approximations"*;
//! * [`Snap1`]/[`Snap2`] are the Menick et al. (2020) comparison points and
//!   deliberately approximate.
//!
//! Cost accounting: every engine charges its MACs to an [`OpCounter`] phase
//! so Table 1's analytic factors can be checked against measured counts.

pub mod bptt;
pub mod column_map;
pub mod dense;
pub mod influence;
pub mod snap;
pub mod sparse;
pub mod uoro;

pub use bptt::Bptt;
pub use column_map::ColumnMap;
pub use dense::DenseRtrl;
pub use snap::{Snap1, Snap2};
pub use uoro::Uoro;
pub use sparse::{SparseRtrl, SparsityMode};

use crate::metrics::OpCounter;
use crate::nn::{Loss, Readout, RnnCell};

/// Supervision for one timestep.
#[derive(Debug, Clone, Copy)]
pub enum Target<'a> {
    /// No loss at this step (influence still propagates).
    None,
    /// Integer class target (softmax cross-entropy).
    Class(usize),
    /// Dense regression target (MSE).
    Vector(&'a [f32]),
}

impl Target<'_> {
    pub fn is_some(&self) -> bool {
        !matches!(self, Target::None)
    }
}

/// Per-step observation returned by [`Algorithm::step`].
#[derive(Debug, Clone, Copy, Default)]
pub struct StepResult {
    /// Instantaneous loss, if a target was given.
    pub loss: Option<f32>,
    /// Whether the prediction matched a class target.
    pub correct: Option<bool>,
    /// α̃n — units with nonzero activation.
    pub active_units: usize,
    /// β̃n — units with nonzero pseudo-derivative.
    pub deriv_units: usize,
    /// Influence-matrix zero fraction, when measurement is enabled.
    pub influence_sparsity: Option<f32>,
}

/// A gradient algorithm over one sequence at a time.
///
/// Protocol: `begin_sequence` → `step` × T → `end_sequence` → `grads`.
/// RTRL variants accumulate gradients online during `step`; BPTT materializes
/// them in `end_sequence`. Readout gradients accumulate into the `Readout`
/// (scaled by the trainer), recurrent-parameter gradients into `grads()`
/// (dense layout `R^p`, structurally zero at masked positions).
pub trait Algorithm {
    /// Short name for reports ("rtrl-dense", "snap1", …).
    fn name(&self) -> &'static str;

    /// Reset per-sequence state (influence matrix, histories, gradients).
    fn begin_sequence(&mut self);

    /// Advance one timestep.
    fn step(
        &mut self,
        cell: &RnnCell,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult;

    /// Finish the sequence (no-op for online methods; backward pass for BPTT).
    fn end_sequence(
        &mut self,
        cell: &RnnCell,
        readout: &mut Readout,
        ops: &mut OpCounter,
    );

    /// Accumulated `∂𝓛/∂w` for the last completed sequence (dense `R^p`).
    fn grads(&self) -> &[f32];

    /// Clear gradient accumulators while *keeping* sequence state (influence
    /// matrix, activations). This is the online-learning regime the paper
    /// motivates: apply an update every supervised step of an endless
    /// stream, M carries on. (BPTT cannot support this — its gradient needs
    /// the stored history, which is exactly what online learning forbids.)
    fn reset_grads(&mut self);

    /// Enable/disable influence-sparsity measurement (costs a scan; trainers
    /// turn it on only for logging iterations). Default: ignored.
    fn set_measure_influence(&mut self, _on: bool) {}

    /// Peak memory words this algorithm holds for sequence state (the
    /// Table-1 "memory" column): influence matrices for RTRL, stored history
    /// for BPTT. Measured, not analytic.
    fn state_memory_words(&self) -> usize;
}

/// Shared helper: run readout + loss + credit assignment for a supervised
/// step. Returns `(loss, correct, c_bar_filled)`.
pub(crate) fn supervised_step(
    readout: &mut Readout,
    loss: &mut Loss,
    a: &[f32],
    target: Target,
    logits: &mut [f32],
    dlogits: &mut [f32],
    c_bar: &mut [f32],
    ops: &mut OpCounter,
) -> (Option<f32>, Option<bool>) {
    match target {
        Target::None => (None, None),
        Target::Class(t) => {
            readout.forward(a, logits, ops);
            let l = loss.cross_entropy(logits, t, dlogits);
            let correct = Loss::predict(logits) == t;
            readout.backward(a, dlogits, c_bar, ops);
            (Some(l), Some(correct))
        }
        Target::Vector(tv) => {
            readout.forward(a, logits, ops);
            let l = loss.mse(logits, tv, dlogits);
            readout.backward(a, dlogits, c_bar, ops);
            (Some(l), None)
        }
    }
}
