//! Fully dense RTRL — the paper's `O(n²p)`-per-step baseline, on the
//! stacked state.
//!
//! No *value* skipping of any kind: every row of the full `N×P` influence
//! matrix is recomputed every step, the own-layer gather runs over all of
//! the layer's previous rows and the cross-layer gather over all of the
//! lower layer's new rows, always at the full column width `P` — exactly
//! the cost Table 1's "Fully dense / RTRL" row charges, generalized to the
//! block lower-bidiagonal recursion (`Σ_l n_l(n_l + n_{l-1})P` MACs per
//! step; at depth 1 this is the familiar `n(n+1)p`). On an activity-sparse
//! stack this engine still produces the *same* gradients as the sparse
//! engines (the skipped work is all zeros); it just pays for the zeros —
//! which is the comparison the paper draws. The one thing it does not
//! invent is architecturally impossible coupling: the recursion is the
//! exact recursion of the layered network, so the structurally-zero upper
//! blocks hold zeros in the materialized `N×P` matrix too.
//!
//! The row update runs on the shared lane-chunked kernels of
//! [`super::kernels`] (`fused_gather`/`axpy`), so SIMD-shaped improvements
//! to that layer speed this baseline up identically to the sparse engines.

use super::kernels::{self, CrossSelect, JacobianSlab, OwnSelect, RowSelect};
use super::{supervised_step, EngineState, GradientEngine, StateError, StepResult, Target};
use crate::metrics::{OpCounter, Phase};
use crate::nn::{LayerStack, Loss, Readout, StackScratch};
use crate::tensor::Matrix;

/// Snapshot-format version of [`DenseRtrl`] (see [`EngineState`]).
const STATE_VERSION: u32 = 1;

/// Dense RTRL engine (per-sequence state; reusable).
pub struct DenseRtrl {
    /// Full `N × P` influence panels (current and next).
    m_cur: Matrix,
    m_next: Matrix,
    scratch: StackScratch,
    a_prev: Vec<f32>,
    /// Per-step dense Jacobian slab (all rows × all columns — the baseline
    /// pays for the structural zeros; that is the comparison Table 1 draws).
    slab: JacobianSlab,
    grads: Vec<f32>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    c_bar: Vec<f32>,
    measure_influence: bool,
}

impl DenseRtrl {
    pub fn new(net: &LayerStack, readout_n_out: usize) -> Self {
        let (n, p) = (net.total_units(), net.p());
        DenseRtrl {
            m_cur: Matrix::zeros(n, p),
            m_next: Matrix::zeros(n, p),
            scratch: net.scratch(),
            a_prev: vec![0.0; n],
            slab: JacobianSlab::new(),
            grads: vec![0.0; p],
            logits: vec![0.0; readout_n_out],
            dlogits: vec![0.0; readout_n_out],
            c_bar: vec![0.0; net.top_n()],
            measure_influence: false,
        }
    }

    /// Dense copy of the current influence matrix (tests / Fig. 2).
    pub fn influence(&self) -> &Matrix {
        &self.m_cur
    }

    /// Forward scratch of the last step (tests / Fig. 2).
    pub fn scratch(&self) -> &StackScratch {
        &self.scratch
    }
}

impl GradientEngine for DenseRtrl {
    fn name(&self) -> &'static str {
        "rtrl-dense"
    }

    fn begin_sequence(&mut self) {
        self.m_cur.fill_zero();
        self.m_next.fill_zero();
        self.a_prev.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn step(
        &mut self,
        net: &LayerStack,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult {
        let p = net.p();
        net.forward(&self.a_prev, x, &mut self.scratch, ops);
        let active_units = self.scratch.active_units();
        let deriv_units = self.scratch.deriv_units();

        // M_next = blockwise J·M + C·M_next_lower + M̄, no value skipping:
        // the slab is built dense (all rows × all columns, masked entries
        // included) and every coefficient — zero or not — is streamed
        // through the full-width axpy, exactly the cost Table 1's dense
        // row charges.
        for l in 0..net.layers() {
            ops.set_layer(l);
            let cell = net.layer(l);
            let sl = &self.scratch.layers[l];
            let nl = cell.n();
            let soff = net.layout().state_offset(l);
            let poff = net.layout().param_offset(l);
            let nprev = if l > 0 { net.layer(l - 1).n() } else { 0 };
            let soff_prev = if l > 0 { net.layout().state_offset(l - 1) } else { 0 };
            let a_prev_l = &self.a_prev[soff..soff + nl];
            let input_l: &[f32] = if l == 0 { x } else { &self.scratch.layers[l - 1].a };
            let cross_sel = if l > 0 { CrossSelect::All } else { CrossSelect::Skip };
            let counts = self.slab.build(cell, sl, RowSelect::All, OwnSelect::Dense, cross_sel);
            ops.macs(
                Phase::Jacobian,
                counts.own_entries * cell.dv_da_cost() + counts.cross_entries * cell.dv_dx_cost(),
            );
            // Split the next panel at this layer's first row so the lower
            // layer's already-written rows stay readable while we write.
            let (next_lower, next_upper) = self.m_next.split_at_row_mut(soff);
            for k in 0..nl {
                let row = &mut next_upper[k * p..(k + 1) * p];
                row.iter_mut().for_each(|r| *r = 0.0);
                // full own-layer Jacobian row from the slab
                let (cols, vals) = self.slab.own_row(k);
                for (&c, &jv) in cols.iter().zip(vals) {
                    kernels::axpy(row, jv, self.m_cur.row(soff + c as usize));
                }
                // cross-layer block: lower layer's new rows, full width
                if l > 0 {
                    for (j, &cv) in self.slab.cross_row(k).iter().enumerate() {
                        let src = &next_lower[(soff_prev + j) * p..(soff_prev + j + 1) * p];
                        kernels::axpy(row, cv, src);
                    }
                }
                cell.immediate_row(sl, a_prev_l, input_l, k, |pi, val| row[poff + pi] += val, ops);
                // flush-to-zero at the row gate (see kernels::FLUSH_EPS)
                kernels::scale_flush(row, sl.dphi[k]);
                ops.macs(Phase::InfluenceUpdate, ((nl + nprev) * p + p) as u64);
            }
            ops.words(
                Phase::InfluenceUpdate,
                ((nl * (nl + nprev) + nl) * p) as u64,
            );
        }
        ops.clear_layer();

        let (loss_val, correct, prediction) = supervised_step(
            readout,
            loss,
            &self.scratch.top().a,
            target,
            &mut self.logits,
            &mut self.dlogits,
            &mut self.c_bar,
            ops,
        );
        if loss_val.is_some() {
            // grads += M_nextᵀ c̄ over the top layer's rows (credit for
            // lower layers is folded into the top rows' columns — exact)
            let top_off = net.layout().state_offset(net.layers() - 1);
            for (k, &coef) in self.c_bar.iter().enumerate() {
                let mrow = self.m_next.row(top_off + k);
                for (g, m) in self.grads.iter_mut().zip(mrow) {
                    *g += coef * m;
                }
            }
            ops.macs(Phase::GradCombine, (self.c_bar.len() * p) as u64);
        }

        let influence_sparsity = if self.measure_influence {
            Some(self.m_next.sparsity())
        } else {
            None
        };

        std::mem::swap(&mut self.m_cur, &mut self.m_next);
        self.scratch.write_state(&mut self.a_prev);

        StepResult { loss: loss_val, correct, prediction, active_units, deriv_units, influence_sparsity }
    }

    fn end_sequence(&mut self, _net: &LayerStack, _readout: &mut Readout, _ops: &mut OpCounter) {}

    fn grads(&self) -> &[f32] {
        &self.grads
    }

    fn reset_grads(&mut self) {
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn set_measure_influence(&mut self, on: bool) {
        self.measure_influence = on;
    }

    fn state_memory_words(&self) -> usize {
        self.m_cur.len() + self.m_next.len()
    }

    fn activations(&self) -> &[f32] {
        &self.a_prev
    }

    fn save_state(&self) -> EngineState {
        // m_next is pure staging (every row is rewritten before it is read),
        // so the sequence state is the current panel + activations + grads.
        let mut st = EngineState::new(self.name(), STATE_VERSION);
        st.put_floats("m_cur", self.m_cur.as_slice().to_vec());
        st.put_floats("a_prev", self.a_prev.clone());
        st.put_floats("grads", self.grads.clone());
        st
    }

    fn load_state(&mut self, _net: &LayerStack, state: &EngineState) -> Result<(), StateError> {
        state.require(self.name(), STATE_VERSION)?;
        let m = state.floats_exact("m_cur", self.m_cur.len())?;
        let a = state.floats_exact("a_prev", self.a_prev.len())?;
        let g = state.floats_exact("grads", self.grads.len())?;
        self.m_cur.as_mut_slice().copy_from_slice(m);
        self.m_next.fill_zero();
        self.a_prev.copy_from_slice(a);
        self.grads.copy_from_slice(g);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{LossKind, RnnCell};
    use crate::util::Pcg64;

    #[test]
    fn dense_pays_full_cost_regardless_of_activity() {
        let mut rng = Pcg64::new(20);
        // threshold so high nothing fires
        let net = LayerStack::single(RnnCell::egru(6, 2, 100.0, 0.3, 0.5, None, &mut rng));
        let mut readout = Readout::new(2, 6, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = DenseRtrl::new(&net, 2);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        eng.step(&net, &mut readout, &mut loss, &[1.0, 1.0], Target::None, &mut ops);
        let n = 6u64;
        let p = net.p() as u64;
        // exactly n·(n·p + p) influence MACs charged even though all-zero
        assert_eq!(ops.macs_in(Phase::InfluenceUpdate), n * (n * p + p));
    }

    #[test]
    fn influence_rows_zero_where_dphi_zero() {
        let mut rng = Pcg64::new(21);
        let net = LayerStack::single(RnnCell::egru(8, 2, 0.1, 0.3, 0.5, None, &mut rng));
        let mut readout = Readout::new(2, 8, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = DenseRtrl::new(&net, 2);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        eng.step(&net, &mut readout, &mut loss, &[0.7, -0.4], Target::None, &mut ops);
        // paper Eq. 10: rows of M with φ'(v_k)=0 are fully zero
        for k in 0..8 {
            if eng.scratch.top().dphi[k] == 0.0 {
                assert!(eng.m_cur.row(k).iter().all(|&v| v == 0.0), "row {k} not zero");
            }
        }
    }

    #[test]
    fn masked_columns_stay_zero() {
        let mut rng = Pcg64::new(22);
        let mask = crate::sparse::MaskPattern::random(6, 6, 0.3, &mut rng);
        let net = LayerStack::single(RnnCell::evrnn(6, 2, 0.0, 0.3, 0.5, Some(mask.clone()), &mut rng));
        let mut readout = Readout::new(2, 6, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = DenseRtrl::new(&net, 2);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        for t in 0..5 {
            let x = [0.5 + 0.1 * t as f32, -0.2];
            eng.step(&net, &mut readout, &mut loss, &x, Target::None, &mut ops);
        }
        // §5: columns of M for dropped params remain zero across timesteps
        let layout = net.layer(0).layout();
        let voff = layout.offset(crate::nn::cell::linear_blocks::V);
        for r in 0..6 {
            for c in 0..6 {
                if !mask.is_kept(r, c) {
                    let pi = voff + r * 6 + c;
                    for k in 0..6 {
                        assert_eq!(eng.m_cur.get(k, pi), 0.0, "M[{k},{pi}] nonzero");
                    }
                }
            }
        }
    }

    /// Depth 2: cross-layer blocks of the materialized N×P matrix hold the
    /// structural zeros (upper blocks: layer-0 rows over layer-1 columns),
    /// while the lower blocks fill in as influence propagates upward.
    #[test]
    fn depth2_upper_blocks_structurally_zero() {
        let mut rng = Pcg64::new(23);
        let l0 = RnnCell::egru(5, 2, 0.05, 0.3, 0.9, None, &mut rng);
        let l1 = RnnCell::egru(4, 5, 0.05, 0.3, 0.9, None, &mut rng);
        let net = LayerStack::new(vec![l0, l1]);
        let mut readout = Readout::new(2, 4, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = DenseRtrl::new(&net, 2);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        let mut xr = Pcg64::new(8);
        for _ in 0..5 {
            eng.step(&net, &mut readout, &mut loss, &[xr.normal(), xr.normal()], Target::None, &mut ops);
        }
        let p0 = net.layer(0).p();
        // layer-0 rows (0..5) over layer-1 param columns (p0..P): all zero
        for k in 0..5 {
            for pi in p0..net.p() {
                assert_eq!(eng.m_cur.get(k, pi), 0.0, "upper block M[{k},{pi}] nonzero");
            }
        }
        // layer-1 rows carry influence over layer-0 params (lower block)
        let lower_nonzero = (5..9)
            .flat_map(|k| (0..p0).map(move |pi| (k, pi)))
            .filter(|&(k, pi)| eng.m_cur.get(k, pi) != 0.0)
            .count();
        assert!(lower_nonzero > 0, "cross-layer influence never propagated");
    }
}
