//! Fully dense RTRL — the paper's `O(n²p)`-per-step baseline.
//!
//! No skipping of any kind: every row of `M` is recomputed every step and
//! the gather runs over all `n` previous rows and all `p` columns, exactly
//! the cost Table 1's "Fully dense / RTRL" row charges. On an
//! activity-sparse cell this engine still produces the *same* gradients as
//! the sparse engines (the skipped work is all zeros); it just pays for the
//! zeros — which is the comparison the paper draws.

use super::{supervised_step, GradientEngine, StepResult, Target};
use crate::metrics::{OpCounter, Phase};
use crate::nn::{CellScratch, Loss, Readout, RnnCell};
use crate::tensor::Matrix;

/// Dense RTRL engine (per-sequence state; reusable).
pub struct DenseRtrl {
    m_cur: Matrix,
    m_next: Matrix,
    scratch: CellScratch,
    a_prev: Vec<f32>,
    jrow: Vec<f32>,
    grads: Vec<f32>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    c_bar: Vec<f32>,
    measure_influence: bool,
}

impl DenseRtrl {
    pub fn new(cell: &RnnCell, readout_n_out: usize) -> Self {
        let (n, p) = (cell.n(), cell.p());
        DenseRtrl {
            m_cur: Matrix::zeros(n, p),
            m_next: Matrix::zeros(n, p),
            scratch: CellScratch::new(n),
            a_prev: vec![0.0; n],
            jrow: vec![0.0; n],
            grads: vec![0.0; p],
            logits: vec![0.0; readout_n_out],
            dlogits: vec![0.0; readout_n_out],
            c_bar: vec![0.0; n],
            measure_influence: false,
        }
    }

    /// Dense copy of the current influence matrix (tests / Fig. 2).
    pub fn influence(&self) -> &Matrix {
        &self.m_cur
    }
}

impl GradientEngine for DenseRtrl {
    fn name(&self) -> &'static str {
        "rtrl-dense"
    }

    fn begin_sequence(&mut self) {
        self.m_cur.fill_zero();
        self.m_next.fill_zero();
        self.a_prev.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn step(
        &mut self,
        cell: &RnnCell,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult {
        let n = cell.n();
        let p = cell.p();
        cell.forward(&self.a_prev, x, &mut self.scratch, ops);
        let active_units = self.scratch.active_units();
        let deriv_units = self.scratch.deriv_units();

        // M_next = J · M_cur + M̄, with J = φ' ⊙ dv_da, no skipping.
        for k in 0..n {
            let dphi_k = self.scratch.dphi[k];
            // full Jacobian row
            for l in 0..n {
                self.jrow[l] = cell.dv_da(&self.scratch, k, l);
            }
            ops.macs(Phase::Jacobian, n as u64 * cell.dv_da_cost());
            let row = self.m_next.row_mut(k);
            row.iter_mut().for_each(|r| *r = 0.0);
            for l in 0..n {
                let jv = self.jrow[l];
                let src = self.m_cur.row(l);
                for (r, s) in row.iter_mut().zip(src) {
                    *r += jv * s;
                }
            }
            cell.immediate_row(&self.scratch, &self.a_prev, x, k, |pi, val| row[pi] += val, ops);
            // flush-to-zero at the row gate (see SparseRtrl::step §Perf note)
            for r in row.iter_mut() {
                let v = *r * dphi_k;
                *r = if v.abs() < 1e-30 { 0.0 } else { v };
            }
            ops.macs(Phase::InfluenceUpdate, (n * p + p) as u64);
        }
        ops.words(Phase::InfluenceUpdate, ((n + 1) * n * p) as u64);

        let (loss_val, correct) = supervised_step(
            readout,
            loss,
            &self.scratch.a,
            target,
            &mut self.logits,
            &mut self.dlogits,
            &mut self.c_bar,
            ops,
        );
        if loss_val.is_some() {
            // grads += M_nextᵀ c̄ over all rows
            for k in 0..n {
                let coef = self.c_bar[k];
                let mrow = self.m_next.row(k);
                for (g, m) in self.grads.iter_mut().zip(mrow) {
                    *g += coef * m;
                }
            }
            ops.macs(Phase::GradCombine, (n * p) as u64);
        }

        let influence_sparsity = if self.measure_influence {
            Some(self.m_next.sparsity())
        } else {
            None
        };

        std::mem::swap(&mut self.m_cur, &mut self.m_next);
        self.a_prev.copy_from_slice(&self.scratch.a);

        StepResult { loss: loss_val, correct, active_units, deriv_units, influence_sparsity }
    }

    fn end_sequence(&mut self, _cell: &RnnCell, _readout: &mut Readout, _ops: &mut OpCounter) {}

    fn grads(&self) -> &[f32] {
        &self.grads
    }

    fn reset_grads(&mut self) {
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn set_measure_influence(&mut self, on: bool) {
        self.measure_influence = on;
    }

    fn state_memory_words(&self) -> usize {
        self.m_cur.len() + self.m_next.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LossKind;
    use crate::util::Pcg64;

    #[test]
    fn dense_pays_full_cost_regardless_of_activity() {
        let mut rng = Pcg64::new(20);
        // threshold so high nothing fires
        let cell = RnnCell::egru(6, 2, 100.0, 0.3, 0.5, None, &mut rng);
        let mut readout = Readout::new(2, 6, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = DenseRtrl::new(&cell, 2);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        eng.step(&cell, &mut readout, &mut loss, &[1.0, 1.0], Target::None, &mut ops);
        let n = 6u64;
        let p = cell.p() as u64;
        // exactly n·(n·p + p) influence MACs charged even though all-zero
        assert_eq!(ops.macs_in(Phase::InfluenceUpdate), n * (n * p + p));
    }

    #[test]
    fn influence_rows_zero_where_dphi_zero() {
        let mut rng = Pcg64::new(21);
        let cell = RnnCell::egru(8, 2, 0.1, 0.3, 0.5, None, &mut rng);
        let mut readout = Readout::new(2, 8, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = DenseRtrl::new(&cell, 2);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        eng.step(&cell, &mut readout, &mut loss, &[0.7, -0.4], Target::None, &mut ops);
        // paper Eq. 10: rows of M with φ'(v_k)=0 are fully zero
        for k in 0..8 {
            if eng.scratch.dphi[k] == 0.0 {
                assert!(eng.m_cur.row(k).iter().all(|&v| v == 0.0), "row {k} not zero");
            }
        }
    }

    #[test]
    fn masked_columns_stay_zero() {
        let mut rng = Pcg64::new(22);
        let mask = crate::sparse::MaskPattern::random(6, 6, 0.3, &mut rng);
        let cell = RnnCell::evrnn(6, 2, 0.0, 0.3, 0.5, Some(mask.clone()), &mut rng);
        let mut readout = Readout::new(2, 6, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = DenseRtrl::new(&cell, 2);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        for t in 0..5 {
            let x = [0.5 + 0.1 * t as f32, -0.2];
            eng.step(&cell, &mut readout, &mut loss, &x, Target::None, &mut ops);
        }
        // §5: columns of M for dropped params remain zero across timesteps
        let layout = cell.layout();
        let voff = layout.offset(crate::nn::cell::linear_blocks::V);
        for r in 0..6 {
            for c in 0..6 {
                if !mask.is_kept(r, c) {
                    let pi = voff + r * 6 + c;
                    for k in 0..6 {
                        assert_eq!(eng.m_cur.get(k, pi), 0.0, "M[{k},{pi}] nonzero");
                    }
                }
            }
        }
    }
}
