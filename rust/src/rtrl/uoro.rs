//! UORO — Unbiased Online Recurrent Optimization (Tallec & Ollivier 2017),
//! the classic *stochastic* rank-1 RTRL approximation, included as a third
//! comparison point alongside SnAp (Marschall et al. 2020 situate both in
//! the same framework the paper builds on).
//!
//! The influence matrix is approximated by a rank-1 outer product
//! `M ≈ s̃ ⊗ θ̃` with `s̃ ∈ R^N`, `θ̃ ∈ R^P` over the *stacked* state and
//! parameters, updated with random signs `ν ∈ {±1}^N` and
//! variance-balancing scales `ρ₀, ρ₁`:
//!
//! ```text
//! s̃ ← ρ₀·J s̃ + ρ₁·ν           θ̃ ← θ̃/ρ₀ + (νᵀ M̄)/ρ₁
//! ```
//!
//! For a stack, `J` is the one-step Jacobian of the *composed* map and `M̄`
//! the composed immediate influence; both factor along the block
//! lower-bidiagonal structure, so `J·s̃` is computed by **forward
//! substitution** through the layers
//! (`(Js̃)_l = φ'_l ⊙ (J_l s̃_l + C_l (Js̃)_{l-1})`) and `νᵀM̄` by **backward
//! substitution** (`g_l = ν_l + C_{l+1}ᵀ(φ'_{l+1} ⊙ g_{l+1})`, then layer
//! `l` contributes `(φ'_l ⊙ g_l)ᵀ M̄_l` to its own parameter block). This
//! keeps `E[s̃ ⊗ θ̃] = M` (unbiased) at `O(N² + P)` per step — far cheaper
//! than exact RTRL but with gradient *variance* that exact sparse RTRL does
//! not pay. This is the contrast the paper draws: its savings are free of
//! both bias (SnAp) and variance (UORO). The substitution passes run on
//! the shared lane-chunked kernels of [`super::kernels`], same as every
//! other engine family.

use super::kernels::{self, CrossSelect, JacobianSlab, OwnSelect, RowSelect};
use super::{supervised_step, EngineState, GradientEngine, StateError, StepResult, Target};
use crate::metrics::{OpCounter, Phase};
use crate::nn::{LayerStack, Loss, Readout, StackScratch};
use crate::util::math::{dot, l2_norm};
use crate::util::Pcg64;

/// Snapshot-format version of [`Uoro`] (see [`EngineState`]).
const STATE_VERSION: u32 = 1;

/// UORO engine (per-sequence state; reusable).
pub struct Uoro {
    /// Rank-1 state factor s̃ (over the concatenated state).
    s_tilde: Vec<f32>,
    /// Rank-1 parameter factor θ̃ (over the concatenated params).
    theta_tilde: Vec<f32>,
    scratch: StackScratch,
    a_prev: Vec<f32>,
    grads: Vec<f32>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    c_bar: Vec<f32>,
    /// staging for J·s̃, νᵀM̄ and the backward-substituted sign vector
    js: Vec<f32>,
    nu_mbar: Vec<f32>,
    g_signs: Vec<f32>,
    /// Per-layer step-Jacobian slabs (scratch). Built once during the
    /// forward substitution and **reused** by the backward sign
    /// substitution — the cross-layer `∂v/∂x` entries are no longer
    /// re-derived per pass, which is the slab layer's wall-clock win here.
    slabs: Vec<JacobianSlab>,
    rng: Pcg64,
}

impl Uoro {
    pub fn new(net: &LayerStack, readout_n_out: usize, seed: u64) -> Self {
        let (n, p) = (net.total_units(), net.p());
        Uoro {
            s_tilde: vec![0.0; n],
            theta_tilde: vec![0.0; p],
            scratch: net.scratch(),
            a_prev: vec![0.0; n],
            grads: vec![0.0; p],
            logits: vec![0.0; readout_n_out],
            dlogits: vec![0.0; readout_n_out],
            c_bar: vec![0.0; net.top_n()],
            js: vec![0.0; n],
            nu_mbar: vec![0.0; p],
            g_signs: vec![0.0; n],
            slabs: (0..net.layers()).map(|_| JacobianSlab::new()).collect(),
            rng: Pcg64::new(seed),
        }
    }
}

impl GradientEngine for Uoro {
    fn name(&self) -> &'static str {
        "uoro"
    }

    fn begin_sequence(&mut self) {
        self.s_tilde.iter_mut().for_each(|x| *x = 0.0);
        self.theta_tilde.iter_mut().for_each(|x| *x = 0.0);
        self.a_prev.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn step(
        &mut self,
        net: &LayerStack,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult {
        let n = net.total_units();
        let p = net.p();
        net.forward(&self.a_prev, x, &mut self.scratch, ops);
        let active_units = self.scratch.active_units();
        let deriv_units = self.scratch.deriv_units();

        // J·s̃ by forward substitution through the layers (sparse over kept
        // own-layer cols; the cross-layer block reads the already-computed
        // (Js̃)_{l-1} of this very step). The per-layer step-Jacobian slab
        // is built here — deriv-active rows × kept cols, dense cross block
        // — and reused below by the backward sign substitution. Charges
        // keep the engine's historical cost model: (eval + multiply) per
        // entry, in this layer's InfluenceUpdate scope.
        for l in 0..net.layers() {
            ops.set_layer(l);
            let mut macs = 0u64;
            let cell = net.layer(l);
            let sl = &self.scratch.layers[l];
            let soff = net.layout().state_offset(l);
            let soff_prev = if l > 0 { net.layout().state_offset(l - 1) } else { 0 };
            let nprev = if l > 0 { net.layer(l - 1).n() } else { 0 };
            let cross_sel = if l > 0 { CrossSelect::All } else { CrossSelect::Skip };
            self.slabs[l].build(cell, sl, RowSelect::DerivActive, OwnSelect::Kept, cross_sel);
            for k in 0..cell.n() {
                let dphi_k = sl.dphi[k];
                let mut acc = 0.0;
                if dphi_k != 0.0 {
                    let (jcols, jvals) = self.slabs[l].own_row(k);
                    acc = kernels::dot_sparse_acc(
                        acc,
                        jcols,
                        jvals,
                        &self.s_tilde[soff..soff + cell.n()],
                    );
                    macs += jcols.len() as u64 * (cell.dv_da_cost() + 1);
                    acc = kernels::dot_dense_acc(
                        acc,
                        self.slabs[l].cross_row(k),
                        &self.js[soff_prev..soff_prev + nprev],
                    );
                    macs += nprev as u64 * (cell.dv_dx_cost() + 1);
                }
                self.js[soff + k] = dphi_k * acc;
            }
            ops.macs(Phase::InfluenceUpdate, macs);
        }
        ops.clear_layer();
        // νᵀ M̄ of the composed map: draw signs, backward-substitute them
        // down the stack, then broadcast through each layer's local M̄.
        self.nu_mbar.iter_mut().for_each(|v| *v = 0.0);
        for k in 0..n {
            self.g_signs[k] = if self.rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        }
        let nu: Vec<f32> = self.g_signs.clone();
        for l in (1..net.layers()).rev() {
            ops.set_layer(l);
            let mut macs = 0u64;
            let cell = net.layer(l);
            let sl = &self.scratch.layers[l];
            let soff = net.layout().state_offset(l);
            let soff_prev = net.layout().state_offset(l - 1);
            let nprev = net.layer(l - 1).n();
            for k in 0..cell.n() {
                let coef = sl.dphi[k] * self.g_signs[soff + k];
                if coef == 0.0 {
                    continue;
                }
                // coef ≠ 0 ⇒ φ'_k ≠ 0 ⇒ the forward pass built this slab
                // row; the cross entries are read back, not re-derived.
                // Charged at the historical (eval + multiply) rate so the
                // engine's cost model is unchanged by the reuse — the
                // saving is wall-clock, not counted MACs.
                let cross = self.slabs[l].cross_row(k);
                kernels::axpy(&mut self.g_signs[soff_prev..soff_prev + nprev], coef, cross);
                macs += nprev as u64 * (cell.dv_dx_cost() + 1);
            }
            ops.macs(Phase::InfluenceUpdate, macs);
        }
        for l in 0..net.layers() {
            ops.set_layer(l);
            let cell = net.layer(l);
            let sl = &self.scratch.layers[l];
            let soff = net.layout().state_offset(l);
            let poff = net.layout().param_offset(l);
            let a_prev_l = &self.a_prev[soff..soff + cell.n()];
            let input_l: &[f32] = if l == 0 { x } else { &self.scratch.layers[l - 1].a };
            for k in 0..cell.n() {
                let dphi_k = sl.dphi[k];
                if dphi_k == 0.0 {
                    continue;
                }
                let gk = self.g_signs[soff + k] * dphi_k;
                if gk == 0.0 {
                    continue;
                }
                let nu_mbar = &mut self.nu_mbar;
                cell.immediate_row(
                    sl,
                    a_prev_l,
                    input_l,
                    k,
                    |pi, val| nu_mbar[poff + pi] += gk * val,
                    ops,
                );
            }
        }
        ops.clear_layer();
        // variance-balancing scales
        let norm_js = l2_norm(&self.js);
        let norm_tt = l2_norm(&self.theta_tilde);
        let norm_nm = l2_norm(&self.nu_mbar);
        let eps = 1e-7;
        let rho0 = ((norm_tt + eps) / (norm_js + eps)).sqrt();
        let rho1 = ((norm_nm + eps) / ((n as f32).sqrt() + eps)).sqrt();
        for k in 0..n {
            self.s_tilde[k] = rho0 * self.js[k] + rho1 * nu[k];
        }
        for pi in 0..p {
            self.theta_tilde[pi] = self.theta_tilde[pi] / rho0 + self.nu_mbar[pi] / rho1;
        }
        // rank-1 rescale touches every state and parameter entry once —
        // whole-stack work, charged outside any layer scope
        ops.macs(Phase::InfluenceUpdate, (2 * p + 2 * n) as u64);

        let (loss_val, correct, prediction) = supervised_step(
            readout,
            loss,
            &self.scratch.top().a,
            target,
            &mut self.logits,
            &mut self.dlogits,
            &mut self.c_bar,
            ops,
        );
        if loss_val.is_some() {
            // grad += (c̄ · s̃_top) θ̃ — c̄ lives on the top layer only
            let top_off = net.layout().state_offset(net.layers() - 1);
            let coef = dot(&self.c_bar, &self.s_tilde[top_off..top_off + self.c_bar.len()]);
            if coef != 0.0 {
                for (g, t) in self.grads.iter_mut().zip(&self.theta_tilde) {
                    *g += coef * t;
                }
                ops.macs(Phase::GradCombine, p as u64);
            }
        }

        self.scratch.write_state(&mut self.a_prev);
        StepResult { loss: loss_val, correct, prediction, active_units, deriv_units, influence_sparsity: None }
    }

    fn end_sequence(&mut self, _net: &LayerStack, _readout: &mut Readout, _ops: &mut OpCounter) {}

    fn grads(&self) -> &[f32] {
        &self.grads
    }

    fn reset_grads(&mut self) {
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn state_memory_words(&self) -> usize {
        // s̃ + θ̃ + staging — the O(N + P) memory row
        self.s_tilde.len() + 2 * self.theta_tilde.len() + self.js.len()
    }

    fn activations(&self) -> &[f32] {
        &self.a_prev
    }

    fn save_state(&self) -> EngineState {
        // The rank-1 factors + the *noise RNG position*: UORO's gradient is
        // a function of the sign draws, so bit-exact resume requires the
        // stream to continue where it stopped. js/nu_mbar/g_signs are
        // staging, fully rewritten every step.
        let mut st = EngineState::new(self.name(), STATE_VERSION);
        st.put_floats("s_tilde", self.s_tilde.clone());
        st.put_floats("theta_tilde", self.theta_tilde.clone());
        st.put_floats("a_prev", self.a_prev.clone());
        st.put_floats("grads", self.grads.clone());
        st.put_ints("rng", self.rng.state_words().to_vec());
        st
    }

    fn load_state(&mut self, _net: &LayerStack, state: &EngineState) -> Result<(), StateError> {
        state.require(self.name(), STATE_VERSION)?;
        let s = state.floats_exact("s_tilde", self.s_tilde.len())?;
        let t = state.floats_exact("theta_tilde", self.theta_tilde.len())?;
        let a = state.floats_exact("a_prev", self.a_prev.len())?;
        let g = state.floats_exact("grads", self.grads.len())?;
        let rng = state.ints("rng")?;
        if rng.len() != 4 {
            return Err(StateError(format!("rng state has {} words, expected 4", rng.len())));
        }
        self.s_tilde.copy_from_slice(s);
        self.theta_tilde.copy_from_slice(t);
        self.a_prev.copy_from_slice(a);
        self.grads.copy_from_slice(g);
        self.rng = Pcg64::from_state_words([rng[0], rng[1], rng[2], rng[3]]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgorithmKind;
    use crate::nn::{LossKind, RnnCell};
    use crate::train::build_engine;

    /// E[ĝ] over noise draws must approach the exact gradient (unbiasedness).
    #[test]
    fn unbiased_in_expectation() {
        let mut rng = Pcg64::new(70);
        let net = LayerStack::single(RnnCell::gated_tanh(5, 2, None, &mut rng));
        let seq: Vec<[f32; 2]> = (0..4).map(|_| [rng.normal(), rng.normal()]).collect();

        let run_exact = || {
            let mut rr = Pcg64::new(7);
            let mut readout = Readout::new(2, 5, &mut rr);
            let mut loss = Loss::new(LossKind::CrossEntropy, 2);
            let mut ops = OpCounter::new();
            let mut eng = build_engine(AlgorithmKind::RtrlDense, &net, 2);
            eng.begin_sequence();
            for (t, x) in seq.iter().enumerate() {
                let tg = if t + 1 == seq.len() { Target::Class(1) } else { Target::None };
                eng.step(&net, &mut readout, &mut loss, x, tg, &mut ops);
            }
            eng.grads().to_vec()
        };
        let exact = run_exact();

        let trials = 4000;
        let mut mean = vec![0.0f64; net.p()];
        for trial in 0..trials {
            let mut rr = Pcg64::new(7);
            let mut readout = Readout::new(2, 5, &mut rr);
            let mut loss = Loss::new(LossKind::CrossEntropy, 2);
            let mut ops = OpCounter::new();
            let mut eng = Uoro::new(&net, 2, 1000 + trial);
            eng.begin_sequence();
            for (t, x) in seq.iter().enumerate() {
                let tg = if t + 1 == seq.len() { Target::Class(1) } else { Target::None };
                eng.step(&net, &mut readout, &mut loss, x, tg, &mut ops);
            }
            for (m, g) in mean.iter_mut().zip(eng.grads()) {
                *m += *g as f64 / trials as f64;
            }
        }
        // cosine similarity of the averaged stochastic gradient with exact
        let dot: f64 = mean.iter().zip(&exact).map(|(m, e)| m * *e as f64).sum();
        let nm: f64 = mean.iter().map(|m| m * m).sum::<f64>().sqrt();
        let ne: f64 = exact.iter().map(|e| (*e as f64).powi(2)).sum::<f64>().sqrt();
        let cos = dot / (nm * ne + 1e-12);
        assert!(cos > 0.9, "E[UORO grad] should align with exact: cos={cos:.3}");
    }

    /// Single draws are noisy (that is UORO's trade-off).
    #[test]
    fn single_draw_is_noisy() {
        let mut rng = Pcg64::new(71);
        let net = LayerStack::single(RnnCell::gated_tanh(5, 2, None, &mut rng));
        let x = [[0.3f32, -0.2], [0.8, 0.1], [-0.4, 0.6]];
        let one = |seed: u64| {
            let mut rr = Pcg64::new(7);
            let mut readout = Readout::new(2, 5, &mut rr);
            let mut loss = Loss::new(LossKind::CrossEntropy, 2);
            let mut ops = OpCounter::new();
            let mut eng = Uoro::new(&net, 2, seed);
            eng.begin_sequence();
            for (t, xi) in x.iter().enumerate() {
                let tg = if t == 2 { Target::Class(0) } else { Target::None };
                eng.step(&net, &mut readout, &mut loss, xi, tg, &mut ops);
            }
            eng.grads().to_vec()
        };
        let a = one(1);
        let b = one(2);
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-4, "different noise draws must differ");
    }

    /// UORO is much cheaper per step than exact dense RTRL.
    #[test]
    fn cheaper_than_dense() {
        let mut rng = Pcg64::new(72);
        let net = LayerStack::single(RnnCell::egru(16, 2, 0.1, 0.3, 0.5, None, &mut rng));
        let mut readout = Readout::new(2, 16, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut run = |eng: &mut dyn GradientEngine| {
            let mut ops = OpCounter::new();
            eng.begin_sequence();
            let mut xr = Pcg64::new(5);
            for _ in 0..10 {
                let x = [xr.normal(), xr.normal()];
                eng.step(&net, &mut readout, &mut loss, &x, Target::None, &mut ops);
            }
            ops.macs_in(Phase::InfluenceUpdate)
        };
        let dense = run(&mut *build_engine(AlgorithmKind::RtrlDense, &net, 2));
        let uoro = run(&mut Uoro::new(&net, 2, 3));
        assert!(uoro * 10 < dense, "uoro {uoro} should be ≫ cheaper than dense {dense}");
    }

    /// Memory is O(N + P), below every exact RTRL variant.
    #[test]
    fn memory_is_linear() {
        let mut rng = Pcg64::new(73);
        let net = LayerStack::single(RnnCell::egru(16, 2, 0.1, 0.3, 0.5, None, &mut rng));
        let uoro = Uoro::new(&net, 2, 1);
        let dense = build_engine(AlgorithmKind::RtrlDense, &net, 2);
        assert!(uoro.state_memory_words() < dense.state_memory_words() / 4);
    }

    /// Depth 2: the stacked forward/backward substitutions keep UORO
    /// unbiased — mean over draws aligns with the exact stacked gradient.
    #[test]
    fn depth2_unbiased_in_expectation() {
        let mut rng = Pcg64::new(74);
        let l0 = RnnCell::gated_tanh(4, 2, None, &mut rng);
        let l1 = RnnCell::gated_tanh(3, 4, None, &mut rng);
        let net = LayerStack::new(vec![l0, l1]);
        let seq: Vec<[f32; 2]> = (0..3).map(|_| [rng.normal(), rng.normal()]).collect();
        let run = |eng: &mut dyn GradientEngine| {
            let mut rr = Pcg64::new(7);
            let mut readout = Readout::new(2, 3, &mut rr);
            let mut loss = Loss::new(LossKind::CrossEntropy, 2);
            let mut ops = OpCounter::new();
            eng.begin_sequence();
            for (t, x) in seq.iter().enumerate() {
                let tg = if t + 1 == seq.len() { Target::Class(1) } else { Target::None };
                eng.step(&net, &mut readout, &mut loss, x, tg, &mut ops);
            }
            eng.grads().to_vec()
        };
        let exact = run(&mut *build_engine(AlgorithmKind::RtrlDense, &net, 2));
        let trials = 3000u64;
        let mut mean = vec![0.0f64; net.p()];
        for trial in 0..trials {
            let g = run(&mut Uoro::new(&net, 2, 9000 + trial));
            for (m, v) in mean.iter_mut().zip(&g) {
                *m += *v as f64 / trials as f64;
            }
        }
        let dot: f64 = mean.iter().zip(&exact).map(|(m, e)| m * *e as f64).sum();
        let nm: f64 = mean.iter().map(|m| m * m).sum::<f64>().sqrt();
        let ne: f64 = exact.iter().map(|e| (*e as f64).powi(2)).sum::<f64>().sqrt();
        let cos = dot / (nm * ne + 1e-12);
        assert!(cos > 0.8, "E[UORO] should align with stacked exact: cos={cos:.3}");
    }
}
