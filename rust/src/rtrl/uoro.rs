//! UORO — Unbiased Online Recurrent Optimization (Tallec & Ollivier 2017),
//! the classic *stochastic* rank-1 RTRL approximation, included as a third
//! comparison point alongside SnAp (Marschall et al. 2020 situate both in
//! the same framework the paper builds on).
//!
//! The influence matrix is approximated by a rank-1 outer product
//! `M ≈ s̃ ⊗ θ̃` with `s̃ ∈ R^n`, `θ̃ ∈ R^p`, updated with random signs
//! `ν ∈ {±1}^n` and variance-balancing scales `ρ₀, ρ₁`:
//!
//! ```text
//! s̃ ← ρ₀·J s̃ + ρ₁·ν           θ̃ ← θ̃/ρ₀ + (νᵀ M̄)/ρ₁
//! ```
//!
//! which keeps `E[s̃ ⊗ θ̃] = M` (unbiased) at `O(n² + p)` per step — far
//! cheaper than exact RTRL but with gradient *variance* that exact sparse
//! RTRL does not pay. This is the contrast the paper draws: its savings are
//! free of both bias (SnAp) and variance (UORO).

use super::{supervised_step, GradientEngine, StepResult, Target};
use crate::metrics::{OpCounter, Phase};
use crate::nn::{CellScratch, Loss, Readout, RnnCell};
use crate::util::Pcg64;

/// UORO engine (per-sequence state; reusable).
pub struct Uoro {
    /// Rank-1 state factor s̃.
    s_tilde: Vec<f32>,
    /// Rank-1 parameter factor θ̃.
    theta_tilde: Vec<f32>,
    scratch: CellScratch,
    a_prev: Vec<f32>,
    grads: Vec<f32>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    c_bar: Vec<f32>,
    /// staging for J·s̃ and νᵀM̄
    js: Vec<f32>,
    nu_mbar: Vec<f32>,
    rng: Pcg64,
}

impl Uoro {
    pub fn new(cell: &RnnCell, readout_n_out: usize, seed: u64) -> Self {
        let (n, p) = (cell.n(), cell.p());
        Uoro {
            s_tilde: vec![0.0; n],
            theta_tilde: vec![0.0; p],
            scratch: CellScratch::new(n),
            a_prev: vec![0.0; n],
            grads: vec![0.0; p],
            logits: vec![0.0; readout_n_out],
            dlogits: vec![0.0; readout_n_out],
            c_bar: vec![0.0; n],
            js: vec![0.0; n],
            nu_mbar: vec![0.0; p],
            rng: Pcg64::new(seed),
        }
    }
}

impl GradientEngine for Uoro {
    fn name(&self) -> &'static str {
        "uoro"
    }

    fn begin_sequence(&mut self) {
        self.s_tilde.iter_mut().for_each(|x| *x = 0.0);
        self.theta_tilde.iter_mut().for_each(|x| *x = 0.0);
        self.a_prev.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn step(
        &mut self,
        cell: &RnnCell,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult {
        let n = cell.n();
        let p = cell.p();
        cell.forward(&self.a_prev, x, &mut self.scratch, ops);
        let active_units = self.scratch.active_units();
        let deriv_units = self.scratch.deriv_units();

        // J·s̃ with J = φ' ⊙ dv_da (sparse over kept cols)
        let mut macs = 0u64;
        for k in 0..n {
            let dphi_k = self.scratch.dphi[k];
            let mut acc = 0.0;
            if dphi_k != 0.0 {
                for &l in cell.kept_cols(k) {
                    acc += cell.dv_da(&self.scratch, k, l as usize) * self.s_tilde[l as usize];
                }
                macs += cell.kept_cols(k).len() as u64 * (cell.dv_da_cost() + 1);
            }
            self.js[k] = dphi_k * acc;
        }
        // νᵀ M̄ (ν broadcast through each unit's fan-in rows)
        self.nu_mbar.iter_mut().for_each(|v| *v = 0.0);
        let mut nu = vec![0.0f32; n];
        for k in 0..n {
            nu[k] = if self.rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        }
        for k in 0..n {
            let dphi_k = self.scratch.dphi[k];
            if dphi_k == 0.0 {
                continue;
            }
            let nk = nu[k] * dphi_k;
            let nu_mbar = &mut self.nu_mbar;
            cell.immediate_row(
                &self.scratch,
                &self.a_prev,
                x,
                k,
                |pi, val| nu_mbar[pi] += nk * val,
                ops,
            );
        }
        // variance-balancing scales
        let norm_js = self.js.iter().map(|v| v * v).sum::<f32>().sqrt();
        let norm_tt = self.theta_tilde.iter().map(|v| v * v).sum::<f32>().sqrt();
        let norm_nm = self.nu_mbar.iter().map(|v| v * v).sum::<f32>().sqrt();
        let eps = 1e-7;
        let rho0 = ((norm_tt + eps) / (norm_js + eps)).sqrt();
        let rho1 = ((norm_nm + eps) / ((n as f32).sqrt() + eps)).sqrt();
        for k in 0..n {
            self.s_tilde[k] = rho0 * self.js[k] + rho1 * nu[k];
        }
        for pi in 0..p {
            self.theta_tilde[pi] = self.theta_tilde[pi] / rho0 + self.nu_mbar[pi] / rho1;
        }
        macs += (2 * p + 2 * n) as u64;
        ops.macs(Phase::InfluenceUpdate, macs);

        let (loss_val, correct) = supervised_step(
            readout,
            loss,
            &self.scratch.a,
            target,
            &mut self.logits,
            &mut self.dlogits,
            &mut self.c_bar,
            ops,
        );
        if loss_val.is_some() {
            // grad += (c̄ · s̃) θ̃
            let coef: f32 = self.c_bar.iter().zip(&self.s_tilde).map(|(c, s)| c * s).sum();
            if coef != 0.0 {
                for (g, t) in self.grads.iter_mut().zip(&self.theta_tilde) {
                    *g += coef * t;
                }
                ops.macs(Phase::GradCombine, p as u64);
            }
        }

        self.a_prev.copy_from_slice(&self.scratch.a);
        StepResult { loss: loss_val, correct, active_units, deriv_units, influence_sparsity: None }
    }

    fn end_sequence(&mut self, _cell: &RnnCell, _readout: &mut Readout, _ops: &mut OpCounter) {}

    fn grads(&self) -> &[f32] {
        &self.grads
    }

    fn reset_grads(&mut self) {
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn state_memory_words(&self) -> usize {
        // s̃ + θ̃ + staging — the O(n + p) memory row
        self.s_tilde.len() + 2 * self.theta_tilde.len() + self.js.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgorithmKind;
    use crate::nn::LossKind;
    use crate::train::build_engine;

    /// E[ĝ] over noise draws must approach the exact gradient (unbiasedness).
    #[test]
    fn unbiased_in_expectation() {
        let mut rng = Pcg64::new(70);
        let cell = RnnCell::gated_tanh(5, 2, None, &mut rng);
        let seq: Vec<[f32; 2]> = (0..4).map(|_| [rng.normal(), rng.normal()]).collect();

        let run_exact = || {
            let mut rr = Pcg64::new(7);
            let mut readout = Readout::new(2, 5, &mut rr);
            let mut loss = Loss::new(LossKind::CrossEntropy, 2);
            let mut ops = OpCounter::new();
            let mut eng = build_engine(AlgorithmKind::RtrlDense, &cell, 2);
            eng.begin_sequence();
            for (t, x) in seq.iter().enumerate() {
                let tg = if t + 1 == seq.len() { Target::Class(1) } else { Target::None };
                eng.step(&cell, &mut readout, &mut loss, x, tg, &mut ops);
            }
            eng.grads().to_vec()
        };
        let exact = run_exact();

        let trials = 4000;
        let mut mean = vec![0.0f64; cell.p()];
        for trial in 0..trials {
            let mut rr = Pcg64::new(7);
            let mut readout = Readout::new(2, 5, &mut rr);
            let mut loss = Loss::new(LossKind::CrossEntropy, 2);
            let mut ops = OpCounter::new();
            let mut eng = Uoro::new(&cell, 2, 1000 + trial);
            eng.begin_sequence();
            for (t, x) in seq.iter().enumerate() {
                let tg = if t + 1 == seq.len() { Target::Class(1) } else { Target::None };
                eng.step(&cell, &mut readout, &mut loss, x, tg, &mut ops);
            }
            for (m, g) in mean.iter_mut().zip(eng.grads()) {
                *m += *g as f64 / trials as f64;
            }
        }
        // cosine similarity of the averaged stochastic gradient with exact
        let dot: f64 = mean.iter().zip(&exact).map(|(m, e)| m * *e as f64).sum();
        let nm: f64 = mean.iter().map(|m| m * m).sum::<f64>().sqrt();
        let ne: f64 = exact.iter().map(|e| (*e as f64).powi(2)).sum::<f64>().sqrt();
        let cos = dot / (nm * ne + 1e-12);
        assert!(cos > 0.9, "E[UORO grad] should align with exact: cos={cos:.3}");
    }

    /// Single draws are noisy (that is UORO's trade-off).
    #[test]
    fn single_draw_is_noisy() {
        let mut rng = Pcg64::new(71);
        let cell = RnnCell::gated_tanh(5, 2, None, &mut rng);
        let x = [[0.3f32, -0.2], [0.8, 0.1], [-0.4, 0.6]];
        let one = |seed: u64| {
            let mut rr = Pcg64::new(7);
            let mut readout = Readout::new(2, 5, &mut rr);
            let mut loss = Loss::new(LossKind::CrossEntropy, 2);
            let mut ops = OpCounter::new();
            let mut eng = Uoro::new(&cell, 2, seed);
            eng.begin_sequence();
            for (t, xi) in x.iter().enumerate() {
                let tg = if t == 2 { Target::Class(0) } else { Target::None };
                eng.step(&cell, &mut readout, &mut loss, xi, tg, &mut ops);
            }
            eng.grads().to_vec()
        };
        let a = one(1);
        let b = one(2);
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-4, "different noise draws must differ");
    }

    /// UORO is much cheaper per step than exact dense RTRL.
    #[test]
    fn cheaper_than_dense() {
        let mut rng = Pcg64::new(72);
        let cell = RnnCell::egru(16, 2, 0.1, 0.3, 0.5, None, &mut rng);
        let mut readout = Readout::new(2, 16, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut run = |eng: &mut dyn GradientEngine| {
            let mut ops = OpCounter::new();
            eng.begin_sequence();
            let mut xr = Pcg64::new(5);
            for _ in 0..10 {
                let x = [xr.normal(), xr.normal()];
                eng.step(&cell, &mut readout, &mut loss, &x, Target::None, &mut ops);
            }
            ops.macs_in(Phase::InfluenceUpdate)
        };
        let dense = run(&mut *build_engine(AlgorithmKind::RtrlDense, &cell, 2));
        let uoro = run(&mut Uoro::new(&cell, 2, 3));
        assert!(uoro * 10 < dense, "uoro {uoro} should be ≫ cheaper than dense {dense}");
    }

    /// Memory is O(n + p), below every exact RTRL variant.
    #[test]
    fn memory_is_linear() {
        let mut rng = Pcg64::new(73);
        let cell = RnnCell::egru(16, 2, 0.1, 0.3, 0.5, None, &mut rng);
        let uoro = Uoro::new(&cell, 2, 1);
        let dense = build_engine(AlgorithmKind::RtrlDense, &cell, 2);
        assert!(uoro.state_memory_words() < dense.state_memory_words() / 4);
    }
}
