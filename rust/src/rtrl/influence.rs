//! Ping-pong influence-matrix buffers with active-row tracking — one
//! [`InfluenceBuffers`] per layer, collected in a [`StackedInfluence`].
//!
//! `M^{(t)}` has `β̃^{(t)}n` nonzero rows (paper Eq. 10). The buffers hold
//! two `n × pc` panels (current and next) plus the active-row set of each;
//! rows outside a panel's active set are *logically zero* and are never read
//! or written, which is exactly how the `β̃²` factor arises: the gather for
//! a new row touches only prev-active rows, and only deriv-active rows are
//! produced.
//!
//! In a stack, layer `l`'s panel is `n_l × cum_pc(l)` — its columns span
//! the compact columns of layers `0..=l` only, never the structurally-zero
//! blocks for deeper layers (see `rtrl::column_map::StackColumnMap`). The
//! cross-layer term of the block recursion reads layer `l−1`'s **next**
//! panel (already written this step) and accumulates into the leading
//! `cum_pc(l−1)` slice of layer `l`'s next row; [`StackedInfluence`]
//! hands out exactly that disjoint pair of borrows.

use crate::sparse::RowSet;
use crate::tensor::Matrix;

/// Double-buffered row-sparse influence matrix.
#[derive(Debug, Clone)]
pub struct InfluenceBuffers {
    cur: Matrix,
    next: Matrix,
    active_cur: RowSet,
    active_next: RowSet,
}

impl InfluenceBuffers {
    pub fn new(n: usize, pc: usize) -> Self {
        InfluenceBuffers {
            cur: Matrix::zeros(n, pc),
            next: Matrix::zeros(n, pc),
            active_cur: RowSet::empty(n),
            active_next: RowSet::empty(n),
        }
    }

    /// Reset to `M = 0` (start of sequence).
    pub fn reset(&mut self) {
        // Logical zero via empty active sets; buffers are lazily overwritten.
        self.active_cur.clear();
        self.active_next.clear();
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.cur.rows()
    }

    #[inline]
    pub fn pc(&self) -> usize {
        self.cur.cols()
    }

    /// Current panel's active rows (nonzero rows of `M^{(t-1)}`).
    #[inline]
    pub fn active_cur(&self) -> &RowSet {
        &self.active_cur
    }

    /// Row of the current panel (caller must ensure `k ∈ active_cur`).
    #[inline]
    pub fn cur_row(&self, k: usize) -> &[f32] {
        self.cur.row(k)
    }

    /// Begin writing the next panel: clears its active set.
    pub fn begin_next(&mut self) {
        self.active_next.clear();
    }

    /// Claim row `k` of the next panel for writing; marks it active and
    /// returns the (stale — caller overwrites) row buffer.
    #[inline]
    pub fn claim_next_row(&mut self, k: usize) -> &mut [f32] {
        self.active_next.insert(k);
        self.next.row_mut(k)
    }

    /// Mark row `k` active in the next panel *without* borrowing its
    /// buffer. The parallel panel update claims all rows first (serially,
    /// in ascending order, so the active set is identical to the serial
    /// path's), then splits the panel into disjoint `&mut` row slices via
    /// [`Self::split_cur_next`].
    #[inline]
    pub fn mark_next_active(&mut self, k: usize) {
        self.active_next.insert(k);
    }

    /// Disjoint borrow of `(current panel read-only, next panel writable)`
    /// — the borrow shape of the intra-step row update: every row job reads
    /// the shared previous panel and writes its own next-panel row.
    #[inline]
    pub fn split_cur_next(&mut self) -> (&Matrix, &mut Matrix) {
        (&self.cur, &mut self.next)
    }

    /// Read access to a just-written next-panel row (gradient accumulation).
    #[inline]
    pub fn next_row(&self, k: usize) -> &[f32] {
        self.next.row(k)
    }

    /// Next panel's active rows.
    #[inline]
    pub fn active_next(&self) -> &RowSet {
        &self.active_next
    }

    /// Rotate: next becomes current.
    pub fn advance(&mut self) {
        std::mem::swap(&mut self.cur, &mut self.next);
        std::mem::swap(&mut self.active_cur, &mut self.active_next);
    }

    /// Nonzero entries in the *next* panel (inactive rows count as zero).
    pub fn next_nonzero_count(&self) -> usize {
        let mut nonzero = 0usize;
        for k in self.active_next.iter() {
            nonzero += self.next.row(k).iter().filter(|&&x| x != 0.0).count();
        }
        nonzero
    }

    /// Exact zero fraction of the stored `M^{(t)}` panel (the *next* panel
    /// if called between write and advance). Callers with column compaction
    /// should rescale to the logical `n×p` via [`Self::next_nonzero_count`].
    pub fn next_zero_fraction(&self) -> f32 {
        let total = (self.n() * self.pc()) as f64;
        if total == 0.0 {
            return 1.0;
        }
        (1.0 - self.next_nonzero_count() as f64 / total) as f32
    }

    /// Memory words held (both panels — the Table-1 memory column measures
    /// the live footprint of the method).
    pub fn memory_words(&self) -> usize {
        self.cur.len() + self.next.len()
    }

    /// Snapshot the *current* panel between steps: the active row indices
    /// and their values, concatenated in active-set order. Inactive rows are
    /// logically zero and are not stored; the stale next panel is never read
    /// before being rewritten, so it is not part of the state.
    pub fn snapshot_cur(&self) -> (Vec<u64>, Vec<f32>) {
        let mut rows = Vec::with_capacity(self.active_cur.len());
        let mut vals = Vec::with_capacity(self.active_cur.len() * self.pc());
        for k in self.active_cur.iter() {
            rows.push(k as u64);
            vals.extend_from_slice(self.cur.row(k));
        }
        (rows, vals)
    }

    /// Restore a [`InfluenceBuffers::snapshot_cur`] snapshot: the current
    /// panel holds exactly the given active rows (everything else zero) and
    /// the next panel is reset. Errors on out-of-range rows or a value
    /// buffer that does not match `rows.len() × pc`.
    pub fn restore_cur(&mut self, rows: &[u64], vals: &[f32]) -> Result<(), String> {
        let pc = self.pc();
        if vals.len() != rows.len() * pc {
            return Err(format!(
                "influence snapshot holds {} values for {} rows × {pc} cols",
                vals.len(),
                rows.len()
            ));
        }
        self.cur.fill_zero();
        self.next.fill_zero();
        self.active_cur.clear();
        self.active_next.clear();
        for (i, &r) in rows.iter().enumerate() {
            let r = r as usize;
            if r >= self.n() {
                return Err(format!("influence snapshot row {r} out of range (n={})", self.n()));
            }
            self.active_cur.insert(r);
            self.cur.row_mut(r).copy_from_slice(&vals[i * pc..(i + 1) * pc]);
        }
        Ok(())
    }
}

/// Per-layer influence buffers for a stacked network.
#[derive(Debug, Clone)]
pub struct StackedInfluence {
    layers: Vec<InfluenceBuffers>,
}

impl StackedInfluence {
    /// `dims[l] = (n_l, panel_cols_l)` where `panel_cols_l` is the
    /// cumulative compact-column count of layers `0..=l`.
    pub fn new(dims: &[(usize, usize)]) -> Self {
        StackedInfluence {
            layers: dims.iter().map(|&(n, pc)| InfluenceBuffers::new(n, pc)).collect(),
        }
    }

    #[inline]
    pub fn layers(&self) -> usize {
        self.layers.len()
    }

    #[inline]
    pub fn layer(&self, l: usize) -> &InfluenceBuffers {
        &self.layers[l]
    }

    #[inline]
    pub fn layer_mut(&mut self, l: usize) -> &mut InfluenceBuffers {
        &mut self.layers[l]
    }

    /// Disjoint borrow of `(layer l−1 readable, layer l writable)` — the
    /// cross-layer gather pattern. Layer 0 has no lower layer.
    #[inline]
    pub fn lower_and_current(&mut self, l: usize) -> (Option<&InfluenceBuffers>, &mut InfluenceBuffers) {
        if l == 0 {
            (None, &mut self.layers[0])
        } else {
            let (lo, hi) = self.layers.split_at_mut(l);
            (Some(&lo[l - 1]), &mut hi[0])
        }
    }

    /// Reset every panel to `M = 0` (start of sequence).
    pub fn reset(&mut self) {
        self.layers.iter_mut().for_each(InfluenceBuffers::reset);
    }

    /// Begin a new step: clear every layer's next-panel active set.
    pub fn begin_next(&mut self) {
        self.layers.iter_mut().for_each(InfluenceBuffers::begin_next);
    }

    /// Rotate every layer: next becomes current.
    pub fn advance(&mut self) {
        self.layers.iter_mut().for_each(InfluenceBuffers::advance);
    }

    /// Σ memory words across layer panels (Table-1 memory column).
    pub fn memory_words(&self) -> usize {
        self.layers.iter().map(InfluenceBuffers::memory_words).sum()
    }

    /// Σ nonzero entries in the next panels (stored blocks only — the
    /// never-materialized cross-layer blocks are zero by construction).
    pub fn next_nonzero_total(&self) -> usize {
        self.layers.iter().map(InfluenceBuffers::next_nonzero_count).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_empties_active_sets() {
        let mut b = InfluenceBuffers::new(4, 10);
        b.begin_next();
        b.claim_next_row(2).iter_mut().for_each(|x| *x = 1.0);
        b.advance();
        assert_eq!(b.active_cur().len(), 1);
        b.reset();
        assert_eq!(b.active_cur().len(), 0);
        assert_eq!(b.active_next().len(), 0);
    }

    #[test]
    fn claim_write_advance_read() {
        let mut b = InfluenceBuffers::new(3, 4);
        b.begin_next();
        let row = b.claim_next_row(1);
        row.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        b.advance();
        assert!(b.active_cur().contains(1));
        assert_eq!(b.cur_row(1), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn zero_fraction_counts_inactive_rows_as_zero() {
        let mut b = InfluenceBuffers::new(4, 4);
        b.begin_next();
        let row = b.claim_next_row(0);
        row.copy_from_slice(&[1.0, 0.0, 2.0, 0.0]);
        // 2 nonzero out of 16 logical entries
        assert!((b.next_zero_fraction() - 14.0 / 16.0).abs() < 1e-6);
    }

    #[test]
    fn stacked_buffers_expose_disjoint_lower_and_current() {
        let mut s = StackedInfluence::new(&[(3, 4), (2, 10)]);
        assert_eq!(s.layers(), 2);
        assert_eq!(s.layer(0).pc(), 4);
        assert_eq!(s.layer(1).pc(), 10);
        s.begin_next();
        s.layer_mut(0).claim_next_row(1).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        {
            let (lower, cur) = s.lower_and_current(1);
            let lower = lower.expect("layer 1 has a lower layer");
            assert!(lower.active_next().contains(1));
            // cross-layer accumulate into the 4-column prefix of layer 1's row
            let row = cur.claim_next_row(0);
            for (r, v) in row[..4].iter_mut().zip(lower.next_row(1)) {
                *r = 2.0 * v;
            }
        }
        assert_eq!(&s.layer(1).next_row(0)[..4], &[2.0, 4.0, 6.0, 8.0]);
        let (lower, _) = s.lower_and_current(0);
        assert!(lower.is_none());
        assert_eq!(s.memory_words(), 2 * (3 * 4) + 2 * (2 * 10));
        s.advance();
        assert!(s.layer(0).active_cur().contains(1));
    }

    #[test]
    fn snapshot_restore_roundtrips_active_rows() {
        let mut b = InfluenceBuffers::new(4, 3);
        b.begin_next();
        b.claim_next_row(1).copy_from_slice(&[1.0, 2.0, 3.0]);
        b.claim_next_row(3).copy_from_slice(&[-4.0, 5.0, 0.5]);
        b.advance();
        let (rows, vals) = b.snapshot_cur();
        assert_eq!(rows, vec![1, 3]);
        assert_eq!(vals.len(), 6);
        let mut c = InfluenceBuffers::new(4, 3);
        c.restore_cur(&rows, &vals).unwrap();
        assert!(c.active_cur().contains(1) && c.active_cur().contains(3));
        assert!(!c.active_cur().contains(0));
        assert_eq!(c.cur_row(1), &[1.0, 2.0, 3.0]);
        assert_eq!(c.cur_row(3), &[-4.0, 5.0, 0.5]);
        // malformed snapshots are rejected
        assert!(c.restore_cur(&[9], &[0.0; 3]).is_err());
        assert!(c.restore_cur(&[1], &[0.0; 2]).is_err());
    }

    #[test]
    fn stale_rows_are_not_readable_via_active_set() {
        let mut b = InfluenceBuffers::new(2, 2);
        b.begin_next();
        b.claim_next_row(0).copy_from_slice(&[5.0, 5.0]);
        b.advance();
        // next step: row 0 not claimed
        b.begin_next();
        b.claim_next_row(1).copy_from_slice(&[7.0, 7.0]);
        b.advance();
        assert!(!b.active_cur().contains(0));
        assert!(b.active_cur().contains(1));
    }
}
