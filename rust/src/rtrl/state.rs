//! Versioned engine-state snapshots — the save/restore half of the
//! [`GradientEngine`](crate::rtrl::GradientEngine) streaming contract.
//!
//! An [`EngineState`] is a flat, schema-light container: an engine name, a
//! state-format version, and named `u64` / `f32` buffers. Each engine owns
//! its key layout (influence panels for RTRL, rank-1 vectors plus the noise
//! RNG for UORO, pattern slabs for SnAp, the stored tape for BPTT) and bumps
//! its version when that layout changes, so a checkpoint written by an old
//! build fails loudly on restore instead of silently misloading.
//!
//! The contract (pinned by `rust/tests/engine_contract.rs`): a snapshot
//! taken **between steps** and restored into a freshly-built engine of the
//! same configuration continues the sequence with **bit-identical**
//! gradients and predictions — floats are carried verbatim, never
//! re-derived, and stochastic engines include their RNG stream position.
//! Serialization to disk (with exact f32-bit encoding) lives in
//! [`crate::session::checkpoint`]; this module is the in-memory form.

use std::collections::BTreeMap;
use std::fmt;

/// Restore failure: wrong engine, wrong version, missing key, or a buffer
/// whose length does not match the live engine's configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StateError(pub String);

impl fmt::Display for StateError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "engine state: {}", self.0)
    }
}

impl std::error::Error for StateError {}

/// A named-buffer snapshot of one engine's sequence state.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EngineState {
    /// Engine name the snapshot belongs to (must match on restore).
    pub engine: String,
    /// Engine-specific state-format version (must match on restore).
    pub version: u32,
    ints: BTreeMap<String, Vec<u64>>,
    floats: BTreeMap<String, Vec<f32>>,
}

impl EngineState {
    pub fn new(engine: &str, version: u32) -> Self {
        EngineState {
            engine: engine.to_string(),
            version,
            ints: BTreeMap::new(),
            floats: BTreeMap::new(),
        }
    }

    /// Store an integer buffer under `key`.
    pub fn put_ints(&mut self, key: &str, v: Vec<u64>) {
        self.ints.insert(key.to_string(), v);
    }

    /// Store a single integer under `key`.
    pub fn put_scalar(&mut self, key: &str, v: u64) {
        self.put_ints(key, vec![v]);
    }

    /// Store a float buffer under `key`.
    pub fn put_floats(&mut self, key: &str, v: Vec<f32>) {
        self.floats.insert(key.to_string(), v);
    }

    /// Integer buffer under `key`.
    pub fn ints(&self, key: &str) -> Result<&[u64], StateError> {
        self.ints
            .get(key)
            .map(Vec::as_slice)
            .ok_or_else(|| StateError(format!("missing int buffer {key:?}")))
    }

    /// Single integer under `key`.
    pub fn scalar(&self, key: &str) -> Result<u64, StateError> {
        let v = self.ints(key)?;
        if v.len() != 1 {
            return Err(StateError(format!("{key:?} holds {} ints, expected 1", v.len())));
        }
        Ok(v[0])
    }

    /// Float buffer under `key`.
    pub fn floats(&self, key: &str) -> Result<&[f32], StateError> {
        self.floats
            .get(key)
            .map(Vec::as_slice)
            .ok_or_else(|| StateError(format!("missing float buffer {key:?}")))
    }

    /// Float buffer under `key`, checked against the length the live engine
    /// requires — a mismatch means the snapshot came from a differently
    /// configured engine.
    pub fn floats_exact(&self, key: &str, len: usize) -> Result<&[f32], StateError> {
        let v = self.floats(key)?;
        if v.len() != len {
            return Err(StateError(format!(
                "{key:?} holds {} floats, engine expects {len}",
                v.len()
            )));
        }
        Ok(v)
    }

    /// All integer buffers, key-sorted (serialization).
    pub fn int_entries(&self) -> impl Iterator<Item = (&str, &[u64])> {
        self.ints.iter().map(|(k, v)| (k.as_str(), v.as_slice()))
    }

    /// All float buffers, key-sorted (serialization).
    pub fn float_entries(&self) -> impl Iterator<Item = (&str, &[f32])> {
        self.floats.iter().map(|(k, v)| (k.as_str(), v.as_slice()))
    }

    /// Verify the snapshot header matches the restoring engine.
    pub fn require(&self, engine: &str, version: u32) -> Result<(), StateError> {
        if self.engine != engine {
            return Err(StateError(format!(
                "snapshot is for engine {:?}, cannot restore into {engine:?}",
                self.engine
            )));
        }
        if self.version != version {
            return Err(StateError(format!(
                "snapshot version {} ≠ engine state version {version} for {engine:?}",
                self.version
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let mut st = EngineState::new("rtrl-both", 1);
        st.put_floats("a", vec![1.0, -2.5]);
        st.put_ints("rows", vec![0, 3, 5]);
        st.put_scalar("layers", 2);
        assert_eq!(st.floats("a").unwrap(), &[1.0, -2.5]);
        assert_eq!(st.ints("rows").unwrap(), &[0, 3, 5]);
        assert_eq!(st.scalar("layers").unwrap(), 2);
        assert_eq!(st.floats_exact("a", 2).unwrap().len(), 2);
        assert!(st.floats_exact("a", 3).is_err());
        assert!(st.floats("missing").is_err());
        assert!(st.scalar("rows").is_err());
    }

    #[test]
    fn header_mismatches_are_loud() {
        let st = EngineState::new("uoro", 1);
        assert!(st.require("uoro", 1).is_ok());
        let e = st.require("bptt", 1).unwrap_err();
        assert!(e.to_string().contains("uoro"), "{e}");
        let e = st.require("uoro", 2).unwrap_err();
        assert!(e.to_string().contains("version"), "{e}");
    }

    #[test]
    fn entries_iterate_sorted() {
        let mut st = EngineState::new("x", 1);
        st.put_floats("b", vec![]);
        st.put_floats("a", vec![1.0]);
        let keys: Vec<&str> = st.float_entries().map(|(k, _)| k).collect();
        assert_eq!(keys, ["a", "b"]);
    }
}
