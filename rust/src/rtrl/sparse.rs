//! The paper's contribution: **exact** RTRL exploiting activity and/or
//! parameter sparsity.
//!
//! One engine covers the three sparse rows of Table 1 via [`SparsityMode`]:
//!
//! * `Activity` — rows of `J`/`M̄`/`M` with `φ'(v_k)=0` are skipped; the
//!   gather touches only rows active at `t−1` → `O(β̃^{(t)}β̃^{(t-1)}n²p)`.
//! * `Parameter` — masked recurrent params drop columns of `M`/`M̄` (compact
//!   storage) and elements of `J` → `O(ω̃²n²p)`.
//! * `Both` — the combination → `O(ω̃²β̃²n²p)` (paper §5).
//!
//! No approximation anywhere: skipped work is *structurally zero*, so the
//! gradient equals dense RTRL / BPTT bit-for-bit up to FP reassociation
//! (enforced by `rust/tests/sparse_exactness.rs`).

use super::column_map::ColumnMap;
use super::influence::InfluenceBuffers;
use super::{supervised_step, GradientEngine, StepResult, Target};
use crate::metrics::{OpCounter, Phase};
use crate::nn::{CellScratch, Loss, Readout, RnnCell};

/// Which structural zeros the engine exploits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparsityMode {
    /// Activity sparsity only (Table 1 row "with activity sparsity").
    Activity,
    /// Parameter sparsity only (row "with parameter sparsity").
    Parameter,
    /// Both (row "with both").
    Both,
}

impl SparsityMode {
    fn use_activity(self) -> bool {
        matches!(self, SparsityMode::Activity | SparsityMode::Both)
    }

    fn use_columns(self) -> bool {
        matches!(self, SparsityMode::Parameter | SparsityMode::Both)
    }
}

/// Exact sparse RTRL engine (per-sequence state; reusable across sequences).
pub struct SparseRtrl {
    mode: SparsityMode,
    colmap: ColumnMap,
    buffers: InfluenceBuffers,
    scratch: CellScratch,
    a_prev: Vec<f32>,
    /// Jacobian row staging: `(l, ∂v_k/∂a_l)` pairs for the current row.
    jlist: Vec<(u32, f32)>,
    /// Gradient accumulator over compact columns (scattered at end).
    grad_compact: Vec<f32>,
    /// Dense `R^p` gradient view (valid after `end_sequence`).
    grads: Vec<f32>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    c_bar: Vec<f32>,
    measure_influence: bool,
}

impl SparseRtrl {
    /// Build for a cell. `Parameter`/`Both` modes compact columns using the
    /// cell's mask (a dense cell degrades gracefully to full columns).
    pub fn new(cell: &RnnCell, readout_n_out: usize, mode: SparsityMode) -> Self {
        let n = cell.n();
        let p = cell.p();
        let colmap = if mode.use_columns() {
            ColumnMap::from_cell(cell)
        } else {
            ColumnMap::full(p)
        };
        let pc = colmap.len();
        SparseRtrl {
            mode,
            colmap,
            buffers: InfluenceBuffers::new(n, pc),
            scratch: CellScratch::new(n),
            a_prev: vec![0.0; n],
            jlist: Vec::with_capacity(n),
            grad_compact: vec![0.0; pc],
            grads: vec![0.0; p],
            logits: vec![0.0; readout_n_out],
            dlogits: vec![0.0; readout_n_out],
            c_bar: vec![0.0; n],
            measure_influence: false,
        }
    }

    pub fn mode(&self) -> SparsityMode {
        self.mode
    }

    /// Compact column count `pc` (≈ ω̃-scaled when columns are compacted).
    pub fn tracked_columns(&self) -> usize {
        self.colmap.len()
    }

    /// Current activation state (for inference-style probing in examples).
    pub fn activations(&self) -> &[f32] {
        &self.a_prev
    }
}

impl GradientEngine for SparseRtrl {
    fn name(&self) -> &'static str {
        match self.mode {
            SparsityMode::Activity => "rtrl-activity",
            SparsityMode::Parameter => "rtrl-param",
            SparsityMode::Both => "rtrl-both",
        }
    }

    fn begin_sequence(&mut self) {
        self.buffers.reset();
        self.a_prev.iter_mut().for_each(|x| *x = 0.0);
        self.grad_compact.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn step(
        &mut self,
        cell: &RnnCell,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult {
        let n = cell.n();
        // ---- forward ----------------------------------------------------
        cell.forward(&self.a_prev, x, &mut self.scratch, ops);
        let active_units = self.scratch.active_units();
        let deriv_units = self.scratch.deriv_units();

        // ---- influence update (Eq. 10) ----------------------------------
        self.buffers.begin_next();
        let dv_da_cost = cell.dv_da_cost();
        let pc = self.colmap.len();
        let mut jac_macs = 0u64;
        let mut upd_macs = 0u64;
        let mut rows_read = 0usize;
        for k in 0..n {
            let dphi_k = self.scratch.dphi[k];
            if self.mode.use_activity() && dphi_k == 0.0 {
                continue; // row k of J, M̄, M is structurally zero
            }
            // Jacobian row, restricted to kept params × prev-active rows.
            self.jlist.clear();
            for &l in cell.kept_cols(k) {
                if !self.buffers.active_cur().contains(l as usize) {
                    continue; // M^{t-1} row l is zero
                }
                let jv = cell.dv_da(&self.scratch, k, l as usize);
                jac_macs += dv_da_cost;
                if jv != 0.0 {
                    self.jlist.push((l, jv));
                }
            }
            rows_read += self.jlist.len();
            upd_macs += self.jlist.len() as u64 * pc as u64;
            let row = self.buffers.gather_into_next(k, &self.jlist);
            // Immediate influence M̄ row k (structural nonzeros only).
            let colmap = &self.colmap;
            cell.immediate_row(
                &self.scratch,
                &self.a_prev,
                x,
                k,
                |pi, val| {
                    row[colmap.compact_of_unchecked(pi)] += val;
                },
                ops,
            );
            // Row gate φ'(v_k) (Eq. 10's common factor), with flush-to-zero:
            // M entries only ever shrink through this multiply (φ' ≤ γ < 1),
            // so long sequences would otherwise decay them into denormal
            // range, where scalar multiplies cost ~100 cycles (§Perf: this
            // was a measured 10× slowdown). Flushing tiny magnitudes to an
            // exact 0 both restores full-speed arithmetic and surfaces the
            // decayed-influence entries as the structural zeros they
            // effectively are.
            for r in row.iter_mut() {
                let v = *r * dphi_k;
                *r = if v.abs() < 1e-30 { 0.0 } else { v };
            }
            upd_macs += pc as u64;
        }
        ops.macs(Phase::Jacobian, jac_macs);
        ops.macs(Phase::InfluenceUpdate, upd_macs);
        ops.words(
            Phase::InfluenceUpdate,
            self.buffers.touched_words(rows_read) as u64,
        );

        // ---- loss + gradient accumulation (Eq. 3) ------------------------
        let (loss_val, correct) = supervised_step(
            readout,
            loss,
            &self.scratch.a,
            target,
            &mut self.logits,
            &mut self.dlogits,
            &mut self.c_bar,
            ops,
        );
        if loss_val.is_some() {
            let mut grad_macs = 0u64;
            for k in self.buffers.active_next().as_slice() {
                let coef = self.c_bar[*k];
                if coef == 0.0 {
                    continue;
                }
                let mrow = self.buffers.next_row(*k);
                for (g, m) in self.grad_compact.iter_mut().zip(mrow) {
                    *g += coef * m;
                }
                grad_macs += pc as u64;
            }
            ops.macs(Phase::GradCombine, grad_macs);
        }

        let influence_sparsity = if self.measure_influence {
            // Report over the *logical* n×p matrix (the paper's M): masked
            // columns are structural zeros even though they are compacted
            // out of storage.
            let logical = (n * self.colmap.p()) as f64;
            Some((1.0 - self.buffers.next_nonzero_count() as f64 / logical) as f32)
        } else {
            None
        };

        // ---- rotate state -------------------------------------------------
        self.buffers.advance();
        self.a_prev.copy_from_slice(&self.scratch.a);

        StepResult {
            loss: loss_val,
            correct,
            active_units,
            deriv_units,
            influence_sparsity,
        }
    }

    fn end_sequence(&mut self, _cell: &RnnCell, _readout: &mut Readout, _ops: &mut OpCounter) {
        self.grads.iter_mut().for_each(|x| *x = 0.0);
        self.colmap.scatter_add(&self.grad_compact, 1.0, &mut self.grads);
    }

    fn grads(&self) -> &[f32] {
        &self.grads
    }

    fn reset_grads(&mut self) {
        self.grad_compact.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn set_measure_influence(&mut self, on: bool) {
        self.measure_influence = on;
    }

    fn state_memory_words(&self) -> usize {
        self.buffers.memory_words() + self.grad_compact.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LossKind;
    use crate::util::Pcg64;

    fn setup(mode: SparsityMode) -> (RnnCell, Readout, Loss, SparseRtrl) {
        let mut rng = Pcg64::new(11);
        let cell = RnnCell::egru(8, 2, 0.1, 0.3, 0.5, None, &mut rng);
        let readout = Readout::new(2, 8, &mut rng);
        let loss = Loss::new(LossKind::CrossEntropy, 2);
        let engine = SparseRtrl::new(&cell, 2, mode);
        (cell, readout, loss, engine)
    }

    #[test]
    fn runs_a_sequence_and_produces_grads() {
        let (cell, mut readout, mut loss, mut eng) = setup(SparsityMode::Both);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        let xs = [[0.5, -0.2], [0.9, 0.1], [-0.3, 0.7]];
        for (t, x) in xs.iter().enumerate() {
            let target = if t == 2 { Target::Class(1) } else { Target::None };
            let r = eng.step(&cell, &mut readout, &mut loss, x, target, &mut ops);
            assert!(r.active_units <= 8);
        }
        eng.end_sequence(&cell, &mut readout, &mut ops);
        // gradient exists (possibly zero if no unit was ever deriv-active,
        // but with these seeds some are)
        assert_eq!(eng.grads().len(), cell.p());
    }

    #[test]
    fn inactive_rows_never_contribute() {
        // With activity mode, if no unit is deriv-active the gradient must
        // be exactly zero even under a loss.
        let mut rng = Pcg64::new(12);
        // huge threshold: v strongly negative => H'=0 everywhere
        let cell = RnnCell::egru(6, 2, 100.0, 0.3, 0.5, None, &mut rng);
        let mut readout = Readout::new(2, 6, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = SparseRtrl::new(&cell, 2, SparsityMode::Activity);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        for _ in 0..4 {
            let r = eng.step(&cell, &mut readout, &mut loss, &[1.0, 1.0], Target::Class(0), &mut ops);
            assert_eq!(r.deriv_units, 0);
        }
        eng.end_sequence(&cell, &mut readout, &mut ops);
        assert!(eng.grads().iter().all(|&g| g == 0.0));
        // and the influence update cost is zero
        assert_eq!(ops.macs_in(Phase::InfluenceUpdate), 0);
    }

    #[test]
    fn influence_sparsity_measured_when_enabled() {
        let (cell, mut readout, mut loss, mut eng) = setup(SparsityMode::Activity);
        eng.set_measure_influence(true);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        let r = eng.step(&cell, &mut readout, &mut loss, &[0.5, 0.5], Target::None, &mut ops);
        assert!(r.influence_sparsity.is_some());
        let s = r.influence_sparsity.unwrap();
        assert!((0.0..=1.0).contains(&s));
    }

    #[test]
    fn parameter_mode_tracks_fewer_columns_with_mask() {
        let mut rng = Pcg64::new(13);
        let mask = crate::sparse::MaskPattern::random(8, 8, 0.2, &mut rng);
        let cell = RnnCell::egru(8, 2, 0.1, 0.3, 0.5, Some(mask), &mut rng);
        let eng = SparseRtrl::new(&cell, 2, SparsityMode::Parameter);
        assert!(eng.tracked_columns() < cell.p());
        let dense_eng = SparseRtrl::new(&cell, 2, SparsityMode::Activity);
        assert_eq!(dense_eng.tracked_columns(), cell.p());
    }
}
