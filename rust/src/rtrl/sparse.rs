//! The paper's contribution: **exact** RTRL exploiting activity and/or
//! parameter sparsity — generalized to stacked layers with a block
//! lower-bidiagonal Jacobian.
//!
//! One engine covers the three sparse rows of Table 1 via [`SparsityMode`]:
//!
//! * `Activity` — rows of `J`/`M̄`/`M` with `φ'(v_k)=0` are skipped *per
//!   layer*; the own-layer gather touches only rows active at `t−1` and the
//!   cross-layer gather only rows of the lower layer active at `t` →
//!   `O(β̃^{(t)}β̃^{(t-1)}n²p)` per layer pair.
//! * `Parameter` — masked recurrent params drop columns of `M`/`M̄` (compact
//!   storage) and elements of `J` → `O(ω̃²n²p)`.
//! * `Both` — the combination → `O(ω̃²β̃²n²p)` (paper §5).
//!
//! # Block structure (stacked networks)
//!
//! Layer `l` keeps its own ping-pong panel of shape `n_l × cum_pc(l)`:
//! rows are its units, columns the compact columns of layers `0..=l`. The
//! update per row `k` of layer `l` (see `rtrl::mod` docs):
//!
//! ```text
//! M_l^{(t)}[k] = φ'_k · ( Σ_c J_l[k,c]·M_l^{(t-1)}[c]          own layer, M^{(t-1)}
//!                       + Σ_j C_l[k,j]·M_{l-1}^{(t)}[j]        lower layer, M^{(t)} (!)
//!                       + M̄_l[k] )
//! ```
//!
//! The cross-layer term reads the lower layer's **already-updated** next
//! panel and lands in the leading `cum_pc(l−1)` slice of the row — the
//! panels' column spaces nest by construction
//! ([`StackColumnMap::cum_cols`]), so no index translation happens and the
//! structurally-zero blocks (layer `l` rows over deeper layers' columns)
//! are never materialized **or charged**: every MAC is charged inside
//! layer `l`'s `(layer, Phase)` scope and is proportional to the stored
//! panel widths only.
//!
//! No approximation anywhere: skipped work is *structurally zero*, so the
//! gradient equals dense RTRL / BPTT bit-for-bit up to FP reassociation
//! (enforced by `rust/tests/sparse_exactness.rs` and
//! `rust/tests/grad_equivalence.rs`, including at depth 2).

use super::column_map::StackColumnMap;
use super::influence::StackedInfluence;
use super::kernels::{
    self, CrossSelect, JacobianSlab, OwnSelect, RowSelect,
};
use super::{supervised_step, EngineState, GradientEngine, StateError, StepResult, Target};
use crate::metrics::{OpCounter, Phase};
use crate::nn::{LayerStack, Loss, Readout, StackScratch};

/// Snapshot-format version of [`SparseRtrl`] (see [`EngineState`]) —
/// shared with [`super::BatchedSparse`]'s per-lane snapshots, which speak
/// the same format.
pub(crate) const SPARSE_STATE_VERSION: u32 = 1;
const STATE_VERSION: u32 = SPARSE_STATE_VERSION;

/// Minimum panel elements (claimed rows × panel width) before the row
/// update fans out over the worker pool. The pool spawns scoped threads
/// per call (tens of microseconds), so small panels — where a whole step
/// is only a few microseconds of row work — must stay serial even at
/// `--threads N`; results are bit-identical either way, so this threshold
/// is purely a wall-clock guard. Shared with [`super::BatchedSparse`],
/// whose panels count `rows × width × lanes` against the same floor.
pub(crate) const PAR_MIN_PANEL_ELEMS: u64 = 32 * 1024;

/// One staged panel-row update: row `k` with its filtered Jacobian
/// coefficient span in the engine's flat `jflat` staging buffer.
#[derive(Debug, Clone, Copy)]
struct RowPlan {
    k: u32,
    jstart: u32,
    jend: u32,
}

/// Per-row statistics a row job returns, summed after the join so op
/// charging is independent of scheduling.
#[derive(Debug, Clone, Copy)]
struct RowStats {
    rows_read: u64,
    upd_macs: u64,
    emitted: u64,
}

/// Which structural zeros the engine exploits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparsityMode {
    /// Activity sparsity only (Table 1 row "with activity sparsity").
    Activity,
    /// Parameter sparsity only (row "with parameter sparsity").
    Parameter,
    /// Both (row "with both").
    Both,
}

impl SparsityMode {
    fn use_activity(self) -> bool {
        matches!(self, SparsityMode::Activity | SparsityMode::Both)
    }

    fn use_columns(self) -> bool {
        matches!(self, SparsityMode::Parameter | SparsityMode::Both)
    }
}

/// Exact sparse RTRL engine (per-sequence state; reusable across sequences).
pub struct SparseRtrl {
    mode: SparsityMode,
    colmap: StackColumnMap,
    buffers: StackedInfluence,
    scratch: StackScratch,
    /// Concatenated previous state (`R^N`).
    a_prev: Vec<f32>,
    /// Per-step, per-layer Jacobian slab (scratch; rebuilt every step).
    slab: JacobianSlab,
    /// Staged row plans for the current layer's panel update.
    plans: Vec<RowPlan>,
    /// Flat `(col, ∂v_k/∂a_col)` staging shared by all plans of a layer.
    jflat: Vec<(u32, f32)>,
    /// Intra-step worker threads for the panel-row update (resolved; 1 =
    /// serial). Bit-identical results at any value.
    threads: usize,
    /// Gradient accumulator over the full compact column space.
    grad_compact: Vec<f32>,
    /// Dense `R^P` gradient view (valid after `end_sequence`).
    grads: Vec<f32>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    c_bar: Vec<f32>,
    measure_influence: bool,
}

impl SparseRtrl {
    /// Build for a stack. `Parameter`/`Both` modes compact columns using
    /// each layer's mask (dense layers degrade gracefully to full columns).
    pub fn new(net: &LayerStack, readout_n_out: usize, mode: SparsityMode) -> Self {
        let colmap = StackColumnMap::from_stack(net, mode.use_columns());
        let dims: Vec<(usize, usize)> = (0..net.layers())
            .map(|l| (net.layer(l).n(), colmap.cum_cols(l)))
            .collect();
        let pc_total = colmap.total_cols();
        SparseRtrl {
            mode,
            colmap,
            buffers: StackedInfluence::new(&dims),
            scratch: net.scratch(),
            a_prev: vec![0.0; net.total_units()],
            slab: JacobianSlab::new(),
            plans: Vec::with_capacity(net.total_units()),
            jflat: Vec::with_capacity(net.total_units()),
            threads: 1,
            grad_compact: vec![0.0; pc_total],
            grads: vec![0.0; net.p()],
            logits: vec![0.0; readout_n_out],
            dlogits: vec![0.0; readout_n_out],
            c_bar: vec![0.0; net.top_n()],
            measure_influence: false,
        }
    }

    pub fn mode(&self) -> SparsityMode {
        self.mode
    }

    /// Compact column count `pc` of the top panel (≈ ω̃-scaled total when
    /// columns are compacted).
    pub fn tracked_columns(&self) -> usize {
        self.colmap.total_cols()
    }

}

impl GradientEngine for SparseRtrl {
    fn name(&self) -> &'static str {
        match self.mode {
            SparsityMode::Activity => "rtrl-activity",
            SparsityMode::Parameter => "rtrl-param",
            SparsityMode::Both => "rtrl-both",
        }
    }

    fn begin_sequence(&mut self) {
        self.buffers.reset();
        self.a_prev.iter_mut().for_each(|x| *x = 0.0);
        self.grad_compact.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn step(
        &mut self,
        net: &LayerStack,
        readout: &mut Readout,
        loss: &mut Loss,
        x: &[f32],
        target: Target,
        ops: &mut OpCounter,
    ) -> StepResult {
        // ---- forward (charges per-layer Forward ops) --------------------
        net.forward(&self.a_prev, x, &mut self.scratch, ops);
        let active_units = self.scratch.active_units();
        let deriv_units = self.scratch.deriv_units();

        // ---- influence update (Eq. 10, block-by-block) ------------------
        //
        // Per layer: (1) build the step-Jacobian slab once — deriv-active
        // rows × (kept ∩ prev-active) columns, cross block over the lower
        // layer's just-written active rows; (2) stage one RowPlan per row
        // (nonzero coefficients only, the gather list); (3) run the row
        // update — fused gather + cross axpy + immediate scatter + φ' gate
        // — serially or across panel rows on the worker pool. Rows write
        // disjoint panel memory and read only frozen state, so the
        // parallel path is bit-identical to the serial one.
        self.buffers.begin_next();
        for l in 0..net.layers() {
            ops.set_layer(l);
            let cell = net.layer(l);
            let sl = &self.scratch.layers[l];
            let dv_da_cost = cell.dv_da_cost();
            let dv_dx_cost = cell.dv_dx_cost();
            let pc_lower = if l > 0 { self.colmap.cum_cols(l - 1) } else { 0 };
            let a_prev_l = &self.a_prev[net.layout().state_range(l)];
            let input_l: &[f32] = if l == 0 { x } else { &self.scratch.layers[l - 1].a };
            let (lower, buf) = self.buffers.lower_and_current(l);
            let pc_l = buf.pc();

            // (1) slab: the exact evaluation set of the per-scalar path —
            // same entries, same order, same Jacobian-phase charge.
            let row_sel = if self.mode.use_activity() {
                RowSelect::DerivActive
            } else {
                RowSelect::All
            };
            let cross_sel = match lower {
                // Only the lower layer's rows active at t (produced this
                // step) are nonzero — the never-materialized zero blocks
                // cost nothing here.
                Some(lo) => CrossSelect::Cols(lo.active_next().as_slice()),
                None => CrossSelect::Skip,
            };
            let counts = self.slab.build(
                cell,
                sl,
                row_sel,
                OwnSelect::KeptActive(buf.active_cur()),
                cross_sel,
            );
            let jac_macs =
                counts.own_entries * dv_da_cost + counts.cross_entries * dv_dx_cost;

            // (2) stage gather lists: drop exact-zero coefficients.
            self.plans.clear();
            self.jflat.clear();
            for &k in self.slab.rows() {
                let (cols, vals) = self.slab.own_row(k as usize);
                let jstart = self.jflat.len() as u32;
                for (&c, &v) in cols.iter().zip(vals) {
                    if v != 0.0 {
                        self.jflat.push((c, v));
                    }
                }
                self.plans.push(RowPlan { k, jstart, jend: self.jflat.len() as u32 });
            }

            // (3) claim rows serially (ascending — identical active set
            // regardless of how the update runs), then run the row update.
            for p in &self.plans {
                buf.mark_next_active(p.k as usize);
            }
            let (cur_panel, next_panel) = buf.split_cur_next();
            let slab = &self.slab;
            let jflat = &self.jflat;
            let colmap = &self.colmap;
            let update_row = |plan: RowPlan, row: &mut [f32]| -> RowStats {
                let k = plan.k as usize;
                // Own-layer gather: Σ_c J[k,c] · M_l^{(t-1)}[c].
                let jlist = &jflat[plan.jstart as usize..plan.jend as usize];
                kernels::fused_gather(row, jlist, |c| cur_panel.row(c));
                let mut rows_read = jlist.len() as u64;
                let mut upd_macs = jlist.len() as u64 * pc_l as u64;
                // Cross-layer block: lower layer's *new* panel rows land in
                // the leading pc_lower slice (nested column spaces).
                if let Some(lo) = lower {
                    let cvals = slab.cross_row(k);
                    for (&j, &cv) in slab.cross_cols().iter().zip(cvals) {
                        if cv == 0.0 {
                            continue;
                        }
                        kernels::axpy(&mut row[..pc_lower], cv, lo.next_row(j as usize));
                        rows_read += 1;
                        upd_macs += pc_lower as u64;
                    }
                }
                // Immediate influence M̄_l row k (structural nonzeros only),
                // landing in layer l's own column block.
                let emitted = cell.immediate_row_visit(sl, a_prev_l, input_l, k, |pi, val| {
                    row[colmap.global_compact_of(l, pi)] += val;
                });
                // Row gate φ'(v_k) (Eq. 10's common factor) with
                // flush-to-zero — see kernels::FLUSH_EPS for why.
                kernels::scale_flush(row, sl.dphi[k]);
                upd_macs += pc_l as u64;
                RowStats { rows_read, upd_macs, emitted }
            };
            // Serial path: allocation-free — iterate plans, one row at a
            // time. Parallel path: fan disjoint row slices out over the
            // pool, but only when the panel work dwarfs the per-step
            // thread-spawn cost (scoped threads are spawned per call); tiny
            // panels stay serial even at --threads N. Either way the
            // per-row math is `update_row`, so results are bit-identical.
            let (mut rows_read, mut upd_macs, mut emitted) = (0u64, 0u64, 0u64);
            let panel_elems = self.plans.len() as u64 * pc_l as u64;
            if self.threads > 1 && self.plans.len() > 1 && panel_elems >= PAR_MIN_PANEL_ELEMS {
                let mut row_slots: Vec<Option<&mut [f32]>> =
                    next_panel.as_mut_slice().chunks_mut(pc_l.max(1)).map(Some).collect();
                let mut jobs: Vec<(RowPlan, &mut [f32])> = Vec::with_capacity(self.plans.len());
                for p in &self.plans {
                    jobs.push((*p, row_slots[p.k as usize].take().expect("row claimed once")));
                }
                let stats = kernels::for_each_row_parallel(jobs, self.threads, |(plan, row)| {
                    update_row(plan, row)
                });
                // Summed in row order — charges independent of scheduling.
                for s in &stats {
                    rows_read += s.rows_read;
                    upd_macs += s.upd_macs;
                    emitted += s.emitted;
                }
            } else {
                for p in &self.plans {
                    let s = update_row(*p, next_panel.row_mut(p.k as usize));
                    rows_read += s.rows_read;
                    upd_macs += s.upd_macs;
                    emitted += s.emitted;
                }
            }
            ops.macs(Phase::Jacobian, jac_macs);
            ops.macs(Phase::Immediate, emitted);
            ops.macs(Phase::InfluenceUpdate, upd_macs);
            // Words touched: rows written at this panel's width plus rows
            // read (own prev rows at pc_l, lower rows at pc_lower — charge
            // at the width actually streamed, conservatively pc_l).
            ops.words(
                Phase::InfluenceUpdate,
                (self.plans.len() as u64 + rows_read) * pc_l as u64,
            );
        }
        ops.clear_layer();

        // ---- loss + gradient accumulation (Eq. 3) ------------------------
        // The readout reads the top layer; credit for lower layers' params
        // is already folded into the top panel's columns by the cross-layer
        // gather above, so combining top rows only is exact.
        let (loss_val, correct, prediction) = supervised_step(
            readout,
            loss,
            &self.scratch.top().a,
            target,
            &mut self.logits,
            &mut self.dlogits,
            &mut self.c_bar,
            ops,
        );
        if loss_val.is_some() {
            let top = self.buffers.layer(net.layers() - 1);
            let pc_total = self.colmap.total_cols();
            let mut grad_macs = 0u64;
            for k in top.active_next().as_slice() {
                let coef = self.c_bar[*k];
                if coef == 0.0 {
                    continue;
                }
                let mrow = top.next_row(*k);
                for (g, m) in self.grad_compact.iter_mut().zip(mrow) {
                    *g += coef * m;
                }
                grad_macs += pc_total as u64;
            }
            ops.macs(Phase::GradCombine, grad_macs);
        }

        let influence_sparsity = if self.measure_influence {
            // Report over the *logical* N×P matrix (the paper's M for the
            // stacked map): masked columns and the cross-layer upper blocks
            // are structural zeros even though they are never stored.
            let logical = (self.a_prev.len() * self.colmap.p()) as f64;
            Some((1.0 - self.buffers.next_nonzero_total() as f64 / logical) as f32)
        } else {
            None
        };

        // ---- rotate state -------------------------------------------------
        self.buffers.advance();
        self.scratch.write_state(&mut self.a_prev);

        StepResult {
            loss: loss_val,
            correct,
            prediction,
            active_units,
            deriv_units,
            influence_sparsity,
        }
    }

    fn end_sequence(&mut self, net: &LayerStack, _readout: &mut Readout, _ops: &mut OpCounter) {
        self.grads.iter_mut().for_each(|x| *x = 0.0);
        self.colmap.scatter_add(net, &self.grad_compact, 1.0, &mut self.grads);
    }

    fn grads(&self) -> &[f32] {
        &self.grads
    }

    fn reset_grads(&mut self) {
        self.grad_compact.iter_mut().for_each(|x| *x = 0.0);
        self.grads.iter_mut().for_each(|x| *x = 0.0);
    }

    fn set_measure_influence(&mut self, on: bool) {
        self.measure_influence = on;
    }

    fn set_threads(&mut self, threads: usize) {
        self.threads = crate::util::pool::resolve_workers(threads);
    }

    fn state_memory_words(&self) -> usize {
        // The Jacobian slab and row plans are per-step scratch, not
        // sequence state — excluded from the Table-1 memory column.
        self.buffers.memory_words() + self.grad_compact.len()
    }

    fn activations(&self) -> &[f32] {
        &self.a_prev
    }

    fn save_state(&self) -> EngineState {
        // Per-layer: the active rows of the current panel (inactive rows are
        // logical zeros and never stored). The column maps are rebuilt
        // deterministically from the stack, so only values travel.
        let mut st = EngineState::new(self.name(), STATE_VERSION);
        st.put_scalar("layers", self.buffers.layers() as u64);
        for l in 0..self.buffers.layers() {
            let (rows, vals) = self.buffers.layer(l).snapshot_cur();
            st.put_ints(&format!("rows_{l}"), rows);
            st.put_floats(&format!("vals_{l}"), vals);
        }
        st.put_floats("a_prev", self.a_prev.clone());
        st.put_floats("grad_compact", self.grad_compact.clone());
        st.put_floats("grads", self.grads.clone());
        st
    }

    fn load_state(&mut self, _net: &LayerStack, state: &EngineState) -> Result<(), StateError> {
        state.require(self.name(), STATE_VERSION)?;
        if state.scalar("layers")? != self.buffers.layers() as u64 {
            return Err(StateError(format!(
                "snapshot has {} influence layers, engine has {}",
                state.scalar("layers")?,
                self.buffers.layers()
            )));
        }
        let a = state.floats_exact("a_prev", self.a_prev.len())?;
        let gc = state.floats_exact("grad_compact", self.grad_compact.len())?;
        let g = state.floats_exact("grads", self.grads.len())?;
        for l in 0..self.buffers.layers() {
            let rows = state.ints(&format!("rows_{l}"))?;
            let vals = state.floats(&format!("vals_{l}"))?;
            self.buffers.layer_mut(l).restore_cur(rows, vals).map_err(StateError)?;
        }
        self.a_prev.copy_from_slice(a);
        self.grad_compact.copy_from_slice(gc);
        self.grads.copy_from_slice(g);
        Ok(())
    }

    fn as_sparse(&mut self) -> Option<&mut SparseRtrl> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{LossKind, RnnCell};
    use crate::util::Pcg64;

    fn setup(mode: SparsityMode) -> (LayerStack, Readout, Loss, SparseRtrl) {
        let mut rng = Pcg64::new(11);
        let net = LayerStack::single(RnnCell::egru(8, 2, 0.1, 0.3, 0.5, None, &mut rng));
        let readout = Readout::new(2, 8, &mut rng);
        let loss = Loss::new(LossKind::CrossEntropy, 2);
        let engine = SparseRtrl::new(&net, 2, mode);
        (net, readout, loss, engine)
    }

    #[test]
    fn runs_a_sequence_and_produces_grads() {
        let (net, mut readout, mut loss, mut eng) = setup(SparsityMode::Both);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        let xs = [[0.5, -0.2], [0.9, 0.1], [-0.3, 0.7]];
        for (t, x) in xs.iter().enumerate() {
            let target = if t == 2 { Target::Class(1) } else { Target::None };
            let r = eng.step(&net, &mut readout, &mut loss, x, target, &mut ops);
            assert!(r.active_units <= 8);
        }
        eng.end_sequence(&net, &mut readout, &mut ops);
        assert_eq!(eng.grads().len(), net.p());
    }

    #[test]
    fn inactive_rows_never_contribute() {
        // With activity mode, if no unit is deriv-active the gradient must
        // be exactly zero even under a loss.
        let mut rng = Pcg64::new(12);
        // huge threshold: v strongly negative => H'=0 everywhere
        let net = LayerStack::single(RnnCell::egru(6, 2, 100.0, 0.3, 0.5, None, &mut rng));
        let mut readout = Readout::new(2, 6, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = SparseRtrl::new(&net, 2, SparsityMode::Activity);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        for _ in 0..4 {
            let r = eng.step(&net, &mut readout, &mut loss, &[1.0, 1.0], Target::Class(0), &mut ops);
            assert_eq!(r.deriv_units, 0);
        }
        eng.end_sequence(&net, &mut readout, &mut ops);
        assert!(eng.grads().iter().all(|&g| g == 0.0));
        // and the influence update cost is zero
        assert_eq!(ops.macs_in(Phase::InfluenceUpdate), 0);
    }

    #[test]
    fn influence_sparsity_measured_when_enabled() {
        let (net, mut readout, mut loss, mut eng) = setup(SparsityMode::Activity);
        eng.set_measure_influence(true);
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        let r = eng.step(&net, &mut readout, &mut loss, &[0.5, 0.5], Target::None, &mut ops);
        assert!(r.influence_sparsity.is_some());
        let s = r.influence_sparsity.unwrap();
        assert!((0.0..=1.0).contains(&s));
    }

    #[test]
    fn parameter_mode_tracks_fewer_columns_with_mask() {
        let mut rng = Pcg64::new(13);
        let mask = crate::sparse::MaskPattern::random(8, 8, 0.2, &mut rng);
        let net =
            LayerStack::single(RnnCell::egru(8, 2, 0.1, 0.3, 0.5, Some(mask), &mut rng));
        let eng = SparseRtrl::new(&net, 2, SparsityMode::Parameter);
        assert!(eng.tracked_columns() < net.p());
        let dense_eng = SparseRtrl::new(&net, 2, SparsityMode::Activity);
        assert_eq!(dense_eng.tracked_columns(), net.p());
    }

    /// Depth 2: the per-layer panels have nested column spaces, layer 0's
    /// panel never allocates or charges columns for layer 1's parameters,
    /// and per-layer op attribution covers the whole influence cost.
    #[test]
    fn depth2_panels_nest_and_layer0_never_pays_for_layer1_columns() {
        let mut rng = Pcg64::new(14);
        let l0 = RnnCell::egru(6, 2, 0.05, 0.3, 0.9, None, &mut rng);
        let l1 = RnnCell::egru(5, 6, 0.05, 0.3, 0.9, None, &mut rng);
        let net = LayerStack::new(vec![l0, l1]);
        let mut readout = Readout::new(2, 5, &mut rng);
        let mut loss = Loss::new(LossKind::CrossEntropy, 2);
        let mut eng = SparseRtrl::new(&net, 2, SparsityMode::Activity);
        // layer 0 panel: p0 columns; layer 1 panel: p0 + p1 columns
        assert_eq!(eng.buffers.layer(0).pc(), net.layer(0).p());
        assert_eq!(eng.buffers.layer(1).pc(), net.p());
        let mut ops = OpCounter::new();
        eng.begin_sequence();
        let mut xr = Pcg64::new(3);
        for t in 0..6 {
            let x = [xr.normal(), xr.normal()];
            let target = if t == 5 { Target::Class(0) } else { Target::None };
            eng.step(&net, &mut readout, &mut loss, &x, target, &mut ops);
        }
        eng.end_sequence(&net, &mut readout, &mut ops);
        // both layers charged influence work, and the split is complete
        let l0_macs = ops.macs_in_layer(0, Phase::InfluenceUpdate);
        let l1_macs = ops.macs_in_layer(1, Phase::InfluenceUpdate);
        assert!(l0_macs > 0 && l1_macs > 0);
        assert_eq!(l0_macs + l1_macs, ops.macs_in(Phase::InfluenceUpdate));
        // layer 0's per-step influence charge is bounded by work over its
        // own panel width (p0 columns), i.e. the zero blocks for layer 1's
        // params were never charged: even a fully-dense row update costs at
        // most (rows_read + 1) * p0 per row.
        let n0 = net.layer(0).n() as u64;
        let p0 = net.layer(0).p() as u64;
        let steps = 6u64;
        assert!(
            l0_macs <= steps * n0 * (n0 + 1) * p0,
            "layer 0 charged {l0_macs} MACs — exceeds its own-panel bound"
        );
        // gradient exists for both layers' params
        let off1 = net.layout().param_offset(1);
        assert!(eng.grads()[..off1].iter().any(|&g| g != 0.0), "layer 0 got no gradient");
        assert!(eng.grads()[off1..].iter().any(|&g| g != 0.0), "layer 1 got no gradient");
    }
}
