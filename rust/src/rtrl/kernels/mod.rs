//! Shared step-Jacobian slabs and fused row kernels — the single hot-path
//! layer every gradient engine drives.
//!
//! Before this layer existed, each engine re-derived the one-step Jacobian
//! entry-by-entry through per-scalar `cell.dv_da`/`cell.dv_dx` callbacks
//! inside its innermost loop, interleaving op accounting with arithmetic.
//! Now a [`JacobianSlab`] is built **once per step per layer** — CSR over
//! the engine-selected rows × columns, reusing the cell's `kept_cols`
//! pattern and the engines' active sets — and the engines compose their
//! updates from a handful of fused row kernels ([`rowops`]): the Eq.-10
//! panel gather, cross-layer axpy, the `φ'` gate with flush-to-zero,
//! adjoint scatters and slab·vector dots. Op charging is bulk per kernel
//! call, derived from slice lengths and [`SlabCounts`].
//!
//! # Structure-of-arrays kernel shapes
//!
//! The row kernels are written for autovectorization on stable Rust: every
//! hot loop runs over contiguous, pre-truncated slices in
//! [`rowops::LANES`]-wide `chunks_exact` blocks with a scalar remainder
//! tail, so no per-element bounds check survives into the loop body and
//! LLVM can emit packed SIMD for the block bodies. The unrolling regroups
//! *elements*, never the terms of one element's sum, so the kernels stay
//! bit-identical to their plain-loop forms (pinned by kernel-level tests
//! and `rust/tests/jacobian_slab.rs`). All five engine families — dense,
//! sparse, SnAp-1/2, UORO and BPTT — run these same loops.
//!
//! # Shared-weight batched stepping
//!
//! When N sessions share one weight+mask set, the parameter-mode slab
//! structure is identical across them — only the *values* differ. The
//! batched path ([`BatchedSlab`] + the panel kernels
//! [`gather_panel`]/[`axpy_panel`]/[`scale_flush_panel`]) builds the
//! structure **once per step** and stores each session's influence panel
//! lane-interleaved (`row[c*B + s]` is compact column `c` of lane `s`), so
//! one pass over a row's shared column list advances all N sessions. Lanes
//! never mix arithmetically — lane `s` of a width-`B` run is bit-identical
//! to a width-1 run of that session alone through the same panel kernels —
//! and op accounting charges every lane the same counts it would pay solo.
//! `rtrl::BatchedSparse` drives these kernels; `session::SessionPool::
//! step_batched` and the bench `--batch` axis expose them.
//!
//! # Intra-step parallelism
//!
//! The exact-RTRL influence update writes disjoint memory per panel row
//! (row `k` of `M^{(t)}` depends only on the *previous* panel, the lower
//! layer's finished panel and row `k`'s immediate term), so
//! [`for_each_row_parallel`] fans rows out over the in-tree worker pool.
//! Because every kernel fixes its floating-point association order and a
//! row's inputs are immutable during the update, a multi-threaded step is
//! **bit-identical** to the single-threaded one — pinned by
//! `rust/tests/jacobian_slab.rs` over a full training run. The same holds
//! under batching: a batched panel row carries all lanes, so thread count
//! changes neither lane values nor charged ops.

pub mod rowops;
pub mod slab;

pub use rowops::{
    axpy, axpy_panel, dot_dense_acc, dot_sparse_acc, fused_gather, gather_panel, scale_flush,
    scale_flush_panel, scatter_axpy, FLUSH_EPS, LANES,
};
pub use slab::{BatchedSlab, CrossSelect, JacobianSlab, OwnSelect, RowSelect, SlabCounts};

use crate::util::pool;

/// Run one job per panel row, on `threads` workers when `threads > 1`
/// (a plain in-order map otherwise). Jobs must write disjoint memory —
/// the caller passes each row's `&mut` slice *into* its job, so the
/// borrow checker enforces disjointness. Results return in job order;
/// per-row op statistics are summed by the caller after the join, which
/// keeps charged counts independent of scheduling.
///
/// Cost note: the pool spawns *scoped* threads per call (no persistent
/// workers in-tree), so one invocation costs tens of microseconds before
/// any row runs. Callers on a per-step path must gate on the amount of
/// row work — see `SparseRtrl`'s panel-size threshold — and hot serial
/// callers should iterate rows directly rather than build a job vector.
pub fn for_each_row_parallel<T, R, F>(jobs: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    if threads > 1 && jobs.len() > 1 {
        pool::run_parallel(jobs, threads, |_, job| f(job))
    } else {
        jobs.into_iter().map(f).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_parallel_matches_serial_and_preserves_order() {
        let rows: Vec<Vec<f32>> = (0..32).map(|r| vec![r as f32; 8]).collect();
        let run = |threads: usize| {
            let jobs: Vec<(usize, Vec<f32>)> = rows.iter().cloned().enumerate().collect();
            for_each_row_parallel(jobs, threads, |(i, mut row)| {
                for v in row.iter_mut() {
                    *v = *v * 2.0 + i as f32;
                }
                row
            })
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn disjoint_mut_rows_cross_thread() {
        // The real usage pattern: chunk a buffer into disjoint &mut rows,
        // move each into a job, mutate in place.
        let mut buf = vec![0.0f32; 6 * 4];
        {
            let jobs: Vec<(usize, &mut [f32])> =
                buf.chunks_mut(4).enumerate().collect();
            let stats = for_each_row_parallel(jobs, 3, |(i, row)| {
                for v in row.iter_mut() {
                    *v = i as f32;
                }
                row.len() as u64
            });
            assert_eq!(stats.iter().sum::<u64>(), 24);
        }
        for (i, chunk) in buf.chunks(4).enumerate() {
            assert!(chunk.iter().all(|&v| v == i as f32));
        }
    }

    #[test]
    fn zero_jobs_is_a_no_op_at_any_thread_count() {
        for threads in [1, 2, 8] {
            let out: Vec<u64> = for_each_row_parallel(Vec::<u64>::new(), threads, |j| j);
            assert!(out.is_empty(), "threads {threads}");
        }
    }

    #[test]
    fn fewer_jobs_than_threads_runs_every_job_once_in_order() {
        // 3 jobs on 8 requested workers: the pool must clamp, run each job
        // exactly once, and return results in job order.
        use std::sync::atomic::{AtomicU64, Ordering};
        let runs = AtomicU64::new(0);
        let out = for_each_row_parallel(vec![10u64, 20, 30], 8, |j| {
            runs.fetch_add(1, Ordering::SeqCst);
            j + 1
        });
        assert_eq!(out, vec![11, 21, 31]);
        assert_eq!(runs.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn panicking_job_lets_sibling_rows_complete() {
        // util/pool contract: every job runs to completion even when one
        // panics; the first panic (by job index) is re-raised afterwards.
        use std::sync::atomic::{AtomicU64, Ordering};
        let done = AtomicU64::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for_each_row_parallel((0..16u64).collect(), 4, |j| {
                if j == 5 {
                    panic!("row job {j} failed");
                }
                done.fetch_add(1, Ordering::SeqCst);
                j
            })
        }));
        assert!(result.is_err(), "the job panic must propagate to the caller");
        assert_eq!(done.load(Ordering::SeqCst), 15, "sibling rows must still complete");
    }
}
