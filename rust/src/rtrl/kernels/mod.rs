//! Shared step-Jacobian slabs and fused row kernels — the single hot-path
//! layer every gradient engine drives.
//!
//! Before this layer existed, each engine re-derived the one-step Jacobian
//! entry-by-entry through per-scalar `cell.dv_da`/`cell.dv_dx` callbacks
//! inside its innermost loop, interleaving op accounting with arithmetic.
//! Now a [`JacobianSlab`] is built **once per step per layer** — CSR over
//! the engine-selected rows × columns, reusing the cell's `kept_cols`
//! pattern and the engines' active sets — and the engines compose their
//! updates from a handful of fused row kernels ([`rowops`]): the Eq.-10
//! panel gather, cross-layer axpy, the `φ'` gate with flush-to-zero,
//! adjoint scatters and slab·vector dots. Op charging is bulk per kernel
//! call, derived from slice lengths and [`SlabCounts`].
//!
//! # Intra-step parallelism
//!
//! The exact-RTRL influence update writes disjoint memory per panel row
//! (row `k` of `M^{(t)}` depends only on the *previous* panel, the lower
//! layer's finished panel and row `k`'s immediate term), so
//! [`for_each_row_parallel`] fans rows out over the in-tree worker pool.
//! Because every kernel fixes its floating-point association order and a
//! row's inputs are immutable during the update, a multi-threaded step is
//! **bit-identical** to the single-threaded one — pinned by
//! `rust/tests/jacobian_slab.rs` over a full training run.

pub mod rowops;
pub mod slab;

pub use rowops::{
    axpy, dot_dense_acc, dot_sparse_acc, fused_gather, scale_flush, scatter_axpy, FLUSH_EPS,
};
pub use slab::{CrossSelect, JacobianSlab, OwnSelect, RowSelect, SlabCounts};

use crate::util::pool;

/// Run one job per panel row, on `threads` workers when `threads > 1`
/// (a plain in-order map otherwise). Jobs must write disjoint memory —
/// the caller passes each row's `&mut` slice *into* its job, so the
/// borrow checker enforces disjointness. Results return in job order;
/// per-row op statistics are summed by the caller after the join, which
/// keeps charged counts independent of scheduling.
///
/// Cost note: the pool spawns *scoped* threads per call (no persistent
/// workers in-tree), so one invocation costs tens of microseconds before
/// any row runs. Callers on a per-step path must gate on the amount of
/// row work — see `SparseRtrl`'s panel-size threshold — and hot serial
/// callers should iterate rows directly rather than build a job vector.
pub fn for_each_row_parallel<T, R, F>(jobs: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    if threads > 1 && jobs.len() > 1 {
        pool::run_parallel(jobs, threads, |_, job| f(job))
    } else {
        jobs.into_iter().map(f).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_parallel_matches_serial_and_preserves_order() {
        let rows: Vec<Vec<f32>> = (0..32).map(|r| vec![r as f32; 8]).collect();
        let run = |threads: usize| {
            let jobs: Vec<(usize, Vec<f32>)> = rows.iter().cloned().enumerate().collect();
            for_each_row_parallel(jobs, threads, |(i, mut row)| {
                for v in row.iter_mut() {
                    *v = *v * 2.0 + i as f32;
                }
                row
            })
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn disjoint_mut_rows_cross_thread() {
        // The real usage pattern: chunk a buffer into disjoint &mut rows,
        // move each into a job, mutate in place.
        let mut buf = vec![0.0f32; 6 * 4];
        {
            let jobs: Vec<(usize, &mut [f32])> =
                buf.chunks_mut(4).enumerate().collect();
            let stats = for_each_row_parallel(jobs, 3, |(i, row)| {
                for v in row.iter_mut() {
                    *v = i as f32;
                }
                row.len() as u64
            });
            assert_eq!(stats.iter().sum::<u64>(), 24);
        }
        for (i, chunk) in buf.chunks(4).enumerate() {
            assert!(chunk.iter().all(|&v| v == i as f32));
        }
    }
}
