//! [`JacobianSlab`] — the step Jacobian of one layer, materialized once per
//! timestep as a sparse slab and consumed by every engine through shared
//! row kernels.
//!
//! One step of layer `l` has two Jacobian blocks (see `nn::stack`):
//!
//! * **own-layer** `∂v_k/∂a_l` — structurally restricted to the kept
//!   entries of the recurrent mask, stored CSR-style over the *built* rows
//!   ([`RowSelect`]) × the *selected* columns ([`OwnSelect`]);
//! * **cross-layer** `∂v_k/∂x_j` — structurally dense (input weights carry
//!   no mask), stored as dense rows over a shared column list
//!   ([`CrossSelect`], typically the lower layer's active rows).
//!
//! The selects mirror exactly the evaluation set each engine historically
//! walked with per-scalar `cell.dv_da`/`cell.dv_dx` callbacks, so a
//! slab-driven engine evaluates the same entries in the same order — the
//! gradient *and* the op counts stay bit-identical to the per-scalar path
//! (pinned by `rust/tests/jacobian_slab.rs`). What changes is the shape of
//! the work: one branch dispatch and one `gu/gz` load per *row* instead of
//! per *entry* (see [`crate::nn::RnnCell::fill_dv_da_cols`]), values
//! reusable across every consumer within the step (UORO's backward
//! substitution reuses the forward slab; the paper's Eq.-10 recursion reads
//! each row once per panel gather).
//!
//! The slab does **not** charge the [`crate::metrics::OpCounter`] itself:
//! [`JacobianSlab::build`] returns a [`SlabCounts`] and each engine charges
//! its own cost model in bulk — the accounting contract of `rtrl::mod`
//! predates the slab and must not drift with implementation details.
//! Buffers are retained across steps (no per-step allocation in steady
//! state), and the slab is scratch: it is rebuilt every step and never part
//! of an engine's [`crate::rtrl::EngineState`] snapshot.

use crate::nn::{CellScratch, RnnCell};
use crate::sparse::RowSet;

/// Sentinel for "row not built" in the reverse row map.
const ABSENT: u32 = u32::MAX;

/// Which rows of the layer's Jacobian are materialized.
#[derive(Clone, Copy)]
pub enum RowSelect<'a> {
    /// Every row (the dense baseline, SnAp's unskipped sweep, and the
    /// sparse engine without activity mode).
    All,
    /// Rows with `φ'(v_k) ≠ 0` — the `β̃n` nonzero rows of Eq. 10.
    DerivActive,
    /// An explicit row list (BPTT's reverse pass builds only the rows whose
    /// adjoint `δv_k` is nonzero at this frame).
    Rows(&'a [u32]),
}

/// Which own-layer columns are evaluated per built row.
#[derive(Clone, Copy)]
pub enum OwnSelect<'a> {
    /// All `n` columns, masked entries included (the dense engine pays for
    /// the structural zeros — that is the baseline the paper prices).
    Dense,
    /// The kept columns of the recurrent mask (structural `J` pattern).
    Kept,
    /// Kept columns whose source row is in the given active set — the
    /// `β̃²` intersection of the exact sparse engine: a `J` entry is only
    /// worth evaluating when the influence row it would multiply is
    /// nonzero.
    KeptActive(&'a RowSet),
    /// Only the diagonal entry `(k, k)` — SnAp-1's structural need.
    Diag,
}

/// Which cross-layer (input-path) columns are evaluated.
#[derive(Clone, Copy)]
pub enum CrossSelect<'a> {
    /// No cross block (layer 0, or engines that route cross-layer credit
    /// outside the influence recursion).
    Skip,
    /// All `n_in` columns.
    All,
    /// An explicit column list (the lower layer's rows active at `t` — the
    /// only rows of its just-written panel that are nonzero).
    Cols(&'a [usize]),
}

/// Entry counts of one [`JacobianSlab::build`], for bulk op charging at the
/// call site (`own_entries × dv_da_cost`, `cross_entries × dv_dx_cost`).
#[derive(Debug, Clone, Copy)]
pub struct SlabCounts {
    pub own_entries: u64,
    pub cross_entries: u64,
}

/// One layer's step Jacobian, materialized (see module docs).
#[derive(Debug, Clone, Default)]
pub struct JacobianSlab {
    /// Built row indices, in build order (ascending for `All`/`DerivActive`).
    rows: Vec<u32>,
    /// Unit index → position in `rows` (`ABSENT` if not built).
    row_of: Vec<u32>,
    /// CSR row pointers over `rows` (`len = rows.len() + 1`).
    own_ptr: Vec<u32>,
    own_cols: Vec<u32>,
    own_vals: Vec<f32>,
    /// Shared cross-block column list (lower-layer unit indices).
    cross_cols: Vec<u32>,
    /// Dense cross values, `rows.len() × cross_cols.len()` row-major.
    cross_vals: Vec<f32>,
}

impl JacobianSlab {
    pub fn new() -> Self {
        Self::default()
    }

    /// Materialize the slab for one `(cell, step scratch)` pair. Buffers are
    /// reused; previous contents are discarded. Returns the entry counts for
    /// bulk op charging.
    pub fn build(
        &mut self,
        cell: &RnnCell,
        sl: &CellScratch,
        rows: RowSelect,
        own: OwnSelect,
        cross: CrossSelect,
    ) -> SlabCounts {
        let n = cell.n();
        self.rows.clear();
        self.row_of.clear();
        self.row_of.resize(n, ABSENT);
        match rows {
            RowSelect::All => self.rows.extend(0..n as u32),
            RowSelect::DerivActive => {
                for k in 0..n {
                    if sl.dphi[k] != 0.0 {
                        self.rows.push(k as u32);
                    }
                }
            }
            RowSelect::Rows(list) => self.rows.extend_from_slice(list),
        }
        for (i, &k) in self.rows.iter().enumerate() {
            debug_assert!((k as usize) < n, "slab row {k} out of range");
            self.row_of[k as usize] = i as u32;
        }

        // Own-layer block: columns first, then one fused value fill per row.
        self.own_ptr.clear();
        self.own_cols.clear();
        self.own_vals.clear();
        self.own_ptr.push(0);
        for &k in &self.rows {
            let k = k as usize;
            let start = self.own_cols.len();
            match own {
                OwnSelect::Dense => self.own_cols.extend(0..n as u32),
                OwnSelect::Kept => self.own_cols.extend_from_slice(cell.kept_cols(k)),
                OwnSelect::KeptActive(active) => {
                    for &c in cell.kept_cols(k) {
                        if active.contains(c as usize) {
                            self.own_cols.push(c);
                        }
                    }
                }
                OwnSelect::Diag => self.own_cols.push(k as u32),
            }
            let end = self.own_cols.len();
            self.own_vals.resize(end, 0.0);
            cell.fill_dv_da_cols(sl, k, &self.own_cols[start..end], &mut self.own_vals[start..end]);
            self.own_ptr.push(end as u32);
        }

        // Cross-layer block: shared column list, dense value rows.
        self.cross_cols.clear();
        self.cross_vals.clear();
        match cross {
            CrossSelect::Skip => {}
            CrossSelect::All => self.cross_cols.extend(0..cell.n_in() as u32),
            CrossSelect::Cols(js) => self.cross_cols.extend(js.iter().map(|&j| j as u32)),
        }
        let w = self.cross_cols.len();
        if w > 0 {
            self.cross_vals.resize(self.rows.len() * w, 0.0);
            for (i, &k) in self.rows.iter().enumerate() {
                cell.fill_dv_dx_cols(
                    sl,
                    k as usize,
                    &self.cross_cols,
                    &mut self.cross_vals[i * w..(i + 1) * w],
                );
            }
        }
        SlabCounts {
            own_entries: self.own_vals.len() as u64,
            cross_entries: self.cross_vals.len() as u64,
        }
    }

    /// Built rows, in build order.
    #[inline]
    pub fn rows(&self) -> &[u32] {
        &self.rows
    }

    /// Whether row `k` was built.
    #[inline]
    pub fn has_row(&self, k: usize) -> bool {
        self.row_of.get(k).is_some_and(|&i| i != ABSENT)
    }

    /// Own-layer row `k`: `(column indices, values)`. Empty for unbuilt rows.
    #[inline]
    pub fn own_row(&self, k: usize) -> (&[u32], &[f32]) {
        match self.row_of.get(k) {
            Some(&i) if i != ABSENT => {
                let (s, e) = (self.own_ptr[i as usize] as usize, self.own_ptr[i as usize + 1] as usize);
                (&self.own_cols[s..e], &self.own_vals[s..e])
            }
            _ => (&[], &[]),
        }
    }

    /// Diagonal entry `∂v_k/∂a_k` of a [`OwnSelect::Diag`] build (0.0 for
    /// unbuilt rows — structurally consistent: an unbuilt row is zero).
    #[inline]
    pub fn diag(&self, k: usize) -> f32 {
        let (cols, vals) = self.own_row(k);
        debug_assert!(cols.len() <= 1, "diag() on a non-diagonal slab row");
        vals.first().copied().unwrap_or(0.0)
    }

    /// The shared cross-block column list (lower-layer unit indices).
    #[inline]
    pub fn cross_cols(&self) -> &[u32] {
        &self.cross_cols
    }

    /// Cross-layer values of row `k`, aligned with [`Self::cross_cols`].
    /// Empty for unbuilt rows or a [`CrossSelect::Skip`] build.
    #[inline]
    pub fn cross_row(&self, k: usize) -> &[f32] {
        let w = self.cross_cols.len();
        if w == 0 {
            return &[];
        }
        match self.row_of.get(k) {
            Some(&i) if i != ABSENT => &self.cross_vals[i as usize * w..(i as usize + 1) * w],
            _ => &[],
        }
    }
}

/// The step Jacobian of one layer for a **batch** of sessions sharing one
/// weight+mask set: structure built once, values filled once per lane.
///
/// In parameter-sparsity mode the slab's structure is value-independent —
/// every row is built, own columns are the mask's `kept_cols` (or empty on
/// the first step after a reset, when the previous influence panel is all
/// zero), and the cross block is structurally dense. N sessions stepping
/// the same weights therefore share one structure per `(layer, step)`:
/// [`BatchedSlab::build_structure`] lays out the CSR shell, then
/// [`BatchedSlab::fill_lane`] writes each session's Jacobian values into
/// lane-interleaved value panels (`own_vals[e*B + s]` is entry `e` of lane
/// `s`) via the cell's strided column fillers. The fused panel kernels
/// ([`rowops::gather_panel`](super::rowops::gather_panel) and friends) then
/// advance all N influence panels in one pass per row.
///
/// The returned [`SlabCounts`] are **per lane**: op accounting charges each
/// session the same Jacobian cost it would pay solo, whether the structure
/// was built once or N times — amortization shows up in wall time, never
/// in charged ops.
#[derive(Debug, Clone, Default)]
pub struct BatchedSlab {
    n: usize,
    batch: usize,
    /// CSR row pointers over all `n` rows (`len = n + 1`).
    own_ptr: Vec<u32>,
    own_cols: Vec<u32>,
    /// Own values, entry-major / lane-minor: `own_vals[e*batch + s]`.
    own_vals: Vec<f32>,
    cross_cols: Vec<u32>,
    /// Cross values, `(row, col)`-major / lane-minor:
    /// `cross_vals[(k*w + j)*batch + s]`.
    cross_vals: Vec<f32>,
}

impl BatchedSlab {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lay out the shared sparsity structure for one layer and batch width.
    /// All `n` rows are built. `own_kept` selects the mask's kept columns
    /// per row (false → no own block, the first post-reset step);
    /// `cross_all` selects the full `n_in` cross block (false → no cross
    /// block, layer 0). Value panels are resized and zeroed. Returns the
    /// **per-lane** entry counts.
    pub fn build_structure(
        &mut self,
        cell: &RnnCell,
        own_kept: bool,
        cross_all: bool,
        batch: usize,
    ) -> SlabCounts {
        assert!(batch >= 1, "batch width must be at least 1");
        let n = cell.n();
        self.n = n;
        self.batch = batch;
        self.own_ptr.clear();
        self.own_cols.clear();
        self.own_ptr.push(0);
        for k in 0..n {
            if own_kept {
                self.own_cols.extend_from_slice(cell.kept_cols(k));
            }
            self.own_ptr.push(self.own_cols.len() as u32);
        }
        self.own_vals.clear();
        self.own_vals.resize(self.own_cols.len() * batch, 0.0);

        self.cross_cols.clear();
        if cross_all {
            self.cross_cols.extend(0..cell.n_in() as u32);
        }
        self.cross_vals.clear();
        self.cross_vals.resize(n * self.cross_cols.len() * batch, 0.0);
        SlabCounts {
            own_entries: self.own_cols.len() as u64,
            cross_entries: (n * self.cross_cols.len()) as u64,
        }
    }

    /// Fill lane `s`'s Jacobian values from one session's step scratch.
    /// The cell must match the one the structure was built for.
    pub fn fill_lane(&mut self, lane: usize, cell: &RnnCell, sl: &CellScratch) {
        let b = self.batch;
        debug_assert!(lane < b);
        for k in 0..self.n {
            let (s, e) = (self.own_ptr[k] as usize, self.own_ptr[k + 1] as usize);
            if s == e {
                continue;
            }
            cell.fill_dv_da_cols_strided(
                sl,
                k,
                &self.own_cols[s..e],
                &mut self.own_vals[s * b + lane..e * b],
                b,
            );
        }
        let w = self.cross_cols.len();
        if w > 0 {
            for k in 0..self.n {
                cell.fill_dv_dx_cols_strided(
                    sl,
                    k,
                    &self.cross_cols,
                    &mut self.cross_vals[k * w * b + lane..(k + 1) * w * b],
                    b,
                );
            }
        }
    }

    /// Batch width the structure was built for.
    #[inline]
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Own-layer row `k`: `(shared column indices, lane-interleaved
    /// values)` — `values[e*batch + s]` is entry `e` of lane `s`.
    #[inline]
    pub fn own_row(&self, k: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.own_ptr[k] as usize, self.own_ptr[k + 1] as usize);
        (&self.own_cols[s..e], &self.own_vals[s * self.batch..e * self.batch])
    }

    /// The shared cross-block column list (lower-layer unit indices).
    #[inline]
    pub fn cross_cols(&self) -> &[u32] {
        &self.cross_cols
    }

    /// Cross-layer values of row `k`, `(col)`-major / lane-minor:
    /// `row[j*batch + s]`. Empty when no cross block was built.
    #[inline]
    pub fn cross_row(&self, k: usize) -> &[f32] {
        let w = self.cross_cols.len();
        if w == 0 {
            return &[];
        }
        let b = self.batch;
        &self.cross_vals[k * w * b..(k + 1) * w * b]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OpCounter;
    use crate::sparse::MaskPattern;
    use crate::util::Pcg64;

    fn forward(cell: &RnnCell, seed: u64) -> CellScratch {
        let mut rng = Pcg64::new(seed);
        let a_prev: Vec<f32> = (0..cell.n()).map(|_| rng.normal().max(0.0)).collect();
        let x: Vec<f32> = (0..cell.n_in()).map(|_| rng.normal()).collect();
        let mut s = CellScratch::new(cell.n());
        cell.forward(&a_prev, &x, &mut s, &mut OpCounter::new());
        s
    }

    #[test]
    fn dense_build_matches_direct_dv_da_and_dv_dx() {
        let mut rng = Pcg64::new(1);
        let cell = RnnCell::gated_tanh(6, 3, None, &mut rng);
        let s = forward(&cell, 2);
        let mut slab = JacobianSlab::new();
        let counts = slab.build(&cell, &s, RowSelect::All, OwnSelect::Dense, CrossSelect::All);
        assert_eq!(counts.own_entries, 36);
        assert_eq!(counts.cross_entries, 18);
        for k in 0..6 {
            let (cols, vals) = slab.own_row(k);
            assert_eq!(cols.len(), 6);
            for (&c, &v) in cols.iter().zip(vals) {
                assert_eq!(v.to_bits(), cell.dv_da(&s, k, c as usize).to_bits());
            }
            for (j, &v) in slab.cross_row(k).iter().enumerate() {
                assert_eq!(v.to_bits(), cell.dv_dx(&s, k, j).to_bits());
            }
        }
    }

    #[test]
    fn kept_build_follows_mask_pattern() {
        let mut rng = Pcg64::new(3);
        let mask = MaskPattern::random(8, 8, 0.4, &mut rng);
        let cell = RnnCell::egru(8, 2, 0.05, 0.3, 0.9, Some(mask), &mut rng);
        let s = forward(&cell, 4);
        let mut slab = JacobianSlab::new();
        slab.build(&cell, &s, RowSelect::All, OwnSelect::Kept, CrossSelect::Skip);
        for k in 0..8 {
            let (cols, vals) = slab.own_row(k);
            assert_eq!(cols, cell.kept_cols(k));
            for (&c, &v) in cols.iter().zip(vals) {
                assert_eq!(v.to_bits(), cell.dv_da(&s, k, c as usize).to_bits());
            }
            assert!(slab.cross_row(k).is_empty());
        }
    }

    #[test]
    fn deriv_active_rows_and_kept_active_cols_filter() {
        let mut rng = Pcg64::new(5);
        // n_in = 6 so the explicit cross-column list below stays in range
        let cell = RnnCell::egru(10, 6, 0.1, 0.3, 0.4, None, &mut rng);
        let s = forward(&cell, 6);
        let active = RowSet::from_pred(10, |k| k % 3 == 0);
        let mut slab = JacobianSlab::new();
        slab.build(
            &cell,
            &s,
            RowSelect::DerivActive,
            OwnSelect::KeptActive(&active),
            CrossSelect::Cols(&[1, 4]),
        );
        for k in 0..10 {
            if s.dphi[k] == 0.0 {
                assert!(!slab.has_row(k));
                assert!(slab.own_row(k).0.is_empty());
                assert!(slab.cross_row(k).is_empty());
            } else {
                assert!(slab.has_row(k));
                let (cols, _) = slab.own_row(k);
                assert!(cols.iter().all(|&c| active.contains(c as usize)));
                assert_eq!(slab.cross_row(k).len(), 2);
            }
        }
        assert_eq!(slab.cross_cols(), &[1, 4]);
    }

    #[test]
    fn diag_build_has_one_entry_per_row() {
        let mut rng = Pcg64::new(7);
        let cell = RnnCell::vanilla(5, 2, None, &mut rng);
        let s = forward(&cell, 8);
        let mut slab = JacobianSlab::new();
        let counts = slab.build(&cell, &s, RowSelect::All, OwnSelect::Diag, CrossSelect::Skip);
        assert_eq!(counts.own_entries, 5);
        for k in 0..5 {
            assert_eq!(slab.diag(k).to_bits(), cell.dv_da(&s, k, k).to_bits());
        }
    }

    #[test]
    fn explicit_row_list_builds_exactly_those_rows() {
        let mut rng = Pcg64::new(9);
        let cell = RnnCell::vanilla(6, 2, None, &mut rng);
        let s = forward(&cell, 10);
        let mut slab = JacobianSlab::new();
        slab.build(&cell, &s, RowSelect::Rows(&[1, 4]), OwnSelect::Kept, CrossSelect::All);
        assert_eq!(slab.rows(), &[1, 4]);
        assert!(slab.has_row(1) && slab.has_row(4) && !slab.has_row(0));
        assert_eq!(slab.cross_row(4).len(), 2);
        assert!(slab.cross_row(0).is_empty());
    }

    #[test]
    fn rebuild_reuses_buffers_and_discards_old_contents() {
        let mut rng = Pcg64::new(11);
        let cell = RnnCell::vanilla(4, 2, None, &mut rng);
        let s = forward(&cell, 12);
        let mut slab = JacobianSlab::new();
        slab.build(&cell, &s, RowSelect::All, OwnSelect::Dense, CrossSelect::All);
        slab.build(&cell, &s, RowSelect::Rows(&[2]), OwnSelect::Diag, CrossSelect::Skip);
        assert_eq!(slab.rows(), &[2]);
        assert!(!slab.has_row(0));
        assert!(slab.cross_cols().is_empty());
        assert_eq!(slab.own_row(2).0, &[2]);
    }

    /// Every lane of a batched slab must carry bit-identical values to a
    /// solo [`JacobianSlab`] built from that lane's scratch with the same
    /// structural selects — and the per-lane counts must match too.
    #[test]
    fn batched_slab_lanes_bit_match_solo_slabs() {
        let mut rng = Pcg64::new(21);
        let mask = MaskPattern::random(7, 7, 0.45, &mut rng);
        let cell = RnnCell::egru(7, 3, 0.05, 0.3, 0.9, Some(mask), &mut rng);
        let scratches: Vec<CellScratch> = (0..3).map(|i| forward(&cell, 30 + i)).collect();

        let mut batched = BatchedSlab::new();
        let bcounts = batched.build_structure(&cell, true, true, scratches.len());
        for (lane, s) in scratches.iter().enumerate() {
            batched.fill_lane(lane, &cell, s);
        }

        let mut solo = JacobianSlab::new();
        for (lane, s) in scratches.iter().enumerate() {
            let counts =
                solo.build(&cell, s, RowSelect::All, OwnSelect::Kept, CrossSelect::All);
            assert_eq!(counts.own_entries, bcounts.own_entries);
            assert_eq!(counts.cross_entries, bcounts.cross_entries);
            for k in 0..7 {
                let (bcols, bvals) = batched.own_row(k);
                let (scols, svals) = solo.own_row(k);
                assert_eq!(bcols, scols);
                for (e, &v) in svals.iter().enumerate() {
                    assert_eq!(
                        bvals[e * batched.batch() + lane].to_bits(),
                        v.to_bits(),
                        "own row {k} entry {e} lane {lane}"
                    );
                }
                let bx = batched.cross_row(k);
                for (j, &v) in solo.cross_row(k).iter().enumerate() {
                    assert_eq!(
                        bx[j * batched.batch() + lane].to_bits(),
                        v.to_bits(),
                        "cross row {k} col {j} lane {lane}"
                    );
                }
            }
        }
    }

    /// `own_kept = false` (the first post-reset step) builds an empty own
    /// block but keeps the dense cross block; layer-0 style builds skip
    /// the cross block.
    #[test]
    fn batched_slab_structure_flags() {
        let mut rng = Pcg64::new(23);
        let cell = RnnCell::vanilla(5, 2, None, &mut rng);
        let s = forward(&cell, 24);
        let mut batched = BatchedSlab::new();
        let counts = batched.build_structure(&cell, false, true, 2);
        batched.fill_lane(0, &cell, &s);
        assert_eq!(counts.own_entries, 0);
        assert_eq!(counts.cross_entries, 10);
        for k in 0..5 {
            assert!(batched.own_row(k).0.is_empty());
            assert_eq!(batched.cross_row(k).len(), 2 * 2);
        }
        let counts = batched.build_structure(&cell, true, false, 2);
        assert_eq!(counts.cross_entries, 0);
        assert!(batched.cross_cols().is_empty());
        assert!(counts.own_entries > 0);
    }
}
