//! Fused row kernels — the handful of inner loops every gradient engine is
//! built from.
//!
//! Each kernel operates on whole row slices and returns nothing the caller
//! cannot derive from slice lengths, so op accounting happens **in bulk at
//! the call site** (`count × per-entry cost`), never per scalar inside the
//! loop. The kernels are deliberately free functions over plain slices:
//! they hold no state, so a row update composed from them can run on any
//! thread — the property [`super::for_each_row_parallel`] exploits.
//!
//! # Structure-of-arrays shapes
//!
//! The hot loops are written in autovectorization-friendly form on stable
//! Rust: operands are pre-truncated to a common length (no per-element
//! bounds checks survive into the loop body), the body runs over
//! [`LANES`]-wide `chunks_exact` blocks with a scalar remainder tail, and
//! element `i`'s arithmetic never depends on element `i−1`'s — except in
//! the two dot kernels, whose single accumulator chain is *deliberately*
//! sequential (see below). The panel variants ([`gather_panel`],
//! [`axpy_panel`], [`scale_flush_panel`]) extend the same shapes to
//! lane-interleaved batch panels, where `B` independent sessions' influence
//! rows are stored element-major / lane-minor (`row[c*B + s]` is column `c`
//! of lane `s`) so one pass over a row advances every lane at once.
//!
//! # Bit-exactness contract
//!
//! These kernels pin the floating-point *association order* of the hot
//! loops. [`fused_gather`] consumes its coefficient list in pairs (two
//! fused multiply-adds per row element — the measured-fastest form of the
//! `J·M` gather); [`axpy`], [`scatter_axpy`] and the dot kernels accumulate
//! strictly left-to-right. The `chunks_exact` unrolling regroups *elements*
//! across iterations, never the terms of any one element's sum, so it is
//! bit-identical to the plain loop. The dot kernels fold everything into
//! one accumulator and therefore cannot be widened without reassociating —
//! they stay a sequential chain on purpose. Engines that must stay
//! bit-identical across refactors, thread counts and batch widths rely on
//! this: the same kernel call sequence produces the same bits regardless
//! of which thread runs it or how many lanes ride along. Each panel kernel
//! applies, per lane, exactly the arithmetic of its scalar counterpart in
//! the same order, so lane `s` of a width-`B` panel run is bit-identical
//! to a width-1 run of that lane alone.

/// Fixed unroll width of the element loops. Eight `f32`s is one AVX2
/// register / two NEON registers — wide enough that LLVM reliably
/// vectorizes the `chunks_exact` bodies, small enough that the scalar
/// remainder tail stays cheap for the short rows of small networks.
pub const LANES: usize = 8;

/// Magnitudes below this are flushed to an exact zero by
/// [`scale_flush`]. Influence entries only ever shrink through the `φ'`
/// row gate (`φ' ≤ γ < 1`), so long sequences would otherwise decay them
/// into denormal range, where scalar multiplies cost ~100 cycles (§Perf:
/// a measured 10× slowdown). Flushing restores full-speed arithmetic and
/// surfaces decayed influence as the structural zero it effectively is.
///
/// # Flush invariant
///
/// For every element, with `v = row[i] * g`:
///
/// * `|v| < FLUSH_EPS` → the element becomes exactly `+0.0` (this includes
///   `v = -0.0`, so flushed zeros have one canonical bit pattern);
/// * otherwise the element becomes `v` unchanged — **including non-finite
///   values**: `NaN.abs() < eps` and `∞.abs() < eps` are both false, so a
///   NaN or ±∞ entering the gate always survives it. The kernels never
///   silently drop a non-finite value; it stays in the panel where tests,
///   telemetry and downstream gradients surface it.
pub const FLUSH_EPS: f32 = 1e-30;

/// `dst[i] = j0·s0[i] + j1·s1[i]` over pre-truncated equal-length slices.
#[inline]
fn pair_write(dst: &mut [f32], j0: f32, s0: &[f32], j1: f32, s1: &[f32]) {
    let len = dst.len();
    let (s0, s1) = (&s0[..len], &s1[..len]);
    let mut d = dst.chunks_exact_mut(LANES);
    let mut a = s0.chunks_exact(LANES);
    let mut b = s1.chunks_exact(LANES);
    for dc in &mut d {
        let (ac, bc) = (a.next().unwrap(), b.next().unwrap());
        for i in 0..LANES {
            dc[i] = j0 * ac[i] + j1 * bc[i];
        }
    }
    for ((dv, &av), &bv) in d.into_remainder().iter_mut().zip(a.remainder()).zip(b.remainder()) {
        *dv = j0 * av + j1 * bv;
    }
}

/// `dst[i] += ja·sa[i] + jb·sb[i]` over pre-truncated equal-length slices.
#[inline]
fn pair_add(dst: &mut [f32], ja: f32, sa: &[f32], jb: f32, sb: &[f32]) {
    let len = dst.len();
    let (sa, sb) = (&sa[..len], &sb[..len]);
    let mut d = dst.chunks_exact_mut(LANES);
    let mut a = sa.chunks_exact(LANES);
    let mut b = sb.chunks_exact(LANES);
    for dc in &mut d {
        let (ac, bc) = (a.next().unwrap(), b.next().unwrap());
        for i in 0..LANES {
            dc[i] += ja * ac[i] + jb * bc[i];
        }
    }
    for ((dv, &av), &bv) in d.into_remainder().iter_mut().zip(a.remainder()).zip(b.remainder()) {
        *dv += ja * av + jb * bv;
    }
}

/// The influence-recursion gather (paper Eq. 10, inner bracket):
/// `dst = Σ_i jlist[i].1 · src(jlist[i].0)`.
///
/// `src` maps a row index to its slice (the previous influence panel; all
/// source rows must be at least `dst.len()` long). An empty `jlist` zeroes
/// `dst`. §Perf: the first contribution *writes* the row (no separate
/// zeroing pass) and entries are consumed in pairs so each pass over the
/// row does two fused multiply-adds per element — halving row read/write
/// traffic and roughly doubling ILP on the measured hot loop. The passes
/// themselves run [`LANES`]-wide with a scalar tail (see module docs);
/// per-element association order is unchanged.
pub fn fused_gather<'a>(
    dst: &mut [f32],
    jlist: &[(u32, f32)],
    src: impl Fn(usize) -> &'a [f32],
) {
    if jlist.is_empty() {
        dst.iter_mut().for_each(|x| *x = 0.0);
        return;
    }
    let (l0, j0) = jlist[0];
    let s0 = src(l0 as usize);
    let mut idx = 1;
    if jlist.len() >= 2 {
        let (l1, j1) = jlist[1];
        pair_write(dst, j0, s0, j1, src(l1 as usize));
        idx = 2;
    } else {
        for (r, s) in dst.iter_mut().zip(s0) {
            *r = j0 * s;
        }
    }
    while idx + 1 < jlist.len() {
        let (la, ja) = jlist[idx];
        let (lb, jb) = jlist[idx + 1];
        pair_add(dst, ja, src(la as usize), jb, src(lb as usize));
        idx += 2;
    }
    if idx < jlist.len() {
        let (l, jv) = jlist[idx];
        let s = src(l as usize);
        for (r, sv) in dst.iter_mut().zip(s) {
            *r += jv * sv;
        }
    }
}

/// `dst[i] += a · src[i]` over `min(dst.len(), src.len())` elements —
/// the cross-layer panel accumulation and the dense-row adjoint push.
#[inline]
pub fn axpy(dst: &mut [f32], a: f32, src: &[f32]) {
    let len = dst.len().min(src.len());
    let (dst, src) = (&mut dst[..len], &src[..len]);
    let mut d = dst.chunks_exact_mut(LANES);
    let mut s = src.chunks_exact(LANES);
    for dc in &mut d {
        let sc = s.next().unwrap();
        for i in 0..LANES {
            dc[i] += a * sc[i];
        }
    }
    for (dv, &sv) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *dv += a * sv;
    }
}

/// The `φ'` row gate with flush-to-zero: `row[i] = row[i] · g`, magnitudes
/// below [`FLUSH_EPS`] snapped to an exact `+0.0`. Non-finite products are
/// never flushed — see the [`FLUSH_EPS`] invariant.
#[inline]
pub fn scale_flush(row: &mut [f32], g: f32) {
    let mut chunks = row.chunks_exact_mut(LANES);
    for rc in &mut chunks {
        for r in rc.iter_mut() {
            let v = *r * g;
            *r = if v.abs() < FLUSH_EPS { 0.0 } else { v };
        }
    }
    for r in chunks.into_remainder() {
        let v = *r * g;
        *r = if v.abs() < FLUSH_EPS { 0.0 } else { v };
    }
}

/// Sparse transpose-axpy: `dst[cols[i]] += a · vals[i]` — the `Jᵀ·δv`
/// adjoint scatter of BPTT's reverse pass. Inherently gather/scatter
/// shaped: the random column writes cannot be chunked, so this stays the
/// plain indexed loop.
#[inline]
pub fn scatter_axpy(dst: &mut [f32], a: f32, cols: &[u32], vals: &[f32]) {
    for (&c, &v) in cols.iter().zip(vals) {
        dst[c as usize] += a * v;
    }
}

/// Sparse dot continuing an accumulator: `acc + Σ_i vals[i] · x[cols[i]]`
/// — the slab-row · vector product of UORO's forward substitution. The
/// accumulator threads through so a row's own-layer and cross-layer
/// contributions fold left-to-right into one sum (bit-compatible with the
/// historical single-loop form). The single accumulator chain is
/// sequential by contract — widening it would reassociate the sum.
#[inline]
pub fn dot_sparse_acc(mut acc: f32, cols: &[u32], vals: &[f32], x: &[f32]) -> f32 {
    for (&c, &v) in cols.iter().zip(vals) {
        acc += v * x[c as usize];
    }
    acc
}

/// Dense dot continuing an accumulator: `acc + Σ_i vals[i] · x[i]`.
/// Sequential chain by contract, like [`dot_sparse_acc`].
#[inline]
pub fn dot_dense_acc(mut acc: f32, vals: &[f32], x: &[f32]) -> f32 {
    for (v, xv) in vals.iter().zip(x) {
        acc += v * xv;
    }
    acc
}

// ---------------------------------------------------------------------------
// Lane-interleaved panel kernels (shared-weight batched stepping)
// ---------------------------------------------------------------------------

/// Panel form of [`fused_gather`] over a lane-interleaved batch panel:
/// `dst[c·b + s] = Σ_e vals[e·b + s] · src(cols[e])[c·b + s]`.
///
/// `cols` is the **shared** structural column list (one slab structure for
/// all `b` lanes); `vals` carries the per-lane Jacobian coefficients of
/// each entry, entry-major / lane-minor (`vals[e*b + s]` is entry `e` of
/// lane `s`). Entries are consumed in the same first-pair-writes /
/// pairs-add / single-tail order as [`fused_gather`], and within an entry
/// each lane multiplies only its own coefficient — lanes never mix — so
/// lane `s` of this kernel is bit-identical to [`fused_gather`] run on
/// lane `s`'s columns alone with the *same structural list* (zero-valued
/// coefficients included). An empty `cols` zeroes `dst`.
pub fn gather_panel<'a>(
    dst: &mut [f32],
    cols: &[u32],
    vals: &[f32],
    src: impl Fn(usize) -> &'a [f32],
    b: usize,
) {
    debug_assert_eq!(vals.len(), cols.len() * b);
    debug_assert_eq!(dst.len() % b.max(1), 0);
    if cols.is_empty() {
        dst.iter_mut().for_each(|x| *x = 0.0);
        return;
    }
    let len = dst.len();
    let c0 = &vals[..b];
    let s0 = src(cols[0] as usize);
    let mut idx = 1;
    if cols.len() >= 2 {
        let c1 = &vals[b..2 * b];
        let s1 = &src(cols[1] as usize)[..len];
        let s0 = &s0[..len];
        for ((dc, ac), bc) in
            dst.chunks_exact_mut(b).zip(s0.chunks_exact(b)).zip(s1.chunks_exact(b))
        {
            for s in 0..b {
                dc[s] = c0[s] * ac[s] + c1[s] * bc[s];
            }
        }
        idx = 2;
    } else {
        let s0 = &s0[..len];
        for (dc, ac) in dst.chunks_exact_mut(b).zip(s0.chunks_exact(b)) {
            for s in 0..b {
                dc[s] = c0[s] * ac[s];
            }
        }
    }
    while idx + 1 < cols.len() {
        let ca = &vals[idx * b..(idx + 1) * b];
        let cb = &vals[(idx + 1) * b..(idx + 2) * b];
        let sa = &src(cols[idx] as usize)[..len];
        let sb = &src(cols[idx + 1] as usize)[..len];
        for ((dc, ac), bc) in
            dst.chunks_exact_mut(b).zip(sa.chunks_exact(b)).zip(sb.chunks_exact(b))
        {
            for s in 0..b {
                dc[s] += ca[s] * ac[s] + cb[s] * bc[s];
            }
        }
        idx += 2;
    }
    if idx < cols.len() {
        let cv = &vals[idx * b..(idx + 1) * b];
        let sv = &src(cols[idx] as usize)[..len];
        for (dc, ac) in dst.chunks_exact_mut(b).zip(sv.chunks_exact(b)) {
            for s in 0..b {
                dc[s] += cv[s] * ac[s];
            }
        }
    }
}

/// Panel form of [`axpy`] with a per-lane coefficient vector:
/// `dst[c·b + s] += coef[s] · src[c·b + s]` over
/// `min(dst.len(), src.len())` panel elements. Lane `s` sees exactly the
/// arithmetic of `axpy(dst_lane_s, coef[s], src_lane_s)` — including for
/// `coef[s] == 0.0`, which adds a signed zero on finite data (normalized
/// to `+0.0` by the next [`scale_flush_panel`]) but turns a non-finite
/// source element into NaN (`0·∞`), surfacing it rather than masking it.
#[inline]
pub fn axpy_panel(dst: &mut [f32], coef: &[f32], src: &[f32], b: usize) {
    debug_assert_eq!(coef.len(), b);
    let len = dst.len().min(src.len());
    let (dst, src) = (&mut dst[..len], &src[..len]);
    for (dc, sc) in dst.chunks_exact_mut(b).zip(src.chunks_exact(b)) {
        for s in 0..b {
            dc[s] += coef[s] * sc[s];
        }
    }
}

/// Panel form of [`scale_flush`] with a per-lane gate vector:
/// `row[c·b + s] = row[c·b + s] · g[s]`, flushed per the [`FLUSH_EPS`]
/// invariant (non-finite values always survive).
#[inline]
pub fn scale_flush_panel(row: &mut [f32], g: &[f32], b: usize) {
    debug_assert_eq!(g.len(), b);
    for rc in row.chunks_exact_mut(b) {
        for s in 0..b {
            let v = rc[s] * g[s];
            rc[s] = if v.abs() < FLUSH_EPS { 0.0 } else { v };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fused_gather_empty_zeroes() {
        let mut dst = vec![3.0f32; 4];
        fused_gather(&mut dst, &[], |_| unreachable!());
        assert_eq!(dst, vec![0.0; 4]);
    }

    #[test]
    fn fused_gather_matches_naive_for_every_list_length() {
        let src_rows: Vec<Vec<f32>> = (0..7)
            .map(|r| (0..5).map(|c| (r * 5 + c) as f32 * 0.3 - 2.0).collect())
            .collect();
        for len in 0..7usize {
            let jlist: Vec<(u32, f32)> =
                (0..len).map(|i| (i as u32, 0.7 - 0.4 * i as f32)).collect();
            let mut dst = vec![9.0f32; 5];
            fused_gather(&mut dst, &jlist, |r| &src_rows[r]);
            let mut naive = vec![0.0f32; 5];
            for &(r, j) in &jlist {
                for (n, s) in naive.iter_mut().zip(&src_rows[r as usize]) {
                    *n += j * s;
                }
            }
            for (a, b) in dst.iter().zip(&naive) {
                assert!((a - b).abs() < 1e-5, "len {len}: {a} vs {b}");
            }
        }
    }

    /// The `chunks_exact` unrolling must be bit-identical to the plain
    /// element loop at every row length around the LANES boundary.
    #[test]
    fn unrolled_kernels_bit_match_plain_loops_at_all_tail_lengths() {
        for len in 0..(3 * LANES + 3) {
            let src_rows: Vec<Vec<f32>> = (0..5)
                .map(|r| (0..len).map(|c| ((r * 31 + c * 7) as f32).sin()).collect())
                .collect();
            let jlist: Vec<(u32, f32)> =
                (0..5).map(|i| (i as u32, 0.9 - 0.37 * i as f32)).collect();
            let mut dst = vec![0.0f32; len];
            fused_gather(&mut dst, &jlist, |r| &src_rows[r]);
            // plain reference with the same pair-consumption order
            let mut reference = vec![0.0f32; len];
            for i in 0..len {
                let mut v = jlist[0].1 * src_rows[0][i] + jlist[1].1 * src_rows[1][i];
                v += jlist[2].1 * src_rows[2][i] + jlist[3].1 * src_rows[3][i];
                v += jlist[4].1 * src_rows[4][i];
                reference[i] = v;
            }
            for (a, b) in dst.iter().zip(&reference) {
                assert_eq!(a.to_bits(), b.to_bits(), "len {len}");
            }

            let mut d1 = src_rows[0].clone();
            let mut d2 = src_rows[0].clone();
            axpy(&mut d1, 1.7, &src_rows[1]);
            for (d, s) in d2.iter_mut().zip(&src_rows[1]) {
                *d += 1.7 * s;
            }
            assert_eq!(
                d1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                d2.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );

            let mut r1 = src_rows[2].clone();
            let mut r2 = src_rows[2].clone();
            scale_flush(&mut r1, 0.3);
            for r in r2.iter_mut() {
                let v = *r * 0.3;
                *r = if v.abs() < FLUSH_EPS { 0.0 } else { v };
            }
            assert_eq!(
                r1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                r2.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn axpy_and_scatter() {
        let mut d = vec![1.0f32, 2.0, 3.0];
        axpy(&mut d, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(d, vec![3.0, 4.0, 5.0]);
        let mut s = vec![0.0f32; 4];
        scatter_axpy(&mut s, 3.0, &[1, 3], &[2.0, -1.0]);
        assert_eq!(s, vec![0.0, 6.0, 0.0, -3.0]);
    }

    #[test]
    fn scale_flush_gates_and_flushes() {
        let mut row = vec![2.0f32, 1e-35, -4.0, 0.0];
        scale_flush(&mut row, 0.5);
        assert_eq!(row, vec![1.0, 0.0, -2.0, 0.0]);
    }

    /// Flush-invariant property: an empty row is a no-op, an
    /// all-below-threshold row flushes to canonical `+0.0` everywhere
    /// (including `-0.0` inputs), and every surviving element is exactly
    /// `row[i] * g`.
    #[test]
    fn scale_flush_edge_rows() {
        let mut empty: Vec<f32> = vec![];
        scale_flush(&mut empty, 0.5);
        assert!(empty.is_empty());

        let mut tiny: Vec<f32> = (0..19)
            .map(|i| if i % 2 == 0 { 1e-33 } else { -1e-38 })
            .collect();
        tiny.push(-0.0);
        scale_flush(&mut tiny, 0.9);
        for v in &tiny {
            assert_eq!(v.to_bits(), 0.0f32.to_bits(), "flush must produce +0.0");
        }

        let mut mixed: Vec<f32> = vec![3.0, 1e-35, -2.5, 5e-31, 0.25];
        let expect: Vec<f32> = mixed
            .iter()
            .map(|&x| {
                let v = x * 0.5;
                if v.abs() < FLUSH_EPS {
                    0.0
                } else {
                    v
                }
            })
            .collect();
        scale_flush(&mut mixed, 0.5);
        assert_eq!(
            mixed.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            expect.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    /// Non-finite values must never be silently dropped: NaN and ±∞ pass
    /// through the flush gate (their `abs()` compares false against any
    /// threshold), and a zero gate over ±∞ surfaces NaN rather than
    /// producing a clean zero.
    #[test]
    fn scale_flush_surfaces_non_finite_values() {
        let mut row = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0];
        scale_flush(&mut row, 0.5);
        assert!(row[0].is_nan(), "NaN was dropped by the flush gate");
        assert_eq!(row[1], f32::INFINITY);
        assert_eq!(row[2], f32::NEG_INFINITY);
        assert_eq!(row[3], 0.5);

        // zero gate × infinity = NaN (surfaced), × NaN = NaN, × finite = 0
        let mut row = vec![f32::INFINITY, f32::NAN, 7.0];
        scale_flush(&mut row, 0.0);
        assert!(row[0].is_nan() && row[1].is_nan());
        assert_eq!(row[2], 0.0);

        // long rows: the unrolled body and the tail behave identically
        let mut long = vec![1.0f32; 2 * LANES + 3];
        long[1] = f32::NAN;
        long[LANES] = f32::INFINITY;
        long[2 * LANES + 2] = f32::NAN;
        scale_flush(&mut long, 1.0);
        assert!(long[1].is_nan() && long[2 * LANES + 2].is_nan());
        assert_eq!(long[LANES], f32::INFINITY);
    }

    #[test]
    fn dots_accumulate_left_to_right() {
        let x = [1.0f32, 2.0, 3.0];
        let acc = dot_sparse_acc(1.0, &[0, 2], &[2.0, 4.0], &x);
        assert_eq!(acc, 1.0 + 2.0 + 12.0);
        let acc = dot_dense_acc(acc, &[1.0, 1.0, 1.0], &x);
        assert_eq!(acc, 15.0 + 6.0);
    }

    // -- panel kernels ----------------------------------------------------

    /// Lane `s` of every panel kernel must be bit-identical to the scalar
    /// kernel run on that lane alone with the same structural list.
    #[test]
    fn panel_kernels_lane_bit_match_scalar_kernels() {
        let b = 3;
        let pc = 2 * LANES + 5;
        let n = 6;
        // lane-interleaved source panel + per-lane deinterleaved copies
        let panel: Vec<f32> =
            (0..n * pc * b).map(|i| ((i * 37 % 101) as f32 * 0.11 - 3.0).sin()).collect();
        let lane_rows = |s: usize| -> Vec<Vec<f32>> {
            (0..n)
                .map(|k| (0..pc).map(|c| panel[(k * pc + c) * b + s]).collect())
                .collect()
        };
        let cols: Vec<u32> = vec![0, 2, 3, 5, 1];
        // per-lane coefficients, entry-major — include an exact zero
        let vals: Vec<f32> = (0..cols.len() * b)
            .map(|i| if i == 4 { 0.0 } else { 0.8 - 0.13 * i as f32 })
            .collect();

        let mut dst = vec![0.0f32; pc * b];
        gather_panel(&mut dst, &cols, &vals, |k| &panel[k * pc * b..(k + 1) * pc * b], b);
        for s in 0..b {
            let rows = lane_rows(s);
            let jlist: Vec<(u32, f32)> =
                cols.iter().enumerate().map(|(e, &c)| (c, vals[e * b + s])).collect();
            let mut lane_dst = vec![0.0f32; pc];
            fused_gather(&mut lane_dst, &jlist, |k| &rows[k]);
            for c in 0..pc {
                assert_eq!(
                    dst[c * b + s].to_bits(),
                    lane_dst[c].to_bits(),
                    "gather_panel lane {s} col {c}"
                );
            }
        }

        // empty structural list zeroes the panel row
        let mut z = vec![5.0f32; pc * b];
        gather_panel(&mut z, &[], &[], |_| unreachable!(), b);
        assert!(z.iter().all(|&v| v == 0.0));

        // axpy_panel
        let coef = [0.7f32, 0.0, -1.3];
        let mut pd = dst.clone();
        let src = &panel[..pc * b];
        axpy_panel(&mut pd, &coef, src, b);
        for s in 0..b {
            let mut lane_d: Vec<f32> = (0..pc).map(|c| dst[c * b + s]).collect();
            let lane_s: Vec<f32> = (0..pc).map(|c| src[c * b + s]).collect();
            axpy(&mut lane_d, coef[s], &lane_s);
            for c in 0..pc {
                assert_eq!(
                    pd[c * b + s].to_bits(),
                    lane_d[c].to_bits(),
                    "axpy_panel lane {s} col {c}"
                );
            }
        }

        // scale_flush_panel (after a zero-coefficient axpy the signed zeros
        // must normalize identically on both paths)
        let g = [0.4f32, 0.0, 1.0];
        let mut pf = pd.clone();
        scale_flush_panel(&mut pf, &g, b);
        for s in 0..b {
            let mut lane: Vec<f32> = (0..pc).map(|c| pd[c * b + s]).collect();
            scale_flush(&mut lane, g[s]);
            for c in 0..pc {
                assert_eq!(
                    pf[c * b + s].to_bits(),
                    lane[c].to_bits(),
                    "scale_flush_panel lane {s} col {c}"
                );
            }
        }
    }

    /// Width-1 panels are the degenerate batch: every panel kernel must be
    /// bit-identical to its scalar counterpart at `b = 1`.
    #[test]
    fn panel_kernels_at_width_one_match_scalar_exactly() {
        let pc = LANES + 3;
        let n = 4;
        let panel: Vec<f32> = (0..n * pc).map(|i| (i as f32 * 0.77).cos()).collect();
        let cols: Vec<u32> = vec![3, 0, 2];
        let vals: Vec<f32> = vec![1.5, -0.25, 0.0];
        let mut a = vec![0.0f32; pc];
        let mut bb = vec![0.0f32; pc];
        gather_panel(&mut a, &cols, &vals, |k| &panel[k * pc..(k + 1) * pc], 1);
        let jlist: Vec<(u32, f32)> =
            cols.iter().zip(&vals).map(|(&c, &v)| (c, v)).collect();
        fused_gather(&mut bb, &jlist, |k| &panel[k * pc..(k + 1) * pc]);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            bb.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );

        axpy_panel(&mut a, &[0.9], &panel[..pc], 1);
        axpy(&mut bb, 0.9, &panel[..pc]);
        scale_flush_panel(&mut a, &[0.21], 1);
        scale_flush(&mut bb, 0.21);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            bb.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}
