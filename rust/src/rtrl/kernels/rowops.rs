//! Fused row kernels — the handful of inner loops every gradient engine is
//! built from.
//!
//! Each kernel operates on whole row slices and returns nothing the caller
//! cannot derive from slice lengths, so op accounting happens **in bulk at
//! the call site** (`count × per-entry cost`), never per scalar inside the
//! loop. The kernels are deliberately free functions over plain slices:
//! they hold no state, so a row update composed from them can run on any
//! thread — the property [`super::for_each_row_parallel`] exploits.
//!
//! # Bit-exactness contract
//!
//! These kernels pin the floating-point *association order* of the hot
//! loops. [`fused_gather`] consumes its coefficient list in pairs (two
//! fused multiply-adds per row element — the measured-fastest form of the
//! `J·M` gather); [`axpy`], [`scatter_axpy`] and the dot kernels accumulate
//! strictly left-to-right. Engines that must stay bit-identical across
//! refactors and thread counts rely on this: the same kernel call sequence
//! produces the same bits regardless of which thread runs it.

/// Magnitudes below this are flushed to an exact zero by
/// [`scale_flush`]. Influence entries only ever shrink through the `φ'`
/// row gate (`φ' ≤ γ < 1`), so long sequences would otherwise decay them
/// into denormal range, where scalar multiplies cost ~100 cycles (§Perf:
/// a measured 10× slowdown). Flushing restores full-speed arithmetic and
/// surfaces decayed influence as the structural zero it effectively is.
pub const FLUSH_EPS: f32 = 1e-30;

/// The influence-recursion gather (paper Eq. 10, inner bracket):
/// `dst = Σ_i jlist[i].1 · src(jlist[i].0)`.
///
/// `src` maps a row index to its slice (the previous influence panel; all
/// source rows must be at least `dst.len()` long). An empty `jlist` zeroes
/// `dst`. §Perf: the first contribution *writes* the row (no separate
/// zeroing pass) and entries are consumed in pairs so each pass over the
/// row does two fused multiply-adds per element — halving row read/write
/// traffic and roughly doubling ILP on the measured hot loop.
pub fn fused_gather<'a>(
    dst: &mut [f32],
    jlist: &[(u32, f32)],
    src: impl Fn(usize) -> &'a [f32],
) {
    if jlist.is_empty() {
        dst.iter_mut().for_each(|x| *x = 0.0);
        return;
    }
    let len = dst.len();
    let (l0, j0) = jlist[0];
    let s0 = src(l0 as usize);
    let mut idx = 1;
    if jlist.len() >= 2 {
        let (l1, j1) = jlist[1];
        let s1 = src(l1 as usize);
        let (s0, s1) = (&s0[..len], &s1[..len]);
        for i in 0..len {
            dst[i] = j0 * s0[i] + j1 * s1[i];
        }
        idx = 2;
    } else {
        for (r, s) in dst.iter_mut().zip(s0) {
            *r = j0 * s;
        }
    }
    while idx + 1 < jlist.len() {
        let (la, ja) = jlist[idx];
        let (lb, jb) = jlist[idx + 1];
        let sa = src(la as usize);
        let sb = src(lb as usize);
        let (sa, sb) = (&sa[..len], &sb[..len]);
        for i in 0..len {
            dst[i] += ja * sa[i] + jb * sb[i];
        }
        idx += 2;
    }
    if idx < jlist.len() {
        let (l, jv) = jlist[idx];
        let s = src(l as usize);
        for (r, sv) in dst.iter_mut().zip(s) {
            *r += jv * sv;
        }
    }
}

/// `dst[i] += a · src[i]` over `min(dst.len(), src.len())` elements —
/// the cross-layer panel accumulation and the dense-row adjoint push.
#[inline]
pub fn axpy(dst: &mut [f32], a: f32, src: &[f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d += a * s;
    }
}

/// The `φ'` row gate with flush-to-zero: `row[i] = row[i] · g`, magnitudes
/// below [`FLUSH_EPS`] snapped to an exact `0.0`.
#[inline]
pub fn scale_flush(row: &mut [f32], g: f32) {
    for r in row.iter_mut() {
        let v = *r * g;
        *r = if v.abs() < FLUSH_EPS { 0.0 } else { v };
    }
}

/// Sparse transpose-axpy: `dst[cols[i]] += a · vals[i]` — the `Jᵀ·δv`
/// adjoint scatter of BPTT's reverse pass.
#[inline]
pub fn scatter_axpy(dst: &mut [f32], a: f32, cols: &[u32], vals: &[f32]) {
    for (&c, &v) in cols.iter().zip(vals) {
        dst[c as usize] += a * v;
    }
}

/// Sparse dot continuing an accumulator: `acc + Σ_i vals[i] · x[cols[i]]`
/// — the slab-row · vector product of UORO's forward substitution. The
/// accumulator threads through so a row's own-layer and cross-layer
/// contributions fold left-to-right into one sum (bit-compatible with the
/// historical single-loop form).
#[inline]
pub fn dot_sparse_acc(mut acc: f32, cols: &[u32], vals: &[f32], x: &[f32]) -> f32 {
    for (&c, &v) in cols.iter().zip(vals) {
        acc += v * x[c as usize];
    }
    acc
}

/// Dense dot continuing an accumulator: `acc + Σ_i vals[i] · x[i]`.
#[inline]
pub fn dot_dense_acc(mut acc: f32, vals: &[f32], x: &[f32]) -> f32 {
    for (v, xv) in vals.iter().zip(x) {
        acc += v * xv;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fused_gather_empty_zeroes() {
        let mut dst = vec![3.0f32; 4];
        fused_gather(&mut dst, &[], |_| unreachable!());
        assert_eq!(dst, vec![0.0; 4]);
    }

    #[test]
    fn fused_gather_matches_naive_for_every_list_length() {
        let src_rows: Vec<Vec<f32>> = (0..7)
            .map(|r| (0..5).map(|c| (r * 5 + c) as f32 * 0.3 - 2.0).collect())
            .collect();
        for len in 0..7usize {
            let jlist: Vec<(u32, f32)> =
                (0..len).map(|i| (i as u32, 0.7 - 0.4 * i as f32)).collect();
            let mut dst = vec![9.0f32; 5];
            fused_gather(&mut dst, &jlist, |r| &src_rows[r]);
            let mut naive = vec![0.0f32; 5];
            for &(r, j) in &jlist {
                for (n, s) in naive.iter_mut().zip(&src_rows[r as usize]) {
                    *n += j * s;
                }
            }
            for (a, b) in dst.iter().zip(&naive) {
                assert!((a - b).abs() < 1e-5, "len {len}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn axpy_and_scatter() {
        let mut d = vec![1.0f32, 2.0, 3.0];
        axpy(&mut d, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(d, vec![3.0, 4.0, 5.0]);
        let mut s = vec![0.0f32; 4];
        scatter_axpy(&mut s, 3.0, &[1, 3], &[2.0, -1.0]);
        assert_eq!(s, vec![0.0, 6.0, 0.0, -3.0]);
    }

    #[test]
    fn scale_flush_gates_and_flushes() {
        let mut row = vec![2.0f32, 1e-35, -4.0, 0.0];
        scale_flush(&mut row, 0.5);
        assert_eq!(row, vec![1.0, 0.0, -2.0, 0.0]);
    }

    #[test]
    fn dots_accumulate_left_to_right() {
        let x = [1.0f32, 2.0, 3.0];
        let acc = dot_sparse_acc(1.0, &[0, 2], &[2.0, 4.0], &x);
        assert_eq!(acc, 1.0 + 2.0 + 12.0);
        let acc = dot_dense_acc(acc, &[1.0, 1.0, 1.0], &x);
        assert_eq!(acc, 15.0 + 6.0);
    }
}
