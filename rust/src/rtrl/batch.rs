//! [`BatchedSparse`] — shared-weight batched stepping for the exact
//! parameter-sparse engine: N sessions, one slab structure, fused panel
//! kernels.
//!
//! In [`SparsityMode::Parameter`](super::SparsityMode) the step-Jacobian
//! slab's *structure* is value-independent: every row is built, own
//! columns are the mask's `kept_cols` (empty only on the first step after
//! a reset, when the previous influence panel is logically zero), and the
//! cross block is structurally dense over the lower layer's rows. N
//! sessions that share one weight+mask set therefore share the structure
//! exactly — only the Jacobian *values* and the influence *panels* differ
//! per session. This engine exploits that:
//!
//! * one [`BatchedSlab`](super::kernels::BatchedSlab) per `(layer, step)`
//!   — structure laid out once, values filled once per lane via the cell's
//!   strided column fillers;
//! * lane-interleaved influence panels (`row[c*B + s]` is compact column
//!   `c` of lane `s`), advanced by the fused panel kernels
//!   ([`gather_panel`](super::kernels::gather_panel) and friends) — one
//!   pass over a row's shared column list moves all N sessions;
//! * per-lane forward passes, readout/loss steps and gradient
//!   accumulators, identical to a solo [`SparseRtrl`] run.
//!
//! # Bit-exactness and accounting contract
//!
//! Lanes never mix arithmetically: lane `s` of a width-`B` step performs
//! exactly the arithmetic of a width-1 step of that session through the
//! same panel kernels, in the same order — so gradients, losses and
//! predictions are **bit-identical across batch widths and thread counts**
//! (pinned by `rust/tests/batched_step.rs`). One deliberate difference
//! from the solo [`SparseRtrl`] path: the solo engine drops exact-zero
//! Jacobian coefficients while staging its gather lists, which regroups
//! [`fused_gather`](super::kernels::fused_gather)'s pair consumption; the
//! batched path keeps the full *structural* list at every width (a
//! per-lane filter would diverge the shared structure). The two paths
//! agree to FP-reassociation tolerance, and exactly when no structural
//! coefficient evaluates to 0.0 — the generic case.
//!
//! Op accounting charges every lane the counts its session would pay solo:
//! value-dependent phases (Forward, Immediate, GradCombine) are charged
//! per lane from that lane's own work; structure-dependent phases
//! (Jacobian, InfluenceUpdate) are charged **identically to each lane**
//! from the shared structural counts, whether the structure was built once
//! or N times. Amortization shows up in wall time only, never in charged
//! ops.
//!
//! The per-lane snapshot surface ([`BatchedSparse::save_lane`] /
//! [`BatchedSparse::load_lane`]) speaks the *same* [`EngineState`] format
//! as a solo `rtrl-param` [`SparseRtrl`], so [`crate::session::SessionPool`]
//! can move sessions between solo and batched stepping freely.

use super::column_map::StackColumnMap;
use super::kernels::{self, BatchedSlab};
use super::sparse::{PAR_MIN_PANEL_ELEMS, SPARSE_STATE_VERSION};
use super::{supervised_step, EngineState, StateError, StepResult, Target};
use crate::metrics::{OpCounter, Phase};
use crate::nn::{LayerStack, Loss, Readout, StackScratch};

/// One layer's lane-interleaved influence panel pair: `n × pc × B` floats,
/// element `(row k, compact col c, lane s)` at `k*pc*B + c*B + s`.
#[derive(Debug, Clone)]
struct Panel {
    n: usize,
    pc: usize,
    cur: Vec<f32>,
    next: Vec<f32>,
}

/// Exact parameter-sparse RTRL over a batch of sessions sharing one
/// weight+mask set (see module docs). Owns a clone of the shared stack, so
/// stepping needs no external network borrow — the session pool hands it
/// per-lane readouts, losses and op counters only.
pub struct BatchedSparse {
    net: LayerStack,
    batch: usize,
    colmap: StackColumnMap,
    panels: Vec<Panel>,
    slab: BatchedSlab,
    /// Per-lane step scratch / previous state / gradient accumulators.
    scratch: Vec<StackScratch>,
    a_prev: Vec<Vec<f32>>,
    grad_compact: Vec<Vec<f32>>,
    grads: Vec<Vec<f32>>,
    /// Readout scratch, reused serially across lanes.
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    c_bar: Vec<f32>,
    /// Per-row per-lane `φ'` staging for `scale_flush_panel` (`n·B`).
    dphi: Vec<f32>,
    threads: usize,
    /// Whether the *current* panels carry live rows (step ≥ 2 of a
    /// sequence). Structural in parameter mode, hence one flag for the
    /// whole group — `load_lane` rejects states that disagree.
    cur_active: bool,
    measure_influence: bool,
}

impl BatchedSparse {
    /// Build for `batch` lanes over a shared stack (cloned; parameter-mode
    /// column compaction). `readout_n_out` sizes the readout scratch.
    pub fn new(net: &LayerStack, readout_n_out: usize, batch: usize) -> Self {
        assert!(batch >= 1, "batch width must be at least 1");
        let colmap = StackColumnMap::from_stack(net, true);
        let panels: Vec<Panel> = (0..net.layers())
            .map(|l| {
                let (n, pc) = (net.layer(l).n(), colmap.cum_cols(l));
                Panel { n, pc, cur: vec![0.0; n * pc * batch], next: vec![0.0; n * pc * batch] }
            })
            .collect();
        let pc_total = colmap.total_cols();
        let top_n = net.top_n();
        let total_units = net.total_units();
        let p = net.p();
        let scratch = (0..batch).map(|_| net.scratch()).collect();
        BatchedSparse {
            net: net.clone(),
            batch,
            colmap,
            panels,
            slab: BatchedSlab::new(),
            scratch,
            a_prev: vec![vec![0.0; total_units]; batch],
            grad_compact: vec![vec![0.0; pc_total]; batch],
            grads: vec![vec![0.0; p]; batch],
            logits: vec![0.0; readout_n_out],
            dlogits: vec![0.0; readout_n_out],
            c_bar: vec![0.0; top_n],
            dphi: Vec::new(),
            threads: 1,
            cur_active: false,
            measure_influence: false,
        }
    }

    /// Batch width (number of lanes).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// The cloned shared stack this engine steps.
    pub fn net(&self) -> &LayerStack {
        &self.net
    }

    /// Worker threads for the panel-row update (`0` = hardware count).
    /// Bit-identical results at any value.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = crate::util::pool::resolve_workers(threads);
    }

    pub fn set_measure_influence(&mut self, on: bool) {
        self.measure_influence = on;
    }

    /// Reset every lane to the start of a sequence.
    pub fn begin_sequence(&mut self) {
        for p in &mut self.panels {
            p.cur.iter_mut().for_each(|x| *x = 0.0);
            p.next.iter_mut().for_each(|x| *x = 0.0);
        }
        for s in 0..self.batch {
            self.a_prev[s].iter_mut().for_each(|x| *x = 0.0);
            self.grad_compact[s].iter_mut().for_each(|x| *x = 0.0);
            self.grads[s].iter_mut().for_each(|x| *x = 0.0);
        }
        self.cur_active = false;
    }

    /// Advance every lane one timestep. `xs[s]`/`targets[s]` are lane
    /// `s`'s input and supervision; `readouts[s]`/`losses[s]`/`ops[s]` its
    /// session-owned readout, loss and op counter. Returns one
    /// [`StepResult`] per lane.
    pub fn step(
        &mut self,
        xs: &[&[f32]],
        targets: &[Target<'_>],
        readouts: &mut [&mut Readout],
        losses: &mut [&mut Loss],
        ops: &mut [&mut OpCounter],
    ) -> Vec<StepResult> {
        let b = self.batch;
        assert_eq!(xs.len(), b, "one input per lane");
        assert_eq!(targets.len(), b, "one target per lane");
        assert_eq!(readouts.len(), b, "one readout per lane");
        assert_eq!(losses.len(), b, "one loss per lane");
        assert_eq!(ops.len(), b, "one op counter per lane");

        // ---- forward, per lane (charges per-layer Forward ops) ----------
        for s in 0..b {
            self.net.forward(&self.a_prev[s], xs[s], &mut self.scratch[s], ops[s]);
        }

        // ---- influence update: one shared structure, fused panels -------
        let layers = self.net.layers();
        for l in 0..layers {
            for o in ops.iter_mut() {
                o.set_layer(l);
            }
            let cell = self.net.layer(l);
            let n_l = cell.n();
            let dv_da_cost = cell.dv_da_cost();
            let dv_dx_cost = cell.dv_dx_cost();
            let pc_lower = if l > 0 { self.colmap.cum_cols(l - 1) } else { 0 };

            // (1) shared structure + per-lane value fill. Per-lane counts
            // equal a solo parameter-mode build of the same step.
            let counts = self.slab.build_structure(cell, self.cur_active, l > 0, b);
            for s in 0..b {
                self.slab.fill_lane(s, cell, &self.scratch[s].layers[l]);
            }
            let jac_macs = counts.own_entries * dv_da_cost + counts.cross_entries * dv_dx_cost;

            // (2) stage the per-row per-lane φ' gates, row-major.
            self.dphi.clear();
            for k in 0..n_l {
                for s in 0..b {
                    self.dphi.push(self.scratch[s].layers[l].dphi[k]);
                }
            }

            // (3) panel-row update. The lower layer's panel was finished
            // earlier in this same loop (block lower-bidiagonal order).
            let (lower_panels, rest) = self.panels.split_at_mut(l);
            let lower = lower_panels.last();
            let panel = &mut rest[0];
            let pc_l = panel.pc;
            let cur: &[f32] = &panel.cur;
            let next: &mut [f32] = &mut panel.next;
            let srange = self.net.layout().state_range(l);
            let (srange0, srange1) = (srange.start, srange.end);
            let slab = &self.slab;
            let colmap = &self.colmap;
            let scratch = &self.scratch;
            let a_prev = &self.a_prev;
            let dphi = &self.dphi;
            let update_row = |k: usize, row: &mut [f32]| -> (u64, u64, Vec<u64>) {
                // Own-layer gather: Σ_c J[k,c] · M_l^{(t-1)}[c], all lanes.
                let (cols, vals) = slab.own_row(k);
                kernels::gather_panel(row, cols, vals, |c| &cur[c * pc_l * b..(c + 1) * pc_l * b], b);
                let mut rows_read = cols.len() as u64;
                let mut upd_macs = cols.len() as u64 * pc_l as u64;
                // Cross-layer block into the leading pc_lower panel slice.
                if let Some(lo) = lower {
                    let cvals = slab.cross_row(k);
                    for (e, &j) in slab.cross_cols().iter().enumerate() {
                        let j = j as usize;
                        kernels::axpy_panel(
                            &mut row[..pc_lower * b],
                            &cvals[e * b..(e + 1) * b],
                            &lo.next[j * lo.pc * b..(j + 1) * lo.pc * b],
                            b,
                        );
                    }
                    rows_read += slab.cross_cols().len() as u64;
                    upd_macs += slab.cross_cols().len() as u64 * pc_lower as u64;
                }
                // Immediate influence M̄ row k, per lane (value-dependent).
                let mut emitted = vec![0u64; b];
                for s in 0..b {
                    let sl = &scratch[s].layers[l];
                    let a_prev_l = &a_prev[s][srange0..srange1];
                    let input_l: &[f32] = if l == 0 { xs[s] } else { &scratch[s].layers[l - 1].a };
                    emitted[s] +=
                        cell.immediate_row_visit(sl, a_prev_l, input_l, k, |pi, val| {
                            row[colmap.global_compact_of(l, pi) * b + s] += val;
                        });
                }
                // Row gate φ'(v_k), per lane, with flush-to-zero.
                kernels::scale_flush_panel(row, &dphi[k * b..(k + 1) * b], b);
                upd_macs += pc_l as u64;
                (rows_read, upd_macs, emitted)
            };

            let panel_elems = (n_l * pc_l * b) as u64;
            let stats: Vec<(u64, u64, Vec<u64>)> =
                if self.threads > 1 && n_l > 1 && panel_elems >= PAR_MIN_PANEL_ELEMS {
                    let jobs: Vec<(usize, &mut [f32])> =
                        next.chunks_mut(pc_l * b).enumerate().collect();
                    kernels::for_each_row_parallel(jobs, self.threads, |(k, row)| {
                        update_row(k, row)
                    })
                } else {
                    next.chunks_mut(pc_l * b).enumerate().map(|(k, row)| update_row(k, row)).collect()
                };

            // Charges: structural counts identical for every lane (built
            // once, charged N times); Immediate is per-lane.
            let (mut rows_read, mut upd_macs) = (0u64, 0u64);
            let mut emitted = vec![0u64; b];
            for (rr, um, em) in &stats {
                rows_read += rr;
                upd_macs += um;
                for s in 0..b {
                    emitted[s] += em[s];
                }
            }
            for (s, o) in ops.iter_mut().enumerate() {
                o.macs(Phase::Jacobian, jac_macs);
                o.macs(Phase::Immediate, emitted[s]);
                o.macs(Phase::InfluenceUpdate, upd_macs);
                o.words(Phase::InfluenceUpdate, (n_l as u64 + rows_read) * pc_l as u64);
            }
        }
        for o in ops.iter_mut() {
            o.clear_layer();
        }

        // ---- loss + gradient accumulation, per lane ---------------------
        let top_l = layers - 1;
        let pc_total = self.colmap.total_cols();
        let mut results = Vec::with_capacity(b);
        for s in 0..b {
            let (loss_val, correct, prediction) = supervised_step(
                readouts[s],
                losses[s],
                &self.scratch[s].top().a,
                targets[s],
                &mut self.logits,
                &mut self.dlogits,
                &mut self.c_bar,
                ops[s],
            );
            if loss_val.is_some() {
                let top = &self.panels[top_l];
                let mut grad_macs = 0u64;
                for k in 0..top.n {
                    let coef = self.c_bar[k];
                    if coef == 0.0 {
                        continue;
                    }
                    let row = &top.next[k * top.pc * b..(k + 1) * top.pc * b];
                    for (c, g) in self.grad_compact[s].iter_mut().enumerate() {
                        *g += coef * row[c * b + s];
                    }
                    grad_macs += pc_total as u64;
                }
                ops[s].macs(Phase::GradCombine, grad_macs);
            }

            let influence_sparsity = if self.measure_influence {
                let logical = (self.a_prev[s].len() * self.colmap.p()) as f64;
                let nonzero: usize = self
                    .panels
                    .iter()
                    .map(|p| p.next.iter().skip(s).step_by(b).filter(|&&v| v != 0.0).count())
                    .sum();
                Some((1.0 - nonzero as f64 / logical) as f32)
            } else {
                None
            };

            results.push(StepResult {
                loss: loss_val,
                correct,
                prediction,
                active_units: self.scratch[s].active_units(),
                deriv_units: self.scratch[s].deriv_units(),
                influence_sparsity,
            });
        }

        // ---- rotate state ----------------------------------------------
        for p in &mut self.panels {
            std::mem::swap(&mut p.cur, &mut p.next);
        }
        for s in 0..b {
            self.scratch[s].write_state(&mut self.a_prev[s]);
        }
        self.cur_active = true;
        results
    }

    /// Materialize every lane's dense `R^P` gradient from its compact
    /// accumulator (the solo engine's `end_sequence`).
    pub fn end_sequence(&mut self) {
        for s in 0..self.batch {
            self.grads[s].iter_mut().for_each(|x| *x = 0.0);
            self.colmap.scatter_add(&self.net, &self.grad_compact[s], 1.0, &mut self.grads[s]);
        }
    }

    /// Lane `s`'s dense gradient (valid after [`Self::end_sequence`]).
    pub fn grads(&self, lane: usize) -> &[f32] {
        &self.grads[lane]
    }

    /// Lane `s`'s current activations `a ∈ R^N`.
    pub fn activations(&self, lane: usize) -> &[f32] {
        &self.a_prev[lane]
    }

    /// Snapshot lane `lane` in the solo `rtrl-param` [`EngineState`]
    /// format: a [`super::SparseRtrl`] built for the same stack loads it
    /// via `load_state` and continues bit-identically, and vice versa.
    pub fn save_lane(&self, lane: usize) -> EngineState {
        let mut st = EngineState::new("rtrl-param", SPARSE_STATE_VERSION);
        st.put_scalar("layers", self.panels.len() as u64);
        for (l, p) in self.panels.iter().enumerate() {
            let (rows, vals) = if self.cur_active {
                let rows: Vec<u64> = (0..p.n as u64).collect();
                let mut vals = Vec::with_capacity(p.n * p.pc);
                for k in 0..p.n {
                    for c in 0..p.pc {
                        vals.push(p.cur[(k * p.pc + c) * self.batch + lane]);
                    }
                }
                (rows, vals)
            } else {
                (Vec::new(), Vec::new())
            };
            st.put_ints(&format!("rows_{l}"), rows);
            st.put_floats(&format!("vals_{l}"), vals);
        }
        st.put_floats("a_prev", self.a_prev[lane].clone());
        st.put_floats("grad_compact", self.grad_compact[lane].clone());
        st.put_floats("grads", self.grads[lane].clone());
        st
    }

    /// Restore lane `lane` from a solo `rtrl-param` snapshot. Lanes must
    /// be loaded in ascending order starting at lane 0: the parameter-mode
    /// structure is shared, so lane 0's "are the current panels live"
    /// state becomes the group's, and later lanes must agree. States with
    /// a *partial* active row set (possible only for a snapshot that never
    /// was parameter-mode) are rejected — callers fall back to solo
    /// stepping on any error.
    pub fn load_lane(&mut self, lane: usize, state: &EngineState) -> Result<(), StateError> {
        state.require("rtrl-param", SPARSE_STATE_VERSION)?;
        if state.scalar("layers")? != self.panels.len() as u64 {
            return Err(StateError(format!(
                "snapshot has {} influence layers, batched engine has {}",
                state.scalar("layers")?,
                self.panels.len()
            )));
        }
        let a = state.floats_exact("a_prev", self.a_prev[lane].len())?;
        let gc = state.floats_exact("grad_compact", self.grad_compact[lane].len())?;
        let g = state.floats_exact("grads", self.grads[lane].len())?;
        // Validate every layer before mutating anything.
        let mut active = None;
        for (l, p) in self.panels.iter().enumerate() {
            let rows = state.ints(&format!("rows_{l}"))?;
            let vals = state.floats(&format!("vals_{l}"))?;
            if vals.len() != rows.len() * p.pc {
                return Err(StateError(format!(
                    "snapshot layer {l} holds {} values for {} rows × {} cols",
                    vals.len(),
                    rows.len(),
                    p.pc
                )));
            }
            let layer_active = !rows.is_empty();
            if layer_active {
                let mut sorted: Vec<u64> = rows.to_vec();
                sorted.sort_unstable();
                if sorted.len() != p.n || sorted.iter().enumerate().any(|(k, &r)| r != k as u64) {
                    return Err(StateError(format!(
                        "snapshot layer {l} has a partial active set ({} of {} rows) — \
                         not a parameter-mode state",
                        rows.len(),
                        p.n
                    )));
                }
            }
            match active {
                None => active = Some(layer_active),
                Some(a) if a != layer_active => {
                    return Err(StateError(format!(
                        "snapshot layer {l} activity disagrees with earlier layers"
                    )));
                }
                _ => {}
            }
        }
        let active = active.unwrap_or(false);
        if lane == 0 {
            self.cur_active = active;
        } else if active != self.cur_active {
            return Err(StateError(
                "lane state's panel activity disagrees with the group's".into(),
            ));
        }
        // Commit.
        let b = self.batch;
        for (l, p) in self.panels.iter_mut().enumerate() {
            let rows = state.ints(&format!("rows_{l}"))?;
            let vals = state.floats(&format!("vals_{l}"))?;
            for slot in p.cur.iter_mut().skip(lane).step_by(b) {
                *slot = 0.0;
            }
            for slot in p.next.iter_mut().skip(lane).step_by(b) {
                *slot = 0.0;
            }
            for (i, &k) in rows.iter().enumerate() {
                let k = k as usize;
                for c in 0..p.pc {
                    p.cur[(k * p.pc + c) * b + lane] = vals[i * p.pc + c];
                }
            }
        }
        self.a_prev[lane].copy_from_slice(a);
        self.grad_compact[lane].copy_from_slice(gc);
        self.grads[lane].copy_from_slice(g);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{GradientEngine, SparseRtrl, SparsityMode};
    use super::*;
    use crate::nn::{LossKind, RnnCell};
    use crate::sparse::MaskPattern;
    use crate::util::Pcg64;

    fn make_net(seed: u64) -> LayerStack {
        let mut rng = Pcg64::new(seed);
        let mask = MaskPattern::random(8, 8, 0.4, &mut rng);
        LayerStack::single(RnnCell::egru(8, 2, 0.05, 0.3, 0.9, Some(mask), &mut rng))
    }

    fn lane_inputs(lane: u64, t: u64) -> Vec<f32> {
        let mut r = Pcg64::new(0x1000 + lane * 97 + t);
        vec![r.normal(), r.normal()]
    }

    /// Lane 0 of a width-3 batched run must be bit-identical to a width-1
    /// batched run of the same session — gradients, losses and op counts.
    #[test]
    fn lane_zero_is_bit_identical_across_batch_widths() {
        let net = make_net(51);
        let run = |b: usize| {
            let mut readouts: Vec<Readout> =
                (0..b).map(|_| Readout::new(2, 8, &mut Pcg64::new(7))).collect();
            let mut losses: Vec<Loss> =
                (0..b).map(|_| Loss::new(LossKind::CrossEntropy, 2)).collect();
            let mut counters: Vec<OpCounter> = (0..b).map(|_| OpCounter::new()).collect();
            let mut eng = BatchedSparse::new(&net, 2, b);
            eng.begin_sequence();
            let mut lane0_losses = Vec::new();
            for t in 0..6u64 {
                let xs: Vec<Vec<f32>> = (0..b as u64).map(|s| lane_inputs(s, t)).collect();
                let xrefs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
                let tgts: Vec<Target> = (0..b)
                    .map(|_| if t % 2 == 1 { Target::Class(0) } else { Target::None })
                    .collect();
                let mut rref: Vec<&mut Readout> = readouts.iter_mut().collect();
                let mut lref: Vec<&mut Loss> = losses.iter_mut().collect();
                let mut oref: Vec<&mut OpCounter> = counters.iter_mut().collect();
                let rs = eng.step(&xrefs, &tgts, &mut rref, &mut lref, &mut oref);
                lane0_losses.push(rs[0].loss.map(f32::to_bits));
            }
            eng.end_sequence();
            (eng.grads(0).to_vec(), lane0_losses, counters[0].to_words_vec())
        };
        let (g1, l1, o1) = run(1);
        let (g3, l3, o3) = run(3);
        assert_eq!(l1, l3, "lane-0 losses diverged across batch widths");
        assert_eq!(o1, o3, "lane-0 op counts diverged across batch widths");
        assert_eq!(
            g1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            g3.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "lane-0 gradient diverged across batch widths"
        );
    }

    /// Batched lanes must match a solo SparseRtrl parameter-mode run to FP
    /// tolerance (exact up to the solo path's zero-coefficient filtering).
    #[test]
    fn lanes_match_solo_parameter_engine() {
        let net = make_net(52);
        let b = 2;
        let mut eng = BatchedSparse::new(&net, 2, b);
        let mut readouts: Vec<Readout> =
            (0..b).map(|_| Readout::new(2, 8, &mut Pcg64::new(9))).collect();
        let mut losses: Vec<Loss> =
            (0..b).map(|_| Loss::new(LossKind::CrossEntropy, 2)).collect();
        let mut counters: Vec<OpCounter> = (0..b).map(|_| OpCounter::new()).collect();
        eng.begin_sequence();
        for t in 0..5u64 {
            let xs: Vec<Vec<f32>> = (0..b as u64).map(|s| lane_inputs(s, t)).collect();
            let xrefs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let tgts: Vec<Target> =
                (0..b).map(|_| if t == 4 { Target::Class(1) } else { Target::None }).collect();
            let mut rref: Vec<&mut Readout> = readouts.iter_mut().collect();
            let mut lref: Vec<&mut Loss> = losses.iter_mut().collect();
            let mut oref: Vec<&mut OpCounter> = counters.iter_mut().collect();
            eng.step(&xrefs, &tgts, &mut rref, &mut lref, &mut oref);
        }
        eng.end_sequence();

        for lane in 0..b {
            let mut solo = SparseRtrl::new(&net, 2, SparsityMode::Parameter);
            let mut readout = Readout::new(2, 8, &mut Pcg64::new(9));
            let mut loss = Loss::new(LossKind::CrossEntropy, 2);
            let mut ops = OpCounter::new();
            solo.begin_sequence();
            for t in 0..5u64 {
                let x = lane_inputs(lane as u64, t);
                let tgt = if t == 4 { Target::Class(1) } else { Target::None };
                solo.step(&net, &mut readout, &mut loss, &x, tgt, &mut ops);
            }
            solo.end_sequence(&net, &mut readout, &mut ops);
            let solo_g = solo.grads();
            let batched_g = eng.grads(lane);
            assert_eq!(solo_g.len(), batched_g.len());
            for (i, (a, c)) in solo_g.iter().zip(batched_g).enumerate() {
                assert!(
                    (a - c).abs() <= 1e-5 * (1.0 + a.abs()),
                    "lane {lane} grad[{i}]: solo {a} vs batched {c}"
                );
            }
        }
    }

    /// Lane snapshots round-trip through the solo engine's state format in
    /// both directions, and a continued run stays on track.
    #[test]
    fn lane_state_interoperates_with_solo_engine() {
        let net = make_net(53);
        let b = 2;
        let mut eng = BatchedSparse::new(&net, 2, b);
        let mut readouts: Vec<Readout> =
            (0..b).map(|_| Readout::new(2, 8, &mut Pcg64::new(13))).collect();
        let mut losses: Vec<Loss> =
            (0..b).map(|_| Loss::new(LossKind::CrossEntropy, 2)).collect();
        let mut counters: Vec<OpCounter> = (0..b).map(|_| OpCounter::new()).collect();
        eng.begin_sequence();
        for t in 0..3u64 {
            let xs: Vec<Vec<f32>> = (0..b as u64).map(|s| lane_inputs(s, t)).collect();
            let xrefs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let tgts = vec![Target::None; b];
            let mut rref: Vec<&mut Readout> = readouts.iter_mut().collect();
            let mut lref: Vec<&mut Loss> = losses.iter_mut().collect();
            let mut oref: Vec<&mut OpCounter> = counters.iter_mut().collect();
            eng.step(&xrefs, &tgts, &mut rref, &mut lref, &mut oref);
        }
        // batched lane -> solo engine
        let st = eng.save_lane(1);
        let mut solo = SparseRtrl::new(&net, 2, SparsityMode::Parameter);
        solo.load_state(&net, &st).expect("solo engine loads a batched lane snapshot");
        // solo engine -> batched lane (fresh group)
        let solo_st = solo.save_state();
        let mut eng2 = BatchedSparse::new(&net, 2, b);
        eng2.load_lane(0, &st).expect("lane 0 loads");
        eng2.load_lane(1, &solo_st).expect("lane 1 loads a solo snapshot");
        // a fresh-sequence lane cannot join a mid-sequence group
        let mut eng3 = BatchedSparse::new(&net, 2, b);
        eng3.load_lane(0, &st).expect("lane 0 loads");
        let fresh = SparseRtrl::new(&net, 2, SparsityMode::Parameter).save_state();
        assert!(
            eng3.load_lane(1, &fresh).is_err(),
            "mixed fresh/mid-sequence lanes must be rejected"
        );
    }
}
