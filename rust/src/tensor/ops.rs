//! Vector helper operations used across the engines.

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

/// Dot product.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Elementwise product into `out`.
pub fn hadamard_into(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x * y;
    }
}

/// `out = a - b`.
pub fn sub_into(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// Scale in place.
pub fn scale(x: &mut [f32], alpha: f32) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// L2 norm.
pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// Argmax index (first on ties); panics on empty input.
pub fn argmax(x: &[f32]) -> usize {
    assert!(!x.is_empty());
    let mut best = 0;
    for (i, &v) in x.iter().enumerate() {
        if v > x[best] {
            best = i;
        }
    }
    best
}

/// Fraction of exactly-zero entries.
pub fn zero_fraction(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 1.0;
    }
    x.iter().filter(|&&v| v == 0.0).count() as f32 / x.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_known() {
        let mut y = [1.0, 2.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, [7.0, 10.0]);
    }

    #[test]
    fn dot_known() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn hadamard_known() {
        let mut out = [0.0; 2];
        hadamard_into(&[2.0, 3.0], &[4.0, 5.0], &mut out);
        assert_eq!(out, [8.0, 15.0]);
    }

    #[test]
    fn argmax_first_on_tie() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }

    #[test]
    fn zero_fraction_counts() {
        assert_eq!(zero_fraction(&[0.0, 1.0, 0.0, 0.0]), 0.75);
    }

    #[test]
    fn norm2_pythagorean() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}
