//! Row-major dense matrix.

use crate::util::Pcg64;

/// Row-major dense `rows × cols` matrix of `f32`.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from an explicit row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Matrix { rows, cols, data }
    }

    /// Glorot/Xavier-uniform init: `U(-s, s)`, `s = sqrt(6/(fan_in+fan_out))`.
    pub fn glorot(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let s = (6.0 / (rows + cols) as f32).sqrt();
        let data = (0..rows * cols).map(|_| rng.uniform(-s, s)).collect();
        Matrix { rows, cols, data }
    }

    /// Uniform init in `[lo, hi)`.
    pub fn uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Pcg64) -> Self {
        let data = (0..rows * cols).map(|_| rng.uniform(lo, hi)).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Immutable view of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable view of row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Split the buffer at the start of row `r`: `(rows 0..r, rows r..)`,
    /// both row-major. Lets a caller read earlier rows while writing later
    /// ones — the borrow pattern of the stacked RTRL update, where layer
    /// `l`'s new influence rows gather from layer `l−1`'s already-written
    /// rows of the *same* panel.
    #[inline]
    pub fn split_at_row_mut(&mut self, r: usize) -> (&mut [f32], &mut [f32]) {
        debug_assert!(r <= self.rows);
        self.data.split_at_mut(r * self.cols)
    }

    /// Full row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Full mutable row-major buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Set every element to zero (reused per-sequence to avoid realloc).
    pub fn fill_zero(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// `y = self · x` (matrix–vector product) into `y`.
    pub fn matvec_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let row = self.row(r);
            let mut acc = 0.0f32;
            for (w, xv) in row.iter().zip(x) {
                acc += w * xv;
            }
            y[r] = acc;
        }
    }

    /// `y += self · x`.
    pub fn matvec_add_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            let row = self.row(r);
            let mut acc = 0.0f32;
            for (w, xv) in row.iter().zip(x) {
                acc += w * xv;
            }
            y[r] += acc;
        }
    }

    /// `y = selfᵀ · x` (used by readout backward).
    pub fn tmatvec_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.iter_mut().for_each(|v| *v = 0.0);
        for r in 0..self.rows {
            let row = self.row(r);
            let xr = x[r];
            if xr == 0.0 {
                continue;
            }
            for (yc, w) in y.iter_mut().zip(row) {
                *yc += w * xr;
            }
        }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.set(c, r, self.get(r, c));
            }
        }
        t
    }

    /// Dense `self · other` (tests / small readouts only — the RTRL hot path
    /// never calls this).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for r in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(r, k);
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(r);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Count of exactly-zero entries.
    pub fn count_zeros(&self) -> usize {
        self.data.iter().filter(|&&x| x == 0.0).count()
    }

    /// Fraction of exactly-zero entries (`1.0` for an empty matrix).
    pub fn sparsity(&self) -> f32 {
        if self.data.is_empty() {
            1.0
        } else {
            self.count_zeros() as f32 / self.data.len() as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape_and_values() {
        let m = Matrix::zeros(3, 4);
        assert_eq!((m.rows(), m.cols(), m.len()), (3, 4, 12));
        assert!(m.as_slice().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn get_set_roundtrip() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn matvec_known_values() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let x = [1.0, 0.0, -1.0];
        let mut y = [0.0; 2];
        m.matvec_into(&x, &mut y);
        assert_eq!(y, [-2.0, -2.0]);
    }

    #[test]
    fn matvec_add_accumulates() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let mut y = [10.0, 20.0];
        m.matvec_add_into(&[1.0, 2.0], &mut y);
        assert_eq!(y, [11.0, 22.0]);
    }

    #[test]
    fn tmatvec_matches_transpose_matvec() {
        let mut rng = Pcg64::new(1);
        let m = Matrix::glorot(4, 3, &mut rng);
        let x: Vec<f32> = (0..4).map(|i| i as f32 - 1.5).collect();
        let mut y1 = vec![0.0; 3];
        m.tmatvec_into(&x, &mut y1);
        let mut y2 = vec![0.0; 3];
        m.transpose().matvec_into(&x, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::new(2);
        let m = Matrix::glorot(3, 3, &mut rng);
        let eye = Matrix::from_vec(3, 3, vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]);
        assert_eq!(m.matmul(&eye).as_slice(), m.as_slice());
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::new(3);
        let m = Matrix::glorot(5, 2, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn glorot_within_bound() {
        let mut rng = Pcg64::new(4);
        let m = Matrix::glorot(10, 20, &mut rng);
        let s = (6.0 / 30.0f32).sqrt();
        assert!(m.as_slice().iter().all(|&x| x.abs() <= s));
    }

    #[test]
    fn sparsity_counts() {
        let m = Matrix::from_vec(2, 2, vec![0.0, 1.0, 0.0, 2.0]);
        assert_eq!(m.count_zeros(), 2);
        assert_eq!(m.sparsity(), 0.5);
    }
}
