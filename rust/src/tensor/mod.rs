//! Minimal dense f32 tensor substrate.
//!
//! The RTRL engines operate on row-major [`Matrix`] buffers plus plain
//! `&[f32]` vectors. This is intentionally a small, fully-owned substrate —
//! the paper's compute model counts multiply-accumulates on unstructured
//! sparse data, so the engines need direct index-level control over every
//! inner loop rather than a BLAS facade.

pub mod dense;
pub mod ops;

pub use dense::Matrix;
