//! Typed experiment configuration, (de)serialized via the in-tree
//! TOML-subset parser (`util::toml_mini`).

use crate::util::toml_mini::{escape, Doc};

/// Which cell family to build (see `nn::RnnCell` constructors).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellKind {
    /// EGRU (Eq.-5 gated + Heaviside) — activity sparse.
    Egru,
    /// Thresholded vanilla RNN (EvNN) — activity sparse.
    EvRnn,
    /// Gated + tanh — the "without activity sparsity" control.
    GatedTanh,
    /// Vanilla tanh RNN.
    Vanilla,
}

impl CellKind {
    pub fn is_event_based(self) -> bool {
        matches!(self, CellKind::Egru | CellKind::EvRnn)
    }

    pub fn name(self) -> &'static str {
        match self {
            CellKind::Egru => "egru",
            CellKind::EvRnn => "ev_rnn",
            CellKind::GatedTanh => "gated_tanh",
            CellKind::Vanilla => "vanilla",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "egru" => CellKind::Egru,
            "ev_rnn" => CellKind::EvRnn,
            "gated_tanh" => CellKind::GatedTanh,
            "vanilla" => CellKind::Vanilla,
            _ => return None,
        })
    }
}

/// Which gradient algorithm trains the recurrent weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgorithmKind {
    RtrlDense,
    RtrlActivity,
    RtrlParam,
    RtrlBoth,
    Snap1,
    Snap2,
    Uoro,
    Bptt,
}

impl AlgorithmKind {
    pub fn all() -> [AlgorithmKind; 8] {
        [
            AlgorithmKind::RtrlDense,
            AlgorithmKind::RtrlActivity,
            AlgorithmKind::RtrlParam,
            AlgorithmKind::RtrlBoth,
            AlgorithmKind::Snap1,
            AlgorithmKind::Snap2,
            AlgorithmKind::Uoro,
            AlgorithmKind::Bptt,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            AlgorithmKind::RtrlDense => "rtrl-dense",
            AlgorithmKind::RtrlActivity => "rtrl-activity",
            AlgorithmKind::RtrlParam => "rtrl-param",
            AlgorithmKind::RtrlBoth => "rtrl-both",
            AlgorithmKind::Snap1 => "snap1",
            AlgorithmKind::Snap2 => "snap2",
            AlgorithmKind::Uoro => "uoro",
            AlgorithmKind::Bptt => "bptt",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "rtrl-dense" | "rtrl_dense" => AlgorithmKind::RtrlDense,
            "rtrl-activity" | "rtrl_activity" => AlgorithmKind::RtrlActivity,
            "rtrl-param" | "rtrl_param" => AlgorithmKind::RtrlParam,
            "rtrl-both" | "rtrl_both" => AlgorithmKind::RtrlBoth,
            "snap1" => AlgorithmKind::Snap1,
            "snap2" => AlgorithmKind::Snap2,
            "uoro" => AlgorithmKind::Uoro,
            "bptt" => AlgorithmKind::Bptt,
            _ => return None,
        })
    }
}

/// Model hyperparameters.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub cell: CellKind,
    /// Hidden units n per layer (paper: 16).
    pub hidden: usize,
    /// Stacked recurrent layers L ≥ 1 (layer l reads layer l−1's new
    /// activations; depth 1 is the paper's single-cell configuration).
    pub layers: usize,
    /// Threshold ϑ (event cells).
    pub theta: f32,
    /// Pseudo-derivative height γ.
    pub gamma: f32,
    /// Pseudo-derivative support half-width ε.
    pub eps: f32,
    /// Parameter sparsity ω ∈ [0,1) (fraction of recurrent weights dropped
    /// in every layer; ω̃ = 1−ω kept). 0 = dense.
    pub param_sparsity: f32,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            cell: CellKind::Egru,
            hidden: 16,
            layers: 1,
            theta: 0.1,
            gamma: 0.3,
            // ε = 0.2 gives β ≈ 0.5–0.6 backward sparsity on the spiral task,
            // matching the ~50% the paper reports for EGRU (§1), while still
            // converging; larger ε trains marginally faster but is barely
            // activity-sparse in the backward pass.
            eps: 0.2,
            param_sparsity: 0.0,
        }
    }
}

/// Task selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    Spiral,
    Copy,
    DelayedXor,
}

impl TaskKind {
    pub fn name(self) -> &'static str {
        match self {
            TaskKind::Spiral => "spiral",
            TaskKind::Copy => "copy",
            TaskKind::DelayedXor => "delayed_xor",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "spiral" => TaskKind::Spiral,
            "copy" => TaskKind::Copy,
            "delayed_xor" => TaskKind::DelayedXor,
            _ => return None,
        })
    }
}

/// Task parameters.
#[derive(Debug, Clone)]
pub struct TaskConfig {
    pub task: TaskKind,
    /// Number of sequences (paper: 10 000 spirals).
    pub num_sequences: usize,
    /// Sequence length (paper: 17).
    pub timesteps: usize,
    /// Validation fraction split off the generated data.
    pub val_fraction: f32,
}

impl Default for TaskConfig {
    fn default() -> Self {
        TaskConfig { task: TaskKind::Spiral, num_sequences: 10_000, timesteps: 17, val_fraction: 0.1 }
    }
}

/// Training-loop parameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub algorithm: AlgorithmKind,
    /// Parameter-update iterations (paper: 1700).
    pub iterations: u64,
    /// Batch size (paper: 32).
    pub batch_size: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Log every k iterations (metrics + influence-sparsity scan).
    pub log_every: u64,
    /// Evaluate on validation every k iterations (0 = never).
    pub eval_every: u64,
    /// Validation sequences per evaluation (subsampled for speed).
    pub eval_sequences: usize,
    /// Dynamic rewiring cadence in iterations (0 = fixed mask, the paper's
    /// protocol; >0 enables the Deep-Rewiring-style extension).
    pub rewire_every: u64,
    /// Fraction of kept recurrent entries relocated per rewiring step.
    pub rewire_fraction: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            algorithm: AlgorithmKind::RtrlBoth,
            iterations: 1700,
            batch_size: 32,
            lr: 0.01,
            log_every: 10,
            eval_every: 50,
            eval_sequences: 256,
            rewire_every: 0,
            rewire_fraction: 0.2,
        }
    }
}

/// A complete experiment specification.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Run name (used in result file names).
    pub name: String,
    pub model: ModelConfig,
    pub task: TaskConfig,
    pub train: TrainConfig,
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "spiral-egru".to_string(),
            model: ModelConfig::default(),
            task: TaskConfig::default(),
            train: TrainConfig::default(),
            seed: 1,
        }
    }
}

macro_rules! read_opt {
    ($doc:expr, $sec:expr, $key:expr, $as:ident, $into:expr) => {
        if let Some(v) = $doc.get($sec, $key) {
            *$into = v
                .$as()
                .ok_or_else(|| format!("{}:{} has wrong type", $sec, $key))?
                .try_into()
                .map_err(|_| format!("{}:{} out of range", $sec, $key))?;
        }
    };
}

impl ExperimentConfig {
    /// Parse from TOML text (missing keys keep defaults — partial configs
    /// are how sweeps override a base file).
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = Doc::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        if let Some(v) = doc.get("", "name") {
            cfg.name = v.as_str().ok_or("name must be a string")?.to_string();
        }
        if let Some(v) = doc.get("", "seed") {
            cfg.seed = v.as_i64().ok_or("seed must be an integer")? as u64;
        }
        // [model]
        if let Some(v) = doc.get("model", "cell") {
            let s = v.as_str().ok_or("model:cell must be a string")?;
            cfg.model.cell = CellKind::from_name(s).ok_or_else(|| format!("unknown cell {s:?}"))?;
        }
        read_opt!(doc, "model", "hidden", as_i64, &mut cfg.model.hidden);
        read_opt!(doc, "model", "layers", as_i64, &mut cfg.model.layers);
        read_f32(&doc, "model", "theta", &mut cfg.model.theta)?;
        read_f32(&doc, "model", "gamma", &mut cfg.model.gamma)?;
        read_f32(&doc, "model", "eps", &mut cfg.model.eps)?;
        read_f32(&doc, "model", "param_sparsity", &mut cfg.model.param_sparsity)?;
        // [task]
        if let Some(v) = doc.get("task", "task") {
            let s = v.as_str().ok_or("task:task must be a string")?;
            cfg.task.task = TaskKind::from_name(s).ok_or_else(|| format!("unknown task {s:?}"))?;
        }
        read_opt!(doc, "task", "num_sequences", as_i64, &mut cfg.task.num_sequences);
        read_opt!(doc, "task", "timesteps", as_i64, &mut cfg.task.timesteps);
        read_f32(&doc, "task", "val_fraction", &mut cfg.task.val_fraction)?;
        // [train]
        if let Some(v) = doc.get("train", "algorithm") {
            let s = v.as_str().ok_or("train:algorithm must be a string")?;
            cfg.train.algorithm =
                AlgorithmKind::from_name(s).ok_or_else(|| format!("unknown algorithm {s:?}"))?;
        }
        read_opt!(doc, "train", "iterations", as_i64, &mut cfg.train.iterations);
        read_opt!(doc, "train", "batch_size", as_i64, &mut cfg.train.batch_size);
        read_f32(&doc, "train", "lr", &mut cfg.train.lr)?;
        read_opt!(doc, "train", "log_every", as_i64, &mut cfg.train.log_every);
        read_opt!(doc, "train", "eval_every", as_i64, &mut cfg.train.eval_every);
        read_opt!(doc, "train", "eval_sequences", as_i64, &mut cfg.train.eval_sequences);
        read_opt!(doc, "train", "rewire_every", as_i64, &mut cfg.train.rewire_every);
        read_f32(&doc, "train", "rewire_fraction", &mut cfg.train.rewire_fraction)?;
        if !(0.0..1.0).contains(&cfg.model.param_sparsity) {
            return Err("model:param_sparsity must be in [0,1)".into());
        }
        // An explicit `layers = 0` is a configuration error, not a value to
        // silently clamp: a zero-layer network has no state to train.
        if cfg.model.layers == 0 {
            return Err("model:layers must be ≥ 1 (a zero-depth stack has no recurrent state); omit the key for the single-layer default".into());
        }
        Ok(cfg)
    }

    /// Serialize to TOML text (full round-trip of every field).
    pub fn to_toml(&self) -> String {
        format!(
            "name = {}\nseed = {}\n\n[model]\ncell = {}\nhidden = {}\nlayers = {}\ntheta = {}\ngamma = {}\neps = {}\nparam_sparsity = {}\n\n[task]\ntask = {}\nnum_sequences = {}\ntimesteps = {}\nval_fraction = {}\n\n[train]\nalgorithm = {}\niterations = {}\nbatch_size = {}\nlr = {}\nlog_every = {}\neval_every = {}\neval_sequences = {}\nrewire_every = {}\nrewire_fraction = {}\n",
            escape(&self.name),
            self.seed,
            escape(self.model.cell.name()),
            self.model.hidden,
            self.model.layers,
            fmt_f32(self.model.theta),
            fmt_f32(self.model.gamma),
            fmt_f32(self.model.eps),
            fmt_f32(self.model.param_sparsity),
            escape(self.task.task.name()),
            self.task.num_sequences,
            self.task.timesteps,
            fmt_f32(self.task.val_fraction),
            escape(self.train.algorithm.name()),
            self.train.iterations,
            self.train.batch_size,
            fmt_f32(self.train.lr),
            self.train.log_every,
            self.train.eval_every,
            self.train.eval_sequences,
            self.train.rewire_every,
            fmt_f32(self.train.rewire_fraction),
        )
    }

    /// ω̃ = 1 − ω, the kept fraction.
    pub fn omega_tilde(&self) -> f32 {
        1.0 - self.model.param_sparsity
    }
}

fn read_f32(doc: &Doc, sec: &str, key: &str, into: &mut f32) -> Result<(), String> {
    if let Some(v) = doc.get(sec, key) {
        *into = v.as_f64().ok_or_else(|| format!("{sec}:{key} must be a number"))? as f32;
    }
    Ok(())
}

/// Emit a float so that it parses back as a float (always a dot).
fn fmt_f32(f: f32) -> String {
    let s = format!("{f}");
    if s.contains('.') || s.contains('e') {
        s
    } else {
        format!("{s}.0")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_roundtrip() {
        let mut c = ExperimentConfig::default();
        c.model.param_sparsity = 0.8;
        c.train.algorithm = AlgorithmKind::Snap2;
        c.name = "round \"trip\"".into();
        let text = c.to_toml();
        let back = ExperimentConfig::from_toml(&text).unwrap();
        assert_eq!(back.name, c.name);
        assert_eq!(back.model.hidden, 16);
        assert_eq!(back.train.iterations, 1700);
        assert_eq!(back.train.algorithm, AlgorithmKind::Snap2);
        assert!((back.model.param_sparsity - 0.8).abs() < 1e-6);
    }

    #[test]
    fn parses_partial_overrides() {
        let text = r#"
            name = "custom"
            seed = 7
            [model]
            cell = "ev_rnn"
            param_sparsity = 0.9
            [train]
            algorithm = "rtrl_both"
            iterations = 10
        "#;
        let c = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(c.model.cell, CellKind::EvRnn);
        assert!((c.omega_tilde() - 0.1).abs() < 1e-6);
        assert_eq!(c.train.algorithm, AlgorithmKind::RtrlBoth);
        assert_eq!(c.train.iterations, 10);
        // untouched defaults survive
        assert_eq!(c.train.batch_size, 32);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(ExperimentConfig::from_toml("[model]\ncell = \"nope\"").is_err());
        assert!(ExperimentConfig::from_toml("[model]\nparam_sparsity = 1.5").is_err());
        assert!(ExperimentConfig::from_toml("[train]\nalgorithm = 3").is_err());
    }

    /// Pre-existing experiment TOMLs (written before the `layers` key
    /// existed) must keep parsing, defaulting to the single-layer network —
    /// and any *other* unknown keys they might carry are ignored rather
    /// than fatal (partial configs are how sweeps override a base file).
    #[test]
    fn legacy_toml_without_layers_parses_to_depth_1() {
        let legacy = r#"
            name = "pre-stack experiment"
            seed = 11
            [model]
            cell = "egru"
            hidden = 24
            param_sparsity = 0.8
            # a key from some future/older schema revision:
            dropout = 0.1
            [train]
            algorithm = "rtrl-both"
        "#;
        let c = ExperimentConfig::from_toml(legacy).unwrap();
        assert_eq!(c.model.layers, 1, "missing layers key must default to 1");
        assert_eq!(c.model.hidden, 24);
        assert_eq!(c.train.algorithm, AlgorithmKind::RtrlBoth);
    }

    /// `layers = 0` is a loud error naming the key, never a silent default.
    #[test]
    fn zero_layers_is_a_clear_error() {
        let err = ExperimentConfig::from_toml("[model]\nlayers = 0").unwrap_err();
        assert!(err.contains("layers"), "error should name the offending key: {err}");
        assert!(err.contains("≥ 1") || err.contains(">= 1"), "error should state the bound: {err}");
        // negative values are rejected by the integer conversion
        assert!(ExperimentConfig::from_toml("[model]\nlayers = -2").is_err());
        // and a valid depth round-trips
        let mut c = ExperimentConfig::default();
        c.model.layers = 3;
        let back = ExperimentConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.model.layers, 3);
    }

    #[test]
    fn paper_defaults() {
        let c = ExperimentConfig::default();
        assert_eq!(c.model.hidden, 16);
        assert_eq!(c.task.num_sequences, 10_000);
        assert_eq!(c.task.timesteps, 17);
        assert_eq!(c.train.batch_size, 32);
        assert_eq!(c.train.iterations, 1700);
    }

    #[test]
    fn enum_name_roundtrips() {
        for k in AlgorithmKind::all() {
            assert_eq!(AlgorithmKind::from_name(k.name()), Some(k));
        }
        for c in [CellKind::Egru, CellKind::EvRnn, CellKind::GatedTanh, CellKind::Vanilla] {
            assert_eq!(CellKind::from_name(c.name()), Some(c));
        }
    }
}
