//! Experiment configuration (serde + TOML).
//!
//! One [`ExperimentConfig`] fully determines a run: model, sparsity levels,
//! algorithm, task, optimizer and seed. The sweep coordinator expands a base
//! config across the Fig.-3 grid.

pub mod experiment;

pub use experiment::{
    AlgorithmKind, CellKind, ExperimentConfig, ModelConfig, TaskConfig, TaskKind, TrainConfig,
};
