//! Load generator for the multi-tenant serve loop (`serve` block, schema
//! v7): deterministic seeded tenants with Zipf-skewed arrivals driven
//! through [`crate::serve::Scheduler`], measuring end-to-end events/sec
//! and per-lane-step latency for the fused batched schedule against the
//! naive per-session round-robin baseline, with and without a resident
//! budget.
//!
//! All tenants share one weight seed — the serve scheduler's best case and
//! the configuration the batched-vs-round-robin CI gate measures (batched
//! must clear 1.2× the baseline's events/sec at the quick grid's 64
//! tenants). The workload is a pure function of the bench seed: tenant
//! choice per event comes from inverse-CDF sampling over `1/(i+1)^0.6`
//! weights via [`Pcg64`], never from ambient randomness, so two runs of
//! the same grid enqueue byte-identical event streams.

use crate::config::{AlgorithmKind, ExperimentConfig};
use crate::data::StepTarget;
use crate::serve::{SchedulePolicy, Scheduler, ServeConfig};
use crate::session::{StreamEvent, UpdatePolicy};
use crate::telemetry::names;
use crate::telemetry::HistogramSummary;
use crate::util::math::sum_f64;
use crate::util::Pcg64;

/// Weight seed every bench tenant shares (shared weights → fusable).
pub const TENANT_SEED: u64 = 42;
/// Workload RNG seed (arrival skew + inputs).
pub const WORKLOAD_SEED: u64 = 2023;
/// Zipf-ish skew exponent for tenant arrival weights.
pub const SKEW: f64 = 0.6;
/// Burst length the serve cases run with.
pub const BURST: usize = 16;

/// One measured serve case.
#[derive(Debug, Clone)]
pub struct ServeBenchResult {
    /// `"batched"` or `"round-robin"`.
    pub schedule: &'static str,
    pub tenants: usize,
    /// Resident-session budget (0 = unlimited).
    pub max_resident: usize,
    /// Intra-step kernel threads of each tenant/fused group.
    pub threads: usize,
    /// Burst length (longest fused run per tenant per round).
    pub burst: usize,
    /// Events applied end to end.
    pub events: u64,
    /// Scheduling rounds taken to drain the workload.
    pub rounds: u64,
    /// Wall time of the drain, ns.
    pub wall_ns: u64,
    /// End-to-end throughput: `events / wall`.
    pub events_per_sec: f64,
    /// Per-lane-step latency quantiles (amortized within each bucket call).
    pub p50_step_ns: u64,
    pub p99_step_ns: u64,
    /// Lane-steps that went through the fused shared-weight path.
    pub fused_lane_steps: u64,
    /// Lane-steps that ran per-session.
    pub solo_steps: u64,
    /// Residency churn during the drain.
    pub evictions: u64,
    pub admissions: u64,
}

/// The bench model: big enough that a fused group's panel crosses the
/// kernels' parallel threshold while a solo session stays serial — the
/// regime the batched schedule is built for.
fn bench_base() -> ExperimentConfig {
    let mut base = ExperimentConfig::default();
    base.model.hidden = 32;
    base.model.param_sparsity = 0.8;
    base.train.algorithm = AlgorithmKind::RtrlParam;
    base
}

/// The deterministic workload: `(tenant index, event)` in arrival order.
/// Tenant `i` is drawn with probability ∝ `1/(i+1)^SKEW` (head tenants
/// stay busy every round, tail tenants go idle — the shape that exercises
/// both the full-burst and straggler buckets and, under a budget, LRU
/// churn). Every third event is supervised.
pub fn workload(tenants: usize, events: usize) -> Vec<(usize, StreamEvent)> {
    let mut rng = Pcg64::new(WORKLOAD_SEED);
    let weights: Vec<f64> = (0..tenants).map(|i| 1.0 / ((i + 1) as f64).powf(SKEW)).collect();
    let total = sum_f64(weights.iter().copied());
    let mut out = Vec::with_capacity(events);
    for e in 0..events {
        let mut pick = rng.f64() * total;
        let mut tenant = tenants - 1;
        for (i, w) in weights.iter().enumerate() {
            if pick < *w {
                tenant = i;
                break;
            }
            pick -= *w;
        }
        let x = vec![rng.normal(), rng.normal()];
        let target =
            if e % 3 == 2 { StepTarget::Class(e % 2) } else { StepTarget::None };
        out.push((tenant, StreamEvent::Step { x, target }));
    }
    out
}

/// Run one serve case over the shared workload and measure the drain.
fn run_case(
    schedule: SchedulePolicy,
    tenants: usize,
    max_resident: usize,
    threads: usize,
    events: &[(usize, StreamEvent)],
) -> ServeBenchResult {
    let spill_dir = std::env::temp_dir().join(format!(
        "sparse-rtrl-serve-bench-{}-{}-{}-{}",
        std::process::id(),
        schedule.name(),
        tenants,
        max_resident
    ));
    let cfg = ServeConfig {
        base: bench_base(),
        policy: UpdatePolicy::Manual,
        threads,
        max_resident,
        burst: BURST,
        spill_dir: spill_dir.clone(),
        schedule,
    };
    let mut sched = match Scheduler::new(cfg) {
        Ok(s) => s,
        Err(e) => {
            // a bench case that cannot set up reports a zeroed row rather
            // than poisoning the whole report
            eprintln!("serve bench: {e}");
            return zero_result(schedule, tenants, max_resident, threads);
        }
    };
    let mut ok = true;
    for i in 0..tenants {
        ok &= sched.open(&format!("t{i:03}"), Some(TENANT_SEED)).is_ok();
    }
    let mut queues: Vec<Vec<StreamEvent>> = vec![Vec::new(); tenants];
    for (tenant, ev) in events {
        queues[*tenant].push(ev.clone());
    }
    for (i, q) in queues.into_iter().enumerate() {
        if !q.is_empty() {
            ok &= sched.enqueue(&format!("t{i:03}"), q).is_ok();
        }
    }
    let t0 = std::time::Instant::now();
    let rounds = match sched.run_until_idle() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("serve bench: {e}");
            ok = false;
            0
        }
    };
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let rec = sched.recorder();
    let latency = rec
        .histogram(names::SERVE_STEP_NS)
        .map(HistogramSummary::from_histogram)
        .unwrap_or(HistogramSummary { count: 0, sum: 0, min: 0, max: 0, p50: 0, p99: 0 });
    let snap = sched.stats();
    let applied = rec.counter_value(names::SERVE_EVENTS);
    std::fs::remove_dir_all(&spill_dir).ok();
    if !ok {
        return zero_result(schedule, tenants, max_resident, threads);
    }
    ServeBenchResult {
        schedule: schedule.name(),
        tenants,
        max_resident,
        threads,
        burst: BURST,
        events: applied,
        rounds,
        wall_ns,
        events_per_sec: if wall_ns > 0 {
            applied as f64 * 1e9 / wall_ns as f64
        } else {
            0.0
        },
        p50_step_ns: latency.p50,
        p99_step_ns: latency.p99,
        fused_lane_steps: rec.counter_value(names::SERVE_FUSED_STEPS),
        solo_steps: rec.counter_value(names::SERVE_SOLO_STEPS),
        evictions: snap.evictions,
        admissions: snap.admissions,
    }
}

fn zero_result(
    schedule: SchedulePolicy,
    tenants: usize,
    max_resident: usize,
    threads: usize,
) -> ServeBenchResult {
    ServeBenchResult {
        schedule: schedule.name(),
        tenants,
        max_resident,
        threads,
        burst: BURST,
        events: 0,
        rounds: 0,
        wall_ns: 0,
        events_per_sec: 0.0,
        p50_step_ns: 0,
        p99_step_ns: 0,
        fused_lane_steps: 0,
        solo_steps: 0,
        evictions: 0,
        admissions: 0,
    }
}

/// Measure the serve grid: for each tenant count, the batched schedule
/// (unlimited residency), the round-robin baseline (the CI gate's
/// denominator), and the batched schedule under a half-capacity resident
/// budget (spill/cold-start in the loop). Every case replays the identical
/// workload. `events == 0` skips the grid entirely (how the CI invariance
/// arms opt out of serve timing they don't assert on).
pub fn measure(tenant_counts: &[usize], events: usize, threads: usize) -> Vec<ServeBenchResult> {
    let mut out = Vec::new();
    if events == 0 {
        return out;
    }
    for &tenants in tenant_counts {
        let tenants = tenants.max(1);
        let load = workload(tenants, events);
        out.push(run_case(SchedulePolicy::Batched, tenants, 0, threads, &load));
        out.push(run_case(SchedulePolicy::RoundRobin, tenants, 0, threads, &load));
        out.push(run_case(
            SchedulePolicy::Batched,
            tenants,
            (tenants / 2).max(1),
            threads,
            &load,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_is_deterministic_and_skewed() {
        let a = workload(8, 400);
        let b = workload(8, 400);
        assert_eq!(a.len(), 400);
        assert_eq!(
            a.iter().map(|(t, _)| *t).collect::<Vec<_>>(),
            b.iter().map(|(t, _)| *t).collect::<Vec<_>>(),
            "same seed, same arrivals"
        );
        let mut counts = [0usize; 8];
        for (t, _) in &a {
            counts[*t] += 1;
        }
        assert!(
            counts[0] > counts[7],
            "head tenant must outdraw the tail: {counts:?}"
        );
        assert!(counts.iter().all(|&c| c > 0), "every tenant appears: {counts:?}");
    }

    /// Smoke the measurement path at toy scale: three rows per tenant
    /// count, all events applied, fused steps only in the batched rows.
    #[test]
    fn measure_produces_three_cases_per_tenant_count() {
        let rows = measure(&[4], 48, 1);
        assert_eq!(rows.len(), 3);
        let (batched, rr, budget) = (&rows[0], &rows[1], &rows[2]);
        assert_eq!(batched.schedule, "batched");
        assert_eq!(rr.schedule, "round-robin");
        assert_eq!(budget.schedule, "batched");
        assert_eq!(budget.max_resident, 2);
        for r in &rows {
            assert_eq!(r.tenants, 4);
            assert_eq!(r.events, 48, "{}: every event applies", r.schedule);
            assert!(r.rounds > 0);
            assert!(r.wall_ns > 0);
            assert!(r.events_per_sec > 0.0);
            assert_eq!(r.fused_lane_steps + r.solo_steps, 48, "{}", r.schedule);
        }
        assert!(batched.fused_lane_steps > 0, "shared-seed tenants must fuse");
        assert_eq!(rr.fused_lane_steps, 0, "the baseline never fuses");
        assert!(budget.evictions > 0, "a half-capacity budget must spill");
        assert!(budget.admissions > 0, "…and re-admit");
    }
}
