//! Telemetry-overhead measurement for the bench report: the same
//! reference-scale session driven with telemetry off and on, so the cost
//! of observability is itself part of the tracked perf trajectory
//! (`telemetry` block, schema v5).
//!
//! Two claims are on record here: disabled telemetry costs one branch per
//! step (off ≈ a never-instrumented build), and enabled telemetry's cost
//! is bounded sampling work (clock reads, window folds, the optional
//! influence-panel scan) — never a change in results, which
//! `tests/telemetry.rs` pins bit-exactly.

use crate::config::AlgorithmKind;
use crate::rtrl::Target;
use crate::session::{OnlineSession, SessionBuilder, UpdatePolicy};
use crate::telemetry::{HistogramSummary, TelemetryConfig};
use crate::util::math::sum_f32;
use crate::util::Pcg64;

/// The rep count the bench run uses.
pub const DEFAULT_REPS: usize = 3;
/// Steps driven per timed repetition.
pub const BENCH_STEPS: usize = 64;
/// Metrics-window cadence of the measured session.
pub const BENCH_SAMPLE_EVERY: u64 = 8;

/// Telemetry cost + sampled-series summary on the reference session.
#[derive(Debug, Clone)]
pub struct TelemetryBenchResult {
    /// Steps per timed repetition.
    pub steps: u64,
    /// Best-of-reps wall time per step with telemetry disabled, ns.
    pub ns_per_step_off: u64,
    /// Best-of-reps wall time per step with telemetry enabled
    /// (cadence [`BENCH_SAMPLE_EVERY`], influence measurement on), ns.
    pub ns_per_step_on: u64,
    /// Metric points sampled by the enabled run.
    pub points: u64,
    /// Mean sampled activity sparsity α across those points.
    pub alpha_mean: f32,
    /// Mean sampled pseudo-derivative sparsity β across those points.
    pub beta_mean: f32,
    /// Step-latency histogram summary of the enabled run (self-measured by
    /// the telemetry under test).
    pub latency_ns: HistogramSummary,
}

/// Reference session at bench scale: the paper's combined-sparsity engine,
/// same shape as [`crate::bench::snapshot::measure`]'s checkpoint source.
fn build_session() -> OnlineSession {
    SessionBuilder::new()
        .algorithm(AlgorithmKind::RtrlBoth)
        .hidden(32)
        .param_sparsity(0.8)
        .policy(UpdatePolicy::EveryKSteps(2))
        .build()
}

/// Drive `BENCH_STEPS` deterministic steps; returns total wall ns.
fn drive(session: &mut OnlineSession) -> u64 {
    let mut rng = Pcg64::new(17);
    let t0 = std::time::Instant::now();
    for i in 0..BENCH_STEPS {
        let x = [rng.normal(), rng.normal()];
        let t = if i % 3 == 2 { Target::Class(i % 2) } else { Target::None };
        session.step(&x, t);
    }
    t0.elapsed().as_nanos() as u64
}

/// Measure telemetry-off vs telemetry-on step cost, best-of `reps` fresh
/// sessions each, and summarize the enabled run's sampled series.
pub fn measure(reps: usize) -> TelemetryBenchResult {
    let reps = reps.max(1);
    let mut off_best = u64::MAX;
    for _ in 0..reps {
        let mut s = build_session();
        off_best = off_best.min(drive(&mut s));
    }
    let mut on_best = u64::MAX;
    let mut sampled = None;
    for _ in 0..reps {
        let mut s = build_session();
        s.enable_telemetry(TelemetryConfig {
            sample_every: BENCH_SAMPLE_EVERY,
            ..TelemetryConfig::default()
        });
        on_best = on_best.min(drive(&mut s));
        sampled = Some(s);
    }
    let session = sampled.expect("reps >= 1");
    let tel = session.telemetry().expect("telemetry enabled");
    let points: Vec<_> = tel.points().collect();
    let n = points.len().max(1) as f32;
    TelemetryBenchResult {
        steps: BENCH_STEPS as u64,
        ns_per_step_off: off_best / BENCH_STEPS as u64,
        ns_per_step_on: on_best / BENCH_STEPS as u64,
        points: points.len() as u64,
        alpha_mean: sum_f32(points.iter().map(|p| p.alpha)) / n,
        beta_mean: sum_f32(points.iter().map(|p| p.beta)) / n,
        latency_ns: HistogramSummary::from_histogram(tel.latency_histogram()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_both_modes_and_samples_series() {
        let r = measure(1);
        assert_eq!(r.steps, BENCH_STEPS as u64);
        assert!(r.ns_per_step_off > 0);
        assert!(r.ns_per_step_on > 0);
        // 64 steps at cadence 8 → 8 windows
        assert_eq!(r.points, (BENCH_STEPS as u64) / BENCH_SAMPLE_EVERY);
        assert!((0.0..=1.0).contains(&r.alpha_mean), "alpha {}", r.alpha_mean);
        assert!((0.0..=1.0).contains(&r.beta_mean), "beta {}", r.beta_mean);
        assert_eq!(r.latency_ns.count, BENCH_STEPS as u64);
        assert!(r.latency_ns.max >= r.latency_ns.min);
    }
}
