//! The `bench` subsystem: machine-readable performance trajectory.
//!
//! Sweeps gradient engine × hidden size × depth × parameter sparsity
//! through the unified [`crate::rtrl::GradientEngine`] trait, measuring
//! per-step wall-time alongside the per-phase **and per-layer** MAC/word
//! counters from [`crate::metrics::ops`], and emits a `BENCH_rtrl.json`
//! report that CI uploads on every PR — the repo's perf record across
//! time. The report carries `schema_version` (see [`json::SCHEMA_VERSION`])
//! so downstream perf-trajectory tooling can detect format changes instead
//! of misreading old files.
//!
//! Cases fan out over [`crate::util::pool::run_parallel`]. The default is a
//! single worker (exclusive timing); raising `workers` trades timing noise
//! for throughput, which is what the CI smoke bench (`--quick`) does.
//!
//! Everything here goes through `build_engine` + the trait — adding a new
//! engine automatically adds it to the bench grid.

pub mod json;
pub mod kernels;
pub mod runner;
pub mod serve;
pub mod snapshot;
pub mod telemetry;

use crate::config::AlgorithmKind;
use crate::metrics::Phase;
use crate::util::pool;

pub use kernels::KernelBenchResult;
pub use serve::ServeBenchResult;
pub use snapshot::SnapshotCodecResult;
pub use telemetry::TelemetryBenchResult;

/// Grid + measurement knobs for one bench invocation.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Engines to measure (default: every [`AlgorithmKind`]).
    pub engines: Vec<AlgorithmKind>,
    /// Hidden sizes n (per layer).
    pub hidden_sizes: Vec<usize>,
    /// Stack depths L ≥ 1.
    pub layers: Vec<usize>,
    /// Parameter-sparsity levels ω ∈ [0, 1).
    pub param_sparsities: Vec<f32>,
    /// Sequence length T per repetition (paper: 17).
    pub timesteps: usize,
    /// Timed sequences per case.
    pub sequences: usize,
    /// Untimed warm-up sequences per case.
    pub warmup_sequences: usize,
    /// EGRU threshold ϑ (controls activity sparsity of the bench cell).
    pub theta: f32,
    /// Worker threads for the *case grid* fan-out (0 = available
    /// parallelism; 1 = exclusive timing).
    pub workers: usize,
    /// Worker threads for the *intra-step* kernels of each measured engine
    /// (0 = available parallelism; 1 = serial, the default). Op counts are
    /// identical at any value — CI diffs 1 vs 2 to prove it.
    pub threads: usize,
    /// Shared-weight batch widths (default `[1]`). `rtrl-param` cases run
    /// every width through the batched machinery
    /// ([`crate::rtrl::BatchedSparse`]) — width 1 included, so `--batch 1`
    /// vs `--batch 8` is bit-identical by construction; other engines step
    /// the extra lanes serially (same wall-clock accounting, no fusion).
    /// Op counts and lane-0 gradients are batch-invariant — CI diffs
    /// `--batch 1` vs `--batch 8` to prove it.
    pub batches: Vec<usize>,
    /// Tenant counts for the multi-tenant serve bench (empty = skip the
    /// `serve` block). Each count measures batched vs round-robin vs a
    /// half-capacity resident budget over one identical workload
    /// ([`serve::measure`]).
    pub serve_tenants: Vec<usize>,
    /// Events per serve case.
    pub serve_events: usize,
    /// Intra-step kernel threads of the serve cases (the batched-vs-solo
    /// gate needs ≥ 2: a fused group's panel crosses the kernels' parallel
    /// threshold, a solo session's does not).
    pub serve_threads: usize,
    /// Whether this is the reduced CI grid.
    pub quick: bool,
}

impl BenchConfig {
    /// The full grid: every engine, paper-and-beyond sizes and sparsities.
    pub fn full() -> Self {
        BenchConfig {
            engines: AlgorithmKind::all().to_vec(),
            hidden_sizes: vec![16, 32, 64],
            layers: vec![1, 2],
            param_sparsities: vec![0.0, 0.5, 0.8, 0.9],
            timesteps: 17,
            sequences: 30,
            warmup_sequences: 3,
            theta: 0.1,
            workers: 1,
            threads: 1,
            batches: vec![1],
            serve_tenants: vec![16, 64],
            serve_events: 4096,
            serve_threads: 2,
            quick: false,
        }
    }

    /// The CI smoke grid: every engine, one size, two sparsity levels —
    /// small enough to run on every PR, complete enough to catch a
    /// regression in any engine's hot path.
    pub fn quick() -> Self {
        BenchConfig {
            hidden_sizes: vec![16],
            layers: vec![1],
            param_sparsities: vec![0.0, 0.8],
            sequences: 6,
            warmup_sequences: 1,
            serve_tenants: vec![64],
            serve_events: 1536,
            quick: true,
            ..Self::full()
        }
    }

    /// Expand the grid into concrete cases — batch-major, then size, depth,
    /// sparsity, engine varying fastest — in a deterministic order so
    /// reports diff cleanly between runs. `seed` is the positional index
    /// *within the batch block*: case `i` at every batch width shares one
    /// weight/stream seed, so gradients and op counts are comparable
    /// across widths inside a single report and across separate
    /// single-width invocations alike.
    pub fn expand(&self) -> Vec<BenchCase> {
        let mut cases = Vec::new();
        for &batch in &self.batches {
            let block = cases.len();
            for &hidden in &self.hidden_sizes {
                for &layers in &self.layers {
                    for &omega in &self.param_sparsities {
                        for &engine in &self.engines {
                            cases.push(BenchCase {
                                engine,
                                hidden,
                                layers: layers.max(1),
                                param_sparsity: omega,
                                timesteps: self.timesteps.max(1),
                                sequences: self.sequences.max(1),
                                warmup_sequences: self.warmup_sequences,
                                theta: self.theta,
                                threads: self.threads,
                                batch: batch.max(1),
                                seed: (cases.len() - block) as u64,
                            });
                        }
                    }
                }
            }
        }
        cases
    }
}

/// One (engine, n, L, ω) measurement unit.
#[derive(Debug, Clone)]
pub struct BenchCase {
    pub engine: AlgorithmKind,
    pub hidden: usize,
    /// Stack depth.
    pub layers: usize,
    pub param_sparsity: f32,
    pub timesteps: usize,
    pub sequences: usize,
    pub warmup_sequences: usize,
    pub theta: f32,
    /// Intra-step kernel threads handed to the engine under measurement.
    pub threads: usize,
    /// Shared-weight lanes stepped together (1 = the classic single-lane
    /// case; `rtrl-param` still routes through the batched machinery).
    pub batch: usize,
    /// Deterministic per-case RNG stream id (shared across batch widths).
    pub seed: u64,
}

/// Measured outcome of one case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub engine: &'static str,
    pub hidden: usize,
    /// Stack depth of the bench network.
    pub layers: usize,
    pub param_sparsity: f32,
    pub omega_tilde: f32,
    /// Flat parameter count P of the bench stack.
    pub p: usize,
    pub timesteps: usize,
    pub sequences: usize,
    /// Intra-step kernel threads the engine ran with.
    pub threads: usize,
    /// Shared-weight lanes stepped together (schema v6).
    pub batch: usize,
    /// FNV-1a fingerprint folded over lane-0's end-of-sequence gradient
    /// bit patterns — the batch/thread invariance witness CI diffs
    /// (schema v6; serialized as a decimal string to survive f64 parsers).
    pub grad_fp: u64,
    /// Total timed wall-clock nanoseconds (covers **all** lanes).
    pub wall_ns: u64,
    /// Wall time per lane-step (`wall_ns / (steps · batch)`), so widths
    /// compare directly: batching helps exactly when this drops.
    pub ns_per_step: f64,
    /// Timed throughput, lane-steps per second (`1e9 / ns_per_step`).
    pub steps_per_sec: f64,
    /// Timed throughput, whole sequences per second across all lanes.
    pub seqs_per_sec: f64,
    /// Per-phase MACs per step, indexed like [`Phase::all`].
    pub macs_per_step: [u64; crate::metrics::ops::NUM_PHASES],
    pub macs_per_step_total: u64,
    pub words_per_step_total: u64,
    /// Per-layer MACs per step (layer-attributable charges only).
    pub macs_per_step_per_layer: Vec<u64>,
    /// Per-layer words per step.
    pub words_per_step_per_layer: Vec<u64>,
    /// Live state footprint (Table-1 memory column).
    pub state_memory_words: usize,
    /// Measured mean active-unit fraction α̃.
    pub alpha_tilde: f64,
    /// Measured mean deriv-active fraction β̃.
    pub beta_tilde: f64,
}

/// A full bench run: config echo + every case result.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub quick: bool,
    pub timesteps: usize,
    pub sequences: usize,
    pub workers: usize,
    /// Intra-step kernel threads of the measured engines.
    pub threads: usize,
    /// Seconds since the Unix epoch at report creation.
    pub created_unix: u64,
    pub results: Vec<CaseResult>,
    /// Snapshot-codec cost (encode/decode ns, byte size) per format on the
    /// reference checkpoint — see [`snapshot::measure`]. Schema v4.
    pub snapshot_codecs: Vec<SnapshotCodecResult>,
    /// Telemetry overhead + sampled-series summary on the reference
    /// session — see [`telemetry::measure`]. Schema v5.
    pub telemetry: TelemetryBenchResult,
    /// Per-kernel ns/element at several row densities — see
    /// [`kernels::measure`]. Schema v6.
    pub kernels: Vec<KernelBenchResult>,
    /// Multi-tenant serve loop throughput/latency: batched vs round-robin
    /// vs a resident budget per tenant count — see [`serve::measure`].
    /// Schema v7.
    pub serve: Vec<ServeBenchResult>,
}

impl BenchReport {
    /// Human-readable per-case table (stdout companion of the JSON).
    pub fn summary_table(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "{:<14}{:>6}{:>4}{:>7}{:>4}{:>14}{:>14}{:>16}{:>12}\n",
            "engine", "n", "L", "ω", "B", "ns/step", "steps/s", "MACs/step", "mem words"
        ));
        for r in &self.results {
            s.push_str(&format!(
                "{:<14}{:>6}{:>4}{:>7.2}{:>4}{:>14.1}{:>14.0}{:>16}{:>12}\n",
                r.engine,
                r.hidden,
                r.layers,
                r.param_sparsity,
                r.batch,
                r.ns_per_step,
                r.steps_per_sec,
                r.macs_per_step_total,
                r.state_memory_words,
            ));
        }
        if !self.kernels.is_empty() {
            s.push_str("\nrow kernels (synthetic rows, ns per element):\n");
            s.push_str(&format!(
                "{:<20}{:>9}{:>14}{:>14}\n",
                "kernel", "density", "elements", "ns/elem"
            ));
            for k in &self.kernels {
                s.push_str(&format!(
                    "{:<20}{:>9.2}{:>14}{:>14.3}\n",
                    k.kernel, k.density, k.elements, k.ns_per_element
                ));
            }
        }
        if !self.serve.is_empty() {
            s.push_str("\nserve loop (multi-tenant, shared weights):\n");
            s.push_str(&format!(
                "{:<13}{:>9}{:>10}{:>4}{:>13}{:>12}{:>12}{:>8}{:>8}\n",
                "schedule", "tenants", "resident", "thr", "events/s", "p50 ns", "p99 ns", "evict",
                "admit"
            ));
            for r in &self.serve {
                s.push_str(&format!(
                    "{:<13}{:>9}{:>10}{:>4}{:>13.0}{:>12}{:>12}{:>8}{:>8}\n",
                    r.schedule,
                    r.tenants,
                    r.max_resident,
                    r.threads,
                    r.events_per_sec,
                    r.p50_step_ns,
                    r.p99_step_ns,
                    r.evictions,
                    r.admissions,
                ));
            }
        }
        if !self.snapshot_codecs.is_empty() {
            s.push_str("\nsnapshot codecs (reference checkpoint):\n");
            s.push_str(&format!(
                "{:<10}{:>12}{:>14}{:>14}\n",
                "format", "bytes", "encode ns", "decode ns"
            ));
            for c in &self.snapshot_codecs {
                s.push_str(&format!(
                    "{:<10}{:>12}{:>14}{:>14}\n",
                    c.format, c.bytes, c.encode_ns, c.decode_ns
                ));
            }
        }
        s.push_str(&format!(
            "\ntelemetry overhead (reference session, {} steps): \
             {} ns/step off, {} ns/step on, {} sampled point(s)\n",
            self.telemetry.steps,
            self.telemetry.ns_per_step_off,
            self.telemetry.ns_per_step_on,
            self.telemetry.points
        ));
        s
    }
}

/// Run the full grid over the worker pool. `progress` echoes one line per
/// completed case to stderr.
pub fn run(cfg: &BenchConfig, progress: bool) -> BenchReport {
    let cases = cfg.expand();
    let workers = pool::resolve_workers(cfg.workers);
    let total = cases.len();
    let done = std::sync::atomic::AtomicUsize::new(0);
    let results = pool::run_parallel(cases, workers, |_, case| {
        let r = runner::run_case(&case);
        let i = done.fetch_add(1, std::sync::atomic::Ordering::SeqCst) + 1;
        if progress {
            eprintln!(
                "[bench {}/{}] {} n={} ω={:.2} -> {:.1} ns/step, {} MACs/step",
                i, total, r.engine, r.hidden, r.param_sparsity, r.ns_per_step, r.macs_per_step_total
            );
        }
        r
    });
    BenchReport {
        quick: cfg.quick,
        timesteps: cfg.timesteps,
        sequences: cfg.sequences,
        workers,
        threads: cfg.threads,
        created_unix: std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0),
        results,
        snapshot_codecs: snapshot::measure(snapshot::DEFAULT_REPS),
        telemetry: telemetry::measure(telemetry::DEFAULT_REPS),
        kernels: kernels::measure(kernels::DEFAULT_REPS),
        serve: serve::measure(&cfg.serve_tenants, cfg.serve_events, cfg.serve_threads),
    }
}

/// Name of a phase slot, aligned with [`CaseResult::macs_per_step`].
pub fn phase_name(i: usize) -> &'static str {
    Phase::all()[i].name()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> BenchConfig {
        BenchConfig {
            engines: vec![AlgorithmKind::RtrlDense, AlgorithmKind::RtrlBoth],
            hidden_sizes: vec![6],
            layers: vec![1, 2],
            param_sparsities: vec![0.0, 0.5],
            timesteps: 5,
            sequences: 2,
            warmup_sequences: 1,
            theta: 0.1,
            workers: 2,
            threads: 1,
            batches: vec![1],
            serve_tenants: vec![],
            serve_events: 0,
            serve_threads: 1,
            quick: true,
        }
    }

    #[test]
    fn expand_covers_grid_in_order() {
        let cfg = tiny_cfg();
        let cases = cfg.expand();
        assert_eq!(cases.len(), 2 * 2 * 2);
        assert_eq!(cases[0].engine, AlgorithmKind::RtrlDense);
        assert_eq!(cases[1].engine, AlgorithmKind::RtrlBoth);
        assert_eq!(cases[0].layers, 1);
        assert!((cases[2].param_sparsity - 0.5).abs() < 1e-6);
        assert_eq!(cases[4].layers, 2, "depth axis follows size");
        // seeds are distinct per case
        let mut seeds: Vec<u64> = cases.iter().map(|c| c.seed).collect();
        seeds.dedup();
        assert_eq!(seeds.len(), 8);
    }

    /// The batch axis is outermost and seed-transparent: case `i` of the
    /// width-8 block carries the same weight/stream seed as case `i` of the
    /// width-1 block, so gradients compare across widths within one report.
    #[test]
    fn batch_axis_replicates_the_grid_with_shared_seeds() {
        let mut cfg = tiny_cfg();
        cfg.batches = vec![1, 8];
        let cases = cfg.expand();
        assert_eq!(cases.len(), 16);
        let (b1, b8) = cases.split_at(8);
        assert!(b1.iter().all(|c| c.batch == 1));
        assert!(b8.iter().all(|c| c.batch == 8));
        for (a, b) in b1.iter().zip(b8) {
            assert_eq!(a.seed, b.seed, "twin cases must share a seed");
            assert_eq!(a.engine, b.engine);
            assert_eq!((a.hidden, a.layers), (b.hidden, b.layers));
        }
    }

    #[test]
    fn run_produces_complete_results() {
        let cfg = tiny_cfg();
        let report = run(&cfg, false);
        assert_eq!(report.results.len(), 8);
        assert!(!report.kernels.is_empty(), "v6 reports carry the kernel micro-bench");
        for r in &report.results {
            assert!(r.wall_ns > 0, "{}: no time measured", r.engine);
            assert!(r.macs_per_step_total > 0, "{}: no MACs charged", r.engine);
            assert!(r.state_memory_words > 0);
            assert!(r.ns_per_step.is_finite());
            assert!((0.0..=1.0).contains(&r.alpha_tilde));
            assert!((0.0..=1.0).contains(&r.beta_tilde));
            assert_eq!(r.macs_per_step_per_layer.len(), r.layers);
            assert_eq!(r.words_per_step_per_layer.len(), r.layers);
        }
        // sparse-exact engine at ω=0.5 must charge fewer MACs than dense at
        // the same size — the paper's point, visible in the bench report
        let dense = report
            .results
            .iter()
            .find(|r| r.engine == "rtrl-dense" && r.param_sparsity == 0.0)
            .unwrap();
        let both = report
            .results
            .iter()
            .find(|r| r.engine == "rtrl-both" && r.param_sparsity > 0.0)
            .unwrap();
        assert!(
            both.macs_per_step_total < dense.macs_per_step_total,
            "both {} !< dense {}",
            both.macs_per_step_total,
            dense.macs_per_step_total
        );
    }

    #[test]
    fn summary_table_mentions_every_engine() {
        let report = run(&tiny_cfg(), false);
        let table = report.summary_table();
        assert!(table.contains("rtrl-dense"));
        assert!(table.contains("rtrl-both"));
    }

    /// Acceptance check for the block structure: at depth 2 the sparse
    /// engine's layer-0 counters stay bounded by its own narrow panel —
    /// the cross-layer zero blocks (layer 0 rows × layer 1 columns) are
    /// never charged — while the dense baseline charges layer 0 at the
    /// full P width.
    #[test]
    fn depth2_per_layer_counters_expose_uncharged_zero_blocks() {
        let report = run(&tiny_cfg(), false);
        let both = report
            .results
            .iter()
            .find(|r| r.engine == "rtrl-both" && r.layers == 2 && r.param_sparsity == 0.0)
            .unwrap();
        let dense = report
            .results
            .iter()
            .find(|r| r.engine == "rtrl-dense" && r.layers == 2 && r.param_sparsity == 0.0)
            .unwrap();
        // layer 0's panel tracks only its own p0 columns; layer 1 tracks
        // p0 + p1 — visible directly in the per-layer counters
        assert!(
            both.macs_per_step_per_layer[0] < both.macs_per_step_per_layer[1],
            "layer 0 ({}) should be cheaper than layer 1 ({})",
            both.macs_per_step_per_layer[0],
            both.macs_per_step_per_layer[1]
        );
        // dense pays ≥ the sparse engine in every layer
        for l in 0..2 {
            assert!(dense.macs_per_step_per_layer[l] >= both.macs_per_step_per_layer[l]);
        }
    }
}
